# Developer entry points.  PYTHONPATH=src is the only environment the repo
# needs; everything runs on a CPU-only host (kernels interpret via Pallas).

PY ?= python
export PYTHONPATH := src

.PHONY: test test-diff test-chaos bench-smoke bench bench-json perf-gate \
	trace-demo clean-cache

# tier-1 verify: the gate every PR must keep green (collects the
# differential suite too — test-diff is the focused entry point)
test:
	$(PY) -m pytest -x -q

# differential/property harness: seeded random workloads replayed through
# scalar-vs-batched fault paths and untiered/2-tier/4-tier managers.  The
# generating seed is part of each test id (shown on failure); add seeds
# with DIFF_SEEDS=7,8 make test-diff
test-diff:
	$(PY) -m pytest -q -m differential tests/test_differential.py

# resilience/chaos lane: seeded failure schedules through the containment
# machinery — injector determinism, quarantine/backoff, supervisor detach,
# degraded engine modes, and the chaos differential (identical failure
# schedule across scalar/batched routes => bit-identical KV + end state)
test-chaos:
	$(PY) -m pytest -q -m chaos

# tier-1 tests + the tiered-memory capacity sweep in smoke mode
bench-smoke: test
	$(PY) -m benchmarks.capacity_sweep --smoke

# full benchmark harness (fig2 policy sweep, capacity sweep, hot path, VM,
# kernels)
bench:
	$(PY) -m benchmarks.run

# hot-path perf artifact: BENCH_hotpath.json (steps/s, faults/s,
# policy-invocations/step, mgmt_ns, wall_host_s; scalar vs batched per
# policy and batch size) — the perf trajectory tracked from PR 2 onward
bench-json:
	$(PY) -m benchmarks.hotpath_bench --json BENCH_hotpath.json
	$(PY) -m benchmarks.prefix_bench --json BENCH_prefix.json
	$(PY) -m benchmarks.profile_bench --json BENCH_profile.json

# CI perf gates: zero-cost claims (telemetry off / resilience disarmed
# within 2% of baseline) + the one-dispatch hot path (batched ebpf@b16
# steps/s within 2% of the committed BENCH_hotpath.json, fused executor
# still issuing <= 1 dispatch/step, steady-state table crossings zero)
perf-gate:
	$(PY) -m benchmarks.telemetry_gate
	$(PY) -m benchmarks.hotpath_gate
	$(PY) -m benchmarks.prefix_gate
	$(PY) -m benchmarks.profile_gate

# telemetry demo: serve a tiered smoke workload with ONLINE profiling and
# tracing on; writes out/trace_demo.json (load in ui.perfetto.dev — the
# "mm profiler" track carries per-process WSS counters and profile-reload
# instants), a Prometheus-style metrics snapshot, and the profiler's
# WSS-curve dump — the artifacts CI uploads per run
trace-demo:
	mkdir -p out
	$(PY) examples/serve_paged.py --requests 4 --hbm-blocks 64 \
		--host-blocks 128 --profile auto \
		--trace out/trace_demo.json \
		--metrics out/metrics_demo.txt \
		--wss-curve out/wss_demo.json

# drop the cross-session compiler-artifact cache (pickled lowering/unroll
# artifacts + persisted XLA executables under .cache/); everything rebuilds
# cold on the next run — use after suspicious cache behavior or to measure
# cold-start costs.  REPRO_CACHE_DIR overrides the location; =off disables.
clean-cache:
	rm -rf .cache
