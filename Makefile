# Developer entry points.  PYTHONPATH=src is the only environment the repo
# needs; everything runs on a CPU-only host (kernels interpret via Pallas).

PY ?= python
export PYTHONPATH := src

.PHONY: test bench-smoke bench bench-json

# tier-1 verify: the gate every PR must keep green
test:
	$(PY) -m pytest -x -q

# tier-1 tests + the tiered-memory capacity sweep in smoke mode
bench-smoke: test
	$(PY) -m benchmarks.capacity_sweep --smoke

# full benchmark harness (fig2 policy sweep, capacity sweep, hot path, VM,
# kernels)
bench:
	$(PY) -m benchmarks.run

# hot-path perf artifact: BENCH_hotpath.json (steps/s, faults/s,
# policy-invocations/step, mgmt_ns, wall_host_s; scalar vs batched per
# policy and batch size) — the perf trajectory tracked from PR 2 onward
bench-json:
	$(PY) -m benchmarks.hotpath_bench --json BENCH_hotpath.json
