# Developer entry points.  PYTHONPATH=src is the only environment the repo
# needs; everything runs on a CPU-only host (kernels interpret via Pallas).

PY ?= python
export PYTHONPATH := src

.PHONY: test bench-smoke bench

# tier-1 verify: the gate every PR must keep green
test:
	$(PY) -m pytest -x -q

# tier-1 tests + the tiered-memory capacity sweep in smoke mode
bench-smoke: test
	$(PY) -m benchmarks.capacity_sweep --smoke

# full benchmark harness (fig2 policy sweep, capacity sweep, VM, kernels)
bench:
	$(PY) -m benchmarks.run
