"""Shared fixtures. NOTE: device count stays 1 here — only launch/dryrun.py
forces 512 host devices, per the dry-run contract."""

import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


@pytest.fixture(autouse=True)
def _np_seed():
    np.random.seed(0)
