"""Batched fault path — parity and equivalence guarantees.

* ctx-matrix parity: the vectorized batch builder must reproduce the scalar
  ``_build_ctx`` rows bit-for-bit (one snapshot, vectorized DAMON heat).
* executor parity: interpreter == JIT == predicated decisions for every
  shipped program over randomized ctx batches.
* end-state equivalence: ``fault_batch`` == sequential ``ensure_mapped``
  (page tables, stats, move lists) when decisions don't depend on mid-batch
  allocator drift.
* engine accounting: with a fault program attached, a decode step issues
  exactly ONE ``HOOK_FAULT`` batch invocation.
* incremental block tables stay consistent with a from-scratch rebuild
  across install/unmap/collapse/compaction/migration.
"""

import numpy as np
import pytest

from repro.core import (ArrayMap, HWSpec, JitPolicy, MapRegistry,
                        MemoryManager, PolicyVM, PredicatedPolicy, Profile,
                        ProfileRegion, TieredMemoryManager, ebpf_mm_program,
                        make_cost_model, never_program, reclaim_lru_program,
                        thp_always_program, tier_damon_program,
                        tier_lru_program, tier_never_program)
from repro.core.buddy import order_blocks
from repro.core.context import CTX, CTX_LEN, FaultContext, FaultKind
from repro.core.hooks import HOOK_FAULT
from repro.core.tiering import TIER_HOST


def mk_mm(num_blocks=2048, default="thp", *, tiered=False, host=256,
          profile=None, program=None):
    cost = make_cost_model(HWSpec(), kv_heads=8, head_dim=128)
    if tiered:
        mm = TieredMemoryManager(num_blocks, cost, host_blocks=host,
                                 default_mode=default)
    else:
        mm = MemoryManager(num_blocks, cost, default_mode=default)
    if profile is not None:
        mm.load_profile(profile)
    if program is not None:
        mm.attach_fault_program(program)
    return mm


def striped_profile(app="app", blocks=256, nreg=8):
    bounds = np.linspace(0, blocks, nreg + 1).astype(int)
    regions = [ProfileRegion(int(a), int(b),
                             (0, 150_000, 0, 0) if i % 2 == 0
                             else (0, 0, 0, 0))
               for i, (a, b) in enumerate(zip(bounds, bounds[1:])) if b > a]
    return Profile(app, regions)


def reference_block_table(mm, pid, max_blocks):
    """From-scratch rebuild (the seed implementation) as the oracle."""
    st = mm.procs[pid]
    t = np.full(max_blocks, -1, dtype=np.int32)
    for m in st.page_table.values():
        size = order_blocks(m.order)
        hi = min(m.logical_start + size, max_blocks)
        base = mm._device_index(m)
        for i in range(m.logical_start, hi):
            t[i] = base + (i - m.logical_start)
    return t


class TestCtxBatchParity:
    def test_rows_match_scalar_builder(self):
        mm = mk_mm(profile=striped_profile(),
                   program=ebpf_mm_program(max_regions=8))
        rng = np.random.default_rng(0)
        mm.create_process(1, app="app", vma_blocks=256)
        mm.create_process(2, app=None, vma_blocks=100)
        mm.ensure_range(1, 0, 40)
        mm.ensure_range(2, 0, 10)
        mm.record_access(1, rng.random(256) * 3)
        mm.record_access(2, rng.random(100))
        mm.tick()
        reqs = [(1, int(a), FaultKind.FIRST_TOUCH)
                for a in rng.integers(0, 256, 12)]
        reqs += [(2, int(a), FaultKind.PREFILL)
                 for a in rng.integers(0, 100, 7)]
        mat = mm._build_ctx_batch(reqs)
        assert mat.shape == (len(reqs), CTX_LEN)
        for row, (pid, addr, kind) in zip(mat, reqs):
            ref = mm._build_ctx(mm.procs[pid], addr, kind)
            # scalar builder has no batch, so its reservation column is 0;
            # every other column must match bit for bit
            row = row.copy()
            row[CTX.BATCH_RESERVED] = 0
            np.testing.assert_array_equal(row, ref)
        # the reservation column is the exclusive running sum of the worst
        # case grant (4^fault_max_order base blocks) of the earlier rows
        grants = 4 ** mat[:, CTX.FAULT_MAX_ORDER]
        expect = np.concatenate([[0], np.cumsum(grants)[:-1]])
        np.testing.assert_array_equal(mat[:, CTX.BATCH_RESERVED], expect)

    @pytest.mark.parametrize("max_order", [1, 2, 3])
    def test_vectorized_fault_max_orders(self, max_order):
        cost = make_cost_model(HWSpec(), kv_heads=8, head_dim=128)
        mm = MemoryManager(2048, cost, default_mode="never",
                           max_order=max_order)
        mm.create_process(1, vma_blocks=200)
        rng = np.random.default_rng(1)
        for a in rng.integers(0, 200, 60):
            if int(a) not in mm.procs[1].mapped:
                mm.ensure_mapped(1, int(a))
        addrs = [int(a) for a in np.arange(200) if a not in mm.procs[1].mapped]
        reqs = [(1, a, FaultKind.FIRST_TOUCH) for a in addrs]
        vec = mm._build_ctx_batch(reqs)[:, CTX.FAULT_MAX_ORDER]
        ref = [mm.fault_max_order(mm.procs[1], a) for a in addrs]
        np.testing.assert_array_equal(vec, ref)


def _random_ctx_batch(rng, n, *, nregions=0, map_id=0):
    rows = []
    for _ in range(n):
        fc = FaultContext(
            addr=int(rng.integers(0, 256)), pid=int(rng.integers(1, 9)),
            vma_start=0, vma_end=int(rng.integers(1, 257)),
            fault_max_order=int(rng.integers(0, 4)),
            has_profile=int(nregions > 0 and rng.random() < 0.8),
            profile_map_id=map_id, profile_nregions=nregions,
            free_blocks=tuple(rng.integers(0, 200, 4)),
            frag=tuple(rng.integers(0, 1001, 4)),
            heat=tuple(rng.integers(0, 50, 4)),
            zero_ns_per_block=int(rng.integers(100, 2000)),
            compact_ns_per_block=int(rng.integers(100, 3000)),
            descriptor_ns=800, block_bytes=65536,
            ktime_ns=int(rng.integers(0, 10 ** 9)),
            mem_pressure=int(rng.integers(0, 1001)),
            fault_kind=int(rng.integers(0, 3)),
            seq_len=int(rng.integers(0, 257)),
            tier_free_blocks=int(rng.integers(0, 300)),
            tier_total_blocks=256,
            tier_pressure=int(rng.integers(0, 1001)),
            pcie_ns_per_block=int(rng.integers(100, 4000)),
            page_tier=int(rng.integers(0, 2)),
            page_order=int(rng.integers(0, 4)),
            page_age=int(rng.integers(0, 20)),
            page_heat=int(rng.integers(0, 5000)),
            migrate_setup_ns=2000,
            migrate_ns_per_block=int(rng.integers(500, 5000)))
        rows.append(fc.vector())
    return np.stack(rows)


class TestExecutorParity:
    """interpreter == JIT == predicated for every shipped program."""

    @pytest.mark.parametrize("name,make,with_profile", [
        ("ebpf_mm", lambda: ebpf_mm_program(max_regions=8), True),
        ("thp_always", thp_always_program, False),
        ("never", never_program, False),
        ("reclaim_lru", reclaim_lru_program, False),
        ("tier_damon", tier_damon_program, False),
        ("tier_lru", tier_lru_program, False),
        ("tier_never", tier_never_program, False),
    ])
    def test_all_executors_agree(self, name, make, with_profile):
        rng = np.random.default_rng(hash(name) % (2 ** 31))
        maps = MapRegistry()
        nregions = 0
        if with_profile:
            m = ArrayMap(64)
            striped_profile(blocks=256, nreg=8).load_into(m)
            maps.register(m)
            nregions = 8
        prog = make()
        mat = _random_ctx_batch(rng, 24, nregions=nregions)
        vm = PolicyVM(prog, maps)
        host = [vm.run(row).ret for row in mat]
        jit = JitPolicy(prog, maps).run_batch(mat)
        pred = PredicatedPolicy(prog, maps).run_batch(mat)
        assert host == list(jit), f"{name}: interpreter != JIT"
        assert host == list(pred), f"{name}: interpreter != predicated"


def _state(mm):
    tables = {pid: sorted((m.logical_start, m.phys_start, m.order, m.tier)
                          for m in st.page_table.values())
              for pid, st in mm.procs.items()}
    mapped = {pid: sorted(st.mapped) for pid, st in mm.procs.items()}
    return tables, mapped, mm.stats.snapshot(), mm.drain_moves(), \
        sorted(mm.buddy.allocated.items())


class TestFaultBatchEquivalence:
    """fault_batch == sequential ensure_mapped end state (ample pool, so
    policy decisions can't depend on mid-batch allocator drift)."""

    def _pair(self, **kw):
        return mk_mm(**kw), mk_mm(**kw)

    @pytest.mark.parametrize("default", ["thp", "never"])
    def test_decode_crossings_default_paths(self, default):
        a, b = self._pair(default=default)
        for mm in (a, b):
            for pid in range(1, 5):
                mm.create_process(pid, vma_blocks=64)
                mm.ensure_range(pid, 0, 8)
        reqs = [(pid, 8, FaultKind.FIRST_TOUCH) for pid in range(1, 5)]
        a.fault_batch(reqs)
        for pid, addr, kind in reqs:
            b.ensure_mapped(pid, addr, kind)
        assert _state(a) == _state(b)

    def test_prefill_range_with_program(self):
        kw = dict(profile=striped_profile(),
                  program=ebpf_mm_program(max_regions=8))
        a, b = self._pair(**kw)
        for mm in (a, b):
            mm.create_process(1, app="app", vma_blocks=256)
        ra = a.fault_range(1, 0, 96)
        rb = b.ensure_range(1, 0, 96)
        assert [(r.order, r.phys_start, r.hinted) for r in ra] == \
            [(r.order, r.phys_start, r.hinted) for r in rb]
        assert _state(a) == _state(b)

    def test_mixed_pids_with_program(self):
        kw = dict(profile=striped_profile(),
                  program=ebpf_mm_program(max_regions=8))
        a, b = self._pair(**kw)
        rng = np.random.default_rng(3)
        for mm in (a, b):
            for pid in (1, 2, 3):
                mm.create_process(pid, app="app", vma_blocks=256)
                mm.ensure_range(pid, 0, 16)
                mm.record_access(pid, rng.random(64))
        rng = np.random.default_rng(4)
        reqs = [(int(p), int(ad), FaultKind.FIRST_TOUCH)
                for p, ad in zip(rng.integers(1, 4, 20),
                                 rng.integers(0, 256, 20))]
        a.fault_batch(reqs)
        for pid, addr, kind in reqs:
            b.ensure_mapped(pid, addr, kind)
        assert _state(a) == _state(b)

    def test_already_mapped_returns_none_without_invocation(self):
        mm = mk_mm(profile=striped_profile(),
                   program=ebpf_mm_program(max_regions=8))
        mm.create_process(1, app="app", vma_blocks=64)
        mm.fault_range(1, 0, 16)
        calls0 = mm.hooks.calls[HOOK_FAULT]
        res = mm.fault_batch([(1, 3, FaultKind.FIRST_TOUCH)])
        assert res == [None]
        assert mm.hooks.calls[HOOK_FAULT] == calls0   # nothing pending


class TestBlockTableConsistency:
    def test_randomized_ops_keep_table_in_sync(self):
        rng = np.random.default_rng(7)
        mm = mk_mm(num_blocks=64, default="never", tiered=True, host=64)
        mm.create_process(1, vma_blocks=64)
        mm.create_process(2, vma_blocks=32)
        for _ in range(300):
            pid = int(rng.integers(1, 3))
            st = mm.procs[pid]
            op = rng.random()
            try:
                if op < 0.45:
                    mm.ensure_mapped(pid, int(rng.integers(0, st.vma_end)))
                elif op < 0.6 and st.page_table:
                    lg = list(st.page_table)[
                        int(rng.integers(0, len(st.page_table)))]
                    mm.unmap(pid, lg)
                elif op < 0.75 and st.page_table:
                    lg = list(st.page_table)[
                        int(rng.integers(0, len(st.page_table)))]
                    mm.demote_page(pid, lg)
                elif op < 0.9 and st.page_table:
                    lg = list(st.page_table)[
                        int(rng.integers(0, len(st.page_table)))]
                    mm.promote_page(pid, lg)
                else:
                    mm.collapse(pid, int(rng.integers(0, st.vma_end)), 1)
            except Exception:
                pass   # OOM etc — state must still be consistent
            for p in (1, 2):
                np.testing.assert_array_equal(
                    mm.block_table(p, 64), reference_block_table(mm, p, 64))
        # metadata arrays agree with the oracle too
        for p in (1, 2):
            starts, sizes, orders, tiers, dev = \
                mm._mapping_arrays(mm.procs[p])
            ms = mm.procs[p].mappings_sorted()
            assert list(starts) == [m.logical_start for m in ms]
            assert list(orders) == [m.order for m in ms]
            assert list(tiers) == [m.tier for m in ms]
            assert list(dev) == [mm._device_index(m) for m in ms]

    def test_compaction_keeps_table_in_sync(self):
        mm = mk_mm(num_blocks=64, default="never")
        mm.create_process(1, vma_blocks=64)
        mm.ensure_range(1, 0, 48)
        for lg in list(mm.procs[1].page_table)[::2]:
            mm.unmap(1, lg)
        mm._install(mm.procs[1], 60, 2, hinted=False)   # forces compaction
        np.testing.assert_array_equal(
            mm.block_table(1, 64), reference_block_table(mm, 1, 64))


class TestEngineInvocationAccounting:
    """The acceptance property: with a fault program attached, a full decode
    step issues exactly ONE HOOK_FAULT batch invocation — and the scalar
    run() entry point never fires from the engine."""

    @pytest.fixture(scope="class")
    def setup(self):
        import jax
        from repro.configs.base import get_smoke_config
        from repro.models import PagedLayout, materialize, model_spec
        cfg = get_smoke_config("deepseek_7b")
        params = materialize(jax.random.PRNGKey(0), model_spec(cfg))
        layout = PagedLayout(num_blocks=256, block_tokens=4, max_blocks=32)
        return cfg, params, layout

    def _engine(self, setup, **kw):
        from repro.serving import Request, ServingEngine
        cfg, params, layout = setup
        # never-prog: base pages only, so every block boundary crossing is a
        # fault — with 4 slots in lockstep, decode steps carry multiple
        # faults for one invocation to amortize
        eng = ServingEngine(cfg, params, layout, max_batch=4,
                            policy="never-prog", **kw)
        rng = np.random.default_rng(0)
        for r in range(4):
            eng.submit(Request(rid=r,
                               prompt=rng.integers(1, cfg.vocab, 18).tolist(),
                               max_new_tokens=12))
        return eng

    def test_one_batch_invocation_per_decode_step(self, setup):
        eng = self._engine(setup)
        hooks = eng.mm.hooks
        total_faults = 0
        steps_with_fault = 0
        for _ in range(40):
            calls0 = hooks.calls[HOOK_FAULT]
            batch0 = hooks.batch_calls[HOOK_FAULT]
            faults0 = eng.mm.stats.faults
            if not eng.active:
                if not eng.step():       # admission steps may batch prefill
                    break
                continue
            eng._decode_once()
            dcalls = hooks.batch_calls[HOOK_FAULT] - batch0
            dfaults = eng.mm.stats.faults - faults0
            assert dcalls <= 1, "a decode step must batch all its faults"
            if dfaults > 0:
                assert dcalls == 1
                steps_with_fault += 1
            total_faults += dfaults
            # every invocation was a batch one — no scalar run() on faults
            assert hooks.calls[HOOK_FAULT] - calls0 == dcalls
        assert steps_with_fault > 0 and total_faults > steps_with_fault, \
            "workload must exercise multi-fault steps"

    def test_scalar_mode_never_batches(self, setup):
        eng = self._engine(setup, batch_faults=False)
        eng.run(max_steps=30)
        hooks = eng.mm.hooks
        assert hooks.batch_calls[HOOK_FAULT] == 0
        assert hooks.calls[HOOK_FAULT] == hooks.invocations[HOOK_FAULT] > 0

    def test_batched_and_scalar_engines_agree(self, setup):
        from repro.core import Profile, ProfileRegion
        from repro.serving import Request, ServingEngine
        cfg, params, layout = setup
        prof = Profile("chat", [
            ProfileRegion(0, 8, (0, 150_000, 600_000, 2_500_000)),
            ProfileRegion(8, 32, (0, 0, 0, 0))])
        outs = {}
        for batched in (True, False):
            eng = ServingEngine(cfg, params, layout, max_batch=2,
                                policy="ebpf", profile=prof,
                                batch_faults=batched)
            rng = np.random.default_rng(0)
            for r in range(3):
                eng.submit(Request(
                    rid=r, prompt=rng.integers(1, cfg.vocab, 22).tolist(),
                    max_new_tokens=10, app="chat"))
            eng.run(max_steps=200)
            outs[batched] = (dict(eng.finished),
                             eng.mm.stats.snapshot()["pages_per_order"])
        assert outs[True] == outs[False]


class TestPredicatedUnrollBoundary:
    """Regression guards at the predicated-executor segment budget.

    Since the unified pipeline's SEGMENTED unroll, a program over the
    512-insn budget no longer falls back to the while+switch JIT: its
    flattened code splits into predicated segments chained by the dispatch
    loop.  These guards pin that routing AND the decisions:

    1. The default 64-region Fig-1 program (900 unrolled insns) routes
       through the segmented predicated executor — multiple segments, no
       JIT fallback — with decisions identical to interpreter and JIT.
    2. At EXACTLY the 512-insn boundary the compile is a single segment;
       one insn over becomes two segments; decisions never change across
       the cut.
    """

    @staticmethod
    def _boundary_program(body_n=100, trips=5, pad=0):
        """Unrolls to exactly 2 + trips*(body_n+1) + pad + 5 insns: a
        verifier-bounded counting loop plus a ctx-dependent tail so
        decisions vary per row."""
        from repro.core import Asm
        a = Asm()
        a.movi("r4", 0)
        a.movi("r3", trips)
        a.label("loop")
        for _ in range(body_n):
            a.addi("r4", 1)
        a.jnzdec("r3", "loop")
        for _ in range(pad):
            a.movi("r6", 0)
        a.ldctx("r5", CTX.ADDR)
        a.andi("r5", 3)
        a.add("r4", "r5")
        a.mov("r0", "r4")
        a.exit()
        return a.build(f"boundary_pad{pad}")

    def test_default_fig1_program_routes_segmented(self):
        from repro.core.hooks import PRED_MAX_UNROLL, HookRegistry
        from repro.core.predicate import unroll
        maps = MapRegistry()
        m = ArrayMap(64)
        striped_profile(blocks=256, nreg=8).load_into(m)
        maps.register(m)
        prog = ebpf_mm_program()           # full 64-region search loop
        assert len(unroll(prog, maps)) > PRED_MAX_UNROLL, \
            "the default Fig-1 program now fits one predicated segment — " \
            "update these guards"
        reg = HookRegistry()
        reg.attach(HOOK_FAULT, prog, maps)
        rng = np.random.default_rng(11)
        mat = _random_ctx_batch(rng, 8, nregions=8)
        out = reg.run_batch(HOOK_FAULT, mat)
        ap = reg._hooks[HOOK_FAULT]
        assert ap.pred is not None and not ap.pred_unfit, \
            "the realistic Fig-1 profile must take the segmented fast path"
        assert ap.pred.num_segments >= 2, \
            "over-budget program must be split into chained segments"
        assert ap.jit is None, "no JIT fallback for the default profile"
        vm = PolicyVM(prog, maps)
        host = [vm.run(row).ret for row in mat]
        assert host == list(out), "segmented executor changed decisions"
        assert host == list(JitPolicy(prog, maps).run_batch(mat)), \
            "segmented != JIT for the default Fig-1 program"

    def test_executor_parity_at_unroll_boundary(self):
        from repro.core.hooks import PRED_MAX_UNROLL, HookRegistry
        from repro.core.predicate import unroll
        maps = MapRegistry()
        at = self._boundary_program(pad=0)
        over = self._boundary_program(pad=2)
        assert len(unroll(at, maps)) == PRED_MAX_UNROLL
        assert len(unroll(over, maps)) == PRED_MAX_UNROLL + 2
        rng = np.random.default_rng(12)
        mat = _random_ctx_batch(rng, 8)
        for prog, want_segments in ((at, 1), (over, 2)):
            reg = HookRegistry()
            reg.attach(HOOK_FAULT, prog, maps)
            out = reg.run_batch(HOOK_FAULT, mat)
            ap = reg._hooks[HOOK_FAULT]
            assert ap.pred is not None and not ap.pred_unfit, \
                f"{prog.name}: predicated route must serve both sides"
            assert ap.pred.num_segments == want_segments, \
                f"{prog.name}: wrong segment count at the 512-insn boundary"
            vm = PolicyVM(prog, maps)
            host = [vm.run(row).ret for row in mat]
            assert host == list(out), \
                f"{prog.name}: boundary backend changed decisions"
            assert host == list(JitPolicy(prog, maps).run_batch(mat)), \
                f"{prog.name}: interpreter != JIT at the boundary"

    def test_fused_scan_executor_at_unroll_boundary(self):
        """The lax.scan segment executor alongside the chained plan: both
        sides of the 512/514 boundary factor their unrolled counting loop
        into a scanned copy body whose traced length fits ONE fused
        dispatch — while ``num_segments`` still reports the chained plan the
        guards above pin — and decisions stay bit-identical to the
        interpreter."""
        from repro.core.hooks import PRED_MAX_UNROLL
        maps = MapRegistry()
        rng = np.random.default_rng(13)
        mat = _random_ctx_batch(rng, 8)
        for pad, want_segments in ((0, 1), (2, 2)):
            prog = self._boundary_program(pad=pad)
            pol = PredicatedPolicy(prog, maps, seg_limit=PRED_MAX_UNROLL)
            assert pol.num_segments == want_segments, \
                f"{prog.name}: chained plan changed at the boundary"
            assert pol.fused and pol.scan_stages >= 1, \
                f"{prog.name}: loop copies not factored into a lax.scan"
            assert pol.traced_len < pol.unrolled_len, \
                f"{prog.name}: scan factoring did not compress the trace"
            assert pol.dispatches == 1, \
                f"{prog.name}: fused executor must cost one dispatch"
            vm = PolicyVM(prog, maps)
            host = [vm.run(row).ret for row in mat]
            before = pol.total_dispatches
            out = pol.run_batch(mat)
            assert host == list(out), \
                f"{prog.name}: fused scan executor changed decisions"
            assert pol.total_dispatches == before + 1, \
                f"{prog.name}: fused run_batch issued extra dispatches"


class TestTierCtxCache:
    def _mk(self):
        mm = mk_mm(num_blocks=64, default="never", tiered=True, host=64)
        mm.attach_tier_program(tier_damon_program())
        mm.create_process(1, vma_blocks=32)
        mm.ensure_range(1, 0, 32)
        for lg in list(mm.procs[1].page_table)[:12]:
            mm.demote_page(1, lg)
        mm.tick()
        return mm

    def test_batch_rows_match_scalar_tier_ctx(self):
        mm = self._mk()
        mm.record_access(1, np.arange(32, dtype=float))
        cands = [(mm.procs[1], m) for m in mm.procs[1].mappings_sorted()]
        mat = mm._tier_ctx_batch(cands)
        for row, (st, m) in zip(mat, cands):
            np.testing.assert_array_equal(row, mm._tier_ctx(st, m))

    def test_scan_ctx_reused_until_heat_changes(self):
        mm = self._mk()
        mm.promotion_scan(0)      # budget 0: decisions run, nothing moves
        misses0 = mm.ctx_cache_misses
        assert misses0 >= 1
        mm.tick()
        mm.promotion_scan(0)      # same candidates, same DAMON -> cache hit
        assert mm.ctx_cache_hits >= 1
        assert mm.ctx_cache_misses == misses0
        mm.record_access(1, np.ones(32))   # DAMON changed -> rebuild
        mm.promotion_scan(0)
        assert mm.ctx_cache_misses > misses0

    def test_cached_scan_decisions_match_fresh(self):
        mm = self._mk()
        mm.promotion_scan(0)
        mm.tick()
        cands = [(mm.procs[1], m) for m in mm.procs[1].mappings_sorted()
                 if m.tier == TIER_HOST]
        cached = mm.tier_decisions(cands, scan="promote")
        fresh = mm.tier_decisions(cands)          # no cache slot
        assert cached == fresh
