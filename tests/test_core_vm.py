"""Policy VM: ISA semantics, verifier guarantees, interpreter == XLA JIT.

Fuzz tests use a seeded numpy RNG (the container has no hypothesis)."""

import numpy as np
import pytest

from repro.core import (CTX, CTX_LEN, Asm, ArrayMap, FaultContext, FaultKind,
                        JitPolicy, MapRegistry, PolicyVM, Profile,
                        ProfileRegion, VerifierError, ebpf_mm_program,
                        never_program, thp_always_program)
from repro.core.isa import MAX_LOOP_ITERS, Op
from repro.core.vm import HELPER_PROMOTION_COST


def make_ctx(**kw) -> np.ndarray:
    fc = FaultContext(
        addr=kw.get("addr", 10), pid=1, vma_start=0,
        vma_end=kw.get("vma_end", 4096),
        fault_max_order=kw.get("fmax", 3),
        has_profile=kw.get("has_profile", 1), profile_map_id=0,
        profile_nregions=kw.get("nregions", 0),
        free_blocks=kw.get("free", (100, 25, 6, 1)),
        frag=kw.get("frag", (0, 100, 400, 900)),
        heat=kw.get("heat", (5, 5, 5, 5)),
        zero_ns_per_block=kw.get("zero", 700),
        compact_ns_per_block=kw.get("compact", 1300),
        descriptor_ns=800, block_bytes=65536)
    return fc.vector()


class TestInterpreter:
    def test_alu_semantics(self):
        a = Asm()
        a.movi("r1", 7).movi("r2", -3)
        a.mul("r1", "r2")          # -21
        a.movi("r3", 4)
        a.div("r1", "r3")          # -5 (trunc toward zero)
        a.movi("r4", 0)
        a.div("r1", "r4")          # /0 -> 0
        a.addi("r1", 41)
        a.mov("r0", "r1")
        a.exit()
        vm = PolicyVM(a.build(), MapRegistry())
        assert vm.run(make_ctx()).ret == 41

    def test_mod_zero_keeps_lhs(self):
        a = Asm()
        a.movi("r1", 13).movi("r2", 0).mod("r1", "r2").mov("r0", "r1").exit()
        assert PolicyVM(a.build(), MapRegistry()).run(make_ctx()).ret == 13

    def test_wrapping_64bit(self):
        a = Asm()
        a.movi("r1", (1 << 62)).movi("r2", 4).mul("r1", "r2")
        a.mov("r0", "r1").exit()
        assert PolicyVM(a.build(), MapRegistry()).run(make_ctx()).ret == 0

    def test_bounded_loop_sum(self):
        a = Asm()
        a.movi("r0", 0).movi("r1", 10)
        a.label("loop")
        a.addi("r0", 2)
        a.jnzdec("r1", "loop")
        a.exit()
        assert PolicyVM(a.build(), MapRegistry()).run(make_ctx()).ret == 20

    def test_map_lookup_oob_returns_zero(self):
        maps = MapRegistry()
        m = ArrayMap(8)
        m.load([11, 22, 33])
        maps.register(m)
        a = Asm()
        a.movi("r1", 99).ldmap("r0", 0, "r1").exit()
        assert PolicyVM(a.build(), maps).run(make_ctx()).ret == 0
        b = Asm()
        b.movi("r1", 1).ldmap("r0", 0, "r1").exit()
        assert PolicyVM(b.build(), maps).run(make_ctx()).ret == 22

    def test_promotion_cost_helper(self):
        a = Asm()
        a.movi("r1", 2).call(HELPER_PROMOTION_COST).exit()
        ctx = make_ctx(free=(10, 10, 5, 1), zero=700)
        # free order-2 pages exist -> zeroing only: 700 * 16
        assert PolicyVM(a.build(), MapRegistry()).run(ctx).ret == 700 * 16
        ctx2 = make_ctx(free=(10, 10, 0, 1), zero=700, compact=1300,
                        frag=(0, 0, 500, 0))
        want = 700 * 16 + 1300 * 16 * 1500 // 1000
        assert PolicyVM(a.build(), MapRegistry()).run(ctx2).ret == want


class TestVerifier:
    def test_rejects_uninit_read(self):
        a = Asm()
        a.mov("r0", "r5").exit()
        with pytest.raises(VerifierError, match="uninitialized"):
            PolicyVM(a.build(), MapRegistry())

    def test_rejects_oob_ctx(self):
        a = Asm()
        a.ldctx("r0", CTX_LEN + 3).exit()
        with pytest.raises(VerifierError, match="ctx offset"):
            PolicyVM(a.build(), MapRegistry())

    def test_rejects_unknown_map(self):
        a = Asm()
        a.movi("r1", 0).ldmap("r0", 5, "r1").exit()
        with pytest.raises(VerifierError, match="map id"):
            PolicyVM(a.build(), MapRegistry())

    def test_rejects_unbounded_loop(self):
        a = Asm()
        a.ldctx("r1", CTX.ADDR)      # counter not a tracked constant
        a.movi("r0", 0)
        a.label("loop")
        a.addi("r0", 1)
        a.jnzdec("r1", "loop")
        a.exit()
        with pytest.raises(VerifierError, match="constant"):
            PolicyVM(a.build(), MapRegistry())

    def test_rejects_excessive_trip_count(self):
        a = Asm()
        a.movi("r1", MAX_LOOP_ITERS + 1).movi("r0", 0)
        a.label("loop")
        a.addi("r0", 1)
        a.jnzdec("r1", "loop")
        a.exit()
        with pytest.raises(VerifierError, match="trip count"):
            PolicyVM(a.build(), MapRegistry())

    def test_rejects_counter_clobber(self):
        a = Asm()
        a.movi("r1", 8).movi("r0", 0)
        a.label("loop")
        a.movi("r1", 8)              # body writes the loop counter
        a.jnzdec("r1", "loop")
        a.exit()
        with pytest.raises(VerifierError, match="counter"):
            PolicyVM(a.build(), MapRegistry())

    def test_rejects_missing_exit(self):
        a = Asm()
        a.movi("r0", 1)
        with pytest.raises(VerifierError):
            PolicyVM(a.build(), MapRegistry())

    def test_rejects_unknown_helper(self):
        a = Asm()
        a.call(999).exit()
        with pytest.raises(VerifierError, match="helper"):
            PolicyVM(a.build(), MapRegistry())

    def test_rejects_div_by_zero_imm(self):
        a = Asm()
        a.movi("r0", 1).divi("r0", 0).exit()
        with pytest.raises(VerifierError, match="division"):
            PolicyVM(a.build(), MapRegistry())

    def test_accepts_builtin_programs(self):
        maps = MapRegistry()
        m = ArrayMap(512)
        maps.register(m)
        for prog in (ebpf_mm_program(0), thp_always_program(),
                     never_program()):
            PolicyVM(prog, maps)     # must not raise


ALU_IMM_OPS = [Op.MOVI, Op.ADDI, Op.SUBI, Op.MULI, Op.ANDI, Op.ORI, Op.XORI,
               Op.LSHI, Op.RSHI, Op.MINI, Op.MAXI]


def straight_line_program(rng: np.random.Generator):
    """Random verified straight-line ALU program over ctx loads."""
    a = Asm()
    a.movi("r0", int(rng.integers(-1000, 1001)))
    for r in range(1, 6):
        a.ldctx(f"r{r}", int(rng.integers(0, CTX_LEN)))
    n = int(rng.integers(1, 31))
    reg_ops = ["add", "sub", "mul", "and_", "or_", "xor", "min_", "max_",
               "div", "mod"]
    for _ in range(n):
        choice = int(rng.integers(0, len(ALU_IMM_OPS) + 1))
        dst = f"r{int(rng.integers(0, 6))}"
        if choice == len(ALU_IMM_OPS):
            regop = reg_ops[int(rng.integers(0, len(reg_ops)))]
            getattr(a, regop)(dst, f"r{int(rng.integers(0, 6))}")
        else:
            op = ALU_IMM_OPS[choice]
            if op in (Op.LSHI, Op.RSHI):
                imm = int(rng.integers(0, 64))
            else:
                imm = int(rng.integers(-(2**31), 2**31))
            getattr(a, op.name.lower())(dst, imm)
    a.exit()
    return a.build("fuzz")


def fuzz_case(rng: np.random.Generator):
    prog = straight_line_program(rng)
    addr = int(rng.integers(0, 2**31))
    heat = tuple(int(rng.integers(0, 10**6 + 1)) for _ in range(4))
    return prog, addr, heat


class TestJitEquivalence:
    @pytest.mark.parametrize("example", range(40))
    def test_interpreter_matches_jit(self, example):
        rng = np.random.default_rng(2000 + example)
        prog, addr, heat = fuzz_case(rng)
        maps = MapRegistry()
        ctx = make_ctx(addr=addr, heat=heat)
        host = PolicyVM(prog, maps).run(ctx).ret
        dev = JitPolicy(prog, maps).run(ctx)
        assert host == dev

    @pytest.mark.parametrize("example", range(15))
    def test_interpreter_matches_predicated(self, example):
        from repro.core.predicate import PredicatedPolicy
        rng = np.random.default_rng(3000 + example)
        prog, addr, heat = fuzz_case(rng)
        maps = MapRegistry()
        ctx = make_ctx(addr=addr, heat=heat)
        host = PolicyVM(prog, maps).run(ctx).ret
        dev = PredicatedPolicy(prog, maps).run_batch(ctx[None])[0]
        assert host == dev

    def test_predicated_loop_program(self):
        """Bounded-loop unrolling + if-conversion == interpreter, for a
        region-search loop with early exit and a helper call."""
        from repro.core.predicate import PredicatedPolicy
        from repro.core.vm import HELPER_PROMOTION_COST
        maps = MapRegistry()
        m = ArrayMap(64)
        m.load([0, 16, 0, 9000, 90000, 900000, 16, 4096, 0, 0, 0, 0])
        maps.register(m)
        a = Asm()
        a.ldctx("r1", CTX.ADDR)
        a.movi("r8", -1).movi("r4", 0).movi("r3", 8)
        a.label("loop")
        a.mov("r9", "r4").muli("r9", 6)
        a.ldmap("r5", 0, "r9")
        a.jgt("r5", "r1", "nx")
        a.mov("r10", "r9").addi("r10", 1)
        a.ldmap("r5", 0, "r10")
        a.jle("r5", "r1", "nx")
        a.mov("r8", "r9")
        a.ja("done")
        a.label("nx")
        a.addi("r4", 1)
        a.jnzdec("r3", "loop")
        a.label("done")
        a.jlti("r8", 0, "fb")
        a.movi("r1", 1)
        a.call(HELPER_PROMOTION_COST)
        a.exit()
        a.label("fb")
        a.movi("r0", -1)
        a.exit()
        prog = a.build("mini")
        vm = PolicyVM(prog, maps)
        ctxs = np.stack([make_ctx(addr=x) for x in (0, 10, 16, 100, 4000)])
        host = [vm.run(c).ret for c in ctxs]
        dev = PredicatedPolicy(prog, maps).run_batch(ctxs)
        assert host == list(dev)

    def test_fig1_program_matches_jit_batch(self):
        maps = MapRegistry()
        m = ArrayMap(512)
        prof = Profile("app", [ProfileRegion(0, 64, (0, 9000, 90000, 900000)),
                               ProfileRegion(64, 512, (0, 0, 0, 0))])
        prof.load_into(m)
        maps.register(m)
        prog = ebpf_mm_program(0)
        vm = PolicyVM(prog, maps)
        jp = JitPolicy(prog, maps)
        ctxs = np.stack([make_ctx(addr=a, nregions=2)
                         for a in (0, 5, 63, 64, 100, 400)])
        host = [vm.run(c).ret for c in ctxs]
        dev = jp.run_batch(ctxs)
        assert host == list(dev)
