"""Pallas kernels vs pure-jnp oracles (interpret=True), shape/dtype sweeps."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.block_copy.ops import apply_moves, expand_moves
from repro.kernels.block_copy.ref import block_copy_ref
from repro.kernels.flash_attention.kernel import flash_attention_fwd
from repro.kernels.flash_attention.ref import mha_ref
from repro.kernels.paged_attention import ref as pa_ref
from repro.kernels.paged_attention.kernel import paged_class_partials
from repro.kernels.paged_attention.ops import paged_decode_attention

RNG = np.random.default_rng(7)


def rand(shape, dtype):
    x = RNG.normal(size=shape).astype(np.float32)
    return jnp.asarray(x, dtype)


def make_pages(B, NB, MP, pb, seed=0):
    rng = np.random.default_rng(seed)
    tbl = np.full((B, MP), -1, np.int32)
    logical = np.full((B, MP), -1, np.int32)
    for b in range(B):
        n = rng.integers(1, MP + 1)
        starts = rng.choice(NB // pb, size=n, replace=False) * pb
        tbl[b, :n] = starts
        logical[b, :n] = np.arange(n)
    return jnp.asarray(tbl), jnp.asarray(logical)


class TestPagedAttentionKernel:
    @pytest.mark.parametrize("order", [0, 1, 2])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("B,H,KVH,hd,bt,NB,MP", [
        (2, 8, 4, 32, 8, 128, 5),
        (1, 4, 1, 64, 16, 256, 3),
        (3, 4, 4, 16, 4, 192, 7),
    ])
    def test_partials_match_ref(self, order, dtype, B, H, KVH, hd, bt, NB, MP):
        pb = 4 ** order
        if NB // pb < MP:
            pytest.skip("pool too small for this class")
        q = rand((B, H, hd), dtype)
        pk = rand((NB, bt, KVH, hd), dtype)
        pv = rand((NB, bt, KVH, hd), dtype)
        tbl, logical = make_pages(B, NB, MP, pb, seed=order)
        lengths = jnp.asarray(
            RNG.integers(1, MP * pb * bt, size=(B,)), jnp.int32)
        acc, m, l, heat = paged_class_partials(
            q, pk, pv, tbl, logical, lengths, page_blocks=pb,
            block_tokens=bt, interpret=True)
        racc, rm, rl, _ = pa_ref.paged_class_partials_ref(
            q, pk, pv, tbl, logical, lengths, page_blocks=pb, block_tokens=bt)
        out_k = pa_ref.combine_partials_ref([(acc, m, l)])
        out_r = pa_ref.combine_partials_ref([(racc, rm, rl)])
        tol = 2e-5 if dtype == jnp.float32 else 2e-2
        np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                                   rtol=tol, atol=tol)
        hrun = pa_ref.paged_class_heat_running_ref(
            q, pk, pv, tbl, logical, lengths, page_blocks=pb, block_tokens=bt)
        np.testing.assert_allclose(np.asarray(heat), np.asarray(hrun),
                                   rtol=tol, atol=tol)

    def test_window_masking(self):
        B, H, KVH, hd, bt, NB, MP = 2, 4, 2, 32, 8, 128, 4
        q = rand((B, H, hd), jnp.float32)
        pk = rand((NB, bt, KVH, hd), jnp.float32)
        pv = rand((NB, bt, KVH, hd), jnp.float32)
        tbl, logical = make_pages(B, NB, MP, 1, seed=3)
        lengths = jnp.asarray([20, 30], jnp.int32)
        acc, m, l, _ = paged_class_partials(
            q, pk, pv, tbl, logical, lengths, page_blocks=1, block_tokens=bt,
            window=8, interpret=True)
        racc, rm, rl, _ = pa_ref.paged_class_partials_ref(
            q, pk, pv, tbl, logical, lengths, page_blocks=1, block_tokens=bt,
            window=8)
        np.testing.assert_allclose(
            np.asarray(pa_ref.combine_partials_ref([(acc, m, l)])),
            np.asarray(pa_ref.combine_partials_ref([(racc, rm, rl)])),
            rtol=2e-5, atol=2e-5)

    def test_multi_class_combine_matches_full_oracle(self):
        """Multi-size decode: orders 0+1 together == oracle over both."""
        B, H, KVH, hd, bt, NB = 2, 4, 2, 32, 8, 256
        q = rand((B, H, hd), jnp.float32)
        pk = rand((NB, bt, KVH, hd), jnp.float32)
        pv = rand((NB, bt, KVH, hd), jnp.float32)
        t0, l0 = make_pages(B, NB // 2, 4, 1, seed=1)
        t1_, l1_ = make_pages(B, NB // 2, 2, 4, seed=2)
        t1 = jnp.where(t1_ >= 0, t1_ + NB // 2, t1_)   # disjoint pool halves
        # logical indices of class-1 pages follow the class-0 pages
        l1 = jnp.where(l1_ >= 0, l1_ + 1, l1_)
        lengths = jnp.asarray([NB * bt, NB * bt], jnp.int32)
        out, heats = paged_decode_attention(
            q, pk, pv, (t0, t1), (l0, l1), lengths,
            block_tokens=bt, orders=(0, 1), interpret=True)
        ref_out, _ = pa_ref.paged_decode_ref(
            q, pk, pv, {0: t0, 1: t1}, {0: l0, 1: l1}, lengths,
            block_tokens=bt)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref_out),
                                   rtol=2e-5, atol=2e-5)


class TestFlashAttentionKernel:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("B,Sq,Sk,H,KVH,hd,causal,window", [
        (2, 64, 64, 4, 2, 32, True, None),
        (1, 96, 96, 4, 4, 16, True, 8),
        (2, 33, 65, 8, 2, 64, True, None),
        (1, 64, 64, 2, 1, 32, False, None),
        (1, 128, 128, 4, 1, 48, True, 32),
    ])
    def test_matches_ref(self, dtype, B, Sq, Sk, H, KVH, hd, causal, window):
        q = rand((B, Sq, H, hd), dtype)
        k = rand((B, Sk, KVH, hd), dtype)
        v = rand((B, Sk, KVH, hd), dtype)
        o = flash_attention_fwd(q, k, v, causal=causal, window=window,
                                bq=32, bk=32, interpret=True)
        r = mha_ref(q, k, v, causal=causal, window=window)
        tol = 2e-5 if dtype == jnp.float32 else 2.5e-2
        np.testing.assert_allclose(np.asarray(o, np.float32),
                                   np.asarray(r, np.float32),
                                   rtol=tol, atol=tol)

    def test_soft_cap(self):
        q = rand((1, 64, 2, 32), jnp.float32)
        k = rand((1, 64, 2, 32), jnp.float32)
        v = rand((1, 64, 2, 32), jnp.float32)
        o = flash_attention_fwd(q, k, v, soft_cap=20.0, bq=32, bk=32,
                                interpret=True)
        r = mha_ref(q, k, v, soft_cap=20.0)
        np.testing.assert_allclose(np.asarray(o), np.asarray(r),
                                   rtol=2e-5, atol=2e-5)


class TestBlockCopyKernel:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int32])
    def test_moves_match_ref(self, dtype):
        pool = (jnp.arange(32 * 64).reshape(32, 4, 16) % 97).astype(dtype)
        plan = [(0, 16, 1), (8, 24, 0), (9, 25, 0)]
        src, dst = expand_moves(plan, pad_to=8)
        out = apply_moves(pool, jnp.asarray(src), jnp.asarray(dst),
                          interpret=True)
        ref = block_copy_ref(pool.reshape(32, -1), jnp.asarray(src),
                             jnp.asarray(dst)).reshape(pool.shape)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))

    def test_mm_compaction_plan_applies(self):
        """End-to-end: MM compaction plan -> kernel moves keep data intact."""
        from repro.core import HWSpec, MemoryManager, make_cost_model
        mm = MemoryManager(64, make_cost_model(HWSpec(), 2, 8),
                           default_mode="never")
        mm.create_process(1, vma_blocks=64)
        mm.ensure_range(1, 0, 48)
        st = mm.procs[1]
        for lstart in list(st.page_table)[::2]:
            mm.unmap(1, lstart)
        pool = jnp.asarray(RNG.normal(size=(64, 4, 8)).astype(np.float32))
        expect = {m.phys_start: np.asarray(pool[m.phys_start])
                  for m in st.page_table.values()}
        keys = {m.phys_start: lg for lg, m in st.page_table.items()}
        mm._install(st, 60, 2, hinted=False)       # triggers compaction
        moves = mm.drain_moves()
        if moves:
            src, dst = expand_moves(moves, pad_to=None)
            pool = apply_moves(pool, jnp.asarray(src), jnp.asarray(dst),
                               interpret=True)
        for lg, m in st.page_table.items():
            if m.order == 0 and lg in keys.values():
                pass
        # verify moved rows carry their original contents
        remap = {s: d for s, d, _ in moves}
        for old_phys, data in expect.items():
            new_phys = remap.get(old_phys, old_phys)
            np.testing.assert_array_equal(np.asarray(pool[new_phys]), data)
