"""Device-resident block tables, active-row masking, sampler hardening.

The one-dispatch decode step keeps the ``[B, max_blocks]`` block table as a
PERSISTENT device buffer fed by dirty-row uploads (serving.tables).  That
buys the dispatch count down but creates two hazards these tests pin:

* a vacated/skipped slot's row still holds live-looking physical indices —
  without the explicit active-row mask its length-0 decode would scatter
  garbage KV into its first block (the PR 1 scatter-to-block-0 bug class,
  one level up);
* a same-step tier migration remaps rows AFTER the last upload — the
  ``table_version`` protocol must force a re-upload before the dispatch.

Plus the sampler's renormalization (``p /= p.sum()``) on degenerate
distributions (all -inf, NaN-poisoned, under/overflowed sums).
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import get_smoke_config
from repro.core import HWSpec, MemoryManager, TieredMemoryManager, \
    make_cost_model
from repro.models import PagedLayout, materialize, model_spec
from repro.models.decode import cache_init, decode_step
from repro.serving import Request, ServingEngine
from repro.serving.sampler import Sampler
from repro.serving.tables import DeviceBlockTables

RNG = jax.random.PRNGKey(0)
POOL_KEYS = ("pool_k", "pool_v", "pool_ckv")


def mk_mm(blocks=64, *, tiered=False, host=64):
    cost = make_cost_model(HWSpec(), kv_heads=4, head_dim=64)
    if tiered:
        return TieredMemoryManager(blocks, cost, host_blocks=host,
                                   default_mode="thp")
    return MemoryManager(blocks, cost, default_mode="thp")


# --------------------------------------------------------------- dirty rows
def _apply(buf, didx, drows, tri):
    """The engine's in-jit install, host-side: full rows then triples."""
    buf[didx] = drows
    buf[tri[:, 0], tri[:, 1]] = tri[:, 2]
    return buf


class TestDeviceBlockTables:
    def test_dirty_row_protocol(self):
        mm = mk_mm()
        mm.create_process(1, app="app", vma_blocks=8)
        mm.fault_range(1, 0, 4)
        dbt = DeviceBlockTables(2, 8)
        buf = np.full((2, 8), -1, np.int32)
        didx, drows, active, tri = dbt.sync(mm, [1, None])
        # a fresh pid's row is APPEND-ONLY over the blank mirror: it ships
        # as delta triples, not a full-width row
        assert len(didx) == 0 and len(tri) == 4
        _apply(buf, didx, drows, tri)
        np.testing.assert_array_equal(buf[0], mm.block_table(1, 8))
        assert list(active) == [True, False]
        assert dbt.delta_rows == 1 and dbt.delta_cells == 4
        # steady state: no table mutation -> no upload of either kind
        didx, _, _, tri = dbt.sync(mm, [1, None])
        assert len(didx) == 0 and len(tri) == 0
        # a new fault appends cells -> only those cells ship, as triples
        mm.fault_range(1, 4, 6)
        didx, drows, _, tri = dbt.sync(mm, [1, None])
        assert len(didx) == 0 and len(tri) >= 2
        assert (tri[:, 1] >= 4).all(), "pre-existing cells must not re-ship"
        _apply(buf, didx, drows, tri)
        np.testing.assert_array_equal(buf[0], mm.block_table(1, 8))

    def test_vacated_slot_blanks_and_deactivates(self):
        mm = mk_mm()
        mm.create_process(1, app="app", vma_blocks=8)
        mm.fault_range(1, 0, 4)
        dbt = DeviceBlockTables(2, 8)
        dbt.sync(mm, [1, None])
        mm.free_process(1)
        didx, drows, active, tri = dbt.sync(mm, [None, None])
        assert list(didx) == [0], "vacated slot must re-upload a blank row"
        assert (drows[0] == -1).all()
        assert len(tri) == 0, "blanking must take the full-row path"
        assert not active.any()
        assert dbt.blank_rows == 1 and dbt.full_rows == 1

    def test_migration_invalidates_row_same_step(self):
        """The satellite-b hazard at unit level: demotion moves blocks AFTER
        the last sync; the version bump must force the row back up before
        the next dispatch, bit-identical to a fresh host recapture.
        Migration rewrites LIVE cells, so it must ship full-width (the
        delta path is append-only by construction)."""
        mm = mk_mm(blocks=8, tiered=True, host=64)
        mm.create_process(1, app="app", vma_blocks=8)
        mm.fault_range(1, 0, 8)
        dbt = DeviceBlockTables(1, 8)
        buf = np.full((1, 8), -1, np.int32)
        didx, drows, _, tri = dbt.sync(mm, [1])
        _apply(buf, didx, drows, tri)
        stale = buf[0].copy()
        assert mm.demote_cold_global(4) > 0, "demotion did not move blocks"
        assert mm.drain_moves(), "no KV moves drained for the demotion"
        didx, drows, active, tri = dbt.sync(mm, [1])
        assert list(didx) == [0], \
            "migration did not dirty the device row (stale table published)"
        assert len(tri) == 0, "live-cell rewrite must not ship as triples"
        fresh = mm.block_table(1, 8)
        np.testing.assert_array_equal(drows[0], fresh)
        assert not np.array_equal(stale, fresh), \
            "demotion did not change the table — hazard not exercised"


# ------------------------------------------------------------- active mask
class TestActiveRowMask:
    @pytest.fixture(scope="class")
    def setup(self):
        cfg = get_smoke_config("deepseek_7b")
        params = materialize(RNG, model_spec(cfg))
        layout = PagedLayout(num_blocks=32, block_tokens=4, max_blocks=4)
        return cfg, params, layout

    @staticmethod
    def _pool_rows(cache, block):
        """All pool-leaf contents at physical ``block`` (handles stacked
        scan-segment leaves [reps, NB, ...])."""
        rows = []

        def grab(path, leaf):
            key = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
            if key in POOL_KEYS:
                rows.append(np.asarray(leaf[:, block] if leaf.ndim >= 2
                                       and leaf.shape[0] != 32
                                       else leaf[block]))
        jax.tree_util.tree_map_with_path(grab, cache)
        return rows

    def test_inactive_row_kv_scatter_dropped(self, setup):
        """A persistent table row of a skipped/vacated slot must not write
        KV: with the active mask the stale row's first block is bit-
        identical before and after the step; WITHOUT the mask the same
        inputs corrupt it — the mask is load-bearing, not belt-and-braces."""
        cfg, params, layout = setup
        cache = cache_init(cfg, layout, batch=2)
        # row 0 live (2 tokens, blocks 0..), row 1 VACATED but its stale row
        # still points at blocks 5.. — exactly what the persistent device
        # buffer holds after a completion, before the row is re-blanked
        table = jnp.asarray(np.array([[0, 1, 2, 3], [5, 6, 7, 8]], np.int32))
        tokens = jnp.asarray(np.array([3, 7], np.int32))
        lengths = jnp.asarray(np.array([2, 0], np.int32))
        active = jnp.asarray(np.array([True, False]))

        _, masked, heat = decode_step(params, cfg, cache, tokens, lengths,
                                      table, layout, active=active)
        for before, after in zip(self._pool_rows(cache, 5),
                                 self._pool_rows(masked, 5)):
            np.testing.assert_array_equal(before, after)
        assert np.asarray(heat)[1].sum() == 0.0, \
            "inactive row contributed attention heat"

        _, unmasked, _ = decode_step(params, cfg, cache, tokens, lengths,
                                     table, layout, active=None)
        assert any(not np.array_equal(b, a)
                   for b, a in zip(self._pool_rows(cache, 5),
                                   self._pool_rows(unmasked, 5))), \
            "control: without the mask the stale row should have scattered " \
            "(if this fires, the scenario no longer exercises the hazard)"

    def test_active_rows_unaffected_by_mask(self, setup):
        """Masking inactive rows must not perturb live rows' outputs."""
        cfg, params, layout = setup
        cache = cache_init(cfg, layout, batch=2)
        table = jnp.asarray(np.array([[0, 1, 2, 3], [5, 6, 7, 8]], np.int32))
        tokens = jnp.asarray(np.array([3, 7], np.int32))
        lengths = jnp.asarray(np.array([2, 0], np.int32))
        logits_m, _, _ = decode_step(params, cfg, cache, tokens, lengths,
                                     table, layout,
                                     active=jnp.asarray([True, False]))
        logits_u, _, _ = decode_step(params, cfg, cache, tokens, lengths,
                                     table, layout, active=None)
        np.testing.assert_array_equal(np.asarray(logits_m)[0],
                                      np.asarray(logits_u)[0])


# ---------------------------------------------------------------- engine
class TestEnginePersistentTables:
    def test_slot_reuse_blanks_and_outputs_stable(self):
        """A sequence sharing the batch with an earlier-finishing neighbour
        must produce the same greedy tokens as running alone: the vacated
        slot's persistent row cannot corrupt the survivor's KV."""
        cfg = get_smoke_config("deepseek_7b")
        params = materialize(RNG, model_spec(cfg))
        layout = PagedLayout(num_blocks=256, block_tokens=4, max_blocks=32)

        def run(reqs):
            eng = ServingEngine(cfg, params, layout, max_batch=2,
                                policy="never")
            for r in reqs:
                eng.submit(r)
            out = eng.run(max_steps=200)
            return eng, out

        long_req = Request(rid=0, prompt=list(range(1, 25)),
                           max_new_tokens=12)
        short_req = Request(rid=1, prompt=list(range(30, 40)),
                            max_new_tokens=2)
        eng_alone, _ = run([long_req])
        eng_both, out = run([long_req, short_req])
        assert eng_alone.finished[0] == eng_both.finished[0], \
            "vacated neighbour slot perturbed the survivor's decode"
        assert out["tables"]["blank_rows"] >= 1, \
            "completion never re-blanked the vacated device row"
        assert out["tables"]["syncs"] > 0

    def test_dirty_rows_bounded_by_table_mutations(self):
        """The crossings contract: row uploads happen only when the table
        actually changes — bounded by faults + moves + blanks, NOT by
        steps * batch (the old per-step recapture)."""
        cfg = get_smoke_config("deepseek_7b")
        params = materialize(RNG, model_spec(cfg))
        layout = PagedLayout(num_blocks=256, block_tokens=4, max_blocks=32)
        eng = ServingEngine(cfg, params, layout, max_batch=2, policy="never")
        rng = np.random.default_rng(3)
        for r in range(3):
            eng.submit(Request(rid=r,
                               prompt=rng.integers(1, cfg.vocab, 17).tolist(),
                               max_new_tokens=9))
        out = eng.run(max_steps=200)
        assert out["engine"]["completed"] == 3
        t = out["tables"]
        mutations = out["mm"]["faults"] + t["blank_rows"] + \
            out["mm"]["compactions"] + out["mm"].get("collapses", 0)
        assert t["synced_rows"] <= mutations + 2 * t["blank_rows"] + 8, \
            f"dirty-row uploads ({t['synced_rows']}) not bounded by table " \
            f"mutations ({mutations}) — recapture snuck back in"


# ---------------------------------------------------------------- sampler
class TestSamplerDegenerate:
    def test_all_neg_inf_returns_argmax(self):
        s = Sampler(seed=0)
        logits = np.full(16, -np.inf)
        assert s.sample(logits, 16, temperature=1.0) == 0

    def test_nan_poisoned_row_returns_best_finite(self):
        s = Sampler(seed=0)
        logits = np.zeros(16)
        logits[3] = np.nan
        logits[7] = 5.0
        assert s.sample(logits, 16, temperature=0.7) == 7

    def test_pos_inf_wins(self):
        s = Sampler(seed=0)
        logits = np.zeros(16)
        logits[11] = np.inf
        assert s.sample(logits, 16, temperature=1.0) == 11

    def test_greedy_and_normal_paths_unchanged(self):
        s = Sampler(seed=0)
        rng = np.random.default_rng(0)
        logits = rng.normal(size=32)
        assert s.sample(logits, 32, temperature=0.0) == int(np.argmax(logits))
        tok = s.sample(logits, 32, temperature=0.8)
        assert 0 <= tok < 32
        # reproducible under the seeded rng
        assert Sampler(seed=4).sample(logits, 32, 0.8) == \
            Sampler(seed=4).sample(logits, 32, 0.8)
