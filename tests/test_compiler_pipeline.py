"""Unified compiler pipeline: lowering IR, segmented predicated unroll,
register-indexed LDCTXR, and the cross-session artifact cache.

The structural properties the pipeline must hold:

* one :func:`repro.core.lower.lower` pass produces the IR every executor
  consumes — absolute branch targets, resolved map slots, validated ctx
  offsets — and flattening/segmentation preserve decisions exactly;
* segment cuts land on loop-copy (back-edge) boundaries when one is in
  budget, and the chained dispatch is bit-identical to the single-segment
  compile and to the interpreter/JIT, whatever the cut pattern;
* ``LDCTXR`` is verified (initialized index register, const-tracked index
  inside the ctx struct) and lowered with one clamp by every backend;
* artifacts persist across "sessions" (fresh registries + caches over one
  directory) without changing a single decision.
"""

import numpy as np
import pytest

from repro.core import (Asm, ArrayMap, CTX, CTX_LEN, JitPolicy, MapRegistry,
                        PolicyVM, VerifierError, ebpf_mm_program,
                        tier_edge_admission_program)
from repro.core.cache import ArtifactCache
from repro.core.context import FaultContext
from repro.core.hooks import HOOK_FAULT, PRED_MAX_UNROLL, HookRegistry
from repro.core.lower import (lower, segment_code, unroll_lowered)
from repro.core.predicate import PredicatedPolicy


def _ctx_rows(rng, n, **kw):
    rows = []
    for _ in range(n):
        fc = FaultContext(
            addr=int(rng.integers(0, 256)), pid=1, vma_start=0,
            vma_end=256, fault_max_order=int(rng.integers(0, 4)),
            has_profile=kw.get("has_profile", 0),
            profile_map_id=0, profile_nregions=kw.get("nregions", 0),
            free_blocks=tuple(rng.integers(0, 200, 4)),
            frag=tuple(rng.integers(0, 1001, 4)),
            heat=tuple(rng.integers(0, 50, 4)),
            zero_ns_per_block=int(rng.integers(100, 2000)),
            compact_ns_per_block=int(rng.integers(100, 3000)),
            descriptor_ns=800, block_bytes=65536,
            mem_pressure=int(rng.integers(0, 1001)),
            page_tier=int(rng.integers(0, 4)),
            page_order=int(rng.integers(0, 4)),
            page_heat=int(rng.integers(0, 5000)),
            pcie_ns_per_block=int(rng.integers(100, 4000)),
            ntiers=4, tier_free=tuple(rng.integers(0, 64, 4)),
            tier_total=(64, 64, 64, 64),
            mig_cum_setup=(0, 2000, 5000, 30000),
            mig_cum_ns=(0, 800, 2800, 12800))
        rows.append(fc.vector())
    return np.stack(rows)


class TestLoweringIR:
    def test_lowered_targets_are_absolute(self):
        a = Asm()
        a.movi("r1", 3)
        a.jeqi("r1", 3, "hit")
        a.movi("r0", 0)
        a.exit()
        a.label("hit")
        a.movi("r0", 7)
        a.exit()
        lp = lower(a.build(), MapRegistry())
        jeq = lp.insns[1]
        assert jeq.target == 4          # absolute pc of "hit"
        assert lp.insns[0].target == -1

    def test_digest_covers_program_and_map_shape(self):
        maps_a, maps_b = MapRegistry(), MapRegistry()
        maps_b.register(ArrayMap(8))
        a = Asm()
        a.movi("r0", 1).exit()
        prog = a.build()
        assert lower(prog, maps_a).digest() != lower(prog, maps_b).digest()
        b = Asm()
        b.movi("r0", 2).exit()
        assert lower(prog, maps_a).digest() != \
            lower(b.build(), maps_a).digest()

    def test_unroll_cuts_on_loop_copy_boundaries(self):
        a = Asm()
        a.movi("r0", 0).movi("r1", 6)
        a.label("loop")
        for _ in range(10):
            a.addi("r0", 1)
        a.jnzdec("r1", "loop")
        a.exit()
        lp = lower(a.build(), MapRegistry())
        code, cuts = unroll_lowered(lp)
        # 2 prefix + 6 * (10-body + counter SUBI) + exit
        assert len(code) == 2 + 6 * 11 + 1
        assert set(cuts) == {2 + c * 11 for c in range(7)}
        segs = segment_code(code, cuts, limit=30)
        for start, end in segs[:-1]:
            assert end in cuts, "cut must land on a loop-copy boundary"
            assert end - start <= 30


class TestSegmentedParity:
    """Chained segments == single segment == interpreter == JIT, for cut
    budgets that slice the Fig-1 unroll every which way."""

    @pytest.fixture(scope="class")
    def fig1(self):
        maps = MapRegistry()
        m = ArrayMap(512)
        from repro.core import Profile, ProfileRegion
        Profile("app", [ProfileRegion(0, 64, (0, 9000, 90000, 900000)),
                        ProfileRegion(64, 256, (0, 30000, 0, 0))]
                ).load_into(m)
        maps.register(m)
        prog = ebpf_mm_program(0, max_regions=16)   # ~230-insn unroll
        rng = np.random.default_rng(21)
        mat = _ctx_rows(rng, 16, has_profile=1, nregions=2)
        host = [PolicyVM(prog, maps).run(r).ret for r in mat]
        return prog, maps, mat, host

    @pytest.mark.parametrize("limit", [48, 97, 200, 512])
    def test_any_cut_pattern_preserves_decisions(self, fig1, limit):
        prog, maps, mat, host = fig1
        pol = PredicatedPolicy(prog, maps, seg_limit=limit)
        if limit < pol.unrolled_len:
            assert pol.num_segments >= 2
        assert host == list(pol.run_batch(mat)), \
            f"seg_limit={limit} changed decisions"

    def test_matches_jit(self, fig1):
        prog, maps, mat, host = fig1
        assert host == list(JitPolicy(prog, maps).run_batch(mat))


class TestLDCTXR:
    def test_rejects_uninitialized_index_register(self):
        a = Asm()
        a.ldctxr("r0", "r4").exit()
        with pytest.raises(VerifierError, match="uninitialized"):
            PolicyVM(a.build(), MapRegistry())

    def test_rejects_const_index_out_of_bounds(self):
        for bad in (CTX_LEN, CTX_LEN + 9, -1):
            a = Asm()
            a.movi("r1", bad)
            a.ldctxr("r0", "r1")
            a.exit()
            with pytest.raises(VerifierError, match="out of ctx bounds"):
                PolicyVM(a.build(), MapRegistry())

    def test_const_index_in_bounds_accepted(self):
        a = Asm()
        a.movi("r1", CTX_LEN - 1)
        a.ldctxr("r0", "r1")
        a.exit()
        PolicyVM(a.build(), MapRegistry())      # must not raise

    def test_all_executors_clamp_dynamic_index_identically(self):
        # index = ADDR * 3 - 40: wanders below 0 and beyond CTX_LEN; each
        # backend must clamp to the same edge reads
        a = Asm()
        a.ldctx("r1", CTX.ADDR)
        a.muli("r1", 3)
        a.subi("r1", 40)
        a.ldctxr("r0", "r1")
        a.exit()
        prog = a.build("dyn_ldctxr")
        maps = MapRegistry()
        rng = np.random.default_rng(5)
        mat = _ctx_rows(rng, 24)
        host = [PolicyVM(prog, maps).run(r).ret for r in mat]
        assert host == list(JitPolicy(prog, maps).run_batch(mat))
        assert host == list(PredicatedPolicy(prog, maps).run_batch(mat))

    def test_edge_admission_reads_target_pool_free_list(self):
        """The upgraded tier_edge_admission_program vetoes a one-hop
        promotion when the TARGET pool's TIER_FREE_T{t} cannot back the
        page, and admits it when it can — on every backend."""
        prog = tier_edge_admission_program()
        maps = MapRegistry()
        vm = PolicyVM(prog, maps)

        def ctx(tier_free, order=2):
            return FaultContext(
                addr=0, pid=1, vma_start=0, vma_end=64, fault_max_order=0,
                has_profile=0, profile_map_id=0, profile_nregions=0,
                free_blocks=(8, 8, 8, 8), frag=(0, 0, 0, 0),
                heat=(0, 0, 0, 0), zero_ns_per_block=700,
                compact_ns_per_block=1300, descriptor_ns=800,
                block_bytes=65536, mem_pressure=100,    # plenty of headroom
                page_tier=2, page_order=order, page_heat=500_000,
                pcie_ns_per_block=3000, ntiers=4,
                tier_free=tier_free, tier_total=(64, 64, 64, 64),
                mig_cum_setup=(0, 2000, 5000, 30000),
                mig_cum_ns=(0, 800, 2800, 12800)).vector()

        room = ctx(tier_free=(64, 64, 64, 64))      # tier 1 can back 4^2
        full = ctx(tier_free=(64, 15, 64, 64))      # tier 1: 15 < 16 blocks
        assert vm.run(room).ret == 1, "hot page with room must promote"
        assert vm.run(full).ret == 2, \
            "promotion must be vetoed when the target pool is full"
        mat = np.stack([room, full])
        for backend in (JitPolicy(prog, maps),
                        PredicatedPolicy(prog, maps)):
            assert list(backend.run_batch(mat)) == [1, 2]


class TestArtifactCache:
    @pytest.fixture(autouse=True)
    def _restore_xla_cache_dir(self):
        # enable_xla_cache flips the process-global jax compilation-cache
        # dir; leave the session the way we found it (tmp_path is deleted)
        import jax
        prev = jax.config.jax_compilation_cache_dir
        yield
        jax.config.update("jax_compilation_cache_dir", prev)

    def _fig1_setup(self):
        maps = MapRegistry()
        m = ArrayMap(512)
        from repro.core import Profile, ProfileRegion
        Profile("app", [ProfileRegion(0, 64, (0, 9000, 90000, 900000)),
                        ProfileRegion(64, 512, (0, 0, 0, 0))]).load_into(m)
        maps.register(m)
        return ebpf_mm_program(max_regions=8), maps

    def test_cold_then_warm_identical_decisions(self, tmp_path):
        prog, maps = self._fig1_setup()
        rng = np.random.default_rng(31)
        mat = _ctx_rows(rng, 8, has_profile=1, nregions=2)
        outs, caches = [], []
        for _ in range(2):      # two "sessions" over one cache dir
            cache = ArtifactCache(tmp_path)
            reg = HookRegistry(cache=cache)
            reg.attach(HOOK_FAULT, prog, maps)
            outs.append(list(reg.run_batch(HOOK_FAULT, mat)))
            caches.append(cache)
        assert outs[0] == outs[1]
        assert caches[0].stats["unroll_misses"] == 1
        assert caches[1].stats["unroll_misses"] == 0, \
            "second session must reuse the persisted unroll artifact"
        assert caches[1].stats["unroll_hits"] == 1
        assert outs[0] == [PolicyVM(prog, maps).run(r).ret for r in mat]

    def test_corrupt_artifact_recomputes(self, tmp_path):
        prog, maps = self._fig1_setup()
        lp = lower(prog, maps)
        cache = ArtifactCache(tmp_path)
        cache.unrolled(lp)
        [p.write_bytes(b"not a pickle")
         for p in (tmp_path / "ebpf").glob("*.pkl")]
        fresh = ArtifactCache(tmp_path)
        code, _cuts = fresh.unrolled(lp)
        assert fresh.stats["unroll_misses"] == 1
        assert len(code) == len(cache._unrolled[lp.digest()][0])

    def test_disabled_cache_writes_nothing(self, tmp_path, monkeypatch):
        monkeypatch.setenv("REPRO_CACHE_DIR", "off")
        cache = ArtifactCache()
        assert not cache.enabled
        prog, maps = self._fig1_setup()
        cache.unrolled(lower(prog, maps))   # must work purely in memory
        assert cache.stats["unroll_misses"] == 1


class TestTierSnapshotShape:
    def test_legacy_host_keys_removed_and_per_tier_list_is_api(self):
        from repro.core import (HWSpec, TieredMemoryManager, default_tier_chain,
                                make_cost_model)
        hw = HWSpec()
        cost = make_cost_model(hw, kv_heads=4, head_dim=64)
        mm = TieredMemoryManager(32, cost,
                                 tiers=default_tier_chain(hw, (16, 32, 16)))
        snap = mm.tier_snapshot()
        assert type(snap) is dict                   # plain dict, no warn shim
        assert len(snap["tiers"]) == 4
        assert snap["ntiers"] == 4
        # the deprecated 2-pool host_* aliases went through their removal
        # cycle: they named tier 1, which on this 4-tier chain is peer-HBM
        for key in ("host_blocks", "host_free_blocks",
                    "host_resident_blocks", "host_utilization_milli"):
            assert key not in snap
        assert snap["tiers"][1]["blocks"] == 16
        assert snap["tiers"][2]["blocks"] == 32
