"""Telemetry subsystem tests: ring-buffer event parity across all three
policy executors (interpreter / while+switch JIT / segmented predicated),
histogram + counter behavior, exporter schema stability, hook-registry
drain semantics, and the artifact cache's LRU eviction.

The parity tests are the observability analogue of the differential
harness: ``bpf_ringbuf_output`` must produce BIT-IDENTICAL event streams
(including overflow drop counts) whichever executor ran the program —
otherwise a trace taken on the batched path lies about what the scalar
reference semantics did.
"""

from __future__ import annotations

import json
import re

import numpy as np
import pytest

from repro.core import (Asm, MapRegistry, MemoryManager, PolicyVM,
                        ebpf_mm_program, make_cost_model, HWSpec, JitPolicy,
                        Profile, ProfileRegion)
from repro.core.cache import ArtifactCache
from repro.core.context import CTX, CTX_LEN, FaultKind
from repro.core.hooks import HOOK_FAULT, HookRegistry
from repro.core.lower import RB_MAX_PER_RUN, lower
from repro.core.predicate import PredicatedPolicy
from repro.core.vm import HELPER_RINGBUF_OUTPUT, HELPER_TRACE
from repro.obs import (EV_FAULT, EV_HOOK, EV_PROG_BASE, EV_PROG_TRACE,
                       EventRing, Log2Hist, Telemetry, chrome_trace,
                       flatten_metrics, render_prometheus, tag_name)


# ------------------------------------------------------------ ring buffer
class TestEventRing:
    def test_fifo_and_counters(self):
        r = EventRing(capacity=4)
        for i in range(3):
            assert r.push(100 + i, EV_PROG_BASE, i, 2 * i, 3 * i)
        assert len(r) == 3
        got = r.drain()
        assert [tuple(e) for e in got] == \
            [(100 + i, EV_PROG_BASE, i, 2 * i, 3 * i) for i in range(3)]
        assert len(r) == 0
        assert r.emitted == 3 and r.dropped == 0

    def test_overflow_drops(self):
        r = EventRing(capacity=2)
        assert r.push(1, 1, 0, 0, 0)
        assert r.push(2, 1, 0, 0, 0)
        assert not r.push(3, 1, 0, 0, 0)       # full -> dropped
        snap = r.snapshot()
        assert snap["pending"] == 2
        assert snap["emitted"] == 2
        assert snap["dropped"] == 1
        # drain frees capacity again
        assert len(r.drain()) == 2
        assert r.push(4, 1, 0, 0, 0)

    def test_tag_name(self):
        assert tag_name(EV_FAULT) == "mm_fault"
        assert tag_name(EV_PROG_BASE).startswith("prog")


# -------------------------------------------------------------- histogram
class TestLog2Hist:
    def test_bucket_edges(self):
        h = Log2Hist()
        h.observe(0)          # bucket 0
        h.observe(1)          # bucket 1
        h.observe(2)          # bucket 2
        h.observe(3)          # bucket 2
        h.observe(1024)       # bucket 11
        snap = h.snapshot()
        assert snap["count"] == 5
        assert snap["sum"] == 1030
        assert snap["buckets"]["2"] == 2

    def test_percentile_upper_bound(self):
        h = Log2Hist()
        for v in (10, 20, 3000):
            h.observe(v)
        # p50 lands in the bucket holding 20 (bucket 5: 16..31)
        assert h.percentile(50) == 31
        assert h.percentile(99) >= 3000

    def test_observe_many_matches_loop(self):
        vals = np.array([0, 1, 5, 9, 120, 4096, 123456])
        a, b = Log2Hist(), Log2Hist()
        for v in vals:
            a.observe(int(v))
        b.observe_many(vals)
        assert np.array_equal(a.counts, b.counts)
        assert a.count == b.count and a.total == b.total


# ------------------------------------------------- executor parity (Asm)
def _emit_program(trips: int = 3):
    """A bounded loop emitting one custom event per trip, then a legacy
    HELPER_TRACE emission — covers both ring-buffer helpers."""
    a = Asm()
    a.movi("r6", trips)
    a.ldctx("r5", CTX.ADDR)
    a.label("loop")
    a.movi("r1", EV_PROG_BASE + 8)
    a.mov("r2", "r5")
    a.movi("r3", 7)
    a.mov("r4", "r6")
    a.call(HELPER_RINGBUF_OUTPUT)
    a.jnzdec("r6", "loop")
    a.movi("r1", 99)
    a.call(HELPER_TRACE)
    a.movi("r0", 1)
    a.exit()
    return a.build("emit_parity")


def _overflow_program(trips: int = 64):
    """Emits 2x RB_MAX_PER_RUN slots (two call sites in a max-trip loop):
    every executor must agree on the drop count and the -1 helper return."""
    a = Asm()
    a.movi("r6", trips)
    a.label("loop")
    for k in (9, 10):
        a.movi("r1", EV_PROG_BASE + k)
        a.movi("r2", 0)
        a.movi("r3", 0)
        a.mov("r4", "r6")
        a.call(HELPER_RINGBUF_OUTPUT)
    a.jnzdec("r6", "loop")
    a.mov("r0", "r0")   # r0 = last helper return (-1 once saturated)
    a.exit()
    return a.build("emit_overflow")


def _ctx_mat(n: int) -> np.ndarray:
    mat = np.zeros((n, CTX_LEN), dtype=np.int64)
    mat[:, CTX.ADDR] = np.arange(n) * 3 + 1
    mat[:, CTX.KTIME_NS] = 5_000 + np.arange(n)
    return mat


def _interp_events(vm: PolicyVM, mat: np.ndarray):
    evs, drops, rets = [], 0, []
    for row in mat:
        res = vm.run(row)
        evs.extend(tuple(e) for e in res.events)
        drops += res.dropped
        rets.append(res.ret)
    return evs, drops, rets


class TestExecutorEventParity:
    def test_identical_streams(self):
        prog = _emit_program(trips=3)
        maps = MapRegistry()
        vm = PolicyVM(prog, maps)
        lp = vm.lowered
        assert lp.facts["rb_cap"] >= 4    # 3 loop emissions + 1 trace
        mat = _ctx_mat(6)
        ref_ev, ref_drops, ref_r0 = _interp_events(vm, mat)
        assert ref_drops == 0
        assert any(e[1] == EV_PROG_TRACE for e in ref_ev)
        for backend in (JitPolicy(lp, maps),
                        PredicatedPolicy(lp, maps, seg_limit=8)):
            r0 = backend.run_batch(mat)
            ev, drops = backend.take_events(mat.shape[0])
            assert [tuple(e) for e in ev] == ref_ev, type(backend).__name__
            assert drops == ref_drops
            assert list(r0) == ref_r0
            # drained: a second take returns nothing
            assert backend.take_events(mat.shape[0]) == ([], 0)

    def test_overflow_drop_parity(self):
        prog = _overflow_program(trips=64)
        maps = MapRegistry()
        vm = PolicyVM(prog, maps)
        assert vm.lowered.facts["rb_cap"] == RB_MAX_PER_RUN
        mat = _ctx_mat(5)
        ref_ev, ref_drops, ref_r0 = _interp_events(vm, mat)
        assert ref_drops == 5 * (2 * 64 - RB_MAX_PER_RUN)
        assert all(r == -1 for r in ref_r0)   # saturated helper returns -1
        for backend in (JitPolicy(vm.lowered, maps),
                        PredicatedPolicy(vm.lowered, maps, seg_limit=64)):
            r0 = backend.run_batch(mat)
            ev, drops = backend.take_events(mat.shape[0])
            assert [tuple(e) for e in ev] == ref_ev, type(backend).__name__
            assert drops == ref_drops
            assert list(r0) == ref_r0

    def test_emit_free_program_has_no_rb_state(self):
        a = Asm()
        a.movi("r0", 4).exit()
        lp = lower(a.build(), MapRegistry())
        assert lp.facts["rb_cap"] == 0
        jit = JitPolicy(lp, MapRegistry())
        assert jit.rb_cap == 0
        assert jit.run_batch(_ctx_mat(4)).tolist() == [4] * 4
        assert jit.take_events(4) == ([], 0)


# ----------------------------------------------- hook registry ring drain
class TestHookRegistryDrain:
    def test_padding_lanes_excluded(self):
        tel = Telemetry()
        reg = HookRegistry(telemetry=tel)
        reg.attach(HOOK_FAULT, _emit_program(trips=2), MapRegistry())
        n = 5                          # pads to 8; 3 padded lanes discarded
        reg.run_batch(HOOK_FAULT, _ctx_mat(n))
        evs = tel.ring.drain()
        prog_evs = [e for e in evs if e[1] >= EV_PROG_BASE]
        assert len(prog_evs) == n * 2
        trace_evs = [e for e in evs if e[1] == EV_PROG_TRACE]
        assert len(trace_evs) == n
        hook_evs = [e for e in evs if e[1] == EV_HOOK]
        assert len(hook_evs) == 1 and hook_evs[0][3] == n
        assert tel.prog_lane_drops == 0

    def test_no_telemetry_is_silent(self):
        reg = HookRegistry()           # telemetry=None: the default config
        reg.attach(HOOK_FAULT, _emit_program(trips=2), MapRegistry())
        out = reg.run_batch(HOOK_FAULT, _ctx_mat(4))
        assert out.shape == (4,)


# ------------------------------------------------- workload-level parity
EXECUTORS = ("interp", "jit", "segmented")


def _run_traced_workload(mode, monkeypatch):
    """Drive a MemoryManager with the TRACED Fig-1 program through one
    executor; return the program-tag + fault event stream."""
    tel = Telemetry()
    cost = make_cost_model(HWSpec(), kv_heads=4, head_dim=64)
    # default_mode="never": unprofiled/fallback addresses fault per-block,
    # so the walk below produces a long stream of program + fault events
    mm = MemoryManager(160, cost, default_mode="never", telemetry=tel)
    mm.load_profile(Profile("app", [
        ProfileRegion(0, 8, (0, 150_000, 0, 0)),
        ProfileRegion(8, 24, (0, 0, 0, 0)),
    ]))
    mm.attach_fault_program(ebpf_mm_program(max_regions=8, trace=True))
    if mode == "jit":
        for ap in mm.hooks._hooks.values():
            if ap is not None:
                ap.pred_unfit = True
    elif mode == "segmented":
        import repro.core.hooks as hooks_mod
        monkeypatch.setattr(hooks_mod, "PRED_MAX_UNROLL", 64)
    for pid in (1, 2, 3):
        mm.create_process(pid, app="app", vma_blocks=24)
    rng = np.random.default_rng(0)
    for step in range(24):
        reqs = [(pid, step, FaultKind.FIRST_TOUCH) for pid in (1, 2, 3)]
        if mode == "interp":
            for pid, addr, kind in reqs:
                mm.ensure_mapped(pid, addr, kind)
        else:
            mm.fault_batch(reqs)
        for pid in (1, 2, 3):
            mm.record_access(pid, rng.random(step + 1) * 2)
        mm.tick()
    if mode == "segmented":
        ap = mm.hooks._hooks[HOOK_FAULT]
        assert ap.pred is not None and ap.pred.num_segments >= 2
    evs = [tuple(e) for e in tel.ring.drain()]
    # the scalar path interleaves program-event/install pairs while the
    # batched path drains a whole batch's program events before installing
    # — so parity is asserted PER TAG CLASS, where order is deterministic
    return {"prog": [e for e in evs if e[1] >= EV_PROG_BASE],
            "fault": [e for e in evs if e[1] == EV_FAULT]}


class TestWorkloadEventParity:
    def test_all_executors_identical(self, monkeypatch):
        streams = {m: _run_traced_workload(m, monkeypatch)
                   for m in EXECUTORS}
        ref = streams["interp"]
        assert len(ref["prog"]) > 30       # the program really traced
        assert len(ref["fault"]) > 30      # the mm tracepoints really fired
        for mode in ("jit", "segmented"):
            assert streams[mode]["prog"] == ref["prog"], \
                f"{mode} program event stream diverged from interpreter"
            assert streams[mode]["fault"] == ref["fault"], \
                f"{mode} fault event stream diverged from interpreter"


# -------------------------------------------------------- exporter schema
def _populated_telemetry() -> Telemetry:
    tel = Telemetry(trace=True)
    tel.emit(EV_FAULT, 1, 5, 2, ts=1_000)
    tel.emit(EV_PROG_BASE, 5, 1, 3, ts=2_000)
    tel.observe_hook("mm_fault", 12_000, 4)
    tel.observe_migrate(30_000)
    tel.inc("backend_builds")
    tel.observe_residency(np.array([0, 1]), np.array([1, 0]),
                          np.array([4, 1]))
    with tel.span("step 0"):
        pass
    return tel


class TestTelemetrySchema:
    def test_snapshot_schema_stable(self):
        snap = _populated_telemetry().snapshot()
        assert set(snap) == {"enabled", "ring", "hooks", "migrate_path_ns",
                             "mgmt_step_ns", "request_ttft_ns",
                             "decode_token_ns", "counters",
                             "residency_block_ticks"}
        assert set(snap["ring"]) == {"capacity", "pending", "emitted",
                                     "dropped", "prog_lane_drops"}
        hook = snap["hooks"]["mm_fault"]
        assert set(hook) == {"invoke_ns", "batch_size"}
        assert set(hook["invoke_ns"]) == {"count", "sum", "p50", "p99",
                                          "buckets"}
        assert snap["counters"]["backend_builds"] == 1
        assert snap["residency_block_ticks"]["t0_o1"] == 4

    def test_disabled_snapshot(self):
        tel = Telemetry(enabled=False)
        assert tel.snapshot()["enabled"] is False
        tel.emit(EV_FAULT, 1, 2, 3)            # no-op, not an error
        assert tel.ring.snapshot()["pending"] == 0

    def test_chrome_trace_structure(self, tmp_path):
        tel = _populated_telemetry()
        doc = chrome_trace(tel)
        assert set(doc) == {"traceEvents", "displayTimeUnit"}
        events = doc["traceEvents"]
        phases = {e["ph"] for e in events}
        assert "X" in phases            # spans
        assert "i" in phases            # ring instants
        assert "M" in phases            # process/thread metadata
        for e in events:
            assert {"ph", "pid", "name"} <= set(e)
        # round-trips through JSON (perfetto-loadable)
        path = tmp_path / "trace.json"
        from repro.obs import write_chrome_trace
        write_chrome_trace(tel, path)
        assert json.loads(path.read_text())["traceEvents"]

    def test_metrics_flatten_and_prometheus(self):
        flat = flatten_metrics({
            "engine": {"steps": 3, "done": True},
            "tier": {"tiers": [{"blocks": 4}]},
            "skip": {"name": "str-dropped"},
        })
        assert flat["engine_steps"] == 3
        assert flat["engine_done"] == 1
        assert flat["tier_tiers_0_blocks"] == 4
        assert not any("name" in k for k in flat)
        text = render_prometheus(flat)
        assert text.endswith("\n")
        for line in text.strip().splitlines():
            assert re.fullmatch(r"repro_[a-zA-Z0-9_]+ -?[0-9.eE+-]+", line), \
                line
        # deterministic ordering
        assert text == render_prometheus(dict(reversed(list(flat.items()))))


# --------------------------------------------------------- cache eviction
class TestCacheLRUEviction:
    def test_size_cap_evicts_oldest(self, tmp_path):
        cache = ArtifactCache(tmp_path, max_bytes=1)   # everything over cap
        progs = []
        for trips in (3, 4, 5):
            lp = lower(_emit_program(trips=trips), MapRegistry())
            cache.unrolled(lp)
            progs.append(lp)
        pkls = list((tmp_path / "ebpf").glob("*.pkl"))
        # each write evicts the previous entry; the just-written one is kept
        assert len(pkls) == 1
        assert cache.stats["evictions"] == 2

    def test_generous_cap_keeps_all(self, tmp_path):
        cache = ArtifactCache(tmp_path, max_bytes=64 * 1024 * 1024)
        for trips in (3, 4):
            cache.unrolled(lower(_emit_program(trips=trips), MapRegistry()))
        assert len(list((tmp_path / "ebpf").glob("*.pkl"))) == 2
        assert cache.stats["evictions"] == 0
