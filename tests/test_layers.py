"""Layer-math references: flash-jnp vs naive, MoE vs dense loop, SSD vs
recurrence, MLA absorbed vs expanded, paged-gather vs dense decode."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import MambaCfg, MoECfg
from repro.kernels.flash_attention.ref import mha_ref
from repro.models.attention import (decode_attention_dense, flash_attention,
                                    mla_absorbed_decode, mla_expand_attention)
from repro.models.common import materialize, ParamSpec
from repro.models.decode import (paged_decode_attention_gather,
                                 write_prefill_kv, write_token_kv)
from repro.models.mamba2 import (mamba_apply, mamba_decode_step, mamba_spec,
                                 mamba_state_init, ssd_chunked)
from repro.models.moe import moe_apply, moe_spec

RNG = np.random.default_rng(3)


def jarr(shape):
    return jnp.asarray(RNG.normal(size=shape).astype(np.float32))


class TestFlashJnp:
    @pytest.mark.parametrize("causal,window", [(True, None), (True, 8),
                                               (False, None)])
    def test_matches_naive(self, causal, window):
        q, k, v = jarr((2, 24, 4, 16)), jarr((2, 24, 2, 16)), jarr((2, 24, 2, 16))
        out = flash_attention(q, k, v, causal=causal, window=window, chunk=8)
        ref = mha_ref(q, k, v, causal=causal, window=window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)

    def test_decode_dense_matches_last_row(self):
        S = 16
        q_full, k, v = jarr((1, S, 4, 16)), jarr((1, S, 2, 16)), jarr((1, S, 2, 16))
        full = mha_ref(q_full, k, v, causal=True)
        dec = decode_attention_dense(q_full[:, -1], k, v,
                                     jnp.asarray([S], jnp.int32))
        np.testing.assert_allclose(np.asarray(dec), np.asarray(full[:, -1]),
                                   rtol=2e-5, atol=2e-5)


class TestPagedGather:
    def test_matches_dense_decode(self):
        B, S, KVH, hd, bt = 2, 32, 2, 16, 4
        NB, MB = 32, 8
        q = jarr((B, 4, hd))
        k_seq, v_seq = jarr((B, S, KVH, hd)), jarr((B, S, KVH, hd))
        pool_k = jnp.zeros((NB, bt, KVH, hd))
        pool_v = jnp.zeros((NB, bt, KVH, hd))
        tbl = np.stack([np.arange(8), np.arange(8) + 8]).astype(np.int32)
        pool_k = write_prefill_kv(pool_k, k_seq, jnp.asarray(tbl), block_tokens=bt)
        pool_v = write_prefill_kv(pool_v, v_seq, jnp.asarray(tbl), block_tokens=bt)
        lengths = jnp.asarray([20, 32], jnp.int32)
        out, heat = paged_decode_attention_gather(
            q, pool_k, pool_v, jnp.asarray(tbl), lengths, block_tokens=bt)
        ref = decode_attention_dense(q, k_seq, v_seq, lengths)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)
        # heat sums to (#heads) per sequence (prob mass over blocks)
        np.testing.assert_allclose(np.asarray(heat.sum(-1)),
                                   np.full(B, 4.0), rtol=1e-4)

    def test_token_write_roundtrip(self):
        NB, bt, KVH, hd, B = 16, 4, 2, 8, 3
        pool = jnp.zeros((NB, bt, KVH, hd))
        new = jarr((B, KVH, hd))
        tbl = jnp.asarray(np.tile(np.arange(5, dtype=np.int32), (B, 1)) +
                          np.arange(B, dtype=np.int32)[:, None] * 5)
        lengths = jnp.asarray([0, 5, 13], jnp.int32)
        pool2 = write_token_kv(pool, new, tbl, lengths, block_tokens=bt)
        for b, L in enumerate([0, 5, 13]):
            phys = int(tbl[b, L // bt])
            np.testing.assert_allclose(np.asarray(pool2[phys, L % bt]),
                                       np.asarray(new[b]), rtol=1e-6)


class TestMoE:
    def _dense_ref(self, params, x, cfg, mlp):
        """Naive per-token loop (no capacity drops)."""
        logits = x @ params["router"]
        probs = jax.nn.softmax(logits, -1)
        gates, idx = jax.lax.top_k(probs, cfg.top_k)
        gates = gates / gates.sum(-1, keepdims=True)
        out = jnp.zeros_like(x)
        for t in range(x.shape[0]):
            acc = jnp.zeros(x.shape[1])
            for j in range(cfg.top_k):
                e = int(idx[t, j])
                h = x[t] @ params["w_in"][e]
                g = x[t] @ params["w_gate"][e]
                h = jax.nn.silu(g) * h
                acc += gates[t, j] * (h @ params["w_out"][e])
            out = out.at[t].set(acc)
        if cfg.num_shared:
            h = x @ params["shared_in"]
            g = x @ params["shared_gate"]
            out = out + (jax.nn.silu(g) * h) @ params["shared_out"]
        return out

    def test_matches_dense_reference_no_drops(self):
        cfg = MoECfg(num_experts=4, top_k=2, d_ff_expert=16, num_shared=1,
                     capacity_factor=8.0)     # huge capacity: no drops
        spec = moe_spec(32, cfg, "swiglu")
        params = materialize(jax.random.PRNGKey(0), spec)
        x = jarr((12, 32))
        out, aux = moe_apply(params, x, cfg, "swiglu")
        ref = self._dense_ref(params, x, cfg, "swiglu")
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)
        assert float(aux) >= 0

    @pytest.mark.parametrize("T,E,k", [
        (4, 2, 1), (7, 4, 2), (12, 8, 1), (16, 2, 2), (21, 4, 1),
        (25, 8, 2), (29, 2, 1), (33, 4, 2), (37, 8, 1), (40, 8, 2),
    ])
    def test_capacity_drops_keep_finite(self, T, E, k):
        cfg = MoECfg(num_experts=E, top_k=k, d_ff_expert=8,
                     capacity_factor=0.5)     # force drops
        spec = moe_spec(16, cfg, "swiglu")
        params = materialize(jax.random.PRNGKey(1), spec)
        out, aux = moe_apply(params, jarr((T, 16)), cfg, "swiglu")
        assert np.isfinite(np.asarray(out)).all()
        assert np.isfinite(float(aux))


class TestMamba2:
    def _naive_recurrence(self, x, dt, A, Bm, Cm):
        """Token-by-token SSM recurrence (the definition SSD must match)."""
        Bsz, S, H, P = x.shape
        N = Bm.shape[-1]
        h = np.zeros((Bsz, H, N, P))
        ys = np.zeros_like(np.asarray(x))
        for t in range(S):
            g = np.exp(np.asarray(dt[:, t]) * np.asarray(A))      # [B,H]
            dBx = np.einsum("bh,bn,bhp->bhnp", np.asarray(dt[:, t]),
                            np.asarray(Bm[:, t]), np.asarray(x[:, t]))
            h = h * g[..., None, None] + dBx
            ys[:, t] = np.einsum("bn,bhnp->bhp", np.asarray(Cm[:, t]), h)
        return ys, h

    def test_ssd_matches_recurrence(self):
        Bsz, S, H, P, N, chunk = 2, 16, 3, 8, 4, 4
        x = jarr((Bsz, S, H, P))
        dt = jnp.abs(jarr((Bsz, S, H))) * 0.5
        A = -jnp.abs(jarr((H,)))
        Bm, Cm = jarr((Bsz, S, N)), jarr((Bsz, S, N))
        y, h_last = ssd_chunked(x, dt, A, Bm, Cm, chunk)
        y_ref, h_ref = self._naive_recurrence(x, dt, A, Bm, Cm)
        np.testing.assert_allclose(np.asarray(y), y_ref, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(h_last), h_ref, rtol=1e-4,
                                   atol=1e-4)

    def test_decode_matches_full_scan(self):
        cfg = MambaCfg(d_state=8, head_dim=8, expand=2, chunk=4, conv_dim=4)
        d = 16
        spec = mamba_spec(d, cfg)
        params = materialize(jax.random.PRNGKey(2), spec)
        x = jarr((1, 12, d))
        full = mamba_apply(params, x, cfg)
        # replay through decode steps
        state = mamba_state_init(1, d, cfg)
        outs = []
        for t in range(12):
            y, state = mamba_decode_step(params, x[:, t], state, cfg)
            outs.append(y)
        dec = jnp.stack(outs, axis=1)
        np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                                   rtol=5e-4, atol=5e-4)


class TestMLA:
    def test_absorbed_matches_expand(self):
        B, S, H, Dn, Dr, L, Dv = 1, 10, 4, 16, 8, 32, 16
        q_nope, q_rope = jarr((B, S, H, Dn)), jarr((B, S, H, Dr))
        c_kv, k_rope = jarr((B, S, L)), jarr((B, S, Dr))
        w_uk, w_uv = jarr((H, L, Dn)), jarr((H, L, Dv))
        full = mla_expand_attention(q_nope, q_rope, c_kv, k_rope, w_uk, w_uv,
                                    causal=True, chunk=4)
        dec = mla_absorbed_decode(q_nope[:, -1], q_rope[:, -1], c_kv, k_rope,
                                  jnp.asarray([S], jnp.int32), w_uk, w_uv)
        np.testing.assert_allclose(np.asarray(dec), np.asarray(full[:, -1]),
                                   rtol=2e-4, atol=2e-4)
