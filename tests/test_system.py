"""End-to-end system behaviour: serving engine across policies (the paper's
workflow), trainer with crash-restart, and the policy-comparison properties
behind Figure 2."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint.store import CheckpointStore
from repro.configs.base import get_smoke_config
from repro.core import Profile, ProfileRegion
from repro.data.pipeline import make_batch_iter
from repro.distributed.fault import SimulatedFailure
from repro.models import PagedLayout, materialize, model_spec
from repro.serving import Request, ServingEngine
from repro.training.trainer import Trainer, TrainerConfig

RNG = jax.random.PRNGKey(0)


def hot_prefix_profile(max_blocks):
    return Profile("chat", [
        ProfileRegion(0, max(4, max_blocks // 4),
                      (0, 150_000, 600_000, 2_500_000)),
        ProfileRegion(max(4, max_blocks // 4), max_blocks, (0, 0, 0, 0)),
    ])


class TestServingPolicies:
    @pytest.fixture(scope="class")
    def setup(self):
        cfg = get_smoke_config("deepseek_7b")
        params = materialize(RNG, model_spec(cfg))
        layout = PagedLayout(num_blocks=256, block_tokens=4, max_blocks=32)
        return cfg, params, layout

    def _run(self, setup, policy, n_req=4):
        cfg, params, layout = setup
        prof = hot_prefix_profile(layout.max_blocks) if policy == "ebpf" else None
        eng = ServingEngine(cfg, params, layout, max_batch=2, policy=policy,
                            profile=prof)
        rng = np.random.default_rng(0)
        for r in range(n_req):
            eng.submit(Request(rid=r,
                               prompt=rng.integers(1, cfg.vocab, 24).tolist(),
                               max_new_tokens=10, app="chat"))
        out = eng.run(max_steps=300)
        assert out["engine"]["completed"] == n_req
        return out

    def test_all_policies_complete(self, setup):
        for policy in ("never", "thp", "ebpf", "thp-prog", "never-prog"):
            out = self._run(setup, policy)
            assert out["engine"]["decode_tokens"] > 0

    def test_fig2_ordering(self, setup):
        """The paper's headline: eBPF-mm ~ THP performance (modeled time,
        TLB-analogue) while allocating fewer huge pages than THP."""
        never = self._run(setup, "never")
        thp = self._run(setup, "thp")
        ebpf = self._run(setup, "ebpf")
        # translation-overhead analogue: never >> thp, ebpf
        assert never["mm"]["descriptors_touched"] > \
            1.5 * thp["mm"]["descriptors_touched"]
        assert ebpf["mm"]["access_ns"] <= 1.2 * thp["mm"]["access_ns"]
        # eBPF must not allocate MORE huge blocks than greedy THP
        huge_ebpf = sum(n * 4 ** o for o, n in
                        enumerate(ebpf["mm"]["pages_per_order"]) if o > 0)
        huge_thp = sum(n * 4 ** o for o, n in
                       enumerate(thp["mm"]["pages_per_order"]) if o > 0)
        assert huge_ebpf <= huge_thp

    def test_same_tokens_across_policies(self, setup):
        """Memory policy must not change model outputs (greedy tokens)."""
        outs = {}
        for policy in ("never", "thp", "ebpf"):
            cfg, params, layout = setup
            prof = hot_prefix_profile(layout.max_blocks) if policy == "ebpf" else None
            eng = ServingEngine(cfg, params, layout, max_batch=2,
                                policy=policy, profile=prof)
            eng.submit(Request(rid=0, prompt=list(range(1, 25)),
                               max_new_tokens=8, app="chat"))
            eng.run(max_steps=100)
            outs[policy] = eng.finished[0]
        assert outs["never"] == outs["thp"] == outs["ebpf"]

    def test_preemption_under_pressure(self, setup):
        cfg, params, _ = setup
        tiny = PagedLayout(num_blocks=24, block_tokens=4, max_blocks=16)
        eng = ServingEngine(cfg, params, tiny, max_batch=3, policy="never")
        rng = np.random.default_rng(1)
        for r in range(3):
            eng.submit(Request(rid=r,
                               prompt=rng.integers(1, cfg.vocab, 30).tolist(),
                               max_new_tokens=16))
        out = eng.run(max_steps=400)
        assert out["engine"]["completed"] == 3
        assert out["engine"]["preemptions"] >= 1


class TestTrainerFaultTolerance:
    def test_loss_decreases_and_restarts(self, tmp_path):
        cfg = get_smoke_config("deepseek_7b")
        params = materialize(RNG, model_spec(cfg))
        data = make_batch_iter(cfg, batch=8, seq_len=32)
        crash = {"armed": True}

        def failure_hook(step):
            if step == 12 and crash["armed"]:
                crash["armed"] = False
                raise SimulatedFailure()

        trainer = Trainer(
            TrainerConfig(num_steps=30, checkpoint_every=10, log_every=5,
                          base_lr=1e-3, chunk=16),
            cfg, params, data, CheckpointStore(tmp_path),
            failure_hook=failure_hook)
        out = trainer.run()
        assert out["restarts"] == 1
        assert out["final_step"] == 30
        losses = [m["loss"] for m in out["metrics"]]
        assert losses[-1] < losses[0] - 0.2, losses

    def test_resume_from_checkpoint(self, tmp_path):
        cfg = get_smoke_config("mamba2_1p3b")
        params = materialize(RNG, model_spec(cfg))
        data = make_batch_iter(cfg, batch=4, seq_len=16)
        store = CheckpointStore(tmp_path)
        t1 = Trainer(TrainerConfig(num_steps=10, checkpoint_every=5,
                                   chunk=8), cfg, params, data, store)
        t1.run()
        # new trainer on the same dir resumes at step 10
        t2 = Trainer(TrainerConfig(num_steps=15, checkpoint_every=5,
                                   chunk=8), cfg, params, data, store)
        assert t2.start_step == 10
        out = t2.run()
        assert out["final_step"] == 15
