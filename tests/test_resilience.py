"""Resilience subsystem units: injector determinism, the quarantine/backoff
state machine, supervisor strike/detach behavior on both dispatch routes,
migration retry/rollback, degraded engine modes, and the live ring consumer.

The chaos DIFFERENTIAL (identical seeded failure schedule across
scalar/batched routes and executors => bit-identical state) lives in
``test_differential.py``; this file covers the state machines and the
engine-level acceptance behaviors directly.
"""

from __future__ import annotations

import numpy as np
import pytest

from repro.core import (Asm, HWSpec, MemoryManager, TieredMemoryManager,
                        make_cost_model, tier_damon_program)
from repro.core.context import (CTX, POLICY_DETACHED, POLICY_FALLBACK,
                                FaultKind)
from repro.core.hooks import HOOK_FAULT, HOOK_TIER, HookRegistry
from repro.core.tiering import (MIGRATE_MAX_ATTEMPTS, TIER_HBM, TIER_HOST)
from repro.obs import EV_DETACH, EV_QUARANTINE, EV_READMIT, EV_RETRY
from repro.obs.telemetry import Telemetry
from repro.resilience import (BACKOFF_BASE_NS, DETACH_THRESHOLD,
                              QUARANTINE_THRESHOLD, SITE_HOOK_RUN,
                              SITE_MIGRATE_COPY, SITE_TIER_ALLOC, SITES,
                              BackoffState, FailureInjector, PolicySupervisor)

pytestmark = pytest.mark.chaos


def mk_cost():
    return make_cost_model(HWSpec(), kv_heads=4, head_dim=64)


def bad_return_program(value: int = -5):
    """A verifier-clean program whose return is BELOW the sentinel range —
    the one thing a policy must never produce (it would be misread as
    POLICY_FALLBACK/POLICY_DETACHED)."""
    a = Asm()
    a.movi("r0", value)
    a.exit()
    return a.build("bad_return")


# --------------------------------------------------------------- injector
class TestFailureInjector:
    def test_pure_and_deterministic(self):
        a = FailureInjector(7, {s: 0.3 for s in SITES})
        b = FailureInjector(7, {s: 0.3 for s in SITES})
        keys = [(s, pid, addr, t) for s in SITES
                for pid in range(4) for addr in range(8)
                for t in (0, 1_000_000)]
        va = [a.fires(s, *k) for s, *k in keys]
        vb = [b.fires(s, *k) for s, *k in keys]
        assert va == vb
        # re-asking the same keys gives the same answers (pure, not a stream)
        assert va == [a.fires(s, *k) for s, *k in keys]
        assert 0 < sum(va) < len(va)

    def test_seed_changes_schedule(self):
        keys = [(pid, addr, 0) for pid in range(16) for addr in range(16)]
        a = FailureInjector(1, {SITE_TIER_ALLOC: 0.3})
        b = FailureInjector(2, {SITE_TIER_ALLOC: 0.3})
        assert [a.fires(SITE_TIER_ALLOC, *k) for k in keys] != \
            [b.fires(SITE_TIER_ALLOC, *k) for k in keys]

    def test_rate_zero_and_unknown_sites(self):
        inj = FailureInjector(0, {SITE_TIER_ALLOC: 0.0})
        assert not inj.armed
        assert not inj.fires(SITE_TIER_ALLOC, 1, 2, 3)
        assert inj.checks[SITE_TIER_ALLOC] == 0     # disarmed: one dict probe
        with pytest.raises(ValueError):
            FailureInjector(0, {"no_such_site": 0.5})

    def test_rate_statistics(self):
        inj = FailureInjector(3, {SITE_HOOK_RUN: 0.25})
        n = 4000
        hits = sum(inj.fires(SITE_HOOK_RUN, i) for i in range(n))
        assert 0.2 < hits / n < 0.3

    def test_link_flap_windows_cohere(self):
        inj = FailureInjector.uniform(11, 0.5, sites=("link_flap",))
        w = inj.flap_window_ns
        for edge in range(3):
            for base in range(0, 6):
                vals = {inj.link_down(edge, base * w + off)
                        for off in (0, w // 3, w - 1)}
                assert len(vals) == 1, "intra-window verdicts must agree"

    def test_snapshot_numeric(self):
        from repro.obs.metrics import flatten_metrics
        inj = FailureInjector(5, {SITE_MIGRATE_COPY: 0.5})
        inj.fires(SITE_MIGRATE_COPY, 1, 2, 0, 1, 0)
        flat = flatten_metrics({"injector": inj.snapshot()})
        assert any(k.endswith("checks") and v > 0 for k, v in flat.items())


# ------------------------------------------------------ backoff/quarantine
class TestBackoffState:
    def test_threshold_then_quarantine(self):
        st = BackoffState()
        for _ in range(QUARANTINE_THRESHOLD - 1):
            assert st.record_error(0) is False
        assert st.ok(0)
        assert st.record_error(0) is True           # newly quarantined
        assert not st.ok(0)
        assert st.level == 1 and st.quarantines == 1
        assert st.quarantined_until == BACKOFF_BASE_NS

    def test_probe_failure_escalates_probe_success_decays(self):
        st = BackoffState()
        for _ in range(QUARANTINE_THRESHOLD):
            st.record_error(0)
        t1 = st.quarantined_until
        assert st.ok(t1)                            # window expired: probe
        # probe fails: window doubles and the edge re-enters quarantine
        assert st.record_error(t1) is True
        assert st.level == 2 and st.quarantines == 2
        assert st.quarantined_until == t1 + (BACKOFF_BASE_NS << 1)
        t2 = st.quarantined_until
        # two successful probes decay level 2 -> 0 and re-admit
        assert st.record_success(t2) is False and st.level == 1
        assert st.record_success(t2) is True and st.level == 0
        assert st.ok(t2) and st.readmits == 1 and st.quarantined_until == -1

    def test_success_resets_consecutive_errors(self):
        st = BackoffState()
        for _ in range(QUARANTINE_THRESHOLD - 1):
            st.record_error(0)
        st.record_success(0)
        assert st.record_error(0) is False          # streak restarted
        assert st.level == 0

    def test_backoff_level_caps(self):
        st = BackoffState()
        now = 0
        for _ in range(40):
            st.record_error(now)
            now = st.quarantined_until
        assert st.level == st.max_level
        assert st.backoff_ns() == st.base_ns << st.max_level


# ------------------------------------------------------------- supervisor
class TestPolicySupervisor:
    def test_detach_at_threshold(self):
        sup = PolicySupervisor(threshold=3)
        assert not sup.strike("mm_fault", 0)
        assert not sup.strike("mm_fault", 1)
        assert sup.strike("mm_fault", 0)
        snap = sup.snapshot()
        assert snap["mm_fault"]["strikes"] == 3

    def test_disabled_counts_but_never_detaches(self):
        sup = PolicySupervisor(threshold=2, enabled=False)
        for _ in range(10):
            assert not sup.strike("mm_fault", 0)
        assert sup.snapshot()["mm_fault"]["strikes"] == 10

    def test_rb_streak_strikes_once_per_limit(self):
        sup = PolicySupervisor(rb_streak_limit=3)
        assert not sup.note_rb_drops("mm_fault", 2)
        assert not sup.note_rb_drops("mm_fault", 1)
        assert sup.note_rb_drops("mm_fault", 4)     # third consecutive
        assert not sup.note_rb_drops("mm_fault", 1)  # streak reset
        sup.note_rb_clean("mm_fault")
        assert not sup.note_rb_drops("mm_fault", 1)  # clean call reset it

    def test_reset_preserves_lifetime_detaches(self):
        sup = PolicySupervisor(threshold=1)
        assert sup.strike("mm_fault", 1)
        sup.record_detach("mm_fault", 1, "prog")
        sup.reset("mm_fault")
        snap = sup.snapshot()["mm_fault"]
        assert snap["strikes"] == 0 and snap["detaches"] == 1

    def test_scalar_route_detaches_bad_program(self):
        mm = MemoryManager(64, mk_cost(), default_mode="never")
        mm.create_process(1, vma_blocks=48)
        mm.attach_fault_program(bad_return_program())
        for addr in range(DETACH_THRESHOLD):
            mm.ensure_mapped(1, addr)
        assert not mm.hooks.attached(HOOK_FAULT)
        snap = mm.hooks.supervisor.snapshot()
        assert snap["mm_fault"]["detaches"] == 1
        assert snap["mm_fault"]["invalid_return"] == DETACH_THRESHOLD
        # strikes fell back to the default path, and post-detach faults run
        # the kernel default with no further accounting
        assert mm.stats.fallback_faults == DETACH_THRESHOLD
        mm.ensure_mapped(1, 40)
        assert mm.stats.fallback_faults == DETACH_THRESHOLD

    def test_batched_route_mid_batch_detach_tail(self):
        reg = HookRegistry(supervisor=PolicySupervisor(threshold=3))
        from repro.core.maps import MapRegistry
        reg.attach(HOOK_FAULT, bad_return_program(), MapRegistry())
        ap = reg._hooks[HOOK_FAULT]
        from repro.core.context import CTX_LEN
        ctx = np.zeros((8, CTX_LEN), dtype=np.int64)
        out = reg.run_batch(HOOK_FAULT, ctx)
        # rows 0..2 strike (-> FALLBACK), row 2 crosses the threshold, and
        # the tail takes the detached sentinel
        assert list(out[:3]) == [POLICY_FALLBACK] * 3
        assert list(out[3:]) == [POLICY_DETACHED] * 5
        assert reg._hooks[HOOK_FAULT] is None and ap is not None

    def test_reattach_resets_strikes(self):
        mm = MemoryManager(64, mk_cost(), default_mode="never")
        mm.create_process(1, vma_blocks=48)
        mm.attach_fault_program(bad_return_program())
        for addr in range(DETACH_THRESHOLD):
            mm.ensure_mapped(1, addr)
        assert not mm.hooks.attached(HOOK_FAULT)
        mm.attach_fault_program(bad_return_program())
        assert mm.hooks.attached(HOOK_FAULT)
        snap = mm.hooks.supervisor.snapshot()["mm_fault"]
        assert snap["strikes"] == 0 and snap["detaches"] == 1

    def test_injected_hook_errors_detach_and_emit(self):
        tel = Telemetry()
        inj = FailureInjector.uniform(3, 1.0, sites=(SITE_HOOK_RUN,))
        mm = MemoryManager(64, mk_cost(), default_mode="never",
                           telemetry=tel, injector=inj)
        mm.create_process(1, vma_blocks=48)
        mm.attach_fault_program(bad_return_program())  # never even runs
        for addr in range(DETACH_THRESHOLD):
            mm.ensure_mapped(1, addr)
        assert not mm.hooks.attached(HOOK_FAULT)
        snap = mm.hooks.supervisor.snapshot()["mm_fault"]
        assert snap["runtime_error"] == DETACH_THRESHOLD
        events = tel.poll_events()
        assert any(e["tag"] == EV_DETACH for e in events)
        assert tel.counters.get("policy_detaches") == 1


# --------------------------------------------------- migration containment
def mk_chaos_tmm(rates, seed=0, containment=True, hbm=32, host=64):
    cost = mk_cost()
    return TieredMemoryManager(
        hbm, cost, host_blocks=host, default_mode="never",
        injector=FailureInjector(seed, rates), containment=containment,
        telemetry=Telemetry())


class TestMigrationContainment:
    def test_copy_failure_retries_then_aborts_with_rollback(self):
        # rate 1.0: every copy attempt fails -> bounded retries, then abort
        mm = mk_chaos_tmm({SITE_MIGRATE_COPY: 1.0})
        mm.create_process(1, vma_blocks=8)
        mm.ensure_range(1, 0, 8)
        host_free0 = mm.host_buddy.free_blocks_total()
        m = mm.procs[1].page_table[0]
        assert not mm.migrate_page(1, 0, TIER_HOST)
        # rollback: page stays put, the dst allocation was released
        assert m.tier == TIER_HBM
        assert mm.host_buddy.free_blocks_total() == host_free0
        assert mm.stats.migrate_retries == MIGRATE_MAX_ATTEMPTS - 1
        assert mm.stats.migrate_aborts == 1
        tags = [e["tag"] for e in mm.telemetry.poll_events()]
        assert tags.count(EV_RETRY) == MIGRATE_MAX_ATTEMPTS - 1

    def test_no_containment_single_shot(self):
        mm = mk_chaos_tmm({SITE_MIGRATE_COPY: 1.0}, containment=False)
        mm.create_process(1, vma_blocks=8)
        mm.ensure_range(1, 0, 8)
        assert not mm.migrate_page(1, 0, TIER_HOST)
        assert mm.stats.migrate_retries == 0
        assert mm.stats.migrate_aborts == 1

    def test_repeated_failures_quarantine_then_readmit(self):
        mm = mk_chaos_tmm({SITE_MIGRATE_COPY: 1.0})
        mm.create_process(1, vma_blocks=16)
        mm.ensure_range(1, 0, 16)
        lgs = sorted(mm.procs[1].page_table)
        fails = 0
        while not mm.health.quarantined_edges(mm.ktime_ns):
            assert not mm.migrate_page(1, lgs[fails % len(lgs)], TIER_HOST)
            fails += 1
            assert fails < 10, "edge never quarantined"
        assert mm.health.edges[0].level >= 1
        events = [e for e in mm.telemetry.poll_events()
                  if e["tag"] == EV_QUARANTINE]
        assert len(events) == 1 and events[0]["a0"] == 0
        # while quarantined, migrate_page skips the edge without any attempt
        retries0 = mm.stats.migrate_retries
        assert not mm.migrate_page(1, lgs[-1], TIER_HOST)
        assert mm.stats.migrate_retries == retries0
        # heal the link: advance modeled time past the window, stop injecting
        mm.injector.rates.clear()
        while not mm.health.edges[0].ok(mm.ktime_ns):
            mm.tick()
        level = mm.health.edges[0].level
        for i in range(level):
            assert mm.migrate_page(1, lgs[i], TIER_HOST)
        assert mm.health.edges[0].level == 0     # fully re-admitted
        assert any(e["tag"] == EV_READMIT
                   for e in mm.telemetry.poll_events())

    def test_alloc_failures_counted_and_hopped(self):
        mm = mk_chaos_tmm({SITE_TIER_ALLOC: 1.0})
        mm.create_process(1, vma_blocks=8)
        mm.ensure_range(1, 0, 8)
        assert not mm.migrate_page(1, 0, TIER_HOST)
        assert mm.stats.tier_alloc_failures > 0
        assert mm.health.tier_alloc_failures[TIER_HOST] > 0

    def test_failure_free_run_untouched_by_machinery(self):
        """containment=True with no injector must behave exactly like the
        seed: no retries, no aborts, health monitor never activates."""
        mm = mk_tiered_pair()[0]
        mm.create_process(1, vma_blocks=8)
        mm.ensure_range(1, 0, 8)
        assert mm.migrate_page(1, 0, TIER_HOST)
        assert mm.stats.migrate_retries == 0
        assert mm.stats.migrate_aborts == 0
        assert mm.health.active is False


def mk_tiered_pair():
    cost_a = mk_cost()
    cost_b = mk_cost()
    a = TieredMemoryManager(32, cost_a, host_blocks=64, default_mode="never")
    b = TieredMemoryManager(32, cost_b, host_blocks=64, default_mode="never",
                            containment=False)
    return a, b


# ------------------------------------------------------- engine-level lanes
@pytest.fixture(scope="module")
def engine_setup():
    import jax
    from repro.configs.base import get_smoke_config
    from repro.models import PagedLayout, materialize, model_spec
    cfg = get_smoke_config("deepseek_7b")
    params = materialize(jax.random.PRNGKey(0), model_spec(cfg))
    layout = PagedLayout(num_blocks=48, block_tokens=4, max_blocks=32)
    return cfg, params, layout


def run_engine(engine_setup, n_req=4, max_steps=200, **kw):
    from repro.core import Profile, ProfileRegion
    from repro.serving import Request, ServingEngine
    cfg, params, layout = engine_setup
    kw.setdefault("policy", "never")
    if kw["policy"] == "ebpf" and "profile" not in kw:
        kw["profile"] = Profile("chat", [
            ProfileRegion(0, 8, (0, 150_000, 600_000, 2_500_000)),
            ProfileRegion(8, 32, (0, 0, 0, 0))])
    eng = ServingEngine(cfg, params, layout, max_batch=4, **kw)
    rng = np.random.default_rng(0)
    for r in range(n_req):
        eng.submit(Request(rid=r,
                           prompt=rng.integers(1, cfg.vocab, 40).tolist(),
                           max_new_tokens=24, app="chat"))
    steps = 0
    while eng.step():
        steps += 1
        if steps >= max_steps:
            break
    return eng


@pytest.mark.timeout(300)
class TestEngineResilience:
    def test_chaos_run_completes_with_containment(self, engine_setup):
        eng = run_engine(engine_setup, host_blocks=128,
                         tier_policy="ebpf-tier", chaos=7, chaos_rate=0.1,
                         telemetry=True)
        assert eng.stats.completed == 4
        m = eng.metrics()
        assert m["resilience_injector_seed"] == 7
        fired = sum(v for k, v in m.items()
                    if k.startswith("resilience_injector") and
                    k.endswith("fired"))
        assert fired > 0, "chaos engine run never injected"

    def test_persistent_spill_failure_degrades_to_preempt(self, engine_setup):
        """Degraded mode: every spill-tier allocation fails -> demotion can
        never relieve pressure, so the engine must fall back to preempt-only
        and still finish the workload (zero crashes)."""
        inj = FailureInjector(1, {SITE_TIER_ALLOC: 1.0})
        eng = run_engine(engine_setup, host_blocks=128,
                         tier_policy="ebpf-tier", chaos=inj)
        assert eng.stats.completed == 4
        assert eng.stats.preemptions > 0        # preempt-only fallback
        assert eng.mm.stats.demotions == 0      # the spill tier never took
        assert eng.mm.stats.tier_alloc_failures > 0

    def test_detach_visible_in_metrics_and_trace(self, engine_setup, tmp_path):
        inj = FailureInjector(3, {SITE_HOOK_RUN: 1.0})
        eng = run_engine(engine_setup, policy="ebpf", chaos=inj,
                         telemetry=True, trace=True)
        assert eng.stats.completed == 4          # fallback kept serving
        assert not eng.mm.hooks.attached(HOOK_FAULT)
        m = eng.metrics()
        assert m["resilience_supervisor_detaches"] >= 1
        assert m["resilience_supervisor_mm_fault_detaches"] == 1
        # EV_DETACH lands in the Chrome trace (write BEFORE poll_events —
        # the live consumer drains the ring destructively)
        trace = tmp_path / "trace.json"
        eng.write_trace(trace)
        assert '"detach"' in trace.read_text()

    def test_poll_events_live_consumer(self, engine_setup):
        eng = run_engine(engine_setup, host_blocks=128,
                         tier_policy="ebpf-tier", chaos=9, chaos_rate=0.15,
                         telemetry=True, max_steps=40)
        batch1 = eng.poll_events()
        assert batch1, "armed chaos run should publish ring events"
        assert all({"tag", "name", "ts", "a0"} <= set(e) for e in batch1)
        # drained: an immediate re-poll returns nothing new
        assert eng.poll_events() == []
        # untelemetered engines return [] instead of raising
        eng2 = run_engine(engine_setup, max_steps=4)
        assert eng2.poll_events() == []

    def test_containment_off_keeps_counters_but_no_detach(self, engine_setup):
        inj = FailureInjector(3, {SITE_HOOK_RUN: 1.0})
        eng = run_engine(engine_setup, policy="ebpf", chaos=inj,
                         containment=False)
        assert eng.stats.completed == 4
        assert eng.mm.hooks.attached(HOOK_FAULT)   # never detached
        m = eng.metrics()
        assert m["resilience_supervisor_mm_fault_strikes"] > DETACH_THRESHOLD
        assert m["resilience_supervisor_detaches"] == 0


# ----------------------------------------------------- cache + placement
class TestArtifactCacheChaos:
    def test_injected_corruption_recompiles(self, tmp_path):
        from repro.core.cache import ArtifactCache
        from repro.core.maps import MapRegistry
        cache = ArtifactCache(root=tmp_path)
        reg1 = HookRegistry(cache=cache)
        reg1.attach(HOOK_FAULT, tier_damon_program(), MapRegistry())
        from repro.core.context import CTX_LEN
        reg1.run_batch(HOOK_FAULT, np.zeros((4, CTX_LEN), dtype=np.int64))
        assert cache.stats["unroll_misses"] == 1
        # fresh session, same disk cache, corruption injected on read
        cache2 = ArtifactCache(root=tmp_path)
        inj = FailureInjector.uniform(0, 1.0, sites=("cache_corrupt",))
        reg2 = HookRegistry(cache=cache2, injector=inj)
        reg2.attach(HOOK_FAULT, tier_damon_program(), MapRegistry())
        out = reg2.run_batch(HOOK_FAULT,
                             np.zeros((4, CTX_LEN), dtype=np.int64))
        assert out is not None                     # recompiled, never raised
        assert cache2.stats["miss_corrupt"] == 1
        assert cache2.stats["unroll_misses"] == 1


class TestDecodePlacement:
    def test_first_touch_batch_consults_tier_hook(self):
        """FIRST_TOUCH fault batches run decode-time placement: with a
        demote-everything tier program attached, freshly installed decode
        blocks land in the spill tier in the same step."""
        from repro.core.context import TIER_DEMOTE
        mm = TieredMemoryManager(32, mk_cost(), host_blocks=64,
                                 default_mode="never")
        a = Asm()
        a.movi("r0", TIER_HOST)
        a.exit()
        mm.attach_tier_program(a.build("demote_all"))
        mm.create_process(1, vma_blocks=8)
        mm.fault_batch([(1, 0, FaultKind.FIRST_TOUCH)])
        assert mm.procs[1].page_table and all(
            m.tier == TIER_HOST for m in mm.procs[1].page_table.values())

    def test_scalar_place_decode_matches_batched(self):
        mms = []
        for batched in (False, True):
            mm = TieredMemoryManager(32, mk_cost(), host_blocks=64,
                                     default_mode="never")
            mm.attach_tier_program(tier_damon_program())
            mm.create_process(1, vma_blocks=8)
            reqs = [(1, a, FaultKind.FIRST_TOUCH) for a in range(4)]
            if batched:
                mm.fault_batch(reqs)
            else:
                for pid, a, kind in reqs:
                    mm.ensure_mapped(pid, a, kind)
                mm.place_decode(reqs)
            mms.append(mm)
        t0 = sorted((m.logical_start, m.tier)
                    for m in mms[0].procs[1].page_table.values())
        t1 = sorted((m.logical_start, m.tier)
                    for m in mms[1].procs[1].page_table.values())
        assert t0 == t1
