"""Online profiling plane: verified profiler programs over the live DAMON
stream, the sampled HOOK_PROFILE surface, and host-side profile synthesis.

Four layers pinned here:

* the three shipped profiler programs (WSS/idle estimator, log2
  heat-histogram accumulator, promotion-benefit scorer) pass the verifier
  and decide + emit BIT-IDENTICALLY on the interpreter, the while+switch
  JIT and the segmented predicated executor — the profiling plane obeys
  the same parity contract as every other hook;
* ``mm.profile_scan``: one batched HOOK_PROFILE invocation per sampled
  process, rows aligned with the DAMON region snapshot;
* the ProfileSynthesizer: scans fold into profiles in the offline
  ``profile_from_heat`` mold, hot-reloads are map WRITEs (verified map
  ids survive), convergence stops the reload churn, and the EV_WSS /
  EV_PROFILE attribution events + WSS curve land in telemetry;
* exporter schema: the new event tags have stable names, and the Chrome
  trace grows the ``mm profiler`` track (WSS counter series, heat-bucket
  counters, reload instants).
"""

from __future__ import annotations

import json

import numpy as np
import pytest

from repro.core import (HWSpec, JitPolicy, MapRegistry, MemoryManager,
                        PolicyVM, PredicatedPolicy, Profile, ProfileRegion,
                        ProfileSynthesizer, make_cost_model,
                        profile_benefit_program,
                        profile_heat_histogram_program, profile_wss_program)
from repro.core.context import CTX, FIXED_POINT, ctx_batch
from repro.core.hooks import HOOK_PROFILE
from repro.obs import (EV_PROFILE, EV_WSS, PROF_TAG_BENEFIT, PROF_TAG_HEAT,
                       PROF_TAG_WSS, Telemetry, chrome_trace, tag_name)

PROFILER_PROGRAMS = (profile_wss_program, profile_heat_histogram_program,
                     profile_benefit_program)


def mk_mm(blocks=256, **kw):
    cost = make_cost_model(HWSpec(), kv_heads=4, head_dim=64)
    return MemoryManager(blocks, cost, **kw)


def _region_ctx(n: int) -> np.ndarray:
    """A batch of synthetic DAMON-region rows spanning idle, lukewarm and
    hot regions of varying sizes (including spans too small for any large
    order — the benefit program's fit check)."""
    rng = np.random.default_rng(7)
    mat = ctx_batch(n)
    start = 0
    for i in range(n):
        span = int(rng.integers(1, 40))
        mat[i, CTX.PROF_REGION_START] = start
        mat[i, CTX.PROF_REGION_END] = start + span
        start += span
        mat[i, CTX.PROF_REGION_HEAT] = int(rng.integers(0, 9_000))
        mat[i, CTX.PROF_REGION_AGE] = int(rng.integers(0, 12))
    mat[:, CTX.PID] = 3
    mat[:, CTX.PROF_MAPPED_BLOCKS] = start
    mat[:, CTX.PROF_WINDOW] = 5
    mat[:, CTX.KTIME_NS] = 1_000_000 + np.arange(n)
    mat[:, CTX.DESCRIPTOR_NS] = 100
    return mat


# --------------------------------------------------- executor parity
class TestProfilerProgramParity:
    @pytest.mark.parametrize("factory", PROFILER_PROGRAMS,
                             ids=lambda f: f.__name__)
    def test_three_executors_bit_identical(self, factory):
        prog = factory()
        maps = MapRegistry()
        vm = PolicyVM(prog, maps)          # verifier accepts at attach
        assert vm.lowered.facts["rb_cap"] >= 1   # each lane emits once
        mat = _region_ctx(11)
        ref_ev, ref_drops, ref_r0 = [], 0, []
        for row in mat:
            res = vm.run(row)
            ref_ev.extend(tuple(e) for e in res.events)
            ref_drops += res.dropped
            ref_r0.append(res.ret)
        assert len(ref_ev) == 11           # exactly one emission per region
        for backend in (JitPolicy(vm.lowered, maps),
                        PredicatedPolicy(vm.lowered, maps, seg_limit=32)):
            r0 = backend.run_batch(mat)
            ev, drops = backend.take_events(mat.shape[0])
            name = type(backend).__name__
            assert [tuple(e) for e in ev] == ref_ev, name
            assert drops == ref_drops, name
            assert list(r0) == ref_r0, name

    def test_wss_semantics(self):
        vm = PolicyVM(profile_wss_program(idle_milli=50), MapRegistry())
        mat = _region_ctx(2)
        mat[0, CTX.PROF_REGION_START], mat[0, CTX.PROF_REGION_END] = 0, 10
        mat[0, CTX.PROF_REGION_HEAT] = 49          # idle: below threshold
        mat[1, CTX.PROF_REGION_START], mat[1, CTX.PROF_REGION_END] = 10, 16
        mat[1, CTX.PROF_REGION_HEAT] = 800
        cold = vm.run(mat[0])
        hot = vm.run(mat[1])
        assert cold.ret == 0                       # PROFILE_COLD
        assert hot.ret == 800                      # hot score = heat
        # emitted (tag, pid, wss_contribution, span)
        assert cold.events[0][1:] == (PROF_TAG_WSS, 3, 0, 10)
        assert hot.events[0][1:] == (PROF_TAG_WSS, 3, 6, 6)

    def test_heat_histogram_bucket(self):
        vm = PolicyVM(profile_heat_histogram_program(), MapRegistry())
        mat = _region_ctx(3)
        for row, heat in zip(mat, (0, 1024, 5000)):
            row[CTX.PROF_REGION_HEAT] = heat
        buckets = [vm.run(row).ret for row in mat]
        assert buckets[0] == 0
        assert buckets[1] == 10                    # floor(log2(1024))
        assert buckets[2] == 12                    # floor(log2(5000))

    def test_benefit_respects_region_fit(self):
        vm = PolicyVM(profile_benefit_program(), MapRegistry())
        mat = _region_ctx(2)
        for row in mat:
            row[CTX.PROF_REGION_HEAT] = 8_000
            row[CTX.DESCRIPTOR_NS] = 1_000
        mat[0, CTX.PROF_REGION_START], mat[0, CTX.PROF_REGION_END] = 0, 3
        mat[1, CTX.PROF_REGION_START], mat[1, CTX.PROF_REGION_END] = 0, 64
        small = vm.run(mat[0])
        big = vm.run(mat[1])
        # a 3-block region fits no order >= 1: nothing scores
        assert small.ret == 0 and small.events[0][3] == 0
        # a 64-block hot region scores some order with positive net benefit
        assert big.ret > 0
        assert 1 <= big.events[0][3] <= 3          # a1 = chosen order


# --------------------------------------------------------- profile_scan
class TestProfileScan:
    def test_rows_align_with_damon_regions(self):
        mm = mk_mm()
        mm.create_process(1, app="app", vma_blocks=64)
        mm.attach_profile_program(profile_wss_program())
        heat = np.zeros(64)
        heat[:16] = 8.0
        for _ in range(5):
            mm.record_access(1, heat)
            mm.tick()
        rows = mm.profile_scan(1)
        regions = mm.procs[1].damon.regions
        assert len(rows) == len(regions)
        for (start, end, heat_milli, age, _score), r in zip(rows, regions):
            assert (start, end) == (r.start, r.end)
            assert heat_milli == int(r.nr_accesses * FIXED_POINT)
            assert age == r.age
        # the hot span scored hot, the cold tail cold
        assert any(s > 0 for st, _e, _h, _a, s in rows if st < 16)
        assert all(s == 0 for st, _e, _h, _a, s in rows if st >= 32)

    def test_no_program_returns_none(self):
        mm = mk_mm()
        mm.create_process(1, app="app", vma_blocks=8)
        assert mm.profile_scan(1) is None
        assert not mm.hooks.attached(HOOK_PROFILE)


# ----------------------------------------------------------- synthesizer
def _warmed_mm(tel=None):
    mm = mk_mm(telemetry=tel)
    mm.create_process(1, app="chat", vma_blocks=64)
    mm.attach_profile_program(profile_wss_program())
    heat = np.zeros(64)
    heat[:16] = 9.0
    for _ in range(6):
        mm.record_access(1, heat)
        mm.tick()
    return mm


class TestProfileSynthesizer:
    def test_synthesizes_and_hot_reloads(self):
        tel = Telemetry()
        mm = _warmed_mm(tel)
        # preload an empty profile so the reload demonstrably reuses the
        # registered map slot (the verified-map-id contract)
        slot_before = mm.load_profile(Profile("chat", []))
        syn = ProfileSynthesizer(mm, mm.cost, period=1, max_regions=8,
                                 telemetry=tel)
        assert syn.tick([(1, "chat")]) == ["chat"]
        prof, slot_after = mm.profiles["chat"]
        assert slot_after == slot_before           # map WRITE, not a new map
        assert prof.regions, "synthesized profile has a hot region"
        assert prof.regions[0].start == 0
        assert 8 <= prof.regions[0].end <= 24      # the hot [0, 16) span
        assert max(prof.regions[0].benefit) > 0
        evs = [tuple(e) for e in tel.ring.drain()]
        assert any(e[1] == EV_WSS and e[2] == 1 for e in evs)
        assert any(e[1] == EV_PROFILE for e in evs)
        assert tel.counters["profile_scans"] == 1
        assert tel.counters["profile_reloads"] == 1

    def test_convergence_stops_reload_churn(self):
        mm = _warmed_mm()
        syn = ProfileSynthesizer(mm, mm.cost, period=1, max_regions=8)
        assert syn.tick([(1, "chat")]) == ["chat"]
        v1 = syn.versions["chat"]
        # identical DAMON state -> identical profile -> no reload
        assert syn.tick([(1, "chat")]) == []
        assert syn.versions["chat"] == v1
        assert syn.reloads == 1 and syn.scans == 2

    def test_period_rate_limits_scans(self):
        mm = _warmed_mm()
        syn = ProfileSynthesizer(mm, mm.cost, period=4)
        for _ in range(7):
            syn.tick([(1, "chat")])
        assert syn.scans == 1                      # only the 4th tick scans

    def test_wss_curve_and_snapshot(self, tmp_path):
        mm = _warmed_mm()
        syn = ProfileSynthesizer(mm, mm.cost, period=1, max_regions=8)
        syn.tick([(1, "chat")])
        snap = syn.snapshot()
        assert set(snap) == {"scans", "reloads", "wss_blocks", "apps"}
        assert snap["wss_blocks"]["1"] > 0
        app = snap["apps"]["chat"]
        assert set(app) == {"version", "regions", "region_start",
                            "region_end", "region_benefit_top"}
        assert len(app["region_start"]) == app["regions"]
        path = tmp_path / "wss.json"
        syn.write_wss_curve(path)
        curve = json.loads(path.read_text())
        assert len(curve["1"]) == 1
        t, wss, mapped = curve["1"][0]
        assert wss == snap["wss_blocks"]["1"]

    def test_detached_profiler_is_inert(self):
        mm = mk_mm()
        mm.create_process(1, app="chat", vma_blocks=16)
        syn = ProfileSynthesizer(mm, mm.cost, period=1)
        assert syn.tick([(1, "chat")]) == []       # no program attached
        assert syn.scans == 0 and syn.reloads == 0


# ------------------------------------------------------- exporter schema
class TestProfilerEventSchema:
    def test_tag_names_stable(self):
        assert tag_name(EV_PROFILE) == "profile_reload"
        assert tag_name(EV_WSS) == "wss_sample"
        assert tag_name(PROF_TAG_WSS) == "prof_wss"
        assert tag_name(PROF_TAG_HEAT) == "prof_heat"
        assert tag_name(PROF_TAG_BENEFIT) == "prof_benefit"

    def test_trace_grows_profiler_track(self):
        tel = Telemetry(trace=True)
        tel.emit(EV_WSS, 1, 12, 20, ts=1_000)
        tel.emit(PROF_TAG_HEAT, 1, 5, 8, ts=1_500)
        tel.emit(EV_PROFILE, 1, 2, 3, ts=2_000)
        doc = chrome_trace(tel)
        ev = doc["traceEvents"]
        names = [e["args"]["name"] for e in ev
                 if e["ph"] == "M" and e["name"] == "thread_name"
                 and e.get("pid") == 2]
        assert "mm profiler" in names
        wss = [e for e in ev if e["ph"] == "C" and e["name"] == "wss pid1"]
        assert wss and wss[0]["args"] == {"wss_blocks": 12,
                                          "mapped_blocks": 8}
        heat = [e for e in ev if e["ph"] == "C"
                and e["name"] == "heat b5 pid1"]
        assert heat and heat[0]["args"] == {"blocks": 8}
        reload_ = [e for e in ev if e["name"] == "profile reload v3"]
        assert reload_ and reload_[0]["tid"] == 3
        assert reload_[0]["args"] == {"pid": 1, "regions": 2, "version": 3}
