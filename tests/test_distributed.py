"""Sharding rules, gradient compression, fault tolerance, checkpoint store."""

import os
import subprocess
import sys

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.checkpoint.store import CheckpointStore
from repro.configs.base import get_config
from repro.distributed.compression import (compress_residual, dequantize_int8,
                                           quantize_int8)
from repro.distributed.fault import (HeartbeatRegistry, RestartableLoop,
                                     SimulatedFailure, StepWatchdog,
                                     elastic_plan)
from repro.distributed.sharding import spec_for, zero1_spec
from repro.launch.mesh import make_host_mesh
from repro.models.common import abstract, logical_axes
from repro.models.transformer import model_spec


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape

    @property
    def axis_names(self):
        return tuple(self.shape.keys())


class TestShardingRules:
    MESH = FakeMesh({"data": 16, "model": 16})
    PODMESH = FakeMesh({"pod": 2, "data": 16, "model": 16})

    def test_tp_axes(self):
        assert spec_for((4096, 24576), ("embed", "ff"), self.MESH) == \
            P(None, "model")
        assert spec_for((256000, 6144), ("vocab", "embed"), self.MESH) == \
            P("model")
        assert spec_for((64, 2048, 1408), ("expert", "embed", "ff"),
                        self.MESH) == P("model")   # expert wins model first

    def test_divisibility_fallback(self):
        # qwen2-vl: 28 q_heads * 128 = 3584 -> divisible; kv 4*128=512 OK;
        # but e.g. a 28-dim head axis alone must replicate
        assert spec_for((28, 100), ("q_heads", None), self.MESH) == P()
        assert spec_for((51865, 1024), ("vocab", "embed"), self.MESH) == P()

    def test_no_axis_reuse(self):
        s = spec_for((64, 4096, 1408), ("expert", "ff", "ff"), self.MESH)
        assert s == P("model")     # second ff cannot reuse model

    def test_zero1_adds_data_axis(self):
        s = zero1_spec((4096, 24576), ("embed", "ff"), self.MESH)
        assert s == P("data", "model")
        s2 = zero1_spec((233, 24576), ("embed", "ff"), self.MESH)
        assert s2 == P(None, "model") or s2 == P(None, "model")

    def test_full_model_spec_has_tp(self):
        cfg = get_config("nemotron_4_15b")
        spec = model_spec(cfg)
        ab, ax = abstract(spec), logical_axes(spec)
        s = spec_for(tuple(ab["blocks"]["s0"]["m0"]["mlp"]["w_in"].shape),
                     ax["blocks"]["s0"]["m0"]["mlp"]["w_in"], self.MESH)
        assert "model" in str(s)


class TestCompression:
    def test_quantize_roundtrip_error_bounded(self):
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=(16, 256)).astype(np.float32))
        q, scale = quantize_int8(x)
        deq = dequantize_int8(q, scale)
        err = np.abs(np.asarray(deq - x))
        amax = np.abs(np.asarray(x)).max(-1, keepdims=True)
        assert (err <= amax / 127.0 * 0.51 + 1e-7).all()

    def test_error_feedback_carries_residual(self):
        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.normal(size=(8, 64)).astype(np.float32))
        err = jnp.zeros_like(x)
        q, scale, new_err = compress_residual(x, err)
        deq = dequantize_int8(q, scale).reshape(x.shape)
        np.testing.assert_allclose(np.asarray(deq + new_err), np.asarray(x),
                                   rtol=1e-5, atol=1e-5)

    def test_compressed_allreduce_multidevice_subprocess(self):
        """Run the int8 all-reduce on 8 fake devices in a subprocess."""
        code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.distributed.compression import compressed_psum_mean
mesh = jax.make_mesh((8,), ("data",))
rng = np.random.default_rng(0)
g = jnp.asarray(rng.normal(size=(8, 4, 32)).astype(np.float32))  # per-shard grads
mean, err = compressed_psum_mean(g, mesh, "data", mode="int8")
exact = np.asarray(g).mean(0)
got = np.asarray(mean)[0] if np.asarray(mean).ndim == 3 else np.asarray(mean)
rel = np.abs(got - exact).max() / (np.abs(exact).max() + 1e-9)
assert rel < 0.02, rel
print("REL_ERR", rel)
"""
        env = dict(os.environ, PYTHONPATH="src")
        r = subprocess.run([sys.executable, "-c", code], cwd="/root/repo",
                           env=env, capture_output=True, text=True,
                           timeout=300)
        assert r.returncode == 0, r.stderr[-2000:]
        assert "REL_ERR" in r.stdout


class TestFaultTolerance:
    def test_watchdog_flags_stragglers(self):
        w = StepWatchdog(slow_factor=3.0, escalate_after=2)
        for _ in range(8):
            w.record(1.0)
        assert not w.record(1.1)["slow"]
        assert w.record(10.0)["slow"]
        out = w.record(12.0)
        assert out["slow"] and out["restart_recommended"]

    def test_heartbeats(self):
        h = HeartbeatRegistry(timeout_s=10)
        h.beat("w0", now=0.0)
        h.beat("w1", now=0.0)
        assert h.healthy(now=5.0)
        h.beat("w0", now=20.0)
        assert h.dead_workers(now=21.0) == ["w1"]

    def test_elastic_plan(self):
        assert elastic_plan(512, model_axis=16) == (32, 16)
        assert elastic_plan(256, model_axis=16) == (16, 16)
        assert elastic_plan(240, model_axis=16) == (15, 16)
        assert elastic_plan(8, model_axis=16) == (1, 8)

    def test_restartable_loop_replays(self):
        saves = {}

        def save(state, step):
            saves["ckpt"] = (dict(state), step)

        def restore():
            return dict(saves["ckpt"][0]), saves["ckpt"][1]

        crashed = {"done": False}

        def step_fn(state, step):
            if step == 7 and not crashed["done"]:
                crashed["done"] = True
                raise SimulatedFailure()
            state["x"] += 1
            return state

        loop = RestartableLoop(save, restore)
        state, step = loop.run({"x": 0}, 0, 10, step_fn, checkpoint_every=5)
        assert step == 10 and loop.restarts == 1
        # restore rewinds to the step-5 snapshot (x=5); steps 5..9 replay on
        # the restored state, so the final count is exactly 10 — replay must
        # NOT double-apply the crashed steps
        assert state["x"] == 10


class TestCheckpointStore:
    def test_roundtrip_and_gc(self, tmp_path):
        store = CheckpointStore(tmp_path, num_shards=3)
        tree = {"a": jnp.arange(10), "b": {"c": jnp.ones((4, 4))}}
        for step in (5, 10, 15, 20):
            store.save(tree, step=step, keep=2)
        assert store.all_steps() == [15, 20]
        got, meta = store.restore(20, like=tree)
        assert meta["step"] == 20
        np.testing.assert_array_equal(np.asarray(got["a"]), np.arange(10))

    def test_uncommitted_checkpoint_invisible(self, tmp_path):
        store = CheckpointStore(tmp_path)
        tree = {"a": jnp.arange(4)}
        store.save(tree, step=1)
        # simulate a crash mid-write: a dir without the commit marker
        bad = tmp_path / "step_000000099"
        bad.mkdir()
        (bad / "manifest.json").write_text("{}")
        assert store.latest_step() == 1

    def test_async_save(self, tmp_path):
        store = CheckpointStore(tmp_path)
        tree = {"a": jnp.arange(100)}
        store.save(tree, step=7, blocking=False)
        store.wait()
        assert store.all_steps() == [7]

    def test_elastic_restore_new_sharding(self, tmp_path):
        store = CheckpointStore(tmp_path)
        tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
        store.save(tree, step=3)
        mesh = make_host_mesh(model=1)
        from jax.sharding import NamedSharding
        sh = {"w": NamedSharding(mesh, P("data"))}
        got, _ = store.restore(3, like=tree, shardings=sh)
        assert got["w"].sharding.is_equivalent_to(sh["w"], 2)
