"""Multi-device equivalence of the distributed (shard_map) execution paths
against single-device references, on 8 fake CPU devices in a subprocess
(device count must be set before jax initializes — hence the isolation).

Covers the §Perf hillclimb code paths:
  * paged_decode_attention_sharded (GQA flash-decoding, batch-sharded)
  * paged_mla_decode_sharded       (MLA latent flash-decoding)
  * moe_apply_ep                   (expert-parallel all-to-all dispatch)
"""

import os
import subprocess
import sys

import pytest

CODE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

mesh = jax.make_mesh((2, 4), ("data", "model"))
rng = np.random.default_rng(0)

# ---------------- GQA flash-decoding vs gather reference -----------------
from repro.distributed.flashdecode import (set_decode_mesh,
                                           paged_decode_attention_sharded)
from repro.models.decode import paged_decode_attention_gather

set_decode_mesh(mesh)
B, H, KVH, hd, bt = 4, 8, 4, 16, 4
NB, MB = 64, 8          # NB divisible by 8 shards; MB by model=4
q = jnp.asarray(rng.normal(size=(B, H, hd)).astype(np.float32))
pk = jnp.asarray(rng.normal(size=(NB, bt, KVH, hd)).astype(np.float32))
pv = jnp.asarray(rng.normal(size=(NB, bt, KVH, hd)).astype(np.float32))
lengths = jnp.asarray([9, 17, 25, 32], jnp.int32)

# blocks for sequence b (data shard d = b // 2) must live in shard rows:
# shard (d, m) owns rows [ (d*4+m)*8, +8 ). Round-robin logical blocks over m.
NB_loc = NB // 8
tbl = np.full((B, MB), -1, np.int32)
sh_tbl = np.full((B, 4, MB // 4), -1, np.int32)
sh_log = np.full((B, 4, MB // 4), -1, np.int32)
counters = {}
for b in range(B):
    d = b // 2
    nblk = int(np.ceil(float(lengths[b]) / bt))
    for lb in range(nblk):
        m = lb % 4
        shard = d * 4 + m
        slot = counters.get((shard, b), 0)
        counters[(shard, b)] = slot + 1
        phys = shard * NB_loc + b % 2 + slot * 2     # unique row in shard
        tbl[b, lb] = phys
        sh_tbl[b, m, lb // 4] = phys
        sh_log[b, m, lb // 4] = lb
tbl, sh_tbl, sh_log = map(jnp.asarray, (tbl, sh_tbl, sh_log))

ref_out, ref_heat = paged_decode_attention_gather(
    q, pk, pv, tbl, lengths, block_tokens=bt)
out, heat = jax.jit(lambda *a: paged_decode_attention_sharded(
    *a, block_tokens=bt))(q, pk, pv, sh_tbl, sh_log, lengths)
np.testing.assert_allclose(np.asarray(out), np.asarray(ref_out),
                           rtol=2e-5, atol=2e-5)
# heat is normalized per shard (running-max semantics, like the Pallas
# kernel) so only structural invariants hold vs the exact reference
h = np.asarray(heat)
assert np.isfinite(h).all() and (h >= 0).all()
assert (h.sum(-1) > 0).all()
print("GQA flashdecode OK")

# ---------------- MLA latent flash-decoding ------------------------------
from repro.distributed.flashdecode import paged_mla_decode_sharded
from repro.models.decode import paged_decode_attention_mla_gather

L, Dr, Dn = 32, 8, 16
pool = jnp.asarray(rng.normal(size=(NB, bt, L + Dr)).astype(np.float32))
q_eff = jnp.asarray(rng.normal(size=(B, H, L)).astype(np.float32))
q_rope = jnp.asarray(rng.normal(size=(B, H, Dr)).astype(np.float32))
r_lat, r_heat = paged_decode_attention_mla_gather(
    q_eff, q_rope, pool, tbl, lengths, block_tokens=bt, kv_lora=L,
    qk_nope=Dn)
o_lat, m_heat = jax.jit(lambda *a: paged_mla_decode_sharded(
    *a, block_tokens=bt, kv_lora=L, qk_nope=Dn))(
        q_eff, q_rope, pool, sh_tbl, sh_log, lengths)
np.testing.assert_allclose(np.asarray(o_lat), np.asarray(r_lat),
                           rtol=2e-5, atol=2e-5)
mh = np.asarray(m_heat)
assert np.isfinite(mh).all() and (mh >= 0).all() and (mh.sum(-1) > 0).all()
print("MLA flashdecode OK")

# ---------------- EP MoE vs local dispatch -------------------------------
from repro.configs.base import MoECfg
from repro.models.moe import moe_apply_ep, _moe_apply_local, moe_spec
from repro.models.common import materialize

cfg = MoECfg(num_experts=8, top_k=2, d_ff_expert=16, num_shared=1,
             capacity_factor=8.0)
spec = moe_spec(32, cfg, "swiglu")
params = materialize(jax.random.PRNGKey(1), spec)
x = jnp.asarray(rng.normal(size=(16, 32)).astype(np.float32))
ref, ref_aux = _moe_apply_local(params, x, cfg, "swiglu")
out, aux = moe_apply_ep(params, x, cfg, "swiglu", mesh)
np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                           rtol=1e-4, atol=1e-4)
print("EP MoE OK; aux local/ep:", float(ref_aux), float(aux))
"""


@pytest.mark.timeout(600)
def test_shardmap_paths_match_references():
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", CODE], cwd="/root/repo",
                       env=env, capture_output=True, text=True, timeout=580)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    assert "GQA flashdecode OK" in r.stdout
    assert "MLA flashdecode OK" in r.stdout
    assert "EP MoE OK" in r.stdout
