"""Tiered-memory subsystem: demote/promote round-trips, HOOK_TIER programs,
OOM-in-both-tiers preemption fallback, and stats/occupancy invariants."""

import numpy as np
import jax
import pytest

from repro.configs.base import get_smoke_config
from repro.core import (HWSpec, JitPolicy, MapRegistry, MMOutOfMemory,
                        PolicyVM, TieredMemoryManager, make_cost_model,
                        tier_damon_program, tier_lru_program,
                        tier_never_program)
from repro.core.buddy import order_blocks
from repro.core.context import CTX, FaultContext, TIER_DEMOTE, TIER_KEEP
from repro.core.tiering import TIER_HBM, TIER_HOST
from repro.models import PagedLayout, materialize, model_spec
from repro.serving import Request, ServingEngine

RNG = jax.random.PRNGKey(0)


def mk_tmm(hbm=32, host=64, default="never"):
    cost = make_cost_model(HWSpec(), kv_heads=8, head_dim=128)
    return TieredMemoryManager(hbm, cost, host_blocks=host,
                               default_mode=default)


def apply_moves(pool: np.ndarray, moves) -> None:
    """Sequential move application — the engine's batching is equivalent."""
    for src, dst, order in moves:
        n = order_blocks(order)
        pool[dst:dst + n] = pool[src:src + n]


class TestMigration:
    def test_demote_promote_roundtrip_preserves_contents(self):
        mm = mk_tmm(hbm=32, host=32)
        mm.create_process(1, vma_blocks=16)
        mm.ensure_range(1, 0, 16)
        mm.drain_moves()
        pool = np.zeros(mm.device_pool_blocks, np.int64)
        t0 = mm.block_table(1, 16)
        content = np.arange(16) + 100
        pool[t0] = content

        for lg in sorted(mm.procs[1].page_table):
            assert mm.demote_page(1, lg)
        apply_moves(pool, mm.drain_moves())
        t1 = mm.block_table(1, 16)
        assert (t1 >= 32).all(), "all pages should be host-resident"
        np.testing.assert_array_equal(pool[t1], content)

        for lg in sorted(mm.procs[1].page_table):
            assert mm.promote_page(1, lg)
        apply_moves(pool, mm.drain_moves())
        t2 = mm.block_table(1, 16)
        assert (t2 < 32).all(), "all pages should be back in HBM"
        np.testing.assert_array_equal(pool[t2], content)
        mm.buddy.check_invariants()
        mm.host_buddy.check_invariants()

    def test_roundtrip_with_huge_pages(self):
        mm = mk_tmm(hbm=64, host=64, default="thp")
        mm.create_process(1, vma_blocks=32)
        mm.ensure_range(1, 0, 32)     # thp default -> order-2 pages
        assert any(m.order > 0 for m in mm.procs[1].page_table.values())
        mm.drain_moves()
        pool = np.zeros(mm.device_pool_blocks, np.int64)
        t0 = mm.block_table(1, 32)
        content = np.arange(32) + 7
        pool[t0] = content
        for lg in sorted(mm.procs[1].page_table):
            assert mm.demote_page(1, lg)
        for lg in sorted(mm.procs[1].page_table):
            assert mm.promote_page(1, lg)
        apply_moves(pool, mm.drain_moves())
        np.testing.assert_array_equal(pool[mm.block_table(1, 32)], content)

    def test_demote_fails_when_host_full(self):
        mm = mk_tmm(hbm=32, host=4)
        mm.create_process(1, vma_blocks=16)
        mm.ensure_range(1, 0, 16)
        demoted = sum(mm.demote_page(1, lg)
                      for lg in sorted(mm.procs[1].page_table))
        assert demoted == 4           # host pool capacity
        assert mm.stats.demotion_blocks == 4

    def test_free_process_releases_both_tiers(self):
        mm = mk_tmm(hbm=16, host=16)
        mm.create_process(1, vma_blocks=8)
        mm.ensure_range(1, 0, 8)
        for lg in list(mm.procs[1].page_table)[:4]:
            mm.demote_page(1, lg)
        mm.free_process(1)
        assert mm.buddy.free_blocks_total() == 16
        assert mm.host_buddy.free_blocks_total() == 16
        mm.buddy.check_invariants()
        mm.host_buddy.check_invariants()


class TestTierPrograms:
    def test_verifier_accepts_tier_programs(self):
        for prog in (tier_damon_program(), tier_lru_program(),
                     tier_never_program()):
            PolicyVM(prog, MapRegistry())     # must not raise

    def _ctx(self, **kw):
        fc = FaultContext(
            addr=0, pid=1, vma_start=0, vma_end=64, fault_max_order=0,
            has_profile=0, profile_map_id=0, profile_nregions=0,
            free_blocks=(0, 0, 0, 0), frag=(0, 0, 0, 0), heat=(0, 0, 0, 0),
            zero_ns_per_block=700, compact_ns_per_block=1300,
            descriptor_ns=800, block_bytes=65536,
            mem_pressure=kw.get("pressure", 1000),
            tier_free_blocks=kw.get("tier_free", 64),
            tier_total_blocks=64,
            pcie_ns_per_block=kw.get("pcie", 2048),
            page_tier=kw.get("tier", 0), page_order=kw.get("order", 0),
            page_age=kw.get("age", 0), page_heat=kw.get("heat", 0),
            migrate_setup_ns=kw.get("setup", 2000),
            migrate_ns_per_block=kw.get("mig", 2208),
            ntiers=kw.get("ntiers", 2),
            mig_cum_setup=(0,) + (kw.get("setup", 2000),) * 3,
            mig_cum_ns=(0,) + (kw.get("mig", 2208),) * 3)
        return fc.vector()

    def test_damon_admission_control(self):
        vm = PolicyVM(tier_damon_program(), MapRegistry())
        # cold page under pressure -> demote
        assert vm.run(self._ctx(heat=0, pressure=950)).ret == TIER_DEMOTE
        # hot page under soft pressure -> vetoed
        assert vm.run(self._ctx(heat=900, pressure=950)).ret == TIER_KEEP
        # hot page under HARD pressure -> demotion admitted anyway
        assert vm.run(self._ctx(heat=900, pressure=1000)).ret == TIER_DEMOTE
        # no pressure -> keep
        assert vm.run(self._ctx(heat=0, pressure=100)).ret == TIER_KEEP
        # host tier full -> keep
        assert vm.run(self._ctx(heat=0, tier_free=0)).ret == TIER_KEEP

    def test_damon_promotion_cost_benefit(self):
        vm = PolicyVM(tier_damon_program(), MapRegistry())
        # hot host page with HBM headroom -> promote (KEEP = live in HBM)
        hot = self._ctx(tier=1, heat=5000, pressure=100)
        assert vm.run(hot).ret == TIER_KEEP
        # untouched host page -> stays demoted
        cold = self._ctx(tier=1, heat=0, pressure=100)
        assert vm.run(cold).ret == TIER_DEMOTE
        # hot host page but no HBM headroom -> no churn
        full = self._ctx(tier=1, heat=5000, pressure=1000)
        assert vm.run(full).ret == TIER_DEMOTE

    def test_tier_programs_jit_matches_interpreter(self):
        """The batched tier-decision path must agree with the host VM."""
        maps = MapRegistry()
        cases = [self._ctx(), self._ctx(heat=900, pressure=950),
                 self._ctx(tier=1, heat=5000, pressure=100),
                 self._ctx(tier=1, heat=0), self._ctx(tier=1, order=2,
                                                      heat=300, pressure=100)]
        mat = np.stack(cases)
        for prog in (tier_damon_program(), tier_lru_program(),
                     tier_never_program()):
            host = [PolicyVM(prog, maps).run(c).ret for c in cases]
            dev = JitPolicy(prog, maps).run_batch(mat)
            assert host == list(dev), prog.name


class TestReclaimPaths:
    def test_demote_cold_respects_never_tier_veto(self):
        mm = mk_tmm(hbm=16, host=16)
        mm.attach_tier_program(tier_never_program())
        mm.create_process(1, vma_blocks=16)
        mm.ensure_range(1, 0, 16)
        assert mm.demote_cold_global(8) == 0
        assert mm.stats.demotions == 0

    def test_demote_cold_global_spans_processes(self):
        mm = mk_tmm(hbm=32, host=64)
        for pid in (1, 2):
            mm.create_process(pid, vma_blocks=16)
            mm.ensure_range(pid, 0, 16)
        # attach AFTER the prefill so prefill-time placement stays out of the
        # picture and the scan alone relieves the pressure
        mm.attach_tier_program(tier_damon_program())
        freed = mm.demote_cold_global(24, prefer_pid=1)
        assert freed >= 24
        # the preferred victim's pages go first
        assert sum(1 for m in mm.procs[1].page_table.values()
                   if m.tier == TIER_HOST) == 16

    def test_stats_invariants_match_occupancy(self):
        mm = mk_tmm(hbm=32, host=64)
        mm.create_process(1, vma_blocks=32)
        mm.ensure_range(1, 0, 32)
        for lg in list(mm.procs[1].page_table)[:12]:
            mm.demote_page(1, lg)
        mm.tick()
        # heat the demoted span so the default policy promotes some back
        mm.record_access(1, np.ones(32) * 3)
        mm.promotion_scan(4)
        st = mm.stats
        assert st.demotions == 12 and st.tier_promotions > 0
        # occupancy invariant: blocks demoted minus blocks promoted back ==
        # blocks currently resident in the host pool (no frees yet)
        assert (st.demotion_blocks - st.tier_promotion_blocks
                == mm.host_resident_blocks())
        hbm_resident = sum(order_blocks(m.order)
                           for m in mm.procs[1].page_table.values()
                           if m.tier == TIER_HBM)
        assert hbm_resident + mm.host_resident_blocks() == 32
        mm.buddy.check_invariants()
        mm.host_buddy.check_invariants()


class TestEngineTiering:
    @pytest.fixture(scope="class")
    def setup(self):
        cfg = get_smoke_config("deepseek_7b")
        params = materialize(RNG, model_spec(cfg))
        layout = PagedLayout(num_blocks=48, block_tokens=4, max_blocks=32)
        return cfg, params, layout

    def _run(self, setup, n_req=6, max_steps=280, **kw):
        # Active sequences must OUTGROW the 48-block HBM pool (admission no
        # longer preempts actives — the waiting-queue watermark — so the
        # pressure has to come from decode growth): 3 admitted seqs at
        # 14 prompt blocks grow toward 24 blocks each, 72 > 48.
        cfg, params, layout = setup
        eng = ServingEngine(cfg, params, layout, max_batch=6, policy="never",
                            **kw)
        rng = np.random.default_rng(0)
        for r in range(n_req):
            eng.submit(Request(rid=r,
                               prompt=rng.integers(1, cfg.vocab, 56).tolist(),
                               max_new_tokens=40, app="chat"))
        steps = 0
        while eng.step():
            steps += 1
            if steps >= max_steps:
                break
        return eng

    def test_demote_before_preempt_eliminates_preemptions(self, setup):
        """The acceptance workload: overcommitted HBM preempts without a host
        tier; with ebpf-tier the same workload runs preemption-free."""
        base = self._run(setup, max_steps=60)
        assert base.stats.preemptions > 0
        tiered = self._run(setup, host_blocks=192, tier_policy="ebpf-tier")
        assert tiered.stats.preemptions == 0
        assert tiered.stats.completed == 6
        # pressure is absorbed by demotion — reactively (an OOM relief pass)
        # or proactively (decode-time FIRST_TOUCH placement demoting cold
        # blocks before the pool ever runs dry) — never by preemption
        assert tiered.mm.stats.demotions > 0

    def test_oom_in_both_tiers_falls_back_to_preemption(self, setup):
        """Tiny host tier: demotion relief runs dry, and the engine must fall
        back to whole-sequence preemption instead of deadlocking."""
        eng = self._run(setup, host_blocks=8, tier_policy="ebpf-tier",
                        max_steps=80)
        assert eng.mm.stats.demotions > 0      # the tier absorbed what it could
        assert eng.stats.preemptions > 0       # then preemption kicked in
        assert eng.stats.decode_tokens > 0     # and the engine kept running

    def test_never_tier_behaves_like_preempt_only(self, setup):
        eng = self._run(setup, host_blocks=192, tier_policy="never-tier",
                        max_steps=60)
        assert eng.mm.stats.demotions == 0
        assert eng.stats.preemptions > 0

    def test_two_tier_baselines_rejected_on_deep_chains(self, setup):
        """ebpf-tier / lru-tier demote targets never pass tier 1, so pairing
        them with a deeper chain would strand tiers 2.. and fall back to
        preemption with free deep capacity — the engine refuses the combo."""
        cfg, params, layout = setup
        for policy in ("ebpf-tier", "lru-tier"):
            with pytest.raises(ValueError, match="2-tier baseline"):
                ServingEngine(cfg, params, layout, policy="never",
                              tier_blocks=(16, 96, 80), tier_policy=policy)
        # the same capacities with an N-tier policy are accepted
        eng = ServingEngine(cfg, params, layout, policy="never",
                            tier_blocks=(16, 96, 80), tier_policy="heat-tier")
        assert eng.mm.ntiers == 4
