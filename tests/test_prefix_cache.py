"""Cross-request KV prefix cache with verified HOOK_EVICT eviction.

Four layers pinned here:

* rolling-hash chunking (``chunk_keys``): each key commits to the ENTIRE
  prefix through its block, so equal keys imply equal prefixes and a
  one-token edit anywhere invalidates every downstream key;
* mm-layer sharing primitives: ``map_shared`` borrows live outside the
  buddy accounting of the borrowing process (``free_process`` must NOT
  free cache-owned blocks), and ``cow_break`` repoints exactly one shared
  mapping at a private copy, idempotently;
* PrefixCache admission/insert/release: longest-chain matching, the
  whole-blocks + partial-tail split with its CoW marker, refcount
  pinning, ghost feedback, and the HOOK_EVICT scan demoting down the
  tier chain and dropping only on EVICT_DROP;
* the three shipped eviction programs decide IDENTICALLY on the
  interpreter, JIT and predicated executors, and the engine-level cache
  changes nothing about model outputs while skipping prefill work.
"""

import numpy as np
import jax
import pytest

from repro.configs.base import get_smoke_config
from repro.core import (EVICT_DROP, HWSpec, JitPolicy, MapRegistry,
                        MemoryManager, PolicyVM, PredicatedPolicy,
                        TieredMemoryManager, evict_ghost_program,
                        evict_lfu_program, evict_lru_program,
                        make_cost_model)
from repro.core.context import CTX, ctx_batch
from repro.core.hooks import HOOK_EVICT
from repro.models import PagedLayout, materialize, model_spec
from repro.serving import PrefixCache, Request, ServingEngine, chunk_keys

RNG = jax.random.PRNGKey(0)
BT = 4


def mk_mm(blocks=64, *, tiered=False, host=64):
    cost = make_cost_model(HWSpec(), kv_heads=4, head_dim=64)
    if tiered:
        return TieredMemoryManager(blocks, cost, host_blocks=host,
                                   default_mode="thp")
    return MemoryManager(blocks, cost, default_mode="thp")


def seeded_cache(mm, prompt, *, cap_blocks=32, pid=1):
    """A cache populated from one prefilled donor prompt.  The doorkeeper
    is off so a single insert admits (its behavior has its own tests)."""
    cache = PrefixCache(mm, BT, cap_blocks=cap_blocks, doorkeeper=False)
    mm.create_process(pid, app="app", vma_blocks=16)
    n = len(prompt) // BT
    mm.fault_range(pid, 0, n)
    assert cache.insert(pid, prompt) == n
    mm.drain_moves()
    return cache


# ------------------------------------------------------------ rolling hash
class TestChunkKeys:
    def test_chain_commits_to_entire_prefix(self):
        a = list(range(100, 116))
        b = list(a)
        b[1] += 1                      # edit inside block 0
        ka, kb = chunk_keys(a, BT), chunk_keys(b, BT)
        assert len(ka) == len(kb) == 4
        assert all(x != y for x, y in zip(ka, kb)), \
            "an early edit must invalidate every downstream key"

    def test_shared_prefix_shares_keys(self):
        a = list(range(100, 116))
        b = a[:8] + [7, 7, 7, 7, 8, 8, 8, 8]
        ka, kb = chunk_keys(a, BT), chunk_keys(b, BT)
        assert ka[:2] == kb[:2]
        assert ka[2] != kb[2]

    def test_partial_block_never_keyed(self):
        assert len(chunk_keys(list(range(11)), BT)) == 2
        assert len(chunk_keys(list(range(3)), BT)) == 0

    def test_position_matters(self):
        # same token multiset, different order -> different keys
        assert chunk_keys([1, 2, 3, 4], BT) != chunk_keys([4, 3, 2, 1], BT)


# ------------------------------------------------- mm sharing primitives
class TestSharedMappingPrimitives:
    def test_free_process_skips_shared_blocks(self):
        mm = mk_mm()
        cache_phys = mm.cache_alloc_block()
        mm.create_process(1, app="app", vma_blocks=8)
        mm.map_shared(1, 0, [(0, cache_phys)])
        mm.fault_range(1, 1, 3)
        mm.free_process(1)
        # the cache still owns its block: freeing it must not double-free
        mm.cache_free_block(0, cache_phys)

    def test_tiered_free_process_skips_shared_blocks(self):
        mm = mk_mm(tiered=True)
        cache_phys = mm.cache_alloc_block()
        mm.create_process(1, app="app", vma_blocks=8)
        mm.map_shared(1, 0, [(0, cache_phys)])
        mm.fault_range(1, 1, 3)
        mm.free_process(1)
        mm.cache_free_block(0, cache_phys)

    def test_cow_break_repoints_and_copies(self):
        mm = mk_mm()
        cache_phys = mm.cache_alloc_block()
        mm.create_process(1, app="app", vma_blocks=8)
        mm.map_shared(1, 0, [(0, cache_phys)])
        moves = mm.cow_break(1, 0)
        assert len(moves) == 1
        src, dst, _ = moves[0]
        assert src == mm.cache_device_index(0, cache_phys)
        m = mm.procs[1].page_table[0]
        assert not m.shared and m.phys_start != cache_phys
        assert mm.cow_break(1, 0) == [], "second break must be a no-op"


# ------------------------------------------------------ cache admission
class TestPrefixCacheAdmission:
    PROMPT = list(range(200, 216))           # 16 tokens = 4 whole blocks

    def test_identical_prompt_partial_tail_and_cow(self):
        mm = mk_mm()
        cache = seeded_cache(mm, self.PROMPT)
        mm.create_process(2, app="app", vma_blocks=16)
        m = cache.acquire(2, self.PROMPT)
        # cap at L-1: 3 whole blocks + 3 tokens into the 4th (CoW target)
        assert m is not None and m.tokens == 15
        assert len(m.entries) == 4 and m.cow_logical == 3
        assert all(e.refcount == 1 for e in m.entries)
        cache.release(m)
        cache.release(m)                     # idempotent
        assert all(e.refcount == 0 for e in m.entries)

    def test_diverging_prompt_whole_blocks_only(self):
        mm = mk_mm()
        cache = seeded_cache(mm, self.PROMPT)
        mm.create_process(2, app="app", vma_blocks=16)
        other = self.PROMPT[:8] + [9, 9, 9, 9, 9, 9, 9, 9]
        m = cache.acquire(2, other)
        assert m is not None and m.tokens == 8
        assert len(m.entries) == 2 and m.cow_logical is None
        cache.release(m)

    def test_complete_miss_pins_nothing(self):
        mm = mk_mm()
        cache = seeded_cache(mm, self.PROMPT)
        assert cache.acquire(2, [1, 2, 3, 4, 5, 6, 7, 8]) is None
        assert all(e.refcount == 0 for e in cache.entries.values())

    def test_insert_is_deduplicating(self):
        mm = mk_mm()
        cache = seeded_cache(mm, self.PROMPT)
        mm.create_process(2, app="app", vma_blocks=16)
        mm.fault_range(2, 0, 4)
        assert cache.insert(2, self.PROMPT) == 0
        assert len(cache.entries) == 4

    def test_drop_feeds_ghost_and_ghost_hits_count(self):
        mm = mk_mm()
        cache = seeded_cache(mm, self.PROMPT, cap_blocks=2)
        # untiered: over budget, default policy has nowhere to demote ->
        # drops (chained descendants go with the root)
        assert cache.used_blocks(0) <= 2
        assert cache.evict_drops >= 2 and len(cache.ghost) >= 2
        before = cache.ghost_hits
        mm.create_process(2, app="app", vma_blocks=16)
        cache.acquire(2, self.PROMPT)
        assert cache.ghost_hits >= before    # re-asking for dropped prefix

    def test_pinned_entries_survive_scan(self):
        mm = mk_mm()
        cache = seeded_cache(mm, self.PROMPT, cap_blocks=32)
        mm.create_process(2, app="app", vma_blocks=16)
        m = cache.acquire(2, self.PROMPT)
        cache.cap_blocks = 0                 # maximum pressure
        cache.scan(need_blocks=8)
        assert len(cache.entries) == 4, "pinned chain must not be evicted"
        cache.release(m)

    def test_tiered_scan_demotes_then_drops(self):
        mm = mk_mm(tiered=True)
        cache = seeded_cache(mm, self.PROMPT, cap_blocks=32)
        mm.attach_evict_program(evict_lru_program(min_age_ticks=1))
        cache.cap_blocks = 1                 # now over budget
        mm.ktime_ns += 50_000_000            # age entries past the gate
        freed = cache.scan()
        assert freed > 0
        assert cache.evict_demotions > 0 and cache.evict_drops == 0, \
            "tier chain must absorb cold prefixes before anything drops"
        assert all(e.blk.tier == 1 for e in cache.entries.values())
        # refill HBM with a second donor, age, rescan: the tier-1 entries
        # sit at the chain end, so the program now says DROP for them
        mm.create_process(2, app="app", vma_blocks=16)
        mm.fault_range(2, 0, 4)
        other = [9000 + i for i in range(16)]
        assert cache.insert(2, other) == 4
        mm.ktime_ns += 50_000_000
        cache.scan()
        assert cache.evict_drops > 0
        assert all(e.blk.tier == 0 for e in cache.entries.values()) or \
            cache.evict_demotions > 4


# ------------------------------------------------------------ doorkeeper
class TestDoorkeeper:
    """TinyLFU-style admission: a chunk must be seen twice (or sit in the
    ghost list) before its block is cached."""
    PROMPT = list(range(300, 316))

    def _cache(self, mm, pid=1):
        cache = PrefixCache(mm, BT, cap_blocks=32)       # doorkeeper on
        mm.create_process(pid, app="app", vma_blocks=16)
        mm.fault_range(pid, 0, 4)
        return cache

    def test_first_sight_notes_second_sight_admits(self):
        mm = mk_mm()
        cache = self._cache(mm)
        assert cache.insert(1, self.PROMPT) == 0, \
            "a never-seen chain must be held at the door"
        assert len(cache.entries) == 0 and cache.door_rejects == 4
        assert len(cache.door) == 4
        assert cache.insert(1, self.PROMPT) == 4
        assert len(cache.entries) == 4 and len(cache.door) == 0

    def test_diverging_tail_admits_shared_head_only(self):
        mm = mk_mm()
        cache = self._cache(mm)
        other = self.PROMPT[:8] + [7000 + i for i in range(8)]
        cache.insert(1, self.PROMPT)
        assert cache.insert(1, other) == 2, \
            "only the chunks both prompts share are second-sight"
        assert len(cache.entries) == 2
        assert cache.insert(1, other) == 2   # tail is second-sight now

    def test_ghost_hit_bypasses_door(self):
        mm = mk_mm()
        cache = self._cache(mm)
        cache.insert(1, self.PROMPT)
        cache.insert(1, self.PROMPT)         # admitted
        cache.cap_blocks = 0
        cache.scan(need_blocks=8)            # untiered: everything drops
        assert len(cache.entries) == 0 and len(cache.ghost) == 4
        assert cache.insert(1, self.PROMPT) == 4, \
            "a previously-cached chain re-admits without a second sighting"

    def test_door_capacity_is_bounded(self):
        mm = mk_mm()
        cache = self._cache(mm)
        cache.door_capacity = 8
        rng = np.random.default_rng(11)
        for _ in range(10):
            cache.insert(1, rng.integers(1, 10_000, 16).tolist())
        assert len(cache.door) <= 8


# ------------------------------------------------- evict program parity
def _random_evict_batch(rng, n):
    mat = ctx_batch(n)
    mat[:, CTX.ADDR] = rng.integers(1, 1000, n)
    mat[:, CTX.PAGE_TIER] = rng.integers(0, 3, n)
    mat[:, CTX.PAGE_AGE] = rng.integers(0, 6, n)
    mat[:, CTX.PAGE_HEAT] = rng.integers(0, 5000, n)
    mat[:, CTX.NTIERS] = rng.integers(1, 4, n)
    mat[:, CTX.CACHE_REFCOUNT] = rng.integers(0, 3, n)
    mat[:, CTX.CACHE_HITS] = rng.integers(0, 5, n)
    mat[:, CTX.CACHE_BLOCKS] = 1
    mat[:, CTX.CACHE_GHOST_HITS] = rng.integers(0, 40, n)
    mat[:, CTX.CACHE_ENTRIES] = rng.integers(1, 64, n)
    mat[:, CTX.CACHE_CAP_BLOCKS] = rng.integers(0, 16, n)
    mat[:, CTX.CACHE_USED_BLOCKS] = rng.integers(0, 32, n)
    # clamp tier below ntiers so rows describe reachable states
    mat[:, CTX.PAGE_TIER] = np.minimum(mat[:, CTX.PAGE_TIER],
                                       mat[:, CTX.NTIERS] - 1)
    return mat


class TestEvictExecutorParity:
    """interpreter == JIT == predicated for every eviction program."""

    @pytest.mark.parametrize("name,make", [
        ("evict_lru", evict_lru_program),
        ("evict_lfu", evict_lfu_program),
        ("evict_ghost", evict_ghost_program),
    ])
    def test_all_executors_agree(self, name, make):
        rng = np.random.default_rng(hash(name) % (2 ** 31))
        prog, maps = make(), MapRegistry()
        mat = _random_evict_batch(rng, 32)
        vm = PolicyVM(prog, maps)
        host = [vm.run(row).ret for row in mat]
        jit = JitPolicy(prog, maps).run_batch(mat)
        pred = PredicatedPolicy(prog, maps).run_batch(mat)
        assert host == list(jit), f"{name}: interpreter != JIT"
        assert host == list(pred), f"{name}: interpreter != predicated"
        # decisions must be sane: a target tier within the chain, or DROP
        for row, d in zip(mat, host):
            assert 0 <= d <= EVICT_DROP
            if d < EVICT_DROP:
                assert d <= row[CTX.NTIERS], name

    def test_programs_verify_and_attach(self):
        mm = mk_mm(tiered=True)
        for make in (evict_lru_program, evict_lfu_program,
                     evict_ghost_program):
            mm.attach_evict_program(make())   # verifier runs inside attach
            assert mm.hooks.attached(HOOK_EVICT)


# ---------------------------------------------------------- engine level
class TestEnginePrefixCache:
    @pytest.fixture(scope="class")
    def setup(self):
        cfg = get_smoke_config("deepseek_7b")
        params = materialize(RNG, model_spec(cfg))
        layout = PagedLayout(num_blocks=256, block_tokens=4, max_blocks=32)
        return cfg, params, layout

    def _run(self, setup, *, prefix_cache, n_req=4):
        cfg, params, layout = setup
        eng = ServingEngine(cfg, params, layout, max_batch=2, policy="never",
                            prefix_cache=prefix_cache)
        shared = list(range(1, 17))          # 16-token system prompt
        outs = []
        for r in range(n_req):
            eng.submit(Request(rid=r, prompt=shared + [100 + r] * 8,
                               max_new_tokens=8, app="chat"))
            out = eng.run(max_steps=200)     # serial -> insert before reuse
            outs.append(out)
        assert outs[-1]["engine"]["completed"] == n_req  # cumulative counter
        return eng, outs[-1]

    def test_cache_changes_no_tokens_and_skips_prefill(self, setup):
        eng_off, _ = self._run(setup, prefix_cache=False)
        eng_on, out = self._run(setup, prefix_cache=True)
        assert eng_on.finished == eng_off.finished, \
            "prefix sharing must be invisible in the sampled tokens"
        snap = out["prefix_cache"]
        # doorkeeper: req 0 NOTES the chain, req 1 admits it (second
        # sight), reqs 2 and 3 hit it
        assert snap["hits"] == 2
        assert snap["door_rejects"] >= 4
        assert snap["tokens_skipped"] >= 2 * 15
        assert out["engine"]["prefill_tokens"] < 4 * 24
        assert snap["inserted_blocks"] >= 4

    def test_mixed_traffic_and_eviction_complete(self, setup):
        cfg, params, layout = setup
        eng = ServingEngine(cfg, params, layout, max_batch=2, policy="never",
                            prefix_cache=4,          # tiny cap -> evictions
                            evict_policy="lfu-evict")
        eng.prefix_cache.doorkeeper = False  # admit everything: this test
        rng = np.random.default_rng(3)       # is about pressure, not entry
        shared = list(range(1, 17))
        for r in range(5):
            prompt = (shared + [200 + r] * 8) if r % 2 == 0 else \
                rng.integers(1, cfg.vocab, 20).tolist()
            eng.submit(Request(rid=r, prompt=prompt, max_new_tokens=6))
        out = eng.run(max_steps=400)
        assert out["engine"]["completed"] == 5
        snap = out["prefix_cache"]
        assert snap["scans"] > 0
        # scans are rate-limited to the scan period, so the drained stream
        # can end with recent insertions still pending reclaim; one aged
        # pass must bring the pool back to budget (+1: the LFU program
        # protects hot chain heads, cold tails must all go)
        eng.mm.ktime_ns += 50_000_000
        eng.prefix_cache.scan()
        assert eng.prefix_cache.used_blocks(0) <= 4 + 1

    def test_non_attention_models_reject_cache(self):
        cfg = get_smoke_config("mamba2_1p3b")
        params = materialize(RNG, model_spec(cfg))
        layout = PagedLayout(num_blocks=64, block_tokens=4, max_blocks=16)
        with pytest.raises(ValueError, match="prefix_cache"):
            ServingEngine(cfg, params, layout, policy="never",
                          prefix_cache=True)
