"""Per-arch smoke tests (reduced configs): one train + serve step on CPU,
shape/NaN asserts; decode-vs-forward consistency; layer math references."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ARCH_IDS, get_config, get_smoke_config, SHAPES, \
    supports_shape
from repro.models import (PagedLayout, cache_init, decode_step, lm_loss,
                          materialize, model_forward, model_spec, prefill_step)
from repro.models.common import pad_vocab

RNG = jax.random.PRNGKey(0)
B, S = 2, 32
LAYOUT = PagedLayout(num_blocks=64, block_tokens=4, max_blocks=16)


def make_batch(cfg, b=B, s=S):
    batch = {"tokens": jax.random.randint(RNG, (b, s + 1), 0, cfg.vocab)}
    if cfg.enc_dec:
        batch["frames"] = jax.random.normal(RNG, (b, cfg.enc_frames, cfg.d_model))
    if cfg.vlm_patches:
        batch["patches"] = jax.random.normal(RNG, (b, cfg.vlm_patches, cfg.d_model))
        batch["pos3d"] = jnp.tile(
            jnp.arange(s, dtype=jnp.float32)[None, None], (3, b, 1))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
class TestArchSmoke:
    def test_train_step(self, arch):
        cfg = get_smoke_config(arch)
        params = materialize(RNG, model_spec(cfg))
        batch = make_batch(cfg)
        loss, parts = jax.jit(
            lambda p, b: lm_loss(p, cfg, b, chunk=16))(params, batch)
        assert jnp.isfinite(loss)
        grads = jax.grad(
            lambda p: lm_loss(p, cfg, make_batch(cfg), chunk=16)[0])(params)
        gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
        assert np.isfinite(gn) and gn > 0

    def test_forward_shapes(self, arch):
        cfg = get_smoke_config(arch)
        params = materialize(RNG, model_spec(cfg))
        batch = make_batch(cfg)
        logits, aux = model_forward(
            params, cfg, batch["tokens"][:, :-1],
            frames=batch.get("frames"), patches=batch.get("patches"),
            pos3d=batch.get("pos3d"), chunk=16)
        assert logits.shape == (B, S, pad_vocab(cfg.vocab))
        assert jnp.isfinite(logits).all()

    def test_serve_roundtrip(self, arch):
        cfg = get_smoke_config(arch)
        params = materialize(RNG, model_spec(cfg))
        cache = cache_init(cfg, LAYOUT, B)
        tokens = jax.random.randint(RNG, (B, S), 0, cfg.vocab)
        tbl = np.full((B, LAYOUT.max_blocks), -1, np.int32)
        for b in range(B):
            tbl[b, :S // 4 + 2] = np.arange(S // 4 + 2) + b * 12
        tbl = jnp.asarray(tbl)
        kw = {}
        if cfg.enc_dec:
            kw["frames"] = jax.random.normal(RNG, (B, cfg.enc_frames, cfg.d_model))
        if cfg.vlm_patches:
            kw["patches"] = jax.random.normal(RNG, (B, cfg.vlm_patches, cfg.d_model))
            kw["pos3d"] = jnp.tile(
                jnp.arange(S, dtype=jnp.float32)[None, None], (3, B, 1))
        logits, cache = prefill_step(params, cfg, cache, tokens, tbl, LAYOUT,
                                     chunk=16, **kw)
        assert jnp.isfinite(logits).all()
        lengths = jnp.full((B,), S, jnp.int32)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        pos3d = (jnp.tile(lengths.astype(jnp.float32)[None, :, None],
                          (3, 1, 1)) if cfg.vlm_patches else None)
        logits2, cache, heat = decode_step(params, cfg, cache, tok, lengths,
                                           tbl, LAYOUT, pos3d=pos3d)
        assert jnp.isfinite(logits2).all()
        assert heat.shape == (B, LAYOUT.max_blocks)
        assert float(heat.sum()) >= 0


class TestDecodeForwardConsistency:
    """Greedy decode through the paged path must match teacher-forced
    forward logits (same positions, f32 numerics tolerance)."""

    @pytest.mark.parametrize("arch", ["deepseek_7b", "gemma3_27b",
                                      "mamba2_1p3b", "deepseek_v2_lite_16b"])
    def test_prefill_then_decode_matches_forward(self, arch):
        cfg = get_smoke_config(arch)
        params = materialize(RNG, model_spec(cfg))
        s0 = 16
        tokens = jax.random.randint(jax.random.PRNGKey(3), (1, s0 + 1),
                                    0, cfg.vocab)
        # teacher-forced forward logits at position s0-1 given tokens[:s0]
        full_logits, _ = model_forward(params, cfg, tokens[:, :s0], chunk=8,
                                       compute_dtype=jnp.float32, remat=False)
        cache = cache_init(cfg, LAYOUT, 1, dtype=jnp.float32)
        tbl = jnp.asarray(np.arange(LAYOUT.max_blocks, dtype=np.int32)[None])
        pre_logits, cache = prefill_step(params, cfg, cache, tokens[:, :s0],
                                         tbl, LAYOUT, chunk=8,
                                         compute_dtype=jnp.float32)
        np.testing.assert_allclose(np.asarray(pre_logits),
                                   np.asarray(full_logits[:, -1]),
                                   rtol=2e-3, atol=2e-3)
        # decode one token and compare with forward over s0+1 tokens
        full2, _ = model_forward(params, cfg, tokens[:, :s0 + 1], chunk=8,
                                 compute_dtype=jnp.float32, remat=False)
        dec_logits, cache, _ = decode_step(
            params, cfg, cache, tokens[:, s0], jnp.asarray([s0], jnp.int32),
            tbl, LAYOUT, compute_dtype=jnp.float32)
        if cfg.moe is not None:
            # MoE routing is DISCONTINUOUS: the decode path computes attention
            # with gather (vs chunked flash in forward), and ~1e-6 numeric
            # differences can flip a near-tied top-k expert, shifting logits
            # by O(0.1). The serving-relevant invariant is greedy-token
            # agreement; dense archs below get the tight logits check.
            assert int(jnp.argmax(dec_logits)) == int(jnp.argmax(full2[:, -1]))
        else:
            np.testing.assert_allclose(np.asarray(dec_logits),
                                       np.asarray(full2[:, -1]),
                                       rtol=2e-3, atol=2e-3)


class TestConfigsMatchAssignment:
    """Pin the exact published numbers from the assignment table."""

    def test_values(self):
        want = {
            "nemotron_4_15b": (32, 6144, 48, 8, 24576, 256000),
            "deepseek_7b": (30, 4096, 32, 32, 11008, 102400),
            "phi3_mini_3p8b": (32, 3072, 32, 32, 8192, 32064),
            "gemma3_27b": (62, 5376, 32, 16, 21504, 262144),
            "qwen2_vl_7b": (28, 3584, 28, 4, 18944, 152064),
            "jamba_v0_1_52b": (32, 4096, 32, 8, 14336, 65536),
            "whisper_medium": (24, 1024, 16, 16, 4096, 51865),
            "mamba2_1p3b": (48, 2048, 32, 32, 0, 50280),
        }
        for arch, (L, d, H, kv, ff, V) in want.items():
            cfg = get_config(arch)
            assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.kv_heads,
                    cfg.d_ff, cfg.vocab) == (L, d, H, kv, ff, V), arch

    def test_moe_configs(self):
        moe16 = get_config("deepseek_moe_16b")
        assert (moe16.moe.num_experts, moe16.moe.top_k,
                moe16.moe.d_ff_expert, moe16.moe.num_shared) == (64, 6, 1408, 2)
        lite = get_config("deepseek_v2_lite_16b")
        assert lite.mla.kv_lora == 512
        assert (lite.moe.num_experts, lite.moe.top_k) == (64, 6)
        jamba = get_config("jamba_v0_1_52b")
        assert (jamba.moe.num_experts, jamba.moe.top_k) == (16, 2)
        assert jamba.hybrid_pattern.count("a") == 1
        assert len(jamba.hybrid_pattern) == 8
        m2 = get_config("mamba2_1p3b")
        assert m2.mamba.d_state == 128

    def test_shape_skip_rules(self):
        long = SHAPES["long_500k"]
        ok, _ = supports_shape(get_config("mamba2_1p3b"), long)
        assert ok
        ok, _ = supports_shape(get_config("jamba_v0_1_52b"), long)
        assert ok
        ok, _ = supports_shape(get_config("gemma3_27b"), long)
        assert ok
        for arch in ("nemotron_4_15b", "deepseek_7b", "phi3_mini_3p8b",
                     "deepseek_v2_lite_16b", "qwen2_vl_7b", "whisper_medium"):
            ok, reason = supports_shape(get_config(arch), long)
            assert not ok and reason, arch

    def test_gemma_pattern(self):
        cfg = get_config("gemma3_27b")
        kinds = cfg.attn_kinds()
        assert kinds[:6] == ("l", "l", "l", "l", "l", "g")
        assert sum(1 for k in kinds if k == "g") == 10  # 62 layers, 5:1
