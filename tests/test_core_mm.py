"""Buddy allocator, DAMON, MemoryManager, khugepaged — invariants + behavior.

Property tests use a seeded numpy RNG (the container has no hypothesis)."""

import numpy as np
import pytest

from repro.core import (Damon, HWSpec, Khugepaged, MemoryManager,
                        MMOutOfMemory, Profile, ProfileRegion,
                        ebpf_mm_program, make_cost_model, never_program,
                        thp_always_program)
from repro.core.buddy import BuddyAllocator, BuddyError, order_blocks


def mk_mm(num_blocks=1024, default="thp"):
    cost = make_cost_model(HWSpec(), kv_heads=8, head_dim=128)
    return MemoryManager(num_blocks, cost, default_mode=default)


class TestBuddy:
    @pytest.mark.parametrize("example", range(30))
    def test_random_ops_keep_invariants(self, example):
        rng = np.random.default_rng(1000 + example)
        n_ops = int(rng.integers(1, 121))
        b = BuddyAllocator(256)
        live = []
        for _ in range(n_ops):
            kind = "alloc" if rng.random() < 0.5 else "free"
            order = int(rng.integers(0, 4))
            if kind == "alloc":
                try:
                    s = b.alloc(order)
                    assert s % order_blocks(order) == 0
                    live.append(s)
                except BuddyError:
                    pass
            elif live:
                b.free(live.pop())
            b.check_invariants()

    def test_full_alloc_free_roundtrip(self):
        b = BuddyAllocator(256)
        starts = [b.alloc(0) for _ in range(256)]
        assert sorted(starts) == list(range(256))
        with pytest.raises(BuddyError):
            b.alloc(0)
        for s in starts:
            b.free(s)
        b.check_invariants()
        # everything coalesced back to max-order pages
        assert b.stats().free_per_order[3] == 256 // 64

    def test_double_free_rejected(self):
        b = BuddyAllocator(64)
        s = b.alloc(1)
        b.free(s)
        with pytest.raises(BuddyError):
            b.free(s)

    def test_compaction_creates_high_order_page(self):
        b = BuddyAllocator(64)
        blocks = [b.alloc(0) for _ in range(64)]
        # free all but one block per 16-block window -> no order-2 page free
        for s in blocks:
            if s % 16 != 0:
                b.free(s)
        assert b.stats().free_per_order[2] == 0
        plan = b.plan_compaction(2)
        assert plan, "compaction should find a plan"
        b.check_invariants()
        s = b.alloc(2)                      # must now succeed
        assert s % 16 == 0

    def test_frag_index_monotone(self):
        b = BuddyAllocator(256)
        st0 = b.stats()
        assert st0.frag_index_milli[3] < 100
        blocks = [b.alloc(0) for _ in range(128)]
        for s in blocks[::2]:
            b.free(s)
        st1 = b.stats()
        assert st1.frag_index_milli[3] > st0.frag_index_milli[3]


class TestDamon:
    def test_region_budget_respected(self):
        d = Damon(4096, min_nr_regions=10, max_nr_regions=60)
        rng = np.random.default_rng(0)
        for _ in range(20):
            d.record(rng.random(4096))
            assert 1 <= len(d.regions) <= 60
        # full coverage, no overlap
        regs = sorted(d.regions, key=lambda r: r.start)
        assert regs[0].start == 0 and regs[-1].end == 4096
        for a, b in zip(regs, regs[1:]):
            assert a.end == b.start

    def test_hot_region_detected(self):
        d = Damon(1024, seed=1)
        heat = np.zeros(1024)
        heat[100:160] = 50.0
        for _ in range(12):
            d.record(heat)
        assert d.heat_at(128, 2) > 5 * max(d.heat_at(700, 2), 0.01)

    def test_grow(self):
        d = Damon(64)
        d.grow(128)
        assert d.space_blocks == 128
        d.record(np.ones(128))
        assert sorted(r.end for r in d.regions)[-1] == 128


class TestMemoryManager:
    def test_default_never_vs_thp(self):
        for mode, want_order in (("never", 0), ("thp", 2)):
            mm = mk_mm(default=mode)
            mm.create_process(1, vma_blocks=256)
            r = mm.ensure_mapped(1, 0)
            assert r.order == want_order, mode

    def test_profile_guided_sizes(self):
        mm = mk_mm()
        prof = Profile("app", [
            ProfileRegion(0, 64, (0, 10_000, 200_000, 4_000_000)),
            ProfileRegion(64, 256, (0, 0, 0, 0)),
        ])
        mid = mm.load_profile(prof)
        mm.attach_fault_program(ebpf_mm_program(profile_map_id=mid))
        mm.create_process(1, app="app", vma_blocks=256)
        hot = mm.ensure_mapped(1, 0)
        cold = mm.ensure_mapped(1, 200)
        assert hot.order == 3 and hot.hinted
        assert cold.order == 0 and cold.hinted

    def test_unprofiled_pid_falls_back(self):
        mm = mk_mm(default="never")
        prof = Profile("app", [ProfileRegion(0, 8, (0, 1, 1, 1))])
        mm.attach_fault_program(
            ebpf_mm_program(profile_map_id=mm.load_profile(prof)))
        mm.create_process(2, app=None, vma_blocks=64)   # no profile
        r = mm.ensure_mapped(2, 0)
        assert not r.hinted and r.order == 0
        assert mm.stats.fallback_faults == 1

    def test_block_table_consistency(self):
        mm = mk_mm()
        mm.create_process(1, vma_blocks=128)
        mm.ensure_range(1, 0, 128)
        t = mm.block_table(1, 128)
        assert (t >= 0).all()
        assert len(np.unique(t)) == 128      # no two logicals share a block

    def test_fault_respects_vma_and_overlap(self):
        mm = mk_mm(default="thp")
        mm.create_process(1, vma_blocks=20)  # order 2 (16) fits only at 0
        r0 = mm.ensure_mapped(1, 17)         # window [16,32) exceeds vma
        assert r0.order < 2
        with pytest.raises(Exception):
            mm.ensure_mapped(1, 100)

    def test_oom_reports_victim_and_eviction_frees(self):
        mm = mk_mm(num_blocks=64, default="never")
        mm.create_process(1, vma_blocks=64)
        mm.ensure_range(1, 0, 64)
        mm.create_process(2, vma_blocks=16)
        with pytest.raises(MMOutOfMemory) as ei:
            mm.ensure_mapped(2, 0)
        assert ei.value.victim_pid == 1
        mm.evict_process(1)
        assert mm.ensure_mapped(2, 0) is not None

    def test_collapse_migrates_and_frees(self):
        mm = mk_mm(default="never")
        mm.create_process(1, vma_blocks=64)
        mm.ensure_range(1, 0, 16)
        assert mm.descriptors_for(1) == 16
        res = mm.collapse(1, 0, 2)
        assert res is not None and res.order == 2
        assert mm.descriptors_for(1) == 1
        assert mm.stats.promotions == 1
        assert len(mm.drain_moves()) >= 16
        mm.buddy.check_invariants()

    def test_compaction_updates_page_tables(self):
        mm = mk_mm(num_blocks=64, default="never")
        mm.create_process(1, vma_blocks=64)
        mm.ensure_range(1, 0, 48)
        # free every other mapping to fragment
        st = mm.procs[1]
        for lstart in list(st.page_table)[::2]:
            mm.unmap(1, lstart)
        before = {m.phys_start for m in st.page_table.values()}
        r = mm._install(st, 60, 2, hinted=False)   # needs compaction
        assert r.order == 2
        mm.buddy.check_invariants()
        t = mm.block_table(1, 64)
        mapped = t[t >= 0]
        assert len(np.unique(mapped)) == len(mapped)


class TestKhugepaged:
    def test_hot_region_collapsed(self):
        mm = mk_mm(default="never")
        mm.create_process(1, vma_blocks=256)
        mm.ensure_range(1, 0, 64)
        heat = np.zeros(256)
        heat[:64] = 80.0
        for _ in range(6):
            mm.record_access(1, heat)
        kh = Khugepaged(mm)
        total = sum(kh.tick() for _ in range(8))
        assert total >= 1
        assert mm.stats.promotions == total
        mm.buddy.check_invariants()

    def test_cold_region_left_alone(self):
        mm = mk_mm(default="never")
        mm.create_process(1, vma_blocks=256)
        mm.ensure_range(1, 0, 64)
        for _ in range(6):
            mm.record_access(1, np.zeros(256))
        kh = Khugepaged(mm)
        assert sum(kh.tick() for _ in range(4)) == 0
