"""Differential / property harness for the fault + tiering fast paths.

A seeded random workload script (admissions, decode steps, completions,
pressure spikes, async collapses) is generated ONCE per seed — fully
state-independent, so the identical op sequence replays against every
manager variant — and driven through:

  * scalar-vs-batched fault paths (``ensure_mapped``/``ensure_range`` vs
    ``fault_batch``/``fault_range``), asserting the two replicas stay
    STEP-FOR-STEP identical (page tables, mapped sets, tier occupancy,
    stats);
  * untiered vs 2-tier vs 4-tier managers, asserting end-state invariants
    after every step:
      - no double-mapped device block, and each tier's buddy ``allocated``
        map exactly covers that tier's mapped pages;
      - the incremental block table and the mapping-metadata arrays agree
        with a from-scratch rebuild of the page table;
      - KV bytes survive every migration / compaction / collapse: a modeled
        device pool applies the drained move lists and every value written
        through a block table read back unchanged forever after;
      - with a fault program attached, the batched replica issues at most
        ONE ``HOOK_FAULT`` batch invocation per workload step (plus one per
        OOM-relief retry), and never a scalar invocation.

Failures print the generating seed (it is also part of the test id);
re-run one case with e.g.
``pytest "tests/test_differential.py::test_scalar_vs_batched[2tier-1]"``.
Extra seeds: ``DIFF_SEEDS=7,8,9 make test-diff``.
"""

from __future__ import annotations

import os
from dataclasses import dataclass, field

import numpy as np
import pytest

from repro.core import (HWSpec, MemoryManager, MMOutOfMemory, Profile,
                        ProfileRegion, TieredMemoryManager,
                        default_tier_chain, ebpf_mm_program, make_cost_model,
                        tier_damon_program, tier_heat_band_program)
from repro.core.buddy import order_blocks
from repro.core.context import FaultKind
from repro.core.hooks import HOOK_FAULT
from repro.serving.tables import DeviceBlockTables

SEEDS = [0, 1, 2]
if os.environ.get("DIFF_SEEDS"):
    SEEDS += [int(s) for s in os.environ["DIFF_SEEDS"].split(",") if s]
TOPOLOGIES = ["untiered", "2tier", "4tier"]

HBM_BLOCKS = {"untiered": 96, "2tier": 64, "4tier": 64}
VMA_MAX = 24


def _profile() -> Profile:
    return Profile("app", [
        ProfileRegion(0, 8, (0, 150_000, 600_000, 0)),
        ProfileRegion(8, VMA_MAX, (0, 0, 0, 0)),
    ])


def mk_manager(topology: str, *, injector=None,
               containment: bool = True) -> MemoryManager:
    hw = HWSpec()
    cost = make_cost_model(hw, kv_heads=4, head_dim=64)
    kw = dict(default_mode="thp", injector=injector, containment=containment)
    if topology == "untiered":
        mm = MemoryManager(HBM_BLOCKS[topology], cost, **kw)
    elif topology == "2tier":
        mm = TieredMemoryManager(HBM_BLOCKS[topology], cost, host_blocks=128,
                                 **kw)
        mm.attach_tier_program(tier_damon_program())
    elif topology == "4tier":
        mm = TieredMemoryManager(
            HBM_BLOCKS[topology], cost,
            tiers=default_tier_chain(hw, (32, 64, 32)), **kw)
        mm.attach_tier_program(tier_heat_band_program())
    else:  # pragma: no cover
        raise ValueError(topology)
    mm.load_profile(_profile())
    mm.attach_fault_program(ebpf_mm_program(max_regions=8))
    return mm


# ---------------------------------------------------------------- workload
@dataclass
class Step:
    admits: list = field(default_factory=list)     # [(pid, vma, prompt)]
    completes: list = field(default_factory=list)  # [pid]
    decodes: list = field(default_factory=list)    # [pid] faulting this step
    heats: dict = field(default_factory=dict)      # pid -> per-block heat
    collapses: list = field(default_factory=list)  # [(pid, addr, order)]
    spike: int = 0                                 # blocks of pressure relief


def make_script(seed: int, nsteps: int = 36) -> list[Step]:
    """A state-independent op script: the same admissions/decodes/completions
    replay against every manager variant, whatever its internal state."""
    rng = np.random.default_rng(seed)
    steps: list[Step] = []
    live: dict[int, tuple[int, int]] = {}   # pid -> (vma, pos)
    next_pid = 1
    for _ in range(nsteps):
        s = Step()
        # completions: each live pid completes with small probability, or
        # when it has filled its VMA
        for pid, (vma, pos) in sorted(live.items()):
            if pos >= vma or (pos > 2 and rng.random() < 0.06):
                s.completes.append(pid)
        for pid in s.completes:
            del live[pid]
        # admissions: keep up to 6 sequences live
        while len(live) < 6 and rng.random() < 0.5:
            vma = int(rng.integers(8, VMA_MAX + 1))
            prompt = int(rng.integers(4, min(12, vma) + 1))
            s.admits.append((next_pid, vma, prompt))
            live[next_pid] = (vma, prompt)
            next_pid += 1
        # decode: every live pid that still has room crosses one boundary
        for pid, (vma, pos) in sorted(live.items()):
            if pos < vma:
                s.decodes.append(pid)
                live[pid] = (vma, pos + 1)
        # per-pid attention heat over the blocks mapped so far (drives DAMON
        # and therefore every tier decision — identical across replicas)
        for pid, (vma, pos) in sorted(live.items()):
            heat = rng.random(pos) * 3.0
            heat[rng.random(pos) < 0.4] = 0.0
            s.heats[pid] = heat
        # occasional async collapse attempt (khugepaged analogue)
        if live and rng.random() < 0.2:
            pid = int(sorted(live)[int(rng.integers(0, len(live)))])
            vma, pos = live[pid]
            s.collapses.append((pid, int(rng.integers(0, vma)), 1))
        # pressure spike: force a reclaim pass
        if rng.random() < 0.15:
            s.spike = int(rng.integers(4, 17))
        steps.append(s)
    return steps


# ------------------------------------------------------------ replica state
class Replica:
    """One manager + a modeled device pool + the KV content oracle.

    ``injector`` arms the chaos lane: a seeded FailureInjector shared
    schedule (NOT a shared object — build one per replica from the same
    seed/rates so counters stay independent).  Chaos lanes use the
    deterministic ``_kv_value`` oracle, a pure function of (pid, block),
    so two replicas whose PLACEMENT diverges under failures can still be
    compared content-wise block by block."""

    def __init__(self, topology: str, batched: bool, *, injector=None,
                 containment: bool = True, value_fn=None) -> None:
        self.mm = mk_manager(topology, injector=injector,
                             containment=containment)
        self.value_fn = value_fn
        self.batched = batched
        self.tiered = isinstance(self.mm, TieredMemoryManager)
        n = self.mm.device_pool_blocks if self.tiered \
            else self.mm.buddy.num_blocks
        self.pool = np.full(n, -1, dtype=np.int64)
        self.expected: dict[tuple[int, int], int] = {}
        self.vma: dict[int, int] = {}
        self._stamp = 0
        self.relief_events = 0
        # table-management axis: a device-resident mirror (dirty-row
        # protocol, exactly what the serving engine runs) maintained
        # alongside the host-recapture reference and compared after every
        # step — see _check_device_tables
        self._tbl_slots = 10            # > max live pids in make_script
        self.slots: dict[int, int] = {}
        self._free_slots = list(range(self._tbl_slots))
        self.dtables = DeviceBlockTables(self._tbl_slots, VMA_MAX)
        self.table_buf = np.full((self._tbl_slots, VMA_MAX), -1, np.int32)
        self.move_decode_steps = 0      # steps with migration AND decode

    # ---- faults with deterministic OOM relief ----
    def _relieve(self, need: int) -> None:
        self.relief_events += 1
        if self.tiered and self.mm.demote_cold_global(need) > 0:
            return
        # spill exhausted (or untiered): unmap the largest process's tail
        victim = max(self.mm.procs,
                     key=lambda p: (len(self.mm.procs[p].page_table), -p))
        st = self.mm.procs[victim]
        freed = 0
        for lg in sorted(st.page_table, reverse=True):
            if freed >= need:
                break
            freed += order_blocks(st.page_table[lg].order)
            for b in range(lg, lg + order_blocks(st.page_table[lg].order)):
                self.expected.pop((victim, b), None)
            self.mm.unmap(victim, lg)

    def _with_relief(self, fn, need: int) -> None:
        for _ in range(12):
            try:
                fn()
                return
            except MMOutOfMemory:
                self._relieve(need)
        raise AssertionError("workload does not fit any tier combination")

    def admit(self, pid: int, vma: int, prompt: int) -> None:
        self.mm.create_process(pid, app="app", vma_blocks=vma)
        self.vma[pid] = vma
        self.slots[pid] = self._free_slots.pop(0)
        if self.batched:
            self._with_relief(
                lambda: self.mm.fault_range(pid, 0, prompt), prompt)
        else:
            self._with_relief(
                lambda: self.mm.ensure_range(pid, 0, prompt), prompt)

    def decode(self, pids: list[int]) -> None:
        reqs = []
        for pid in pids:
            st = self.mm.procs[pid]
            unmapped = [a for a in range(st.vma_end)
                        if a not in st.mapped]
            if unmapped:
                reqs.append((pid, unmapped[0], FaultKind.FIRST_TOUCH))
        if not reqs:
            return
        if self.batched:
            self._with_relief(lambda: self.mm.fault_batch(reqs), len(reqs))
        else:
            def scalar():
                for pid, addr, kind in reqs:
                    self.mm.ensure_mapped(pid, addr, kind)
                # decode-time tier placement parity: the batched route runs
                # its FIRST_TOUCH placement pass inside fault_batch
                self.mm.place_decode(reqs)
            self._with_relief(scalar, len(reqs))

    def complete(self, pid: int) -> None:
        self.mm.free_process(pid)
        self.vma.pop(pid)
        self._free_slots.append(self.slots.pop(pid))
        self._free_slots.sort()
        self.expected = {k: v for k, v in self.expected.items()
                         if k[0] != pid}

    # ---- device pool + KV oracle ----
    def flush_and_write(self) -> None:
        """Apply this step's drained moves (sequentially — the engine's
        chain-safe batching is equivalent by construction), then write a
        fresh sentinel into every newly mapped block."""
        moves = self.mm.drain_moves()
        self._last_moves = len(moves)
        for s, d, o in moves:
            n = order_blocks(o)
            self.pool[d:d + n] = self.pool[s:s + n]
        for pid in sorted(self.mm.procs):
            table = self.mm.block_table(pid, self.vma[pid])
            for lg in sorted(self.mm.procs[pid].mapped):
                if (pid, lg) not in self.expected:
                    if self.value_fn is not None:
                        val = self.value_fn(pid, lg)
                    else:
                        self._stamp += 1
                        val = self._stamp * 1000 + pid
                    self.pool[table[lg]] = val
                    self.expected[(pid, lg)] = val

    # ---- invariants ----
    def check_invariants(self, ctx: str) -> None:
        mm = self.mm
        pools = mm.pools if self.tiered else [mm.buddy]
        base = [0]
        for p in pools[:-1]:
            base.append(base[-1] + p.num_blocks)
        # 1) no double-mapped device block; buddy allocation maps exactly
        #    cover the mapped pages of their tier
        seen: set[int] = set()
        per_tier: list[set] = [set() for _ in pools]
        for pid, st in mm.procs.items():
            for m in st.page_table.values():
                n = order_blocks(m.order)
                span = set(range(base[m.tier] + m.phys_start,
                                 base[m.tier] + m.phys_start + n))
                assert not (span & seen), \
                    f"{ctx}: double-mapped device block(s) {span & seen}"
                seen |= span
                per_tier[m.tier].update(
                    range(m.phys_start, m.phys_start + n))
        for t, p in enumerate(pools):
            allocd = set()
            for start, order in p.allocated.items():
                allocd.update(range(start, start + order_blocks(order)))
            assert allocd == per_tier[t], \
                f"{ctx}: tier {t} buddy/pagetable occupancy mismatch"
            p.check_invariants()
        # 2) incremental block table + metadata arrays == reference rebuild
        for pid, st in mm.procs.items():
            ref = np.full(self.vma[pid], -1, dtype=np.int32)
            for m in st.page_table.values():
                n = order_blocks(m.order)
                hi = min(m.logical_start + n, self.vma[pid])
                dev = mm._device_index(m)
                for i in range(m.logical_start, hi):
                    ref[i] = dev + (i - m.logical_start)
            np.testing.assert_array_equal(
                mm.block_table(pid, self.vma[pid]), ref,
                err_msg=f"{ctx}: pid {pid} incremental table diverged")
            starts, _sizes, orders, tiers, dev = mm._mapping_arrays(st)
            ms = st.mappings_sorted()
            assert list(starts) == [m.logical_start for m in ms], ctx
            assert list(orders) == [m.order for m in ms], ctx
            assert list(tiers) == [m.tier for m in ms], ctx
            assert list(dev) == [mm._device_index(m) for m in ms], ctx
        # 3) KV bytes survive every migration/compaction/collapse
        for (pid, lg), val in self.expected.items():
            table = self.mm.block_table(pid, self.vma[pid])
            assert self.pool[table[lg]] == val, (
                f"{ctx}: KV bytes lost for pid {pid} block {lg} "
                f"(expected {val}, found {self.pool[table[lg]]})")
        # 4) table-management axis: the device-resident mirror (dirty-row
        #    uploads keyed on table_version, migrations included) must stay
        #    BIT-IDENTICAL to a from-scratch host recapture after every step
        self._check_device_tables(ctx)

    def _check_device_tables(self, ctx: str) -> None:
        slot_pids: list = [None] * self._tbl_slots
        for pid, slot in self.slots.items():
            slot_pids[slot] = pid
        didx, drows, active, tri = self.dtables.sync(self.mm, slot_pids)
        self.table_buf[didx] = drows          # the engine's in-jit scatter
        self.table_buf[tri[:, 0], tri[:, 1]] = tri[:, 2]   # delta triples
        for pid, slot in self.slots.items():
            assert active[slot], f"{ctx}: live pid {pid} not active"
            np.testing.assert_array_equal(
                self.table_buf[slot], self.mm.block_table(pid, VMA_MAX),
                err_msg=f"{ctx}: device-resident row for pid {pid} diverged "
                        f"from host recapture (stale dirty-row protocol)")
        for slot in self._free_slots:
            assert not active[slot], f"{ctx}: vacated slot {slot} active"
            assert (self.table_buf[slot] == -1).all(), \
                f"{ctx}: vacated slot {slot} still holds physical indices"

    def state(self):
        """Cross-replica comparable summary."""
        tables = {pid: sorted((m.logical_start, m.phys_start, m.order, m.tier)
                              for m in st.page_table.values())
                  for pid, st in self.mm.procs.items()}
        mapped = {pid: sorted(st.mapped)
                  for pid, st in self.mm.procs.items()}
        occ = [sorted(p.allocated.items())
               for p in (self.mm.pools if self.tiered else [self.mm.buddy])]
        return tables, mapped, occ


def run_step(r: Replica, s: Step) -> None:
    calls0 = r.mm.hooks.calls[HOOK_FAULT]
    batch0 = r.mm.hooks.batch_calls[HOOK_FAULT]
    relief0 = r.relief_events
    for pid in s.completes:
        if pid in r.vma:
            r.complete(pid)
    for pid, vma, prompt in s.admits:
        r.admit(pid, vma, prompt)
    r.decode([p for p in s.decodes if p in r.vma])
    for pid, heat in s.heats.items():
        if pid in r.vma:
            r.mm.record_access(pid, heat)
    for pid, addr, order in s.collapses:
        if pid in r.vma and addr < r.vma[pid]:
            r.mm.collapse(pid, addr, order)
    if s.spike and r.tiered:
        r.mm.demote_cold_global(s.spike)
    if r.tiered:
        r.mm.promotion_scan()
    r.mm.tick()
    r.flush_and_write()
    if r._last_moves and s.decodes:
        # satellite case: migration and decode landed in the SAME step — the
        # device-resident path must re-upload the moved rows (checked by
        # _check_device_tables right after this step)
        r.move_decode_steps += 1
    if r.batched:
        # every fault invocation this step was a batch one (never the scalar
        # run() entry), and admissions + decode each used at most one batch
        # per attempt (one extra attempt per OOM relief)
        dcalls = r.mm.hooks.calls[HOOK_FAULT] - calls0
        dbatch = r.mm.hooks.batch_calls[HOOK_FAULT] - batch0
        attempts = 1 + len(s.admits) + (r.relief_events - relief0)
        assert dcalls == dbatch, "scalar HOOK_FAULT invocation on batch path"
        assert dbatch <= attempts, \
            f"{dbatch} batch invocations for {attempts} fault entries"


@pytest.mark.differential
@pytest.mark.parametrize("topology", TOPOLOGIES)
@pytest.mark.parametrize("seed", SEEDS)
def test_scalar_vs_batched(topology, seed):
    """The acceptance matrix: for every topology and seed, the batched fault
    path replays the scalar reference path step-for-step, and both replicas
    hold every structural + KV invariant after every step."""
    script = make_script(seed)
    scalar = Replica(topology, batched=False)
    batched = Replica(topology, batched=True)
    for i, s in enumerate(script):
        tag = f"seed={seed} topology={topology} step={i}"
        run_step(scalar, s)
        run_step(batched, s)
        scalar.check_invariants(f"{tag} scalar")
        batched.check_invariants(f"{tag} batched")
        assert scalar.state() == batched.state(), \
            f"{tag}: scalar and batched replicas diverged"
    assert scalar.mm.stats.snapshot() == batched.mm.stats.snapshot(), \
        f"seed={seed} topology={topology}: stats diverged"
    assert scalar.mm.stats.faults > 0


EXECUTORS = ["interp", "jit", "segmented"]


def _force_executor(mm, mode, monkeypatch):
    """Pin which executor the hook registry's batch route uses.

    ``interp`` is expressed by the scalar replica (one ``vm.run`` per fault);
    ``jit`` marks every attached program predicate-unfit so ``run_batch``
    takes the while+switch JIT; ``segmented`` shrinks the per-segment budget
    so even the right-sized Fig-1 search loop splits into chained predicated
    segments — the full pipeline, exercised on a real workload."""
    if mode == "jit":
        for ap in mm.hooks._hooks.values():
            if ap is not None:
                ap.pred_unfit = True
    elif mode == "segmented":
        import repro.core.hooks as hooks_mod
        monkeypatch.setattr(hooks_mod, "PRED_MAX_UNROLL", 64)


@pytest.mark.differential
@pytest.mark.parametrize("topology", ["untiered", "4tier"])
@pytest.mark.parametrize("seed", SEEDS[:2])
def test_executor_axis(topology, seed, monkeypatch):
    """The executor axis of the harness: the same seeded workload replayed
    through interpreter (scalar path), while+switch JIT and SEGMENTED
    predicated batch executors must produce identical decisions — page
    tables, tier occupancy, stats — step for step."""
    script = make_script(seed)
    reps = {}
    for mode in EXECUTORS:
        reps[mode] = Replica(topology, batched=(mode != "interp"))
        _force_executor(reps[mode].mm, mode, monkeypatch)
    for i, s in enumerate(script):
        for mode, r in reps.items():
            run_step(r, s)
            r.check_invariants(f"seed={seed} {topology} {mode} step={i}")
        for mode in EXECUTORS[1:]:
            assert reps[mode].state() == reps["interp"].state(), \
                f"seed={seed} {topology} step={i}: {mode} diverged from " \
                f"the interpreter"
    for mode in EXECUTORS[1:]:
        assert reps[mode].mm.stats.snapshot() == \
            reps["interp"].mm.stats.snapshot(), \
            f"seed={seed} {topology}: {mode} stats diverged"
    # the segmented replica really did run chained segments
    ap = reps["segmented"].mm.hooks._hooks[HOOK_FAULT]
    assert ap.pred is not None and ap.pred.num_segments >= 2, \
        "segmented replica compiled a single segment — budget patch inert"
    jap = reps["jit"].mm.hooks._hooks[HOOK_FAULT]
    assert jap.jit is not None and jap.pred is None, \
        "jit replica did not route through the while+switch JIT"
    assert reps["interp"].mm.stats.faults > 0


@pytest.mark.differential
@pytest.mark.parametrize("seed", SEEDS)
def test_tier_topologies_complete_same_workload(seed):
    """The same script must be satisfiable by every topology (reliefs differ,
    data structures stay sound) — and deeper topologies must never need MORE
    unmap-style relief than the untiered pool."""
    script = make_script(seed)
    reps = {t: Replica(t, batched=True) for t in TOPOLOGIES}
    for i, s in enumerate(script):
        for t, r in reps.items():
            run_step(r, s)
            r.check_invariants(f"seed={seed} topology={t} step={i}")
    for t, r in reps.items():
        assert r.mm.stats.faults > 0, f"{t}: workload never faulted"
    # tiered replicas absorb pressure by demotion, not by dropping KV
    assert reps["2tier"].mm.stats.demotions > 0
    assert reps["4tier"].mm.stats.demotions > 0
    # and at least one step combined migration with decode, so the per-step
    # mirror check covered the move -> dirty-row -> re-upload ordering
    assert any(r.move_decode_steps > 0 for r in reps.values()), \
        "no step combined migration with decode on any topology"


# ------------------------------------------------------------- chaos lane
# Aggressive enough that every armed site actually fires on every seed,
# low enough that the workload still completes against every topology.
CHAOS_RATES = {"migrate_copy": 0.15, "tier_alloc": 0.10,
               "link_flap": 0.10, "hook_run": 0.05}


def _kv_value(pid: int, lg: int) -> int:
    """Pure (pid, block) -> sentinel value: lets replicas whose PLACEMENT
    diverged under failures still be compared content-wise per block."""
    return pid * 1_000_003 + lg * 101 + 7


def _chaos_replica(topology: str, batched: bool, seed: int,
                   containment: bool = True) -> Replica:
    from repro.resilience import FailureInjector
    # one injector PER replica (same seed/rates = same pure schedule);
    # sharing an object would only entangle the check/fire counters
    return Replica(topology, batched=batched,
                   injector=FailureInjector(seed, dict(CHAOS_RATES)),
                   containment=containment, value_fn=_kv_value)


@pytest.mark.chaos
@pytest.mark.differential
@pytest.mark.parametrize("topology", ["2tier", "4tier"])
@pytest.mark.parametrize("seed", SEEDS[:2])
def test_chaos_scalar_vs_batched(topology, seed):
    """The resilience acceptance matrix: under an identical seeded failure
    schedule (copy errors, alloc failures, link flaps, hook runtime errors)
    the scalar and batched fault routes must stay STEP-FOR-STEP identical —
    same retries, same aborts, same strikes/detaches, same end state — and
    every structural + KV invariant must hold after every step: failures
    change placement and cost, never data."""
    script = make_script(seed)
    scalar = _chaos_replica(topology, batched=False, seed=seed)
    batched = _chaos_replica(topology, batched=True, seed=seed)
    clean = Replica(topology, batched=True, value_fn=_kv_value)
    for i, s in enumerate(script):
        tag = f"chaos seed={seed} topology={topology} step={i}"
        run_step(scalar, s)
        run_step(batched, s)
        run_step(clean, s)
        scalar.check_invariants(f"{tag} scalar")
        batched.check_invariants(f"{tag} batched")
        assert scalar.state() == batched.state(), \
            f"{tag}: routes diverged under the same failure schedule"
    assert scalar.mm.stats.snapshot() == batched.mm.stats.snapshot(), \
        f"chaos seed={seed} {topology}: stats diverged"
    # the schedule really did inject (rates are sized so every site fires)
    inj = batched.mm.injector
    assert sum(inj.fired.values()) > 0, "chaos lane never injected anything"
    # the device-resident-table hazard actually occurred under chaos: at
    # least one step migrated KV AND decoded, and the per-step mirror check
    # proved the moved rows were re-uploaded before the (modeled) dispatch
    assert batched.move_decode_steps > 0, \
        "no step combined migration with decode — hazard never exercised"
    assert inj.fired == scalar.mm.injector.fired, \
        "pure-schedule contract broken: routes saw different injections"
    # KV bit-identity vs the failure-free run: every block BOTH lanes hold
    # carries identical bytes (placement may differ; content never does)
    for (pid, lg), val in batched.expected.items():
        if (pid, lg) in clean.expected:
            assert val == clean.expected[(pid, lg)]
    clean.check_invariants(f"chaos seed={seed} {topology} clean")


# ------------------------------------------------------ prefix-cache lane
def _make_requests(seed: int, vocab: int, n_req: int = 6):
    """Seeded shared-prefix traffic: a few 'system prompts' reused across
    requests plus unique tails — the workload shape the prefix cache
    exists for, state-independent so both lanes replay it identically."""
    rng = np.random.default_rng(seed)
    prefixes = [rng.integers(1, vocab, 16).tolist() for _ in range(2)]
    reqs = []
    for r in range(n_req):
        if rng.random() < 0.7:
            prompt = list(prefixes[int(rng.integers(0, 2))]) + \
                rng.integers(1, vocab, int(rng.integers(4, 9))).tolist()
        else:
            prompt = rng.integers(1, vocab, int(rng.integers(8, 21))).tolist()
        reqs.append((r, prompt, int(rng.integers(4, 9))))
    return reqs


def _active_kv(eng):
    """Per-rid valid-region KV, gathered THROUGH each sequence's block
    table — placement-independent, so shared cache blocks and private
    blocks compare purely by content."""
    import jax
    bt = eng.layout.block_tokens
    MB = eng.layout.max_blocks
    out = {}
    pools = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(eng.cache)[0]:
        name = "/".join(str(getattr(k, "key", k)) for k in path)
        if "pool_" in name:        # pool_k / pool_v / pool_ckv, per segment
            pools[name] = np.asarray(leaf)
    assert pools, "no paged KV pools in the cache pytree"
    for slot, seq in eng.active.items():
        tbl = eng.mm.block_table(seq.pid, MB)
        nb = (seq.length + bt - 1) // bt
        assert (tbl[:nb] >= 0).all(), f"rid {seq.req.rid}: unmapped valid block"
        kv = {}
        for k, pool in pools.items():
            # plain segments: [NB, bt, ...]; cycled: [reps, NB, bt, ...]
            if pool.ndim == 5:
                toks = pool[:, tbl[:nb]].reshape(
                    pool.shape[0], nb * bt, *pool.shape[3:])[:, :seq.length]
            else:
                toks = pool[tbl[:nb]].reshape(
                    nb * bt, *pool.shape[2:])[:seq.length]
            kv[k] = toks
        out[seq.req.rid] = (seq.length, list(seq.generated), kv)
    return out


@pytest.mark.differential
@pytest.mark.parametrize("seed", SEEDS)
def test_prefix_cached_vs_uncached(seed):
    """The prefix-cache acceptance lane: the same seeded shared-prefix
    request stream through a cache-on and a cache-off engine, stepped in
    LOCKSTEP.  Sharing may only change where prefix KV lives and how much
    prefill runs — after every step each live sequence's valid KV region
    (gathered through its block table) must be bit-identical across lanes,
    and the finished token streams must match exactly at the end."""
    import jax
    from repro.configs.base import get_smoke_config
    from repro.models import PagedLayout, materialize, model_spec
    from repro.serving import Request, ServingEngine

    cfg = get_smoke_config("deepseek_7b")
    params = materialize(jax.random.PRNGKey(0), model_spec(cfg))
    layout = PagedLayout(num_blocks=256, block_tokens=4, max_blocks=32)
    engines = {
        on: ServingEngine(cfg, params, layout, max_batch=2, policy="never",
                          prefix_cache=on)
        for on in (False, True)
    }
    # admit on first sight: the lane's job is maximal coverage of the
    # cached path (borrow, CoW, suffix prefill), not admission policy
    engines[True].prefix_cache.doorkeeper = False
    for rid, prompt, mnt in _make_requests(seed, cfg.vocab):
        for eng in engines.values():
            eng.submit(Request(rid=rid, prompt=prompt, max_new_tokens=mnt,
                               app="chat"))
    for i in range(400):
        more = [eng.step() for eng in engines.values()]
        kv_off, kv_on = (_active_kv(engines[on]) for on in (False, True))
        tag = f"seed={seed} step={i}"
        assert kv_on.keys() == kv_off.keys(), \
            f"{tag}: lanes schedule different sequences"
        for rid in kv_on:
            ln_on, gen_on, pools_on = kv_on[rid]
            ln_off, gen_off, pools_off = kv_off[rid]
            assert ln_on == ln_off and gen_on == gen_off, \
                f"{tag}: rid {rid} token streams diverged"
            for k in pools_on:
                np.testing.assert_array_equal(
                    pools_on[k], pools_off[k],
                    err_msg=f"{tag}: rid {rid} {k} KV bytes diverged "
                            f"(shared prefix is not bit-identical)")
        if not any(more):
            break
    on, off = engines[True], engines[False]
    assert not on.active and not off.active, "lockstep run did not drain"
    assert on.finished == off.finished, \
        f"seed={seed}: cached and uncached end states diverged"
    snap = on.prefix_cache.snapshot()
    assert snap["hits"] > 0 and snap["tokens_skipped"] > 0, \
        f"seed={seed}: workload never exercised the cache"


@pytest.mark.chaos
@pytest.mark.differential
@pytest.mark.parametrize("seed", SEEDS[:2])
def test_chaos_executor_axis(seed, monkeypatch):
    """Chaos x executor: the seeded failure schedule must also replay
    identically across the interpreter, while+switch JIT and segmented
    predicated executors (injection decisions key on modeled state, never
    on which backend produced the decision vector)."""
    script = make_script(seed)
    reps = {}
    for mode in EXECUTORS:
        reps[mode] = _chaos_replica("4tier", batched=(mode != "interp"),
                                    seed=seed)
        _force_executor(reps[mode].mm, mode, monkeypatch)
    for i, s in enumerate(script):
        for mode, r in reps.items():
            run_step(r, s)
            r.check_invariants(f"chaos seed={seed} {mode} step={i}")
        for mode in EXECUTORS[1:]:
            assert reps[mode].state() == reps["interp"].state(), \
                f"chaos seed={seed} step={i}: {mode} diverged"
    for mode in EXECUTORS[1:]:
        assert reps[mode].mm.stats.snapshot() == \
            reps["interp"].mm.stats.snapshot()
    assert sum(reps["interp"].mm.injector.fired.values()) > 0
