from .train_step import make_train_step, split_microbatches
from .trainer import Trainer, TrainerConfig

__all__ = ["make_train_step", "split_microbatches", "Trainer", "TrainerConfig"]
