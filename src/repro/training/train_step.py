"""Training step: loss -> grads -> AdamW, with microbatch gradient
accumulation (lax.scan), mixed precision (bf16 compute / f32 master+moments),
and remat already applied inside the model's layer scans.

``make_train_step`` builds the jit-able step; shardings are applied by the
launcher (launch/train.py, launch/dryrun.py) via in_shardings/out_shardings
from the distributed rule engine — this module stays mesh-agnostic.
"""

from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..models.transformer import lm_loss
from ..optim.adamw import AdamWState, adamw_update
from ..optim.schedule import linear_warmup_cosine

Pytree = Any
F32 = jnp.float32


def split_microbatches(batch: Pytree, num_micro: int) -> Pytree:
    """[B, ...] -> [num_micro, B/num_micro, ...] for every leaf with a batch
    dim (pos3d has it at axis 1)."""
    def split(path_leaf):
        return path_leaf

    def one(k, v):
        if k == "pos3d":
            m = v.shape[1] // num_micro
            return v.reshape(v.shape[0], num_micro, m, *v.shape[2:]) \
                    .transpose(1, 0, *range(2, v.ndim + 1))
        m = v.shape[0] // num_micro
        return v.reshape(num_micro, m, *v.shape[1:])
    return {k: one(k, v) for k, v in batch.items()}


def make_train_step(cfg: ModelConfig, *, num_micro: int = 1,
                    base_lr: float = 3e-4, warmup_steps: int = 100,
                    total_steps: int = 10_000, weight_decay: float = 0.1,
                    clip_norm: float = 1.0, chunk: int = 1024,
                    remat: bool = True, compute_dtype=jnp.bfloat16):
    """Returns train_step(params, opt_state, batch) ->
    (params, opt_state, metrics)."""

    def loss_fn(params, micro):
        loss, parts = lm_loss(params, cfg, micro, compute_dtype=compute_dtype,
                              chunk=chunk, remat=remat)
        return loss, parts

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state: AdamWState, batch):
        if num_micro > 1:
            micros = split_microbatches(batch, num_micro)

            def accum(carry, micro):
                gsum, lsum = carry
                (loss, _), grads = grad_fn(params, micro)
                gsum = jax.tree.map(lambda a, g: a + g.astype(F32), gsum, grads)
                return (gsum, lsum + loss), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params)
            (gsum, lsum), _ = jax.lax.scan(accum, (g0, jnp.zeros((), F32)),
                                           micros)
            grads = jax.tree.map(lambda g: g / num_micro, gsum)
            loss = lsum / num_micro
        else:
            (loss, _), grads = grad_fn(params, batch)

        lr = linear_warmup_cosine(opt_state.step, base_lr=base_lr,
                                  warmup_steps=warmup_steps,
                                  total_steps=total_steps)
        params, opt_state, om = adamw_update(
            params, grads, opt_state, lr=lr, weight_decay=weight_decay,
            clip_norm=clip_norm)
        metrics = {"loss": loss, "lr": lr, **om}
        return params, opt_state, metrics

    return train_step
