"""Trainer: the fault-tolerant outer loop tying data, step, checkpoints,
watchdog and restarts together.  Used by examples/train_lm.py and the
integration tests (with simulated failures)."""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import numpy as np

from ..checkpoint.store import CheckpointStore
from ..distributed.fault import (RestartableLoop, SimulatedFailure,
                                 StepWatchdog)
from ..optim.adamw import adamw_init
from .train_step import make_train_step

Pytree = Any


@dataclass
class TrainerConfig:
    num_steps: int = 100
    checkpoint_every: int = 20
    log_every: int = 10
    num_micro: int = 1
    base_lr: float = 3e-4
    warmup_steps: int = 10
    chunk: int = 512
    keep_checkpoints: int = 3


class Trainer:
    def __init__(self, cfg, model_cfg, params: Pytree, data_iter,
                 store: CheckpointStore, *, failure_hook: Callable | None = None):
        self.cfg = cfg
        self.model_cfg = model_cfg
        self.data_iter = data_iter
        self.store = store
        self.watchdog = StepWatchdog()
        self.failure_hook = failure_hook
        self.metrics_log: list[dict] = []

        self.step_fn = jax.jit(make_train_step(
            model_cfg, num_micro=cfg.num_micro, base_lr=cfg.base_lr,
            warmup_steps=cfg.warmup_steps, total_steps=cfg.num_steps,
            chunk=cfg.chunk))
        self.state = {"params": params, "opt": adamw_init(params)}
        self.start_step = 0
        # resume if a checkpoint exists (crash-only design)
        latest = store.latest_step()
        if latest is not None:
            self.state, meta = store.restore(latest)
            self.start_step = meta["step"]

    def _save(self, state, step):
        self.store.save(state, step=step,
                        keep=self.cfg.keep_checkpoints)

    def _restore(self):
        step = self.store.latest_step()
        state, meta = self.store.restore(step)
        return state, meta["step"]

    def run(self) -> dict:
        loop = RestartableLoop(self._save, self._restore)

        def one_step(state, step):
            if self.failure_hook is not None:
                self.failure_hook(step)          # may raise SimulatedFailure
            self.watchdog.start()
            batch = next(self.data_iter)
            params, opt, metrics = self.step_fn(state["params"], state["opt"],
                                                batch)
            jax.block_until_ready(metrics["loss"])
            wd = self.watchdog.stop()
            if step % self.cfg.log_every == 0 or step == self.cfg.num_steps - 1:
                rec = {"step": step,
                       "loss": float(metrics["loss"]),
                       "grad_norm": float(metrics["grad_norm"]),
                       "lr": float(metrics["lr"]),
                       "sec": wd["duration"],
                       "slow": wd["slow"]}
                self.metrics_log.append(rec)
            return {"params": params, "opt": opt}

        self.state, final_step = loop.run(
            self.state, self.start_step, self.cfg.num_steps, one_step,
            checkpoint_every=self.cfg.checkpoint_every)
        self._save(self.state, final_step)
        return {"final_step": final_step,
                "restarts": loop.restarts,
                "metrics": self.metrics_log}
