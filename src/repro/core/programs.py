"""Built-in policy programs, written in the eBPF-flavored ISA.

``ebpf_mm_program()`` is the paper's Figure-1 program:

    1. check the faulting process has a loaded profile,
    2. search the profile for a region containing the faulting address
       (bounded loop over the profile map — the eBPF-map search),
    3. compute the promotion cost for each feasible size from real-time
       system data (bpf_mm_promotion_cost helper: zeroing + compaction),
    4. combine with the profiled benefit + live DAMON heat and choose the
       most beneficial page size.

``thp_always_program`` / ``never_program`` reproduce the kernel baselines
(THP greedily maps PMD-size = order 2; never = base pages only) as loadable
programs so the hook overhead itself can be benchmarked.

``tier_damon_program`` / ``tier_lru_program`` / ``tier_never_program`` are
mm_tier-hook policies for the tiered-memory subsystem (:mod:`repro.core.
tiering`): DAMON-heat admission control, an LRU-demote baseline, and a
never-tier baseline that forces the preemption fallback.  A tier program's
return value is the TARGET TIER id for the candidate page (0 = HBM,
1..NTIERS-1 = spill tiers; the manager clamps and migrates hop by hop).
``tier_heat_band_program`` and ``tier_edge_admission_program`` are the
N-tier policies: heat-banded direct placement (including prefill-time
cold-prefix placement across the spill chain) and TierBPF-style single-hop
per-edge admission control.
"""

from __future__ import annotations

from .context import (CTX, EVICT_DROP, FaultKind, POLICY_FALLBACK,
                      TIER_DEMOTE, TIER_KEEP)
from .isa import Asm, Program
from .profiles import MAX_PROFILE_REGIONS, REGION_STRIDE
from .vm import (HELPER_MIGRATE_COST, HELPER_PROMOTION_COST,
                 HELPER_RINGBUF_OUTPUT)
from ..obs.ringbuf import (EV_PROG_BASE, PROF_TAG_BENEFIT, PROF_TAG_HEAT,
                           PROF_TAG_WSS)


def ebpf_mm_program(profile_map_id: int | None = None,
                    heat_weight_milli: int = 1000,
                    max_regions: int = MAX_PROFILE_REGIONS,
                    trace: bool = False) -> Program:
    """The paper's fault-hook program.

    profile map layout per region (REGION_STRIDE int64s):
        [start, end, benefit_o0, benefit_o1, benefit_o2, benefit_o3]

    The profile map id is read from ctx (PROFILE_MAP_ID) via the indirect
    LDMAPX load — one loaded program serves every application's profile
    (map-in-map, like the userspace framework registering one map per app).
    Passing ``profile_map_id`` pins a static map instead (single-app mode).
    ``max_regions`` bounds the verified search loop; lowering it keeps the
    unrolled (predicated) compile small when profiles are known to be short.
    ``trace=True`` appends a bpf_ringbuf_output emission of every decision
    (tag EV_PROG_BASE, args addr/decision/fault_max_order) — the same event
    stream on all three executors, at the cost of one event slot per lane.

    Register plan:
        r1 addr / helper arg     r2 nregions / fault_max_order / map id
        r3 loop bound counter    r4 region index
        r5 scratch / net benefit r6 best net benefit / map id
        r7 best order            r8 matched region base (-1 = none)
        r9, r10 scratch
    """
    a = Asm()

    def ld_profile(dst, idx_reg):
        if profile_map_id is None:
            a.ldmapx(dst, "r6", idx_reg)
        else:
            a.ldmap(dst, profile_map_id, idx_reg)

    a.ldctx("r1", CTX.ADDR)
    a.ldctx("r2", CTX.HAS_PROFILE)
    a.jeqi("r2", 0, "fallback")
    a.ldctx("r6", CTX.PROFILE_MAP_ID)
    a.ldctx("r2", CTX.PROFILE_NREGIONS)
    a.jeqi("r2", 0, "fallback")

    # ---- profile region search (bounded loop) ----
    a.movi("r8", -1)
    a.movi("r4", 0)
    a.movi("r3", max_regions)
    a.label("loop")
    a.jge("r4", "r2", "next_iter")          # idx >= nregions: nothing left
    a.mov("r9", "r4")
    a.muli("r9", REGION_STRIDE)
    ld_profile("r5", "r9")                  # region.start
    a.jgt("r5", "r1", "next_iter")          # start > addr
    a.mov("r10", "r9")
    a.addi("r10", 1)
    ld_profile("r5", "r10")                 # region.end
    a.jle("r5", "r1", "next_iter")          # end <= addr
    a.mov("r8", "r9")                        # match
    a.ja("search_done")
    a.label("next_iter")
    a.addi("r4", 1)
    a.jnzdec("r3", "loop")
    a.label("search_done")
    a.jlti("r8", 0, "fallback")             # unprofiled address -> default path

    # ---- per-order cost/benefit, unrolled for orders 1..3 ----
    # r6 keeps the profile map id alive for the indirect loads below.
    a.ldctx("r2", CTX.FAULT_MAX_ORDER)
    a.movi("r10", 0)                         # best net benefit
    a.movi("r7", 0)                          # best order
    for k in (1, 2, 3):
        skip = f"skip_{k}"
        a.jlti("r2", k, skip)                # infeasible at this fault
        # profiled benefit
        a.mov("r9", "r8")
        a.addi("r9", 2 + k)
        ld_profile("r5", "r9")
        # + live DAMON heat bonus: heat_k * descriptor_ns * (4^k - 1)
        a.ldctx("r9", CTX.HEAT_O0 + k)
        a.muli("r9", heat_weight_milli)
        a.divi("r9", 1000)
        a.ldctx("r4", CTX.DESCRIPTOR_NS)
        a.mul("r9", "r4")
        a.muli("r9", (4 ** k) - 1)
        a.add("r5", "r9")
        # - promotion cost (zeroing + compaction, from real-time buddy state)
        a.movi("r1", k)
        a.call(HELPER_PROMOTION_COST)        # r0 = cost ns
        a.sub("r5", "r0")
        a.jle("r5", "r10", skip)
        a.mov("r10", "r5")
        a.movi("r7", k)
        a.label(skip)
    a.mov("r0", "r7")
    if not trace:
        a.exit()
        a.label("fallback")
        a.movi("r0", POLICY_FALLBACK)
        a.exit()
        return a.build("ebpf_mm")

    a.ja("emit")
    a.label("fallback")
    a.movi("r0", POLICY_FALLBACK)
    # shared emit tail: bpf_ringbuf_output(tag, addr, decision, max_order)
    a.label("emit")
    a.mov("r9", "r0")                        # decision survives the call
    a.movi("r1", EV_PROG_BASE)
    a.ldctx("r2", CTX.ADDR)
    a.mov("r3", "r9")
    a.ldctx("r4", CTX.FAULT_MAX_ORDER)
    a.call(HELPER_RINGBUF_OUTPUT)
    a.mov("r0", "r9")
    a.exit()
    return a.build("ebpf_mm_traced")


def thp_always_program() -> Program:
    """Linux THP greedy baseline: PMD-size (order 2) whenever feasible."""
    a = Asm()
    a.ldctx("r0", CTX.FAULT_MAX_ORDER)
    a.mini("r0", 2)
    a.maxi("r0", 0)
    a.exit()
    return a.build("thp_always")


def never_program() -> Program:
    """Base pages only (THP=never)."""
    a = Asm()
    a.movi("r0", 0)
    a.exit()
    return a.build("thp_never")


def tier_damon_program(cold_heat_milli: int = 100, promote_horizon: int = 4,
                       pressure_milli: int = 700) -> Program:
    """DAMON-heat admission control for the mm_tier hook (TierBPF-style).

    For an HBM candidate: under soft pressure, approve demotion only when the
    page's own DAMON heat (FIXED_POINT-scaled accesses/window) is below
    ``cold_heat_milli`` — hot pages are vetoed, which is exactly the
    admission control that keeps proactive migration from thrashing.  Under
    HARD pressure (pool effectively full) the veto is waived: reclaim offers
    pages coldest-first and the alternative is whole-sequence preemption.
    For a spill-tier candidate: promote only when there is HBM headroom AND
    the modeled link penalty it pays per aggregation window, amortized over
    ``promote_horizon`` windows, exceeds the one-off migration cost
    (bpf_mm_migrate_cost helper over the page's tier -> HBM path).
    """
    a = Asm()
    a.ldctx("r1", CTX.PAGE_TIER)
    a.jgei("r1", 1, "spill_resident")
    # ---- HBM page: demote-admission control ----
    a.ldctx("r4", CTX.TIER_FREE_BLOCKS)
    a.jeqi("r4", 0, "keep")                  # host tier full -> nothing to gain
    a.ldctx("r3", CTX.MEM_PRESSURE)
    a.jlti("r3", pressure_milli, "keep")     # no real pressure -> keep in HBM
    # hard pressure (pool effectively full): reclaim is demoting coldest-first
    # and the alternative is whole-sequence preemption — admit unconditionally
    a.jgei("r3", 990, "demote")
    a.ldctx("r2", CTX.PAGE_HEAT)
    a.jgei("r2", cold_heat_milli, "keep")    # hot -> veto proactive demotion
    a.label("demote")
    a.movi("r0", TIER_DEMOTE)                # one tier down from HBM
    a.exit()
    a.label("keep")
    a.movi("r0", TIER_KEEP)
    a.exit()
    # ---- spill page: promote when the link tax beats the move cost ----
    a.label("spill_resident")
    a.ldctx("r6", CTX.MEM_PRESSURE)
    a.jgei("r6", 900, "stay")                # no HBM headroom -> avoid churn
    a.ldctx("r7", CTX.PAGE_HEAT)
    a.jeqi("r7", 0, "stay")                  # untouched -> stay demoted
    a.ldctx("r1", CTX.PAGE_ORDER)
    a.ldctx("r2", CTX.PAGE_TIER)
    a.movi("r3", 0)
    a.call(HELPER_MIGRATE_COST)              # r0 = cost(order, tier -> HBM)
    a.mov("r4", "r0")
    # per-window link tax ~= heat * pcie_ns_per_block * 4^order (heat is
    # FIXED_POINT-scaled, so divide it back out at the end)
    a.ldctx("r3", CTX.PCIE_NS_PER_BLOCK)
    a.mul("r3", "r7")
    a.muli("r3", promote_horizon)
    a.ldctx("r5", CTX.PAGE_ORDER)
    a.muli("r5", 2)
    a.lsh("r3", "r5")                        # * 4^order == << 2*order
    a.divi("r3", 1000)
    a.jgt("r3", "r4", "promote")
    a.label("stay")
    a.ldctx("r0", CTX.PAGE_TIER)             # stay where it lives
    a.exit()
    a.label("promote")
    a.movi("r0", TIER_KEEP)
    a.exit()
    return a.build("tier_damon")


def tier_lru_program(min_age_ticks: int = 1) -> Program:
    """LRU-demote baseline: sink any page that has not changed tiers for
    ``min_age_ticks`` engine ticks one tier down the chain, regardless of
    heat; never proactively promote (demoted pages pay the link tax until
    reclaim churn brings them back) — the classic kernel-default weakness
    eBPF tiering fixes.  In a 2-tier topology this is exactly the old
    KEEP/DEMOTE behavior (the manager clamps the bottom tier in place)."""
    a = Asm()
    a.ldctx("r0", CTX.PAGE_TIER)
    a.ldctx("r2", CTX.PAGE_AGE)
    a.jlti("r2", min_age_ticks, "keep")
    a.addi("r0", 1)                          # aged: one tier down
    a.label("keep")
    a.exit()
    return a.build("tier_lru")


def tier_never_program() -> Program:
    """Never-tier baseline: veto every demotion, so reclaim must fall back to
    whole-process preemption — the seed's behavior, as a loadable program."""
    a = Asm()
    a.movi("r0", TIER_KEEP)
    a.exit()
    return a.build("tier_never")


def tier_heat_band_program(hot_milli: int = 1500, warm_milli: int = 400,
                           cool_milli: int = 50,
                           place_pressure_milli: int = 600,
                           recent_blocks: int = 8) -> Program:
    """Heat-banded N-tier placement.

    Scan queries: the page's own DAMON heat (FIXED_POINT-scaled) picks a
    band — hot -> HBM, warm -> tier 1, cool -> tier 2, cold -> the deepest
    tier of the live topology (NTIERS from ctx; shallower topologies clamp).

    Prefill placement queries (FAULT_KIND == PREFILL): with HBM headroom
    everything defaults to HBM (zero behavior change when idle); under
    pressure the most recent ``recent_blocks`` of the prompt stay in HBM and
    the cold prefix spreads across the spill chain oldest-deepest, so cold
    prompts land directly in host/NVMe tiers instead of bouncing through
    reclaim.
    """
    a = Asm()
    a.ldctx("r9", CTX.NTIERS)
    a.subi("r9", 1)                          # deepest tier id
    a.ldctx("r1", CTX.FAULT_KIND)
    a.jnei("r1", int(FaultKind.PREFILL), "scan")
    # ---- prefill placement: cold-prefix spread across the spill chain ----
    a.ldctx("r3", CTX.MEM_PRESSURE)
    a.jlti("r3", place_pressure_milli, "t0")   # headroom -> default to HBM
    a.ldctx("r4", CTX.SEQ_LEN)
    a.subi("r4", recent_blocks)                # cold-prefix end
    a.ldctx("r5", CTX.ADDR)
    a.jge("r5", "r4", "t0")                    # recent tail stays in HBM
    # tier = deepest - floor(addr * deepest / cold_end): oldest prefix lowest
    a.mov("r6", "r5")
    a.mul("r6", "r9")
    a.div("r6", "r4")
    a.mov("r0", "r9")
    a.sub("r0", "r6")
    a.maxi("r0", 1)                            # always a spill tier here
    a.exit()
    # ---- scan path: band by the page's own heat ----
    a.label("scan")
    a.ldctx("r2", CTX.PAGE_HEAT)
    a.jgei("r2", hot_milli, "t0")
    a.jgei("r2", warm_milli, "t1")
    a.jgei("r2", cool_milli, "t2")
    a.mov("r0", "r9")                        # cold -> deepest tier
    a.exit()
    a.label("t2")
    a.movi("r0", 2)
    a.min_("r0", "r9")
    a.exit()
    a.label("t1")
    a.movi("r0", 1)
    a.min_("r0", "r9")
    a.exit()
    a.label("t0")
    a.movi("r0", 0)
    a.exit()
    return a.build("tier_heat_band")


def tier_edge_admission_program(promote_horizon: int = 4,
                                pressure_milli: int = 700) -> Program:
    """Per-edge admission control à la TierBPF: decisions are SINGLE-HOP —
    a page may only cross one edge of the tier graph per decision, and every
    crossing must pass that edge's own cost test via the
    bpf_mm_migrate_cost(order, src, dst) helper.

    The page's per-window link-tax proxy (heat x pcie_ns_per_block x 4^order
    x horizon) is compared against the edge cost both ways: promote one hop
    up when the tax it keeps paying exceeds the up-edge cost; admit a
    one-hop demotion under HBM pressure only when the tax is BELOW the
    down-edge cost (a hotter page would bounce straight back — the classic
    migration-thrash TierBPF's admission control kills).  Hard pressure
    (>= 990 milli) admits demotion unconditionally, and prefill placements
    (heat 0) admit one hop down under pressure — cold prompts enter the
    spill chain at tier 1 and sink edge by edge.

    Promotions additionally gate on the TARGET pool's ACTUAL free list: the
    register-indexed ``LDCTXR`` reads ``TIER_FREE_T{t-1}`` for the page's
    own up-edge and vetoes the hop unless the pool can back the page
    (4^order base blocks) — before the ISA grew a register-indexed ctx
    load, this program could only gate on global HBM pressure, so a hop
    toward a full intermediate pool was approved and then stalled or hopped
    over in the migration engine (the ROADMAP per-tier free-gating item).
    """
    a = Asm()
    a.ldctx("r8", CTX.PAGE_TIER)
    a.ldctx("r9", CTX.NTIERS)
    a.subi("r9", 1)                          # deepest tier id
    # r7 = per-window link-tax proxy, FIXED_POINT divided back out
    a.ldctx("r7", CTX.PAGE_HEAT)
    a.ldctx("r3", CTX.PCIE_NS_PER_BLOCK)
    a.mul("r7", "r3")
    a.muli("r7", promote_horizon)
    a.ldctx("r5", CTX.PAGE_ORDER)
    a.muli("r5", 2)
    a.lsh("r7", "r5")                        # * 4^order == << 2*order
    a.divi("r7", 1000)
    a.jeqi("r8", 0, "demote_side")
    # ---- spill page: promote admission over edge (t, t-1) ----
    a.ldctx("r6", CTX.MEM_PRESSURE)
    a.jgei("r6", 900, "demote_side")         # no HBM headroom -> consider down
    # free-list gate on the TARGET pool: TIER_FREE_T{t-1}, read through the
    # register-indexed ctx load, must cover the page's 4^order base blocks
    a.mov("r4", "r8")
    a.addi("r4", int(CTX.TIER_FREE_T0) - 1)  # ctx offset of TIER_FREE_T{t-1}
    a.ldctxr("r5", "r4")
    a.ldctx("r1", CTX.PAGE_ORDER)
    a.muli("r1", 2)
    a.movi("r4", 1)
    a.lsh("r4", "r1")                        # 4^order == 1 << 2*order
    a.jlt("r5", "r4", "demote_side")         # target pool cannot back it
    a.ldctx("r1", CTX.PAGE_ORDER)
    a.mov("r2", "r8")
    a.mov("r3", "r8")
    a.subi("r3", 1)
    a.call(HELPER_MIGRATE_COST)              # r0 = cost of one hop up
    a.jle("r7", "r0", "demote_side")         # tax under the edge cost: not up
    a.mov("r0", "r8")
    a.subi("r0", 1)
    a.exit()
    # ---- demote admission over edge (t, t+1) ----
    a.label("demote_side")
    a.jge("r8", "r9", "stay")                # already in the deepest tier
    a.ldctx("r6", CTX.MEM_PRESSURE)
    a.jlti("r6", pressure_milli, "stay")     # no pressure -> nothing to gain
    a.jgei("r6", 990, "admit")               # hard pressure: unconditional
    a.ldctx("r1", CTX.PAGE_ORDER)
    a.mov("r2", "r8")
    a.mov("r3", "r8")
    a.addi("r3", 1)
    a.call(HELPER_MIGRATE_COST)              # r0 = cost of one hop down
    a.jgt("r7", "r0", "stay")                # it would bounce back -> veto
    a.label("admit")
    a.mov("r0", "r8")
    a.addi("r0", 1)
    a.exit()
    a.label("stay")
    a.mov("r0", "r8")
    a.exit()
    return a.build("tier_edge_admission")


def evict_lru_program(min_age_ticks: int = 2) -> Program:
    """LRU eviction for the mm_evict hook (prefix-cache reclaim).

    Evict ctx rows are cached prefix entries: PAGE_TIER / PAGE_AGE /
    PAGE_HEAT carry the entry's tier, ticks since its last admission hit and
    DAMON heat; CACHE_* columns carry refcount/hit/size facts plus the
    cache-global budget state.  The return value is the TARGET TIER for the
    entry (its current tier = keep) or EVICT_DROP to free it outright.

    Policy: never touch pinned entries; do nothing while the cache is under
    its HBM budget; over budget, sink entries idle for ``min_age_ticks``
    one tier down the chain, dropping only past the end of the chain.
    """
    a = Asm()
    a.ldctx("r1", CTX.CACHE_REFCOUNT)
    a.jgei("r1", 1, "keep")                  # pinned: borrowers hold it
    a.ldctx("r2", CTX.CACHE_USED_BLOCKS)
    a.ldctx("r3", CTX.CACHE_CAP_BLOCKS)
    a.jle("r2", "r3", "keep")                # under budget: nothing to do
    a.ldctx("r4", CTX.PAGE_AGE)
    a.jlti("r4", min_age_ticks, "keep")      # recently hit: protect
    a.ldctx("r0", CTX.PAGE_TIER)
    a.addi("r0", 1)                          # one tier down the chain
    a.ldctx("r5", CTX.NTIERS)
    a.jlt("r0", "r5", "done")                # still a live tier: demote
    a.movi("r0", EVICT_DROP)                 # past the chain end: drop
    a.label("done")
    a.exit()
    a.label("keep")
    a.ldctx("r0", CTX.PAGE_TIER)
    a.exit()
    return a.build("evict_lru")


def evict_lfu_program(protect_hits: int = 2, min_age_ticks: int = 1) -> Program:
    """LFU eviction for the mm_evict hook: frequency protects.

    Entries that have served at least ``protect_hits`` admissions stay put
    (frequently reused system prompts survive bursts of one-off traffic);
    low-frequency entries idle for ``min_age_ticks`` sink one tier, dropping
    only off the end of the chain.  Pinned entries and an under-budget cache
    are untouchable, as in :func:`evict_lru_program`.
    """
    a = Asm()
    a.ldctx("r1", CTX.CACHE_REFCOUNT)
    a.jgei("r1", 1, "keep")
    a.ldctx("r2", CTX.CACHE_USED_BLOCKS)
    a.ldctx("r3", CTX.CACHE_CAP_BLOCKS)
    a.jle("r2", "r3", "keep")
    a.ldctx("r4", CTX.CACHE_HITS)
    a.jgei("r4", protect_hits, "keep")       # proven reuse: protect
    a.ldctx("r4", CTX.PAGE_AGE)
    a.jlti("r4", min_age_ticks, "keep")
    a.ldctx("r0", CTX.PAGE_TIER)
    a.addi("r0", 1)
    a.ldctx("r5", CTX.NTIERS)
    a.jlt("r0", "r5", "done")
    a.movi("r0", EVICT_DROP)
    a.label("done")
    a.exit()
    a.label("keep")
    a.ldctx("r0", CTX.PAGE_TIER)
    a.exit()
    return a.build("evict_lfu")


def evict_ghost_program(retain_milli: int = 150,
                        min_age_ticks: int = 1) -> Program:
    """Ghost-hit-rate adaptive eviction (the Cache-is-King feedback loop).

    The cache keeps a ghost list of recently evicted keys; a lookup that
    would have hit a ghost entry is an eviction the policy got wrong.  The
    per-entry ghost pressure proxy ``ghost_hits * 1000 / (ghost_hits +
    live_entries + 1)`` rises when evicted prefixes keep coming back:

      * pressure >= ``retain_milli`` — the policy is over-evicting, so stop
        destroying state: demote one hop down the tier chain and PARK at the
        deepest tier instead of dropping (a later hit re-promotes for one
        link-speed copy instead of a full prefill);
      * pressure below it — evicted prefixes are not returning, so stale
        entries are genuinely dead: drop them outright and skip the
        demotion churn.

    Pinned entries and an under-budget cache are untouchable.
    """
    a = Asm()
    a.ldctx("r1", CTX.CACHE_REFCOUNT)
    a.jgei("r1", 1, "keep")
    a.ldctx("r2", CTX.CACHE_USED_BLOCKS)
    a.ldctx("r3", CTX.CACHE_CAP_BLOCKS)
    a.jle("r2", "r3", "keep")
    a.ldctx("r4", CTX.PAGE_AGE)
    a.jlti("r4", min_age_ticks, "keep")
    # ghost pressure (milli) = ghost * 1000 / (ghost + entries + 1)
    a.ldctx("r5", CTX.CACHE_GHOST_HITS)
    a.mov("r6", "r5")
    a.muli("r6", 1000)
    a.ldctx("r7", CTX.CACHE_ENTRIES)
    a.add("r7", "r5")
    a.addi("r7", 1)
    a.div("r6", "r7")
    a.jgei("r6", retain_milli, "park")
    a.movi("r0", EVICT_DROP)                 # nothing comes back: drop
    a.exit()
    a.label("park")
    a.ldctx("r0", CTX.PAGE_TIER)
    a.addi("r0", 1)
    a.ldctx("r8", CTX.NTIERS)
    a.jlt("r0", "r8", "done")
    a.subi("r0", 1)                          # deepest already: stay parked
    a.label("done")
    a.exit()
    a.label("keep")
    a.ldctx("r0", CTX.PAGE_TIER)
    a.exit()
    return a.build("evict_ghost")


def profile_wss_program(idle_milli: int = 50) -> Program:
    """Per-region WSS / idle-page estimator for the mm_profile hook.

    Profile ctx rows are live DAMON regions (PROF_* columns); the program
    classifies each region against an idle threshold the way the WSS paper's
    in-kernel estimator classifies idle pages: a region whose access EMA is
    below ``idle_milli`` (FIXED_POINT-scaled accesses/window) contributes 0
    blocks to the working set, anything else contributes its full span.  The
    per-region contribution is emitted through bpf_ringbuf_output
    (PROF_TAG_WSS) so the host synthesizer can fold the samples into a WSS
    curve; the return value is the region's hot score (its heat, or
    PROFILE_COLD for idle regions).
    """
    a = Asm()
    a.ldctx("r6", CTX.PROF_REGION_END)
    a.ldctx("r7", CTX.PROF_REGION_START)
    a.sub("r6", "r7")                        # region span, blocks
    a.ldctx("r8", CTX.PROF_REGION_HEAT)
    a.movi("r5", 0)                          # WSS contribution
    a.movi("r9", 0)                          # hot score (PROFILE_COLD)
    a.jlti("r8", idle_milli, "emit")         # idle: contributes nothing
    a.mov("r5", "r6")
    a.mov("r9", "r8")
    a.label("emit")
    a.movi("r1", PROF_TAG_WSS)
    a.ldctx("r2", CTX.PID)
    a.mov("r3", "r5")
    a.mov("r4", "r6")
    a.call(HELPER_RINGBUF_OUTPUT)
    a.mov("r0", "r9")
    a.exit()
    return a.build("profile_wss")


def profile_heat_histogram_program() -> Program:
    """Log2 heat-histogram accumulator for the mm_profile hook.

    Buckets each DAMON region by ``floor(log2(heat))`` with a verified
    bounded loop (the shift-count idiom an in-kernel histogram program
    uses), and emits (pid, bucket, region blocks) through
    bpf_ringbuf_output (PROF_TAG_HEAT) — one histogram sample per region
    per aggregation window.  Returns the bucket index.
    """
    a = Asm()
    a.ldctx("r2", CTX.PROF_REGION_HEAT)
    a.ldctx("r6", CTX.PROF_REGION_END)
    a.ldctx("r7", CTX.PROF_REGION_START)
    a.sub("r6", "r7")                        # region span, blocks
    a.movi("r5", 0)                          # bucket = floor(log2(heat))
    a.movi("r3", 31)                         # verifier loop bound
    a.label("log2")
    a.jlei("r2", 1, "emit")
    a.divi("r2", 2)
    a.addi("r5", 1)
    a.jnzdec("r3", "log2")
    a.label("emit")
    a.movi("r1", PROF_TAG_HEAT)
    a.ldctx("r2", CTX.PID)
    a.mov("r3", "r5")
    a.mov("r4", "r6")
    a.call(HELPER_RINGBUF_OUTPUT)
    a.mov("r0", "r5")
    a.exit()
    return a.build("profile_heat_hist")


def profile_benefit_program(heat_weight_milli: int = 1000) -> Program:
    """Promotion-benefit scorer for the mm_profile hook (CBMM mold).

    For each DAMON region, estimates what a profile entry is worth: the
    per-window TLB/descriptor saving of mapping the region at order k
    (heat x descriptor_ns x (4^k - 1), heat FIXED_POINT-divided back out)
    minus the live promotion cost from real-time buddy state
    (bpf_mm_promotion_cost) — the same cost/benefit arithmetic the Fig-1
    fault program applies at fault time, run SPECULATIVELY over the region
    stream so the synthesizer can write the winning benefit into the
    region's profile entry before any fault touches it.  Emits
    (region start, best order, net benefit) via bpf_ringbuf_output
    (PROF_TAG_BENEFIT); returns the best net benefit (0 = not worth it).
    """
    a = Asm()
    a.ldctx("r8", CTX.PROF_REGION_HEAT)
    a.movi("r10", 0)                         # best net benefit
    a.movi("r7", 0)                          # best order
    for k in (1, 2, 3):
        skip = f"skip_{k}"
        a.ldctx("r6", CTX.PROF_REGION_END)
        a.ldctx("r5", CTX.PROF_REGION_START)
        a.sub("r6", "r5")
        a.jlti("r6", 4 ** k, skip)           # order must fit in the region
        a.mov("r9", "r8")
        a.muli("r9", heat_weight_milli)
        a.divi("r9", 1000)
        a.ldctx("r4", CTX.DESCRIPTOR_NS)
        a.mul("r9", "r4")
        a.muli("r9", (4 ** k) - 1)
        a.divi("r9", 1000)                   # heat is FIXED_POINT-scaled
        a.movi("r1", k)
        a.call(HELPER_PROMOTION_COST)        # r0 = cost ns
        a.sub("r9", "r0")
        a.jle("r9", "r10", skip)
        a.mov("r10", "r9")
        a.movi("r7", k)
        a.label(skip)
    a.movi("r1", PROF_TAG_BENEFIT)
    a.ldctx("r2", CTX.PROF_REGION_START)
    a.mov("r3", "r7")
    a.mov("r4", "r10")
    a.call(HELPER_RINGBUF_OUTPUT)
    a.mov("r0", "r10")
    a.exit()
    return a.build("profile_benefit")


def reclaim_lru_program() -> Program:
    """Default reclaim-hook program: pick the coldest candidate.

    Reclaim ctx reuses the fault ctx layout: HEAT_O0..O3 carry the heat of up
    to 4 victim candidates and ADDR carries the candidate count; returns the
    index of the victim (lowest heat), or FALLBACK when no candidates.
    """
    a = Asm()
    a.ldctx("r1", CTX.ADDR)                 # candidate count
    a.jeqi("r1", 0, "none")
    a.movi("r0", 0)                          # best idx
    a.ldctx("r2", CTX.HEAT_O0)               # best heat
    for i in (1, 2, 3):
        a.jlei("r1", i, "done")              # fewer than i+1 candidates
        a.ldctx("r3", CTX.HEAT_O0 + i)
        a.jge("r3", "r2", f"skip_{i}")
        a.mov("r2", "r3")
        a.movi("r0", i)
        a.label(f"skip_{i}")
    a.label("done")
    a.exit()
    a.label("none")
    a.movi("r0", POLICY_FALLBACK)
    a.exit()
    return a.build("reclaim_lru")
