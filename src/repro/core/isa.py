"""eBPF-flavored instruction set for userspace-loaded memory-management policies.

This is the TPU-framework analogue of the eBPF bytecode the paper loads into
the Linux page-fault path.  Policies are small register programs that read a
flat ``FaultContext`` struct (the "ctx" pointer of an eBPF program), may look
up bounded array maps (the analogue of eBPF maps holding the userspace
profile), and return the chosen page-size class in ``r0``.

Design notes (mirrors eBPF where it matters):
  * 11 general registers ``r0..r10``; ``r0`` is the return value.
  * 64-bit signed integer arithmetic, wrapping, with eBPF's safe-division
    semantics (``x / 0 == 0``, ``x % 0 == x``).
  * Forward conditional jumps only, plus a single verified bounded-loop
    primitive ``JNZDEC`` (decrement-and-branch-back) whose trip count the
    verifier must be able to bound — the moral equivalent of eBPF's
    bounded-loop support.
  * ``CALL`` invokes a white-listed helper (cf. ``bpf_*`` helpers).
  * Programs must be accepted by :mod:`repro.core.verifier` before they can
    be attached to a hook (load-time verification, like the kernel).
"""

from __future__ import annotations

import enum
from dataclasses import dataclass
from typing import Iterable, Sequence

NUM_REGS = 11
R0 = 0  # return value
MAX_PROGRAM_LEN = 4096      # eBPF instruction-count limit
MAX_LOOP_ITERS = 64         # verifier bound for a single JNZDEC loop
MAX_SIM_INSNS = 100_000     # total verified instruction budget (cf. 1M in Linux)

INT64_MASK = (1 << 64) - 1


def _wrap64(x: int) -> int:
    """Wrap a python int to signed 64-bit, mirroring kernel u64/s64 math."""
    x &= INT64_MASK
    if x >= 1 << 63:
        x -= 1 << 64
    return x


class Op(enum.IntEnum):
    # ALU, register source
    MOV = 0
    ADD = 1
    SUB = 2
    MUL = 3
    DIV = 4      # safe: /0 -> 0
    MOD = 5      # safe: %0 -> lhs
    AND = 6
    OR = 7
    XOR = 8
    LSH = 9
    RSH = 10     # logical shift right on the 64-bit pattern
    MIN = 11
    MAX = 12
    # ALU, immediate source
    MOVI = 16
    ADDI = 17
    SUBI = 18
    MULI = 19
    DIVI = 20
    MODI = 21
    ANDI = 22
    ORI = 23
    XORI = 24
    LSHI = 25
    RSHI = 26
    MINI = 27
    MAXI = 28
    NEG = 29
    # Loads
    LDCTX = 32   # rd = ctx[imm]
    LDMAP = 33   # rd = map[src2][clamp(rs)]   (imm = map id, rs = index reg)
    MAPSZ = 34   # rd = len(map[imm])
    LDMAPX = 35  # rd = map[clamp(r_src2=imm reg)][clamp(rs)] — indirect map id
                 # (map-in-map analogue; both indices runtime-clamped)
    LDCTXR = 36  # rd = ctx[clamp(rs)] — REGISTER-indexed ctx load.  The
                 # verifier requires rs to be initialized and rejects a
                 # const-tracked index outside [0, CTX_LEN); every backend
                 # lowers the residual dynamic case with the same clamp.
    # Control flow — conditional jumps compare rs against rt (reg) or imm.
    JA = 48      # unconditional forward jump by +imm
    JEQ = 49
    JNE = 50
    JLT = 51
    JLE = 52
    JGT = 53
    JGE = 54
    JSET = 55    # jump if (rs & operand) != 0
    JEQI = 56
    JNEI = 57
    JLTI = 58
    JLEI = 59
    JGTI = 60
    JGEI = 61
    JSETI = 62
    JNZDEC = 63  # rd -= 1; if rd != 0 jump BACK by -imm (verified bounded loop)
    # Misc
    CALL = 80    # helper call, imm = helper id; args r1..r5, ret r0
    EXIT = 81    # return r0


# Ops whose "imm" field is a jump offset.
JUMP_OPS = frozenset({
    Op.JA, Op.JEQ, Op.JNE, Op.JLT, Op.JLE, Op.JGT, Op.JGE, Op.JSET,
    Op.JEQI, Op.JNEI, Op.JLTI, Op.JLEI, Op.JGTI, Op.JGEI, Op.JSETI,
})
COND_JUMP_REG = frozenset({Op.JEQ, Op.JNE, Op.JLT, Op.JLE, Op.JGT, Op.JGE, Op.JSET})
COND_JUMP_IMM = frozenset({Op.JEQI, Op.JNEI, Op.JLTI, Op.JLEI, Op.JGTI, Op.JGEI, Op.JSETI})
ALU_REG_OPS = frozenset({Op.MOV, Op.ADD, Op.SUB, Op.MUL, Op.DIV, Op.MOD, Op.AND,
                         Op.OR, Op.XOR, Op.LSH, Op.RSH, Op.MIN, Op.MAX})
ALU_IMM_OPS = frozenset({Op.MOVI, Op.ADDI, Op.SUBI, Op.MULI, Op.DIVI, Op.MODI,
                         Op.ANDI, Op.ORI, Op.XORI, Op.LSHI, Op.RSHI, Op.MINI,
                         Op.MAXI})


@dataclass(frozen=True)
class Insn:
    """One instruction. Fields are used per-op:

    op     : opcode
    dst    : destination register (or counter register for JNZDEC)
    src    : source register (ALU reg forms, cond-jump rhs, LDMAP index reg)
    imm    : immediate / ctx offset / map id / jump offset / helper id
    src2   : secondary immediate (LDMAP map id)
    """
    op: Op
    dst: int = 0
    src: int = 0
    imm: int = 0
    src2: int = 0

    def __repr__(self) -> str:  # compact disassembly, used in error messages
        return f"{self.op.name}(dst=r{self.dst}, src=r{self.src}, imm={self.imm}, src2={self.src2})"


class Program:
    """A sequence of instructions plus the maps it references."""

    def __init__(self, insns: Sequence[Insn], name: str = "policy") -> None:
        self.insns: list[Insn] = list(insns)
        self.name = name

    def __len__(self) -> int:
        return len(self.insns)

    def __iter__(self) -> Iterable[Insn]:
        return iter(self.insns)

    def disassemble(self) -> str:
        return "\n".join(f"{i:4d}: {insn!r}" for i, insn in enumerate(self.insns))


class Asm:
    """Tiny assembler with labels, so policies read like eBPF assembly.

    Example::

        a = Asm()
        a.ldctx("r1", CTX.FREE_BLOCKS_0)
        a.jeqi("r1", 0, "no_free")
        a.movi("r0", 2)
        a.exit()
        a.label("no_free")
        a.movi("r0", 0)
        a.exit()
        prog = a.build("my_policy")
    """

    def __init__(self) -> None:
        self._insns: list[tuple] = []   # (op, dst, src, imm_or_label, src2)
        self._labels: dict[str, int] = {}

    # -- label handling -------------------------------------------------
    def label(self, name: str) -> "Asm":
        if name in self._labels:
            raise ValueError(f"duplicate label {name!r}")
        self._labels[name] = len(self._insns)
        return self

    @staticmethod
    def _reg(r) -> int:
        if isinstance(r, str):
            if not r.startswith("r"):
                raise ValueError(f"bad register {r!r}")
            r = int(r[1:])
        if not 0 <= r < NUM_REGS:
            raise ValueError(f"register out of range: r{r}")
        return r

    def _emit(self, op: Op, dst=0, src=0, imm=0, src2=0) -> "Asm":
        self._insns.append((op, self._reg(dst),
                            self._reg(src) if isinstance(src, str) else src,
                            imm, src2))
        return self

    # -- ALU ------------------------------------------------------------
    def mov(self, d, s):  return self._emit(Op.MOV, d, self._reg(s))
    def movi(self, d, imm): return self._emit(Op.MOVI, d, 0, imm)
    def add(self, d, s):  return self._emit(Op.ADD, d, self._reg(s))
    def addi(self, d, imm): return self._emit(Op.ADDI, d, 0, imm)
    def sub(self, d, s):  return self._emit(Op.SUB, d, self._reg(s))
    def subi(self, d, imm): return self._emit(Op.SUBI, d, 0, imm)
    def mul(self, d, s):  return self._emit(Op.MUL, d, self._reg(s))
    def muli(self, d, imm): return self._emit(Op.MULI, d, 0, imm)
    def div(self, d, s):  return self._emit(Op.DIV, d, self._reg(s))
    def divi(self, d, imm): return self._emit(Op.DIVI, d, 0, imm)
    def mod(self, d, s):  return self._emit(Op.MOD, d, self._reg(s))
    def modi(self, d, imm): return self._emit(Op.MODI, d, 0, imm)
    def and_(self, d, s): return self._emit(Op.AND, d, self._reg(s))
    def andi(self, d, imm): return self._emit(Op.ANDI, d, 0, imm)
    def or_(self, d, s):  return self._emit(Op.OR, d, self._reg(s))
    def ori(self, d, imm): return self._emit(Op.ORI, d, 0, imm)
    def xor(self, d, s):  return self._emit(Op.XOR, d, self._reg(s))
    def xori(self, d, imm): return self._emit(Op.XORI, d, 0, imm)
    def lsh(self, d, s):  return self._emit(Op.LSH, d, self._reg(s))
    def lshi(self, d, imm): return self._emit(Op.LSHI, d, 0, imm)
    def rsh(self, d, s):  return self._emit(Op.RSH, d, self._reg(s))
    def rshi(self, d, imm): return self._emit(Op.RSHI, d, 0, imm)
    def min_(self, d, s): return self._emit(Op.MIN, d, self._reg(s))
    def mini(self, d, imm): return self._emit(Op.MINI, d, 0, imm)
    def max_(self, d, s): return self._emit(Op.MAX, d, self._reg(s))
    def maxi(self, d, imm): return self._emit(Op.MAXI, d, 0, imm)
    def neg(self, d):     return self._emit(Op.NEG, d)

    # -- loads ------------------------------------------------------------
    def ldctx(self, d, off: int): return self._emit(Op.LDCTX, d, 0, int(off))
    def ldctxr(self, d, idx_reg): return self._emit(Op.LDCTXR, d, self._reg(idx_reg))
    def ldmap(self, d, map_id: int, idx_reg): return self._emit(Op.LDMAP, d, self._reg(idx_reg), 0, int(map_id))
    def ldmapx(self, d, map_reg, idx_reg):
        return self._emit(Op.LDMAPX, d, self._reg(idx_reg), 0,
                          self._reg(map_reg))
    def mapsz(self, d, map_id: int): return self._emit(Op.MAPSZ, d, 0, int(map_id))

    # -- control flow ------------------------------------------------------
    def ja(self, target: str): return self._emit(Op.JA, 0, 0, target)
    def jeq(self, a, b, t):  return self._emit(Op.JEQ, self._reg(a), self._reg(b), t)
    def jne(self, a, b, t):  return self._emit(Op.JNE, self._reg(a), self._reg(b), t)
    def jlt(self, a, b, t):  return self._emit(Op.JLT, self._reg(a), self._reg(b), t)
    def jle(self, a, b, t):  return self._emit(Op.JLE, self._reg(a), self._reg(b), t)
    def jgt(self, a, b, t):  return self._emit(Op.JGT, self._reg(a), self._reg(b), t)
    def jge(self, a, b, t):  return self._emit(Op.JGE, self._reg(a), self._reg(b), t)
    def jset(self, a, b, t): return self._emit(Op.JSET, self._reg(a), self._reg(b), t)
    def jeqi(self, a, imm, t):  return self._emit(Op.JEQI, self._reg(a), 0, t, imm)
    def jnei(self, a, imm, t):  return self._emit(Op.JNEI, self._reg(a), 0, t, imm)
    def jlti(self, a, imm, t):  return self._emit(Op.JLTI, self._reg(a), 0, t, imm)
    def jlei(self, a, imm, t):  return self._emit(Op.JLEI, self._reg(a), 0, t, imm)
    def jgti(self, a, imm, t):  return self._emit(Op.JGTI, self._reg(a), 0, t, imm)
    def jgei(self, a, imm, t):  return self._emit(Op.JGEI, self._reg(a), 0, t, imm)
    def jseti(self, a, imm, t): return self._emit(Op.JSETI, self._reg(a), 0, t, imm)
    def jnzdec(self, counter, target: str):
        return self._emit(Op.JNZDEC, self._reg(counter), 0, target)

    # -- misc ------------------------------------------------------------
    def call(self, helper_id: int): return self._emit(Op.CALL, 0, 0, int(helper_id))
    def exit(self): return self._emit(Op.EXIT)

    # -- build -----------------------------------------------------------
    def build(self, name: str = "policy") -> Program:
        insns: list[Insn] = []
        for pc, (op, dst, src, imm, src2) in enumerate(self._insns):
            if op in JUMP_OPS or op == Op.JNZDEC:
                # For conditional-immediate jumps the comparison immediate was
                # stashed in src2 by the assembler helpers above.
                if isinstance(imm, str):
                    if imm not in self._labels:
                        raise ValueError(f"undefined label {imm!r}")
                    target = self._labels[imm]
                    off = target - (pc + 1)
                else:
                    off = int(imm)
                if op == Op.JNZDEC:
                    if off >= 0:
                        raise ValueError(f"JNZDEC at {pc} must jump backward (got {off})")
                else:
                    if off < 0:
                        raise ValueError(
                            f"{op.name} at {pc}: backward jumps are only allowed "
                            f"via JNZDEC (got offset {off})")
                cmp_imm = src2 if op in COND_JUMP_IMM else 0
                insns.append(Insn(op, dst, src, off, cmp_imm))
            else:
                insns.append(Insn(op, dst, src, _wrap64(int(imm)) if not isinstance(imm, str) else 0, src2))
        return Program(insns, name)
