"""Cross-session compiler-artifact cache for the policy pipeline.

Engine construction used to pay the full pipeline on every process start:
re-verify, re-unroll (the 64-region Fig-1 flattening alone walks ~900
lowered insns), re-trace and re-XLA-compile every batch bucket.  None of
that work depends on anything but the program bytes and the compilation
shapes, so it is cached across sessions under ``.cache/`` (gitignored;
``make clean-cache`` wipes it):

  * **lowering/unroll artifacts** — the flattened lowered IR + segment cut
    points, pickled per :meth:`LoweredProgram.digest` — a key covering the
    instruction stream, the map-registry shape contract (slot count +
    capacities), the ctx layout width (``CTX_LEN`` — which is how a tier-
    topology/struct change invalidates entries) and the IR version;
  * **XLA executables** — jax's persistent compilation cache, pointed at
    ``.cache/xla``.  Its fingerprint covers the traced computation, which
    is where the remaining key axes live: the BATCH BUCKET (each padded
    batch shape is its own entry) and the map capacities.

Environment: ``REPRO_CACHE_DIR`` overrides the root (default ``.cache`` in
the working directory); ``REPRO_CACHE_DIR=0`` (or ``off``) disables disk
persistence entirely — everything still works, just cold every session.
``REPRO_CACHE_MAX_BYTES`` caps the on-disk artifact directory: when a write
pushes the total over the cap, the least-recently-USED pickles (read hits
refresh mtime) are evicted oldest-first until it fits.
"""

from __future__ import annotations

import os
import pickle
import tempfile
from pathlib import Path

from .lower import LoweredProgram, unroll_lowered

_DISABLED = ("0", "off", "none", "")

# Default on-disk artifact budget — far above a normal session's handful of
# unroll pickles, small enough that a long-lived CI cache can't grow without
# bound across program-shape churn.
DEFAULT_MAX_BYTES = 256 * 1024 * 1024


class ArtifactCache:
    """Two-level (in-process dict, on-disk pickle) cache for lowered and
    unrolled program artifacts, plus the XLA persistent-cache hookup.
    On-disk entries are LRU-evicted by last-used time under a size cap."""

    def __init__(self, root: str | os.PathLike | None = None,
                 max_bytes: int | None = None) -> None:
        if root is None:
            env = os.environ.get("REPRO_CACHE_DIR")
            if env is not None and env.lower() in _DISABLED:
                root = None
            else:
                root = env or ".cache"
        if max_bytes is None:
            max_bytes = int(os.environ.get("REPRO_CACHE_MAX_BYTES",
                                           DEFAULT_MAX_BYTES))
        self.root = Path(root) if root else None
        self.max_bytes = max_bytes
        self._unrolled: dict[str, tuple] = {}   # in-proc, by program digest
        self._xla_enabled = False
        # miss_absent/miss_corrupt split unroll_misses by reason: nothing on
        # disk vs an artifact that was there but failed to load back.
        self.stats = {"unroll_disk_hits": 0, "unroll_hits": 0,
                      "unroll_misses": 0, "miss_absent": 0,
                      "miss_corrupt": 0, "evictions": 0}

    @property
    def enabled(self) -> bool:
        return self.root is not None

    # ------------------------------------------------------------- xla cache
    def enable_xla_cache(self) -> None:
        """Point jax's persistent compilation cache at ``<root>/xla`` so the
        compiled policy executables (per program x batch bucket) survive the
        process.  Idempotent; silently a no-op when persistence is disabled
        or the jax build lacks the knobs."""
        if not self.enabled or self._xla_enabled:
            return
        self._xla_enabled = True
        try:
            import jax
            (self.root / "xla").mkdir(parents=True, exist_ok=True)
            jax.config.update("jax_compilation_cache_dir",
                              str(self.root / "xla"))
            # policy programs are tiny and compile fast — cache them anyway
            jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
            jax.config.update("jax_persistent_cache_min_compile_time_secs", 0)
        except Exception:       # pragma: no cover - older jax knobs
            pass

    # ------------------------------------------------------------ lowering
    def unrolled(self, lp: LoweredProgram, injector=None) -> tuple:
        """``(code, cuts)`` for ``lp`` — memoized in-process and persisted
        on disk keyed by the program digest.  Raises ``ValueError`` (not
        cached) when the flattened form exceeds the pipeline limit.

        A corrupt/truncated on-disk artifact NEVER raises out of here — it
        counts a ``miss_corrupt`` and recompiles (the rewrite heals the
        entry).  ``injector`` (a resilience FailureInjector) can force the
        corrupt path on an otherwise-good disk hit, keyed on the digest so
        the schedule replays.
        """
        key = lp.digest()
        hit = self._unrolled.get(key)
        if hit is not None:
            self.stats["unroll_hits"] += 1
            return hit
        art, miss_reason = self._read(f"unroll-{key}")
        if art is not None and injector is not None and injector.fires(
                "cache_corrupt", key):
            art, miss_reason = None, "corrupt"
        if art is not None:
            self.stats["unroll_hits"] += 1
            self.stats["unroll_disk_hits"] += 1
            self._unrolled[key] = art
            return art
        self.stats["unroll_misses"] += 1
        self.stats[f"miss_{miss_reason}"] += 1
        art = unroll_lowered(lp)
        self._unrolled[key] = art
        self._write(f"unroll-{key}", art)
        return art

    # ---------------------------------------------------------------- disk
    def _path(self, name: str) -> Path:
        return self.root / "ebpf" / f"{name}.pkl"

    def _read(self, name: str):
        """``(artifact, miss_reason)``: ``(obj, None)`` on a hit,
        ``(None, "absent")`` when nothing is on disk (or persistence is
        off), ``(None, "corrupt")`` when a pickle exists but cannot be
        loaded back — truncated/garbled/stale artifacts recompile, they
        never propagate an exception into engine construction."""
        if not self.enabled:
            return None, "absent"
        path = self._path(name)
        try:
            with open(path, "rb") as f:
                obj = pickle.load(f)
            os.utime(path)          # LRU: a read hit refreshes last-used
            return obj, None
        except FileNotFoundError:
            return None, "absent"
        except Exception:
            return None, "corrupt"

    def _write(self, name: str, obj) -> None:
        if not self.enabled:
            return
        try:
            path = self._path(name)
            path.parent.mkdir(parents=True, exist_ok=True)
            fd, tmp = tempfile.mkstemp(dir=path.parent, suffix=".tmp")
            with os.fdopen(fd, "wb") as f:
                pickle.dump(obj, f)
            os.replace(tmp, path)   # atomic: readers never see partials
            self._evict_lru(keep=path)
        except OSError:             # read-only fs etc: stay in-memory only
            pass

    def _evict_lru(self, keep: Path | None = None) -> None:
        """Drop the oldest-used artifact pickles until the directory fits
        ``max_bytes``.  The just-written entry is exempt so a single
        oversized artifact does not evict itself into a write loop."""
        if not self.enabled or self.max_bytes <= 0:
            return
        try:
            entries = []
            for p in (self.root / "ebpf").glob("*.pkl"):
                st = p.stat()
                entries.append((st.st_mtime, st.st_size, p))
        except OSError:
            return
        total = sum(size for _, size, _ in entries)
        entries.sort()              # oldest last-used first
        for _, size, p in entries:
            if total <= self.max_bytes:
                break
            if keep is not None and p == keep:
                continue
            try:
                p.unlink()
            except OSError:
                continue
            total -= size
            self.stats["evictions"] += 1


# The process-wide default instance every HookRegistry uses unless handed a
# private one (the warm/cold benchmark lanes do, to isolate directories).
artifact_cache = ArtifactCache()
