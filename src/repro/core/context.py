"""FaultContext — the flat "ctx" struct visible to policy programs.

The Linux eBPF-mm hook hands the program a context describing the faulting
address, the VMA, and real-time system state (buddy free lists, fragmentation,
DAMON heat, profile hints).  We mirror that as a fixed int64 vector so both the
host interpreter and the vectorized jnp JIT can consume it.

All "time" quantities are modeled nanoseconds on the target TPU (v5e), all
"heat" quantities are DAMON-style access counts per aggregation window, and
fractional quantities use FIXED_POINT scaling.
"""

from __future__ import annotations

import enum
from dataclasses import dataclass, field

import numpy as np

NUM_ORDERS = 4          # page-size classes: 4^order base blocks
FIXED_POINT = 1000      # scale for fractional ctx fields
MAX_TIERS = 4           # tier ids 0..3: local HBM, and up to 3 spill tiers


class CTX(enum.IntEnum):
    """Offsets into the flat context vector."""
    ADDR = 0                 # faulting logical block index (within VMA space)
    PID = 1
    VMA_START = 2            # VMA bounds, in logical blocks
    VMA_END = 3
    FAULT_MAX_ORDER = 4      # max order feasible at ADDR (alignment + VMA fit)
    HAS_PROFILE = 5          # 1 if the faulting pid has a loaded profile
    PROFILE_MAP_ID = 6       # map id holding this pid's profile regions
    PROFILE_NREGIONS = 7
    # Buddy allocator state (per order)
    FREE_BLOCKS_O0 = 8
    FREE_BLOCKS_O1 = 9
    FREE_BLOCKS_O2 = 10
    FREE_BLOCKS_O3 = 11
    # Fragmentation index per order, FIXED_POINT-scaled (0 = none, 1000 = full)
    FRAG_O0 = 12
    FRAG_O1 = 13
    FRAG_O2 = 14
    FRAG_O3 = 15
    # DAMON heat of the aligned region enclosing ADDR, per candidate order
    HEAT_O0 = 16
    HEAT_O1 = 17
    HEAT_O2 = 18
    HEAT_O3 = 19
    # Cost-model constants (calibrated, modeled ns)
    ZERO_NS_PER_BLOCK = 20
    COMPACT_NS_PER_BLOCK = 21
    DESCRIPTOR_NS = 22       # per page-table-entry / DMA-descriptor overhead
    BLOCK_BYTES = 23
    # Misc real-time state
    KTIME_NS = 24
    MEM_PRESSURE = 25        # FIXED_POINT-scaled pool utilization
    FAULT_KIND = 26          # FaultKind enum value
    SEQ_LEN = 27             # current logical length of the owning sequence
    # Tiered-memory state (host-DRAM second pool behind the mm_tier hook)
    TIER_FREE_BLOCKS = 28    # free base blocks in the host-DRAM tier
    TIER_TOTAL_BLOCKS = 29   # capacity of the host-DRAM tier
    TIER_PRESSURE = 30       # FIXED_POINT-scaled host-tier utilization
    PCIE_NS_PER_BLOCK = 31   # modeled ns to move one base block across PCIe
    # Candidate page under a tier decision (mm_tier hook only)
    PAGE_TIER = 32           # current tier of the candidate page (0=HBM, 1..=spill)
    PAGE_ORDER = 33          # order of the candidate page
    PAGE_AGE = 34            # engine ticks since the page last changed tiers
    PAGE_HEAT = 35           # DAMON heat of the page's own span, FIXED_POINT-scaled
    MIGRATE_SETUP_NS = 36    # fixed setup cost of the tier-0<->1 edge (legacy)
    MIGRATE_NS_PER_BLOCK = 37  # per-block cost of the tier-0<->1 edge (legacy)
    # N-pool tier graph (HBM / peer-HBM over ICI / host DRAM / NVMe); the
    # tier id in PAGE_TIER is 0..NTIERS-1, ordered fastest to slowest.
    NTIERS = 38              # live tier count of the topology (0 = untiered ctx)
    # Per-tier pool state (free / total base blocks; unused tiers stay 0)
    TIER_FREE_T0 = 39
    TIER_FREE_T1 = 40
    TIER_FREE_T2 = 41
    TIER_FREE_T3 = 42
    TIER_TOTAL_T0 = 43
    TIER_TOTAL_T1 = 44
    TIER_TOTAL_T2 = 45
    TIER_TOTAL_T3 = 46
    # Cumulative per-edge migration cost tables: entry t is the summed cost of
    # crossing every edge between tier 0 and tier t, so the cost of a
    # (src, dst) path is table[max]-table[min] — the form the
    # bpf_mm_migrate_cost helper evaluates identically on the interpreter,
    # JIT and predicated backends.
    MIG_CUM_SETUP_T0 = 47
    MIG_CUM_SETUP_T1 = 48
    MIG_CUM_SETUP_T2 = 49
    MIG_CUM_SETUP_T3 = 50
    MIG_CUM_NS_T0 = 51
    MIG_CUM_NS_T1 = 52
    MIG_CUM_NS_T2 = 53
    MIG_CUM_NS_T3 = 54
    # Within-batch free-list reservation: base blocks the EARLIER rows of the
    # same fault batch may consume (upper bound: each earlier pending fault
    # takes at most 4^fault_max_order blocks).  Budget-aware programs subtract
    # this from FREE_BLOCKS_* / add it to MEM_PRESSURE reasoning so they see
    # within-batch grants instead of batch-start buddy state.  Always 0 on the
    # scalar path (a scalar fault has no earlier grants to account for).
    BATCH_RESERVED = 55
    # Prefix-cache candidate state (mm_evict hook only).  The candidate entry
    # reuses PAGE_TIER / PAGE_AGE / PAGE_HEAT for its tier, ticks since last
    # hit, and DAMON heat; the columns below carry the cache-specific facts.
    CACHE_REFCOUNT = 56      # sequences currently borrowing the entry (pinned)
    CACHE_HITS = 57          # cumulative admissions served by this entry
    CACHE_BLOCKS = 58        # entry size in base blocks
    # Cache-global state shared by every row of an evict batch
    CACHE_GHOST_HITS = 59    # ghost-list hits (re-requested after eviction)
    CACHE_ENTRIES = 60       # live entries in the cache index
    CACHE_CAP_BLOCKS = 61    # configured HBM budget for cached prefixes
    CACHE_USED_BLOCKS = 62   # HBM blocks currently held by cached prefixes
    # Online-profiler candidate state (mm_profile hook only).  One batch row
    # per live DAMON region of the sampled pid; PID / KTIME_NS / the buddy +
    # tier columns carry the usual system snapshot.
    PROF_REGION_START = 63   # region start, logical blocks
    PROF_REGION_END = 64     # region end (exclusive), logical blocks
    PROF_REGION_HEAT = 65    # region nr_accesses EMA, FIXED_POINT-scaled
    PROF_REGION_AGE = 66     # aggregation windows since the region changed
    PROF_MAPPED_BLOCKS = 67  # blocks currently mapped for the sampled pid
    PROF_WINDOW = 68         # DAMON aggregation window counter (version)
    CTX_LEN = 69             # number of fields; keep last


CTX_LEN = int(CTX.CTX_LEN)


class FaultKind(enum.IntEnum):
    FIRST_TOUCH = 0      # decode crossed into an unmapped logical block
    PREFILL = 1          # bulk mapping at prefill/mmap time
    PROMOTION_SCAN = 2   # khugepaged-style async scan considering a collapse


@dataclass
class FaultContext:
    """Structured view; ``.vector()`` flattens for the VM."""
    addr: int
    pid: int
    vma_start: int
    vma_end: int
    fault_max_order: int
    has_profile: int
    profile_map_id: int
    profile_nregions: int
    free_blocks: tuple[int, int, int, int]
    frag: tuple[int, int, int, int]
    heat: tuple[int, int, int, int]
    zero_ns_per_block: int
    compact_ns_per_block: int
    descriptor_ns: int
    block_bytes: int
    ktime_ns: int = 0
    mem_pressure: int = 0
    fault_kind: int = int(FaultKind.FIRST_TOUCH)
    seq_len: int = 0
    tier_free_blocks: int = 0
    tier_total_blocks: int = 0
    tier_pressure: int = 0
    pcie_ns_per_block: int = 0
    page_tier: int = 0
    page_order: int = 0
    page_age: int = 0
    page_heat: int = 0
    migrate_setup_ns: int = 0
    migrate_ns_per_block: int = 0
    ntiers: int = 0
    tier_free: tuple[int, int, int, int] = (0, 0, 0, 0)
    tier_total: tuple[int, int, int, int] = (0, 0, 0, 0)
    mig_cum_setup: tuple[int, int, int, int] = (0, 0, 0, 0)
    mig_cum_ns: tuple[int, int, int, int] = (0, 0, 0, 0)
    batch_reserved: int = 0
    cache_refcount: int = 0
    cache_hits: int = 0
    cache_blocks: int = 0
    cache_ghost_hits: int = 0
    cache_entries: int = 0
    cache_cap_blocks: int = 0
    cache_used_blocks: int = 0
    prof_region_start: int = 0
    prof_region_end: int = 0
    prof_region_heat: int = 0
    prof_region_age: int = 0
    prof_mapped_blocks: int = 0
    prof_window: int = 0

    def vector(self) -> np.ndarray:
        v = np.zeros(CTX_LEN, dtype=np.int64)
        v[CTX.ADDR] = self.addr
        v[CTX.PID] = self.pid
        v[CTX.VMA_START] = self.vma_start
        v[CTX.VMA_END] = self.vma_end
        v[CTX.FAULT_MAX_ORDER] = self.fault_max_order
        v[CTX.HAS_PROFILE] = self.has_profile
        v[CTX.PROFILE_MAP_ID] = self.profile_map_id
        v[CTX.PROFILE_NREGIONS] = self.profile_nregions
        v[CTX.FREE_BLOCKS_O0:CTX.FREE_BLOCKS_O0 + 4] = self.free_blocks
        v[CTX.FRAG_O0:CTX.FRAG_O0 + 4] = self.frag
        v[CTX.HEAT_O0:CTX.HEAT_O0 + 4] = self.heat
        v[CTX.ZERO_NS_PER_BLOCK] = self.zero_ns_per_block
        v[CTX.COMPACT_NS_PER_BLOCK] = self.compact_ns_per_block
        v[CTX.DESCRIPTOR_NS] = self.descriptor_ns
        v[CTX.BLOCK_BYTES] = self.block_bytes
        v[CTX.KTIME_NS] = self.ktime_ns
        v[CTX.MEM_PRESSURE] = self.mem_pressure
        v[CTX.FAULT_KIND] = self.fault_kind
        v[CTX.SEQ_LEN] = self.seq_len
        v[CTX.TIER_FREE_BLOCKS] = self.tier_free_blocks
        v[CTX.TIER_TOTAL_BLOCKS] = self.tier_total_blocks
        v[CTX.TIER_PRESSURE] = self.tier_pressure
        v[CTX.PCIE_NS_PER_BLOCK] = self.pcie_ns_per_block
        v[CTX.PAGE_TIER] = self.page_tier
        v[CTX.PAGE_ORDER] = self.page_order
        v[CTX.PAGE_AGE] = self.page_age
        v[CTX.PAGE_HEAT] = self.page_heat
        v[CTX.MIGRATE_SETUP_NS] = self.migrate_setup_ns
        v[CTX.MIGRATE_NS_PER_BLOCK] = self.migrate_ns_per_block
        v[CTX.NTIERS] = self.ntiers
        v[CTX.TIER_FREE_T0:CTX.TIER_FREE_T0 + MAX_TIERS] = self.tier_free
        v[CTX.TIER_TOTAL_T0:CTX.TIER_TOTAL_T0 + MAX_TIERS] = self.tier_total
        v[CTX.MIG_CUM_SETUP_T0:CTX.MIG_CUM_SETUP_T0 + MAX_TIERS] = \
            self.mig_cum_setup
        v[CTX.MIG_CUM_NS_T0:CTX.MIG_CUM_NS_T0 + MAX_TIERS] = self.mig_cum_ns
        v[CTX.BATCH_RESERVED] = self.batch_reserved
        v[CTX.CACHE_REFCOUNT] = self.cache_refcount
        v[CTX.CACHE_HITS] = self.cache_hits
        v[CTX.CACHE_BLOCKS] = self.cache_blocks
        v[CTX.CACHE_GHOST_HITS] = self.cache_ghost_hits
        v[CTX.CACHE_ENTRIES] = self.cache_entries
        v[CTX.CACHE_CAP_BLOCKS] = self.cache_cap_blocks
        v[CTX.CACHE_USED_BLOCKS] = self.cache_used_blocks
        v[CTX.PROF_REGION_START] = self.prof_region_start
        v[CTX.PROF_REGION_END] = self.prof_region_end
        v[CTX.PROF_REGION_HEAT] = self.prof_region_heat
        v[CTX.PROF_REGION_AGE] = self.prof_region_age
        v[CTX.PROF_MAPPED_BLOCKS] = self.prof_mapped_blocks
        v[CTX.PROF_WINDOW] = self.prof_window
        return v


# --------------------------------------------------------------------------
# Batched ctx assembly.  A batch built from one snapshot shares all of the
# system-state columns (buddy free lists, fragmentation, cost constants,
# clock, pressure); only the per-fault columns differ per row.  These helpers
# are the column-wise (vectorized) counterpart of FaultContext.vector() and
# are used by both the fault-path and tier-scan batch builders.
# --------------------------------------------------------------------------

def ctx_batch(n: int) -> np.ndarray:
    """A zeroed ``[n, CTX_LEN]`` int64 ctx matrix (one row per fault)."""
    return np.zeros((n, CTX_LEN), dtype=np.int64)


def fill_system_columns(mat: np.ndarray, *,
                        free_blocks, frag,
                        zero_ns_per_block: int, compact_ns_per_block: int,
                        descriptor_ns: int, block_bytes: int,
                        ktime_ns: int, mem_pressure: int,
                        tier_free_blocks: int = 0, tier_total_blocks: int = 0,
                        tier_pressure: int = 0, pcie_ns_per_block: int = 0,
                        migrate_setup_ns: int = 0,
                        migrate_ns_per_block: int = 0,
                        ntiers: int = 0, tier_free=(0, 0, 0, 0),
                        tier_total=(0, 0, 0, 0),
                        mig_cum_setup=(0, 0, 0, 0),
                        mig_cum_ns=(0, 0, 0, 0),
                        cache_ghost_hits: int = 0, cache_entries: int = 0,
                        cache_cap_blocks: int = 0,
                        cache_used_blocks: int = 0) -> np.ndarray:
    """Broadcast one system-state snapshot into every row of ``mat``.

    ``free_blocks``/``frag`` may be shorter than ``NUM_ORDERS`` when the
    allocator runs with a reduced ``max_order``; the tail columns stay 0.
    """
    fb = np.asarray(free_blocks, dtype=np.int64)
    fr = np.asarray(frag, dtype=np.int64)
    mat[:, CTX.FREE_BLOCKS_O0:CTX.FREE_BLOCKS_O0 + fb.size] = fb
    mat[:, CTX.FRAG_O0:CTX.FRAG_O0 + fr.size] = fr
    mat[:, CTX.ZERO_NS_PER_BLOCK] = zero_ns_per_block
    mat[:, CTX.COMPACT_NS_PER_BLOCK] = compact_ns_per_block
    mat[:, CTX.DESCRIPTOR_NS] = descriptor_ns
    mat[:, CTX.BLOCK_BYTES] = block_bytes
    mat[:, CTX.KTIME_NS] = ktime_ns
    mat[:, CTX.MEM_PRESSURE] = mem_pressure
    mat[:, CTX.TIER_FREE_BLOCKS] = tier_free_blocks
    mat[:, CTX.TIER_TOTAL_BLOCKS] = tier_total_blocks
    mat[:, CTX.TIER_PRESSURE] = tier_pressure
    mat[:, CTX.PCIE_NS_PER_BLOCK] = pcie_ns_per_block
    mat[:, CTX.MIGRATE_SETUP_NS] = migrate_setup_ns
    mat[:, CTX.MIGRATE_NS_PER_BLOCK] = migrate_ns_per_block
    mat[:, CTX.NTIERS] = ntiers
    mat[:, CTX.TIER_FREE_T0:CTX.TIER_FREE_T0 + MAX_TIERS] = \
        np.asarray(tier_free, dtype=np.int64)
    mat[:, CTX.TIER_TOTAL_T0:CTX.TIER_TOTAL_T0 + MAX_TIERS] = \
        np.asarray(tier_total, dtype=np.int64)
    mat[:, CTX.MIG_CUM_SETUP_T0:CTX.MIG_CUM_SETUP_T0 + MAX_TIERS] = \
        np.asarray(mig_cum_setup, dtype=np.int64)
    mat[:, CTX.MIG_CUM_NS_T0:CTX.MIG_CUM_NS_T0 + MAX_TIERS] = \
        np.asarray(mig_cum_ns, dtype=np.int64)
    mat[:, CTX.CACHE_GHOST_HITS] = cache_ghost_hits
    mat[:, CTX.CACHE_ENTRIES] = cache_entries
    mat[:, CTX.CACHE_CAP_BLOCKS] = cache_cap_blocks
    mat[:, CTX.CACHE_USED_BLOCKS] = cache_used_blocks
    return mat


# Return-value convention for fault-hook programs.
POLICY_FALLBACK = -1     # defer to the kernel default policy

# Sentinel the BATCHED discipline pass writes into decision rows AFTER a
# mid-batch supervisor detach: the row takes the kernel-default path with NO
# fallback accounting, matching the scalar route where post-detach faults
# never reach the (now-detached) hook at all.  Never a valid program return.
POLICY_DETACHED = -2

# Return-value convention for tier-hook (mm_tier) programs: the return value
# is the TARGET TIER id the candidate page should live in (0 = local HBM,
# 1..NTIERS-1 = spill tiers ordered fastest to slowest; the manager clamps to
# the live topology and migrates hop by hop).  FALLBACK defers to the
# kernel-default tiering policy.  TIER_KEEP / TIER_DEMOTE are the two-pool
# names for targets 0 and 1 — in a 2-tier topology they mean exactly what
# they did before the N-pool generalization (live in HBM / live in host).
TIER_KEEP = 0
TIER_DEMOTE = 1

# Return-value convention for evict-hook (mm_evict) programs: the return value
# is the TARGET TIER the cached prefix entry should live in (its current tier
# = keep where it is; a slower tier = demote hop-by-hop through the chain) or
# EVICT_DROP to free the entry's blocks outright.  EVICT_DROP deliberately
# equals MAX_TIERS: any tier id the topology can't hold already clamps to the
# slowest live tier downstream, so a drop sentinel one past the last tier is
# the natural "past the end of the chain" encoding and is always a VALID
# program return (the supervisor only strikes sub-FALLBACK sentinels).
EVICT_DROP = MAX_TIERS

# Return-value convention for profiler (mm_profile) programs: the return
# value is the region's HOT SCORE (>= 0, FIXED_POINT-scaled) — 0 marks the
# region cold; the ProfileSynthesizer folds positive scores (plus whatever
# the program emitted through bpf_ringbuf_output) into the online profile.
# FALLBACK defers the region to host-side synthesis from raw DAMON heat.
PROFILE_COLD = 0
