"""Promotion cost/benefit model + target-hardware constants.

The paper: "we assume that the primary contributors to this cost are the time
required to prepare a huge page (zeroing) and the time needed to locate an
available one (compaction).  We empirically calculate a fixed cost for both."

TPU adaptation: the pool lives in HBM and is framework-managed, so "zeroing"
is an HBM-bandwidth-bound memset of the page, and "compaction" is block
migration (read+write over HBM) directed by the buddy allocator.  The
*benefit* side replaces TLB-miss reduction with DMA-descriptor / page-table
indirection reduction inside the paged-attention kernel: a page of order k
covers 4^k base blocks with ONE descriptor, and larger contiguous reads get
closer to peak HBM bandwidth (small-transfer overhead amortizes).
"""

from __future__ import annotations

from dataclasses import dataclass, field

from .context import NUM_ORDERS


@dataclass(frozen=True)
class HWSpec:
    """TPU v5e-class target constants (also used by the roofline analysis)."""
    name: str = "tpu-v5e"
    peak_flops_bf16: float = 197e12          # per chip
    hbm_bw: float = 819e9                    # bytes/s
    ici_bw_per_link: float = 50e9            # bytes/s/link
    # Host<->device interconnect (PCIe Gen4 x16 class): the bandwidth the
    # host-DRAM KV tier is demoted to / promoted from.
    pcie_bw: float = 32e9                    # bytes/s
    # Fixed per-migration setup cost (DMA programming, sync) per tier crossing.
    pcie_setup_ns: float = 2_000.0
    # Per-DMA-descriptor fixed overhead for a paged KV read. Order-of-magnitude
    # of a small async copy issue + bookkeeping. Empirically calibrated on the
    # kernel microbench; exposed so profiles can be recalibrated per platform.
    descriptor_ns: float = 800.0
    # Effective bandwidth derate for small contiguous reads: a transfer of B
    # bytes achieves hbm_bw * B / (B + small_read_crossover_bytes).
    small_read_crossover_bytes: float = 64 * 1024.0
    # Fixed per-page setup cost besides the memset (table update, sync).
    page_setup_ns: float = 300.0

    def effective_bw(self, transfer_bytes: float) -> float:
        b = float(transfer_bytes)
        return self.hbm_bw * b / (b + self.small_read_crossover_bytes)


@dataclass
class CostModel:
    """Calibrated promotion cost + access benefit, all in modeled ns."""
    hw: HWSpec
    block_bytes: int                 # bytes of one base block (KV slab)
    block_tokens: int = 16

    # ---- cost side (paper: zeroing + compaction) -------------------------
    def zero_ns_per_block(self) -> int:
        memset = self.block_bytes / self.hw.hbm_bw * 1e9
        return int(memset + self.hw.page_setup_ns / 4)  # setup amortized

    def compact_ns_per_block(self) -> int:
        # migration = read + write of one block over HBM
        return int(2 * self.block_bytes / self.hw.hbm_bw * 1e9)

    # ---- tiering side (HBM <-> host DRAM over PCIe) -----------------------
    def pcie_ns_per_block(self) -> int:
        """Modeled ns to move one base block across the host interconnect."""
        return int(self.block_bytes / self.hw.pcie_bw * 1e9)

    def migrate_ns_per_block(self) -> int:
        """Per-block cost of a tier crossing: PCIe transfer + the HBM-side
        read-or-write.  Exposed to tier programs via ctx so the
        bpf_mm_migrate_cost helper charges exactly what the engine accounts."""
        hbm_side = self.block_bytes / self.hw.hbm_bw * 1e9
        return int(self.pcie_ns_per_block() + hbm_side)

    def migrate_ns(self, order: int) -> int:
        """One tier crossing of an order-k page: per-block transfer cost plus
        the fixed DMA setup cost."""
        return int(self.hw.pcie_setup_ns
                   + (4 ** order) * self.migrate_ns_per_block())

    def tier_access_ns(self, order: int) -> float:
        """Modeled ns to stream one order-k page that is resident in the host
        tier through the attention kernel (PCIe-bound, not HBM-bound)."""
        page_bytes = self.block_bytes * (4 ** order)
        return self.hw.descriptor_ns + page_bytes / self.hw.pcie_bw * 1e9

    def promotion_cost_ns(self, order: int, free_blocks: int, frag_milli: int) -> int:
        nblocks = 4 ** order
        cost = self.zero_ns_per_block() * nblocks
        if free_blocks <= 0:
            cost += self.compact_ns_per_block() * nblocks * (1000 + frag_milli) // 1000
        return int(cost)

    # ---- benefit side (TLB-reach analogue) --------------------------------
    def access_ns(self, order: int) -> float:
        """Modeled ns to stream one order-k page through the attention kernel."""
        page_bytes = self.block_bytes * (4 ** order)
        return self.hw.descriptor_ns + page_bytes / self.hw.effective_bw(page_bytes) * 1e9

    def access_benefit_ns(self, order: int, heat: float = 1.0) -> int:
        """ns saved per aggregation window if the region is backed at
        ``order`` instead of order 0, given ``heat`` accesses per window."""
        if order == 0:
            return 0
        per_page_o0 = self.access_ns(0) * (4 ** order)   # 4^k descriptors
        per_page_ok = self.access_ns(order)              # 1 descriptor
        return int(max(0.0, heat * (per_page_o0 - per_page_ok)))

    def descriptor_count(self, orders: list[int]) -> int:
        """Page-table entries touched to read a mapping = TLB-miss analogue."""
        return len(orders)


def make_cost_model(hw: HWSpec, kv_heads: int, head_dim: int, *,
                    block_tokens: int = 16, dtype_bytes: int = 2,
                    layers_fused: int = 1) -> CostModel:
    """Cost model for a KV pool slab: K+V for ``layers_fused`` layers."""
    block_bytes = block_tokens * kv_heads * head_dim * 2 * dtype_bytes * layers_fused
    return CostModel(hw=hw, block_bytes=block_bytes, block_tokens=block_tokens)
