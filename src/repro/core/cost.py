"""Promotion cost/benefit model + target-hardware constants.

The paper: "we assume that the primary contributors to this cost are the time
required to prepare a huge page (zeroing) and the time needed to locate an
available one (compaction).  We empirically calculate a fixed cost for both."

TPU adaptation: the pool lives in HBM and is framework-managed, so "zeroing"
is an HBM-bandwidth-bound memset of the page, and "compaction" is block
migration (read+write over HBM) directed by the buddy allocator.  The
*benefit* side replaces TLB-miss reduction with DMA-descriptor / page-table
indirection reduction inside the paged-attention kernel: a page of order k
covers 4^k base blocks with ONE descriptor, and larger contiguous reads get
closer to peak HBM bandwidth (small-transfer overhead amortizes).
"""

from __future__ import annotations

from dataclasses import dataclass, field

from .context import MAX_TIERS, NUM_ORDERS


@dataclass(frozen=True)
class HWSpec:
    """TPU v5e-class target constants (also used by the roofline analysis)."""
    name: str = "tpu-v5e"
    peak_flops_bf16: float = 197e12          # per chip
    hbm_bw: float = 819e9                    # bytes/s
    ici_bw_per_link: float = 50e9            # bytes/s/link
    # Fixed per-crossing setup cost of an ICI hop (peer-HBM tier edge).
    ici_setup_ns: float = 1_000.0
    # Host<->device interconnect (PCIe Gen4 x16 class): the bandwidth the
    # host-DRAM KV tier is demoted to / promoted from.
    pcie_bw: float = 32e9                    # bytes/s
    # Fixed per-migration setup cost (DMA programming, sync) per tier crossing.
    pcie_setup_ns: float = 2_000.0
    # NVMe tier (Gen4 x4 class): streaming bandwidth and per-IO setup cost of
    # the host-DRAM <-> NVMe edge.
    nvme_bw: float = 7e9                     # bytes/s
    nvme_setup_ns: float = 10_000.0
    # Per-DMA-descriptor fixed overhead for a paged KV read. Order-of-magnitude
    # of a small async copy issue + bookkeeping. Empirically calibrated on the
    # kernel microbench; exposed so profiles can be recalibrated per platform.
    descriptor_ns: float = 800.0
    # Effective bandwidth derate for small contiguous reads: a transfer of B
    # bytes achieves hbm_bw * B / (B + small_read_crossover_bytes).
    small_read_crossover_bytes: float = 64 * 1024.0
    # Fixed per-page setup cost besides the memset (table update, sync).
    page_setup_ns: float = 300.0

    def effective_bw(self, transfer_bytes: float) -> float:
        b = float(transfer_bytes)
        return self.hbm_bw * b / (b + self.small_read_crossover_bytes)


@dataclass(frozen=True)
class TierSpec:
    """One spill tier of the N-pool topology (tier 0 = local HBM is implicit).

    ``link_bw``/``link_setup_ns`` describe the EDGE connecting this tier to
    the next-faster one (the per-edge bandwidth table the migrate-cost helper
    charges); ``read_bw`` is the bandwidth the attention kernel streams at
    when KV resides here (defaults to the link bandwidth)."""
    name: str
    blocks: int                      # pool capacity in base blocks
    link_bw: float                   # bytes/s across the edge to tier-1 side
    link_setup_ns: float             # fixed per-crossing setup of that edge
    read_bw: float | None = None

    @property
    def stream_bw(self) -> float:
        return self.read_bw if self.read_bw is not None else self.link_bw


def peer_hbm_tier(hw: HWSpec, blocks: int) -> TierSpec:
    """Peer-device HBM reached over ICI."""
    return TierSpec("peer-hbm", blocks, link_bw=hw.ici_bw_per_link,
                    link_setup_ns=hw.ici_setup_ns,
                    read_bw=hw.ici_bw_per_link)


def host_dram_tier(hw: HWSpec, blocks: int) -> TierSpec:
    """Pinned host DRAM reached over PCIe (the original 2-pool spill tier)."""
    return TierSpec("host-dram", blocks, link_bw=hw.pcie_bw,
                    link_setup_ns=hw.pcie_setup_ns, read_bw=hw.pcie_bw)


def nvme_tier(hw: HWSpec, blocks: int) -> TierSpec:
    """NVMe-backed tier behind host DRAM."""
    return TierSpec("nvme", blocks, link_bw=hw.nvme_bw,
                    link_setup_ns=hw.nvme_setup_ns, read_bw=hw.nvme_bw)


def default_tier_chain(hw: HWSpec, tier_blocks) -> tuple[TierSpec, ...]:
    """Spill tiers for a chain of 1..3 capacities: (peer-HBM[, host-DRAM
    [, NVMe]]) for 3+ pools, plain (host-DRAM) for the classic 2-pool case."""
    blocks = [int(b) for b in tier_blocks]
    if not 1 <= len(blocks) <= MAX_TIERS - 1:
        raise ValueError(f"tier chain needs 1..{MAX_TIERS - 1} spill tiers")
    if len(blocks) == 1:
        makers = [host_dram_tier]
    else:
        makers = [peer_hbm_tier, host_dram_tier, nvme_tier][:len(blocks)]
    return tuple(mk(hw, b) for mk, b in zip(makers, blocks))


@dataclass
class CostModel:
    """Calibrated promotion cost + access benefit, all in modeled ns."""
    hw: HWSpec
    block_bytes: int                 # bytes of one base block (KV slab)
    block_tokens: int = 16
    # Spill-tier topology (tier ids 1..len(topology)); None = the classic
    # single host-DRAM tier over PCIe, capacity supplied by the manager.
    topology: tuple[TierSpec, ...] | None = None
    # (key, cum_setup, cum_ns) memo for migrate_cum_tables — the tables sit
    # on the migration hot path and in every tier ctx build.
    _cum_cache: tuple | None = field(default=None, repr=False, compare=False)

    # ---- cost side (paper: zeroing + compaction) -------------------------
    def zero_ns_per_block(self) -> int:
        memset = self.block_bytes / self.hw.hbm_bw * 1e9
        return int(memset + self.hw.page_setup_ns / 4)  # setup amortized

    def compact_ns_per_block(self) -> int:
        # migration = read + write of one block over HBM
        return int(2 * self.block_bytes / self.hw.hbm_bw * 1e9)

    # ---- tiering side (per-edge cost table over the N-pool tier graph) ----
    @property
    def tier_specs(self) -> tuple[TierSpec, ...]:
        """Spill tiers 1..N-1 of the live topology (default: host DRAM)."""
        if self.topology:
            return self.topology
        return (host_dram_tier(self.hw, 0),)

    @property
    def ntiers(self) -> int:
        return 1 + len(self.tier_specs)

    def pcie_ns_per_block(self) -> int:
        """Modeled ns to move one base block across the host interconnect."""
        return int(self.block_bytes / self.hw.pcie_bw * 1e9)

    def _edges(self) -> list[tuple[int, int]]:
        """(setup_ns, ns_per_block) for every adjacent tier edge; edge ``i``
        connects tier ``i`` to tier ``i+1``.  Per-block edge cost is the link
        transfer plus the faster side's read-or-write touch."""
        edges = []
        faster_bw = self.hw.hbm_bw
        for spec in self.tier_specs:
            per_block = (self.block_bytes / spec.link_bw
                         + self.block_bytes / faster_bw) * 1e9
            edges.append((int(spec.link_setup_ns), int(per_block)))
            faster_bw = spec.stream_bw
        return edges

    def edge_names(self) -> tuple[str, ...]:
        """Human-readable label per tier edge (``"hbm->host_dram"``); edge
        ``i`` connects tier ``i`` to tier ``i+1``.  The health monitor and
        resilience metrics key their per-edge state on these."""
        names = ["hbm"] + [s.name for s in self.tier_specs]
        return tuple(f"{a}->{b}" for a, b in zip(names[:-1], names[1:]))

    def migrate_cum_tables(self) -> tuple[tuple[int, ...], tuple[int, ...]]:
        """Cumulative edge-cost tables padded to MAX_TIERS: entry ``t`` is
        the summed (setup, per-block) cost of every edge between tier 0 and
        tier ``t``.  The form the ctx exposes to bpf_mm_migrate_cost.
        Memoized — every migration hop and tier ctx build reads them."""
        key = (self.topology, self.block_bytes, self.hw)
        if self._cum_cache is not None and self._cum_cache[0] == key:
            return self._cum_cache[1], self._cum_cache[2]
        setup, per = [0], [0]
        for s, p in self._edges():
            setup.append(setup[-1] + s)
            per.append(per[-1] + p)
        while len(setup) < MAX_TIERS:      # pad: unreachable tiers add 0 cost
            setup.append(setup[-1])
            per.append(per[-1])
        out = tuple(setup[:MAX_TIERS]), tuple(per[:MAX_TIERS])
        self._cum_cache = (key, out[0], out[1])
        return out

    def migrate_setup_ns(self, src: int = 0, dst: int = 1) -> int:
        """Summed fixed setup cost of every edge on the src->dst path."""
        cum, _ = self.migrate_cum_tables()
        lo, hi = sorted((max(0, src), max(0, dst)))
        return cum[min(hi, MAX_TIERS - 1)] - cum[min(lo, MAX_TIERS - 1)]

    def migrate_ns_per_block(self, src: int = 0, dst: int = 1) -> int:
        """Per-block cost of a src->dst tier crossing: summed per-edge link
        transfers + faster-side touches along the path.  Exposed to tier
        programs via the cumulative ctx tables so the bpf_mm_migrate_cost
        helper charges exactly what the engine accounts."""
        _, cum = self.migrate_cum_tables()
        lo, hi = sorted((max(0, src), max(0, dst)))
        return cum[min(hi, MAX_TIERS - 1)] - cum[min(lo, MAX_TIERS - 1)]

    def migrate_ns(self, order: int, src: int = 0, dst: int = 1) -> int:
        """One src->dst crossing of an order-k page: per-block path cost plus
        the fixed per-edge setup costs."""
        return int(self.migrate_setup_ns(src, dst)
                   + (4 ** order) * self.migrate_ns_per_block(src, dst))

    def tier_access_ns(self, order: int, tier: int = 1) -> float:
        """Modeled ns to stream one order-k page resident in ``tier`` through
        the attention kernel (link-bound, not HBM-bound)."""
        if tier <= 0:
            return self.access_ns(order)
        specs = self.tier_specs
        spec = specs[min(tier, len(specs)) - 1]
        page_bytes = self.block_bytes * (4 ** order)
        return self.hw.descriptor_ns + page_bytes / spec.stream_bw * 1e9

    def promotion_cost_ns(self, order: int, free_blocks: int, frag_milli: int) -> int:
        nblocks = 4 ** order
        cost = self.zero_ns_per_block() * nblocks
        if free_blocks <= 0:
            cost += self.compact_ns_per_block() * nblocks * (1000 + frag_milli) // 1000
        return int(cost)

    # ---- benefit side (TLB-reach analogue) --------------------------------
    def access_ns(self, order: int) -> float:
        """Modeled ns to stream one order-k page through the attention kernel."""
        page_bytes = self.block_bytes * (4 ** order)
        return self.hw.descriptor_ns + page_bytes / self.hw.effective_bw(page_bytes) * 1e9

    def access_benefit_ns(self, order: int, heat: float = 1.0) -> int:
        """ns saved per aggregation window if the region is backed at
        ``order`` instead of order 0, given ``heat`` accesses per window."""
        if order == 0:
            return 0
        per_page_o0 = self.access_ns(0) * (4 ** order)   # 4^k descriptors
        per_page_ok = self.access_ns(order)              # 1 descriptor
        return int(max(0.0, heat * (per_page_o0 - per_page_ok)))

    def descriptor_count(self, orders: list[int]) -> int:
        """Page-table entries touched to read a mapping = TLB-miss analogue."""
        return len(orders)


def make_cost_model(hw: HWSpec, kv_heads: int, head_dim: int, *,
                    block_tokens: int = 16, dtype_bytes: int = 2,
                    layers_fused: int = 1) -> CostModel:
    """Cost model for a KV pool slab: K+V for ``layers_fused`` layers."""
    block_bytes = block_tokens * kv_heads * head_dim * 2 * dtype_bytes * layers_fused
    return CostModel(hw=hw, block_bytes=block_bytes, block_tokens=block_tokens)
