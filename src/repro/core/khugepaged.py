"""Background promoter — the khugepaged analogue (paper's "future work",
implemented here as a beyond-paper feature).

Periodically scans processes' DAMON state for hot regions currently backed by
small pages and collapses them into larger pages when the cost model says the
migration pays for itself.  Runs synchronously from the engine loop
(``tick()``) so behaviour is deterministic and testable; the serving engine
calls it between decode steps, which is exactly where an async kernel thread
would get cycles on a real deployment.
"""

from __future__ import annotations

from dataclasses import dataclass

from .buddy import order_blocks
from .context import NUM_ORDERS
from .mm import MemoryManager


@dataclass
class KhugepagedConfig:
    scan_processes_per_tick: int = 4
    pages_per_scan: int = 8          # collapse budget per tick (throttled, like Linux)
    min_net_benefit_ns: int = 0      # require benefit - cost > this
    target_order: int = 2            # PMD-analogue default target
    heat_horizon: float = 16.0       # windows over which benefit amortizes


class Khugepaged:
    def __init__(self, mm: MemoryManager, cfg: KhugepagedConfig | None = None) -> None:
        self.mm = mm
        self.cfg = cfg or KhugepagedConfig()
        self._cursor = 0
        self.collapsed = 0
        self.considered = 0

    def tick(self) -> int:
        """One scan pass; returns number of collapses performed."""
        cfg = self.cfg
        pids = sorted(self.mm.procs)
        if not pids:
            return 0
        done = 0
        nscan = min(cfg.scan_processes_per_tick, len(pids))
        for i in range(nscan):
            pid = pids[(self._cursor + i) % len(pids)]
            done += self._scan_process(pid, cfg.pages_per_scan - done)
            if done >= cfg.pages_per_scan:
                break
        self._cursor = (self._cursor + nscan) % max(1, len(pids))
        return done

    def _scan_process(self, pid: int, budget: int) -> int:
        if budget <= 0:
            return 0
        mm, cfg = self.mm, self.cfg
        st = mm.procs[pid]
        k = min(cfg.target_order, NUM_ORDERS - 1)
        size = order_blocks(k)
        done = 0
        # candidate windows: aligned order-k ranges fully mapped at lower orders
        windows = sorted({(m.logical_start // size) * size
                          for m in st.page_table.values()
                          if m.order < k and m.tier == 0})
        bstats = mm.buddy.stats()
        for a in windows:
            if done >= budget:
                break
            if a + size > st.vma_end:
                continue
            inside = [m for m in st.page_table.values()
                      if a <= m.logical_start < a + size]
            if not inside or any(m.order >= k for m in inside):
                continue
            self.considered += 1
            heat = st.damon.heat_at(a, k)
            benefit = mm.cost.access_benefit_ns(k, heat * cfg.heat_horizon)
            free_k = bstats.free_per_order[k]
            cost = mm.cost.promotion_cost_ns(k, free_k, bstats.frag_index_milli[k])
            # migration adds copy cost on top of the paper's zero+compact terms
            copied = sum(order_blocks(m.order) for m in inside)
            cost += mm.cost.compact_ns_per_block() * copied
            if benefit - cost > cfg.min_net_benefit_ns:
                if mm.collapse(pid, a, k) is not None:
                    done += 1
                    self.collapsed += 1
                    bstats = mm.buddy.stats()
        return done
