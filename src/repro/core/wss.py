"""Online profile synthesis: the host side of the mm_profile plane.

The source paper builds its region/benefit profiles OFFLINE with DAMON and
loads them before the run.  The profiling plane closes that loop: a verified
profiler program (``core.programs.profile_wss_program`` and friends) runs on
the live DAMON region stream via the sampled ``HOOK_PROFILE`` surface, and
the :class:`ProfileSynthesizer` here folds its per-region observations into
profiles in the existing :mod:`repro.core.profiles` format, hot-reloading
them into the attached fault/tier/evict policies mid-run — a run started
with NO profile converges to the placement an offline profiling run would
have produced.

Division of labor (mirrors the kernel/userspace split):
  * the PROGRAM classifies — per-region hot score through the batched,
    parity-pinned executors, observations out through bpf_ringbuf_output;
  * the SYNTHESIZER aggregates — merges region scans across the app's
    processes, runs the same hot-run/benefit arithmetic as the offline
    :func:`repro.core.profiles.profile_from_heat`, and writes the result
    through ``mm.load_profile`` (a map WRITE, so attached programs keep
    their verified map ids).

Attribution: every reload emits ``EV_PROFILE`` and every scan emits
``EV_WSS`` on the modeled clock, the WSS curve is kept per process for
plotting, and :meth:`snapshot` exposes per-region gauges for the
Prometheus export.
"""

from __future__ import annotations

import json

import numpy as np

from ..obs.ringbuf import EV_PROFILE, EV_WSS
from .context import FIXED_POINT, NUM_ORDERS
from .profiles import MAX_PROFILE_REGIONS, Profile, ProfileRegion

# History cap per process for the WSS curve (one sample per profiler tick).
WSS_CURVE_CAP = 4096


class ProfileSynthesizer:
    """Drains ``HOOK_PROFILE`` scans into per-app profiles and hot-reloads.

    ``period`` rate-limits synthesis to every N-th :meth:`tick` call (the
    engine ticks once per step); ``max_regions`` caps synthesized profiles
    (keep it at the bound the attached fault program was verified with);
    ``hot_quantile`` / ``min_region_blocks`` are the thresholds the offline
    ``profile_from_heat`` uses, applied only to scan rows whose program
    score was POLICY_FALLBACK (the program's own hot/cold verdict wins
    otherwise).
    """

    def __init__(self, mm, hw, *, period: int = 4,
                 max_regions: int = MAX_PROFILE_REGIONS,
                 hot_quantile: float = 0.7, min_region_blocks: int = 4,
                 telemetry=None) -> None:
        self.mm = mm
        self.hw = hw
        self.period = max(1, int(period))
        self.max_regions = min(int(max_regions), MAX_PROFILE_REGIONS)
        self.hot_quantile = float(hot_quantile)
        self.min_region_blocks = int(min_region_blocks)
        self.telemetry = telemetry
        self.scans = 0                 # profile_scan calls that ran a program
        self.reloads = 0               # profiles hot-reloaded into the maps
        self.versions: dict[str, int] = {}     # app -> reload generation
        self.profiles: dict[str, Profile] = {}  # app -> last synthesized
        self.wss_blocks: dict[int, int] = {}    # pid -> latest WSS estimate
        self.wss_curve: dict[int, list[tuple[int, int, int]]] = {}
        self._ticks = 0

    # --------------------------------------------------------------- scanning
    def tick(self, active: list[tuple[int, str]]) -> list[str]:
        """One engine tick.  Every ``period`` ticks, runs the profiler scan
        over each active ``(pid, app)``, synthesizes per-app profiles from
        the merged region observations, and hot-reloads any profile whose
        regions changed.  Returns the list of apps reloaded this tick."""
        self._ticks += 1
        if self._ticks % self.period:
            return []
        per_app: dict[str, list[tuple[int, list[tuple]]]] = {}
        for pid, app in active:
            rows = self.mm.profile_scan(pid)
            if rows is None:           # program detached / never attached
                return []
            self.scans += 1
            self._note_wss(pid, rows)
            if app is not None:
                per_app.setdefault(app, []).append((pid, rows))
        reloaded = []
        for app, scans in per_app.items():
            prof = self._synthesize(app, scans)
            if prof is None:
                continue
            prev = self.profiles.get(app)
            if prev is not None and prev.regions == prof.regions:
                continue               # converged: nothing to reload
            self.mm.load_profile(prof)
            self.profiles[app] = prof
            self.versions[app] = self.versions.get(app, 0) + 1
            self.reloads += 1
            reloaded.append(app)
            tel = self.telemetry
            if tel is not None and tel.enabled:
                tel.emit(EV_PROFILE, scans[0][0], len(prof.regions),
                         self.versions[app], ts=self.mm.ktime_ns)
                tel.inc("profile_reloads")
        return reloaded

    def _note_wss(self, pid: int, rows: list[tuple]) -> None:
        """Fold one scan into the per-process WSS curve: a region counts
        toward the working set when the program scored it hot (score > 0),
        or — for FALLBACK rows — when it saw any access this window."""
        wss = 0
        for start, end, heat_milli, _age, score in rows:
            hot = score > 0 if score >= 0 else heat_milli > 0
            if hot:
                wss += end - start
        mapped = len(self.mm.procs[pid].mapped) if pid in self.mm.procs else 0
        self.wss_blocks[pid] = wss
        curve = self.wss_curve.setdefault(pid, [])
        if len(curve) < WSS_CURVE_CAP:
            curve.append((self.mm.ktime_ns, wss, mapped))
        tel = self.telemetry
        if tel is not None and tel.enabled:
            tel.emit(EV_WSS, pid, wss, mapped, ts=self.mm.ktime_ns)
            tel.inc("profile_scans")

    # -------------------------------------------------------------- synthesis
    def _synthesize(self, app: str, scans: list[tuple[int, list[tuple]]]
                    ) -> Profile | None:
        """Merge region scans from every process of ``app`` into one dense
        per-block view and run the offline synthesis arithmetic over it.

        Merging takes the elementwise MAX across processes — the profile
        must serve the hottest use of each block any instance of the app
        exhibits (same convention as merging offline traces).  The program's
        per-region verdict drives the hot mask; rows it deferred
        (POLICY_FALLBACK) fall back to the ``hot_quantile`` threshold over
        raw heat, exactly like ``profile_from_heat``.
        """
        space = max((max(r[1] for r in rows)
                     for _pid, rows in scans if rows), default=0)
        if space == 0:
            return None
        heat = np.zeros(space, dtype=np.float64)
        verdict = np.full(space, -1, dtype=np.int64)   # -1 = program deferred
        for _pid, rows in scans:
            for start, end, heat_milli, _age, score in rows:
                end = min(end, space)
                if end <= start:
                    continue
                h = heat_milli / FIXED_POINT
                np.maximum(heat[start:end], h, out=heat[start:end])
                if score >= 0:
                    np.maximum(verdict[start:end], int(score > 0),
                               out=verdict[start:end])
        if (heat > 0).any():
            thresh = max(float(np.quantile(heat[heat > 0],
                                           self.hot_quantile)), 1e-12)
        else:
            thresh = np.inf
        hot = np.where(verdict >= 0, verdict > 0, heat >= thresh)
        regions: list[ProfileRegion] = []
        i = 0
        while i < space:
            if not hot[i]:
                i += 1
                continue
            j = i
            while j < space and hot[j]:
                j += 1
            if j - i >= self.min_region_blocks:
                mean_heat = float(heat[i:j].mean())
                benefit = tuple(
                    self.hw.access_benefit_ns(order, mean_heat)
                    if (4 ** order) <= (j - i) else 0
                    for order in range(NUM_ORDERS))
                regions.append(ProfileRegion(i, j, benefit))
            i = j
        return Profile(app, regions[:self.max_regions])

    # --------------------------------------------------------------- exports
    def snapshot(self) -> dict:
        """Numeric gauges for ``engine.metrics()`` / the Prometheus export:
        global scan/reload counters plus, per app, the reload generation and
        per-region start/end/benefit gauges (the attribution surface — each
        promotion the fault program makes traces back to exactly one of
        these regions)."""
        apps = {}
        for app, prof in self.profiles.items():
            apps[app] = {
                "version": self.versions.get(app, 0),
                "regions": len(prof.regions),
                "region_start": [r.start for r in prof.regions],
                "region_end": [r.end for r in prof.regions],
                "region_benefit_top": [int(max(r.benefit))
                                       for r in prof.regions],
            }
        return {
            "scans": self.scans,
            "reloads": self.reloads,
            "wss_blocks": {str(pid): int(w)
                           for pid, w in sorted(self.wss_blocks.items())},
            "apps": apps,
        }

    def wss_curve_doc(self) -> dict:
        """The WSS curve per process as a JSON-ready document — samples are
        ``(modeled ktime ns, WSS blocks, mapped blocks)`` per profiler
        tick; plot WSS/mapped over time to read convergence."""
        return {str(pid): [[int(t), int(w), int(m)] for t, w, m in curve]
                for pid, curve in sorted(self.wss_curve.items())}

    def write_wss_curve(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.wss_curve_doc(), f, indent=1)
