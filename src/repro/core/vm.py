"""Host-side interpreter for verified policy programs.

Page-fault handling in the framework happens on the host (the serving
scheduler decides block allocation before dispatching a device step), so the
common path runs here.  The batched/vectorized jnp paths live in
:mod:`repro.core.jit` and :mod:`repro.core.predicate`; since the unified
pipeline, all three executors consume the SAME lowered IR from
:mod:`repro.core.lower` (one verifier pass, absolute branch targets,
resolved map slots) instead of re-deriving it from the raw instruction
stream each.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable

import numpy as np

from ..obs.ringbuf import EV_PROG_TRACE
from .context import CTX_LEN
from .isa import (ALU_IMM_OPS, ALU_REG_OPS, COND_JUMP_IMM, COND_JUMP_REG,
                  NUM_REGS, Op, Program, _wrap64)
from .maps import MapRegistry

# ---------------------------------------------------------------------------
# Helper (bpf_* analogue) registry
# ---------------------------------------------------------------------------

# helper signature: fn(regs: list[int], ctx: np.ndarray, state: HelperState) -> int
HELPER_KTIME = 1
HELPER_TRACE = 2
HELPER_PROMOTION_COST = 3
HELPER_MIGRATE_COST = 4
HELPER_RINGBUF_OUTPUT = 5

# Helpers that emit into the per-invocation event-slot buffer.  Every
# executor gives them the same semantics: write one (ts, tag, a0, a1, a2)
# record and return 0, or return -1 and bump the lane drop counter once the
# invocation's verifier-derived slot budget (facts["rb_cap"]) is spent.
RB_HELPERS = frozenset({HELPER_TRACE, HELPER_RINGBUF_OUTPUT})


@dataclass
class HelperState:
    """Mutable state helpers may touch (event-slot buffer, clock)."""
    ktime_ns: int = 0
    rb_cap: int = 0                                 # per-invocation slot budget
    rb_events: list = field(default_factory=list)   # this run's records
    rb_drops: int = 0                               # lifetime slot-overflow drops


def _helper_ktime(regs, ctx, state: HelperState) -> int:
    return state.ktime_ns


def _rb_emit(ctx, state: HelperState, tag: int, a0: int, a1: int,
             a2: int) -> int:
    if len(state.rb_events) >= state.rb_cap:
        state.rb_drops += 1
        return -1
    from .context import CTX  # local import to avoid cycle at module load
    state.rb_events.append((int(ctx[CTX.KTIME_NS]), tag, a0, a1, a2))
    return 0


def _helper_trace(regs, ctx, state: HelperState) -> int:
    """bpf_trace(r1) — legacy single-word trace, now a ring-buffer record
    with tag EV_PROG_TRACE (it used to vanish on the compiled executors)."""
    return _rb_emit(ctx, state, EV_PROG_TRACE, int(regs[1]), 0, 0)


def _helper_ringbuf_output(regs, ctx, state: HelperState) -> int:
    """bpf_ringbuf_output(tag=r1, a0=r2, a1=r3, a2=r4) — emit one typed
    event; the record timestamp is the modeled clock from ctx so streams
    are executor-independent."""
    return _rb_emit(ctx, state, int(regs[1]), int(regs[2]), int(regs[3]),
                    int(regs[4]))


def _helper_promotion_cost(regs, ctx, state: HelperState) -> int:
    """bpf_mm_promotion_cost(order=r1) — the paper's empirical cost estimate.

    cost(order) = zeroing(order) + (compaction if no free page of that order).
    Reads the calibrated constants and buddy state out of ctx.
    """
    from .context import CTX  # local import to avoid cycle at module load
    order = max(0, min(3, int(regs[1])))
    nblocks = 4 ** order
    zero = int(ctx[CTX.ZERO_NS_PER_BLOCK]) * nblocks
    free = int(ctx[CTX.FREE_BLOCKS_O0 + order])
    if free > 0:
        return zero
    frag = int(ctx[CTX.FRAG_O0 + order])  # FIXED_POINT scaled (0..1000)
    compact = (int(ctx[CTX.COMPACT_NS_PER_BLOCK]) * nblocks
               * (1000 + frag) // 1000)
    return zero + compact


def _helper_migrate_cost(regs, ctx, state: HelperState) -> int:
    """bpf_mm_migrate_cost(order=r1, src_tier=r2, dst_tier=r3) — full cost of
    moving an order-k page between two tiers of the N-pool graph: the summed
    fixed setup + per-block transfer of every edge on the src->dst path, read
    from the cumulative ctx tables so it matches CostModel.migrate_ns
    exactly.  A same-tier query costs 0."""
    from .context import CTX, MAX_TIERS  # local import to avoid cycle
    order = max(0, min(3, int(regs[1])))
    src = max(0, min(MAX_TIERS - 1, int(regs[2])))
    dst = max(0, min(MAX_TIERS - 1, int(regs[3])))
    lo, hi = (src, dst) if src <= dst else (dst, src)
    setup = int(ctx[CTX.MIG_CUM_SETUP_T0 + hi]) \
        - int(ctx[CTX.MIG_CUM_SETUP_T0 + lo])
    per_block = int(ctx[CTX.MIG_CUM_NS_T0 + hi]) \
        - int(ctx[CTX.MIG_CUM_NS_T0 + lo])
    return setup + per_block * (4 ** order)


HELPERS: dict[int, Callable] = {
    HELPER_KTIME: _helper_ktime,
    HELPER_TRACE: _helper_trace,
    HELPER_PROMOTION_COST: _helper_promotion_cost,
    HELPER_MIGRATE_COST: _helper_migrate_cost,
    HELPER_RINGBUF_OUTPUT: _helper_ringbuf_output,
}
HELPER_IDS = frozenset(HELPERS.keys())


class VMFault(Exception):
    """Runtime fault — should be unreachable for verified programs."""


@dataclass
class RunResult:
    ret: int
    steps: int
    trace: list                                 # EV_PROG_TRACE payloads (r1)
    events: list = field(default_factory=list)  # this run's (ts, tag, a0, a1, a2)
    dropped: int = 0                            # slot-budget drops this run


class PolicyVM:
    """Executes a verified Program against a ctx vector + map registry.

    The program is lowered ONCE at attach time (:func:`repro.core.lower.
    lower` — the same pass the compiled backends consume), so the run loop
    walks absolute branch targets and resolved map slots."""

    def __init__(self, program: Program, maps: MapRegistry | None = None) -> None:
        from .lower import lower    # late: lower imports jax lazily-heavy deps
        self.maps = maps if maps is not None else MapRegistry()
        self.lowered = lower(program, self.maps, helper_ids=HELPER_IDS)
        self.facts = self.lowered.facts
        self.program = program
        self.helper_state = HelperState(rb_cap=self.facts.get("rb_cap", 0))

    def run(self, ctx: np.ndarray) -> RunResult:
        insns = self.lowered.insns
        hs = self.helper_state
        if hs.rb_cap:
            hs.rb_events = []
        drops0 = hs.rb_drops
        regs = [0] * NUM_REGS
        pc = 0
        fuel = self.facts["max_steps"] + 8
        steps = 0
        n = len(insns)
        ctx_hi = CTX_LEN - 1
        while True:
            if steps >= fuel:
                raise VMFault("fuel exhausted — verifier bound violated (bug)")
            if not (0 <= pc < n):
                raise VMFault(f"pc out of bounds: {pc}")
            insn = insns[pc]
            op = insn.op
            steps += 1

            if op in ALU_REG_OPS:
                a, b = regs[insn.dst], regs[insn.src]
                regs[insn.dst] = _alu(op, a, b)
                pc += 1
            elif op in ALU_IMM_OPS:
                if op == Op.MOVI:
                    regs[insn.dst] = _wrap64(insn.imm)
                else:
                    regs[insn.dst] = _alu(_IMM2REG[op], regs[insn.dst], insn.imm)
                pc += 1
            elif op == Op.NEG:
                regs[insn.dst] = _wrap64(-regs[insn.dst])
                pc += 1
            elif op == Op.LDCTX:
                regs[insn.dst] = int(ctx[insn.imm])
                pc += 1
            elif op == Op.LDCTXR:
                regs[insn.dst] = int(ctx[max(0, min(regs[insn.src], ctx_hi))])
                pc += 1
            elif op == Op.LDMAP:
                regs[insn.dst] = self.maps[insn.imm].lookup(regs[insn.src])
                pc += 1
            elif op == Op.LDMAPX:
                mid = max(0, min(regs[insn.src2], len(self.maps) - 1))
                regs[insn.dst] = self.maps[mid].lookup(regs[insn.src])
                pc += 1
            elif op == Op.MAPSZ:
                regs[insn.dst] = len(self.maps[insn.imm])
                pc += 1
            elif op == Op.JA:
                pc = insn.target
            elif op in COND_JUMP_REG:
                taken = _cmp(op, regs[insn.dst], regs[insn.src])
                pc = insn.target if taken else pc + 1
            elif op in COND_JUMP_IMM:
                taken = _cmp(_JIMM2REG[op], regs[insn.dst], insn.src2)
                pc = insn.target if taken else pc + 1
            elif op == Op.JNZDEC:
                regs[insn.dst] = _wrap64(regs[insn.dst] - 1)
                pc = insn.target if regs[insn.dst] != 0 else pc + 1
            elif op == Op.CALL:
                regs[0] = _wrap64(int(HELPERS[insn.imm](regs, ctx, self.helper_state)))
                pc += 1
            elif op == Op.EXIT:
                ev = hs.rb_events
                return RunResult(
                    regs[0], steps,
                    [e[2] for e in ev if e[1] == EV_PROG_TRACE] if ev else [],
                    ev, hs.rb_drops - drops0)
            else:
                raise VMFault(f"unhandled opcode {op!r}")


def _alu(op: Op, a: int, b: int) -> int:
    if op == Op.MOV:
        return b
    if op == Op.ADD:
        return _wrap64(a + b)
    if op == Op.SUB:
        return _wrap64(a - b)
    if op == Op.MUL:
        return _wrap64(a * b)
    if op == Op.DIV:
        if b == 0:
            return 0
        # eBPF divide is unsigned on the bit pattern; we use truncated signed
        # division toward zero which matches C semantics for the s64 ALU.
        q = abs(a) // abs(b)
        return _wrap64(-q if (a < 0) != (b < 0) else q)
    if op == Op.MOD:
        if b == 0:
            return a
        r = abs(a) % abs(b)
        return _wrap64(-r if a < 0 else r)
    if op == Op.AND:
        return _wrap64(a & b)
    if op == Op.OR:
        return _wrap64(a | b)
    if op == Op.XOR:
        return _wrap64(a ^ b)
    if op == Op.LSH:
        return _wrap64(a << (b & 63))
    if op == Op.RSH:
        return _wrap64((a & ((1 << 64) - 1)) >> (b & 63))
    if op == Op.MIN:
        return min(a, b)
    if op == Op.MAX:
        return max(a, b)
    raise VMFault(f"bad ALU op {op!r}")


def _cmp(op: Op, a: int, b: int) -> bool:
    if op == Op.JEQ:
        return a == b
    if op == Op.JNE:
        return a != b
    if op == Op.JLT:
        return a < b
    if op == Op.JLE:
        return a <= b
    if op == Op.JGT:
        return a > b
    if op == Op.JGE:
        return a >= b
    if op == Op.JSET:
        return (a & b) != 0
    raise VMFault(f"bad cmp op {op!r}")


_IMM2REG = {
    Op.ADDI: Op.ADD, Op.SUBI: Op.SUB, Op.MULI: Op.MUL, Op.DIVI: Op.DIV,
    Op.MODI: Op.MOD, Op.ANDI: Op.AND, Op.ORI: Op.OR, Op.XORI: Op.XOR,
    Op.LSHI: Op.LSH, Op.RSHI: Op.RSH, Op.MINI: Op.MIN, Op.MAXI: Op.MAX,
}
_JIMM2REG = {
    Op.JEQI: Op.JEQ, Op.JNEI: Op.JNE, Op.JLTI: Op.JLT, Op.JLEI: Op.JLE,
    Op.JGTI: Op.JGT, Op.JGEI: Op.JGE, Op.JSETI: Op.JSET,
}
