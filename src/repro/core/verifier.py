"""Load-time static verifier for policy programs.

Mirrors the guarantees the in-kernel eBPF verifier gives the paper's
mechanism: a loaded program provably terminates, never reads uninitialized
registers, never accesses out-of-bounds context fields, only references
registered maps and white-listed helpers, and returns a value on every path.

The analysis is a conservative abstract interpretation over the CFG:
  * registers carry an abstract state {UNINIT, INIT, CONST(c)};
  * conditional jumps fork the state; join = field-wise meet;
  * JNZDEC loops must have a const-tracked counter <= MAX_LOOP_ITERS and the
    loop body may not write the counter (other than the JNZDEC itself) —
    which bounds every cycle in the CFG and hence total execution length.
"""

from __future__ import annotations

from dataclasses import dataclass

from .context import CTX_LEN
from .isa import (ALU_IMM_OPS, ALU_REG_OPS, COND_JUMP_IMM, COND_JUMP_REG,
                  MAX_LOOP_ITERS, MAX_PROGRAM_LEN, MAX_SIM_INSNS, NUM_REGS,
                  Insn, Op, Program)

UNINIT = "uninit"
INIT = "init"


class VerifierError(Exception):
    """Program rejected at load time."""


@dataclass
class _RegState:
    # value: UNINIT | INIT | ("const", c)
    vals: list

    def copy(self) -> "_RegState":
        return _RegState(list(self.vals))

    def meet(self, other: "_RegState") -> tuple["_RegState", bool]:
        changed = False
        out = []
        for a, b in zip(self.vals, other.vals):
            if a == b:
                out.append(a)
            elif a == UNINIT or b == UNINIT:
                out.append(UNINIT)
                changed = changed or (a != UNINIT)
            else:  # const vs const / const vs init -> init
                out.append(INIT)
                changed = changed or (a != INIT)
        return _RegState(out), changed


def verify(program: Program, *, num_maps: int = 0, map_lens: list[int] | None = None,
           helper_ids: frozenset[int] = frozenset()) -> dict:
    """Verify ``program``; raise VerifierError on rejection.

    Returns a dict of facts useful to the JIT: {"max_steps": int}.
    """
    insns = program.insns
    n = len(insns)
    if n == 0:
        raise VerifierError("empty program")
    if n > MAX_PROGRAM_LEN:
        raise VerifierError(f"program too long: {n} > {MAX_PROGRAM_LEN}")

    # ---- structural checks ------------------------------------------------
    loop_headers: dict[int, int] = {}   # jnzdec pc -> loop target pc
    for pc, insn in enumerate(insns):
        if not isinstance(insn.op, Op):
            raise VerifierError(f"{pc}: unknown opcode {insn.op}")
        if not (0 <= insn.dst < NUM_REGS and 0 <= insn.src < NUM_REGS):
            raise VerifierError(f"{pc}: register out of range in {insn!r}")
        if insn.op in (Op.JA,) or insn.op in COND_JUMP_REG or insn.op in COND_JUMP_IMM:
            tgt = pc + 1 + insn.imm
            if insn.imm < 0:
                raise VerifierError(f"{pc}: backward jump only allowed via JNZDEC")
            if not (0 <= tgt < n):
                raise VerifierError(f"{pc}: jump target {tgt} out of bounds")
        elif insn.op == Op.JNZDEC:
            tgt = pc + 1 + insn.imm
            if insn.imm >= 0:
                raise VerifierError(f"{pc}: JNZDEC must jump backward")
            if not (0 <= tgt < n):
                raise VerifierError(f"{pc}: JNZDEC target {tgt} out of bounds")
            loop_headers[pc] = tgt
        elif insn.op == Op.LDCTX:
            if not (0 <= insn.imm < CTX_LEN):
                raise VerifierError(f"{pc}: ctx offset {insn.imm} out of bounds [0,{CTX_LEN})")
        elif insn.op == Op.LDCTXR:
            if not (0 <= insn.src < NUM_REGS):
                raise VerifierError(f"{pc}: bad index register in LDCTXR")
        elif insn.op == Op.LDMAP:
            if not (0 <= insn.src2 < num_maps):
                raise VerifierError(f"{pc}: map id {insn.src2} not registered")
        elif insn.op == Op.LDMAPX:
            if num_maps < 1:
                raise VerifierError(f"{pc}: LDMAPX requires >=1 registered map")
            if not (0 <= insn.src2 < NUM_REGS):
                raise VerifierError(f"{pc}: bad map register in LDMAPX")
        elif insn.op == Op.MAPSZ:
            if not (0 <= insn.imm < num_maps):
                raise VerifierError(f"{pc}: map id {insn.imm} not registered")
        elif insn.op == Op.CALL:
            if insn.imm not in helper_ids:
                raise VerifierError(f"{pc}: helper {insn.imm} not white-listed")
        elif insn.op in (Op.DIVI, Op.MODI):
            if insn.imm == 0:
                raise VerifierError(f"{pc}: division by immediate zero")

    if insns[-1].op not in (Op.EXIT, Op.JA):
        # last insn must not fall off the end
        if not (insns[-1].op == Op.JNZDEC):
            raise VerifierError("program may fall off the end (last insn not EXIT)")

    # ---- loop bounding ------------------------------------------------------
    # For each JNZDEC at pc with target t: the counter register must be
    # const-assigned (MOVI) on every path reaching t, with value <= MAX_LOOP_ITERS,
    # and no instruction in [t, pc) may write the counter.
    for pc, tgt in loop_headers.items():
        counter = insns[pc].dst
        for body_pc in range(tgt, pc):
            b = insns[body_pc]
            writes = _written_reg(b)
            if writes == counter:
                raise VerifierError(
                    f"{pc}: loop body (pc {body_pc}) writes JNZDEC counter r{counter}")
            if b.op == Op.JNZDEC:
                raise VerifierError(f"{pc}: nested JNZDEC loops are not allowed")

    # ---- dataflow: reachability + init/const tracking ----------------------
    loop_trips: dict[int, int] = {}     # jnzdec pc -> exact trip count
    start = _RegState([UNINIT] * NUM_REGS)
    states: dict[int, _RegState] = {0: start}
    work = [0]
    visited_exit = False
    visits = 0
    while work:
        pc = work.pop()
        visits += 1
        if visits > 20 * n + 1000:
            raise VerifierError("verifier state explosion (CFG too complex)")
        st = states[pc].copy()
        insn = insns[pc]
        succs: list[int] = []

        def read(r: int) -> None:
            if st.vals[r] == UNINIT:
                raise VerifierError(f"{pc}: read of uninitialized register r{r} in {insn!r}")

        op = insn.op
        if op in ALU_REG_OPS:
            if op != Op.MOV:
                read(insn.dst)
            read(insn.src)
            st.vals[insn.dst] = INIT
            if op == Op.MOV and isinstance(states[pc].vals[insn.src], tuple):
                st.vals[insn.dst] = states[pc].vals[insn.src]
            succs = [pc + 1]
        elif op in ALU_IMM_OPS:
            if op == Op.MOVI:
                st.vals[insn.dst] = ("const", insn.imm)
            else:
                read(insn.dst)
                st.vals[insn.dst] = INIT
            succs = [pc + 1]
        elif op == Op.NEG:
            read(insn.dst)
            st.vals[insn.dst] = INIT
            succs = [pc + 1]
        elif op in (Op.LDCTX, Op.MAPSZ):
            st.vals[insn.dst] = INIT
            succs = [pc + 1]
        elif op == Op.LDMAP:
            read(insn.src)
            st.vals[insn.dst] = INIT
            succs = [pc + 1]
        elif op == Op.LDCTXR:
            # the index register must be provably initialized, and a
            # verifier-tracked constant index must be inside the ctx struct
            # (the analogue of the kernel verifier's ctx bounds check); a
            # non-const index is runtime-clamped identically by every backend
            read(insn.src)
            v = st.vals[insn.src]
            if isinstance(v, tuple) and v[0] == "const" \
                    and not (0 <= v[1] < CTX_LEN):
                raise VerifierError(
                    f"{pc}: LDCTXR index {v[1]} out of ctx bounds [0,{CTX_LEN})")
            st.vals[insn.dst] = INIT
            succs = [pc + 1]
        elif op == Op.LDMAPX:
            read(insn.src)
            read(insn.src2)
            st.vals[insn.dst] = INIT
            succs = [pc + 1]
        elif op == Op.JA:
            succs = [pc + 1 + insn.imm]
        elif op in COND_JUMP_REG:
            read(insn.dst)
            read(insn.src)
            succs = [pc + 1, pc + 1 + insn.imm]
        elif op in COND_JUMP_IMM:
            read(insn.dst)
            succs = [pc + 1, pc + 1 + insn.imm]
        elif op == Op.JNZDEC:
            read(insn.dst)
            v = states[pc].vals[insn.dst]
            if not (isinstance(v, tuple) and v[0] == "const"):
                raise VerifierError(
                    f"{pc}: JNZDEC counter r{insn.dst} is not a verifier-tracked "
                    f"constant at loop entry")
            if not (0 < v[1] <= MAX_LOOP_ITERS):
                raise VerifierError(
                    f"{pc}: JNZDEC trip count {v[1]} outside (0, {MAX_LOOP_ITERS}]")
            loop_trips[pc] = v[1]
            st.vals[insn.dst] = ("const", v[1])  # keep const through iterations
            # back edge: state at target must already subsume; we only follow
            # the fall-through to keep the fixpoint finite (counter is const
            # and the body cannot write it, so the body state is stable).
            succs = [pc + 1]
        elif op == Op.CALL:
            # helpers read r1..r5 as needed (treated as may-read: require r1 init
            # is too strict for nullary helpers; we require nothing, helpers are
            # total functions) and write r0.
            st.vals[0] = INIT
            succs = [pc + 1]
        elif op == Op.EXIT:
            read(0)
            visited_exit = True
            succs = []
        else:
            raise VerifierError(f"{pc}: unhandled opcode {op!r}")

        for s in succs:
            if s >= n:
                raise VerifierError(f"{pc}: control falls off the end of the program")
            if s not in states:
                states[s] = st.copy()
                work.append(s)
            else:
                merged, changed = states[s].meet(st)
                if changed:
                    states[s] = merged
                    work.append(s)

    if not visited_exit:
        raise VerifierError("no reachable EXIT")

    # ---- worst-case step bound ---------------------------------------------
    # Straight-line length + every loop body re-executed (bound-1) more times.
    max_steps = n
    for pc, tgt in loop_headers.items():
        body = pc - tgt + 1
        max_steps += body * MAX_LOOP_ITERS
    if max_steps > MAX_SIM_INSNS:
        raise VerifierError(f"worst-case instruction count {max_steps} > {MAX_SIM_INSNS}")

    return {"max_steps": max_steps, "num_loops": len(loop_headers),
            "loop_trips": loop_trips}


def _written_reg(insn: Insn) -> int | None:
    if insn.op in ALU_REG_OPS or insn.op in ALU_IMM_OPS or insn.op in (
            Op.NEG, Op.LDCTX, Op.LDCTXR, Op.LDMAP, Op.LDMAPX, Op.MAPSZ):
        return insn.dst
    if insn.op == Op.CALL:
        return 0
    return None
