"""Buddy allocator over the HBM block pool, with fragmentation metrics.

The pool is ``num_blocks`` base blocks; an order-k page is 4^k contiguous
base blocks aligned to 4^k (radix-4 buddies — chosen over Linux's radix-2
because the resulting page sizes 16/64/256/1024 tokens are TPU-tile aligned;
see DESIGN.md §Hardware adaptation).

Provides the real-time state the fault hook exposes to policies:
free-list counts per order and a Linux-style unusable-free-space
fragmentation index, plus a compaction planner that emits an explicit block
move list the device executes with the block_copy Pallas kernel.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from .context import NUM_ORDERS

RADIX = 4


def order_blocks(order: int) -> int:
    return RADIX ** order


class BuddyError(Exception):
    pass


@dataclass
class BuddyStats:
    free_per_order: tuple[int, ...]
    frag_index_milli: tuple[int, ...]   # 0..1000 per order
    free_blocks: int
    total_blocks: int

    @property
    def utilization_milli(self) -> int:
        if self.total_blocks == 0:
            return 0
        return 1000 * (self.total_blocks - self.free_blocks) // self.total_blocks


class BuddyAllocator:
    """Radix-4 buddy allocator; addresses are base-block indices."""

    def __init__(self, num_blocks: int, max_order: int = NUM_ORDERS - 1) -> None:
        if num_blocks <= 0:
            raise ValueError("num_blocks must be positive")
        self.max_order = max_order
        self.num_blocks = num_blocks
        # free_lists[k] = set of start blocks of free order-k pages
        self.free_lists: list[set[int]] = [set() for _ in range(max_order + 1)]
        # allocated[start] = order, for every live allocation
        self.allocated: dict[int, int] = {}
        self._seed_free_space()

    def _seed_free_space(self) -> None:
        """Carve the pool into maximal aligned free pages."""
        pos = 0
        while pos < self.num_blocks:
            k = self.max_order
            while k > 0 and (pos % order_blocks(k) != 0
                             or pos + order_blocks(k) > self.num_blocks):
                k -= 1
            self.free_lists[k].add(pos)
            pos += order_blocks(k)

    # ------------------------------------------------------------------ alloc
    def alloc(self, order: int) -> int:
        """Allocate an order-k page; raises BuddyError if impossible without
        compaction (the fault path turns that into a compact-or-fallback)."""
        if not 0 <= order <= self.max_order:
            raise ValueError(f"bad order {order}")
        k = order
        while k <= self.max_order and not self.free_lists[k]:
            k += 1
        if k > self.max_order:
            raise BuddyError(f"no free page of order >= {order}")
        start = min(self.free_lists[k])  # deterministic: lowest address first
        self.free_lists[k].discard(start)
        # split down to the requested order
        while k > order:
            k -= 1
            step = order_blocks(k)
            for i in range(1, RADIX):
                self.free_lists[k].add(start + i * step)
        self.allocated[start] = order
        return start

    def free(self, start: int) -> None:
        if start not in self.allocated:
            raise BuddyError(f"double free / unknown allocation at {start}")
        order = self.allocated.pop(start)
        self._free_page(start, order)

    def _free_page(self, start: int, order: int) -> None:
        k = order
        while k < self.max_order:
            step = order_blocks(k)
            group = (start // (step * RADIX)) * (step * RADIX)
            buddies = [group + i * step for i in range(RADIX)]
            if all(b == start or b in self.free_lists[k] for b in buddies):
                for b in buddies:
                    self.free_lists[k].discard(b)
                start = group
                k += 1
            else:
                break
        self.free_lists[k].add(start)

    # ------------------------------------------------------------------ state
    def free_blocks_total(self) -> int:
        return sum(len(fl) * order_blocks(k) for k, fl in enumerate(self.free_lists))

    def stats(self) -> BuddyStats:
        free_per_order = tuple(
            sum(len(self.free_lists[j]) * (order_blocks(j) // order_blocks(k))
                for j in range(k, self.max_order + 1))
            for k in range(self.max_order + 1))
        total_free = self.free_blocks_total()
        frag = []
        for k in range(self.max_order + 1):
            if total_free == 0:
                frag.append(1000)
                continue
            # Linux extfrag-style: fraction of free memory NOT usable for an
            # order-k request.
            usable = free_per_order[k] * order_blocks(k)
            frag.append(int(1000 * (1 - usable / total_free)))
        return BuddyStats(free_per_order=free_per_order,
                          frag_index_milli=tuple(frag),
                          free_blocks=total_free,
                          total_blocks=self.num_blocks)

    # ------------------------------------------------------------- compaction
    def plan_compaction(self, order: int) -> list[tuple[int, int, int]] | None:
        """Plan moves to create one free aligned order-k page.

        Returns a move list [(src_start, dst_start, order_moved), ...] or None
        if impossible (not enough total free space).  Strategy mirrors Linux
        compaction's two scanners: find the aligned candidate window with the
        fewest allocated blocks, then relocate those allocations into free
        pages outside the window (lowest-address-first).
        """
        need = order_blocks(order)
        if self.free_blocks_total() < need:
            return None
        # Candidate windows: aligned order-k ranges. Score = allocated blocks inside.
        best_window, best_allocs, best_score = None, None, None
        for wstart in range(0, self.num_blocks - need + 1, need):
            allocs_in = [(s, o) for s, o in self.allocated.items()
                         if s < wstart + need and s + order_blocks(o) > wstart]
            # reject windows where an allocation straddles the boundary
            if any(s < wstart or s + order_blocks(o) > wstart + need
                   for s, o in allocs_in):
                continue
            score = sum(order_blocks(o) for _, o in allocs_in)
            free_outside = self.free_blocks_total() - (need - score)
            if free_outside < score:
                continue
            if best_score is None or score < best_score:
                best_window, best_allocs, best_score = wstart, allocs_in, score
            if score == 0:
                break
        if best_window is None:
            return None

        moves: list[tuple[int, int, int]] = []
        # simulate: free everything in the window, then re-alloc outside it
        saved_free = [set(fl) for fl in self.free_lists]
        saved_alloc = dict(self.allocated)
        try:
            for s, o in best_allocs:
                self.free(s)
            # reserve the window so re-allocs land outside
            reserved = self._reserve_range(best_window, need)
            for s, o in sorted(best_allocs, key=lambda x: -x[1]):
                dst = self.alloc(o)
                moves.append((s, dst, o))
            self._unreserve(reserved)
        except BuddyError:
            self.free_lists = saved_free
            self.allocated = saved_alloc
            return None
        return moves

    def _reserve_range(self, start: int, nblocks: int) -> list[tuple[int, int]]:
        """Temporarily remove free pages inside [start, start+nblocks) from
        the free lists. Returns what was removed for later restoration.

        Free pages that CONTAIN the window (possible after coalescing) are
        split down first so every overlapping free page lies strictly inside.
        """
        changed = True
        while changed:
            changed = False
            for k in range(self.max_order, 0, -1):
                step = order_blocks(k)
                for s in list(self.free_lists[k]):
                    overlaps = s < start + nblocks and s + step > start
                    inside = s >= start and s + step <= start + nblocks
                    if overlaps and not inside:
                        self.free_lists[k].discard(s)
                        child = order_blocks(k - 1)
                        for i in range(RADIX):
                            self.free_lists[k - 1].add(s + i * child)
                        changed = True
        removed = []
        for k, fl in enumerate(self.free_lists):
            step = order_blocks(k)
            inside = [s for s in fl if s >= start and s + step <= start + nblocks]
            for s in inside:
                fl.discard(s)
                removed.append((s, k))
        return removed

    def _unreserve(self, removed: list[tuple[int, int]]) -> None:
        # re-add with coalescing so the window comes back as maximal pages
        for s, k in removed:
            self._free_page(s, k)

    def check_invariants(self) -> None:
        """Exhaustive consistency check (used by property tests)."""
        seen: set[int] = set()
        for k, fl in enumerate(self.free_lists):
            step = order_blocks(k)
            for s in fl:
                if s % step != 0:
                    raise AssertionError(f"free page {s} misaligned for order {k}")
                rng = set(range(s, s + step))
                if rng & seen:
                    raise AssertionError(f"overlap in free lists at {s}")
                seen |= rng
        for s, o in self.allocated.items():
            step = order_blocks(o)
            if s % step != 0:
                raise AssertionError(f"allocation {s} misaligned for order {o}")
            rng = set(range(s, s + step))
            if rng & seen:
                raise AssertionError(f"allocation {s} overlaps free space")
            seen |= rng
        if len(seen) != self.num_blocks:
            raise AssertionError(
                f"accounting leak: {len(seen)} != {self.num_blocks} blocks")
