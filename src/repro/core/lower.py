"""Shared lowering pass: verified bytecode -> one flat IR for every executor.

Before this module, the three executors (host interpreter in :mod:`vm`,
while+switch XLA JIT in :mod:`jit`, predicated straight-line compiler in
:mod:`predicate`) each re-derived the same facts from the raw instruction
stream — relative branch offsets, which field of an ``Insn`` holds the map
slot, how LDCTX / the ``bpf_mm_*`` helpers / the map ops read the context —
three hand-kept-consistent copies of the per-op semantics.  The real eBPF
stack does not work that way: ONE verifier accepts the program and one
lowering feeds every JIT backend ("Cache is King"'s verified-once,
compiled-anywhere split).

This module is that single stage:

  * :func:`lower` runs the verifier ONCE and emits a :class:`LoweredProgram`
    of :class:`LIns` — branch targets resolved to ABSOLUTE pcs, map slots
    normalized into ``imm``, ctx offsets validated — the only program form
    the executors consume;
  * :func:`unroll_lowered` expands the verifier-bounded loops (trip counts
    come from the verifier facts, not a re-analysis) into forward-only
    straight-line code, recording the loop-copy boundaries the segmented
    predicated compiler cuts at;
  * the jnp per-op bodies (`alu_jnp`, `cmp_jnp`, :func:`ldctx_dyn`,
    :func:`map_lookup`, :func:`map_lookup_dyn`, :func:`helper_jnp`) are
    written once against a :class:`CtxView` so the vmapped JIT (vector ctx)
    and the predicated compiler (batched ctx) lower every opcode — including
    the register-indexed ``LDCTXR`` — through literally the same code.

The host interpreter shares the IR (absolute targets, resolved slots) and
keeps its scalar Python helper bodies in :mod:`vm`; the two XLA backends
share both the IR and the jnp lowering bodies here.
"""

from __future__ import annotations

import hashlib
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from ..obs.ringbuf import EV_PROG_TRACE
from .context import CTX, CTX_LEN, MAX_TIERS
from .isa import (ALU_IMM_OPS, ALU_REG_OPS, COND_JUMP_IMM, COND_JUMP_REG,
                  JUMP_OPS, Insn, Op, Program)
from .verifier import verify

I64 = jnp.int64

# Hard cap on the flattened (all loops expanded) program length — a backstop
# far above any real policy, mirrored from the old predicate-module limit.
MAX_UNROLLED = 20_000

# Bump when the IR layout or any lowering semantics change: the artifact
# cache (core.cache) folds this into every digest so stale on-disk pickles
# can never be misread by a newer pipeline.
# v2: ring-buffer helpers (HELPER_TRACE / bpf_ringbuf_output) lower to real
# per-lane event-slot writes instead of a device no-op.
IR_VERSION = 2

# Ring-buffer event record width: (ts, tag, a0, a1, a2), all int64.
RB_FIELDS = 5

# Hard per-invocation per-lane event-slot budget.  The exact worst case is
# computed from the verifier's loop trip counts per program; this clamp
# bounds the threaded device buffer for emit-heavy loops (drops past it are
# counted, identically on every executor).
RB_MAX_PER_RUN = 64


@dataclass(frozen=True)
class LIns:
    """One lowered instruction.

    Field use per op class (everything irrelevant is 0 / -1):

    ======================  ====================================================
    ALU reg/imm, NEG        ``dst``, ``src`` / ``imm``
    LDCTX                   ``dst``, ``imm`` = validated ctx offset
    LDCTXR                  ``dst``, ``src`` = index register
    LDMAP                   ``dst``, ``src`` = index reg, ``imm`` = map SLOT
    LDMAPX                  ``dst``, ``src`` = index reg, ``src2`` = map-id reg
    MAPSZ                   ``dst``, ``imm`` = map SLOT
    JA                      ``target`` (absolute)
    cond jumps              ``dst``(lhs), ``src``(rhs reg) / ``src2``(rhs imm),
                            ``target`` = absolute taken-pc
    JNZDEC                  ``dst`` = counter, ``target`` = absolute loop head
    CALL                    ``imm`` = helper id
    EXIT                    —
    ======================  ====================================================
    """
    op: Op
    dst: int = 0
    src: int = 0
    imm: int = 0
    src2: int = 0
    target: int = -1


@dataclass(frozen=True)
class LoweredProgram:
    """The verified, resolved program every backend consumes."""
    name: str
    insns: tuple[LIns, ...]
    facts: dict            # verifier facts; loop_trips keyed by lowered pc
    num_maps: int
    map_caps: tuple[int, ...]     # registered map capacities (shape contract)
    source_len: int

    def digest(self) -> str:
        """Stable content hash — the artifact-cache key component.

        Covers the instruction stream, the map-registry SHAPE contract
        (slot count + capacities; map *contents* are runtime data), the ctx
        layout width (so a ctx-struct change invalidates old artifacts) and
        the IR version."""
        h = hashlib.sha256()
        h.update(f"ir{IR_VERSION}:ctx{CTX_LEN}:tiers{MAX_TIERS}:"
                 f"maps{self.num_maps}:{self.map_caps}:".encode())
        for i in self.insns:
            h.update(f"{int(i.op)},{i.dst},{i.src},{i.imm},{i.src2},"
                     f"{i.target};".encode())
        return h.hexdigest()


def _rb_capacity(program: Program, facts: dict) -> int:
    """Worst-case ring-buffer emissions of ONE invocation: every CALL to an
    emitting helper weighted by its loop's verifier-proven trip count (loops
    are non-nested, so one weight per site), clamped to RB_MAX_PER_RUN.
    This is the static size of the per-lane slot buffer each executor
    threads — and 0 for the (default) programs that never emit, which is
    what keeps the no-telemetry fast path's traced computations unchanged."""
    from .vm import RB_HELPERS          # late: vm imports this module's peer
    insns = program.insns
    loops = [(pc + 1 + insn.imm, pc, trips)      # (body start, back edge, n)
             for pc, insn in enumerate(insns) if insn.op == Op.JNZDEC
             for trips in (facts.get("loop_trips", {}).get(pc, 1),)]
    total = 0
    for pc, insn in enumerate(insns):
        if insn.op == Op.CALL and insn.imm in RB_HELPERS:
            weight = 1
            for t, j, trips in loops:
                if t <= pc < j:
                    weight = trips
                    break
            total += weight
    return min(total, RB_MAX_PER_RUN)


def lower(program: Program, maps, *, helper_ids=None) -> LoweredProgram:
    """Verify ``program`` once and lower it to the shared flat IR."""
    if helper_ids is None:
        from .vm import HELPER_IDS      # late: vm imports verifier only
        helper_ids = HELPER_IDS
    facts = verify(program, num_maps=len(maps), map_lens=maps.lens(),
                   helper_ids=helper_ids)
    facts["rb_cap"] = _rb_capacity(program, facts)
    out: list[LIns] = []
    for pc, insn in enumerate(program.insns):
        op = insn.op
        if op in JUMP_OPS or op == Op.JNZDEC:
            out.append(LIns(op, insn.dst, insn.src, 0, insn.src2,
                            target=pc + 1 + insn.imm))
        elif op == Op.LDMAP:
            # raw form carries the map id in src2; normalize into imm so the
            # backends read one field for the resolved slot
            out.append(LIns(op, insn.dst, insn.src, imm=insn.src2))
        else:
            out.append(LIns(op, insn.dst, insn.src, insn.imm, insn.src2))
    caps = tuple(maps[i].capacity for i in range(len(maps)))
    return LoweredProgram(name=program.name, insns=tuple(out), facts=facts,
                          num_maps=len(maps), map_caps=caps,
                          source_len=len(program.insns))


# ---------------------------------------------------------------------------
# Loop flattening (verifier-bounded unroll) over the lowered IR
# ---------------------------------------------------------------------------

def _retarget(ins: LIns, tgt: int) -> LIns:
    return LIns(ins.op, ins.dst, ins.src, ins.imm, ins.src2, tgt)


def _expand_one(insns: list[LIns], cuts: list[int], t: int, j: int,
                trips: int) -> tuple[list[LIns], list[int]]:
    """Expand the JNZDEC loop body ``[t, j)`` (back edge at ``j``) into
    ``trips`` copies, each closed by the faithful counter SUBI; remap every
    absolute target; shift the recorded cut points past the loop."""
    body = insns[t:j]
    counter = insns[j].dst
    blen = len(body) + 1
    shift = trips * blen - (j - t + 1)

    def remap(tgt: int, copy: int) -> int:
        if tgt < t:
            return tgt
        if t <= tgt < j:
            return t + copy * blen + (tgt - t)
        if tgt == j:        # "continue": this copy's counter SUBI
            return t + copy * blen + len(body)
        return tgt + shift  # past the loop

    out: list[LIns] = []
    for ins in insns[:t]:
        out.append(_retarget(ins, remap(ins.target, 0))
                   if ins.target >= 0 else ins)
    for copy in range(trips):
        for ins in body:
            out.append(_retarget(ins, remap(ins.target, copy))
                       if ins.target >= 0 else ins)
        out.append(LIns(Op.SUBI, counter, 0, 1))
    for ins in insns[j + 1:]:
        out.append(_retarget(ins, remap(ins.target, 0))
                   if ins.target >= 0 else ins)
    # cut points: every copy boundary of this loop is a legal segment cut
    # (the original back-edge positions); prior cuts past the loop shift
    new_cuts = [c if c <= t else c + shift for c in cuts]
    new_cuts.extend(t + copy * blen for copy in range(trips + 1))
    return out, sorted(set(new_cuts))


def unroll_lowered(lp: LoweredProgram) -> tuple[tuple[LIns, ...],
                                                tuple[int, ...]]:
    """Flatten every verifier-bounded loop; returns ``(code, cut_points)``.

    ``code`` is forward-jump-only straight-line IR; ``cut_points`` are the
    loop-copy (back-edge) boundaries, the positions the segmented predicated
    compiler prefers to split at.  Trip counts come from the verifier facts
    of the SINGLE :func:`lower` pass — no re-verification per expansion.
    Raises ``ValueError`` when the flattened form exceeds ``MAX_UNROLLED``.
    """
    insns = list(lp.insns)
    trips_by_pc = dict(lp.facts.get("loop_trips", {}))
    # expand LAST loop first: earlier loop positions (and their trip keys)
    # stay valid because nothing before the expanded span moves
    loops = sorted((pc for pc, ins in enumerate(insns)
                    if ins.op == Op.JNZDEC), reverse=True)
    cuts: list[int] = []
    for j in loops:
        t = insns[j].target
        trips = trips_by_pc[j]
        insns, cuts = _expand_one(insns, cuts, t, j, trips)
        if len(insns) > MAX_UNROLLED:
            raise ValueError(f"unrolled program too long ({len(insns)})")
    return tuple(insns), tuple(cuts)


def _spans_congruent(code: tuple[LIns, ...], a: int, b: int,
                     blen: int) -> bool:
    """True when ``code[a:a+blen]`` and ``code[b:b+blen]`` are loop-copy
    congruent: every non-target field identical, and targets either both
    absent, both the SAME relative offset within their copy, or both the
    SAME absolute pc outside both copies (a shared past-loop exit)."""
    for o in range(blen):
        ia, ib = code[a + o], code[b + o]
        if (ia.op, ia.dst, ia.src, ia.imm, ia.src2) != \
                (ib.op, ib.dst, ib.src, ib.imm, ib.src2):
            return False
        ta, tb = ia.target, ib.target
        if (ta < 0) != (tb < 0):
            return False
        if ta < 0:
            continue
        rel = (ta - a == tb - b) and 0 <= ta - a < blen
        absolute = (ta == tb) and ta >= a + blen and tb >= b + blen
        if not (rel or absolute):
            return False
    return True


def plan_scan_stages(code: tuple[LIns, ...], cuts: tuple[int, ...]
                     ) -> tuple[list[tuple], int]:
    """Factor flattened ``code`` into a stage plan for the fused one-dispatch
    executor: maximal runs of CONGRUENT loop copies (the spans between the
    equally-spaced cut points :func:`unroll_lowered` records) collapse to a
    single ``("scan", start, end, trips, blen)`` stage — one copy body,
    ``lax.scan``-ed ``trips`` times — and everything else stays verbatim
    ``("plain", start, end)`` stages.

    Returns ``(stages, traced_len)`` where ``traced_len`` is the number of
    instructions the fused compile actually traces (each scan run counts one
    copy); it is the budget number a caller compares against its segment
    limit.  A run is rejected (stays plain) unless every copy is congruent
    with the first, no jump from before the run lands inside it anywhere but
    its first pc (a front copy is peeled off into the prologue until that
    holds), and every exit target lands at/after the run end.
    """
    n = len(code)
    cs = sorted({c for c in cuts if 0 <= c <= n})
    runs: list[tuple[int, int, int, int]] = []   # (start, end, trips, blen)
    i = 0
    while i < len(cs) - 1:
        start = cs[i]
        blen = cs[i + 1] - start
        k = i + 1
        while (k + 1 < len(cs) and cs[k + 1] - cs[k] == blen
               and _spans_congruent(code, start, cs[k], blen)):
            k += 1
        trips = k - i
        if trips >= 2 and blen > 0:
            # peel front copies into the plain prologue until no jump from
            # OUTSIDE the run lands strictly inside it (jumps from before a
            # loop can only land in its first copy, so peeling converges)
            while trips >= 2:
                end = start + trips * blen
                bad = [ins.target for pc, ins in enumerate(code)
                       if ins.target >= 0 and not (start <= pc < end)
                       and start < ins.target < end]
                if not bad:
                    break
                if any(t >= start + blen for t in bad):
                    trips = 0      # lands past copy 0: not peelable, reject
                    break
                start += blen
                trips -= 1
            # exits from the copy body must land at/after the run end
            if trips >= 2 and all(
                    ins.target < start + blen or ins.target >= end
                    for ins in code[start:start + blen] if ins.target >= 0):
                runs.append((start, end, trips, blen))
        i = k
    stages: list[tuple] = []
    pos = 0
    for start, end, trips, blen in runs:
        if pos < start:
            stages.append(("plain", pos, start))
        stages.append(("scan", start, end, trips, blen))
        pos = end
    if pos < n:
        stages.append(("plain", pos, n))
    traced = sum((st[4] if st[0] == "scan" else st[2] - st[1])
                 for st in stages)
    return stages, traced


def segment_code(code: tuple[LIns, ...], cuts: tuple[int, ...],
                 limit: int) -> list[tuple[int, int]]:
    """Partition straight-line ``code`` into ``[start, end)`` spans of at most
    ``limit`` insns, cutting at loop-copy boundaries when one is in reach
    (straight-line code may be cut anywhere, so a hard cut is the fallback).
    """
    n = len(code)
    segs: list[tuple[int, int]] = []
    pos = 0
    while pos < n:
        hard = pos + limit
        if n <= hard:
            end = n
        else:
            prefer = [c for c in cuts if pos < c <= hard]
            end = max(prefer) if prefer else hard
        segs.append((pos, end))
        pos = end
    return segs


# ---------------------------------------------------------------------------
# Shared jnp per-op lowering (consumed by jit.py AND predicate.py)
# ---------------------------------------------------------------------------

def alu_jnp(op: Op, a, b):
    """64-bit ALU body, identical across the XLA backends (the interpreter's
    scalar twin lives in vm._alu; test_core_vm fuzzes their agreement)."""
    if op == Op.MOV:
        return b
    if op == Op.ADD:
        return a + b
    if op == Op.SUB:
        return a - b
    if op == Op.MUL:
        return a * b
    if op == Op.DIV:
        # truncated signed division toward zero, x/0 == 0
        q = jnp.where(b == 0, 0, jnp.abs(a) // jnp.where(b == 0, 1, jnp.abs(b)))
        return jnp.where((a < 0) != (b < 0), -q, q).astype(a.dtype)
    if op == Op.MOD:
        r = jnp.abs(a) % jnp.where(b == 0, 1, jnp.abs(b))
        r = jnp.where(a < 0, -r, r).astype(a.dtype)
        return jnp.where(b == 0, a, r)
    if op == Op.AND:
        return a & b
    if op == Op.OR:
        return a | b
    if op == Op.XOR:
        return a ^ b
    if op == Op.LSH:
        return a << (b & 63)
    if op == Op.RSH:
        ua = a.astype(jnp.uint64)
        return (ua >> (b.astype(jnp.uint64) & 63)).astype(a.dtype)
    if op == Op.MIN:
        return jnp.minimum(a, b)
    if op == Op.MAX:
        return jnp.maximum(a, b)
    raise ValueError(f"bad ALU op {op}")


def cmp_jnp(op: Op, a, b):
    if op == Op.JEQ:
        return a == b
    if op == Op.JNE:
        return a != b
    if op == Op.JLT:
        return a < b
    if op == Op.JLE:
        return a <= b
    if op == Op.JGT:
        return a > b
    if op == Op.JGE:
        return a >= b
    if op == Op.JSET:
        return (a & b) != 0
    raise ValueError(f"bad cmp op {op}")


class VecCtx:
    """Ctx view over one ``[CTX_LEN]`` vector (the vmapped JIT's lane)."""
    __slots__ = ("ctx",)

    def __init__(self, ctx):
        self.ctx = ctx

    def col(self, off: int):
        return self.ctx[off]

    def col_dyn(self, idx):
        """ctx[idx] with a traced scalar index (callers clamp)."""
        return jax.lax.dynamic_index_in_dim(self.ctx, idx.astype(jnp.int32),
                                            keepdims=False)

    def zeros_like_lane(self):
        return jnp.asarray(0, I64)

    def lane(self, v: int):
        """Broadcast a python constant to the lane shape."""
        return jnp.asarray(v, I64)

    def event_write(self, events, count, drops, words, fire):
        """One ``bpf_ringbuf_output`` emission into this lane's slot buffer.

        ``events [cap, RB_FIELDS]``, ``count``/``drops`` scalars, ``words``
        the 5 record scalars, ``fire`` whether the call executes (always
        True on the per-lane JIT — reaching the CALL means it runs).
        Returns ``(events, count, drops, r0)``: r0 = 0 on success, -1 when
        the slot budget is spent (then drops increments) — bit-identical to
        the interpreter helper."""
        cap = events.shape[0]
        fire = jnp.asarray(fire)
        ok = fire & (count < cap)
        idx = jnp.clip(count, 0, cap - 1).astype(jnp.int32)
        row = jnp.stack([jnp.asarray(w, I64) for w in words])
        cur = jax.lax.dynamic_slice_in_dim(events, idx, 1, axis=0)
        events = jax.lax.dynamic_update_slice_in_dim(
            events, jnp.where(ok, row[None], cur), idx, axis=0)
        count = count + ok.astype(count.dtype)
        drops = drops + (fire & ~ok).astype(drops.dtype)
        r0 = jnp.where(ok, jnp.asarray(0, I64), jnp.asarray(-1, I64))
        return events, count, drops, r0


class BatchCtx:
    """Ctx view over a ``[B, CTX_LEN]`` matrix (the predicated compiler)."""
    __slots__ = ("ctx",)

    def __init__(self, ctx):
        self.ctx = ctx

    def col(self, off: int):
        return self.ctx[:, off]

    def col_dyn(self, idx):
        """ctx[i, idx_i] with a traced ``[B]`` index vector (callers clamp)."""
        return jnp.take_along_axis(
            self.ctx, idx[:, None].astype(jnp.int32), axis=1)[:, 0]

    def zeros_like_lane(self):
        return jnp.zeros(self.ctx.shape[0], I64)

    def lane(self, v: int):
        """Broadcast a python constant to the lane shape."""
        return jnp.full(self.ctx.shape[0], v, I64)

    def event_write(self, events, count, drops, words, fire):
        """Batched twin of :meth:`VecCtx.event_write`: ``events [B, cap,
        RB_FIELDS]``, ``count``/``drops`` ``[B]``, ``words`` five ``[B]``
        vectors, ``fire`` the predicated compiler's per-lane active mask
        (inactive lanes write nothing, count nothing, drop nothing)."""
        B, cap = events.shape[0], events.shape[1]
        ok = fire & (count < cap)
        idx = jnp.clip(count, 0, cap - 1).astype(jnp.int32)
        lanes = jnp.arange(B)
        row = jnp.stack([jnp.asarray(w, I64) for w in words], axis=-1)
        cur = events[lanes, idx]
        events = events.at[lanes, idx].set(jnp.where(ok[:, None], row, cur))
        count = count + ok.astype(count.dtype)
        drops = drops + (fire & ~ok).astype(drops.dtype)
        r0 = jnp.where(ok, 0, -1).astype(I64)
        return events, count, drops, r0


def ldctx_dyn(cv, idx):
    """The LDCTXR body: bounds-clamped register-indexed ctx read.  The
    verifier already rejected provably-OOB indices; the clamp covers the
    residual dynamic range exactly like the map-op loads do."""
    return cv.col_dyn(jnp.clip(idx, 0, CTX_LEN - 1))


def map_lookup(map_arrays, map_lens, slot: int, idx):
    """LDMAP body (static, lowering-resolved slot): bounds-checked lookup,
    out-of-range reads return 0 (missing key).  ``idx`` may be a scalar (JIT
    lane) or a ``[B]`` vector (predicated batch) — the same expression
    serves both."""
    arr = map_arrays[slot]
    ok = (idx >= 0) & (idx < map_lens[slot])
    safe = jnp.clip(idx, 0, arr.shape[0] - 1)
    return jnp.where(ok, arr[safe], 0)


def map_lookup_dyn(map_arrays, map_lens, mid, idx, zero):
    """LDMAPX body (map-in-map): the map id is a runtime-clamped register.
    Lowered as a masked accumulation over the registered maps — the one
    shape that vectorizes identically for scalar lanes and batches."""
    mid = jnp.clip(mid, 0, len(map_arrays) - 1).astype(jnp.int32)
    val = zero
    for k, arr in enumerate(map_arrays):
        ok = (idx >= 0) & (idx < map_lens[k]) & (mid == k)
        safe = jnp.clip(idx, 0, arr.shape[0] - 1)
        val = jnp.where(ok, arr[safe], val)
    return val


def helper_jnp(helper_id: int, reg, cv):
    """Helper-call lowering shared by the XLA backends.

    ``reg(i)`` reads register ``i`` in the caller's representation (scalar
    for the vmapped JIT, ``[B]`` for the predicated compiler); ``cv`` is the
    matching :class:`VecCtx`/:class:`BatchCtx`.  Must mirror the scalar
    bodies in :mod:`vm` bit for bit — this is the ONE copy the two compiled
    backends share, replacing the per-backend CALL switch arms."""
    from .vm import (HELPER_KTIME, HELPER_MIGRATE_COST,
                     HELPER_PROMOTION_COST, RB_HELPERS)
    if helper_id == HELPER_KTIME:
        return cv.col(CTX.KTIME_NS)
    if helper_id == HELPER_PROMOTION_COST:
        order = jnp.clip(reg(1), 0, 3)
        nblocks = jnp.asarray(4, I64) ** order
        zero = cv.col(CTX.ZERO_NS_PER_BLOCK) * nblocks
        free = cv.col_dyn(jnp.int32(CTX.FREE_BLOCKS_O0) + order.astype(jnp.int32))
        frag = cv.col_dyn(jnp.int32(CTX.FRAG_O0) + order.astype(jnp.int32))
        compact = (cv.col(CTX.COMPACT_NS_PER_BLOCK) * nblocks
                   * (1000 + frag) // 1000)
        return zero + jnp.where(free > 0, 0, compact)
    if helper_id == HELPER_MIGRATE_COST:
        order = jnp.clip(reg(1), 0, 3)
        nblocks = jnp.asarray(4, I64) ** order
        src = jnp.clip(reg(2), 0, MAX_TIERS - 1)
        dst = jnp.clip(reg(3), 0, MAX_TIERS - 1)
        lo = jnp.minimum(src, dst).astype(jnp.int32)
        hi = jnp.maximum(src, dst).astype(jnp.int32)
        setup = (cv.col_dyn(jnp.int32(CTX.MIG_CUM_SETUP_T0) + hi)
                 - cv.col_dyn(jnp.int32(CTX.MIG_CUM_SETUP_T0) + lo))
        per = (cv.col_dyn(jnp.int32(CTX.MIG_CUM_NS_T0) + hi)
               - cv.col_dyn(jnp.int32(CTX.MIG_CUM_NS_T0) + lo))
        return setup + per * nblocks
    if helper_id in RB_HELPERS:
        # the backends' CALL arms route these through CtxView.event_write
        # (they mutate the threaded event buffers, not just r0) — landing
        # here means a backend was miswired
        raise ValueError(f"ring-buffer helper {helper_id} must lower "
                         f"through event_write, not helper_jnp")
    # any future host-only facility: no-op on device
    return cv.zeros_like_lane()


def rb_words(helper_id: int, reg, cv):
    """The 5-word event record of a ring-buffer helper call, shared by both
    compiled backends: ``(ts, tag, a0, a1, a2)`` in the caller's lane shape.
    ``ts`` is the modeled clock from ctx — NOT wall time — so the record is
    bit-identical to the interpreter helper's."""
    from .vm import HELPER_TRACE
    ts = cv.col(CTX.KTIME_NS)
    if helper_id == HELPER_TRACE:
        return (ts, cv.lane(EV_PROG_TRACE), reg(1), cv.lane(0), cv.lane(0))
    return (ts, reg(1), reg(2), reg(3), reg(4))


def collect_rb_events(ev, cnt, drop, n: int) -> tuple[list, int]:
    """Host-side drain of a backend's per-lane event buffers: the records of
    the first ``n`` lanes (lane-major, slot order — exactly the order a
    scalar interpreter loop over the same rows appends) plus their summed
    slot-drop count.  ``ev [B, cap, RB_FIELDS]``, ``cnt``/``drop`` ``[B]``.
    """
    ev = np.asarray(ev)
    cnt = np.asarray(cnt)
    drop = np.asarray(drop)
    out: list = []
    for lane in range(min(n, ev.shape[0])):
        k = int(cnt[lane])
        for s in range(k):
            out.append(tuple(int(x) for x in ev[lane, s]))
    return out, int(drop[:n].sum())
