"""DAMON-analogue: data-access monitoring with controlled overhead.

The paper profiles workloads with DAMON to find hot regions and, online,
uses DAMON access frequency as the promotion-benefit proxy.  Our signal
source is better than sampled page faults: the paged-attention Pallas kernel
emits per-physical-block attention mass (softmax probability summed over the
block) essentially for free, and decode accesses are counted by the engine.

The region machinery is a faithful port of DAMON's design:
  * the monitored "address space" is a process's logical block range;
  * regions carry ``nr_accesses`` aggregated per sampling window;
  * adaptive regions: random split (budgeted by ``max_nr_regions``) and
    merge of adjacent regions whose access counts differ less than a
    threshold — this is what keeps monitoring overhead controlled and
    independent of address-space size.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from .context import NUM_ORDERS


@dataclass
class Region:
    start: int            # logical block, inclusive
    end: int              # exclusive
    nr_accesses: float = 0.0
    age: int = 0          # aggregation windows since last split/merge change

    def __len__(self) -> int:
        return self.end - self.start


class Damon:
    """Per-process access monitor over logical blocks."""

    def __init__(self, space_blocks: int, *, min_nr_regions: int = 10,
                 max_nr_regions: int = 100, merge_threshold: float = 0.15,
                 ema: float = 0.5, seed: int = 0) -> None:
        self.space_blocks = max(1, space_blocks)
        self.min_nr = min_nr_regions
        self.max_nr = max_nr_regions
        self.merge_threshold = merge_threshold
        self.ema = ema
        self._rng = np.random.default_rng(seed)
        n0 = min(self.min_nr, self.space_blocks)
        bounds = np.linspace(0, self.space_blocks, n0 + 1).astype(int)
        self.regions: list[Region] = [
            Region(int(a), int(b)) for a, b in zip(bounds, bounds[1:]) if b > a
        ]
        self.windows = 0
        # bumped whenever the region state changes; lets consumers (batched
        # ctx builders, the tier-scan ctx cache) reuse heat snapshots
        self.version = 0
        self._csum_cache: tuple[int, np.ndarray] | None = None

    # ----------------------------------------------------------- aggregation
    def record(self, heat_per_block: np.ndarray) -> None:
        """Aggregate one window of per-block heat into the regions.

        ``heat_per_block`` may be shorter than the space (tail = 0).  The
        per-region EMA runs as one vectorized pass (this is on the engine's
        per-step path, once per active sequence).
        """
        heat = np.asarray(heat_per_block, dtype=np.float64)
        csum = np.concatenate([[0.0], np.cumsum(heat)])
        n = len(self.regions)
        starts = np.fromiter((r.start for r in self.regions), np.int64, n)
        ends = np.fromiter((r.end for r in self.regions), np.int64, n)
        lo = np.minimum(starts, heat.size)
        hi = np.minimum(ends, heat.size)
        means = (csum[hi] - csum[lo]) / np.maximum(1, ends - starts)
        for r, mean in zip(self.regions, means):
            r.nr_accesses = self.ema * mean + (1 - self.ema) * r.nr_accesses
            r.age += 1
        self.windows += 1
        self._merge_regions()
        self._split_regions()
        self.version += 1

    def grow(self, new_space_blocks: int) -> None:
        """The monitored VMA grew (sequence got longer)."""
        if new_space_blocks <= self.space_blocks:
            return
        self.regions.append(Region(self.space_blocks, new_space_blocks))
        self.space_blocks = new_space_blocks
        self.version += 1

    # --------------------------------------------------- adaptive regions
    def _merge_regions(self) -> None:
        if len(self.regions) <= self.min_nr:
            return
        merged: list[Region] = []
        for r in sorted(self.regions, key=lambda x: x.start):
            if merged:
                prev = merged[-1]
                denom = max(prev.nr_accesses, r.nr_accesses, 1e-9)
                if (prev.end == r.start
                        and abs(prev.nr_accesses - r.nr_accesses) / denom
                        <= self.merge_threshold
                        and len(merged) + (len(self.regions) - len(merged)) > self.min_nr):
                    w1, w2 = len(prev), len(r)
                    prev.nr_accesses = (prev.nr_accesses * w1 + r.nr_accesses * w2) / (w1 + w2)
                    prev.end = r.end
                    prev.age = 0
                    continue
            merged.append(r)
        self.regions = merged

    def _split_regions(self) -> None:
        budget = self.max_nr - len(self.regions)
        if budget <= 0:
            return
        # DAMON splits at a random offset to discover sub-structure; all cut
        # offsets for this pass are drawn in one vectorized call
        splittable = [i for i, r in enumerate(self.regions)
                      if len(r) >= 2][:budget]
        if not splittable:
            return
        lens = np.fromiter((len(self.regions[i]) for i in splittable),
                           np.int64, len(splittable))
        cuts = self._rng.integers(1, lens)    # in [1, len)
        cut_at = dict(zip(splittable, cuts))
        out: list[Region] = []
        for i, r in enumerate(self.regions):
            if i in cut_at:
                cut = r.start + int(cut_at[i])
                out.append(Region(r.start, cut, r.nr_accesses, 0))
                out.append(Region(cut, r.end, r.nr_accesses, 0))
            else:
                out.append(r)
        self.regions = out

    # ------------------------------------------------------------- queries
    def _heat_csum(self) -> np.ndarray:
        """Cumulative per-block heat (``csum[i]`` = heat over blocks
        ``[0, i)``), cached per region-state version.  This is the single
        heat source both the scalar and the batched query paths read, so the
        two agree bit-for-bit."""
        if self._csum_cache is None or self._csum_cache[0] != self.version:
            dense = np.zeros(self.space_blocks, dtype=np.float64)
            for r in self.regions:
                dense[r.start:r.end] = r.nr_accesses
            csum = np.concatenate([[0.0], np.cumsum(dense)])
            self._csum_cache = (self.version, csum)
        return self._csum_cache[1]

    _SIZES = 4 ** np.arange(NUM_ORDERS, dtype=np.int64)   # [1, 4, 16, 64]

    def heat_many(self, addrs: np.ndarray, order: int) -> np.ndarray:
        """Vectorized ``heat_at`` over many addresses at one order."""
        addrs = np.asarray(addrs, dtype=np.int64)
        size = 4 ** order
        a = (addrs // size) * size
        csum = self._heat_csum()
        lo = np.minimum(a, self.space_blocks)
        hi = np.minimum(a + size, self.space_blocks)
        total = csum[hi] - csum[lo]
        covered = hi - lo
        return np.where(covered > 0, total / np.maximum(covered, 1), 0.0)

    def heat_at(self, addr: int, order: int) -> float:
        """Mean access count over the aligned order-k page enclosing ``addr``
        (area-weighted across overlapping monitor regions)."""
        return float(self.heat_many(np.asarray([addr]), order)[0])

    def heat_matrix(self, addrs: np.ndarray) -> np.ndarray:
        """``int64[N, NUM_ORDERS]`` heat of every address at every order —
        the batched-ctx-build form of ``heat_vector``, all orders in one
        broadcasted pass."""
        addrs = np.asarray(addrs, dtype=np.int64)[:, None]     # [N, 1]
        a = (addrs // self._SIZES) * self._SIZES               # [N, K]
        csum = self._heat_csum()
        lo = np.minimum(a, self.space_blocks)
        hi = np.minimum(a + self._SIZES, self.space_blocks)
        total = csum[hi] - csum[lo]
        covered = hi - lo
        heat = np.where(covered > 0, total / np.maximum(covered, 1), 0.0)
        return heat.astype(np.int64)

    def heat_vector(self, addr: int) -> tuple[int, ...]:
        return tuple(int(self.heat_at(addr, k)) for k in range(NUM_ORDERS))

    def snapshot(self) -> list[tuple[int, int, float]]:
        return [(r.start, r.end, r.nr_accesses)
                for r in sorted(self.regions, key=lambda x: x.start)]
