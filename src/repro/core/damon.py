"""DAMON-analogue: data-access monitoring with controlled overhead.

The paper profiles workloads with DAMON to find hot regions and, online,
uses DAMON access frequency as the promotion-benefit proxy.  Our signal
source is better than sampled page faults: the paged-attention Pallas kernel
emits per-physical-block attention mass (softmax probability summed over the
block) essentially for free, and decode accesses are counted by the engine.

The region machinery is a faithful port of DAMON's design:
  * the monitored "address space" is a process's logical block range;
  * regions carry ``nr_accesses`` aggregated per sampling window;
  * adaptive regions: random split (budgeted by ``max_nr_regions``) and
    merge of adjacent regions whose access counts differ less than a
    threshold — this is what keeps monitoring overhead controlled and
    independent of address-space size.
"""

from __future__ import annotations

import random
from dataclasses import dataclass, field

import numpy as np

from .context import NUM_ORDERS


@dataclass
class Region:
    start: int            # logical block, inclusive
    end: int              # exclusive
    nr_accesses: float = 0.0
    age: int = 0          # aggregation windows since last split/merge change

    def __len__(self) -> int:
        return self.end - self.start


class Damon:
    """Per-process access monitor over logical blocks."""

    def __init__(self, space_blocks: int, *, min_nr_regions: int = 10,
                 max_nr_regions: int = 100, merge_threshold: float = 0.15,
                 ema: float = 0.5, seed: int = 0) -> None:
        self.space_blocks = max(1, space_blocks)
        self.min_nr = min_nr_regions
        self.max_nr = max_nr_regions
        self.merge_threshold = merge_threshold
        self.ema = ema
        self._rng = random.Random(seed)
        n0 = min(self.min_nr, self.space_blocks)
        bounds = np.linspace(0, self.space_blocks, n0 + 1).astype(int)
        self.regions: list[Region] = [
            Region(int(a), int(b)) for a, b in zip(bounds, bounds[1:]) if b > a
        ]
        self.windows = 0

    # ----------------------------------------------------------- aggregation
    def record(self, heat_per_block: np.ndarray) -> None:
        """Aggregate one window of per-block heat into the regions.

        ``heat_per_block`` may be shorter than the space (tail = 0).
        """
        heat = np.asarray(heat_per_block, dtype=np.float64)
        csum = np.concatenate([[0.0], np.cumsum(heat)])

        def span_sum(a: int, b: int) -> float:
            a = min(a, heat.size)
            b = min(b, heat.size)
            return float(csum[b] - csum[a]) if b > a else 0.0

        for r in self.regions:
            mean = span_sum(r.start, r.end) / max(1, len(r))
            r.nr_accesses = self.ema * mean + (1 - self.ema) * r.nr_accesses
            r.age += 1
        self.windows += 1
        self._merge_regions()
        self._split_regions()

    def grow(self, new_space_blocks: int) -> None:
        """The monitored VMA grew (sequence got longer)."""
        if new_space_blocks <= self.space_blocks:
            return
        self.regions.append(Region(self.space_blocks, new_space_blocks))
        self.space_blocks = new_space_blocks

    # --------------------------------------------------- adaptive regions
    def _merge_regions(self) -> None:
        if len(self.regions) <= self.min_nr:
            return
        merged: list[Region] = []
        for r in sorted(self.regions, key=lambda x: x.start):
            if merged:
                prev = merged[-1]
                denom = max(prev.nr_accesses, r.nr_accesses, 1e-9)
                if (prev.end == r.start
                        and abs(prev.nr_accesses - r.nr_accesses) / denom
                        <= self.merge_threshold
                        and len(merged) + (len(self.regions) - len(merged)) > self.min_nr):
                    w1, w2 = len(prev), len(r)
                    prev.nr_accesses = (prev.nr_accesses * w1 + r.nr_accesses * w2) / (w1 + w2)
                    prev.end = r.end
                    prev.age = 0
                    continue
            merged.append(r)
        self.regions = merged

    def _split_regions(self) -> None:
        budget = self.max_nr - len(self.regions)
        if budget <= 0:
            return
        out: list[Region] = []
        for r in self.regions:
            if budget > 0 and len(r) >= 2:
                # DAMON splits at a random offset to discover sub-structure
                cut = r.start + self._rng.randint(1, len(r) - 1)
                out.append(Region(r.start, cut, r.nr_accesses, 0))
                out.append(Region(cut, r.end, r.nr_accesses, 0))
                budget -= 1
            else:
                out.append(r)
        self.regions = out

    # ------------------------------------------------------------- queries
    def heat_at(self, addr: int, order: int) -> float:
        """Mean access count over the aligned order-k page enclosing ``addr``
        (area-weighted across overlapping monitor regions)."""
        size = 4 ** order
        a = (addr // size) * size
        b = a + size
        total, covered = 0.0, 0
        for r in self.regions:
            lo, hi = max(a, r.start), min(b, r.end)
            if hi > lo:
                total += r.nr_accesses * (hi - lo)
                covered += hi - lo
        return total / max(1, covered)

    def heat_vector(self, addr: int) -> tuple[int, ...]:
        return tuple(int(self.heat_at(addr, k)) for k in range(NUM_ORDERS))

    def snapshot(self) -> list[tuple[int, int, float]]:
        return [(r.start, r.end, r.nr_accesses)
                for r in sorted(self.regions, key=lambda x: x.start)]
