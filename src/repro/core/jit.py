"""JIT: compile a verified policy program to a jnp function.

Linux runs one eBPF invocation per page fault.  On a TPU serving tier the
scheduler frequently has to resolve *hundreds* of faults per engine step
(every sequence that crossed a block boundary).  Because verified programs
are bounded, we can compile the bytecode to XLA once and ``vmap`` it over the
whole fault batch — a beyond-paper optimization recorded in EXPERIMENTS.md.
The tiered-memory migration engine (:mod:`repro.core.tiering`) runs its
demote/promote scans through the same batch path: one compiled mm_tier
program vets every candidate page in a single vectorized call.

Compilation strategy: the program becomes an instruction-pointer machine
  state = (pc, regs[11], fuel)
  lax.while_loop(pc != EXIT_PC, lax.switch(pc, per-insn updates))
with every instruction lowered to a tiny pure function.  Verified programs
terminate within ``max_steps``, so fuel gives a hard bound that also lets
``vmap`` batch lanes with divergent control flow (lanes that finish early
spin on EXIT until all are done).

Maps are passed in as padded int64 arrays (capacity-sized), so profile
updates from userspace do NOT trigger recompilation — only reloading data.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .context import CTX, MAX_TIERS
from .isa import (ALU_IMM_OPS, ALU_REG_OPS, COND_JUMP_IMM, COND_JUMP_REG,
                  NUM_REGS, Insn, Op, Program)
from .maps import MapRegistry
from .vm import (HELPER_IDS, HELPER_KTIME, HELPER_MIGRATE_COST,
                 HELPER_PROMOTION_COST, HELPER_TRACE, _IMM2REG, _JIMM2REG)
from .verifier import verify

I64 = jnp.int64


def _alu_jnp(op: Op, a, b):
    if op == Op.MOV:
        return b
    if op == Op.ADD:
        return a + b
    if op == Op.SUB:
        return a - b
    if op == Op.MUL:
        return a * b
    if op == Op.DIV:
        # truncated signed division toward zero, x/0 == 0
        q = jnp.where(b == 0, 0, jnp.abs(a) // jnp.where(b == 0, 1, jnp.abs(b)))
        return jnp.where((a < 0) != (b < 0), -q, q).astype(a.dtype)
    if op == Op.MOD:
        r = jnp.abs(a) % jnp.where(b == 0, 1, jnp.abs(b))
        r = jnp.where(a < 0, -r, r).astype(a.dtype)
        return jnp.where(b == 0, a, r)
    if op == Op.AND:
        return a & b
    if op == Op.OR:
        return a | b
    if op == Op.XOR:
        return a ^ b
    if op == Op.LSH:
        return a << (b & 63)
    if op == Op.RSH:
        ua = a.astype(jnp.uint64)
        return (ua >> (b.astype(jnp.uint64) & 63)).astype(a.dtype)
    if op == Op.MIN:
        return jnp.minimum(a, b)
    if op == Op.MAX:
        return jnp.maximum(a, b)
    raise ValueError(f"bad ALU op {op}")


def _cmp_jnp(op: Op, a, b):
    if op == Op.JEQ:
        return a == b
    if op == Op.JNE:
        return a != b
    if op == Op.JLT:
        return a < b
    if op == Op.JLE:
        return a <= b
    if op == Op.JGT:
        return a > b
    if op == Op.JGE:
        return a >= b
    if op == Op.JSET:
        return (a & b) != 0
    raise ValueError(f"bad cmp op {op}")


def compile_program(program: Program, maps: MapRegistry):
    """Compile to ``fn(ctx_vec, map_arrays, map_lens) -> r0`` (all jnp).

    The returned function is jit/vmap-compatible.  ``map_arrays`` is a tuple
    of capacity-padded int64 arrays, ``map_lens`` an int64 vector of live
    lengths (dynamic, so userspace can reload profiles without recompiling).
    """
    facts = verify(program, num_maps=len(maps), map_lens=maps.lens(),
                   helper_ids=HELPER_IDS)
    insns = list(program.insns)
    n = len(insns)
    exit_pc = n  # virtual halt pc

    def make_step(pc: int, insn: Insn):
        op = insn.op

        def step(state, ctx, map_arrays, map_lens):
            regs = state["regs"]
            if op in ALU_REG_OPS:
                val = _alu_jnp(op, regs[insn.dst], regs[insn.src])
                regs = regs.at[insn.dst].set(val)
                return dict(state, regs=regs, pc=jnp.int32(pc + 1))
            if op in ALU_IMM_OPS:
                imm = jnp.asarray(insn.imm, I64)
                if op == Op.MOVI:
                    val = imm
                else:
                    val = _alu_jnp(_IMM2REG[op], regs[insn.dst], imm)
                regs = regs.at[insn.dst].set(val)
                return dict(state, regs=regs, pc=jnp.int32(pc + 1))
            if op == Op.NEG:
                regs = regs.at[insn.dst].set(-regs[insn.dst])
                return dict(state, regs=regs, pc=jnp.int32(pc + 1))
            if op == Op.LDCTX:
                regs = regs.at[insn.dst].set(ctx[insn.imm])
                return dict(state, regs=regs, pc=jnp.int32(pc + 1))
            if op == Op.LDMAP:
                arr = map_arrays[insn.src2]
                idx = regs[insn.src]
                ok = (idx >= 0) & (idx < map_lens[insn.src2])
                safe = jnp.clip(idx, 0, arr.shape[0] - 1)
                val = jnp.where(ok, arr[safe], 0)
                regs = regs.at[insn.dst].set(val)
                return dict(state, regs=regs, pc=jnp.int32(pc + 1))
            if op == Op.LDMAPX:
                nmaps = len(map_arrays)
                mid = jnp.clip(regs[insn.src2], 0, nmaps - 1).astype(jnp.int32)
                idx = regs[insn.src]

                def mk(arr, j):
                    def br(_):
                        ok = (idx >= 0) & (idx < map_lens[j])
                        safe = jnp.clip(idx, 0, arr.shape[0] - 1)
                        return jnp.where(ok, arr[safe], 0)
                    return br
                val = jax.lax.switch(
                    mid, [mk(a, j) for j, a in enumerate(map_arrays)], 0)
                regs = regs.at[insn.dst].set(val)
                return dict(state, regs=regs, pc=jnp.int32(pc + 1))
            if op == Op.MAPSZ:
                regs = regs.at[insn.dst].set(map_lens[insn.imm])
                return dict(state, regs=regs, pc=jnp.int32(pc + 1))
            if op == Op.JA:
                return dict(state, pc=jnp.int32(pc + 1 + insn.imm))
            if op in COND_JUMP_REG or op in COND_JUMP_IMM:
                if op in COND_JUMP_REG:
                    taken = _cmp_jnp(op, regs[insn.dst], regs[insn.src])
                else:
                    taken = _cmp_jnp(_JIMM2REG[op], regs[insn.dst],
                                     jnp.asarray(insn.src2, I64))
                nxt = jnp.where(taken, pc + 1 + insn.imm, pc + 1).astype(jnp.int32)
                return dict(state, pc=nxt)
            if op == Op.JNZDEC:
                newv = regs[insn.dst] - 1
                regs = regs.at[insn.dst].set(newv)
                nxt = jnp.where(newv != 0, pc + 1 + insn.imm, pc + 1).astype(jnp.int32)
                return dict(state, regs=regs, pc=nxt)
            if op == Op.CALL:
                if insn.imm == HELPER_KTIME:
                    r0 = ctx[CTX.KTIME_NS]
                elif insn.imm == HELPER_PROMOTION_COST:
                    order = jnp.clip(regs[1], 0, 3)
                    nblocks = jnp.asarray(4, I64) ** order
                    zero = ctx[CTX.ZERO_NS_PER_BLOCK] * nblocks
                    free = _dyn(ctx, CTX.FREE_BLOCKS_O0, order)
                    frag = _dyn(ctx, CTX.FRAG_O0, order)
                    compact = (ctx[CTX.COMPACT_NS_PER_BLOCK] * nblocks
                               * (1000 + frag) // 1000)
                    r0 = zero + jnp.where(free > 0, 0, compact)
                elif insn.imm == HELPER_MIGRATE_COST:
                    order = jnp.clip(regs[1], 0, 3)
                    nblocks = jnp.asarray(4, I64) ** order
                    src = jnp.clip(regs[2], 0, MAX_TIERS - 1)
                    dst = jnp.clip(regs[3], 0, MAX_TIERS - 1)
                    lo = jnp.minimum(src, dst)
                    hi = jnp.maximum(src, dst)
                    setup = (_dyn(ctx, CTX.MIG_CUM_SETUP_T0, hi)
                             - _dyn(ctx, CTX.MIG_CUM_SETUP_T0, lo))
                    per = (_dyn(ctx, CTX.MIG_CUM_NS_T0, hi)
                           - _dyn(ctx, CTX.MIG_CUM_NS_T0, lo))
                    r0 = setup + per * nblocks
                elif insn.imm == HELPER_TRACE:
                    r0 = jnp.asarray(0, I64)  # trace is a host-only facility
                else:  # pragma: no cover - verifier rejects unknown helpers
                    raise ValueError(f"unknown helper {insn.imm}")
                regs = regs.at[0].set(r0)
                return dict(state, regs=regs, pc=jnp.int32(pc + 1))
            if op == Op.EXIT:
                return dict(state, pc=jnp.int32(exit_pc))
            raise ValueError(f"unhandled opcode {op}")

        return step

    steps = [make_step(pc, insn) for pc, insn in enumerate(insns)]

    def halt_step(state, ctx, map_arrays, map_lens):
        return state

    branches = steps + [halt_step]

    fuel0 = facts["max_steps"] + 8

    def run(ctx, map_arrays, map_lens):
        ctx = jnp.asarray(ctx, I64)
        state = {
            "pc": jnp.int32(0),
            "regs": jnp.zeros(NUM_REGS, I64),
            "fuel": jnp.int32(fuel0),
        }

        def cond(state):
            return (state["pc"] != exit_pc) & (state["fuel"] > 0)

        def body(state):
            new = jax.lax.switch(state["pc"], branches, state, ctx,
                                 map_arrays, map_lens)
            new["fuel"] = state["fuel"] - 1
            return new

        final = jax.lax.while_loop(cond, body, state)
        return final["regs"][0]

    return run, facts


def _dyn(ctx, base: int, order):
    """ctx[base + order] with a traced order."""
    return jax.lax.dynamic_index_in_dim(ctx, jnp.int32(base) + order.astype(jnp.int32),
                                        keepdims=False)


class JitPolicy:
    """Convenience wrapper: compiled program + its maps, batched execution."""

    def __init__(self, program: Program, maps: MapRegistry) -> None:
        self.maps = maps
        self._fn, self.facts = compile_program(program, maps)
        self._batched = jax.jit(jax.vmap(self._fn, in_axes=(0, None, None)))
        self._single = jax.jit(self._fn)
        self._map_cache: tuple | None = None   # (version, arrays, lens)

    def _map_args(self):
        ver = self.maps.version()
        if self._map_cache is None or self._map_cache[0] != ver:
            arrays = tuple(jnp.asarray(self.maps[i].live_array())
                           for i in range(len(self.maps)))
            lens = jnp.asarray(self.maps.lens(), I64)
            if not arrays:
                arrays = (jnp.zeros(1, I64),)
                lens = jnp.zeros(1, I64)
            self._map_cache = (ver, arrays, lens)
        return self._map_cache[1], self._map_cache[2]

    def run(self, ctx_vec: np.ndarray) -> int:
        # enable_x64 scopes true 64-bit ALU semantics to the policy VM without
        # flipping global dtype promotion for the rest of the framework.
        with jax.experimental.enable_x64():
            arrays, lens = self._map_args()
            return int(self._single(jnp.asarray(ctx_vec, I64), arrays, lens))

    def run_batch(self, ctx_mat: np.ndarray) -> np.ndarray:
        """ctx_mat: [batch, CTX_LEN] -> int64[batch] decisions."""
        with jax.experimental.enable_x64():
            arrays, lens = self._map_args()
            return np.asarray(self._batched(jnp.asarray(ctx_mat, I64), arrays, lens))
