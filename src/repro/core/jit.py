"""JIT: compile a verified policy program to a jnp function.

Linux runs one eBPF invocation per page fault.  On a TPU serving tier the
scheduler frequently has to resolve *hundreds* of faults per engine step
(every sequence that crossed a block boundary).  Because verified programs
are bounded, we can compile the bytecode to XLA once and ``vmap`` it over the
whole fault batch — a beyond-paper optimization recorded in EXPERIMENTS.md.
The tiered-memory migration engine (:mod:`repro.core.tiering`) runs its
demote/promote scans through the same batch path: one compiled mm_tier
program vets every candidate page in a single vectorized call.

Compilation strategy: the program becomes an instruction-pointer machine
  state = (pc, regs[11], fuel)
  lax.while_loop(pc != EXIT_PC, lax.switch(pc, per-insn updates))
with every instruction lowered to a tiny pure function.  Verified programs
terminate within ``max_steps``, so fuel gives a hard bound that also lets
``vmap`` batch lanes with divergent control flow (lanes that finish early
spin on EXIT until all are done).

Since the unified pipeline, this backend consumes the shared lowered IR
(:mod:`repro.core.lower`): one verifier pass, absolute branch targets,
resolved map slots — and the per-op LDCTX/LDCTXR/helper/map-op bodies are
the SAME functions the predicated compiler lowers through (``alu_jnp``,
``helper_jnp``, ``map_lookup``...), so the two compiled executors cannot
drift apart opcode by opcode.

Maps are passed in as padded int64 arrays (capacity-sized), so profile
updates from userspace do NOT trigger recompilation — only reloading data.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .isa import (ALU_IMM_OPS, ALU_REG_OPS, COND_JUMP_IMM, COND_JUMP_REG,
                  NUM_REGS, Op, Program)
from .lower import (LIns, LoweredProgram, RB_FIELDS, VecCtx,
                    alu_jnp as _alu_jnp, cmp_jnp as _cmp_jnp,
                    collect_rb_events, helper_jnp, ldctx_dyn, lower,
                    map_lookup, map_lookup_dyn, rb_words)
from .maps import MapRegistry
from .vm import _IMM2REG, _JIMM2REG, RB_HELPERS

I64 = jnp.int64


def compile_program(program: Program | LoweredProgram, maps: MapRegistry):
    """Compile to ``fn(ctx_vec, map_arrays, map_lens) -> r0`` (all jnp).

    The returned function is jit/vmap-compatible.  ``map_arrays`` is a tuple
    of capacity-padded int64 arrays, ``map_lens`` an int64 vector of live
    lengths (dynamic, so userspace can reload profiles without recompiling).

    Programs that call a ring-buffer helper (``facts["rb_cap"] > 0``) thread
    a per-lane event-slot buffer through the machine state, and the compiled
    function returns ``(r0, events [rb_cap, 5], count, drops)`` instead of
    bare ``r0`` — callers drain the extra outputs host-side.  Programs that
    never emit keep the original state/signature exactly.
    """
    lp = program if isinstance(program, LoweredProgram) else \
        lower(program, maps)
    insns = list(lp.insns)
    n = len(insns)
    exit_pc = n  # virtual halt pc
    rb_cap = int(lp.facts.get("rb_cap", 0))

    def make_step(pc: int, insn: LIns):
        op = insn.op

        def step(state, ctx, map_arrays, map_lens):
            regs = state["regs"]
            cv = VecCtx(ctx)
            if op in ALU_REG_OPS:
                val = _alu_jnp(op, regs[insn.dst], regs[insn.src])
                regs = regs.at[insn.dst].set(val)
                return dict(state, regs=regs, pc=jnp.int32(pc + 1))
            if op in ALU_IMM_OPS:
                imm = jnp.asarray(insn.imm, I64)
                if op == Op.MOVI:
                    val = imm
                else:
                    val = _alu_jnp(_IMM2REG[op], regs[insn.dst], imm)
                regs = regs.at[insn.dst].set(val)
                return dict(state, regs=regs, pc=jnp.int32(pc + 1))
            if op == Op.NEG:
                regs = regs.at[insn.dst].set(-regs[insn.dst])
                return dict(state, regs=regs, pc=jnp.int32(pc + 1))
            if op == Op.LDCTX:
                regs = regs.at[insn.dst].set(cv.col(insn.imm))
                return dict(state, regs=regs, pc=jnp.int32(pc + 1))
            if op == Op.LDCTXR:
                regs = regs.at[insn.dst].set(ldctx_dyn(cv, regs[insn.src]))
                return dict(state, regs=regs, pc=jnp.int32(pc + 1))
            if op == Op.LDMAP:
                val = map_lookup(map_arrays, map_lens, insn.imm,
                                 regs[insn.src])
                regs = regs.at[insn.dst].set(val)
                return dict(state, regs=regs, pc=jnp.int32(pc + 1))
            if op == Op.LDMAPX:
                val = map_lookup_dyn(map_arrays, map_lens, regs[insn.src2],
                                     regs[insn.src], cv.zeros_like_lane())
                regs = regs.at[insn.dst].set(val)
                return dict(state, regs=regs, pc=jnp.int32(pc + 1))
            if op == Op.MAPSZ:
                regs = regs.at[insn.dst].set(map_lens[insn.imm])
                return dict(state, regs=regs, pc=jnp.int32(pc + 1))
            if op == Op.JA:
                return dict(state, pc=jnp.int32(insn.target))
            if op in COND_JUMP_REG or op in COND_JUMP_IMM:
                if op in COND_JUMP_REG:
                    taken = _cmp_jnp(op, regs[insn.dst], regs[insn.src])
                else:
                    taken = _cmp_jnp(_JIMM2REG[op], regs[insn.dst],
                                     jnp.asarray(insn.src2, I64))
                nxt = jnp.where(taken, insn.target, pc + 1).astype(jnp.int32)
                return dict(state, pc=nxt)
            if op == Op.JNZDEC:
                newv = regs[insn.dst] - 1
                regs = regs.at[insn.dst].set(newv)
                nxt = jnp.where(newv != 0, insn.target, pc + 1).astype(jnp.int32)
                return dict(state, regs=regs, pc=nxt)
            if op == Op.CALL:
                if rb_cap and insn.imm in RB_HELPERS:
                    words = rb_words(insn.imm, lambda i: regs[i], cv)
                    ev, cnt, dr, r0 = cv.event_write(
                        state["ev"], state["ecnt"], state["edrop"], words,
                        True)
                    regs = regs.at[0].set(r0)
                    return dict(state, regs=regs, ev=ev, ecnt=cnt, edrop=dr,
                                pc=jnp.int32(pc + 1))
                r0 = helper_jnp(insn.imm, lambda i: regs[i], cv)
                regs = regs.at[0].set(r0)
                return dict(state, regs=regs, pc=jnp.int32(pc + 1))
            if op == Op.EXIT:
                return dict(state, pc=jnp.int32(exit_pc))
            raise ValueError(f"unhandled opcode {op}")

        return step

    steps = [make_step(pc, insn) for pc, insn in enumerate(insns)]

    def halt_step(state, ctx, map_arrays, map_lens):
        return state

    branches = steps + [halt_step]

    fuel0 = lp.facts["max_steps"] + 8

    def run(ctx, map_arrays, map_lens):
        ctx = jnp.asarray(ctx, I64)
        state = {
            "pc": jnp.int32(0),
            "regs": jnp.zeros(NUM_REGS, I64),
            "fuel": jnp.int32(fuel0),
        }
        if rb_cap:
            state["ev"] = jnp.zeros((rb_cap, RB_FIELDS), I64)
            state["ecnt"] = jnp.zeros((), I64)
            state["edrop"] = jnp.zeros((), I64)

        def cond(state):
            return (state["pc"] != exit_pc) & (state["fuel"] > 0)

        def body(state):
            new = jax.lax.switch(state["pc"], branches, state, ctx,
                                 map_arrays, map_lens)
            new["fuel"] = state["fuel"] - 1
            return new

        final = jax.lax.while_loop(cond, body, state)
        if rb_cap:
            return (final["regs"][0], final["ev"], final["ecnt"],
                    final["edrop"])
        return final["regs"][0]

    return run, lp.facts


class JitPolicy:
    """Convenience wrapper: compiled program + its maps, batched execution."""

    def __init__(self, program: Program | LoweredProgram,
                 maps: MapRegistry) -> None:
        self.maps = maps
        self._fn, self.facts = compile_program(program, maps)
        self._batched = jax.jit(jax.vmap(self._fn, in_axes=(0, None, None)))
        self._single = jax.jit(self._fn)
        self._map_cache: tuple | None = None   # (version, arrays, lens)
        self.rb_cap = int(self.facts.get("rb_cap", 0))
        self._last_rb: tuple | None = None     # (ev, cnt, drops) device arrays

    def _map_args(self):
        ver = self.maps.version()
        if self._map_cache is None or self._map_cache[0] != ver:
            arrays = tuple(jnp.asarray(self.maps[i].live_array())
                           for i in range(len(self.maps)))
            lens = jnp.asarray(self.maps.lens(), I64)
            if not arrays:
                arrays = (jnp.zeros(1, I64),)
                lens = jnp.zeros(1, I64)
            self._map_cache = (ver, arrays, lens)
        return self._map_cache[1], self._map_cache[2]

    def run(self, ctx_vec: np.ndarray) -> int:
        # enable_x64 scopes true 64-bit ALU semantics to the policy VM without
        # flipping global dtype promotion for the rest of the framework.
        with jax.experimental.enable_x64():
            arrays, lens = self._map_args()
            out = self._single(jnp.asarray(ctx_vec, I64), arrays, lens)
            if self.rb_cap:
                r0, ev, cnt, dr = out
                self._last_rb = (ev[None], cnt[None], dr[None])
                return int(r0)
            return int(out)

    def run_batch(self, ctx_mat: np.ndarray) -> np.ndarray:
        """ctx_mat: [batch, CTX_LEN] -> int64[batch] decisions."""
        with jax.experimental.enable_x64():
            arrays, lens = self._map_args()
            out = self._batched(jnp.asarray(ctx_mat, I64), arrays, lens)
            if self.rb_cap:
                r0, ev, cnt, dr = out
                self._last_rb = (ev, cnt, dr)
                return np.asarray(r0)
            return np.asarray(out)

    def take_events(self, n: int) -> tuple[list, int]:
        """Drain the last run's ring-buffer records for the first ``n``
        lanes (and their slot-drop count); empty until the next run."""
        if self._last_rb is None:
            return [], 0
        ev, cnt, dr = self._last_rb
        self._last_rb = None
        return collect_rb_events(ev, cnt, dr, n)
