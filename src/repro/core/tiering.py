"""Tiered memory: eBPF-guided placement over an N-pool tier graph.

The paper names page placement across memory tiers as the natural next hook
after the fault-path page-size hook and stubs it as ``HOOK_TIER``.  This
module implements that subsystem over an N-pool tier chain — local HBM
(tier 0) plus up to three spill tiers (peer-HBM over ICI, host DRAM over
PCIe, NVMe), each with its own buddy allocator — a
:class:`TieredMemoryManager` over :class:`~repro.core.mm.MemoryManager`
whose :class:`PageMapping`\\ s carry a tier id, and a migration engine that
emits explicit move lists the device executes with the block_copy kernel,
with per-edge bandwidth/setup costs accounted in the
:class:`~repro.core.cost.CostModel` edge table.

Device addressing: the engine materializes ONE combined pool of
``sum(pool sizes)`` base blocks.  Indices ``[0, num_blocks)`` are HBM; each
spill tier occupies the next contiguous span (pinned mirrors the device can
DMA from at that tier's link bandwidth — charged by the cost model, while
the copies themselves stay exact).  Tier crossings are therefore ordinary
``(src, dst, order)`` moves in combined coordinates and reuse the existing
``drain_moves`` / block_copy path unchanged.  Multi-hop crossings
(NVMe -> DRAM -> HBM) chain through intermediate pools hop by hop when they
have room — each hop is its own move, batched through the same pre-kernel
flush — and hop OVER a full intermediate tier (the link is still traversed
and charged) when they don't.

Policy: every migration/placement decision is delegated to the verified
program attached to ``HOOK_TIER`` (TierBPF-style per-edge admission
control).  The program sees a :class:`~repro.core.context.FaultContext`
describing the candidate page (tier, order, DAMON heat, age) plus every
pool's real-time state and the cumulative per-edge migration cost tables,
and returns the TARGET TIER id the page should live in (0 = HBM; the
manager clamps to the live topology and migrates hop by hop).  Prefill-time
placement: ``fault_batch``/``ensure_range`` consult ``HOOK_TIER`` once per
prefill batch so profiles can place cold prefixes directly in the far tiers
instead of defaulting to HBM.  With nothing attached, a kernel-default
policy runs without building the ctx at all — the paper's zero-overhead
property, extended to the new hook.  Decisions over many candidates run
through the vectorized JIT batch path.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .buddy import BuddyAllocator, BuddyError, order_blocks
from .context import (CTX, FIXED_POINT, MAX_TIERS, NUM_ORDERS,
                      POLICY_DETACHED, POLICY_FALLBACK, TIER_KEEP,
                      FaultContext, FaultKind, ctx_batch, fill_system_columns)
from .cost import CostModel, TierSpec, host_dram_tier
from .hooks import HOOK_TIER
from .mm import MemoryManager, PageMapping, ProcessState
from ..obs.ringbuf import EV_MIGRATE_HOP, EV_QUARANTINE, EV_READMIT, EV_RETRY
from ..resilience.faults import SITE_MIGRATE_COPY, SITE_TIER_ALLOC
from ..resilience.health import TierHealthMonitor

TIER_HBM = 0
TIER_HOST = 1     # the first spill tier of the classic 2-pool topology

# Bounded migration retry: a hop copy that fails (injected link error or a
# flap window) is retried up to this many times, each failed attempt
# charging exponentially growing backoff in MODELED time before the next.
# The no-containment baseline (containment=False) gets a single shot.
MIGRATE_MAX_ATTEMPTS = 3
RETRY_BACKOFF_NS = 500_000      # first-retry backoff; doubles per attempt


@dataclass
class TierConfig:
    """Migration-engine knobs (throttles, like khugepaged's)."""
    promote_blocks_per_tick: int = 16    # promotion-scan budget per engine tick
    demote_chunk_blocks: int = 16        # min HBM blocks to free per reclaim event
    batch_threshold: int = 4             # >= this many candidates -> JIT batch path


class TieredMemoryManager(MemoryManager):
    """MemoryManager with an N-pool tier chain behind HOOK_TIER.

    HBM pages live in ``self.pools[0]`` (== ``self.buddy``, tier 0), spill
    tiers 1..N-1 in ``self.pools[1:]`` (peer-HBM / host DRAM / NVMe — the
    topology comes from ``tiers`` or defaults to the classic single
    host-DRAM pool of ``host_blocks``).  ``phys_start`` of a mapping is
    always an index within its own tier's pool; :meth:`_device_index` folds
    all pools into the combined device pool the engine materializes.
    """

    def __init__(self, num_blocks: int, cost: CostModel, *,
                 host_blocks: int = 0, tiers=None,
                 tier_cfg: TierConfig | None = None, **kw) -> None:
        super().__init__(num_blocks, cost, **kw)
        if tiers is None:
            if host_blocks <= 0:
                raise ValueError("host_blocks must be positive (use "
                                 "MemoryManager for an untiered pool)")
            tiers = (host_dram_tier(cost.hw, host_blocks),)
        tiers = tuple(tiers)
        if not 1 <= len(tiers) <= MAX_TIERS - 1:
            raise ValueError(f"need 1..{MAX_TIERS - 1} spill tiers")
        if any(s.blocks <= 0 for s in tiers):
            raise ValueError("every spill tier needs positive capacity")
        # the cost model's per-edge table must describe the same chain the
        # pools are built from (bpf_mm_migrate_cost == engine accounting);
        # a CostModel already bound to a DIFFERENT chain would silently
        # re-cost another manager's pools, so that reuse is rejected
        if cost.topology is not None and tuple(cost.topology) != tiers:
            raise ValueError(
                "CostModel is already bound to a different tier topology; "
                "use a fresh CostModel per tier chain")
        cost.topology = tiers
        self.tier_specs: tuple[TierSpec, ...] = tiers
        self.pools: list[BuddyAllocator] = [self.buddy] + [
            BuddyAllocator(s.blocks, max_order=self.max_order) for s in tiers]
        # device base-block offset of each tier in the combined pool
        self._tier_base = [0]
        for p in self.pools[:-1]:
            self._tier_base.append(self._tier_base[-1] + p.num_blocks)
        self.tier_cfg = tier_cfg or TierConfig()
        # Per-edge link health: error counters + exponential-backoff
        # quarantine keyed on the modeled clock; quarantine routing (and the
        # degraded demote fallback) is disabled on the no-containment
        # baseline but errors are still counted.
        self.health = TierHealthMonitor(len(tiers), cost.edge_names(),
                                        quarantine=self.containment)
        # (pid, logical_start) -> ktime_ns of the last tier change / install
        self._tier_stamp: dict[tuple[int, int], int] = {}
        # Scan-ctx cache: the per-candidate columns of a tier-scan ctx matrix
        # (heat, identity, geometry) are reused across ticks while the
        # candidate set and every involved DAMON monitor are unchanged; only
        # the time-varying columns (clock, age, pool state) are refreshed.
        self._scan_ctx_cache: dict[str, tuple] = {}
        self.ctx_cache_hits = 0
        self.ctx_cache_misses = 0

    # --------------------------------------------------------------- geometry
    @property
    def ntiers(self) -> int:
        return len(self.pools)

    @property
    def host_buddy(self) -> BuddyAllocator:
        """The first spill pool (the classic host-DRAM tier)."""
        return self.pools[TIER_HOST]

    @property
    def host_blocks(self) -> int:
        return self.pools[TIER_HOST].num_blocks

    @property
    def device_pool_blocks(self) -> int:
        """Size of the combined device pool (HBM + every spill mirror)."""
        return sum(p.num_blocks for p in self.pools)

    def _device_index(self, m: PageMapping) -> int:
        return self._tier_base[m.tier] + m.phys_start

    def _free_phys(self, m: PageMapping) -> None:
        # shared (prefix-cache-borrowed) pages belong to the cache, not the
        # borrower's page table — same contract as the base manager
        if m.shared:
            return
        self.pools[m.tier].free(m.phys_start)

    def free_process(self, pid: int) -> None:
        super().free_process(pid)
        self._tier_stamp = {k: v for k, v in self._tier_stamp.items()
                            if k[0] != pid}

    def unmap(self, pid: int, logical_start: int) -> None:
        super().unmap(pid, logical_start)
        self._tier_stamp.pop((pid, logical_start), None)

    def _install(self, st, addr, order, hinted):
        r = super()._install(st, addr, order, hinted)
        a = (addr // order_blocks(r.order)) * order_blocks(r.order)
        self._tier_stamp[(st.pid, a)] = self.ktime_ns
        return r

    def collapse(self, pid: int, addr: int, to_order: int):
        r = super().collapse(pid, addr, to_order)
        if r is not None:
            a = (addr // order_blocks(r.order)) * order_blocks(r.order)
            self._tier_stamp[(pid, a)] = self.ktime_ns
        return r

    # ------------------------------------------------------------ tier policy
    def _page_age_ticks(self, pid: int, logical_start: int) -> int:
        born = self._tier_stamp.get((pid, logical_start), 0)
        return max(0, (self.ktime_ns - born) // 1_000_000)

    def _tier_columns(self, pstats) -> dict:
        """Per-tier pool state + cumulative edge-cost tables for ctx fill
        (``pstats`` = one BuddyStats per pool, computed once per call)."""
        free = [0] * MAX_TIERS
        total = [0] * MAX_TIERS
        for t, s in enumerate(pstats):
            free[t] = s.free_blocks
            total[t] = s.total_blocks
        cum_setup, cum_ns = self.cost.migrate_cum_tables()
        levels = [es.level for es in self.health.edges]
        if any(levels):
            # Flap-aware DECISION costs: an edge with a nonzero backoff level
            # has flapped recently — even while up it is a bad bet, so the
            # cost the policy sees is inflated by (1 + level) on that edge.
            # Applied on top of the memoized physical tables (never inside
            # their memo — the physical accounting in migrate_ns stays
            # health-independent), rebuilt per call as levels decay.
            cs = np.asarray(cum_setup, dtype=np.int64)
            cn = np.asarray(cum_ns, dtype=np.int64)
            mul = np.ones(cs.size - 1, dtype=np.int64)
            mul[:len(levels)] += np.asarray(levels, dtype=np.int64)
            cum_setup = tuple(np.concatenate(
                [cs[:1], cs[0] + np.cumsum(np.diff(cs) * mul)]).tolist())
            cum_ns = tuple(np.concatenate(
                [cn[:1], cn[0] + np.cumsum(np.diff(cn) * mul)]).tolist())
        return dict(ntiers=self.ntiers, tier_free=tuple(free),
                    tier_total=tuple(total), mig_cum_setup=cum_setup,
                    mig_cum_ns=cum_ns)

    def _tier_ctx(self, st: ProcessState, m: PageMapping,
                  kind: int = int(FaultKind.FIRST_TOUCH),
                  seq_len: int | None = None) -> np.ndarray:
        pstats = [p.stats() for p in self.pools]
        bstats = pstats[0]
        hstats = pstats[TIER_HOST]
        tc = self._tier_columns(pstats)
        fc = FaultContext(
            addr=m.logical_start, pid=st.pid, vma_start=0, vma_end=st.vma_end,
            fault_max_order=m.order, has_profile=0, profile_map_id=0,
            profile_nregions=0,
            free_blocks=bstats.free_per_order,
            frag=bstats.frag_index_milli,
            heat=st.damon.heat_vector(m.logical_start),
            zero_ns_per_block=self.cost.zero_ns_per_block(),
            compact_ns_per_block=self.cost.compact_ns_per_block(),
            descriptor_ns=int(self.cost.hw.descriptor_ns),
            block_bytes=self.cost.block_bytes,
            ktime_ns=self.ktime_ns,
            mem_pressure=bstats.utilization_milli,
            fault_kind=int(kind),
            seq_len=st.vma_end if seq_len is None else seq_len,
            tier_free_blocks=hstats.free_blocks,
            tier_total_blocks=hstats.total_blocks,
            tier_pressure=hstats.utilization_milli,
            pcie_ns_per_block=self.cost.pcie_ns_per_block(),
            page_tier=m.tier,
            page_order=m.order,
            page_age=self._page_age_ticks(st.pid, m.logical_start),
            page_heat=int(st.damon.heat_at(m.logical_start, m.order)
                          * FIXED_POINT),
            migrate_setup_ns=self.cost.migrate_setup_ns(0, 1),
            migrate_ns_per_block=self.cost.migrate_ns_per_block(0, 1),
            **tc,
        )
        return fc.vector()

    def _default_tier_decision(self, st: ProcessState, m: PageMapping) -> int:
        """Kernel-default tiering with no program attached: approve one-hop
        demotion of whatever reclaim nominated (candidates arrive
        coldest-first), and bring spill-tier pages that have been touched
        since demotion back to HBM."""
        if m.tier == TIER_HBM:
            return min(m.tier + 1, self.ntiers - 1)
        return (TIER_KEEP if st.damon.heat_at(m.logical_start, m.order) > 0
                else m.tier)

    def _build_tier_mat(self, cands: list[tuple[ProcessState, PageMapping]]
                        ) -> np.ndarray:
        """Vectorized per-candidate ctx columns (identity, geometry, DAMON
        heat) — the part of the matrix the scan cache can reuse across
        ticks.  Time-varying columns are filled by the caller."""
        n = len(cands)
        mat = ctx_batch(n)
        pids = np.fromiter((st.pid for st, _ in cands), np.int64, n)
        addrs = np.fromiter((m.logical_start for _, m in cands), np.int64, n)
        orders = np.fromiter((m.order for _, m in cands), np.int64, n)
        tiers = np.fromiter((m.tier for _, m in cands), np.int64, n)
        mat[:, CTX.ADDR] = addrs
        mat[:, CTX.PID] = pids
        mat[:, CTX.FAULT_MAX_ORDER] = orders
        mat[:, CTX.PAGE_ORDER] = orders
        mat[:, CTX.PAGE_TIER] = tiers
        for pid in np.unique(pids):
            st = self.procs[int(pid)]
            sel = pids == pid
            mat[sel, CTX.VMA_END] = st.vma_end
            mat[sel, CTX.SEQ_LEN] = st.vma_end
            mat[sel, CTX.HEAT_O0:CTX.HEAT_O0 + NUM_ORDERS] = \
                st.damon.heat_matrix(addrs[sel])
            for k in np.unique(orders[sel]):
                s2 = sel & (orders == k)
                heat = st.damon.heat_many(addrs[s2], int(k)) * FIXED_POINT
                mat[s2, CTX.PAGE_HEAT] = heat.astype(np.int64)
        return mat

    def _tier_ctx_batch(self, cands: list[tuple[ProcessState, PageMapping]],
                        *, cache: str | None = None,
                        kind: int = int(FaultKind.FIRST_TOUCH),
                        seq_lens: dict[int, int] | None = None) -> np.ndarray:
        """Ctx matrix for a candidate batch; row ``i`` equals
        ``_tier_ctx(*cands[i])``.  With ``cache`` set, the per-candidate
        columns are reused across ticks while the candidate set and the
        involved DAMON monitors are unchanged (the ROADMAP's promotion-scan
        cost item); the clock/age/pool-state columns refresh every call.
        ``seq_lens`` (pid -> logical extent) overrides the SEQ_LEN column —
        placement queries pass the PREFILL SPAN extent, not the VMA end, so
        programs anchor "recent tail" logic to the prompt actually mapped;
        it is incompatible with ``cache`` (the override would poison the
        cached per-candidate columns)."""
        assert not (cache and seq_lens), "seq_lens would poison the scan cache"
        key = (tuple((st.pid, m.logical_start, m.tier, m.order)
                     for st, m in cands),
               tuple(sorted({(st.pid, st.damon.version) for st, _ in cands})))
        cached = self._scan_ctx_cache.get(cache) if cache else None
        if cached is not None and cached[0] == key:
            mat = cached[1]
            self.ctx_cache_hits += 1
        else:
            mat = self._build_tier_mat(cands)
            self.ctx_cache_misses += 1
            if cache:
                self._scan_ctx_cache[cache] = (key, mat)
        pstats = [p.stats() for p in self.pools]
        bstats = pstats[0]
        hstats = pstats[TIER_HOST]
        fill_system_columns(
            mat,
            free_blocks=bstats.free_per_order,
            frag=bstats.frag_index_milli,
            zero_ns_per_block=self.cost.zero_ns_per_block(),
            compact_ns_per_block=self.cost.compact_ns_per_block(),
            descriptor_ns=int(self.cost.hw.descriptor_ns),
            block_bytes=self.cost.block_bytes,
            ktime_ns=self.ktime_ns,
            mem_pressure=bstats.utilization_milli,
            tier_free_blocks=hstats.free_blocks,
            tier_total_blocks=hstats.total_blocks,
            tier_pressure=hstats.utilization_milli,
            pcie_ns_per_block=self.cost.pcie_ns_per_block(),
            migrate_setup_ns=self.cost.migrate_setup_ns(0, 1),
            migrate_ns_per_block=self.cost.migrate_ns_per_block(0, 1),
            **self._tier_columns(pstats))
        mat[:, CTX.FAULT_KIND] = int(kind)
        if seq_lens is not None:
            mat[:, CTX.SEQ_LEN] = np.fromiter(
                (seq_lens.get(st.pid, st.vma_end) for st, _ in cands),
                np.int64, len(cands))
        mat[:, CTX.PAGE_AGE] = np.fromiter(
            (self._page_age_ticks(st.pid, m.logical_start)
             for st, m in cands), np.int64, len(cands))
        return mat

    def tier_decisions(self, cands: list[tuple[ProcessState, PageMapping]],
                       *, scan: str | None = None,
                       kind: int = int(FaultKind.FIRST_TOUCH),
                       force_batch: bool = False,
                       seq_lens: dict[int, int] | None = None) -> list[int]:
        """Run HOOK_TIER over candidate pages; returns one TARGET TIER id per
        candidate, clamped to the live topology.  Vectorized when the batch
        is large enough to amortize the XLA dispatch (``force_batch`` pins
        the batch route — ONE program invocation however small the batch).
        ``scan`` names the ctx cache slot the batch matrix may be reused
        from across ticks."""
        if not cands:
            return []
        if not self.hooks.attached(HOOK_TIER):
            # zero-overhead default path: no ctx build, no VM run
            return [self._default_tier_decision(st, m) for st, m in cands]
        if force_batch or len(cands) >= self.tier_cfg.batch_threshold:
            mat = self._tier_ctx_batch(cands, cache=scan, kind=kind,
                                       seq_lens=seq_lens)
            raw = self.hooks.run_batch(HOOK_TIER, mat)
            decisions = [int(d) for d in raw]
        else:
            decisions = []
            for st, m in cands:
                r = self.hooks.run(HOOK_TIER, self._tier_ctx(
                    st, m, kind,
                    seq_len=seq_lens.get(st.pid) if seq_lens else None))
                # None: the supervisor detached the hook mid-loop — the
                # remaining candidates take the kernel default, matching the
                # batched route's POLICY_DETACHED tail rows
                decisions.append(POLICY_DETACHED if r is None else int(r))
        last = self.ntiers - 1
        return [self._default_tier_decision(st, m)
                if d in (POLICY_FALLBACK, POLICY_DETACHED)
                else max(0, min(d, last))
                for (st, m), d in zip(cands, decisions)]

    def system_ctx_columns(self) -> dict:
        pstats = [p.stats() for p in self.pools]
        hstats = pstats[TIER_HOST]
        cols = super().system_ctx_columns()
        cols.update(
            tier_free_blocks=hstats.free_blocks,
            tier_total_blocks=hstats.total_blocks,
            tier_pressure=hstats.utilization_milli,
            pcie_ns_per_block=self.cost.pcie_ns_per_block(),
            migrate_setup_ns=self.cost.migrate_setup_ns(0, 1),
            migrate_ns_per_block=self.cost.migrate_ns_per_block(0, 1),
            **self._tier_columns(pstats))
        return cols

    # ----------------------------------------------- prefix-cache integration
    def cache_alloc_block(self) -> int | None:
        return self._alloc_in_tier(0, 0)

    def cache_free_block(self, tier: int, phys: int) -> None:
        self.pools[tier].free(phys)

    def cache_device_index(self, tier: int, phys: int) -> int:
        return self._tier_base[tier] + phys

    def migrate_cache_block(self, blk, dst_tier: int) -> bool:
        """Hop-by-hop migration for one cache-owned base block that lives in
        NO page table (prefix-cache demotion/promotion).  Same routing rules
        as :meth:`migrate_page` — nearest tier toward the target with room,
        quarantined edges hopped over — but the only bookkeeping is the move
        list, the per-edge cost, and ``blk``'s own (tier, phys).  Entries
        with live borrowers are never offered here (the evict scan only
        nominates refcount-0 entries), so no page table needs repointing."""
        dst_tier = max(0, min(dst_tier, self.ntiers - 1))
        h = self.health
        tel = self.telemetry
        while blk.tier != dst_tier:
            step = 1 if dst_tier > blk.tier else -1
            placed = False
            for t in range(blk.tier + step, dst_tier + step, step):
                if h.active and not h.path_ok(blk.tier, t, self.ktime_ns):
                    continue
                phys = self._alloc_in_tier(t, 0)
                if phys is None:
                    continue
                src_dev = self._tier_base[blk.tier] + blk.phys
                self._move_log.append((src_dev, self._tier_base[t] + phys, 0))
                self.pools[blk.tier].free(blk.phys)
                hop_ns = self.cost.migrate_ns(0, blk.tier, t)
                self.stats.mgmt_ns += hop_ns
                if tel is not None and tel.enabled:
                    tel.observe_migrate(hop_ns)
                    tel.emit(EV_MIGRATE_HOP, (blk.tier << 8) | t,
                             self.cost.block_bytes, hop_ns, ts=self.ktime_ns)
                if t > blk.tier:
                    self.stats.demotions += 1
                    self.stats.demotion_blocks += 1
                else:
                    self.stats.tier_promotions += 1
                    self.stats.tier_promotion_blocks += 1
                blk.tier, blk.phys = t, phys
                placed = True
                break
            if not placed:
                return False
        return True

    # -------------------------------------------------------------- migration
    def _alloc_in_tier(self, tier: int, order: int, *, pid: int = -1,
                       addr: int = -1) -> int | None:
        """Allocate an order-k page in ``tier``'s pool, compacting it once if
        fragmented; None when the pool genuinely cannot back the page (or an
        injected SITE_TIER_ALLOC fault transiently fails it — the caller
        hops over, same as a full pool)."""
        inj = self.injector
        if inj is not None and inj.fires(SITE_TIER_ALLOC, tier, pid, addr,
                                         self.ktime_ns):
            self.stats.tier_alloc_failures += 1
            self.health.record_alloc_failure(tier)
            return None
        pool = self.pools[tier]
        try:
            return pool.alloc(order)
        except BuddyError:
            plan = pool.plan_compaction(order)
            if plan is None:
                return None
            self._apply_compaction(plan, tier=tier,
                                   device_offset=self._tier_base[tier])
            try:
                return pool.alloc(order)
            except BuddyError:
                return None

    def _hop(self, st: ProcessState, m: PageMapping, dst_tier: int,
             phys: int) -> None:
        """Bookkeeping for one committed hop: emit the device copy, release
        the old block, charge the per-edge path cost, bump the stats."""
        n = order_blocks(m.order)
        src_dev = self._device_index(m)
        self._move_log.append((src_dev, self._tier_base[dst_tier] + phys,
                               m.order))
        self.pools[m.tier].free(m.phys_start)
        hop_ns = self.cost.migrate_ns(m.order, m.tier, dst_tier)
        self.stats.mgmt_ns += hop_ns
        tel = self.telemetry
        if tel is not None and tel.enabled:
            tel.observe_migrate(hop_ns)
            tel.emit(EV_MIGRATE_HOP, (m.tier << 8) | dst_tier,
                     n * self.cost.block_bytes, hop_ns, ts=self.ktime_ns)
        if dst_tier > m.tier:
            self.stats.demotions += 1
            self.stats.demotion_blocks += n
        else:
            self.stats.tier_promotions += 1
            self.stats.tier_promotion_blocks += n
        m.phys_start = phys
        m.tier = dst_tier
        self._note_mapped(st, m)
        self._tier_stamp[(st.pid, m.logical_start)] = self.ktime_ns

    def _copy_fail_edge(self, st: ProcessState, m: PageMapping, t: int,
                        attempt: int) -> int:
        """First edge on the ``m.tier -> t`` crossing that fails this copy
        attempt (injected link flap or copy error), or -1 when the copy
        succeeds.  Keyed on stable page identity + modeled time so the
        schedule replays identically across fault routes and executors."""
        inj = self.injector
        if inj is None:
            return -1
        lo, hi = sorted((m.tier, t))
        for e in range(lo, hi):
            if inj.link_down(e, self.ktime_ns) or inj.fires(
                    SITE_MIGRATE_COPY, st.pid, m.logical_start, e, attempt,
                    self.ktime_ns):
                return e
        return -1

    def _attempt_copy(self, st: ProcessState, m: PageMapping, t: int,
                      phys: int) -> bool:
        """Bounded-retry copy for one hop (single shot when containment is
        off).  Each failed attempt records the error against the failing
        edge — feeding its quarantine state machine — and charges
        exponentially growing backoff in MODELED time; exhausting the
        budget rolls the destination allocation back, so the page stays
        put and its KV bytes are never touched by a failed copy."""
        h = self.health
        tel = self.telemetry
        attempts = MIGRATE_MAX_ATTEMPTS if self.containment else 1
        for attempt in range(1, attempts + 1):
            edge = self._copy_fail_edge(st, m, t, attempt)
            if edge < 0:
                if h.active:
                    lo, hi = sorted((m.tier, t))
                    for e in range(lo, hi):
                        if h.record_edge_success(e, self.ktime_ns) \
                                and tel is not None and tel.enabled:
                            es = h.edges[e]
                            tel.emit(EV_READMIT, e, es.errors, es.successes,
                                     ts=self.ktime_ns)
                self._hop(st, m, t, phys)
                return True
            newly_quarantined = h.record_edge_error(edge, self.ktime_ns)
            if newly_quarantined and tel is not None and tel.enabled:
                es = h.edges[edge]
                tel.emit(EV_QUARANTINE, edge, es.backoff_ns(), es.level,
                         ts=self.ktime_ns)
                tel.inc("edge_quarantines")
            if attempt < attempts:
                backoff = RETRY_BACKOFF_NS << (attempt - 1)
                self.stats.migrate_retries += 1
                self.stats.mgmt_ns += backoff
                if tel is not None and tel.enabled:
                    tel.emit(EV_RETRY, edge, attempt, backoff,
                             ts=self.ktime_ns)
        self.stats.migrate_aborts += 1
        self.pools[t].free(phys)
        return False

    def migrate_page(self, pid: int, logical_start: int,
                     dst_tier: int) -> bool:
        """Move one mapping toward ``dst_tier``, hop by adjacent hop.  Each
        hop allocates in the nearest tier toward the target with room
        (compacting it if fragmented), emits one device copy and charges the
        per-edge path cost — so an NVMe->HBM promotion chains
        NVMe->DRAM->HBM when the intermediates have room and hops over them
        (still paying their link crossings) when they don't.  A quarantined
        edge is hopped over the same way; a hop whose copy keeps failing
        (see :meth:`_attempt_copy`) is abandoned with the page left where it
        was.  Returns True iff the page ends in ``dst_tier``; partial
        progress (it moved but stalled short) leaves the page at the tier
        it reached."""
        st = self.procs[pid]
        m = st.page_table[logical_start]
        if m.shared:
            return False    # cache-owned phys: only the cache migrates it
        dst_tier = max(0, min(dst_tier, self.ntiers - 1))
        h = self.health
        while m.tier != dst_tier:
            step = 1 if dst_tier > m.tier else -1
            placed = False
            for t in range(m.tier + step, dst_tier + step, step):
                if h.active and not h.path_ok(m.tier, t, self.ktime_ns):
                    continue    # a quarantined edge on the way: hop over
                phys = self._alloc_in_tier(t, m.order, pid=pid,
                                           addr=m.logical_start)
                if phys is None:
                    continue
                if self._attempt_copy(st, m, t, phys):
                    placed = True
                    break
            if not placed:
                return False
        return True

    def demote_page(self, pid: int, logical_start: int) -> bool:
        """Move one mapping one tier down the chain (HBM -> host in the
        2-pool topology). Returns False if the page is already in the
        deepest tier or no pool below can back it."""
        m = self.procs[pid].page_table[logical_start]
        if m.tier >= self.ntiers - 1:
            return False
        return self.migrate_page(pid, logical_start, m.tier + 1)

    def promote_page(self, pid: int, logical_start: int) -> bool:
        """Move one mapping one tier up the chain (host -> HBM in the 2-pool
        topology), compacting the destination pool if needed."""
        m = self.procs[pid].page_table[logical_start]
        if m.tier == TIER_HBM:
            return False
        return self.migrate_page(pid, logical_start, m.tier - 1)

    # ---------------------------------------------------------- reclaim entry
    def demote_cold_global(self, need_blocks: int | None = None,
                           prefer_pid: int | None = None) -> int:
        """Global reclaim scan (the kswapd analogue): nominate HBM pages from
        EVERY process coldest-first — the reclaim victim's pages win ties —
        and demote HOOK_TIER-approved ones toward their target tiers until
        ``need_blocks`` HBM blocks are freed.  A victim that is already fully
        spilled then simply contributes no candidates instead of stalling
        reclaim."""
        need = need_blocks if need_blocks is not None \
            else self.tier_cfg.demote_chunk_blocks
        cands = [(st, m) for st in self.procs.values()
                 for m in st.mappings_sorted()
                 if m.tier == TIER_HBM and not m.shared]
        if not cands:
            return 0
        cands.sort(key=lambda sm: (
            sm[0].damon.heat_at(sm[1].logical_start, sm[1].order),
            0 if sm[0].pid == prefer_pid else 1,
            sm[0].pid, -sm[1].logical_start))
        decisions = self.tier_decisions(cands, scan="demote")
        freed = 0
        for (st, m), d in zip(cands, decisions):
            if freed >= need:
                break
            if d > m.tier:
                self.migrate_page(st.pid, m.logical_start, d)
                if m.tier == TIER_HBM and self.containment:
                    # degraded mode: the approved target (or every path to
                    # it) could not take the page — demote-before-preempt
                    # retries against the REMAINING deeper tiers before
                    # giving up on this page; total blockage leaves freed
                    # short and falls through to the engine's preempt-only
                    # fallback, preserving the PR 1 ordering guarantees
                    for d2 in range(d + 1, self.ntiers):
                        self.migrate_page(st.pid, m.logical_start, d2)
                        if m.tier != TIER_HBM:
                            break
                if m.tier != TIER_HBM:      # left HBM (even if short of d)
                    freed += order_blocks(m.order)
        return freed

    def promotion_scan(self, budget_blocks: int | None = None) -> int:
        """Background promotion (khugepaged-style): offer every spill-tier
        page to HOOK_TIER; pages the policy wants in a faster tier are moved
        up, hottest-first, under a per-tick block budget."""
        budget = budget_blocks if budget_blocks is not None \
            else self.tier_cfg.promote_blocks_per_tick
        # age > 0: never bounce a page demoted within the current tick (the
        # demote and promote copies would otherwise land in one device batch)
        cands = [(st, m) for st in self.procs.values()
                 for m in st.mappings_sorted()
                 if m.tier != TIER_HBM and not m.shared
                 and self._page_age_ticks(st.pid, m.logical_start) > 0]
        if not cands:
            return 0
        cands.sort(key=lambda sm: -sm[0].damon.heat_at(
            sm[1].logical_start, sm[1].order))
        decisions = self.tier_decisions(cands, scan="promote")
        promoted = 0
        for (st, m), d in zip(cands, decisions):
            if promoted >= budget:
                break
            if d < m.tier:
                was = m.tier
                self.migrate_page(st.pid, m.logical_start, d)
                if m.tier < was:            # moved up (even if short of d)
                    promoted += order_blocks(m.order)
        return promoted

    # -------------------------------------------- prefill-time tier placement
    def _mapping_at(self, st: ProcessState, addr: int) -> PageMapping | None:
        """The mapping covering logical block ``addr`` (None if unmapped)."""
        for k in range(self.max_order + 1):
            size = order_blocks(k)
            m = st.page_table.get((addr // size) * size)
            if m is not None and m.order == k:
                return m
        return None

    def _place_prefill(self, reqs) -> None:
        """Fold tier placement into the prefill path: ONE ``HOOK_TIER``
        consult per prefill batch over the pages the batch touched, so
        profiles can place cold prefixes directly in the far tiers instead
        of defaulting to HBM.  Only demotions are applied here (promotion is
        the background scan's job); with no program attached this is a no-op
        — placement stays the zero-overhead HBM default."""
        if not self.hooks.attached(HOOK_TIER):
            return
        seen: set[tuple[int, int]] = set()
        cands: list[tuple[ProcessState, PageMapping]] = []
        last: dict[int, PageMapping] = {}     # skip probes inside known spans
        extent: dict[int, int] = {}           # pid -> prefill-span extent
        for pid, addr, kind in reqs:
            if int(kind) != int(FaultKind.PREFILL):
                continue
            st = self.procs.get(pid)
            if st is None or addr not in st.mapped:
                continue
            extent[pid] = max(extent.get(pid, 0), addr + 1)
            m = last.get(pid)
            if m is not None and m.logical_start <= addr \
                    < m.logical_start + order_blocks(m.order):
                continue
            m = self._mapping_at(st, addr)
            if m is None or m.shared or (pid, m.logical_start) in seen:
                continue
            seen.add((pid, m.logical_start))
            last[pid] = m
            cands.append((st, m))
        if not cands:
            return
        decisions = self.tier_decisions(cands, kind=int(FaultKind.PREFILL),
                                        force_batch=True, seq_lens=extent)
        for (st, m), d in zip(cands, decisions):
            if d > m.tier:
                self.migrate_page(st.pid, m.logical_start, d)

    def _place_first_touch(self, reqs) -> None:
        """Decode-time tier placement: FIRST_TOUCH fault batches consult
        ``HOOK_TIER`` exactly like prefill does — ONE batched consult over
        the pages the batch installed, after all installs — so a pressured
        (or degraded) HBM pool can place decode installs directly in a
        spill tier instead of waiting for the reclaim scan.  Demotion-only,
        like prefill placement; a no-op with nothing attached."""
        if not self.hooks.attached(HOOK_TIER):
            return
        seen: set[tuple[int, int]] = set()
        cands: list[tuple[ProcessState, PageMapping]] = []
        for pid, addr, kind in reqs:
            if int(kind) != int(FaultKind.FIRST_TOUCH):
                continue
            st = self.procs.get(pid)
            if st is None or addr not in st.mapped:
                continue
            m = self._mapping_at(st, addr)
            if m is None or m.shared or (pid, m.logical_start) in seen:
                continue
            seen.add((pid, m.logical_start))
            cands.append((st, m))
        if not cands:
            return
        decisions = self.tier_decisions(
            cands, kind=int(FaultKind.FIRST_TOUCH), force_batch=True)
        for (st, m), d in zip(cands, decisions):
            if d > m.tier:
                self.migrate_page(st.pid, m.logical_start, d)

    def place_decode(self, reqs) -> None:
        """Scalar-route entry for decode-time placement (the batched route
        runs it inside :meth:`fault_batch`): call once after an
        ``ensure_mapped`` loop with the same request list, so both routes
        consult placement at the same post-install state."""
        self._place_first_touch(reqs)

    def fault_batch(self, reqs):
        results = super().fault_batch(reqs)
        self._place_prefill(reqs)
        self._place_first_touch(reqs)
        return results

    def ensure_range(self, pid: int, start: int, end: int):
        results = super().ensure_range(pid, start, end)
        self._place_prefill([(pid, a, FaultKind.PREFILL)
                             for a in range(start, end)])
        return results

    # ----------------------------------------------------------------- state
    def resident_blocks(self, tier: int) -> int:
        return sum(order_blocks(o)
                   for o in self.pools[tier].allocated.values())

    def host_resident_blocks(self) -> int:
        return self.resident_blocks(TIER_HOST)

    def tier_snapshot(self) -> dict:
        """Pool-state snapshot: the per-tier ``tiers`` list is the API.

        The pre-N-pool ``host_*`` keys (which hard-coded "the spill tier"
        as tier 1 — silently the wrong pool on a deeper chain) went through
        a DeprecationWarning cycle and are now REMOVED; consumers index
        ``snapshot["tiers"][t]``."""
        out = {
            "pcie_ns_per_block": self.cost.pcie_ns_per_block(),
            "ntiers": self.ntiers,
            "tiers": [],
        }
        for t, (spec, pool) in enumerate(zip(("hbm",) + tuple(
                s.name for s in self.tier_specs), self.pools)):
            s = pool.stats()
            out["tiers"].append({
                "tier": t, "name": spec, "blocks": pool.num_blocks,
                "free_blocks": s.free_blocks,
                "resident_blocks": self.resident_blocks(t),
                "utilization_milli": s.utilization_milli,
            })
        return out
