"""Tiered memory: eBPF-guided HBM <-> host-DRAM page placement.

The paper names page placement across memory tiers as the natural next hook
after the fault-path page-size hook and stubs it as ``HOOK_TIER``.  This
module implements that subsystem: a second block pool modeling host DRAM with
its own buddy allocator, a :class:`TieredMemoryManager` over
:class:`~repro.core.mm.MemoryManager` whose :class:`PageMapping`\\ s carry a
tier id, and a migration engine that emits explicit move lists the device
executes with the block_copy kernel — with PCIe-bandwidth costs accounted in
the :class:`~repro.core.cost.CostModel`.

Device addressing: the engine materializes ONE combined pool of
``num_blocks + host_blocks`` base blocks.  Indices ``[0, num_blocks)`` are
HBM; ``[num_blocks, num_blocks + host_blocks)`` model pinned host DRAM the
device can DMA from (at PCIe bandwidth — charged by the cost model, while the
copies themselves stay exact).  Tier crossings are therefore ordinary
``(src, dst, order)`` moves in combined coordinates and reuse the existing
``drain_moves`` / block_copy path unchanged.

Policy: every migration decision is delegated to the verified program
attached to ``HOOK_TIER`` (TierBPF-style admission control).  The program
sees a :class:`~repro.core.context.FaultContext` describing the candidate
page (tier, order, DAMON heat, age) plus both pools' real-time state, and
returns ``TIER_KEEP`` (live in HBM) or ``TIER_DEMOTE`` (live in host DRAM).
With nothing attached, a kernel-default policy runs without building the ctx
at all — the paper's zero-overhead property, extended to the new hook.
Decisions over many candidates run through the vectorized JIT batch path.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .buddy import BuddyAllocator, BuddyError, order_blocks
from .context import (CTX, FIXED_POINT, NUM_ORDERS, POLICY_FALLBACK,
                      TIER_DEMOTE, TIER_KEEP, FaultContext, ctx_batch,
                      fill_system_columns)
from .cost import CostModel
from .hooks import HOOK_TIER
from .mm import MemoryManager, PageMapping, ProcessState

TIER_HBM = 0
TIER_HOST = 1


@dataclass
class TierConfig:
    """Migration-engine knobs (throttles, like khugepaged's)."""
    promote_blocks_per_tick: int = 16    # promotion-scan budget per engine tick
    demote_chunk_blocks: int = 16        # min HBM blocks to free per reclaim event
    batch_threshold: int = 4             # >= this many candidates -> JIT batch path


class TieredMemoryManager(MemoryManager):
    """MemoryManager with a second, host-DRAM block pool behind HOOK_TIER.

    HBM pages live in ``self.buddy`` (tier 0), host-DRAM pages in
    ``self.host_buddy`` (tier 1).  ``phys_start`` of a mapping is always an
    index within its own tier's pool; :meth:`_device_index` folds both into
    the combined device pool the engine materializes.
    """

    def __init__(self, num_blocks: int, cost: CostModel, *,
                 host_blocks: int, tier_cfg: TierConfig | None = None,
                 **kw) -> None:
        super().__init__(num_blocks, cost, **kw)
        if host_blocks <= 0:
            raise ValueError("host_blocks must be positive (use MemoryManager "
                             "for an untiered pool)")
        self.host_blocks = host_blocks
        self.host_buddy = BuddyAllocator(host_blocks, max_order=self.max_order)
        self.tier_cfg = tier_cfg or TierConfig()
        # (pid, logical_start) -> ktime_ns of the last tier change / install
        self._tier_stamp: dict[tuple[int, int], int] = {}
        # Scan-ctx cache: the per-candidate columns of a tier-scan ctx matrix
        # (heat, identity, geometry) are reused across ticks while the
        # candidate set and every involved DAMON monitor are unchanged; only
        # the time-varying columns (clock, age, pool state) are refreshed.
        self._scan_ctx_cache: dict[str, tuple] = {}
        self.ctx_cache_hits = 0
        self.ctx_cache_misses = 0

    # --------------------------------------------------------------- geometry
    @property
    def device_pool_blocks(self) -> int:
        """Size of the combined device pool (HBM + host-DRAM mirror)."""
        return self.buddy.num_blocks + self.host_blocks

    def _device_index(self, m: PageMapping) -> int:
        if m.tier == TIER_HOST:
            return self.buddy.num_blocks + m.phys_start
        return m.phys_start

    def _free_phys(self, m: PageMapping) -> None:
        if m.tier == TIER_HOST:
            self.host_buddy.free(m.phys_start)
        else:
            self.buddy.free(m.phys_start)

    def free_process(self, pid: int) -> None:
        super().free_process(pid)
        self._tier_stamp = {k: v for k, v in self._tier_stamp.items()
                            if k[0] != pid}

    def unmap(self, pid: int, logical_start: int) -> None:
        super().unmap(pid, logical_start)
        self._tier_stamp.pop((pid, logical_start), None)

    def _install(self, st, addr, order, hinted):
        r = super()._install(st, addr, order, hinted)
        a = (addr // order_blocks(r.order)) * order_blocks(r.order)
        self._tier_stamp[(st.pid, a)] = self.ktime_ns
        return r

    def collapse(self, pid: int, addr: int, to_order: int):
        r = super().collapse(pid, addr, to_order)
        if r is not None:
            a = (addr // order_blocks(r.order)) * order_blocks(r.order)
            self._tier_stamp[(pid, a)] = self.ktime_ns
        return r

    # ------------------------------------------------------------ tier policy
    def _page_age_ticks(self, pid: int, logical_start: int) -> int:
        born = self._tier_stamp.get((pid, logical_start), 0)
        return max(0, (self.ktime_ns - born) // 1_000_000)

    def _tier_ctx(self, st: ProcessState, m: PageMapping) -> np.ndarray:
        bstats = self.buddy.stats()
        hstats = self.host_buddy.stats()
        fc = FaultContext(
            addr=m.logical_start, pid=st.pid, vma_start=0, vma_end=st.vma_end,
            fault_max_order=m.order, has_profile=0, profile_map_id=0,
            profile_nregions=0,
            free_blocks=bstats.free_per_order,
            frag=bstats.frag_index_milli,
            heat=st.damon.heat_vector(m.logical_start),
            zero_ns_per_block=self.cost.zero_ns_per_block(),
            compact_ns_per_block=self.cost.compact_ns_per_block(),
            descriptor_ns=int(self.cost.hw.descriptor_ns),
            block_bytes=self.cost.block_bytes,
            ktime_ns=self.ktime_ns,
            mem_pressure=bstats.utilization_milli,
            seq_len=st.vma_end,
            tier_free_blocks=hstats.free_blocks,
            tier_total_blocks=hstats.total_blocks,
            tier_pressure=hstats.utilization_milli,
            pcie_ns_per_block=self.cost.pcie_ns_per_block(),
            page_tier=m.tier,
            page_order=m.order,
            page_age=self._page_age_ticks(st.pid, m.logical_start),
            page_heat=int(st.damon.heat_at(m.logical_start, m.order)
                          * FIXED_POINT),
            migrate_setup_ns=int(self.cost.hw.pcie_setup_ns),
            migrate_ns_per_block=self.cost.migrate_ns_per_block(),
        )
        return fc.vector()

    def _default_tier_decision(self, st: ProcessState, m: PageMapping) -> int:
        """Kernel-default tiering with no program attached: approve demotion
        of whatever reclaim nominated (candidates arrive coldest-first), and
        promote host pages that have been touched since demotion."""
        if m.tier == TIER_HBM:
            return TIER_DEMOTE
        return (TIER_KEEP if st.damon.heat_at(m.logical_start, m.order) > 0
                else TIER_DEMOTE)

    def _build_tier_mat(self, cands: list[tuple[ProcessState, PageMapping]]
                        ) -> np.ndarray:
        """Vectorized per-candidate ctx columns (identity, geometry, DAMON
        heat) — the part of the matrix the scan cache can reuse across
        ticks.  Time-varying columns are filled by the caller."""
        n = len(cands)
        mat = ctx_batch(n)
        pids = np.fromiter((st.pid for st, _ in cands), np.int64, n)
        addrs = np.fromiter((m.logical_start for _, m in cands), np.int64, n)
        orders = np.fromiter((m.order for _, m in cands), np.int64, n)
        tiers = np.fromiter((m.tier for _, m in cands), np.int64, n)
        mat[:, CTX.ADDR] = addrs
        mat[:, CTX.PID] = pids
        mat[:, CTX.FAULT_MAX_ORDER] = orders
        mat[:, CTX.PAGE_ORDER] = orders
        mat[:, CTX.PAGE_TIER] = tiers
        for pid in np.unique(pids):
            st = self.procs[int(pid)]
            sel = pids == pid
            mat[sel, CTX.VMA_END] = st.vma_end
            mat[sel, CTX.SEQ_LEN] = st.vma_end
            mat[sel, CTX.HEAT_O0:CTX.HEAT_O0 + NUM_ORDERS] = \
                st.damon.heat_matrix(addrs[sel])
            for k in np.unique(orders[sel]):
                s2 = sel & (orders == k)
                heat = st.damon.heat_many(addrs[s2], int(k)) * FIXED_POINT
                mat[s2, CTX.PAGE_HEAT] = heat.astype(np.int64)
        return mat

    def _tier_ctx_batch(self, cands: list[tuple[ProcessState, PageMapping]],
                        *, cache: str | None = None) -> np.ndarray:
        """Ctx matrix for a candidate batch; row ``i`` equals
        ``_tier_ctx(*cands[i])``.  With ``cache`` set, the per-candidate
        columns are reused across ticks while the candidate set and the
        involved DAMON monitors are unchanged (the ROADMAP's promotion-scan
        cost item); the clock/age/pool-state columns refresh every call."""
        key = (tuple((st.pid, m.logical_start, m.tier, m.order)
                     for st, m in cands),
               tuple(sorted({(st.pid, st.damon.version) for st, _ in cands})))
        cached = self._scan_ctx_cache.get(cache) if cache else None
        if cached is not None and cached[0] == key:
            mat = cached[1]
            self.ctx_cache_hits += 1
        else:
            mat = self._build_tier_mat(cands)
            self.ctx_cache_misses += 1
            if cache:
                self._scan_ctx_cache[cache] = (key, mat)
        bstats = self.buddy.stats()
        hstats = self.host_buddy.stats()
        fill_system_columns(
            mat,
            free_blocks=bstats.free_per_order,
            frag=bstats.frag_index_milli,
            zero_ns_per_block=self.cost.zero_ns_per_block(),
            compact_ns_per_block=self.cost.compact_ns_per_block(),
            descriptor_ns=int(self.cost.hw.descriptor_ns),
            block_bytes=self.cost.block_bytes,
            ktime_ns=self.ktime_ns,
            mem_pressure=bstats.utilization_milli,
            tier_free_blocks=hstats.free_blocks,
            tier_total_blocks=hstats.total_blocks,
            tier_pressure=hstats.utilization_milli,
            pcie_ns_per_block=self.cost.pcie_ns_per_block(),
            migrate_setup_ns=int(self.cost.hw.pcie_setup_ns),
            migrate_ns_per_block=self.cost.migrate_ns_per_block())
        mat[:, CTX.PAGE_AGE] = np.fromiter(
            (self._page_age_ticks(st.pid, m.logical_start)
             for st, m in cands), np.int64, len(cands))
        return mat

    def tier_decisions(self, cands: list[tuple[ProcessState, PageMapping]],
                       *, scan: str | None = None) -> list[int]:
        """Run HOOK_TIER over candidate pages; vectorized when the batch is
        large enough to amortize the XLA dispatch.  ``scan`` names the ctx
        cache slot the batch matrix may be reused from across ticks."""
        if not cands:
            return []
        if not self.hooks.attached(HOOK_TIER):
            # zero-overhead default path: no ctx build, no VM run
            return [self._default_tier_decision(st, m) for st, m in cands]
        if len(cands) >= self.tier_cfg.batch_threshold:
            mat = self._tier_ctx_batch(cands, cache=scan)
            raw = self.hooks.run_batch(HOOK_TIER, mat)
            decisions = [int(d) for d in raw]
        else:
            decisions = [int(self.hooks.run(HOOK_TIER, self._tier_ctx(st, m)))
                         for st, m in cands]
        return [self._default_tier_decision(st, m) if d == POLICY_FALLBACK else d
                for (st, m), d in zip(cands, decisions)]

    # -------------------------------------------------------------- migration
    def demote_page(self, pid: int, logical_start: int) -> bool:
        """Move one mapping HBM -> host tier. Returns False if the host pool
        cannot back it (OOM in both tiers for this page)."""
        st = self.procs[pid]
        m = st.page_table[logical_start]
        if m.tier != TIER_HBM:
            return False
        try:
            hp = self.host_buddy.alloc(m.order)
        except BuddyError:
            plan = self.host_buddy.plan_compaction(m.order)
            if plan is None:
                return False
            self._apply_host_compaction(plan)
            try:
                hp = self.host_buddy.alloc(m.order)
            except BuddyError:
                return False
        n = order_blocks(m.order)
        self._move_log.append((m.phys_start, self.buddy.num_blocks + hp, m.order))
        self.buddy.free(m.phys_start)
        m.phys_start = hp
        m.tier = TIER_HOST
        self._note_mapped(st, m)
        self._tier_stamp[(pid, logical_start)] = self.ktime_ns
        self.stats.demotions += 1
        self.stats.demotion_blocks += n
        self.stats.mgmt_ns += self.cost.migrate_ns(m.order)
        return True

    def promote_page(self, pid: int, logical_start: int) -> bool:
        """Move one mapping host tier -> HBM (compacting HBM if needed)."""
        st = self.procs[pid]
        m = st.page_table[logical_start]
        if m.tier != TIER_HOST:
            return False
        try:
            phys = self.buddy.alloc(m.order)
        except BuddyError:
            plan = self.buddy.plan_compaction(m.order)
            if plan is None:
                return False
            self._apply_compaction(plan)
            try:
                phys = self.buddy.alloc(m.order)
            except BuddyError:
                return False
        n = order_blocks(m.order)
        self._move_log.append((self.buddy.num_blocks + m.phys_start, phys,
                               m.order))
        self.host_buddy.free(m.phys_start)
        m.phys_start = phys
        m.tier = TIER_HBM
        self._note_mapped(st, m)
        self._tier_stamp[(pid, logical_start)] = self.ktime_ns
        self.stats.tier_promotions += 1
        self.stats.tier_promotion_blocks += n
        self.stats.mgmt_ns += self.cost.migrate_ns(m.order)
        return True

    def _apply_host_compaction(self, plan: list[tuple[int, int, int]]) -> None:
        """Host-pool compaction: same bookkeeping as HBM compaction, against
        tier-1 mappings and shifted into combined device coordinates (the
        host-local memcpy shares the read+write cost model)."""
        self._apply_compaction(plan, tier=TIER_HOST,
                               device_offset=self.buddy.num_blocks)

    # ---------------------------------------------------------- reclaim entry
    def demote_cold_global(self, need_blocks: int | None = None,
                           prefer_pid: int | None = None) -> int:
        """Global reclaim scan (the kswapd analogue): nominate HBM pages from
        EVERY process coldest-first — the reclaim victim's pages win ties —
        and demote HOOK_TIER-approved ones until ``need_blocks`` are freed.
        A victim that is already fully host-resident then simply contributes
        no candidates instead of stalling reclaim."""
        need = need_blocks if need_blocks is not None \
            else self.tier_cfg.demote_chunk_blocks
        cands = [(st, m) for st in self.procs.values()
                 for m in st.mappings_sorted() if m.tier == TIER_HBM]
        if not cands:
            return 0
        cands.sort(key=lambda sm: (
            sm[0].damon.heat_at(sm[1].logical_start, sm[1].order),
            0 if sm[0].pid == prefer_pid else 1,
            sm[0].pid, -sm[1].logical_start))
        decisions = self.tier_decisions(cands, scan="demote")
        freed = 0
        for (st, m), d in zip(cands, decisions):
            if freed >= need:
                break
            if d == TIER_DEMOTE and self.demote_page(st.pid, m.logical_start):
                freed += order_blocks(m.order)
        return freed

    def promotion_scan(self, budget_blocks: int | None = None) -> int:
        """Background promotion (khugepaged-style): offer every host-tier
        page to HOOK_TIER; pages the policy wants back in HBM are promoted,
        hottest-first, under a per-tick block budget."""
        budget = budget_blocks if budget_blocks is not None \
            else self.tier_cfg.promote_blocks_per_tick
        # age > 0: never bounce a page demoted within the current tick (the
        # demote and promote copies would otherwise land in one device batch)
        cands = [(st, m) for st in self.procs.values()
                 for m in st.mappings_sorted()
                 if m.tier == TIER_HOST
                 and self._page_age_ticks(st.pid, m.logical_start) > 0]
        if not cands:
            return 0
        cands.sort(key=lambda sm: -sm[0].damon.heat_at(
            sm[1].logical_start, sm[1].order))
        decisions = self.tier_decisions(cands, scan="promote")
        promoted = 0
        for (st, m), d in zip(cands, decisions):
            if promoted >= budget:
                break
            if d == TIER_KEEP and self.promote_page(st.pid, m.logical_start):
                promoted += order_blocks(m.order)
        return promoted

    # ----------------------------------------------------------------- state
    def host_resident_blocks(self) -> int:
        return sum(order_blocks(o) for o in self.host_buddy.allocated.values())

    def tier_snapshot(self) -> dict:
        hstats = self.host_buddy.stats()
        return {
            "host_blocks": self.host_blocks,
            "host_free_blocks": hstats.free_blocks,
            "host_resident_blocks": self.host_resident_blocks(),
            "host_utilization_milli": hstats.utilization_milli,
            "pcie_ns_per_block": self.cost.pcie_ns_per_block(),
        }
