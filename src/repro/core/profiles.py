"""Userspace application profiles.

The paper: "a profile consists of memory regions of interest and their
expected benefit from being backed by pages of 64KB, 2MB and 32MB".  Here an
"application" is a serving workload class (or a training buffer class); its
address space is measured in logical base blocks of its KV region.  Profiles
are produced offline by the profiler (:mod:`repro.core.damon` replay) and
loaded into an eBPF-style array map the fault program searches.

Map encoding (what the bytecode sees), REGION_STRIDE int64s per region:
    [start_block, end_block, benefit_o0, benefit_o1, benefit_o2, benefit_o3]
Benefits are modeled-ns-saved-per-access, FIXED_POINT-free (already ns).
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field

import numpy as np

from .context import NUM_ORDERS
from .maps import ArrayMap

REGION_STRIDE = 2 + NUM_ORDERS
MAX_PROFILE_REGIONS = 64   # keeps the verified search loop bounded


@dataclass
class ProfileRegion:
    start: int                      # logical block, inclusive
    end: int                        # logical block, exclusive
    benefit: tuple[float, ...]      # ns saved per access, per order

    def encode(self) -> list[int]:
        if len(self.benefit) != NUM_ORDERS:
            raise ValueError(f"benefit must have {NUM_ORDERS} entries")
        if not (0 <= self.start < self.end):
            raise ValueError(f"bad region [{self.start}, {self.end})")
        return [int(self.start), int(self.end)] + [int(b) for b in self.benefit]


@dataclass
class Profile:
    """Per-application profile, loadable into a map."""
    app: str
    regions: list[ProfileRegion] = field(default_factory=list)

    def __post_init__(self) -> None:
        if len(self.regions) > MAX_PROFILE_REGIONS:
            raise ValueError(
                f"profile {self.app!r}: {len(self.regions)} regions > "
                f"{MAX_PROFILE_REGIONS} (verifier loop bound)")
        srt = sorted(self.regions, key=lambda r: r.start)
        for a, b in zip(srt, srt[1:]):
            if a.end > b.start:
                raise ValueError(f"profile {self.app!r}: overlapping regions")
        self.regions = srt

    def encode(self) -> np.ndarray:
        flat: list[int] = []
        for r in self.regions:
            flat.extend(r.encode())
        return np.asarray(flat, dtype=np.int64)

    def load_into(self, m: ArrayMap) -> None:
        m.load(self.encode())

    def lookup(self, addr: int) -> ProfileRegion | None:
        for r in self.regions:
            if r.start <= addr < r.end:
                return r
        return None

    # ---- (de)serialization — the userspace framework's on-disk format ----
    def to_json(self) -> str:
        return json.dumps({
            "app": self.app,
            "regions": [
                {"start": r.start, "end": r.end, "benefit": list(r.benefit)}
                for r in self.regions
            ],
        }, indent=2)

    @classmethod
    def from_json(cls, s: str) -> "Profile":
        d = json.loads(s)
        return cls(app=d["app"], regions=[
            ProfileRegion(r["start"], r["end"], tuple(r["benefit"]))
            for r in d["regions"]
        ])


def profile_from_heat(app: str, heat_per_block: np.ndarray, hw, *,
                      hot_quantile: float = 0.7,
                      min_region_blocks: int = 4) -> Profile:
    """Offline profiling: turn a measured per-block heat trace into a profile.

    This is the DAMON-replay step of the paper's workflow: identify hot
    regions and precompute the expected per-access benefit of each page size
    for them (ns saved vs 4K-analogue backing, from the HW model).
    """
    heat = np.asarray(heat_per_block, dtype=np.float64)
    if heat.size == 0:
        return Profile(app, [])
    thresh = np.quantile(heat[heat > 0], hot_quantile) if (heat > 0).any() else np.inf
    hot = heat >= max(thresh, 1e-12)
    regions: list[ProfileRegion] = []
    i = 0
    n = heat.size
    while i < n:
        if not hot[i]:
            i += 1
            continue
        j = i
        while j < n and hot[j]:
            j += 1
        if j - i >= min_region_blocks:
            mean_heat = float(heat[i:j].mean())
            # a page larger than the hot region would back cold blocks too:
            # its benefit is zeroed so the fault program prefers the largest
            # page that still fits the region (cf. the paper only hinting
            # sizes whose reach matches the profiled region)
            benefit = tuple(
                hw.access_benefit_ns(order, mean_heat)
                if (4 ** order) <= (j - i) else 0
                for order in range(NUM_ORDERS))
            regions.append(ProfileRegion(i, j, benefit))
        i = j
    return Profile(app, regions[:MAX_PROFILE_REGIONS])
