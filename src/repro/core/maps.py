"""Bounded array maps — the eBPF-map analogue.

The paper's userspace framework loads application profiles into eBPF maps
that the fault-hook program then searches.  We model maps as fixed-capacity
int64 arrays registered with the VM; lookups are bounds-clamped (a verified
program can therefore never fault on a map access, mirroring how the eBPF
verifier + helpers make map access safe).
"""

from __future__ import annotations

import numpy as np


class ArrayMap:
    """Fixed-capacity flat int64 array map."""

    def __init__(self, capacity: int, name: str = "map") -> None:
        if capacity <= 0:
            raise ValueError("map capacity must be positive")
        self.name = name
        self.capacity = int(capacity)
        self._data = np.zeros(self.capacity, dtype=np.int64)
        self._len = 0
        self.version = 0      # bumped on every userspace write

    def __len__(self) -> int:
        return self._len

    def load(self, values) -> None:
        values = np.asarray(values, dtype=np.int64).ravel()
        if values.size > self.capacity:
            raise ValueError(f"map {self.name}: {values.size} > capacity {self.capacity}")
        self._data[:] = 0
        self._data[:values.size] = values
        self._len = int(values.size)
        self.version += 1

    def lookup(self, idx: int) -> int:
        """Bounds-clamped lookup; out-of-range reads return 0 (missing key)."""
        if 0 <= idx < self._len:
            return int(self._data[idx])
        return 0

    def update(self, idx: int, value: int) -> None:
        if not 0 <= idx < self.capacity:
            raise IndexError(f"map {self.name}: index {idx} out of capacity")
        self._data[idx] = np.int64(value)
        self._len = max(self._len, idx + 1)
        self.version += 1

    def as_array(self) -> np.ndarray:
        return self._data.copy()

    def live_array(self) -> np.ndarray:
        """Zero-copy view for the jnp JIT path (padded to capacity)."""
        return self._data


class MapRegistry:
    """Numbered map table a program is verified and executed against.

    Map ids are stable for the registry's lifetime — programs are verified
    against them — so userspace RELOADS data into an existing map (found by
    name) rather than registering a fresh one; see
    :meth:`~repro.core.mm.MemoryManager.load_profile`.
    """

    def __init__(self) -> None:
        self._maps: list[ArrayMap] = []

    def register(self, m: ArrayMap) -> int:
        self._maps.append(m)
        return len(self._maps) - 1

    def find(self, name: str) -> int | None:
        """Map id of the map registered under ``name`` (None if absent)."""
        for i, m in enumerate(self._maps):
            if m.name == name:
                return i
        return None

    def __len__(self) -> int:
        return len(self._maps)

    def __getitem__(self, map_id: int) -> ArrayMap:
        return self._maps[map_id]

    def lens(self) -> list[int]:
        return [len(m) for m in self._maps]

    def version(self) -> tuple:
        """Registry-wide content version — lets executors cache device-side
        map arguments until userspace reloads a profile."""
        return (len(self._maps), tuple(m.version for m in self._maps))
