"""The framework memory manager — eBPF-mm's kernel side, adapted to a TPU pool.

Owns the HBM block pool (buddy allocator), per-process page tables, the DAMON
monitors, and the hook points.  The serving engine calls ``ensure_mapped`` /
``ensure_range`` as sequences grow (the page-fault analogue); the decision of
*which page size backs the fault* is delegated to the attached policy program
exactly as in the paper, with the kernel-default path (THP-greedy or
base-pages-only) when no program/profile is present.

All costs are accounted in modeled target-TPU nanoseconds via the CostModel,
so policies can be compared quantitatively on a CPU-only host; the physical
copies (zeroing, migration, compaction) are emitted as explicit move lists
that the device executes with the block_copy Pallas kernel.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from ..obs.ringbuf import (EV_COLLAPSE, EV_COMPACT, EV_FAULT, EV_RECLAIM)
from ..resilience.supervisor import PolicySupervisor
from .buddy import RADIX, BuddyAllocator, BuddyError, order_blocks
from .context import (CTX, CTX_LEN, FIXED_POINT, MAX_TIERS, NUM_ORDERS,
                      POLICY_DETACHED, POLICY_FALLBACK, FaultContext,
                      FaultKind, ctx_batch, fill_system_columns)
from .cost import CostModel
from .damon import Damon
from .hooks import (HOOK_EVICT, HOOK_FAULT, HOOK_PROFILE, HOOK_RECLAIM,
                    HOOK_TIER, HookRegistry)
from .maps import ArrayMap, MapRegistry
from .profiles import MAX_PROFILE_REGIONS, Profile


class MMError(Exception):
    pass


class MMOutOfMemory(MMError):
    def __init__(self, msg: str, victim_pid: int | None = None) -> None:
        super().__init__(msg)
        self.victim_pid = victim_pid


@dataclass
class PageMapping:
    logical_start: int
    phys_start: int               # block index within the owning tier's pool
    order: int
    # Tier id in the N-pool chain, 0..MAX_TIERS-1 ordered fastest to slowest
    # (0 = local HBM; 1.. = peer-HBM / host DRAM / NVMe — see core.tiering).
    tier: int = 0
    # Read-only borrow of a prefix-cache block: the physical page belongs to
    # the cache (refcounted there), not to this process — frees skip it,
    # collapse/tier scans leave it alone, and the first write goes through
    # ``cow_break`` (copy-on-write) instead of mutating the shared page.
    shared: bool = False


@dataclass
class ProcessState:
    pid: int
    app: str | None
    vma_end: int                      # logical blocks, VMA is [0, vma_end)
    damon: Damon
    page_table: dict[int, PageMapping] = field(default_factory=dict)
    mapped: set = field(default_factory=set)   # logical block indices
    accesses: int = 0
    # Incremental device-visible block table: logical block -> combined
    # device index (-1 = unmapped).  Updated in place at install/unmap/
    # collapse/compaction/migration time by the MemoryManager — never
    # rebuilt per step.  Mutate mappings only through MemoryManager APIs
    # (install/unmap/collapse/migrate) or the table goes stale.
    blocktab: np.ndarray = field(
        default_factory=lambda: np.empty(0, dtype=np.int32))
    # Monotonic generation counter for ``blocktab``: bumped on EVERY span
    # write or unmap (install/compaction/collapse/tier migration included),
    # so a device-resident table row is stale iff its recorded version
    # differs.  This is what makes dirty-row uploads safe against same-step
    # migrations: _note_mapped goes through _set_span, which bumps it.
    table_version: int = 0
    # Mapping-metadata arrays (sorted starts/sizes/orders/tiers/device
    # indices) for the vectorized access-accounting path; rebuilt lazily
    # when a mapping changes.
    meta_dirty: bool = True
    meta: tuple | None = None

    def mappings_sorted(self) -> list[PageMapping]:
        return [self.page_table[k] for k in sorted(self.page_table)]


@dataclass
class MMStats:
    faults: int = 0
    hinted_faults: int = 0
    fallback_faults: int = 0
    pages_per_order: list[int] = field(default_factory=lambda: [0] * NUM_ORDERS)
    blocks_zeroed: int = 0
    compactions: int = 0
    compaction_blocks_moved: int = 0
    promotions: int = 0
    promotion_blocks_copied: int = 0
    evictions: int = 0
    mgmt_ns: int = 0                  # modeled time spent on zero/compact/migrate
    access_ns: int = 0                # modeled time streaming pages for attention
    descriptors_touched: int = 0      # TLB-miss analogue
    # Tiered-memory counters (HBM <-> host DRAM; see core.tiering)
    demotions: int = 0                # pages moved HBM -> host tier
    demotion_blocks: int = 0
    tier_promotions: int = 0          # pages moved host tier -> HBM
    tier_promotion_blocks: int = 0
    tier_reads: int = 0               # attention reads served from the host tier
    # Resilience counters (see core.tiering migration retry/abort paths and
    # the per-tier injected allocation failures)
    migrate_retries: int = 0          # failed copy attempts that were retried
    migrate_aborts: int = 0           # hops abandoned after retry exhaustion
    tier_alloc_failures: int = 0      # injected transient per-tier alloc fails

    def snapshot(self) -> dict:
        return {
            "faults": self.faults,
            "hinted_faults": self.hinted_faults,
            "fallback_faults": self.fallback_faults,
            "pages_per_order": list(self.pages_per_order),
            "blocks_zeroed": self.blocks_zeroed,
            "compactions": self.compactions,
            "compaction_blocks_moved": self.compaction_blocks_moved,
            "promotions": self.promotions,
            "promotion_blocks_copied": self.promotion_blocks_copied,
            "evictions": self.evictions,
            "mgmt_ns": self.mgmt_ns,
            "access_ns": self.access_ns,
            "descriptors_touched": self.descriptors_touched,
            "demotions": self.demotions,
            "demotion_blocks": self.demotion_blocks,
            "tier_promotions": self.tier_promotions,
            "tier_promotion_blocks": self.tier_promotion_blocks,
            "tier_reads": self.tier_reads,
            "migrate_retries": self.migrate_retries,
            "migrate_aborts": self.migrate_aborts,
            "tier_alloc_failures": self.tier_alloc_failures,
        }


@dataclass
class FaultResult:
    order: int
    phys_start: int
    hinted: bool
    compacted: bool
    moves: list                       # [(src_start, dst_start, order)] for device


class MemoryManager:
    def __init__(self, num_blocks: int, cost: CostModel, *,
                 default_mode: str = "thp", max_order: int = NUM_ORDERS - 1,
                 damon_seed: int = 0, telemetry=None, injector=None,
                 containment: bool = True) -> None:
        if default_mode not in ("thp", "never"):
            raise ValueError("default_mode must be 'thp' or 'never'")
        self.buddy = BuddyAllocator(num_blocks, max_order=max_order)
        self.cost = cost
        self.default_mode = default_mode
        self.max_order = max_order
        # telemetry hub (repro.obs.Telemetry) or None (default, zero cost):
        # tracepoints below fire framework events with the MODELED clock so
        # streams stay deterministic; wall-time observations never land in
        # MMStats (the differential harness asserts snapshot equality
        # across replicas — telemetry keeps its own books).
        self.telemetry = telemetry
        # seeded chaos injector (repro.resilience.FailureInjector) or None;
        # containment=False is the no-containment baseline: faults still
        # fire but the supervisor never detaches, migrations never retry,
        # quarantine never routes around a bad edge.
        self.injector = injector
        self.containment = bool(containment)
        self.hooks = HookRegistry(
            telemetry=telemetry, injector=injector,
            supervisor=PolicySupervisor(enabled=self.containment))
        self.maps = MapRegistry()
        self.procs: dict[int, ProcessState] = {}
        self.profiles: dict[str, tuple[Profile, int]] = {}   # app -> (profile, map_id)
        self.stats = MMStats()
        self.ktime_ns = 0
        self._damon_seed = damon_seed
        self._move_log: list[tuple[int, int, int]] = []   # pending device copies
        self._access_tab: tuple[np.ndarray, np.ndarray] | None = None
        # Physical-placement listeners: callables ``cb(tier, remap)`` invoked
        # whenever compaction relocates blocks within a tier's pool.  The
        # prefix cache registers one — its idle (refcount-0) blocks live in
        # no page table, so the page-table remap loop alone would strand them.
        self.compaction_listeners: list = []

    # ------------------------------------------------------------- userspace
    def load_profile(self, profile: Profile) -> int:
        """Userspace loads an application profile into an eBPF map.

        Reloading the same app's profile reuses its existing map slot (found
        by name) — a reload is a map WRITE, not a new map, so attached
        programs keep their verified map ids and the executors only refresh
        cached map arguments."""
        cap = MAX_PROFILE_REGIONS * (2 + NUM_ORDERS)
        name = f"profile:{profile.app}"
        map_id = self.maps.find(name)
        if map_id is None:
            map_id = self.maps.register(ArrayMap(cap, name=name))
        profile.load_into(self.maps[map_id])
        self.profiles[profile.app] = (profile, map_id)
        return map_id

    def attach_fault_program(self, program) -> None:
        self.hooks.attach(HOOK_FAULT, program, self.maps)

    def attach_reclaim_program(self, program) -> None:
        self.hooks.attach(HOOK_RECLAIM, program, self.maps)

    def attach_tier_program(self, program) -> None:
        self.hooks.attach(HOOK_TIER, program, self.maps)

    def attach_evict_program(self, program) -> None:
        self.hooks.attach(HOOK_EVICT, program, self.maps)

    def attach_profile_program(self, program) -> None:
        self.hooks.attach(HOOK_PROFILE, program, self.maps)

    # ------------------------------------------------------- online profiling
    def profile_scan(self, pid: int) -> list[tuple] | None:
        """One batched ``HOOK_PROFILE`` invocation over ``pid``'s live DAMON
        regions — the sampled profiler surface on the aggregation tick.

        Each ctx row is one region (PROF_* columns: bounds, FIXED_POINT
        access EMA, age, the pid's mapped-block count and the DAMON window
        counter) over the usual shared system snapshot, mirroring the
        tier/evict scan builders.  Returns rows aligned with the region
        snapshot, ``(start, end, heat_milli, age, score)`` where ``score``
        is the program's return value (POLICY_FALLBACK rows defer to
        host-side synthesis from raw heat; POLICY_DETACHED rows follow a
        mid-scan supervisor detach).  Returns None when no profiler program
        is attached — the scan builds no ctx at all, the zero-overhead
        property every hook keeps."""
        if not self.hooks.attached(HOOK_PROFILE):
            return None
        st = self.procs[pid]
        regions = st.damon.regions
        n = len(regions)
        if n == 0:
            return []
        mat = ctx_batch(n)
        fill_system_columns(mat, **self.system_ctx_columns())
        mat[:, CTX.PID] = st.pid
        mat[:, CTX.VMA_END] = st.vma_end
        mat[:, CTX.SEQ_LEN] = st.vma_end
        mat[:, CTX.PROF_MAPPED_BLOCKS] = len(st.mapped)
        mat[:, CTX.PROF_WINDOW] = st.damon.version
        mat[:, CTX.PROF_REGION_START] = \
            np.fromiter((r.start for r in regions), np.int64, n)
        mat[:, CTX.PROF_REGION_END] = \
            np.fromiter((r.end for r in regions), np.int64, n)
        mat[:, CTX.PROF_REGION_HEAT] = np.fromiter(
            (int(r.nr_accesses * FIXED_POINT) for r in regions), np.int64, n)
        mat[:, CTX.PROF_REGION_AGE] = \
            np.fromiter((r.age for r in regions), np.int64, n)
        decisions = self.hooks.run_batch(HOOK_PROFILE, mat)
        return [(int(mat[i, CTX.PROF_REGION_START]),
                 int(mat[i, CTX.PROF_REGION_END]),
                 int(mat[i, CTX.PROF_REGION_HEAT]),
                 int(mat[i, CTX.PROF_REGION_AGE]),
                 int(decisions[i])) for i in range(n)]

    # ------------------------------------------------------------- processes
    def create_process(self, pid: int, *, app: str | None = None,
                       vma_blocks: int = 0) -> ProcessState:
        if pid in self.procs:
            raise MMError(f"pid {pid} already exists")
        st = ProcessState(pid=pid, app=app, vma_end=vma_blocks,
                          damon=Damon(max(1, vma_blocks), seed=self._damon_seed + pid))
        self.procs[pid] = st
        return st

    def grow_vma(self, pid: int, new_end: int) -> None:
        st = self.procs[pid]
        if new_end > st.vma_end:
            st.vma_end = new_end
            st.damon.grow(new_end)

    def free_process(self, pid: int) -> None:
        st = self.procs.pop(pid)
        for m in st.page_table.values():
            self._free_phys(m)

    def unmap(self, pid: int, logical_start: int) -> None:
        """Drop one mapping and release its physical page (partial free —
        e.g. punching holes to fragment a pool).  Goes through the manager
        so the incremental block table stays in sync."""
        st = self.procs[pid]
        m = st.page_table.pop(logical_start)
        size = order_blocks(m.order)
        st.mapped.difference_update(range(m.logical_start,
                                          m.logical_start + size))
        self._free_phys(m)
        self._note_unmapped(st, m.logical_start, m.order)

    def _free_phys(self, m: PageMapping) -> None:
        """Release a mapping's physical page into its tier's allocator.

        Shared (prefix-cache-borrowed) pages are NOT freed here: the cache
        owns the physical blocks and releases them when the entry's refcount
        drops and the eviction policy says so."""
        if m.shared:
            return
        self.buddy.free(m.phys_start)

    def _device_index(self, m: PageMapping) -> int:
        """Base-block index of ``m`` in the device-visible (combined) pool."""
        return m.phys_start

    # ------------------------------------------- prefix-cache integration
    # The cache owns physical blocks OUTSIDE any page table (allocated via
    # cache_alloc_block, refcounted in serving.prefix_cache); borrowers get
    # order-0 ``shared=True`` mappings that point at them read-only.

    def cache_alloc_block(self) -> int | None:
        """Allocate one cache-owned base block in tier 0 (HBM).  Returns the
        tier-local phys index, or None when the pool can't supply it — cache
        insertion is opportunistic and must never OOM a live sequence."""
        try:
            return self.buddy.alloc(0)
        except BuddyError:
            return None

    def cache_free_block(self, tier: int, phys: int) -> None:
        """Release one cache-owned base block back to ``tier``'s pool."""
        if tier != 0:
            raise MMError(f"untiered manager holds no tier-{tier} blocks")
        self.buddy.free(phys)

    def cache_device_index(self, tier: int, phys: int) -> int:
        """Combined device index of a cache-owned block (tier-aware in the
        tiered subclass)."""
        if tier != 0:
            raise MMError(f"untiered manager holds no tier-{tier} blocks")
        return phys

    def migrate_cache_block(self, blk, dst_tier: int) -> bool:
        """Move one cache-owned block toward ``dst_tier``.  The untiered
        manager has nowhere to put it — eviction decisions degrade to
        keep-or-drop (the tiered subclass migrates for real)."""
        return False

    def map_shared(self, pid: int, logical_start: int,
                   blocks: list[tuple[int, int]]) -> None:
        """Install read-only borrows of cache-owned blocks as consecutive
        order-0 mappings starting at ``logical_start``.  ``blocks`` is
        ``[(tier, phys), ...]`` in logical order.  No zeroing, no fault
        accounting — the KV content already exists; this is the page-table
        surgery of a cache hit."""
        st = self.procs[pid]
        for i, (tier, phys) in enumerate(blocks):
            a = logical_start + i
            if a in st.mapped:
                raise MMError(f"pid {pid}: shared map over mapped block {a}")
            m = PageMapping(logical_start=a, phys_start=phys, order=0,
                            tier=tier, shared=True)
            st.page_table[a] = m
            st.mapped.add(a)
            self._note_installed(st, m)
            self.stats.descriptors_touched += 1

    def cow_break(self, pid: int, logical_block: int) -> list[tuple[int, int, int]]:
        """Copy-on-write barrier: repoint one shared mapping at a freshly
        allocated private tier-0 page, emitting the block copy on the move
        list (the existing migration machinery executes it pre-kernel).
        Returns the emitted moves.  No-op for a non-shared mapping."""
        st = self.procs[pid]
        m = st.page_table[logical_block]
        if not m.shared:
            return []
        size = order_blocks(m.order)
        src_dev = self._device_index(m)
        phys = None
        compacted = False
        while phys is None:
            try:
                phys = self.buddy.alloc(m.order)
            except BuddyError:
                plan = self.buddy.plan_compaction(m.order)
                if plan is not None and not compacted:
                    self._apply_compaction(plan)
                    compacted = True
                    continue
                victim = self._pick_reclaim_victim(exclude=st.pid)
                raise MMOutOfMemory(
                    f"pool exhausted on copy-on-write (pid {st.pid})",
                    victim_pid=victim)
        src_tier = m.tier
        m.phys_start = phys
        m.tier = 0
        m.shared = False
        self._note_mapped(st, m)
        moves = [(src_dev, self._device_index(m), m.order)]
        self._move_log.extend(moves)
        if src_tier == 0:
            self.stats.mgmt_ns += self.cost.compact_ns_per_block() * size
        else:
            self.stats.mgmt_ns += int(self.cost.migrate_ns(m.order,
                                                           src_tier, 0))
        return moves

    def queue_block_copy(self, src_dev: int, dst_dev: int,
                         order: int = 0) -> None:
        """Queue one device block copy on the move list.  Prefix-cache insert
        copies ride the same pre-kernel flush as migrations/compactions, so
        the engine's hazard segmentation orders them against any same-drain
        move that touches the donor block."""
        self._move_log.append((src_dev, dst_dev, order))
        self.stats.mgmt_ns += \
            self.cost.compact_ns_per_block() * order_blocks(order)

    # ------------------------------------------------ incremental block table
    def _table(self, st: ProcessState) -> np.ndarray:
        """The process's cached logical->device table, grown to the VMA."""
        if st.blocktab.size < st.vma_end:
            t = np.full(max(st.vma_end, 1), -1, dtype=np.int32)
            t[:st.blocktab.size] = st.blocktab
            st.blocktab = t
        return st.blocktab

    def _set_span(self, st: ProcessState, m: PageMapping) -> int:
        t = self._table(st)
        size = order_blocks(m.order)
        base = self._device_index(m)
        t[m.logical_start:m.logical_start + size] = \
            base + np.arange(size, dtype=np.int32)
        st.table_version += 1
        return base

    def _note_installed(self, st: ProcessState, m: PageMapping) -> None:
        """A NEW mapping: extend the table span; append to the metadata
        arrays in place when the mapping lands past the current tail (the
        decode-growth pattern), full rebuild otherwise."""
        base = self._set_span(st, m)
        if st.meta is not None and not st.meta_dirty:
            starts, sizes, orders, tiers, dev = st.meta
            if starts.size == 0 or m.logical_start > starts[-1]:
                st.meta = (np.append(starts, m.logical_start),
                           np.append(sizes, order_blocks(m.order)),
                           np.append(orders, m.order),
                           np.append(tiers, m.tier),
                           np.append(dev, base))
                return
        st.meta_dirty = True

    def _note_mapped(self, st: ProcessState, m: PageMapping) -> None:
        """An EXISTING mapping changed physical placement (compaction, tier
        migration): refresh its table span; patch the metadata arrays in
        place when its geometry is unchanged."""
        base = self._set_span(st, m)
        if st.meta is not None and not st.meta_dirty:
            starts, sizes, orders, tiers, dev = st.meta
            idx = int(np.searchsorted(starts, m.logical_start))
            if idx < starts.size and starts[idx] == m.logical_start \
                    and orders[idx] == m.order:
                tiers[idx] = m.tier
                dev[idx] = base
                return
        st.meta_dirty = True

    def _note_unmapped(self, st: ProcessState, logical_start: int,
                       order: int) -> None:
        t = self._table(st)
        t[logical_start:logical_start + order_blocks(order)] = -1
        st.table_version += 1
        st.meta_dirty = True

    def _mapping_arrays(self, st: ProcessState) -> tuple:
        """(starts, sizes, orders, tiers, dev) int64 arrays sorted by start,
        rebuilt lazily when a mapping changed (dirty tracking)."""
        if st.meta_dirty or st.meta is None:
            ms = st.mappings_sorted()
            n = len(ms)
            starts = np.fromiter((m.logical_start for m in ms), np.int64, n)
            orders = np.fromiter((m.order for m in ms), np.int64, n)
            tiers = np.fromiter((m.tier for m in ms), np.int64, n)
            dev = np.fromiter((self._device_index(m) for m in ms), np.int64, n)
            st.meta = (starts, RADIX ** orders, orders, tiers, dev)
            st.meta_dirty = False
        return st.meta

    # ---------------------------------------------------------------- faults
    def fault_max_order(self, st: ProcessState, addr: int) -> int:
        k = self.max_order
        while k > 0:
            size = order_blocks(k)
            a = (addr // size) * size
            if a + size <= st.vma_end and not any(
                    b in st.mapped for b in range(a, a + size)):
                return k
            k -= 1
        return 0

    def _build_ctx(self, st: ProcessState, addr: int, kind: FaultKind) -> np.ndarray:
        bstats = self.buddy.stats()
        has_profile = int(st.app in self.profiles) if st.app else 0
        map_id, nregions = 0, 0
        if has_profile:
            prof, map_id = self.profiles[st.app]
            nregions = len(prof.regions)
        fc = FaultContext(
            addr=addr, pid=st.pid, vma_start=0, vma_end=st.vma_end,
            fault_max_order=self.fault_max_order(st, addr),
            has_profile=has_profile, profile_map_id=map_id,
            profile_nregions=nregions,
            free_blocks=bstats.free_per_order,
            frag=bstats.frag_index_milli,
            heat=st.damon.heat_vector(addr),
            zero_ns_per_block=self.cost.zero_ns_per_block(),
            compact_ns_per_block=self.cost.compact_ns_per_block(),
            descriptor_ns=int(self.cost.hw.descriptor_ns),
            block_bytes=self.cost.block_bytes,
            ktime_ns=self.ktime_ns,
            mem_pressure=bstats.utilization_milli,
            fault_kind=int(kind),
            seq_len=st.vma_end,
        )
        return fc.vector()

    def _default_order(self, fmax: int) -> int:
        return min(2, fmax) if self.default_mode == "thp" else 0

    def system_ctx_columns(self) -> dict:
        """One system-state snapshot as :func:`fill_system_columns` kwargs —
        the shared columns of any batched ctx build (the evict scan uses
        this; the fault/tier builders keep their fused inline versions).
        The tiered subclass extends it with per-tier pool state and the
        edge-cost tables."""
        bstats = self.buddy.stats()
        return dict(
            free_blocks=bstats.free_per_order,
            frag=bstats.frag_index_milli,
            zero_ns_per_block=self.cost.zero_ns_per_block(),
            compact_ns_per_block=self.cost.compact_ns_per_block(),
            descriptor_ns=int(self.cost.hw.descriptor_ns),
            block_bytes=self.cost.block_bytes,
            ktime_ns=self.ktime_ns,
            mem_pressure=bstats.utilization_milli)

    def ensure_mapped(self, pid: int, addr: int,
                      kind: FaultKind = FaultKind.FIRST_TOUCH) -> FaultResult | None:
        """The page-fault entry point. Returns None if already mapped."""
        st = self.procs[pid]
        if addr >= st.vma_end:
            raise MMError(f"pid {pid}: fault at {addr} beyond VMA end {st.vma_end}")
        if addr in st.mapped:
            return None
        if not self.hooks.attached(HOOK_FAULT):
            # the paper's zero-overhead property: with no program attached the
            # default path runs without building the eBPF context at all
            fmax = self.fault_max_order(st, addr)
            return self._install(st, addr, self._default_order(fmax), False)
        ctx = self._build_ctx(st, addr, kind)
        fmax = int(ctx[CTX.FAULT_MAX_ORDER])
        decision = self.hooks.run(HOOK_FAULT, ctx)
        hinted = decision is not None and decision != POLICY_FALLBACK
        if not hinted:
            order = self._default_order(fmax)
            if decision == POLICY_FALLBACK:
                self.stats.fallback_faults += 1
        else:
            order = max(0, min(int(decision), fmax))
        return self._install(st, addr, order, hinted)

    def ensure_range(self, pid: int, start: int, end: int) -> list[FaultResult]:
        """Bulk fault (prefill/mmap population), scalar path: one policy
        invocation per fault.  Kept as the reference/no-program route; the
        engine's hot path uses :meth:`fault_range`."""
        results = []
        st = self.procs[pid]
        addr = start
        while addr < end:
            r = self.ensure_mapped(pid, addr, FaultKind.PREFILL)
            if r is None:
                addr += 1
            else:
                size = order_blocks(r.order)
                addr = (addr // size) * size + size
                results.append(r)
        return results

    # --------------------------------------------------------- batched faults
    def fault_batch(self, reqs: list[tuple[int, int, FaultKind]]
                    ) -> list[FaultResult | None]:
        """Resolve many faults through ONE policy invocation.

        ``reqs`` is ``[(pid, addr, kind), ...]``; the return list is aligned
        with it (``None`` = already mapped, or covered by an earlier grant in
        the same batch).  The ctx matrix is built from one system-state
        snapshot (one ``buddy.stats()``, vectorized DAMON heat) and decided
        by a single ``hooks.run_batch`` call; installs then run in request
        order with install-time conflict resolution — an earlier grant that
        covers a later request skips it, one that overlaps a later request's
        window shrinks its feasible order (the grant is clamped to a freshly
        computed ``fault_max_order``).  OOM/degrade/compaction semantics are
        identical to the scalar path: the first request that cannot be
        satisfied raises :class:`MMOutOfMemory` with earlier installs kept,
        exactly like the scalar loop.  With no program attached the default
        path installs directly — no ctx is built (zero-overhead property).
        """
        results: list[FaultResult | None] = [None] * len(reqs)
        pend: list[int] = []
        for i, (pid, addr, _kind) in enumerate(reqs):
            st = self.procs[pid]
            if addr >= st.vma_end:
                raise MMError(
                    f"pid {pid}: fault at {addr} beyond VMA end {st.vma_end}")
            if addr not in st.mapped:
                pend.append(i)
        if not pend:
            return results
        if not self.hooks.attached(HOOK_FAULT):
            for i in pend:
                pid, addr, _kind = reqs[i]
                st = self.procs[pid]
                if addr in st.mapped:          # covered by an earlier install
                    continue
                fmax = self.fault_max_order(st, addr)
                results[i] = self._install(st, addr,
                                           self._default_order(fmax), False)
            return results
        ctx_mat = self._build_ctx_batch([reqs[i] for i in pend])
        # raw decisions: rows covered by an earlier grant are never consumed
        # (the scalar route never faults them), so the misbehavior pass runs
        # per CONSUMED row below — strikes stay identical across routes
        decisions = self.hooks.run_batch(HOOK_FAULT, ctx_mat,
                                         discipline=False)
        row_disc = self.hooks.row_discipline_needed(HOOK_FAULT, decisions)
        # fault_max_order depends only on the pid's own mapped set, which the
        # ctx build just scanned (vectorized): recompute per row only when an
        # EARLIER install in this batch touched the same pid.  Engine decode
        # batches carry distinct pids, so the hot path reuses every row.
        touched: set[int] = set()
        for row, i in enumerate(pend):
            pid, addr, _kind = reqs[i]
            st = self.procs[pid]
            if addr in st.mapped:              # conflict: earlier grant won
                continue
            fmax = self.fault_max_order(st, addr) if pid in touched \
                else int(ctx_mat[row, CTX.FAULT_MAX_ORDER])
            touched.add(pid)
            decision = int(decisions[row])
            if row_disc:
                decision = self.hooks.discipline_row(HOOK_FAULT,
                                                     ctx_mat[row], decision)
            if decision == POLICY_DETACHED:
                # the supervisor detached the program mid-batch: this row
                # takes the unattached default path — no fallback accounting,
                # exactly like the scalar route where post-detach faults
                # never reach the hook
                results[i] = self._install(st, addr,
                                           self._default_order(fmax), False)
                continue
            hinted = decision != POLICY_FALLBACK
            if not hinted:
                order = self._default_order(fmax)
                self.stats.fallback_faults += 1
            else:
                order = max(0, min(decision, fmax))
            results[i] = self._install(st, addr, order, hinted)
        return results

    def fault_range(self, pid: int, start: int, end: int,
                    kind: FaultKind = FaultKind.PREFILL) -> list[FaultResult]:
        """Batched :meth:`ensure_range`: the whole span resolves through one
        policy invocation (every unmapped block is a candidate; blocks
        covered by an earlier grant in the batch are skipped at install)."""
        res = self.fault_batch([(pid, a, kind) for a in range(start, end)])
        return [r for r in res if r is not None]

    def place_decode(self, reqs: list[tuple[int, int, FaultKind]]) -> None:
        """Decode-time tier placement for a completed batch of FIRST_TOUCH
        faults.  The untiered manager has no placement to decide — the
        tiered subclass consults HOOK_TIER here (one batched consult after
        all installs).  ``fault_batch`` runs it internally on the tiered
        manager; SCALAR callers invoke it once after their ``ensure_mapped``
        loop so both routes consult placement at the same post-install
        state."""

    def _build_ctx_batch(self, reqs: list[tuple[int, int, FaultKind]]
                         ) -> np.ndarray:
        """Vectorized :meth:`_build_ctx`: one buddy snapshot shared by every
        row, per-pid vectorized DAMON heat and feasible-order computation.
        Row ``i`` equals ``_build_ctx(procs[pid_i], addr_i, kind_i)`` built
        at batch-start state."""
        bstats = self.buddy.stats()
        n = len(reqs)
        mat = ctx_batch(n)
        fill_system_columns(
            mat,
            free_blocks=bstats.free_per_order,
            frag=bstats.frag_index_milli,
            zero_ns_per_block=self.cost.zero_ns_per_block(),
            compact_ns_per_block=self.cost.compact_ns_per_block(),
            descriptor_ns=int(self.cost.hw.descriptor_ns),
            block_bytes=self.cost.block_bytes,
            ktime_ns=self.ktime_ns,
            mem_pressure=bstats.utilization_milli)
        pids = np.fromiter((r[0] for r in reqs), np.int64, n)
        addrs = np.fromiter((r[1] for r in reqs), np.int64, n)
        kinds = np.fromiter((int(r[2]) for r in reqs), np.int64, n)
        mat[:, CTX.ADDR] = addrs
        mat[:, CTX.PID] = pids
        mat[:, CTX.FAULT_KIND] = kinds
        # Per-process state is gathered through concatenated cumsum tables so
        # the whole batch resolves in a fixed number of numpy ops, however
        # many processes it spans.
        upids, inv = np.unique(pids, return_inverse=True)
        sts = [self.procs[int(p)] for p in upids]
        g = len(sts)
        ves = np.fromiter((st.vma_end for st in sts), np.int64, g)
        mat[:, CTX.VMA_END] = ves[inv]
        mat[:, CTX.SEQ_LEN] = ves[inv]
        has, mapid, nreg = np.zeros(g, np.int64), np.zeros(g, np.int64), \
            np.zeros(g, np.int64)
        for j, st in enumerate(sts):
            if st.app and st.app in self.profiles:
                prof, map_id = self.profiles[st.app]
                has[j], mapid[j], nreg[j] = 1, map_id, len(prof.regions)
        mat[:, CTX.HAS_PROFILE] = has[inv]
        mat[:, CTX.PROFILE_MAP_ID] = mapid[inv]
        mat[:, CTX.PROFILE_NREGIONS] = nreg[inv]
        sizes = self._ORDER_SIZES[:NUM_ORDERS]
        a = (addrs[:, None] // sizes) * sizes                     # [N, K]
        # --- DAMON heat, all rows/orders at once ---
        csums = [st.damon._heat_csum() for st in sts]
        offs = np.zeros(g, np.int64)
        offs[1:] = np.cumsum([c.size for c in csums])[:-1]
        heat_cat = np.concatenate(csums)
        spaces = np.fromiter((st.damon.space_blocks for st in sts),
                             np.int64, g)
        row_space = spaces[inv][:, None]
        row_off = offs[inv][:, None]
        lo = np.minimum(a, row_space)
        hi = np.minimum(a + sizes, row_space)
        total = heat_cat[row_off + hi] - heat_cat[row_off + lo]
        covered = hi - lo
        heat = np.where(covered > 0, total / np.maximum(covered, 1), 0.0)
        mat[:, CTX.HEAT_O0:CTX.HEAT_O0 + NUM_ORDERS] = \
            heat.astype(np.int64)
        # --- feasible order (vectorized fault_max_order), same pattern;
        #     candidate orders stop at self.max_order like the scalar path ---
        frees = [np.concatenate(
            [[0], np.cumsum(self._table(st)[:st.vma_end] == -1)])
            for st in sts]
        foffs = np.zeros(g, np.int64)
        foffs[1:] = np.cumsum([f.size for f in frees])[:-1]
        free_cat = np.concatenate(frees)
        row_ve = ves[inv][:, None]
        row_foff = foffs[inv][:, None]
        ks = self.max_order + 1
        fsizes = sizes[:ks]
        af = a[:, :ks]
        flo = np.minimum(af, row_ve)
        fhi = np.minimum(af + fsizes, row_ve)
        span_free = free_cat[row_foff + fhi] - free_cat[row_foff + flo]
        ok = (af + fsizes <= row_ve) & (span_free == fsizes)
        mat[:, CTX.FAULT_MAX_ORDER] = \
            (ok * np.arange(ks, dtype=np.int64)).max(axis=1)
        # Within-batch free-list reservation: every row of a batch shares the
        # batch-start buddy snapshot, so a budget-aware program could commit
        # the same free blocks N times over.  Row i's BATCH_RESERVED is an
        # upper bound on what rows 0..i-1 can consume (each grant is clamped
        # to its fault_max_order, i.e. at most 4^fmax base blocks) — programs
        # subtract it from the FREE_BLOCKS_* columns to see within-batch
        # grants.  Optimality only: installs already clamp on the live buddy.
        grants = sizes[mat[:, CTX.FAULT_MAX_ORDER]]
        mat[1:, CTX.BATCH_RESERVED] = np.cumsum(grants[:-1])
        return mat

    _ORDER_SIZES = RADIX ** np.arange(NUM_ORDERS, dtype=np.int64)

    def _install(self, st: ProcessState, addr: int, order: int,
                 hinted: bool) -> FaultResult:
        size = order_blocks(order)
        a = (addr // size) * size
        compacted = False
        moves: list[tuple[int, int, int]] = []
        phys = None
        while phys is None:
            try:
                phys = self.buddy.alloc(order)
            except BuddyError:
                plan = self.buddy.plan_compaction(order)
                if plan is not None and not compacted:
                    self._apply_compaction(plan)
                    moves.extend(plan)
                    compacted = True
                    continue
                if order > 0:           # degrade, like a failed THP allocation
                    order = order - 1
                    size = order_blocks(order)
                    a = (addr // size) * size
                    continue
                victim = self._pick_reclaim_victim(exclude=st.pid)
                raise MMOutOfMemory(
                    f"pool exhausted on order-0 fault (pid {st.pid})",
                    victim_pid=victim)
        m = PageMapping(logical_start=a, phys_start=phys, order=order)
        st.page_table[a] = m
        st.mapped.update(range(a, a + size))
        self._note_installed(st, m)
        self.stats.faults += 1
        if hinted:
            self.stats.hinted_faults += 1
        self.stats.pages_per_order[order] += 1
        self.stats.blocks_zeroed += size
        self.stats.mgmt_ns += self.cost.zero_ns_per_block() * size
        tel = self.telemetry
        if tel is not None and tel.enabled:
            tel.emit(EV_FAULT, st.pid, a, order | (int(hinted) << 8),
                     ts=self.ktime_ns)
        return FaultResult(order=order, phys_start=phys, hinted=hinted,
                           compacted=compacted, moves=moves)

    def _apply_compaction(self, plan: list[tuple[int, int, int]], *,
                          tier: int = 0, device_offset: int = 0) -> None:
        """Buddy already mutated its allocation map; fix page tables and
        account the migration cost + device move list.  ``tier`` selects
        which tier's mappings the plan refers to (each tier's pool has its
        own phys numbering) and ``device_offset`` shifts the emitted moves
        into combined device coordinates."""
        self.stats.compactions += 1
        remap = {src: dst for src, dst, _ in plan}
        for st in self.procs.values():
            for m in st.page_table.values():
                if m.tier == tier and m.phys_start in remap:
                    m.phys_start = remap[m.phys_start]
                    self._note_mapped(st, m)
        for cb in self.compaction_listeners:
            cb(tier, remap)
        blocks = sum(order_blocks(o) for _, _, o in plan)
        self.stats.compaction_blocks_moved += blocks
        self.stats.mgmt_ns += self.cost.compact_ns_per_block() * blocks
        self._move_log.extend((device_offset + s, device_offset + d, o)
                              for s, d, o in plan)
        tel = self.telemetry
        if tel is not None and tel.enabled:
            tel.emit(EV_COMPACT, tier, blocks,
                     self.cost.compact_ns_per_block() * blocks,
                     ts=self.ktime_ns)

    # ---------------------------------------------------------- khugepaged
    def collapse(self, pid: int, addr: int, to_order: int) -> FaultResult | None:
        """Promote the aligned window around ``addr`` to one order-k page
        (async promotion — the khugepaged analogue).  Existing data is
        migrated via the device copy list; holes are zero-filled."""
        st = self.procs[pid]
        size = order_blocks(to_order)
        a = (addr // size) * size
        if a + size > st.vma_end:
            return None
        # every mapping OVERLAPPING the window: a page of order >= to_order
        # whose start lies outside [a, a+size) still contains the window
        # (alignment), and collapsing "through" it would double-map the span
        # and zero-fill live KV — the differential harness caught exactly
        # that with a window inside an existing larger page.
        old = [m for m in st.page_table.values()
               if m.logical_start < a + size
               and m.logical_start + order_blocks(m.order) > a]
        if any(m.order >= to_order for m in old):
            return None   # already backed at >= target order
        if any(m.tier != 0 for m in old):
            return None   # demoted pages must be promoted before collapsing
        if any(m.shared for m in old):
            return None   # never collapse through cache-shared pages: the
            #               big page would alias refcounted cache blocks
        try:
            phys = self.buddy.alloc(to_order)
        except BuddyError:
            plan = self.buddy.plan_compaction(to_order)
            if plan is None:
                return None
            self._apply_compaction(plan)
            try:
                phys = self.buddy.alloc(to_order)
            except BuddyError:
                return None
        moves = []
        copied = 0
        for m in old:
            dst = phys + (m.logical_start - a)
            moves.append((m.phys_start, dst, m.order))
            copied += order_blocks(m.order)
            self.buddy.free(m.phys_start)
            del st.page_table[m.logical_start]
        big = PageMapping(a, phys, to_order)
        st.page_table[a] = big
        st.mapped.update(range(a, a + size))
        self._set_span(st, big)        # covers the holes + migrated spans
        st.meta_dirty = True           # structural change: old pages removed
        self.stats.promotions += 1
        self.stats.promotion_blocks_copied += copied
        self.stats.blocks_zeroed += size - copied
        self.stats.mgmt_ns += (self.cost.compact_ns_per_block() * copied
                               + self.cost.zero_ns_per_block() * (size - copied))
        self._move_log.extend(moves)
        tel = self.telemetry
        if tel is not None and tel.enabled:
            tel.emit(EV_COLLAPSE, pid, a, to_order, ts=self.ktime_ns)
        return FaultResult(order=to_order, phys_start=phys, hinted=True,
                           compacted=False, moves=moves)

    # ------------------------------------------------------------- reclaim
    def _pick_reclaim_victim(self, exclude: int) -> int | None:
        cands = [st for pid, st in self.procs.items()
                 if pid != exclude and st.page_table]
        if not cands:
            return None
        cands = sorted(cands, key=lambda s: s.pid)[:4]
        ctx = np.zeros(CTX_LEN, dtype=np.int64)
        ctx[CTX.ADDR] = len(cands)
        for i, st in enumerate(cands):
            mean_heat = (sum(r.nr_accesses for r in st.damon.regions)
                         / max(1, len(st.damon.regions)))
            ctx[CTX.HEAT_O0 + i] = int(mean_heat)
        choice = self.hooks.run(HOOK_RECLAIM, ctx)
        if choice is None or choice == POLICY_FALLBACK:
            # default: lowest pid (FIFO-ish)
            return cands[0].pid
        return cands[max(0, min(int(choice), len(cands) - 1))].pid

    def evict_process(self, pid: int) -> None:
        self.free_process(pid)
        self.stats.evictions += 1
        tel = self.telemetry
        if tel is not None and tel.enabled:
            tel.emit(EV_RECLAIM, pid, 0, 0, ts=self.ktime_ns)
            tel.inc("evictions")

    # -------------------------------------------------------------- access
    def _access_ns_tables(self) -> np.ndarray:
        """Per-(tier, order) access cost matrix, cached — the constants
        behind the vectorized access accounting.  Row 0 is HBM; rows 1..
        charge each spill tier's link bandwidth."""
        if self._access_tab is None:
            ks = range(self.max_order + 1)
            self._access_tab = np.stack([
                np.fromiter((int(self.cost.tier_access_ns(k, t)) for k in ks),
                            np.int64, self.max_order + 1)
                for t in range(MAX_TIERS)])
        return self._access_tab

    def record_access(self, pid: int, heat_per_block: np.ndarray) -> None:
        """Called once per engine step with the kernel-emitted heat stats.

        Access cost is charged only for mappings that were actually READ this
        step (nonzero attention mass over their span) — sliding-window and
        sparse-attention models do not stream their cold blocks.  The
        per-mapping accounting runs as numpy segment sums over the cached
        mapping arrays, not a Python loop."""
        st = self.procs[pid]
        heat = np.asarray(heat_per_block, dtype=np.float64)
        st.damon.record(heat)
        st.accesses += 1
        starts, sizes, orders, tiers, _ = self._mapping_arrays(st)
        if starts.size == 0:
            return
        csum = np.concatenate([[0.0], np.cumsum(heat)])
        lo = np.minimum(starts, heat.size)
        hi = np.minimum(starts + sizes, heat.size)
        read = (hi > lo) & ((csum[hi] - csum[lo]) > 0)
        self.stats.descriptors_touched += int(read.sum())
        acc = self._access_ns_tables()
        rt = np.minimum(tiers[read], MAX_TIERS - 1)
        self.stats.tier_reads += int((rt != 0).sum())
        self.stats.access_ns += int(acc[rt, orders[read]].sum())

    def descriptors_for(self, pid: int) -> int:
        return len(self.procs[pid].page_table)

    # ---------------------------------------------------- device integration
    def block_table(self, pid: int, max_blocks: int) -> np.ndarray:
        """Flattened logical->physical base-block map (-1 = unmapped).

        Served from the per-process incremental table — an O(max_blocks)
        numpy copy, not a per-mapping Python rebuild."""
        st = self.procs[pid]
        t = self._table(st)
        out = np.full(max_blocks, -1, dtype=np.int32)
        n = min(max_blocks, t.size)
        out[:n] = t[:n]
        return out

    def table_version(self, pid: int) -> int:
        """Generation counter of ``pid``'s incremental block table — changes
        exactly when any row of :meth:`block_table` would.  A device-resident
        mirror is fresh iff the version it recorded at upload still matches
        (the dirty-row protocol in :mod:`repro.serving.tables`)."""
        return self.procs[pid].table_version

    def page_lists_by_order(self, pids: list[int]) -> dict[int, np.ndarray]:
        """Per-order page lists for the multi-size paged-attention kernel.

        Returns {order: int32[[seq_slot, logical_page_idx, phys_page_start]]}.
        seq_slot is the position of the pid in ``pids``.  Assembled from the
        cached mapping arrays (dirty-tracked), vectorized per order.
        """
        out: dict[int, list] = {k: [] for k in range(self.max_order + 1)}
        for slot, pid in enumerate(pids):
            starts, _sizes, orders, _tiers, dev = \
                self._mapping_arrays(self.procs[pid])
            for k in range(self.max_order + 1):
                sel = orders == k
                if not sel.any():
                    continue
                rows = np.stack([
                    np.full(int(sel.sum()), slot, dtype=np.int64),
                    starts[sel] // order_blocks(k),
                    dev[sel]], axis=1)
                out[k].append(rows)
        return {k: (np.concatenate(v).astype(np.int32) if v
                    else np.zeros((0, 3), dtype=np.int32))
                for k, v in out.items()}

    def drain_moves(self) -> list[tuple[int, int, int]]:
        """Pending (src, dst, order) physical copies for the device."""
        mv, self._move_log = self._move_log, []
        return mv

    # ------------------------------------------------------------- misc
    def tick(self, ns: int = 1_000_000) -> None:
        tel = self.telemetry
        if tel is not None and tel.enabled:
            # per-(tier, order) residency sample, one block-tick per mapped
            # block per tick — the occupancy matrix behind the metrics
            # snapshot's residency_block_ticks
            for st in self.procs.values():
                _starts, sizes, orders, tiers, _dev = self._mapping_arrays(st)
                if sizes.size:
                    tel.observe_residency(tiers, orders, sizes)
        self.ktime_ns += ns

    def hugepage_block_fraction(self) -> float:
        """Fraction of mapped blocks backed by order>0 pages (Fig 2 metric)."""
        huge = base = 0
        for st in self.procs.values():
            for m in st.page_table.values():
                n = order_blocks(m.order)
                if m.order > 0:
                    huge += n
                else:
                    base += n
        total = huge + base
        return huge / total if total else 0.0
