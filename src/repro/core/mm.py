"""The framework memory manager — eBPF-mm's kernel side, adapted to a TPU pool.

Owns the HBM block pool (buddy allocator), per-process page tables, the DAMON
monitors, and the hook points.  The serving engine calls ``ensure_mapped`` /
``ensure_range`` as sequences grow (the page-fault analogue); the decision of
*which page size backs the fault* is delegated to the attached policy program
exactly as in the paper, with the kernel-default path (THP-greedy or
base-pages-only) when no program/profile is present.

All costs are accounted in modeled target-TPU nanoseconds via the CostModel,
so policies can be compared quantitatively on a CPU-only host; the physical
copies (zeroing, migration, compaction) are emitted as explicit move lists
that the device executes with the block_copy Pallas kernel.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from .buddy import BuddyAllocator, BuddyError, order_blocks
from .context import (CTX, CTX_LEN, NUM_ORDERS, POLICY_FALLBACK, FaultContext,
                      FaultKind)
from .cost import CostModel
from .damon import Damon
from .hooks import HOOK_FAULT, HOOK_RECLAIM, HOOK_TIER, HookRegistry
from .maps import ArrayMap, MapRegistry
from .profiles import MAX_PROFILE_REGIONS, Profile


class MMError(Exception):
    pass


class MMOutOfMemory(MMError):
    def __init__(self, msg: str, victim_pid: int | None = None) -> None:
        super().__init__(msg)
        self.victim_pid = victim_pid


@dataclass
class PageMapping:
    logical_start: int
    phys_start: int               # block index within the owning tier's pool
    order: int
    tier: int = 0                 # 0 = HBM, 1 = host DRAM (see core.tiering)


@dataclass
class ProcessState:
    pid: int
    app: str | None
    vma_end: int                      # logical blocks, VMA is [0, vma_end)
    damon: Damon
    page_table: dict[int, PageMapping] = field(default_factory=dict)
    mapped: set = field(default_factory=set)   # logical block indices
    accesses: int = 0

    def mappings_sorted(self) -> list[PageMapping]:
        return [self.page_table[k] for k in sorted(self.page_table)]


@dataclass
class MMStats:
    faults: int = 0
    hinted_faults: int = 0
    fallback_faults: int = 0
    pages_per_order: list[int] = field(default_factory=lambda: [0] * NUM_ORDERS)
    blocks_zeroed: int = 0
    compactions: int = 0
    compaction_blocks_moved: int = 0
    promotions: int = 0
    promotion_blocks_copied: int = 0
    evictions: int = 0
    mgmt_ns: int = 0                  # modeled time spent on zero/compact/migrate
    access_ns: int = 0                # modeled time streaming pages for attention
    descriptors_touched: int = 0      # TLB-miss analogue
    # Tiered-memory counters (HBM <-> host DRAM; see core.tiering)
    demotions: int = 0                # pages moved HBM -> host tier
    demotion_blocks: int = 0
    tier_promotions: int = 0          # pages moved host tier -> HBM
    tier_promotion_blocks: int = 0
    tier_reads: int = 0               # attention reads served from the host tier

    def snapshot(self) -> dict:
        return {
            "faults": self.faults,
            "hinted_faults": self.hinted_faults,
            "fallback_faults": self.fallback_faults,
            "pages_per_order": list(self.pages_per_order),
            "blocks_zeroed": self.blocks_zeroed,
            "compactions": self.compactions,
            "compaction_blocks_moved": self.compaction_blocks_moved,
            "promotions": self.promotions,
            "promotion_blocks_copied": self.promotion_blocks_copied,
            "evictions": self.evictions,
            "mgmt_ns": self.mgmt_ns,
            "access_ns": self.access_ns,
            "descriptors_touched": self.descriptors_touched,
            "demotions": self.demotions,
            "demotion_blocks": self.demotion_blocks,
            "tier_promotions": self.tier_promotions,
            "tier_promotion_blocks": self.tier_promotion_blocks,
            "tier_reads": self.tier_reads,
        }


@dataclass
class FaultResult:
    order: int
    phys_start: int
    hinted: bool
    compacted: bool
    moves: list                       # [(src_start, dst_start, order)] for device


class MemoryManager:
    def __init__(self, num_blocks: int, cost: CostModel, *,
                 default_mode: str = "thp", max_order: int = NUM_ORDERS - 1,
                 damon_seed: int = 0) -> None:
        if default_mode not in ("thp", "never"):
            raise ValueError("default_mode must be 'thp' or 'never'")
        self.buddy = BuddyAllocator(num_blocks, max_order=max_order)
        self.cost = cost
        self.default_mode = default_mode
        self.max_order = max_order
        self.hooks = HookRegistry()
        self.maps = MapRegistry()
        self.procs: dict[int, ProcessState] = {}
        self.profiles: dict[str, tuple[Profile, int]] = {}   # app -> (profile, map_id)
        self.stats = MMStats()
        self.ktime_ns = 0
        self._damon_seed = damon_seed
        self._move_log: list[tuple[int, int, int]] = []   # pending device copies

    # ------------------------------------------------------------- userspace
    def load_profile(self, profile: Profile) -> int:
        """Userspace loads an application profile into an eBPF map."""
        cap = MAX_PROFILE_REGIONS * (2 + NUM_ORDERS)
        m = ArrayMap(cap, name=f"profile:{profile.app}")
        profile.load_into(m)
        map_id = self.maps.register(m)
        self.profiles[profile.app] = (profile, map_id)
        return map_id

    def attach_fault_program(self, program) -> None:
        self.hooks.attach(HOOK_FAULT, program, self.maps)

    def attach_reclaim_program(self, program) -> None:
        self.hooks.attach(HOOK_RECLAIM, program, self.maps)

    def attach_tier_program(self, program) -> None:
        self.hooks.attach(HOOK_TIER, program, self.maps)

    # ------------------------------------------------------------- processes
    def create_process(self, pid: int, *, app: str | None = None,
                       vma_blocks: int = 0) -> ProcessState:
        if pid in self.procs:
            raise MMError(f"pid {pid} already exists")
        st = ProcessState(pid=pid, app=app, vma_end=vma_blocks,
                          damon=Damon(max(1, vma_blocks), seed=self._damon_seed + pid))
        self.procs[pid] = st
        return st

    def grow_vma(self, pid: int, new_end: int) -> None:
        st = self.procs[pid]
        if new_end > st.vma_end:
            st.vma_end = new_end
            st.damon.grow(new_end)

    def free_process(self, pid: int) -> None:
        st = self.procs.pop(pid)
        for m in st.page_table.values():
            self._free_phys(m)

    def _free_phys(self, m: PageMapping) -> None:
        """Release a mapping's physical page into its tier's allocator."""
        self.buddy.free(m.phys_start)

    def _device_index(self, m: PageMapping) -> int:
        """Base-block index of ``m`` in the device-visible (combined) pool."""
        return m.phys_start

    # ---------------------------------------------------------------- faults
    def fault_max_order(self, st: ProcessState, addr: int) -> int:
        k = self.max_order
        while k > 0:
            size = order_blocks(k)
            a = (addr // size) * size
            if a + size <= st.vma_end and not any(
                    b in st.mapped for b in range(a, a + size)):
                return k
            k -= 1
        return 0

    def _build_ctx(self, st: ProcessState, addr: int, kind: FaultKind) -> np.ndarray:
        bstats = self.buddy.stats()
        has_profile = int(st.app in self.profiles) if st.app else 0
        map_id, nregions = 0, 0
        if has_profile:
            prof, map_id = self.profiles[st.app]
            nregions = len(prof.regions)
        fc = FaultContext(
            addr=addr, pid=st.pid, vma_start=0, vma_end=st.vma_end,
            fault_max_order=self.fault_max_order(st, addr),
            has_profile=has_profile, profile_map_id=map_id,
            profile_nregions=nregions,
            free_blocks=bstats.free_per_order,
            frag=bstats.frag_index_milli,
            heat=st.damon.heat_vector(addr),
            zero_ns_per_block=self.cost.zero_ns_per_block(),
            compact_ns_per_block=self.cost.compact_ns_per_block(),
            descriptor_ns=int(self.cost.hw.descriptor_ns),
            block_bytes=self.cost.block_bytes,
            ktime_ns=self.ktime_ns,
            mem_pressure=bstats.utilization_milli,
            fault_kind=int(kind),
            seq_len=st.vma_end,
        )
        return fc.vector()

    def _default_order(self, fmax: int) -> int:
        return min(2, fmax) if self.default_mode == "thp" else 0

    def ensure_mapped(self, pid: int, addr: int,
                      kind: FaultKind = FaultKind.FIRST_TOUCH) -> FaultResult | None:
        """The page-fault entry point. Returns None if already mapped."""
        st = self.procs[pid]
        if addr >= st.vma_end:
            raise MMError(f"pid {pid}: fault at {addr} beyond VMA end {st.vma_end}")
        if addr in st.mapped:
            return None
        if not self.hooks.attached(HOOK_FAULT):
            # the paper's zero-overhead property: with no program attached the
            # default path runs without building the eBPF context at all
            fmax = self.fault_max_order(st, addr)
            return self._install(st, addr, self._default_order(fmax), False)
        ctx = self._build_ctx(st, addr, kind)
        fmax = int(ctx[CTX.FAULT_MAX_ORDER])
        decision = self.hooks.run(HOOK_FAULT, ctx)
        hinted = decision is not None and decision != POLICY_FALLBACK
        if not hinted:
            order = self._default_order(fmax)
            if decision == POLICY_FALLBACK:
                self.stats.fallback_faults += 1
        else:
            order = max(0, min(int(decision), fmax))
        return self._install(st, addr, order, hinted)

    def ensure_range(self, pid: int, start: int, end: int) -> list[FaultResult]:
        """Bulk fault (prefill/mmap population)."""
        results = []
        st = self.procs[pid]
        addr = start
        while addr < end:
            r = self.ensure_mapped(pid, addr, FaultKind.PREFILL)
            if r is None:
                addr += 1
            else:
                size = order_blocks(r.order)
                addr = (addr // size) * size + size
                results.append(r)
        return results

    def _install(self, st: ProcessState, addr: int, order: int,
                 hinted: bool) -> FaultResult:
        size = order_blocks(order)
        a = (addr // size) * size
        compacted = False
        moves: list[tuple[int, int, int]] = []
        phys = None
        while phys is None:
            try:
                phys = self.buddy.alloc(order)
            except BuddyError:
                plan = self.buddy.plan_compaction(order)
                if plan is not None and not compacted:
                    self._apply_compaction(plan)
                    moves.extend(plan)
                    compacted = True
                    continue
                if order > 0:           # degrade, like a failed THP allocation
                    order = order - 1
                    size = order_blocks(order)
                    a = (addr // size) * size
                    continue
                victim = self._pick_reclaim_victim(exclude=st.pid)
                raise MMOutOfMemory(
                    f"pool exhausted on order-0 fault (pid {st.pid})",
                    victim_pid=victim)
        m = PageMapping(logical_start=a, phys_start=phys, order=order)
        st.page_table[a] = m
        st.mapped.update(range(a, a + size))
        self.stats.faults += 1
        if hinted:
            self.stats.hinted_faults += 1
        self.stats.pages_per_order[order] += 1
        self.stats.blocks_zeroed += size
        self.stats.mgmt_ns += self.cost.zero_ns_per_block() * size
        return FaultResult(order=order, phys_start=phys, hinted=hinted,
                           compacted=compacted, moves=moves)

    def _apply_compaction(self, plan: list[tuple[int, int, int]], *,
                          tier: int = 0, device_offset: int = 0) -> None:
        """Buddy already mutated its allocation map; fix page tables and
        account the migration cost + device move list.  ``tier`` selects
        which tier's mappings the plan refers to (each tier's pool has its
        own phys numbering) and ``device_offset`` shifts the emitted moves
        into combined device coordinates."""
        self.stats.compactions += 1
        remap = {src: dst for src, dst, _ in plan}
        for st in self.procs.values():
            for m in st.page_table.values():
                if m.tier == tier and m.phys_start in remap:
                    m.phys_start = remap[m.phys_start]
        blocks = sum(order_blocks(o) for _, _, o in plan)
        self.stats.compaction_blocks_moved += blocks
        self.stats.mgmt_ns += self.cost.compact_ns_per_block() * blocks
        self._move_log.extend((device_offset + s, device_offset + d, o)
                              for s, d, o in plan)

    # ---------------------------------------------------------- khugepaged
    def collapse(self, pid: int, addr: int, to_order: int) -> FaultResult | None:
        """Promote the aligned window around ``addr`` to one order-k page
        (async promotion — the khugepaged analogue).  Existing data is
        migrated via the device copy list; holes are zero-filled."""
        st = self.procs[pid]
        size = order_blocks(to_order)
        a = (addr // size) * size
        if a + size > st.vma_end:
            return None
        old = [m for m in st.page_table.values()
               if m.logical_start >= a and m.logical_start < a + size]
        if any(m.order >= to_order for m in old):
            return None   # already backed at >= target order
        if any(m.tier != 0 for m in old):
            return None   # demoted pages must be promoted before collapsing
        try:
            phys = self.buddy.alloc(to_order)
        except BuddyError:
            plan = self.buddy.plan_compaction(to_order)
            if plan is None:
                return None
            self._apply_compaction(plan)
            try:
                phys = self.buddy.alloc(to_order)
            except BuddyError:
                return None
        moves = []
        copied = 0
        for m in old:
            dst = phys + (m.logical_start - a)
            moves.append((m.phys_start, dst, m.order))
            copied += order_blocks(m.order)
            self.buddy.free(m.phys_start)
            del st.page_table[m.logical_start]
        st.page_table[a] = PageMapping(a, phys, to_order)
        st.mapped.update(range(a, a + size))
        self.stats.promotions += 1
        self.stats.promotion_blocks_copied += copied
        self.stats.blocks_zeroed += size - copied
        self.stats.mgmt_ns += (self.cost.compact_ns_per_block() * copied
                               + self.cost.zero_ns_per_block() * (size - copied))
        self._move_log.extend(moves)
        return FaultResult(order=to_order, phys_start=phys, hinted=True,
                           compacted=False, moves=moves)

    # ------------------------------------------------------------- reclaim
    def _pick_reclaim_victim(self, exclude: int) -> int | None:
        cands = [st for pid, st in self.procs.items()
                 if pid != exclude and st.page_table]
        if not cands:
            return None
        cands = sorted(cands, key=lambda s: s.pid)[:4]
        ctx = np.zeros(CTX_LEN, dtype=np.int64)
        ctx[CTX.ADDR] = len(cands)
        for i, st in enumerate(cands):
            mean_heat = (sum(r.nr_accesses for r in st.damon.regions)
                         / max(1, len(st.damon.regions)))
            ctx[CTX.HEAT_O0 + i] = int(mean_heat)
        choice = self.hooks.run(HOOK_RECLAIM, ctx)
        if choice is None or choice == POLICY_FALLBACK:
            # default: lowest pid (FIFO-ish)
            return cands[0].pid
        return cands[max(0, min(int(choice), len(cands) - 1))].pid

    def evict_process(self, pid: int) -> None:
        self.free_process(pid)
        self.stats.evictions += 1

    # -------------------------------------------------------------- access
    def record_access(self, pid: int, heat_per_block: np.ndarray) -> None:
        """Called once per engine step with the kernel-emitted heat stats.

        Access cost is charged only for mappings that were actually READ this
        step (nonzero attention mass over their span) — sliding-window and
        sparse-attention models do not stream their cold blocks."""
        st = self.procs[pid]
        heat = np.asarray(heat_per_block, dtype=np.float64)
        st.damon.record(heat)
        st.accesses += 1
        csum = np.concatenate([[0.0], np.cumsum(heat)])
        for m in st.mappings_sorted():
            lo = min(m.logical_start, heat.size)
            hi = min(m.logical_start + order_blocks(m.order), heat.size)
            if hi > lo and csum[hi] - csum[lo] > 0:
                self.stats.descriptors_touched += 1
                if m.tier == 0:
                    self.stats.access_ns += int(self.cost.access_ns(m.order))
                else:
                    # host-tier resident page: the read crosses PCIe
                    self.stats.tier_reads += 1
                    self.stats.access_ns += int(self.cost.tier_access_ns(m.order))

    def descriptors_for(self, pid: int) -> int:
        return len(self.procs[pid].page_table)

    # ---------------------------------------------------- device integration
    def block_table(self, pid: int, max_blocks: int) -> np.ndarray:
        """Flattened logical->physical base-block map (-1 = unmapped)."""
        st = self.procs[pid]
        t = np.full(max_blocks, -1, dtype=np.int32)
        for m in st.page_table.values():
            size = order_blocks(m.order)
            hi = min(m.logical_start + size, max_blocks)
            base = self._device_index(m)
            for i in range(m.logical_start, hi):
                t[i] = base + (i - m.logical_start)
        return t

    def page_lists_by_order(self, pids: list[int]) -> dict[int, np.ndarray]:
        """Per-order page lists for the multi-size paged-attention kernel.

        Returns {order: int32[[seq_slot, logical_page_idx, phys_page_start]]}.
        seq_slot is the position of the pid in ``pids``.
        """
        out = {k: [] for k in range(self.max_order + 1)}
        for slot, pid in enumerate(pids):
            st = self.procs[pid]
            for m in st.mappings_sorted():
                out[m.order].append(
                    (slot, m.logical_start // order_blocks(m.order),
                     self._device_index(m)))
        return {k: np.asarray(v, dtype=np.int32).reshape(-1, 3)
                for k, v in out.items()}

    def drain_moves(self) -> list[tuple[int, int, int]]:
        """Pending (src, dst, order) physical copies for the device."""
        mv, self._move_log = self._move_log, []
        return mv

    # ------------------------------------------------------------- misc
    def tick(self, ns: int = 1_000_000) -> None:
        self.ktime_ns += ns

    def hugepage_block_fraction(self) -> float:
        """Fraction of mapped blocks backed by order>0 pages (Fig 2 metric)."""
        huge = base = 0
        for st in self.procs.values():
            for m in st.page_table.values():
                n = order_blocks(m.order)
                if m.order > 0:
                    huge += n
                else:
                    base += n
        total = huge + base
        return huge / total if total else 0.0
