"""Hook points in the framework memory manager.

The paper introduces one new eBPF hook on the Linux page-fault path and
sketches more (reclaim, tiering).  We implement the same surface: named hook
points a verified program can be attached to.  If nothing is attached, the
default code path runs with zero overhead — mirroring the paper's "zero
overhead on non-hinted faults" property.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import numpy as np

from .isa import Program
from .maps import MapRegistry
from .vm import PolicyVM

HOOK_FAULT = "mm_fault"            # page-size decision on fault (the paper's hook)
HOOK_RECLAIM = "mm_reclaim"        # victim selection under memory pressure
HOOK_TIER = "mm_tier"              # page placement for tiering (future work in paper)

KNOWN_HOOKS = (HOOK_FAULT, HOOK_RECLAIM, HOOK_TIER)


@dataclass
class AttachedProgram:
    program: Program
    vm: PolicyVM
    jit: object | None = None       # JitPolicy, lazily built for batch paths


class HookRegistry:
    def __init__(self) -> None:
        self._hooks: dict[str, AttachedProgram | None] = {h: None for h in KNOWN_HOOKS}
        self.invocations: dict[str, int] = {h: 0 for h in KNOWN_HOOKS}

    def attach(self, hook: str, program: Program, maps: MapRegistry) -> None:
        """Verify (load-time, like the kernel) and attach."""
        if hook not in self._hooks:
            raise KeyError(f"unknown hook {hook!r}; known: {KNOWN_HOOKS}")
        vm = PolicyVM(program, maps)   # raises VerifierError on rejection
        self._hooks[hook] = AttachedProgram(program=program, vm=vm)

    def detach(self, hook: str) -> None:
        if hook not in self._hooks:
            raise KeyError(f"unknown hook {hook!r}")
        self._hooks[hook] = None

    def attached(self, hook: str) -> bool:
        return self._hooks.get(hook) is not None

    def run(self, hook: str, ctx_vec: np.ndarray) -> int | None:
        """Run the attached program; None if nothing attached (default path)."""
        ap = self._hooks.get(hook)
        if ap is None:
            return None
        self.invocations[hook] += 1
        return ap.vm.run(ctx_vec).ret

    def run_batch(self, hook: str, ctx_mat: np.ndarray) -> np.ndarray | None:
        """Vectorized decision for a batch of faults (jnp JIT path)."""
        ap = self._hooks.get(hook)
        if ap is None:
            return None
        if ap.jit is None:
            from .jit import JitPolicy
            ap.jit = JitPolicy(ap.program, ap.vm.maps)
        self.invocations[hook] += ctx_mat.shape[0]
        return ap.jit.run_batch(ctx_mat)
