"""Hook points in the framework memory manager.

The paper introduces one new eBPF hook on the Linux page-fault path and
sketches more (reclaim, tiering).  We implement the same surface: named hook
points a verified program can be attached to.  If nothing is attached, the
default code path runs with zero overhead — mirroring the paper's "zero
overhead on non-hinted faults" property.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import numpy as np

from .isa import Program
from .maps import MapRegistry
from .vm import PolicyVM

HOOK_FAULT = "mm_fault"            # page-size decision on fault (the paper's hook)
HOOK_RECLAIM = "mm_reclaim"        # victim selection under memory pressure
HOOK_TIER = "mm_tier"              # page placement for tiering (future work in paper)

KNOWN_HOOKS = (HOOK_FAULT, HOOK_RECLAIM, HOOK_TIER)


# Batch-execution backend selection: the predicated compiler (unroll +
# if-conversion, straight-line masked vector ops) dispatches in O(unrolled
# length) with NO per-step control flow — far cheaper than the while+switch
# JIT for the small batches a decode step produces — but its compile time
# grows with the unroll, so it is only used when the unrolled program fits.
PRED_MAX_UNROLL = 512

# Batches are padded up to power-of-two buckets so XLA compiles one variant
# per bucket instead of one per distinct batch size.
PAD_MIN = 4


@dataclass
class AttachedProgram:
    program: Program
    vm: PolicyVM
    jit: object | None = None       # JitPolicy, lazily built for batch paths
    pred: object | None = None      # PredicatedPolicy, preferred when small
    pred_unfit: bool = False


class HookRegistry:
    def __init__(self) -> None:
        self._hooks: dict[str, AttachedProgram | None] = {h: None for h in KNOWN_HOOKS}
        # decisions evaluated (one per ctx row — a batch of N counts N)
        self.invocations: dict[str, int] = {h: 0 for h in KNOWN_HOOKS}
        # program-invocation EVENTS: how many times the hook actually fired.
        # A batch of N faults is ONE batch_call — the number the hot-path
        # benchmark and the one-invocation-per-step tests watch.
        self.calls: dict[str, int] = {h: 0 for h in KNOWN_HOOKS}
        self.batch_calls: dict[str, int] = {h: 0 for h in KNOWN_HOOKS}

    def attach(self, hook: str, program: Program, maps: MapRegistry) -> None:
        """Verify (load-time, like the kernel) and attach."""
        if hook not in self._hooks:
            raise KeyError(f"unknown hook {hook!r}; known: {KNOWN_HOOKS}")
        vm = PolicyVM(program, maps)   # raises VerifierError on rejection
        self._hooks[hook] = AttachedProgram(program=program, vm=vm)

    def detach(self, hook: str) -> None:
        if hook not in self._hooks:
            raise KeyError(f"unknown hook {hook!r}")
        self._hooks[hook] = None

    def attached(self, hook: str) -> bool:
        return self._hooks.get(hook) is not None

    def run(self, hook: str, ctx_vec: np.ndarray) -> int | None:
        """Run the attached program; None if nothing attached (default path)."""
        ap = self._hooks.get(hook)
        if ap is None:
            return None
        self.invocations[hook] += 1
        self.calls[hook] += 1
        return ap.vm.run(ctx_vec).ret

    def _batch_backend(self, ap: AttachedProgram):
        if ap.pred is None and not ap.pred_unfit:
            try:
                from .predicate import PredicatedPolicy, unroll
                code = unroll(ap.program, ap.vm.maps)
                if len(code) <= PRED_MAX_UNROLL:
                    ap.pred = PredicatedPolicy(ap.program, ap.vm.maps, code)
                else:
                    ap.pred_unfit = True
            except ValueError:      # unroll over MAX_UNROLLED -> JIT fallback
                ap.pred_unfit = True
        if ap.pred is not None:
            return ap.pred
        if ap.jit is None:
            from .jit import JitPolicy
            ap.jit = JitPolicy(ap.program, ap.vm.maps)
        return ap.jit

    def run_batch(self, hook: str, ctx_mat: np.ndarray) -> np.ndarray | None:
        """Vectorized decision for a batch of faults.

        One call = ONE program invocation regardless of batch size — the
        amortization the batched fault path is built on.  Uses the
        predicated (unrolled straight-line) executor when the program's
        unroll is small, the while+switch JIT otherwise; the batch is padded
        to power-of-two buckets so varying batch sizes reuse compilations.
        """
        ap = self._hooks.get(hook)
        if ap is None:
            return None
        backend = self._batch_backend(ap)
        n = ctx_mat.shape[0]
        self.invocations[hook] += n
        self.calls[hook] += 1
        self.batch_calls[hook] += 1
        pad = PAD_MIN
        while pad < n:
            pad *= 2      # at most log2(max batch) compiled shape variants
        if pad > n:
            ctx_mat = np.concatenate(
                [ctx_mat, np.repeat(ctx_mat[:1], pad - n, axis=0)])
        return backend.run_batch(ctx_mat)[:n]
