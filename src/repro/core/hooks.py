"""Hook points in the framework memory manager.

The paper introduces one new eBPF hook on the Linux page-fault path and
sketches more (reclaim, tiering).  We implement the same surface: named hook
points a verified program can be attached to.  If nothing is attached, the
default code path runs with zero overhead — mirroring the paper's "zero
overhead on non-hinted faults" property.
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable

import numpy as np

from ..obs.ringbuf import EV_CACHE, EV_COMPILE, EV_HOOK
from .context import CTX_LEN
from .isa import Program
from .maps import MapRegistry
from .vm import PolicyVM

HOOK_FAULT = "mm_fault"            # page-size decision on fault (the paper's hook)
HOOK_RECLAIM = "mm_reclaim"        # victim selection under memory pressure
HOOK_TIER = "mm_tier"              # page placement for tiering (future work in paper)

KNOWN_HOOKS = (HOOK_FAULT, HOOK_RECLAIM, HOOK_TIER)
HOOK_INDEX = {h: i for i, h in enumerate(KNOWN_HOOKS)}


# Batch-execution backend selection: the predicated compiler (unroll +
# if-conversion, straight-line masked vector ops) dispatches in O(unrolled
# length) with NO per-step control flow — far cheaper than the while+switch
# JIT for the small batches a decode step produces.  XLA compile time grows
# superlinearly with straight-line length, so programs whose unroll exceeds
# this budget are SPLIT into predicated segments of at most this many insns
# chained by a dispatch loop (see core.predicate) — the 64-region Fig-1
# profile (900 insns) takes the fast path as 2 segments instead of falling
# back to the while+switch JIT.  The JIT remains only for programs whose
# flattening exceeds core.lower.MAX_UNROLLED entirely.
PRED_MAX_UNROLL = 512

# Batches are padded up to power-of-two buckets so XLA compiles one variant
# per bucket instead of one per distinct batch size.
PAD_MIN = 4


@dataclass
class AttachedProgram:
    program: Program
    vm: PolicyVM
    jit: object | None = None       # JitPolicy, the deep-fallback batch path
    pred: object | None = None      # PredicatedPolicy (segmented), default
    pred_unfit: bool = False        # flattening exceeded lower.MAX_UNROLLED


class HookRegistry:
    def __init__(self, cache=None, telemetry=None) -> None:
        # compiler-artifact cache (cross-session lowering/unroll pickles +
        # the XLA persistent cache); None = the process-wide default
        self.cache = cache
        # telemetry hub (repro.obs.Telemetry) or None; every tracepoint in
        # the dispatch paths below guards on it so the default (no
        # telemetry) configuration pays one is-None check per dispatch
        self.telemetry = telemetry
        self._hooks: dict[str, AttachedProgram | None] = {h: None for h in KNOWN_HOOKS}
        # decisions evaluated (one per ctx row — a batch of N counts N)
        self.invocations: dict[str, int] = {h: 0 for h in KNOWN_HOOKS}
        # program-invocation EVENTS: how many times the hook actually fired.
        # A batch of N faults is ONE batch_call — the number the hot-path
        # benchmark and the one-invocation-per-step tests watch.
        self.calls: dict[str, int] = {h: 0 for h in KNOWN_HOOKS}
        self.batch_calls: dict[str, int] = {h: 0 for h in KNOWN_HOOKS}

    def attach(self, hook: str, program: Program, maps: MapRegistry) -> None:
        """Verify (load-time, like the kernel) and attach."""
        if hook not in self._hooks:
            raise KeyError(f"unknown hook {hook!r}; known: {KNOWN_HOOKS}")
        vm = PolicyVM(program, maps)   # raises VerifierError on rejection
        self._hooks[hook] = AttachedProgram(program=program, vm=vm)

    def detach(self, hook: str) -> None:
        if hook not in self._hooks:
            raise KeyError(f"unknown hook {hook!r}")
        self._hooks[hook] = None

    def attached(self, hook: str) -> bool:
        return self._hooks.get(hook) is not None

    def run(self, hook: str, ctx_vec: np.ndarray) -> int | None:
        """Run the attached program; None if nothing attached (default path)."""
        ap = self._hooks.get(hook)
        if ap is None:
            return None
        self.invocations[hook] += 1
        self.calls[hook] += 1
        tel = self.telemetry
        if tel is None or not tel.enabled:
            return ap.vm.run(ctx_vec).ret
        t0 = time.perf_counter_ns()
        res = ap.vm.run(ctx_vec)
        dt = time.perf_counter_ns() - t0
        tel.observe_hook(hook, dt, 1)
        tel.emit(EV_HOOK, HOOK_INDEX[hook], 1, dt)
        for e in res.events:
            tel.ring.push(*e)
        tel.prog_lane_drops += res.dropped
        return res.ret

    def _artifact_cache(self):
        if self.cache is None:
            from .cache import artifact_cache
            self.cache = artifact_cache
        return self.cache

    def _batch_backend(self, ap: AttachedProgram):
        tel = self.telemetry
        built = None        # (segments or -1, wall ns) when a backend is built
        if ap.pred is None and not ap.pred_unfit:
            cache = self._artifact_cache()
            cache.enable_xla_cache()
            t0 = time.perf_counter_ns()
            try:
                from .predicate import PredicatedPolicy
                code, cuts = cache.unrolled(ap.vm.lowered)
                ap.pred = PredicatedPolicy(ap.vm.lowered, ap.vm.maps,
                                           code=code, cuts=cuts,
                                           seg_limit=PRED_MAX_UNROLL)
                built = (ap.pred.num_segments, time.perf_counter_ns() - t0)
            except ValueError:      # unroll over MAX_UNROLLED -> JIT fallback
                ap.pred_unfit = True
        if ap.pred is None and ap.jit is None:
            from .jit import JitPolicy
            t0 = time.perf_counter_ns()
            ap.jit = JitPolicy(ap.vm.lowered, ap.vm.maps)
            built = (-1, time.perf_counter_ns() - t0)
        if built is not None and tel is not None and tel.enabled:
            hook = next((h for h, a in self._hooks.items() if a is ap), "?")
            tel.emit(EV_COMPILE, HOOK_INDEX.get(hook, -1), built[0], built[1])
            cs = self._artifact_cache().stats
            tel.emit(EV_CACHE, cs.get("unroll_hits", 0),
                     cs.get("unroll_misses", 0), cs.get("unroll_disk_hits", 0))
            tel.inc("backend_builds")
        return ap.pred if ap.pred is not None else ap.jit

    def warm(self, hook: str, max_batch: int = PAD_MIN) -> None:
        """Eagerly build (and compile) the batch backend for ``hook`` up to
        the ``max_batch`` bucket — engine construction calls this so the
        first decode step is not the one paying tracing/compilation, and so
        a warm artifact cache is consumed at startup rather than mid-serve.
        No-op when nothing is attached."""
        ap = self._hooks.get(hook)
        if ap is None:
            return
        backend = self._batch_backend(ap)
        pad = PAD_MIN
        while True:
            backend.run_batch(np.zeros((pad, CTX_LEN), dtype=np.int64))
            if pad >= max_batch:
                break
            pad *= 2

    def run_batch(self, hook: str, ctx_mat: np.ndarray) -> np.ndarray | None:
        """Vectorized decision for a batch of faults.

        One call = ONE program invocation regardless of batch size — the
        amortization the batched fault path is built on.  Uses the
        predicated straight-line executor (split into chained segments when
        the unroll exceeds the per-segment budget), falling back to the
        while+switch JIT only for programs whose flattening exceeds
        lower.MAX_UNROLLED entirely; the batch is padded to power-of-two
        buckets so varying batch sizes reuse compilations, and compiled
        artifacts persist across sessions via the artifact cache.
        """
        ap = self._hooks.get(hook)
        if ap is None:
            return None
        backend = self._batch_backend(ap)
        n = ctx_mat.shape[0]
        self.invocations[hook] += n
        self.calls[hook] += 1
        self.batch_calls[hook] += 1
        pad = PAD_MIN
        while pad < n:
            pad *= 2      # at most log2(max batch) compiled shape variants
        if pad > n:
            ctx_mat = np.concatenate(
                [ctx_mat, np.repeat(ctx_mat[:1], pad - n, axis=0)])
        tel = self.telemetry
        if tel is None or not tel.enabled:
            return backend.run_batch(ctx_mat)[:n]
        t0 = time.perf_counter_ns()
        out = backend.run_batch(ctx_mat)[:n]
        dt = time.perf_counter_ns() - t0
        tel.observe_hook(hook, dt, n)
        tel.emit(EV_HOOK, HOOK_INDEX[hook], n, dt)
        if getattr(backend, "rb_cap", 0):
            # drain the device event buffers: only the n real lanes — the
            # power-of-two padding rows are repeats of row 0 and their
            # emissions (like their decisions) are discarded
            events, drops = backend.take_events(n)
            for e in events:
                tel.ring.push(*e)
            tel.prog_lane_drops += drops
        return out
