"""Hook points in the framework memory manager.

The paper introduces one new eBPF hook on the Linux page-fault path and
sketches more (reclaim, tiering).  We implement the same surface: named hook
points a verified program can be attached to.  If nothing is attached, the
default code path runs with zero overhead — mirroring the paper's "zero
overhead on non-hinted faults" property.

Containment: the verifier gates what loads; the PolicySupervisor
(``repro.resilience``) gates what keeps RUNNING.  Both dispatch paths run
the program under a containment envelope — an injected or real runtime
error, an out-of-contract return value, or a ring-slot exhaustion streak
costs the program a strike and falls the decision back to the kernel
default; enough strikes auto-detach the program (EV_DETACH) and the
manager serves on the default THP policy.  The engine never crashes on a
misbehaving program.
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable

import numpy as np

from ..obs.ringbuf import EV_CACHE, EV_COMPILE, EV_DETACH, EV_HOOK
from ..resilience.faults import SITE_HOOK_RUN
from ..resilience import supervisor as _supervisor_mod
from ..resilience.supervisor import (REASON_INVALID_RETURN,
                                     REASON_RB_EXHAUSTION,
                                     REASON_RUNTIME_ERROR, PolicySupervisor)
from .context import CTX, CTX_LEN, POLICY_DETACHED, POLICY_FALLBACK

# the supervisor keeps its own copy of the sentinel (importing it from here
# would be circular); hold the two definitions together
assert _supervisor_mod.POLICY_FALLBACK == POLICY_FALLBACK
from .isa import Program
from .maps import MapRegistry
from .vm import PolicyVM

HOOK_FAULT = "mm_fault"            # page-size decision on fault (the paper's hook)
HOOK_RECLAIM = "mm_reclaim"        # victim selection under memory pressure
HOOK_TIER = "mm_tier"              # page placement for tiering (future work in paper)
HOOK_EVICT = "mm_evict"            # prefix-cache eviction (Cache-is-King mold)
HOOK_PROFILE = "mm_profile"        # sampled profiler on the DAMON aggregation tick

KNOWN_HOOKS = (HOOK_FAULT, HOOK_RECLAIM, HOOK_TIER, HOOK_EVICT, HOOK_PROFILE)
HOOK_INDEX = {h: i for i, h in enumerate(KNOWN_HOOKS)}


# Batch-execution backend selection: the predicated compiler (unroll +
# if-conversion, straight-line masked vector ops) dispatches in O(unrolled
# length) with NO per-step control flow — far cheaper than the while+switch
# JIT for the small batches a decode step produces.  XLA compile time grows
# superlinearly with straight-line length, so programs whose unroll exceeds
# this budget are SPLIT into predicated segments of at most this many insns
# chained by a dispatch loop (see core.predicate) — the 64-region Fig-1
# profile (900 insns) takes the fast path as 2 segments instead of falling
# back to the while+switch JIT.  The JIT remains only for programs whose
# flattening exceeds core.lower.MAX_UNROLLED entirely.
PRED_MAX_UNROLL = 512

# Batches are padded up to power-of-two buckets so XLA compiles one variant
# per bucket instead of one per distinct batch size.
PAD_MIN = 4


@dataclass
class AttachedProgram:
    program: Program
    vm: PolicyVM
    jit: object | None = None       # JitPolicy, the deep-fallback batch path
    pred: object | None = None      # PredicatedPolicy (segmented), default
    pred_unfit: bool = False        # flattening exceeded lower.MAX_UNROLLED


class HookRegistry:
    def __init__(self, cache=None, telemetry=None, injector=None,
                 supervisor=None) -> None:
        # compiler-artifact cache (cross-session lowering/unroll pickles +
        # the XLA persistent cache); None = the process-wide default
        self.cache = cache
        # telemetry hub (repro.obs.Telemetry) or None; every tracepoint in
        # the dispatch paths below guards on it so the default (no
        # telemetry) configuration pays one is-None check per dispatch
        self.telemetry = telemetry
        # resilience FailureInjector (chaos runs) or None; sites guard on it
        # the same way they guard on telemetry
        self.injector = injector
        self.supervisor = supervisor if supervisor is not None \
            else PolicySupervisor()
        self._hooks: dict[str, AttachedProgram | None] = {h: None for h in KNOWN_HOOKS}
        # decisions evaluated (one per ctx row — a batch of N counts N)
        self.invocations: dict[str, int] = {h: 0 for h in KNOWN_HOOKS}
        # program-invocation EVENTS: how many times the hook actually fired.
        # A batch of N faults is ONE batch_call — the number the hot-path
        # benchmark and the one-invocation-per-step tests watch.
        self.calls: dict[str, int] = {h: 0 for h in KNOWN_HOOKS}
        self.batch_calls: dict[str, int] = {h: 0 for h in KNOWN_HOOKS}

    def attach(self, hook: str, program: Program, maps: MapRegistry) -> None:
        """Verify (load-time, like the kernel) and attach."""
        if hook not in self._hooks:
            raise KeyError(f"unknown hook {hook!r}; known: {KNOWN_HOOKS}")
        vm = PolicyVM(program, maps)   # raises VerifierError on rejection
        self._hooks[hook] = AttachedProgram(program=program, vm=vm)
        self.supervisor.reset(hook)    # fresh attach, clean strike ledger

    def detach(self, hook: str) -> None:
        if hook not in self._hooks:
            raise KeyError(f"unknown hook {hook!r}")
        self._hooks[hook] = None

    def attached(self, hook: str) -> bool:
        return self._hooks.get(hook) is not None

    # ------------------------------------------------------------ containment
    def _strike(self, hook: str, ap: AttachedProgram, reason: int,
                ktime: int) -> bool:
        """One supervisor strike against ``hook``; detaches the program and
        emits EV_DETACH when the threshold is crossed.  Returns True when
        the hook is detached (now or already during this invocation)."""
        if self._hooks.get(hook) is not ap:
            return True                 # already detached this invocation
        if not self.supervisor.strike(hook, reason):
            return False
        self._hooks[hook] = None        # fall back to kernel-default policy
        info = self.supervisor.record_detach(
            hook, reason, getattr(ap.program, "name", "") or "?")
        tel = self.telemetry
        if tel is not None and tel.enabled:
            tel.emit(EV_DETACH, HOOK_INDEX[hook], info["strikes"], reason,
                     ts=ktime)
            tel.inc("policy_detaches")
        return True

    def _discipline_scalar(self, hook: str, ap: AttachedProgram, ret: int,
                           dropped: int, ktime: int) -> int:
        sup = self.supervisor
        if dropped:
            if sup.note_rb_drops(hook, dropped):
                self._strike(hook, ap, REASON_RB_EXHAUSTION, ktime)
        else:
            sup.note_rb_clean(hook)
        if not sup.valid(hook, ret):
            self._strike(hook, ap, REASON_INVALID_RETURN, ktime)
            return POLICY_FALLBACK
        return ret

    def _discipline_batch(self, hook: str, ap: AttachedProgram,
                          ctx_mat: np.ndarray, out, n: int) -> np.ndarray:
        """Row-order misbehavior pass over a batch decision vector, mirroring
        the order the scalar route invokes the program so both routes strike
        and detach at the SAME fault (the chaos-differential contract).  A
        striking row's decision becomes POLICY_FALLBACK; rows after a
        mid-batch detach become POLICY_DETACHED (kernel default, no fallback
        accounting — the scalar route never reaches the hook for them).

        Asymmetry note: an injected SITE_HOOK_RUN failure skips the program
        entirely on the scalar route but only overrides its DECISION here
        (all lanes already executed).  Decisions and strikes stay identical;
        programs with map-write or ring-emit side effects would diverge, so
        the chaos differential runs read-only programs.
        """
        out = np.asarray(out)
        inj = self.injector
        injected = inj is not None and inj.site_armed(SITE_HOOK_RUN)
        if not injected:
            # fast path: a well-behaved batch costs one vectorized check
            # (over-range decisions are CLAMPED downstream, the kernel's
            # clamp convention — only sub-sentinel values are misbehavior)
            if not (out < POLICY_FALLBACK).any():
                return out
        hidx = HOOK_INDEX[hook]
        out = np.array(out, dtype=np.int64)
        for i in range(n):
            ktime = int(ctx_mat[i, CTX.KTIME_NS])
            if injected and inj.fires(SITE_HOOK_RUN, hidx,
                                      int(ctx_mat[i, CTX.PID]),
                                      int(ctx_mat[i, CTX.ADDR]), ktime):
                reason = REASON_RUNTIME_ERROR
            elif int(out[i]) < POLICY_FALLBACK:
                reason = REASON_INVALID_RETURN
            else:
                continue
            out[i] = POLICY_FALLBACK
            if self._strike(hook, ap, reason, ktime):
                out[i + 1:n] = POLICY_DETACHED
                break
        return out

    # -------------------------------------------------------------- dispatch
    def run(self, hook: str, ctx_vec: np.ndarray) -> int | None:
        """Run the attached program; None if nothing attached (default path)."""
        ap = self._hooks.get(hook)
        if ap is None:
            return None
        self.invocations[hook] += 1
        self.calls[hook] += 1
        ktime = int(ctx_vec[CTX.KTIME_NS])
        inj = self.injector
        if inj is not None and inj.fires(SITE_HOOK_RUN, HOOK_INDEX[hook],
                                         int(ctx_vec[CTX.PID]),
                                         int(ctx_vec[CTX.ADDR]), ktime):
            self._strike(hook, ap, REASON_RUNTIME_ERROR, ktime)
            return POLICY_FALLBACK
        tel = self.telemetry
        timed = tel is not None and tel.enabled
        t0 = time.perf_counter_ns() if timed else 0
        try:
            res = ap.vm.run(ctx_vec)
        except Exception:
            self._strike(hook, ap, REASON_RUNTIME_ERROR, ktime)
            return POLICY_FALLBACK
        if timed:
            dt = time.perf_counter_ns() - t0
            tel.observe_hook(hook, dt, 1)
            tel.emit(EV_HOOK, HOOK_INDEX[hook], 1, dt)
            for e in res.events:
                tel.ring.push(*e)
            tel.prog_lane_drops += res.dropped
        return self._discipline_scalar(hook, ap, int(res.ret), res.dropped,
                                       ktime)

    def _artifact_cache(self):
        if self.cache is None:
            from .cache import artifact_cache
            self.cache = artifact_cache
        return self.cache

    def _batch_backend(self, ap: AttachedProgram):
        tel = self.telemetry
        built = None        # (segments or -1, wall ns) when a backend is built
        if ap.pred is None and not ap.pred_unfit:
            cache = self._artifact_cache()
            cache.enable_xla_cache()
            t0 = time.perf_counter_ns()
            try:
                from .predicate import PredicatedPolicy
                code, cuts = cache.unrolled(ap.vm.lowered,
                                            injector=self.injector)
                ap.pred = PredicatedPolicy(ap.vm.lowered, ap.vm.maps,
                                           code=code, cuts=cuts,
                                           seg_limit=PRED_MAX_UNROLL)
                built = (ap.pred.num_segments, time.perf_counter_ns() - t0)
            except ValueError:      # unroll over MAX_UNROLLED -> JIT fallback
                ap.pred_unfit = True
                hook = next((h for h, a in self._hooks.items() if a is ap),
                            "?")
                # a budget blowup counts toward the program's strike ledger
                # but never detaches by itself — the JIT fallback IS the
                # graceful degradation
                self.supervisor.note_segment_blowup(hook)
        if ap.pred is None and ap.jit is None:
            from .jit import JitPolicy
            t0 = time.perf_counter_ns()
            ap.jit = JitPolicy(ap.vm.lowered, ap.vm.maps)
            built = (-1, time.perf_counter_ns() - t0)
        if built is not None and tel is not None and tel.enabled:
            hook = next((h for h, a in self._hooks.items() if a is ap), "?")
            tel.emit(EV_COMPILE, HOOK_INDEX.get(hook, -1), built[0], built[1])
            cs = self._artifact_cache().stats
            # a1 packs the miss-reason field: low 24 bits total misses,
            # high bits corrupt-artifact misses (see ringbuf.EV_CACHE)
            tel.emit(EV_CACHE, cs.get("unroll_hits", 0),
                     cs.get("unroll_misses", 0)
                     | (cs.get("miss_corrupt", 0) << 24),
                     cs.get("unroll_disk_hits", 0))
            tel.inc("backend_builds")
        return ap.pred if ap.pred is not None else ap.jit

    def warm(self, hook: str, max_batch: int = PAD_MIN) -> None:
        """Eagerly build (and compile) the batch backend for ``hook`` up to
        the ``max_batch`` bucket — engine construction calls this so the
        first decode step is not the one paying tracing/compilation, and so
        a warm artifact cache is consumed at startup rather than mid-serve.
        No-op when nothing is attached."""
        ap = self._hooks.get(hook)
        if ap is None:
            return
        backend = self._batch_backend(ap)
        pad = PAD_MIN
        while True:
            backend.run_batch(np.zeros((pad, CTX_LEN), dtype=np.int64))
            if pad >= max_batch:
                break
            pad *= 2

    def run_batch(self, hook: str, ctx_mat: np.ndarray, *,
                  discipline: bool = True) -> np.ndarray | None:
        """Vectorized decision for a batch of faults.

        One call = ONE program invocation regardless of batch size — the
        amortization the batched fault path is built on.  Uses the
        predicated straight-line executor (split into chained segments when
        the unroll exceeds the per-segment budget), falling back to the
        while+switch JIT only for programs whose flattening exceeds
        lower.MAX_UNROLLED entirely; the batch is padded to power-of-two
        buckets so varying batch sizes reuse compilations, and compiled
        artifacts persist across sessions via the artifact cache.

        ``discipline=False`` skips the per-row misbehavior pass and returns
        the raw decision vector: callers that CONSUME only a subset of the
        rows (``fault_batch`` — an earlier grant can cover later requests)
        must instead discipline each row they consume via
        :meth:`discipline_row`, so strikes accrue for exactly the rows the
        scalar route would have faulted (the route-parity contract).
        Per-call accounting (ring-drop streaks, backend crashes) happens
        here regardless.
        """
        ap = self._hooks.get(hook)
        if ap is None:
            return None
        backend = self._batch_backend(ap)
        n = ctx_mat.shape[0]
        self.invocations[hook] += n
        self.calls[hook] += 1
        self.batch_calls[hook] += 1
        padded = ctx_mat
        pad = PAD_MIN
        while pad < n:
            pad *= 2      # at most log2(max batch) compiled shape variants
        if pad > n:
            padded = np.concatenate(
                [ctx_mat, np.repeat(ctx_mat[:1], pad - n, axis=0)])
        tel = self.telemetry
        timed = tel is not None and tel.enabled
        t0 = time.perf_counter_ns() if timed else 0
        try:
            out = backend.run_batch(padded)[:n]
        except Exception:
            # a crashing batch backend costs one strike and the whole batch
            # falls back to the kernel default — never an engine crash
            self._strike(hook, ap, REASON_RUNTIME_ERROR,
                         int(ctx_mat[0, CTX.KTIME_NS]) if n else 0)
            return np.full(n, POLICY_FALLBACK, dtype=np.int64)
        dropped = 0
        if timed:
            dt = time.perf_counter_ns() - t0
            tel.observe_hook(hook, dt, n)
            tel.emit(EV_HOOK, HOOK_INDEX[hook], n, dt)
            if getattr(backend, "rb_cap", 0):
                # drain the device event buffers: only the n real lanes — the
                # power-of-two padding rows are repeats of row 0 and their
                # emissions (like their decisions) are discarded
                events, drops = backend.take_events(n)
                for e in events:
                    tel.ring.push(*e)
                tel.prog_lane_drops += drops
                dropped = drops
        sup = self.supervisor
        if dropped:
            if sup.note_rb_drops(hook, dropped):
                self._strike(hook, ap, REASON_RB_EXHAUSTION,
                             int(ctx_mat[0, CTX.KTIME_NS]) if n else 0)
        else:
            sup.note_rb_clean(hook)
        if not discipline:
            return np.asarray(out)
        return self._discipline_batch(hook, ap, ctx_mat, out, n)

    # ------------------------------------------- consumption-time discipline
    def row_discipline_needed(self, hook: str, decisions) -> bool:
        """Whether :meth:`discipline_row` has any work to do for this raw
        decision vector — False on the healthy path, so consuming a clean
        batch costs one vectorized check and zero per-row calls."""
        if decisions is None:
            return False
        inj = self.injector
        if inj is not None and inj.site_armed(SITE_HOOK_RUN):
            return True
        return bool((np.asarray(decisions) < POLICY_FALLBACK).any())

    def discipline_row(self, hook: str, ctx_vec: np.ndarray,
                       decision: int) -> int:
        """Misbehavior pass for ONE consumed batch row (see ``run_batch``
        with ``discipline=False``).  Strikes accrue only for rows the
        caller actually consumes — a row covered by an earlier grant never
        faults on the scalar route, so it must not strike here either.
        Returns the disciplined decision: POLICY_FALLBACK on a strike,
        POLICY_DETACHED once the program detached earlier in the batch."""
        ap = self._hooks.get(hook)
        if ap is None:
            return POLICY_DETACHED
        ktime = int(ctx_vec[CTX.KTIME_NS])
        inj = self.injector
        if inj is not None and inj.fires(SITE_HOOK_RUN, HOOK_INDEX[hook],
                                         int(ctx_vec[CTX.PID]),
                                         int(ctx_vec[CTX.ADDR]), ktime):
            self._strike(hook, ap, REASON_RUNTIME_ERROR, ktime)
            return POLICY_FALLBACK
        if int(decision) < POLICY_FALLBACK:
            self._strike(hook, ap, REASON_INVALID_RETURN, ktime)
            return POLICY_FALLBACK
        return int(decision)
