"""Predicated compiler: verified policy program -> straight-line masked jnp.

EXPERIMENTS.md §Perf iteration #5 found the while+switch XLA build of the VM
no faster than the host interpreter (lax.switch under vmap executes every
branch each step).  The verifier's guarantees enable the classic fix:

  1. bounded-loop UNROLLING — JNZDEC trip counts are verifier-proven exact
     constants, so every loop expands to exactly `trips` copies of its body
     (the flattening lives in :func:`repro.core.lower.unroll_lowered`, over
     the shared lowered IR); the result has only FORWARD jumps;
  2. IF-CONVERSION — forward-jump-only code executes as one straight line
     with a per-lane active mask: conditional jumps move lanes into a
     pending-mask at their target, register writes are `where(active, ...)`.

SEGMENTED UNROLL (the unified-pipeline addition): the XLA compile time of
one straight-line program grows superlinearly with its length, which used
to cap this backend at 512 unrolled insns and push the default 64-region
Fig-1 program (900 insns) onto the slow while+switch JIT.  Instead, the
flattened code is now SPLIT at loop-copy (back-edge) boundaries into
predicated segments of at most ``seg_limit`` insns, each compiled as its
own small XLA program, chained by a host dispatch loop that threads
``(regs, active, done, r0)`` plus the cross-segment pending masks from one
segment to the next.  Because the flattened code is forward-only, ONE pass
over the segments in order is exact — a jump out of segment *i* lands in a
pending mask that segment *j > i* ORs into its active lanes when the pc
walks over the target.  Per-segment artifacts are exactly the unit the
cross-session cache (:mod:`repro.core.cache`) persists.

The compiled function is fully vectorized over a fault batch — within a
segment there is no control flow at all, exactly the shape TPUs (and CPUs)
like.  `PredicatedPolicy` is the drop-in batch executor the engine uses.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from .isa import (ALU_IMM_OPS, ALU_REG_OPS, COND_JUMP_IMM, COND_JUMP_REG,
                  NUM_REGS, Op, Program)
from .lower import (LIns, LoweredProgram, BatchCtx, MAX_UNROLLED, RB_FIELDS,
                    alu_jnp as _alu_jnp, cmp_jnp as _cmp_jnp,
                    collect_rb_events, helper_jnp, ldctx_dyn, lower,
                    map_lookup, map_lookup_dyn, plan_scan_stages, rb_words,
                    segment_code, unroll_lowered)
from .maps import MapRegistry
from .vm import _IMM2REG, _JIMM2REG, RB_HELPERS

I64 = jnp.int64

# Per-SEGMENT predicated-compile budget: one straight-line XLA program never
# exceeds this many lowered insns; longer programs chain segments.
SEG_LIMIT = 512


def unroll(program: Program | LoweredProgram, maps: MapRegistry
           ) -> tuple[LIns, ...]:
    """Flatten all bounded loops; returns the forward-only lowered code.

    Thin wrapper over the shared pipeline (lower once, expand from verifier
    trip counts) kept as the public sizing entry point — ``len(unroll(p,
    maps))`` is the number the segment planner budgets against."""
    lp = program if isinstance(program, LoweredProgram) else \
        lower(program, maps)
    code, _cuts = unroll_lowered(lp)
    return code


class _Segment:
    """Static plan for one predicated segment of the flattened program."""
    __slots__ = ("start", "end", "entry_targets", "exit_targets", "fn")

    def __init__(self, start: int, end: int, entry_targets: tuple[int, ...],
                 exit_targets: tuple[int, ...], fn: Callable):
        self.start = start
        self.end = end
        self.entry_targets = entry_targets
        self.exit_targets = exit_targets
        self.fn = fn


def _plan_segments(code: tuple[LIns, ...], cuts: tuple[int, ...],
                   seg_limit: int) -> list[tuple[int, int, tuple, tuple]]:
    """Split ``code`` into spans and compute each span's cross-segment
    interface: the targets it must accept masks FOR (jumps from earlier
    segments landing inside it) and the targets it emits masks TO (its own
    jumps landing at/after its end)."""
    spans = segment_code(code, cuts, seg_limit)
    plans = []
    for start, end in spans:
        entry = sorted({ins.target for pc, ins in enumerate(code[:start])
                        if ins.target is not None and ins.target >= 0
                        and start <= ins.target < end})
        exits = sorted({ins.target for ins in code[start:end]
                        if ins.target is not None and ins.target >= end})
        plans.append((start, end, tuple(entry), tuple(exits)))
    return plans


def _exec_span(code: tuple[LIns, ...], start: int, end: int, cv,
               map_arrays, map_lens, regs, active, done, r0_final,
               pending: dict, ev=None, ecnt=None, edrop=None,
               rb_cap: int = 0):
    """Execute the straight-line span ``[start, end)`` predicated.

    ``regs`` is a list of per-register ``[B]`` vectors; ``pending`` maps
    absolute jump-target pc -> lane mask — targets inside the span are
    consumed as the walk passes them, targets at/after ``end`` are left in
    (or OR-ed into) ``pending`` for the caller.  This is the ONE lowering
    walk shared by the chained per-segment compile, the fused one-dispatch
    executor and the ``lax.scan`` loop-copy body."""
    B = active.shape[0]

    def write(regs, dst, val, active):
        regs = list(regs)
        regs[dst] = jnp.where(active, val, regs[dst])
        return regs

    for pc in range(start, end):
        if pc in pending:
            active = active | pending.pop(pc)
        insn = code[pc]
        op = insn.op
        if op in ALU_REG_OPS:
            val = _alu_jnp(op, regs[insn.dst], regs[insn.src])
            regs = write(regs, insn.dst, val, active)
        elif op in ALU_IMM_OPS:
            imm = jnp.asarray(insn.imm, I64)
            val = imm if op == Op.MOVI else _alu_jnp(
                _IMM2REG[op], regs[insn.dst], imm)
            regs = write(regs, insn.dst, val, active)
        elif op == Op.NEG:
            regs = write(regs, insn.dst, -regs[insn.dst], active)
        elif op == Op.LDCTX:
            regs = write(regs, insn.dst, cv.col(insn.imm), active)
        elif op == Op.LDCTXR:
            regs = write(regs, insn.dst, ldctx_dyn(cv, regs[insn.src]),
                         active)
        elif op == Op.LDMAP:
            val = map_lookup(map_arrays, map_lens, insn.imm,
                             regs[insn.src])
            regs = write(regs, insn.dst, val, active)
        elif op == Op.LDMAPX:
            val = map_lookup_dyn(map_arrays, map_lens, regs[insn.src2],
                                 regs[insn.src], cv.zeros_like_lane())
            regs = write(regs, insn.dst, val, active)
        elif op == Op.MAPSZ:
            regs = write(regs, insn.dst,
                         jnp.broadcast_to(map_lens[insn.imm], (B,)),
                         active)
        elif op == Op.JA:
            pending[insn.target] = pending.get(
                insn.target, jnp.zeros(B, bool)) | active
            active = jnp.zeros(B, bool)
        elif op in COND_JUMP_REG or op in COND_JUMP_IMM:
            if op in COND_JUMP_REG:
                taken = _cmp_jnp(op, regs[insn.dst], regs[insn.src])
            else:
                taken = _cmp_jnp(_JIMM2REG[op], regs[insn.dst],
                                 jnp.asarray(insn.src2, I64))
            taken = taken & active
            pending[insn.target] = pending.get(
                insn.target, jnp.zeros(B, bool)) | taken
            active = active & ~taken
        elif op == Op.CALL:
            if rb_cap and insn.imm in RB_HELPERS:
                words = rb_words(insn.imm, lambda i: regs[i], cv)
                ev, ecnt, edrop, r0 = cv.event_write(
                    ev, ecnt, edrop, words, active)
            else:
                r0 = helper_jnp(insn.imm, lambda i: regs[i], cv)
            regs = write(regs, 0, r0, active)
        elif op == Op.EXIT:
            r0_final = jnp.where(active & ~done, regs[0], r0_final)
            done = done | active
            active = jnp.zeros(B, bool)
        else:   # pragma: no cover
            raise ValueError(f"unhandled opcode {op}")
    return regs, active, done, r0_final, ev, ecnt, edrop


def _make_segment_fn(code: tuple[LIns, ...], start: int, end: int,
                     entry_targets: tuple[int, ...],
                     exit_targets: tuple[int, ...],
                     rb_cap: int = 0) -> Callable:
    """Build the traced body of one segment.

    Signature: ``(ctx[B,C], map_arrays, map_lens, regs[R,B], active[B],
    done[B], r0[B], entry_masks tuple) -> (regs, active, done, r0,
    exit_masks tuple)`` — ``active`` out is the fall-through mask into the
    next segment.  When the program emits ring-buffer events (``rb_cap >
    0``) the per-lane event buffers ``(ev[B,cap,5], ecnt[B], edrop[B])``
    are threaded through as three extra leading-state params/results;
    emit-free programs keep the original signature (and thus their cached
    XLA executables) exactly."""

    def seg(ctx, map_arrays, map_lens, regs_in, active, done, r0_final,
            entry_masks, ev=None, ecnt=None, edrop=None):
        B = ctx.shape[0]
        cv = BatchCtx(ctx)
        regs = [regs_in[i] for i in range(NUM_REGS)]
        pending: dict[int, jax.Array] = dict(zip(entry_targets, entry_masks))
        regs, active, done, r0_final, ev, ecnt, edrop = _exec_span(
            code, start, end, cv, map_arrays, map_lens, regs, active, done,
            r0_final, pending, ev, ecnt, edrop, rb_cap)
        exit_masks = tuple(pending.pop(t, jnp.zeros(B, bool))
                           for t in exit_targets)
        # forward-only code: anything still pending must be an exit target
        assert not pending, f"unconsumed jump targets {sorted(pending)}"
        if rb_cap:
            return (jnp.stack(regs), active, done, r0_final, exit_masks,
                    ev, ecnt, edrop)
        return jnp.stack(regs), active, done, r0_final, exit_masks

    return seg


def _make_fused_fn(code: tuple[LIns, ...], stages: list[tuple],
                   rb_cap: int = 0) -> Callable:
    """Build the ONE-dispatch executor: the whole flattened program as a
    single traced function — plain stages inline, congruent loop-copy runs
    (see :func:`repro.core.lower.plan_scan_stages`) as a ``lax.scan`` over
    ONE copy body with carry ``(regs, active, done, r0, exit masks)`` plus
    the ring-buffer state when the program emits.  Where the chained path
    pays one XLA dispatch per segment per batch, this costs exactly one,
    and the traced length collapses from the full unroll to prologue + one
    copy per loop + epilogue.

    Signature: ``(ctx, map_arrays, map_lens, regs[R,B], active, done, r0
    [, ev, ecnt, edrop]) -> r0 [, ev, ecnt, edrop]``."""

    def fused(ctx, map_arrays, map_lens, regs_in, active, done, r0_final,
              ev=None, ecnt=None, edrop=None):
        B = ctx.shape[0]
        cv = BatchCtx(ctx)
        zeros = jnp.zeros(B, bool)
        regs = [regs_in[i] for i in range(NUM_REGS)]
        pending: dict[int, jax.Array] = {}
        for st in stages:
            if st[0] == "plain":
                _, s, e = st
                regs, active, done, r0_final, ev, ecnt, edrop = _exec_span(
                    code, s, e, cv, map_arrays, map_lens, regs, active,
                    done, r0_final, pending, ev, ecnt, edrop, rb_cap)
                continue
            _, s, e, trips, blen = st
            if s in pending:
                active = active | pending.pop(s)
            exits = tuple(sorted({ins.target for ins in code[s:s + blen]
                                  if ins.target >= e}))
            exit_acc = tuple(pending.pop(t, zeros) for t in exits)

            def body(carry, _, s=s, blen=blen, exits=exits):
                if rb_cap:
                    (regs_c, act_c, done_c, r0_c, acc,
                     ev_c, ecnt_c, edrop_c) = carry
                else:
                    regs_c, act_c, done_c, r0_c, acc = carry
                    ev_c = ecnt_c = edrop_c = None
                regs_l = [regs_c[i] for i in range(NUM_REGS)]
                local: dict[int, jax.Array] = {}
                regs_l, act_c, done_c, r0_c, ev_c, ecnt_c, edrop_c = \
                    _exec_span(code, s, s + blen, cv, map_arrays, map_lens,
                               regs_l, act_c, done_c, r0_c, local,
                               ev_c, ecnt_c, edrop_c, rb_cap)
                acc = tuple(m | local.pop(t, zeros)
                            for t, m in zip(exits, acc))
                assert not local, \
                    f"scan body leaked targets {sorted(local)}"
                out = (jnp.stack(regs_l), act_c, done_c, r0_c, acc)
                if rb_cap:
                    out += (ev_c, ecnt_c, edrop_c)
                return out, None

            init = (jnp.stack(regs), active, done, r0_final, exit_acc)
            if rb_cap:
                init += (ev, ecnt, edrop)
            carry, _ = jax.lax.scan(body, init, None, length=trips)
            if rb_cap:
                regs_s, active, done, r0_final, exit_acc, ev, ecnt, edrop \
                    = carry
            else:
                regs_s, active, done, r0_final, exit_acc = carry
            regs = [regs_s[i] for i in range(NUM_REGS)]
            for t, m in zip(exits, exit_acc):
                pending[t] = (pending[t] | m) if t in pending else m
        if rb_cap:
            return r0_final, ev, ecnt, edrop
        return r0_final

    return fused


def compile_predicated(program: Program | LoweredProgram, maps: MapRegistry,
                       code=None) -> Callable:
    """Returns fn(ctx [B, CTX_LEN], map_arrays, map_lens) -> r0 [B].

    Single-segment convenience entry (the pre-segmentation surface, kept for
    direct use and tests): the whole flattened program compiles as ONE
    straight-line XLA function.  ``code`` lets a caller that already
    unrolled the program pass the result in instead of unrolling twice."""
    pol = PredicatedPolicy(program, maps, code=code,
                           seg_limit=MAX_UNROLLED)

    def run(ctx, map_arrays, map_lens):
        return pol._run_segments(ctx, map_arrays, map_lens)

    return run


class PredicatedPolicy:
    """Batch fault-decision executor (drop-in for JitPolicy.run_batch).

    Two execution shapes over the same flattened code:

    * **fused** (preferred): when :func:`plan_scan_stages` compresses the
      unroll to a traced length within ``seg_limit`` — congruent loop-copy
      runs become ``lax.scan`` stages — the WHOLE program compiles as one
      XLA function and every ``run_batch`` costs exactly ONE dispatch.
    * **chained** (fallback): a chain of ≤ ``seg_limit``-insn predicated
      segments driven by a host loop threading ``(regs, active, done, r0)``
      plus cross-segment pending masks — one dispatch per segment.

    ``num_segments`` always reports the chained PLAN size (the historical
    invariant the boundary/regression guards pin); ``fused`` /
    ``dispatches`` say what actually executes."""

    def __init__(self, program: Program | LoweredProgram, maps: MapRegistry,
                 code=None, cuts: tuple[int, ...] | None = None,
                 seg_limit: int = SEG_LIMIT) -> None:
        self.maps = maps
        lp = program if isinstance(program, LoweredProgram) else \
            lower(program, maps)
        if code is None:
            code, cuts = unroll_lowered(lp)
        elif code and not isinstance(code[0], LIns):
            raise TypeError("code must be lowered-IR (see core.lower)")
        code = tuple(code)
        cuts = tuple(cuts or ())
        self.unrolled_len = len(code)
        self.seg_limit = seg_limit
        self.rb_cap = int(lp.facts.get("rb_cap", 0))
        self._last_rb: tuple | None = None     # (ev, cnt, drops) device arrays
        self._plans = _plan_segments(code, cuts, seg_limit)
        stages, traced = plan_scan_stages(code, cuts)
        self.traced_len = traced
        self.scan_stages = sum(1 for st in stages if st[0] == "scan")
        self.fused = traced <= seg_limit
        self.segments: list[_Segment] = []
        if self.fused:
            self._fused_fn = jax.jit(
                _make_fused_fn(code, stages, rb_cap=self.rb_cap))
        else:
            self._fused_fn = None
            for start, end, entry, exits in self._plans:
                fn = jax.jit(_make_segment_fn(code, start, end, entry,
                                              exits, rb_cap=self.rb_cap))
                self.segments.append(_Segment(start, end, entry, exits, fn))
        # dispatches per run_batch on the path actually taken, plus a
        # lifetime counter the bench's crossing audit reads
        self.dispatches = 1 if self.fused else len(self._plans)
        self.total_dispatches = 0
        self._map_cache: tuple | None = None   # (version, arrays, lens)
        # per-batch-size initial machine state, built once: jnp constants are
        # immutable, and re-allocating five tiny device arrays per dispatch
        # dominated the per-call cost at decode-sized batches
        self._state_cache: dict[int, tuple] = {}

    @property
    def num_segments(self) -> int:
        return len(self._plans)

    def _map_args(self):
        ver = self.maps.version()
        if self._map_cache is None or self._map_cache[0] != ver:
            arrays = tuple(jnp.asarray(self.maps[i].live_array())
                           for i in range(len(self.maps)))
            lens = jnp.asarray(self.maps.lens(), I64)
            if not arrays:
                arrays = (jnp.zeros(1, I64),)
                lens = jnp.zeros(1, I64)
            self._map_cache = (ver, arrays, lens)
        return self._map_cache[1], self._map_cache[2]

    def _init_state(self, B: int) -> tuple:
        st = self._state_cache.get(B)
        if st is None:
            st = (jnp.zeros((NUM_REGS, B), I64), jnp.ones(B, bool),
                  jnp.zeros(B, bool), jnp.zeros(B, I64))
            if self.rb_cap:
                st += (jnp.zeros((B, self.rb_cap, RB_FIELDS), I64),
                       jnp.zeros(B, I64), jnp.zeros(B, I64))
            self._state_cache[B] = st
        return st

    def _run_segments(self, ctx, map_arrays, map_lens):
        B = ctx.shape[0]
        if self.rb_cap:
            regs, active, done, r0, ev, ecnt, edrop = self._init_state(B)
        else:
            regs, active, done, r0 = self._init_state(B)
        if self._fused_fn is not None:
            self.total_dispatches += 1
            if self.rb_cap:
                r0, ev, ecnt, edrop = self._fused_fn(
                    ctx, map_arrays, map_lens, regs, active, done, r0,
                    ev, ecnt, edrop)
                self._last_rb = (ev, ecnt, edrop)
            else:
                r0 = self._fused_fn(ctx, map_arrays, map_lens, regs,
                                    active, done, r0)
            return r0
        self.total_dispatches += len(self.segments)
        zeros = done
        pending: dict[int, jax.Array] = {}
        for seg in self.segments:
            entry = tuple(pending.pop(t, zeros) for t in seg.entry_targets)
            if self.rb_cap:
                regs, active, done, r0, exits, ev, ecnt, edrop = seg.fn(
                    ctx, map_arrays, map_lens, regs, active, done, r0,
                    entry, ev, ecnt, edrop)
            else:
                regs, active, done, r0, exits = seg.fn(
                    ctx, map_arrays, map_lens, regs, active, done, r0, entry)
            for t, m in zip(seg.exit_targets, exits):
                pending[t] = (pending[t] | m) if t in pending else m
        if self.rb_cap:
            self._last_rb = (ev, ecnt, edrop)
        return r0

    def run_batch(self, ctx_mat: np.ndarray) -> np.ndarray:
        with jax.experimental.enable_x64():
            arrays, lens = self._map_args()
            return np.asarray(self._run_segments(
                jnp.asarray(ctx_mat, I64), arrays, lens))

    def take_events(self, n: int) -> tuple[list, int]:
        """Drain the last batch's ring-buffer records for the first ``n``
        lanes (and their slot-drop count); empty until the next batch."""
        if self._last_rb is None:
            return [], 0
        ev, cnt, dr = self._last_rb
        self._last_rb = None
        return collect_rb_events(ev, cnt, dr, n)
