"""Predicated compiler: verified policy program -> straight-line masked jnp.

EXPERIMENTS.md §Perf iteration #5 found the while+switch XLA build of the VM
no faster than the host interpreter (lax.switch under vmap executes every
branch each step).  The verifier's guarantees enable the classic fix:

  1. bounded-loop UNROLLING — JNZDEC trip counts are verifier-proven exact
     constants (const-tracked counter the body cannot write), so each loop
     expands to exactly `trips` copies of its body with jump targets
     remapped; the result has only FORWARD jumps;
  2. IF-CONVERSION — forward-jump-only code executes as one straight line
     with a per-lane active mask: conditional jumps move lanes into a
     pending-mask at their target, register writes are `where(active, ...)`.

The compiled function is fully vectorized over a fault batch: one XLA
program of ~unrolled-length fused vector ops, no control flow at all —
exactly the shape TPUs (and CPUs) like.  `PredicatedPolicy` is the drop-in
batch executor the engine uses for prefill fault storms.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from .context import CTX, MAX_TIERS
from .isa import (ALU_IMM_OPS, ALU_REG_OPS, COND_JUMP_IMM, COND_JUMP_REG,
                  NUM_REGS, Insn, Op, Program)
from .jit import _alu_jnp, _cmp_jnp
from .maps import MapRegistry
from .vm import (HELPER_IDS, HELPER_KTIME, HELPER_MIGRATE_COST,
                 HELPER_PROMOTION_COST, HELPER_TRACE, _IMM2REG, _JIMM2REG)
from .verifier import verify

I64 = jnp.int64
MAX_UNROLLED = 20_000


class _Jump:
    """Unrolled-form instruction wrapper with an ABSOLUTE target."""
    __slots__ = ("insn", "target")

    def __init__(self, insn: Insn, target: int | None):
        self.insn = insn
        self.target = target


def _find_loop(insns: list[Insn]) -> tuple[int, int] | None:
    for pc, insn in enumerate(insns):
        if insn.op == Op.JNZDEC:
            return pc + 1 + insn.imm, pc      # (target, jnzdec_pc)
    return None


def unroll(program: Program, maps: MapRegistry) -> list[_Jump]:
    """Expand all bounded loops; return instructions with absolute targets."""
    insns = list(program.insns)
    while True:
        facts = verify(Program(insns, program.name), num_maps=len(maps),
                       map_lens=maps.lens(), helper_ids=HELPER_IDS)
        loop = _find_loop(insns)
        if loop is None:
            break
        t, jpc = loop
        trips = facts["loop_trips"][jpc]
        body = insns[t:jpc]
        counter = insns[jpc].dst
        # positions: prefix [0,t) | trips * (body + SUBI) | suffix
        blen = len(body) + 1
        new_pos: dict[int, int] = {}
        for pc in range(t):
            new_pos[pc] = pc
        for pc in range(jpc + 1, len(insns)):
            new_pos[pc] = t + trips * blen + (pc - jpc - 1)
        end_pos = t + trips * blen

        def map_target(old_tgt: int, copy: int) -> int:
            if old_tgt < t:
                return new_pos.get(old_tgt, old_tgt)
            if t <= old_tgt < jpc:                 # inside body
                return t + copy * blen + (old_tgt - t)
            if old_tgt == jpc:                     # "continue": copy's SUBI
                return t + copy * blen + len(body)
            return new_pos[old_tgt]                # past the loop

        out: list[Insn] = list(insns[:t])
        for copy in range(trips):
            for j, b in enumerate(body):
                if b.op in (Op.JA,) or b.op in COND_JUMP_REG \
                        or b.op in COND_JUMP_IMM:
                    old_tgt = (t + j) + 1 + b.imm
                    new_tgt = map_target(old_tgt, copy)
                    here = t + copy * blen + j
                    out.append(Insn(b.op, b.dst, b.src, new_tgt - here - 1,
                                    b.src2))
                else:
                    out.append(b)
            out.append(Insn(Op.SUBI, counter, 0, 1))      # faithful counter
        # suffix with remapped targets
        for pc in range(jpc + 1, len(insns)):
            b = insns[pc]
            if b.op in (Op.JA,) or b.op in COND_JUMP_REG \
                    or b.op in COND_JUMP_IMM:
                old_tgt = pc + 1 + b.imm
                new_tgt = map_target(old_tgt, 0)
                here = new_pos[pc]
                out.append(Insn(b.op, b.dst, b.src, new_tgt - here - 1,
                                b.src2))
            else:
                out.append(b)
        # prefix jumps may cross into/over the loop: remap them too
        fixed: list[Insn] = []
        for pc in range(t):
            b = out[pc]
            if b.op in (Op.JA,) or b.op in COND_JUMP_REG \
                    or b.op in COND_JUMP_IMM:
                old_tgt = pc + 1 + b.imm
                new_tgt = map_target(old_tgt, 0)
                fixed.append(Insn(b.op, b.dst, b.src, new_tgt - pc - 1,
                                  b.src2))
            else:
                fixed.append(b)
        insns = fixed + out[t:]
        if len(insns) > MAX_UNROLLED:
            raise ValueError(f"unrolled program too long ({len(insns)})")
    return [_Jump(i, (pc + 1 + i.imm) if (
        i.op in (Op.JA,) or i.op in COND_JUMP_REG or i.op in COND_JUMP_IMM)
        else None) for pc, i in enumerate(insns)]


def compile_predicated(program: Program, maps: MapRegistry,
                       code: list[_Jump] | None = None) -> Callable:
    """Returns fn(ctx [B, CTX_LEN], map_arrays, map_lens) -> r0 [B].

    ``code`` lets a caller that already unrolled the program (e.g. to size
    it) pass the result in instead of unrolling twice."""
    if code is None:
        code = unroll(program, maps)
    n = len(code)

    def run(ctx, map_arrays, map_lens):
        B = ctx.shape[0]
        regs = [jnp.zeros(B, I64) for _ in range(NUM_REGS)]
        active = jnp.ones(B, bool)
        done = jnp.zeros(B, bool)
        r0_final = jnp.zeros(B, I64)
        pending: dict[int, jax.Array] = {}

        def write(regs, dst, val, active):
            regs = list(regs)
            regs[dst] = jnp.where(active, val, regs[dst])
            return regs

        for pc, j in enumerate(code):
            if pc in pending:
                active = active | pending.pop(pc)
            insn = j.insn
            op = insn.op
            if op in ALU_REG_OPS:
                val = _alu_jnp(op, regs[insn.dst], regs[insn.src])
                regs = write(regs, insn.dst, val, active)
            elif op in ALU_IMM_OPS:
                imm = jnp.asarray(insn.imm, I64)
                val = imm if op == Op.MOVI else _alu_jnp(
                    _IMM2REG[op], regs[insn.dst], imm)
                regs = write(regs, insn.dst, val, active)
            elif op == Op.NEG:
                regs = write(regs, insn.dst, -regs[insn.dst], active)
            elif op == Op.LDCTX:
                regs = write(regs, insn.dst, ctx[:, insn.imm], active)
            elif op in (Op.LDMAP, Op.LDMAPX):
                if op == Op.LDMAP:
                    mids = jnp.full((B,), insn.src2, jnp.int32)
                else:
                    mids = jnp.clip(regs[insn.src2], 0,
                                    len(map_arrays) - 1).astype(jnp.int32)
                idx = regs[insn.src]
                val = jnp.zeros(B, I64)
                for k, arr in enumerate(map_arrays):
                    ok = (idx >= 0) & (idx < map_lens[k]) & (mids == k)
                    safe = jnp.clip(idx, 0, arr.shape[0] - 1)
                    val = jnp.where(ok, arr[safe], val)
                regs = write(regs, insn.dst, val, active)
            elif op == Op.MAPSZ:
                regs = write(regs, insn.dst,
                             jnp.broadcast_to(map_lens[insn.imm], (B,)),
                             active)
            elif op == Op.JA:
                pending[j.target] = pending.get(j.target,
                                                jnp.zeros(B, bool)) | active
                active = jnp.zeros(B, bool)
            elif op in COND_JUMP_REG or op in COND_JUMP_IMM:
                if op in COND_JUMP_REG:
                    taken = _cmp_jnp(op, regs[insn.dst], regs[insn.src])
                else:
                    taken = _cmp_jnp(_JIMM2REG[op], regs[insn.dst],
                                     jnp.asarray(insn.src2, I64))
                taken = taken & active
                pending[j.target] = pending.get(j.target,
                                                jnp.zeros(B, bool)) | taken
                active = active & ~taken
            elif op == Op.CALL:
                if insn.imm == HELPER_KTIME:
                    r0 = ctx[:, CTX.KTIME_NS]
                elif insn.imm == HELPER_PROMOTION_COST:
                    order = jnp.clip(regs[1], 0, 3)
                    nblocks = jnp.asarray(4, I64) ** order
                    zero = ctx[:, CTX.ZERO_NS_PER_BLOCK] * nblocks
                    oi = jnp.int32(CTX.FREE_BLOCKS_O0) + order.astype(jnp.int32)
                    free = jnp.take_along_axis(ctx, oi[:, None], axis=1)[:, 0]
                    fi = jnp.int32(CTX.FRAG_O0) + order.astype(jnp.int32)
                    frag = jnp.take_along_axis(ctx, fi[:, None], axis=1)[:, 0]
                    compact = (ctx[:, CTX.COMPACT_NS_PER_BLOCK] * nblocks
                               * (1000 + frag) // 1000)
                    r0 = zero + jnp.where(free > 0, 0, compact)
                elif insn.imm == HELPER_MIGRATE_COST:
                    order = jnp.clip(regs[1], 0, 3)
                    nblocks = jnp.asarray(4, I64) ** order
                    src = jnp.clip(regs[2], 0, MAX_TIERS - 1)
                    dst = jnp.clip(regs[3], 0, MAX_TIERS - 1)
                    lo = jnp.minimum(src, dst).astype(jnp.int32)
                    hi = jnp.maximum(src, dst).astype(jnp.int32)

                    def gather(base, idx):
                        cols = jnp.int32(base) + idx
                        return jnp.take_along_axis(
                            ctx, cols[:, None], axis=1)[:, 0]
                    setup = (gather(CTX.MIG_CUM_SETUP_T0, hi)
                             - gather(CTX.MIG_CUM_SETUP_T0, lo))
                    per = (gather(CTX.MIG_CUM_NS_T0, hi)
                           - gather(CTX.MIG_CUM_NS_T0, lo))
                    r0 = setup + per * nblocks
                else:   # HELPER_TRACE and friends: host-only, no-op
                    r0 = jnp.zeros(B, I64)
                regs = write(regs, 0, r0, active)
            elif op == Op.EXIT:
                r0_final = jnp.where(active & ~done, regs[0], r0_final)
                done = done | active
                active = jnp.zeros(B, bool)
            else:   # pragma: no cover
                raise ValueError(f"unhandled opcode {op}")
        return r0_final

    return run


class PredicatedPolicy:
    """Batch fault-decision executor (drop-in for JitPolicy.run_batch)."""

    def __init__(self, program: Program, maps: MapRegistry,
                 code: list[_Jump] | None = None) -> None:
        self.maps = maps
        self._fn = jax.jit(compile_predicated(program, maps, code))
        self._map_cache: tuple | None = None   # (version, arrays, lens)

    def _map_args(self):
        ver = self.maps.version()
        if self._map_cache is None or self._map_cache[0] != ver:
            arrays = tuple(jnp.asarray(self.maps[i].live_array())
                           for i in range(len(self.maps)))
            lens = jnp.asarray(self.maps.lens(), I64)
            if not arrays:
                arrays = (jnp.zeros(1, I64),)
                lens = jnp.zeros(1, I64)
            self._map_cache = (ver, arrays, lens)
        return self._map_cache[1], self._map_cache[2]

    def run_batch(self, ctx_mat: np.ndarray) -> np.ndarray:
        with jax.experimental.enable_x64():
            arrays, lens = self._map_args()
            return np.asarray(self._fn(jnp.asarray(ctx_mat, I64), arrays,
                                       lens))
