"""eBPF-mm core: userspace-guided multi-size paged memory management.

The paper's contribution as a composable library:

  * :mod:`isa` / :mod:`verifier` / :mod:`lower` / :mod:`vm` / :mod:`jit` /
    :mod:`predicate` — the eBPF-analogue policy pipeline: restricted
    bytecode, load-time verifier, ONE shared lowering pass (flat IR with
    absolute targets + resolved map slots) consumed by the host interpreter,
    the while+switch XLA JIT and the segmented predicated batch executor.
  * :mod:`cache` — cross-session compiler-artifact cache under ``.cache/``
    (pickled lowering/unroll artifacts + persisted XLA executables).
  * :mod:`maps` / :mod:`profiles` — eBPF maps and the userspace profile format.
  * :mod:`damon` — access monitoring with adaptive regions (benefit signal).
  * :mod:`cost` — calibrated promotion cost (zeroing + compaction) and the
    TLB-reach-analogue benefit model for the paged-attention kernel.
  * :mod:`buddy` / :mod:`mm` — the block-pool allocator and the memory
    manager with the fault hook (the kernel side).
  * :mod:`programs` — Figure-1 policy + THP/never baselines as bytecode.
  * :mod:`khugepaged` — background promotion (async collapse).
  * :mod:`tiering` — N-pool tiered placement behind ``HOOK_TIER`` (per-tier
    buddy pools for peer-HBM / host DRAM / NVMe, per-edge-costed multi-hop
    migration engine, demote/promote scans, prefill-time placement).
  * :mod:`wss` — online profile synthesis: the host consumer of the sampled
    ``HOOK_PROFILE`` surface (verified WSS/heat profiler programs over the
    live DAMON stream), hot-reloading synthesized profiles mid-run.
"""

from .buddy import BuddyAllocator, BuddyError, BuddyStats, order_blocks
from .cache import ArtifactCache, artifact_cache
from .context import (CTX, CTX_LEN, EVICT_DROP, FIXED_POINT, MAX_TIERS,
                      NUM_ORDERS, POLICY_DETACHED, POLICY_FALLBACK,
                      TIER_DEMOTE, TIER_KEEP, FaultContext, FaultKind)
from .cost import (CostModel, HWSpec, TierSpec, default_tier_chain,
                   host_dram_tier, make_cost_model, nvme_tier, peer_hbm_tier)
from .damon import Damon, Region
from .hooks import (HOOK_EVICT, HOOK_FAULT, HOOK_PROFILE, HOOK_RECLAIM,
                    HOOK_TIER, HookRegistry)
from .isa import Asm, Insn, Op, Program
from .jit import JitPolicy, compile_program
from .khugepaged import Khugepaged, KhugepagedConfig
from .lower import (LIns, LoweredProgram, lower, segment_code,
                    unroll_lowered)
from .maps import ArrayMap, MapRegistry
from .mm import (FaultResult, MemoryManager, MMError, MMOutOfMemory, MMStats,
                 PageMapping, ProcessState)
from .predicate import PredicatedPolicy, compile_predicated
from .profiles import (MAX_PROFILE_REGIONS, REGION_STRIDE, Profile,
                       ProfileRegion, profile_from_heat)
from .programs import (ebpf_mm_program, evict_ghost_program,
                       evict_lfu_program, evict_lru_program, never_program,
                       profile_benefit_program, profile_heat_histogram_program,
                       profile_wss_program, reclaim_lru_program,
                       thp_always_program, tier_damon_program,
                       tier_edge_admission_program, tier_heat_band_program,
                       tier_lru_program, tier_never_program)
from .tiering import (TIER_HBM, TIER_HOST, TierConfig, TieredMemoryManager)
from .wss import ProfileSynthesizer
from .verifier import VerifierError, verify
from .vm import (HELPER_IDS, HELPER_KTIME, HELPER_MIGRATE_COST,
                 HELPER_PROMOTION_COST, HELPER_RINGBUF_OUTPUT, HELPER_TRACE,
                 PolicyVM, RunResult, VMFault)

__all__ = [name for name in dir() if not name.startswith("_")]
