"""Qwen2-VL 7B [arXiv:2409.12191; hf]: 28L, d_model 3584, 28 heads (GQA kv=4),
d_ff 18944, vocab 152064; M-RoPE (temporal/height/width sections 16/24/24 of
head_dim/2=64); dynamic-resolution vision frontend is a STUB — input_specs()
provides precomputed patch embeddings + 3D positions."""

from .base import AttnCfg, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    kv_heads=4,
    d_ff=18944,
    vocab=152064,
    mlp="swiglu",
    norm="rms",
    attn=AttnCfg(rope_theta=1_000_000.0, mrope_sections=(16, 24, 24)),
    vlm_patches=1024,
    notes="28 heads not divisible by TP=16: attention-weight sharding falls "
          "back per the rule engine (kv=4 likewise); MLP TP carries the layer",
)


def smoke_config():
    return ModelConfig(
        name="qwen2vl-smoke", family="vlm", n_layers=3, d_model=64,
        n_heads=4, kv_heads=2, d_ff=128, vocab=512, mlp="swiglu", norm="rms",
        attn=AttnCfg(mrope_sections=(4, 2, 2)), vlm_patches=4)
