"""Config system: model configs, input-shape sets, and the arch registry."""

from __future__ import annotations

import importlib
from dataclasses import dataclass, field, replace
from typing import Optional


@dataclass(frozen=True)
class MoECfg:
    num_experts: int                 # routed experts
    top_k: int
    d_ff_expert: int
    num_shared: int = 0
    every: int = 1                   # MoE layer every N layers
    first_dense: int = 1             # leading dense layers
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01


@dataclass(frozen=True)
class MLACfg:
    kv_lora: int = 512
    qk_nope: int = 128
    qk_rope: int = 64
    v_head: int = 128


@dataclass(frozen=True)
class MambaCfg:
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    chunk: int = 256
    conv_dim: int = 4


@dataclass(frozen=True)
class AttnCfg:
    rope_theta: float = 10000.0
    use_rope: bool = True
    window: Optional[int] = None         # sliding-window size for local layers
    # layer pattern, cycled: "g"=global, "l"=local(window). gemma3 = 5 local : 1 global
    pattern: tuple[str, ...] = ("g",)
    mrope_sections: Optional[tuple[int, int, int]] = None   # qwen2-vl M-RoPE
    qk_norm: bool = False
    logit_soft_cap: Optional[float] = None


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                          # dense | moe | vlm | hybrid | audio | ssm
    n_layers: int
    d_model: int
    n_heads: int
    kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                    # 0 -> d_model // n_heads
    mlp: str = "swiglu"                  # swiglu | geglu | relu2 | gelu
    norm: str = "rms"                    # rms | ln
    attn: AttnCfg = field(default_factory=AttnCfg)
    moe: Optional[MoECfg] = None
    mla: Optional[MLACfg] = None
    mamba: Optional[MambaCfg] = None
    # hybrid (jamba): layer kinds cycled over n_layers, "a"=attention, "m"=mamba
    hybrid_pattern: Optional[tuple[str, ...]] = None
    # enc-dec (whisper)
    enc_dec: bool = False
    enc_layers: int = 0
    enc_frames: int = 1500               # stub frontend output length
    # vlm (qwen2-vl): number of stub patch embeddings prepended to the sequence
    vlm_patches: int = 0
    tie_embeddings: bool = False
    notes: str = ""

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // max(1, self.n_heads))

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    def layer_kinds(self) -> tuple[str, ...]:
        """Per-layer kind: 'a' (attention), 'm' (mamba)."""
        if self.family == "ssm":
            return tuple("m" for _ in range(self.n_layers))
        if self.hybrid_pattern:
            pat = self.hybrid_pattern
            return tuple(pat[i % len(pat)] for i in range(self.n_layers))
        return tuple("a" for _ in range(self.n_layers))

    def attn_kinds(self) -> tuple[str, ...]:
        """Per-attention-layer local/global pattern ('l' or 'g')."""
        pat = self.attn.pattern
        return tuple(pat[i % len(pat)] for i in range(self.n_layers))

    def moe_layers(self) -> tuple[bool, ...]:
        if self.moe is None:
            return tuple(False for _ in range(self.n_layers))
        m = self.moe
        return tuple(
            (i >= m.first_dense) and ((i - m.first_dense) % m.every == 0)
            for i in range(self.n_layers))


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode
    sub_quadratic_required: bool = False


TRAIN_4K = ShapeSpec("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeSpec("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeSpec("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeSpec("long_500k", 524288, 1, "decode", sub_quadratic_required=True)

SHAPES: dict[str, ShapeSpec] = {s.name: s for s in
                                (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}

ARCH_IDS = (
    "nemotron_4_15b",
    "deepseek_7b",
    "phi3_mini_3p8b",
    "gemma3_27b",
    "deepseek_moe_16b",
    "deepseek_v2_lite_16b",
    "qwen2_vl_7b",
    "jamba_v0_1_52b",
    "whisper_medium",
    "mamba2_1p3b",
)


def get_config(arch: str) -> ModelConfig:
    arch = arch.replace("-", "_").replace(".", "p")
    if arch not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    arch = arch.replace("-", "_").replace(".", "p")
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.smoke_config()


def supports_shape(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """Whether (arch, shape) is a runnable cell; (False, reason) when skipped.

    Skip rules (recorded in DESIGN.md §Arch-applicability):
      * long_500k needs sub-quadratic attention — run only for SSM / hybrid /
        local:global archs; skip for pure full-attention LMs.
      * whisper's decoder is bounded by its 1500-frame encoder; decode_32k is
        lowered with a 32k self-attention KV for comparability, but long_500k
        is architecturally meaningless for a 30s-audio enc-dec model.
    """
    if shape.sub_quadratic_required:
        if cfg.family in ("ssm", "hybrid"):
            return True, ""
        if any(k == "l" for k in cfg.attn.pattern) and cfg.attn.window:
            return True, "local:global attention keeps per-step work sub-quadratic-dominated"
        return False, "pure full-attention arch: 500k decode requires sub-quadratic attention"
    if cfg.enc_dec and shape.kind == "train" and shape.seq_len > 8192:
        return False, "whisper enc-dec trains on <=1500-frame windows"
    return True, ""
