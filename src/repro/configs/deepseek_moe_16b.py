"""DeepSeekMoE 16B [arXiv:2401.06066; hf]: 28L, d_model 2048, 16 heads
(kv=16), vocab 102400; fine-grained MoE: 64 routed experts (d_ff 1408)
top-6 + 2 shared experts; first layer dense (d_ff 10944)."""

from .base import AttnCfg, ModelConfig, MoECfg

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    kv_heads=16,
    d_ff=10944,                 # the single dense (first) layer
    vocab=102400,
    mlp="swiglu",
    norm="rms",
    attn=AttnCfg(rope_theta=10000.0),
    moe=MoECfg(num_experts=64, top_k=6, d_ff_expert=1408, num_shared=2,
               every=1, first_dense=1),
)


def smoke_config():
    return ModelConfig(
        name="dsmoe-smoke", family="moe", n_layers=3, d_model=64,
        n_heads=4, kv_heads=4, d_ff=128, vocab=512, mlp="swiglu", norm="rms",
        moe=MoECfg(num_experts=4, top_k=2, d_ff_expert=32, num_shared=1,
                   every=1, first_dense=1))
