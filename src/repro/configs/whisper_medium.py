"""Whisper-medium [arXiv:2212.04356; unverified]: enc-dec, 24+24 layers,
d_model 1024, 16 heads (MHA), d_ff 4096, vocab 51865; LayerNorm + GELU;
absolute (sinusoidal) positions, no RoPE.  The conv audio frontend is a STUB:
input_specs() provides precomputed frame embeddings [B, frames, d_model]."""

from .base import AttnCfg, ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="audio",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    kv_heads=16,
    d_ff=4096,
    vocab=51865,
    mlp="gelu",
    norm="ln",
    attn=AttnCfg(use_rope=False),
    enc_dec=True,
    enc_layers=24,
    enc_frames=1500,
    notes="decode_32k lowered with a 32k self-attn KV for cross-arch "
          "comparability; whisper's natural decoder ceiling is 448 tokens",
)


def smoke_config():
    return ModelConfig(
        name="whisper-smoke", family="audio", n_layers=2, d_model=64,
        n_heads=4, kv_heads=4, d_ff=128, vocab=512, mlp="gelu", norm="ln",
        attn=AttnCfg(use_rope=False), enc_dec=True, enc_layers=2,
        enc_frames=16)
