"""Mamba2-1.3B [arXiv:2405.21060; unverified]: 48L, d_model 2048, attention-
free SSD (state-space duality), d_state 128, vocab 50280; no separate MLP
(the mamba mixer is the whole block); tied embeddings.

Arch-applicability (DESIGN.md): the paper's paged multi-size KV technique has
no translated, growing address space to manage here — decode state is a fixed
[H, P, N] tensor — so the serving path uses plain state caching and the
eBPF-mm hook only manages the (fixed) state-buffer allocation.
"""

from .base import AttnCfg, MambaCfg, ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=32,              # unused (attention-free)
    kv_heads=32,
    d_ff=0,
    vocab=50280,
    mlp="swiglu",            # unused
    norm="rms",
    attn=AttnCfg(use_rope=False),
    mamba=MambaCfg(d_state=128, head_dim=64, expand=2, chunk=256, conv_dim=4),
    tie_embeddings=True,
)


def smoke_config():
    return ModelConfig(
        name="mamba2-smoke", family="ssm", n_layers=4, d_model=64,
        n_heads=4, kv_heads=4, d_ff=0, vocab=512, mlp="swiglu", norm="rms",
        attn=AttnCfg(use_rope=False),
        mamba=MambaCfg(d_state=16, head_dim=16, expand=2, chunk=8, conv_dim=4),
        tie_embeddings=True)
