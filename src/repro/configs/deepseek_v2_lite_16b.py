"""DeepSeek-V2-Lite 16B [arXiv:2405.04434; hf]: 27L, d_model 2048, 16 heads,
MLA (kv_lora 512, rope-dim 64), vocab 102400; MoE 64 routed (d_ff 1408)
top-6 + 2 shared, first layer dense (d_ff 10944).

Note: the assignment line says "2 shared+160 routed" in the comment but the
explicit config field is "MoE 64e top-6"; 64 routed matches the published
V2-Lite checkpoint (160 is full V2), so we use 64 — recorded in DESIGN.md.
"""

from .base import AttnCfg, MLACfg, ModelConfig, MoECfg

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    kv_heads=16,            # unused: MLA replaces GQA KV
    d_ff=10944,
    vocab=102400,
    mlp="swiglu",
    norm="rms",
    attn=AttnCfg(rope_theta=10000.0),
    mla=MLACfg(kv_lora=512, qk_nope=128, qk_rope=64, v_head=128),
    moe=MoECfg(num_experts=64, top_k=6, d_ff_expert=1408, num_shared=2,
               every=1, first_dense=1),
    notes="MLA latent cache (512+64 per token) makes even 500k-token KV "
          "small, but attention itself is full — long_500k skipped per rule",
)


def smoke_config():
    return ModelConfig(
        name="dsv2lite-smoke", family="moe", n_layers=3, d_model=64,
        n_heads=4, kv_heads=4, d_ff=128, vocab=512, mlp="swiglu", norm="rms",
        mla=MLACfg(kv_lora=32, qk_nope=16, qk_rope=8, v_head=16),
        moe=MoECfg(num_experts=4, top_k=2, d_ff_expert=32, num_shared=1,
                   every=1, first_dense=1))
