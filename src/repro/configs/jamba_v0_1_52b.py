"""Jamba-v0.1 52B [arXiv:2403.19887; hf]: 32L, d_model 4096, 32 heads
(GQA kv=8), d_ff 14336, vocab 65536; attention:mamba 1:7 interleave
(1 attention layer per 8, at position 4 of each block), MoE 16 experts top-2
every other layer.

Adaptation note (DESIGN.md): Jamba-v0.1 uses Mamba-1 layers; we implement the
SSM with our Mamba-2/SSD layer (d_state 16 as published) since SSD is the
TPU-friendly matmul formulation of the same selective-SSM family.
"""

from .base import AttnCfg, MambaCfg, ModelConfig, MoECfg

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    kv_heads=8,
    d_ff=14336,
    vocab=65536,
    mlp="swiglu",
    norm="rms",
    attn=AttnCfg(use_rope=False),    # jamba uses no positional encoding
    mamba=MambaCfg(d_state=16, head_dim=64, expand=2, chunk=256, conv_dim=4),
    moe=MoECfg(num_experts=16, top_k=2, d_ff_expert=14336, num_shared=0,
               every=2, first_dense=1),
    hybrid_pattern=("m", "m", "m", "m", "a", "m", "m", "m"),
)


def smoke_config():
    return ModelConfig(
        name="jamba-smoke", family="hybrid", n_layers=8, d_model=64,
        n_heads=4, kv_heads=2, d_ff=128, vocab=512, mlp="swiglu", norm="rms",
        attn=AttnCfg(use_rope=False),
        mamba=MambaCfg(d_state=16, head_dim=16, expand=2, chunk=8, conv_dim=4),
        moe=MoECfg(num_experts=4, top_k=2, d_ff_expert=64, num_shared=0,
                   every=2, first_dense=1),
        hybrid_pattern=("m", "m", "m", "m", "a", "m", "m", "m"))
