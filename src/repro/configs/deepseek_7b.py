"""DeepSeek-LLM 7B [arXiv:2401.02954; hf]: llama-arch, 30L, d_model 4096,
32 heads (MHA: kv=32), d_ff 11008, vocab 102400, SwiGLU, RMSNorm, RoPE."""

from .base import AttnCfg, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-7b",
    family="dense",
    n_layers=30,
    d_model=4096,
    n_heads=32,
    kv_heads=32,
    d_ff=11008,
    vocab=102400,
    mlp="swiglu",
    norm="rms",
    attn=AttnCfg(rope_theta=10000.0),
)


def smoke_config():
    return ModelConfig(
        name="deepseek-7b-smoke", family="dense", n_layers=3, d_model=64,
        n_heads=4, kv_heads=4, d_ff=128, vocab=512, mlp="swiglu", norm="rms")
