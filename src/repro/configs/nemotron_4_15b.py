"""Nemotron-4 15B [arXiv:2402.16819; unverified]: 32L, d_model 6144, 48 heads
(GQA kv=8), d_ff 24576, vocab 256000; squared-ReLU MLP (no GLU), RoPE."""

from .base import AttnCfg, ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b",
    family="dense",
    n_layers=32,
    d_model=6144,
    n_heads=48,
    kv_heads=8,
    d_ff=24576,
    vocab=256000,
    mlp="relu2",
    norm="ln",              # nemotron-4 uses LayerNorm
    attn=AttnCfg(rope_theta=10000.0),
    notes="GQA kv=8; squared-ReLU non-gated MLP",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="nemotron-4-smoke", family="dense", n_layers=4, d_model=64,
        n_heads=8, kv_heads=2, d_ff=160, vocab=512, mlp="relu2", norm="ln",
        attn=AttnCfg(rope_theta=10000.0))
