"""Architecture configs (one module per assigned arch) + shape sets."""

from .base import (ARCH_IDS, SHAPES, AttnCfg, MambaCfg, MLACfg, ModelConfig,
                   MoECfg, ShapeSpec, get_config, get_smoke_config,
                   supports_shape)

__all__ = ["ARCH_IDS", "SHAPES", "AttnCfg", "MambaCfg", "MLACfg",
           "ModelConfig", "MoECfg", "ShapeSpec", "get_config",
           "get_smoke_config", "supports_shape"]
