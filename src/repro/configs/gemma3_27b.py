"""Gemma-3 27B [hf:google/gemma-3-1b-pt; unverified]: 62L, d_model 5376,
32 heads (GQA kv=16), d_ff 21504, vocab 262144; 5 local (sliding-window 1024)
: 1 global layer pattern; GeGLU; QK-norm; 128k context."""

from .base import AttnCfg, ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b",
    family="dense",
    n_layers=62,
    d_model=5376,
    n_heads=32,
    kv_heads=16,
    d_ff=21504,
    vocab=262144,
    mlp="geglu",
    norm="rms",
    attn=AttnCfg(rope_theta=1_000_000.0, window=1024,
                 pattern=("l", "l", "l", "l", "l", "g"), qk_norm=True),
    notes="5:1 local:global; local layers use a 1024-token sliding window, "
          "which keeps long_500k decode reads bounded for 52/62 layers",
)


def smoke_config():
    return ModelConfig(
        name="gemma3-smoke", family="dense", n_layers=6, d_model=64,
        n_heads=4, kv_heads=2, d_ff=128, vocab=512, mlp="geglu", norm="rms",
        attn=AttnCfg(window=8, pattern=("l", "l", "g"), qk_norm=True))
