"""Phi-3-mini 3.8B [arXiv:2404.14219; unverified]: 32L, d_model 3072,
32 heads (kv=32), d_ff 8192, vocab 32064, RoPE + SwiGLU."""

from .base import AttnCfg, ModelConfig

CONFIG = ModelConfig(
    name="phi3-mini-3.8b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    kv_heads=32,
    d_ff=8192,
    vocab=32064,
    mlp="swiglu",
    norm="rms",
    attn=AttnCfg(rope_theta=10000.0),
)


def smoke_config():
    return ModelConfig(
        name="phi3-smoke", family="dense", n_layers=3, d_model=48,
        n_heads=4, kv_heads=4, d_ff=96, vocab=512, mlp="swiglu", norm="rms")
