"""Data pipeline: deterministic synthetic LM token streams (Zipf-ish unigram
mixture with local n-gram structure so losses actually decrease), packing,
and per-arch batch assembly (frames/patches/pos3d for the modality stubs).

At scale each data-parallel host reads only its shard (shard_index /
num_shards), exactly like a real tokenized-corpus loader; the synthetic
generator keeps the framework end-to-end runnable offline.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig


@dataclass
class SyntheticLMDataset:
    vocab: int
    seq_len: int
    seed: int = 0
    shard_index: int = 0
    num_shards: int = 1
    zipf_a: float = 1.2

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        # fixed bigram transition structure -> learnable signal
        self._next = rng.integers(0, self.vocab, size=self.vocab)
        ranks = np.arange(1, self.vocab + 1, dtype=np.float64)
        p = 1.0 / np.power(ranks, self.zipf_a)
        self._p = p / p.sum()

    def sample(self, batch: int, step: int) -> np.ndarray:
        """[batch, seq_len+1] int32 tokens (inputs+labels)."""
        rng = np.random.default_rng(
            (self.seed, step, self.shard_index))
        S = self.seq_len + 1
        toks = np.empty((batch, S), np.int32)
        toks[:, 0] = rng.choice(self.vocab, size=batch, p=self._p)
        noise = rng.random((batch, S))
        rand = rng.choice(self.vocab, size=(batch, S), p=self._p)
        for t in range(1, S):
            follow = self._next[toks[:, t - 1]]
            toks[:, t] = np.where(noise[:, t] < 0.75, follow, rand[:, t])
        return toks


def synthetic_batch(cfg: ModelConfig, batch: int, seq_len: int, step: int,
                    *, seed: int = 0, shard_index: int = 0,
                    num_shards: int = 1) -> dict:
    ds = SyntheticLMDataset(cfg.vocab, seq_len, seed=seed,
                            shard_index=shard_index, num_shards=num_shards)
    out = {"tokens": jnp.asarray(ds.sample(batch, step))}
    rng = np.random.default_rng((seed + 1, step))
    if cfg.enc_dec:
        out["frames"] = jnp.asarray(
            rng.normal(size=(batch, cfg.enc_frames, cfg.d_model))
            .astype(np.float32))
    if cfg.vlm_patches:
        out["patches"] = jnp.asarray(
            rng.normal(size=(batch, cfg.vlm_patches, cfg.d_model))
            .astype(np.float32))
        # text follows the patch grid: t = position, h/w = patch grid coords
        pos = np.tile(np.arange(seq_len, dtype=np.float32), (3, batch, 1))
        side = max(1, int(np.sqrt(cfg.vlm_patches)))
        grid = np.arange(cfg.vlm_patches)
        pos[1, :, :cfg.vlm_patches] = grid // side
        pos[2, :, :cfg.vlm_patches] = grid % side
        pos[0, :, :cfg.vlm_patches] = 0
        out["pos3d"] = jnp.asarray(pos)
    return out


def batch_specs_for(cfg: ModelConfig, batch: int, seq_len: int,
                    *, train: bool = True) -> dict:
    """ShapeDtypeStructs for one batch — used by the dry-run input_specs."""
    S = seq_len + 1 if train else seq_len
    specs = {"tokens": jax.ShapeDtypeStruct((batch, S), jnp.int32)}
    if cfg.enc_dec:
        specs["frames"] = jax.ShapeDtypeStruct(
            (batch, cfg.enc_frames, cfg.d_model), jnp.float32)
    if cfg.vlm_patches:
        specs["patches"] = jax.ShapeDtypeStruct(
            (batch, cfg.vlm_patches, cfg.d_model), jnp.float32)
        specs["pos3d"] = jax.ShapeDtypeStruct((3, batch, seq_len), jnp.float32)
    return specs


def make_batch_iter(cfg: ModelConfig, batch: int, seq_len: int, *,
                    seed: int = 0, shard_index: int = 0,
                    num_shards: int = 1) -> Iterator[dict]:
    step = 0
    while True:
        yield synthetic_batch(cfg, batch, seq_len, step, seed=seed,
                              shard_index=shard_index, num_shards=num_shards)
        step += 1
