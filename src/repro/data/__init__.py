from .pipeline import (SyntheticLMDataset, batch_specs_for, make_batch_iter,
                       synthetic_batch)

__all__ = ["SyntheticLMDataset", "batch_specs_for", "make_batch_iter",
           "synthetic_batch"]
