"""ebpf-mm-jax: userspace-guided multi-size paged memory management for a
JAX training/serving framework.

Reproduction (and TPU-native extension) of:
  "eBPF-mm: Userspace-guided memory management in Linux with eBPF"
  K. Mores, S. Psomadakis, G. Goumas — NTUA, 2024.

Subpackages:
  core/        the paper's contribution: policy VM + verifier, profiles,
               DAMON monitor, cost model, buddy pool, memory manager
  kernels/     Pallas TPU kernels (paged attention, flash attention, block copy)
  models/      the 10 assigned architectures as pure-JAX modules
  configs/     one config per architecture + input-shape sets
  serving/     continuous-batching engine with eBPF-mm paged KV cache
  training/    train step, mixed precision, remat, microbatching
  optim/       AdamW + schedules
  data/        token pipeline
  checkpoint/  sharded save/restore + elastic resharding
  distributed/ sharding rules, gradient compression, fault tolerance
  launch/      production mesh, multi-pod dry-run, train/serve CLIs
"""

__version__ = "0.1.0"
