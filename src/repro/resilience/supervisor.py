"""Policy-program supervision: misbehavior accounting and auto-detach.

The verifier (load time) proves a program terminates and touches only
valid state; the supervisor (run time) is the other half of the kernel's
containment story — a program that KEEPS misbehaving (invalid return
values, runtime/helper errors, ring-slot exhaustion streaks, repeated
segment-budget blowups) is detached after a strike threshold and the
manager falls back to the kernel-default THP policy.  The engine keeps
serving; an ``EV_DETACH`` event and ``engine.metrics()`` counters record
the incident.

Determinism contract (chaos differential): strikes accrue in ROW ORDER.
The batched route disciplines its decision vector sequentially, mirroring
the order the scalar route would have invoked the program, so both routes
strike, fall back and detach at the same fault.  A striking row's decision
becomes ``POLICY_FALLBACK`` (kernel default + fallback accounting); rows
AFTER a mid-batch detach become ``POLICY_DETACHED`` — the kernel default
path with NO fallback accounting, matching the scalar route where
post-detach faults never reach the hook at all.

Known route asymmetry (documented, not hidden): ring-slot drop streaks are
observed per CALL — one scalar invocation vs one whole batch — so a
drop-heavy tracing program can strike at different faults on the two
routes.  The chaos differential therefore runs non-tracing programs; the
drop discipline is covered by its own unit tests.
"""

from __future__ import annotations

from dataclasses import dataclass, field

# Mirrors ``repro.core.context.POLICY_FALLBACK``.  Kept as a literal here —
# NOT imported — because ``core.hooks`` imports this module at class-define
# time, so an import edge back into ``core`` would be circular whenever
# ``repro.resilience`` loads first.  ``core.hooks`` asserts the two values
# agree at import time.
POLICY_FALLBACK = -1

REASON_INVALID_RETURN = 0    # return outside the hook's contract
REASON_RUNTIME_ERROR = 1     # injected fault or exception during execution
REASON_RB_EXHAUSTION = 2     # ring-slot drop streak
REASON_SEGMENT_BLOWUP = 3    # predicated unroll over budget at build time
REASON_NAMES = ("invalid_return", "runtime_error", "rb_exhaustion",
                "segment_blowup")

DETACH_THRESHOLD = 8         # strikes before auto-detach
RB_STREAK_LIMIT = 4          # consecutive dropping calls = one strike

# Return validity: the mm clamps OVER-range decisions into each hook's
# contract (order to the feasible max, tier/victim index into range) — the
# kernel's long-standing clamp convention, which synthetic stress programs
# rely on.  What a program must NEVER produce is a value BELOW the
# POLICY_FALLBACK sentinel: that range is reserved for the manager's own
# sentinels (POLICY_FALLBACK, POLICY_DETACHED) and a program emitting it
# would be misread as one.  Those strike as invalid returns.


@dataclass
class HookDiscipline:
    """Per-hook strike ledger."""
    strikes: int = 0
    reasons: list = field(default_factory=lambda: [0] * len(REASON_NAMES))
    rb_streak: int = 0
    detaches: int = 0
    last_detach_reason: int = -1
    last_program: str = ""


class PolicySupervisor:
    """Strike accounting + detach decisions for every hook.

    ``enabled`` False is the no-containment baseline: strikes are still
    counted (visible in metrics) but no detach ever fires.
    """

    def __init__(self, *, threshold: int = DETACH_THRESHOLD,
                 rb_streak_limit: int = RB_STREAK_LIMIT,
                 enabled: bool = True):
        self.threshold = int(threshold)
        self.rb_streak_limit = int(rb_streak_limit)
        self.enabled = bool(enabled)
        self._state: dict = {}

    def _st(self, hook: str) -> HookDiscipline:
        st = self._state.get(hook)
        if st is None:
            st = self._state[hook] = HookDiscipline()
        return st

    def valid(self, hook: str, decision: int) -> bool:
        return decision >= POLICY_FALLBACK

    def strike(self, hook: str, reason: int) -> bool:
        """Record one strike; True when the threshold is crossed and the
        caller must detach the program NOW."""
        st = self._st(hook)
        st.strikes += 1
        st.reasons[reason] += 1
        if not self.enabled:
            return False
        return st.strikes >= self.threshold

    def note_segment_blowup(self, hook: str) -> None:
        """A predicated build blew the segment budget.  Counts toward the
        strike total but never detaches by itself — the compiler already
        degrades gracefully (while+switch JIT fallback)."""
        st = self._st(hook)
        st.strikes += 1
        st.reasons[REASON_SEGMENT_BLOWUP] += 1

    def note_rb_drops(self, hook: str, drops: int) -> bool:
        """One call dropped ring events.  ``rb_streak_limit`` CONSECUTIVE
        dropping calls convert into one RB_EXHAUSTION strike (streak then
        resets); isolated drops are normal backpressure, a streak means the
        program is sized wrong for its slot budget."""
        if drops <= 0:
            return False
        st = self._st(hook)
        st.rb_streak += 1
        if st.rb_streak < self.rb_streak_limit:
            return False
        st.rb_streak = 0
        return True

    def note_rb_clean(self, hook: str) -> None:
        st = self._state.get(hook)
        if st is not None and st.rb_streak:
            st.rb_streak = 0

    def record_detach(self, hook: str, reason: int, program: str) -> dict:
        st = self._st(hook)
        st.detaches += 1
        st.last_detach_reason = reason
        st.last_program = program
        return {"strikes": st.strikes, "detaches": st.detaches}

    def reset(self, hook: str) -> None:
        """A fresh attach starts with a clean ledger (lifetime detach count
        survives, like the kernel's cumulative stats)."""
        st = self._state.get(hook)
        if st is None:
            return
        detaches, last = st.detaches, st.last_detach_reason
        self._state[hook] = HookDiscipline(detaches=detaches,
                                           last_detach_reason=last)

    def snapshot(self) -> dict:
        """Numeric-only per-hook ledger for ``engine.metrics()``."""
        out = {"enabled": self.enabled, "threshold": self.threshold}
        total_detaches = 0
        for hook, st in sorted(self._state.items()):
            total_detaches += st.detaches
            out[hook] = {
                "strikes": st.strikes,
                "detaches": st.detaches,
                "rb_streak": st.rb_streak,
                "last_detach_reason": st.last_detach_reason,
            }
            for i, name in enumerate(REASON_NAMES):
                out[hook][name] = st.reasons[i]
        out["detaches"] = total_detaches
        return out
