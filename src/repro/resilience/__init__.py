"""Failure containment: seeded fault injection, tier-link health with
exponential-backoff quarantine, and runtime policy-program supervision.

The kernel's eBPF story is verifier + runtime containment; PR 4 built the
verifier half, this package is the other half — a misbehaving program,
link, or tier degrades the system, never crashes it.  Everything is keyed
on the MODELED clock so seeded failure schedules replay bit-identically
across the scalar/batched fault routes and all three executors.
"""

from .faults import (FLAP_WINDOW_NS, SITE_CACHE_CORRUPT, SITE_HOOK_RUN,
                     SITE_LINK_FLAP, SITE_MIGRATE_COPY, SITE_TIER_ALLOC,
                     SITES, FailureInjector)
from .health import (BACKOFF_BASE_NS, BACKOFF_MAX_LEVEL,
                     QUARANTINE_THRESHOLD, BackoffState, TierHealthMonitor)
from .supervisor import (DETACH_THRESHOLD, RB_STREAK_LIMIT,
                         REASON_INVALID_RETURN, REASON_NAMES,
                         REASON_RB_EXHAUSTION, REASON_RUNTIME_ERROR,
                         REASON_SEGMENT_BLOWUP, HookDiscipline,
                         PolicySupervisor)

__all__ = [
    "FailureInjector", "SITES", "SITE_MIGRATE_COPY", "SITE_TIER_ALLOC",
    "SITE_LINK_FLAP", "SITE_HOOK_RUN", "SITE_CACHE_CORRUPT",
    "FLAP_WINDOW_NS",
    "BackoffState", "TierHealthMonitor", "QUARANTINE_THRESHOLD",
    "BACKOFF_BASE_NS", "BACKOFF_MAX_LEVEL",
    "PolicySupervisor", "HookDiscipline", "DETACH_THRESHOLD",
    "RB_STREAK_LIMIT", "REASON_INVALID_RETURN",
    "REASON_RUNTIME_ERROR", "REASON_RB_EXHAUSTION", "REASON_SEGMENT_BLOWUP",
    "REASON_NAMES",
]
