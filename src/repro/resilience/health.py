"""Tier-link health: per-edge error accounting with exponential-backoff
quarantine and probe-based re-admission.

Mirrors how a kernel would treat a flaky interconnect path: an edge that
keeps failing copies is marked degraded and traffic routes around it (the
multi-hop migration path already hops over full intermediates; a
quarantined edge is skipped the same way).  All timing is in MODELED
nanoseconds (the mm clock), never wall time, so the state machine replays
exactly under the differential harness.

State machine per edge (``BackoffState``):

* healthy — errors below ``threshold`` consecutive just count.
* quarantined — ``threshold`` consecutive errors (or any error while
  degraded) set ``quarantined_until = now + base_ns << level`` and bump
  the level (capped); ``ok(now)`` is False until the window expires.
* probing — once the window expires the next attempt is the probe: a
  probe failure re-quarantines with a doubled window; a success decays
  one level, and reaching level 0 re-admits the edge.
"""

from __future__ import annotations

from dataclasses import dataclass, field

QUARANTINE_THRESHOLD = 3       # consecutive errors before first quarantine
BACKOFF_BASE_NS = 8_000_000    # first quarantine window (8 modeled ticks)
BACKOFF_MAX_LEVEL = 6          # window caps at base << 6 = 512ms modeled


@dataclass
class BackoffState:
    threshold: int = QUARANTINE_THRESHOLD
    base_ns: int = BACKOFF_BASE_NS
    max_level: int = BACKOFF_MAX_LEVEL
    consec_errors: int = 0
    level: int = 0
    quarantined_until: int = -1
    errors: int = 0
    successes: int = 0
    quarantines: int = 0
    readmits: int = 0

    def backoff_ns(self) -> int:
        return self.base_ns << min(self.level, self.max_level)

    def ok(self, now: int) -> bool:
        """Usable at ``now``?  True once the window expires (the probe)."""
        return now >= self.quarantined_until

    def record_error(self, now: int) -> bool:
        """Count one failure; returns True when this NEWLY quarantines the
        edge (callers emit EV_QUARANTINE exactly then)."""
        self.errors += 1
        self.consec_errors += 1
        if self.level == 0 and self.consec_errors < self.threshold:
            return False
        newly = self.quarantined_until <= now
        self.quarantined_until = now + self.backoff_ns()
        self.level = min(self.level + 1, self.max_level)
        if newly:
            self.quarantines += 1
        return newly

    def record_success(self, now: int) -> bool:
        """Count one success; a successful probe decays one level.  Returns
        True when the edge is fully re-admitted (level back to 0)."""
        self.successes += 1
        self.consec_errors = 0
        if self.level == 0:
            return False
        self.level -= 1
        if self.level == 0:
            self.quarantined_until = -1
            self.readmits += 1
            return True
        return False


class TierHealthMonitor:
    """Per-edge link health + per-tier allocation-failure accounting.

    Edge ``e`` is the link between tier ``e`` and tier ``e+1`` (same
    numbering as ``CostModel.edge_names()``).  The ``active`` flag flips on
    the first recorded error; until then every query short-circuits True so
    a failure-free run pays one attribute read per migration hop.
    ``quarantine`` False (the no-containment baseline) keeps the error
    counters but never routes around a degraded edge.
    """

    def __init__(self, nedges: int, edge_names=None, *,
                 quarantine: bool = True):
        self.edges = [BackoffState() for _ in range(max(0, nedges))]
        self.edge_names = tuple(edge_names) if edge_names else tuple(
            f"edge{i}" for i in range(max(0, nedges)))
        self.quarantine_enabled = bool(quarantine)
        self.tier_alloc_failures = [0] * (max(0, nedges) + 1)
        self.active = False

    def edge_ok(self, edge: int, now: int) -> bool:
        if not self.active or not self.quarantine_enabled:
            return True
        return self.edges[edge].ok(now)

    def path_ok(self, src_tier: int, dst_tier: int, now: int) -> bool:
        """Every edge crossed moving a page src->dst is usable at ``now``."""
        if not self.active or not self.quarantine_enabled:
            return True
        lo, hi = sorted((src_tier, dst_tier))
        return all(self.edges[e].ok(now) for e in range(lo, hi))

    def record_edge_error(self, edge: int, now: int) -> bool:
        self.active = True
        return self.edges[edge].record_error(now)

    def record_edge_success(self, edge: int, now: int) -> bool:
        if not self.active:
            return False
        return self.edges[edge].record_success(now)

    def record_alloc_failure(self, tier: int) -> None:
        self.active = True
        self.tier_alloc_failures[tier] += 1

    def quarantined_edges(self, now: int) -> list:
        return [e for e, st in enumerate(self.edges) if not st.ok(now)]

    def snapshot(self) -> dict:
        """Numeric-only per-edge accounting for ``engine.metrics()``."""
        out = {"alloc_failures": list(self.tier_alloc_failures),
               "quarantine_enabled": self.quarantine_enabled}
        for e, st in enumerate(self.edges):
            name = (self.edge_names[e] if e < len(self.edge_names)
                    else f"edge{e}")
            out[name] = {
                "errors": st.errors, "successes": st.successes,
                "quarantines": st.quarantines, "readmits": st.readmits,
                "level": st.level,
                "quarantined_until": st.quarantined_until,
            }
        return out
