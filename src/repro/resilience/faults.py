"""Seeded, deterministic fault injection.

The injector is the chaos half of the resilience layer: it decides, at
named sites threaded through the stack, whether this particular operation
fails.  Two properties are load-bearing:

* **Deterministic replay.**  Every decision is a pure function of
  ``(seed, site, key...)`` via a splitmix64-style stateless PRF — no wall
  time, no mutable RNG stream.  Two replicas that reach the same site with
  the same key (pid/addr/edge/modeled ktime/attempt) see the same fault,
  regardless of call ORDER, so the scalar and batched fault paths replay
  an identical failure schedule and the differential harness can assert
  bit-identical end state.
* **Zero cost when disarmed.**  A site with no configured rate returns
  ``False`` after one dict probe; an absent injector (``None``) costs a
  single ``is None`` check at each site.  The telemetry-overhead CI gate
  holds the disabled layer within 2% of baseline steps/s.

Sites:

========================  ====================================================
``SITE_MIGRATE_COPY``     one migration-hop copy attempt fails on an edge
``SITE_TIER_ALLOC``       a per-tier buddy allocation transiently fails
``SITE_LINK_FLAP``        a tier link (ICI/PCIe/NVMe) is down for a whole
                          modeled-time window — keyed on ``ktime // window``
                          so every attempt inside the window fails together
``SITE_HOOK_RUN``         a hook program invocation hits a runtime error
``SITE_CACHE_CORRUPT``    a pickled compiler artifact reads back corrupt
========================  ====================================================
"""

from __future__ import annotations

_MASK64 = (1 << 64) - 1

SITE_MIGRATE_COPY = "migrate_copy"
SITE_TIER_ALLOC = "tier_alloc"
SITE_LINK_FLAP = "link_flap"
SITE_HOOK_RUN = "hook_run"
SITE_CACHE_CORRUPT = "cache_corrupt"

SITES = (SITE_MIGRATE_COPY, SITE_TIER_ALLOC, SITE_LINK_FLAP,
         SITE_HOOK_RUN, SITE_CACHE_CORRUPT)
_SITE_ID = {s: i + 1 for i, s in enumerate(SITES)}

# Default modeled-time width of one link-flap outage window.  4 engine
# ticks at the default 1ms tick: a flap takes the link down long enough to
# exhaust a bounded retry and trip the health monitor's backoff.
FLAP_WINDOW_NS = 4_000_000


def _fold(word) -> int:
    """Map one key word (int or str) to a 64-bit lattice point.

    Strings fold byte-by-byte (NOT python ``hash()``, which is salted per
    process and would break cross-process replay)."""
    if isinstance(word, str):
        h = 0
        for b in word.encode():
            h = (h * 131 + b) & _MASK64
        return h
    return int(word) & _MASK64


def _mix(*words) -> int:
    """splitmix64-style stateless PRF over a tuple of 64-bit words."""
    h = 0x9E3779B97F4A7C15
    for w in words:
        h = (h + w + 0x9E3779B97F4A7C15) & _MASK64
        h ^= h >> 30
        h = (h * 0xBF58476D1CE4E5B9) & _MASK64
        h ^= h >> 27
        h = (h * 0x94D049BB133111EB) & _MASK64
        h ^= h >> 31
    return h


class FailureInjector:
    """Per-site seeded failure schedule with hit/check accounting.

    ``rates`` maps site name -> probability in [0, 1]; sites absent from
    the dict (or at rate 0) never fire and cost one dict probe per check.
    """

    def __init__(self, seed: int = 0, rates: dict | None = None, *,
                 flap_window_ns: int = FLAP_WINDOW_NS):
        unknown = set(rates or ()) - set(SITES)
        if unknown:
            raise ValueError(f"unknown failure sites: {sorted(unknown)}")
        self.seed = int(seed)
        self.rates = {s: float(r) for s, r in (rates or {}).items()
                      if float(r) > 0.0}
        self.flap_window_ns = int(flap_window_ns)
        self.checks = {s: 0 for s in SITES}
        self.fired = {s: 0 for s in SITES}

    @classmethod
    def uniform(cls, seed: int, rate: float,
                sites: tuple = SITES, **kw) -> "FailureInjector":
        """One rate across ``sites`` — the `--chaos SEED` convenience."""
        return cls(seed, {s: rate for s in sites}, **kw)

    @property
    def armed(self) -> bool:
        return bool(self.rates)

    def site_armed(self, site: str) -> bool:
        return site in self.rates

    def fires(self, site: str, *key) -> bool:
        """Does the operation identified by ``key`` fail at ``site``?

        Pure in (seed, site, key): re-asking with the same key gives the
        same answer (callers that must re-check — e.g. the batched fault
        discipline pass mirroring the scalar route — stay consistent).
        Check/fire counters are for reporting only.
        """
        rate = self.rates.get(site)
        if not rate:
            return False
        self.checks[site] += 1
        u = _mix(self.seed, _SITE_ID[site], *[_fold(w) for w in key])
        hit = u < rate * 2.0**64
        if hit:
            self.fired[site] += 1
        return hit

    def link_down(self, edge: int, now_ns: int) -> bool:
        """Is the tier link ``edge`` inside an injected outage window?

        Windowed on modeled time: every check within the same
        ``flap_window_ns`` window agrees, so a flap looks like a transient
        outage (repeated retry failures), not i.i.d. noise.
        """
        return self.fires(SITE_LINK_FLAP, edge, now_ns // self.flap_window_ns)

    def snapshot(self) -> dict:
        """Numeric-only accounting (safe for ``flatten_metrics``)."""
        out = {"seed": self.seed, "flap_window_ns": self.flap_window_ns}
        for s in SITES:
            out[s] = {"rate": self.rates.get(s, 0.0),
                      "checks": self.checks[s], "fired": self.fired[s]}
        return out
