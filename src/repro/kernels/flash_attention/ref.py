"""Pure-jnp oracle for the prefill/training flash attention kernel."""

from __future__ import annotations

import math

import jax.numpy as jnp
import jax

F32 = jnp.float32
NEG_INF = -1e30


def mha_ref(q, k, v, *, causal: bool = True, window: int | None = None,
            soft_cap: float | None = None):
    """q: [B,Sq,H,hd]; k,v: [B,Sk,KVH,hd] -> [B,Sq,H,hd] (naive O(S^2))."""
    B, Sq, H, hd = q.shape
    _, Sk, KVH, _ = k.shape
    G = H // KVH
    scale = 1.0 / math.sqrt(hd)
    qg = q.reshape(B, Sq, KVH, G, hd).astype(F32)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k.astype(F32)) * scale
    if soft_cap is not None:
        s = soft_cap * jnp.tanh(s / soft_cap)
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bkgqd", p, v.astype(F32))
    return out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, hd).astype(q.dtype)
