"""jit'd wrapper for the flash attention forward kernel."""

from __future__ import annotations

import functools

import jax

from .kernel import flash_attention_fwd


@functools.partial(jax.jit, static_argnames=("causal", "window", "soft_cap",
                                             "bq", "bk", "interpret"))
def flash_attention(q, k, v, *, causal=True, window=None, soft_cap=None,
                    bq=256, bk=256, interpret=False):
    return flash_attention_fwd(q, k, v, causal=causal, window=window,
                               soft_cap=soft_cap, bq=bq, bk=bk,
                               interpret=interpret)
