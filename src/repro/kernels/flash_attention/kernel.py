"""Pallas TPU kernel: causal/windowed GQA flash attention (forward).

Grid (B, H, nQ, nK), nK innermost; flash state (m, l, unnormalized acc) lives
in revisited output blocks; the final nK step normalizes.  Causal and
out-of-window K blocks are skipped entirely (the flash block-skip), so local
attention layers (gemma-3's 5:1 pattern) only pay for the window.

Block sizes default to (bq, bk) = (256, 256) with hd padded by Pallas to lane
width; MXU work per step is [bq, hd] x [hd, bk] -> [bq, bk].
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

F32 = jnp.float32
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, *,
            bq: int, bk: int, nk: int, head_dim: int, causal: bool,
            window: int | None, soft_cap: float | None, seq_k: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    scale = 1.0 / math.sqrt(head_dim)

    @pl.when(ik == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = iq * bq
    k_start = ik * bk
    # static-shape block skip predicates (traced on grid indices)
    relevant = jnp.asarray(True)
    if causal:
        relevant &= k_start <= q_start + bq - 1
    if window is not None:
        relevant &= k_start + bk - 1 > q_start - window

    @pl.when(relevant)
    def _compute():
        q = q_ref[0, 0].astype(F32) * scale                   # [bq, hd]
        k = k_ref[0, 0].astype(F32)                            # [bk, hd]
        v = v_ref[0, 0].astype(F32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=F32)    # [bq, bk]
        if soft_cap is not None:
            s = soft_cap * jnp.tanh(s / soft_cap)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = kpos < seq_k
        if causal:
            mask &= qpos >= kpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[0, 0]                                   # [bq]
        l_prev = l_ref[0, 0]
        acc_prev = o_ref[0, 0].astype(F32)
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
        corr = jnp.where(m_prev <= NEG_INF / 2, 0.0, jnp.exp(m_prev - m_new))
        l_new = l_prev * corr + p.sum(-1)
        acc_new = acc_prev * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=F32)
        m_ref[0, 0] = m_new
        l_ref[0, 0] = l_new
        o_ref[0, 0] = acc_new.astype(o_ref.dtype)

    @pl.when(ik == nk - 1)
    def _finalize():
        l = l_ref[0, 0]
        o_ref[0, 0] = (o_ref[0, 0].astype(F32)
                       / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, *, causal: bool = True,
                        window: int | None = None,
                        soft_cap: float | None = None,
                        bq: int = 256, bk: int = 256,
                        interpret: bool = False):
    """q: [B,Sq,H,hd]; k,v: [B,Sk,KVH,hd] -> [B,Sq,H,hd]."""
    B, Sq, H, hd = q.shape
    _, Sk, KVH, _ = k.shape
    G = H // KVH
    bq = min(bq, Sq)
    bk = min(bk, Sk)
    # layout: head-major for clean 2D blocks
    qt = q.transpose(0, 2, 1, 3)                  # [B,H,Sq,hd]
    kt = k.transpose(0, 2, 1, 3)                  # [B,KVH,Sk,hd]
    vt = v.transpose(0, 2, 1, 3)
    pad_q = (-Sq) % bq
    pad_k = (-Sk) % bk
    if pad_q:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    nq = qt.shape[2] // bq
    nk = kt.shape[2] // bk

    kern = functools.partial(_kernel, bq=bq, bk=bk, nk=nk, head_dim=hd,
                             causal=causal, window=window, soft_cap=soft_cap,
                             seq_k=Sk)
    out, m, l = pl.pallas_call(
        kern,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j: (b, h // G, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bq), lambda b, h, i, j: (b, h, i)),
            pl.BlockSpec((1, 1, bq), lambda b, h, i, j: (b, h, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, nq * bq, hd), F32),
            jax.ShapeDtypeStruct((B, H, nq * bq), F32),
            jax.ShapeDtypeStruct((B, H, nq * bq), F32),
        ],
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
    )(qt, kt, vt)
    out = out[:, :, :Sq].transpose(0, 2, 1, 3)
    return out.astype(q.dtype)
