"""Pallas TPU kernel: multi-size paged flash-decoding, one size class per call.

Grid: (B, MP) — one program per (sequence, class-page).  The page table and
logical indices ride in scalar-prefetch memory so the BlockSpec index map can
steer the K/V DMA straight at the page's pool rows: a class-c page is
4^c buddy-ALIGNED consecutive pool blocks, so its whole K/V arrives in ONE
contiguous VMEM copy of (4^c * block_tokens) tokens.  This is the TPU-native
payoff of the paper's huge pages: one descriptor + one large contiguous DMA
per page instead of 4^c small ones (cf. TLB reach), and per-page transfer
size is what drives effective HBM bandwidth.

Flash state (m, l, acc) lives in revisited output blocks (index maps constant
in j, the innermost grid dim), initialized at j == 0 — the standard Pallas
reduction pattern.  The kernel also emits per-page attention mass ("heat"),
the DAMON signal; heat is normalized against the RUNNING max at visit time
(exact mass needs a second pass; DAMON only consumes relative heat — see
ref.paged_class_heat_running_ref which mirrors this semantics exactly).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

F32 = jnp.float32
NEG_INF = -1e30


def _kernel(table_ref, logical_ref, len_ref,      # scalar prefetch
            q_ref, k_ref, v_ref,                  # VMEM inputs
            acc_ref, m_ref, l_ref, heat_ref,      # VMEM outputs (revisited)
            *, page_blocks: int, block_tokens: int, kv_heads: int,
            q_heads: int, head_dim: int, window: int | None):
    b = pl.program_id(0)
    j = pl.program_id(1)
    pt = page_blocks * block_tokens
    G = q_heads // kv_heads
    scale = 1.0 / math.sqrt(head_dim)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    page_ok = table_ref[b, j] >= 0

    @pl.when(page_ok)
    def _compute():
        q = q_ref[0].astype(F32) * scale                     # [H, hd]
        qg = q.reshape(kv_heads, G, head_dim)
        k = k_ref[...].astype(F32).reshape(pt, kv_heads, head_dim)
        v = v_ref[...].astype(F32).reshape(pt, kv_heads, head_dim)
        s = jax.lax.dot_general(
            qg, k, (((2,), (2,)), ((0,), (1,))),
            preferred_element_type=F32)                      # [KVH, G, pt]

        length = len_ref[b]
        pos = logical_ref[b, j] * pt + jax.lax.broadcasted_iota(
            jnp.int32, (1, 1, pt), 2)
        valid = pos < length
        if window is not None:
            valid &= pos > (length - 1 - window)
        s = jnp.where(valid, s, NEG_INF)

        m_prev = m_ref[0].reshape(kv_heads, G)               # [KVH, G]
        l_prev = l_ref[0].reshape(kv_heads, G)
        acc_prev = acc_ref[0].reshape(kv_heads, G, head_dim)

        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.where(valid, jnp.exp(s - m_new[..., None]), 0.0)
        corr = jnp.where(m_prev <= NEG_INF / 2, 0.0,
                         jnp.exp(m_prev - m_new))
        l_new = l_prev * corr + p.sum(-1)
        pv = jax.lax.dot_general(
            p, v, (((2,), (0,)), ((0,), (1,))),
            preferred_element_type=F32)                      # [KVH, G, hd]
        acc_new = acc_prev * corr[..., None] + pv

        acc_ref[0] = acc_new.reshape(q_heads, head_dim)
        m_ref[0] = m_new.reshape(q_heads)
        l_ref[0] = l_new.reshape(q_heads)
        heat_ref[0, 0] = p.sum()

    @pl.when(jnp.logical_not(page_ok))
    def _skip():
        heat_ref[0, 0] = 0.0


def paged_class_partials(q, pool_k, pool_v, page_table, logical_idx, lengths,
                         *, page_blocks: int, block_tokens: int,
                         window: int | None = None, interpret: bool = False,
                         active=None):
    """One size class. q: [B,H,hd]; pools: [NB,bt,KVH,hd];
    page_table/logical_idx: [B,MP] int32 (phys start block / logical page,
    -1 = pad); lengths: [B] int32.

    ``active`` ([B] bool, optional) masks out whole lanes — an inactive lane
    is exactly "every page invalid", so it folds into the existing per-page
    ``page_ok`` gate by blanking the lane's table row before prefetch; the
    kernel body and its scalar-prefetch arity are unchanged (no recompile
    churn against cached executables).

    Returns (acc [B,H,hd] f32, m [B,H] f32, l [B,H] f32, heat [B,MP] f32).
    """
    B, H, hd = q.shape
    NB, bt, KVH, _ = pool_k.shape
    MP = page_table.shape[1]
    assert bt == block_tokens
    if active is not None:
        page_table = jnp.where(active[:, None], page_table,
                               jnp.asarray(-1, page_table.dtype))

    kern = functools.partial(
        _kernel, page_blocks=page_blocks, block_tokens=block_tokens,
        kv_heads=KVH, q_heads=H, head_dim=hd, window=window)

    def pool_index(b, j, tbl, logical, lens):
        return (jnp.maximum(tbl[b, j], 0) // page_blocks, 0, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, MP),
        in_specs=[
            pl.BlockSpec((1, H, hd), lambda b, j, *refs: (b, 0, 0)),
            pl.BlockSpec((page_blocks, bt, KVH, hd), pool_index),
            pl.BlockSpec((page_blocks, bt, KVH, hd), pool_index),
        ],
        out_specs=[
            pl.BlockSpec((1, H, hd), lambda b, j, *refs: (b, 0, 0)),
            pl.BlockSpec((1, H), lambda b, j, *refs: (b, 0)),
            pl.BlockSpec((1, H), lambda b, j, *refs: (b, 0)),
            pl.BlockSpec((1, 1), lambda b, j, *refs: (b, j)),
        ],
    )
    out_shapes = [
        jax.ShapeDtypeStruct((B, H, hd), F32),
        jax.ShapeDtypeStruct((B, H), F32),
        jax.ShapeDtypeStruct((B, H), F32),
        jax.ShapeDtypeStruct((B, MP), F32),
    ]
    acc, m, l, heat = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=out_shapes,
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary")),
    )(page_table, logical_idx, lengths, q, pool_k, pool_v)
    return acc, m, l, heat
