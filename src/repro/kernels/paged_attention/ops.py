"""jit'd wrapper: run the class kernel per size class and combine partials."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import paged_class_partials
from .ref import combine_partials_ref

F32 = jnp.float32


@functools.partial(jax.jit, static_argnames=("block_tokens", "window",
                                             "orders", "interpret"))
def paged_decode_attention(q, pool_k, pool_v, page_tables, logical_idxs,
                           lengths, *, block_tokens: int,
                           orders: tuple[int, ...],
                           window: int | None = None,
                           interpret: bool = False, active=None):
    """Multi-size paged decode attention (Pallas).

    page_tables / logical_idxs: tuples aligned with ``orders``; entry i is
    the [B, MP_i] table for size class orders[i].  ``active`` ([B] bool,
    optional) masks whole lanes out of every size class — the device-
    resident-table convention where a vacated slot's rows may still hold
    stale physical indices.
    Returns (out [B,H,hd] in q.dtype, heats tuple of [B,MP_i] f32).
    """
    parts = []
    heats = []
    for o, tbl, logical in zip(orders, page_tables, logical_idxs):
        acc, m, l, heat = paged_class_partials(
            q, pool_k, pool_v, tbl, logical, lengths,
            page_blocks=4 ** o, block_tokens=block_tokens, window=window,
            interpret=interpret, active=active)
        parts.append((acc, m, l))
        heats.append(heat)
    out = combine_partials_ref(parts)
    return out.astype(q.dtype), tuple(heats)
