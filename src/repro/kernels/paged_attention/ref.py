"""Pure-jnp oracle for multi-size paged flash-decoding.

Semantics: for one page-size class c (page = page_blocks consecutive pool
blocks, buddy-aligned), given each sequence's class-c page list, compute the
UNNORMALIZED flash partials over exactly those pages:

    m[b,h]   = max score over the class's valid tokens (NEG_INF if none)
    l[b,h]   = sum exp(score - m)
    acc[b,h] = sum exp(score - m) * v

plus per-page attention *mass* (sum of exp(score - m_global_proxy)) — the
heat signal.  Heat uses the class-local max (it is combined after global
renormalization in ops.combine, so relative mass within a step is what
matters for DAMON).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

F32 = jnp.float32
NEG_INF = -1e30


def paged_class_partials_ref(q, pool_k, pool_v, page_table, logical_idx,
                             lengths, *, page_blocks: int, block_tokens: int,
                             window: int | None = None, active=None):
    """q: [B,H,hd]; pools: [NB,bt,KVH,hd];
    page_table: [B,MP] int32 physical START BLOCK of each class page (-1 pad),
    buddy-aligned to page_blocks; logical_idx: [B,MP] int32 logical page index
    (position = logical_idx * page_blocks * bt + offset); lengths: [B] tokens
    valid (including current); active: optional [B] bool lane mask (an
    inactive lane behaves as all pages invalid — mirrors the kernel).

    Returns (acc [B,H,hd] f32, m [B,H] f32, l [B,H] f32, heat [B,MP] f32).
    """
    if active is not None:
        page_table = jnp.where(active[:, None], page_table,
                               jnp.asarray(-1, page_table.dtype))
    B, H, hd = q.shape
    NB, bt, KVH, _ = pool_k.shape
    MP = page_table.shape[1]
    G = H // KVH
    pt = block_tokens * page_blocks           # tokens per class page
    scale = 1.0 / math.sqrt(hd)

    # gather pages: each page = page_blocks consecutive pool rows
    start = jnp.maximum(page_table, 0)                         # [B,MP]
    offs = jnp.arange(page_blocks)[None, None, :]              # [1,1,pb]
    rows = (start[..., None] + offs).reshape(B, MP * page_blocks)
    k = pool_k[rows].reshape(B, MP, pt, KVH, hd)
    v = pool_v[rows].reshape(B, MP, pt, KVH, hd)

    qg = q.reshape(B, KVH, G, hd).astype(F32)
    s = jnp.einsum("bkgd,bptkd->bkgpt", qg, k.astype(F32)) * scale

    pos = (jnp.maximum(logical_idx, 0)[:, :, None] * pt
           + jnp.arange(pt)[None, None, :])                    # [B,MP,pt]
    valid = (page_table >= 0)[:, :, None] & (pos < lengths[:, None, None])
    if window is not None:
        valid &= pos > (lengths[:, None, None] - 1 - window)
    s = jnp.where(valid[:, None, None], s, NEG_INF)

    s_flat = s.reshape(B, KVH, G, MP * pt)
    m = jnp.max(s_flat, axis=-1)                               # [B,KVH,G]
    p = jnp.exp(s_flat - m[..., None])
    p = jnp.where(valid.reshape(B, 1, 1, MP * pt), p, 0.0)
    l = p.sum(-1)
    acc = jnp.einsum("bkgs,bskd->bkgd", p,
                     v.reshape(B, MP * pt, KVH, hd).astype(F32))
    heat = p.sum(axis=(1, 2)).reshape(B, MP, pt).sum(-1)       # [B,MP]
    return (acc.reshape(B, H, hd), m.reshape(B, H), l.reshape(B, H), heat)


def combine_partials_ref(parts):
    """Combine flash partials [(acc,m,l), ...] -> normalized out [B,H,hd]."""
    m_g = parts[0][1]
    for _, m, _ in parts[1:]:
        m_g = jnp.maximum(m_g, m)
    l_g = jnp.zeros_like(m_g)
    acc_g = jnp.zeros_like(parts[0][0])
    for acc, m, l in parts:
        corr = jnp.exp(m - m_g)
        # fully-masked partials (m == NEG_INF) contribute nothing
        corr = jnp.where(m <= NEG_INF / 2, 0.0, corr)
        l_g = l_g + l * corr
        acc_g = acc_g + acc * corr[..., None]
    return acc_g / jnp.maximum(l_g, 1e-30)[..., None]


def paged_class_heat_running_ref(q, pool_k, pool_v, page_table, logical_idx,
                                 lengths, *, page_blocks: int,
                                 block_tokens: int, window: int | None = None):
    """Oracle for the KERNEL's heat semantics: pages visited sequentially,
    each page's mass normalized against the running max at visit time."""
    B, H, hd = q.shape
    NB, bt, KVH, _ = pool_k.shape
    MP = page_table.shape[1]
    G = H // KVH
    pt = block_tokens * page_blocks
    scale = 1.0 / math.sqrt(hd)
    qg = q.reshape(B, KVH, G, hd).astype(F32)

    heat = jnp.zeros((B, MP), F32)
    m_run = jnp.full((B, KVH, G), NEG_INF, F32)
    for j in range(MP):
        start = jnp.maximum(page_table[:, j], 0)
        rows = start[:, None] + jnp.arange(page_blocks)[None, :]
        k = pool_k[rows].reshape(B, pt, KVH, hd)
        s = jnp.einsum("bkgd,btkd->bkgt", qg, k.astype(F32)) * scale
        pos = (jnp.maximum(logical_idx[:, j], 0)[:, None] * pt
               + jnp.arange(pt)[None, :])
        valid = (page_table[:, j] >= 0)[:, None] & (pos < lengths[:, None])
        if window is not None:
            valid &= pos > (lengths[:, None] - 1 - window)
        s = jnp.where(valid[:, None, None], s, NEG_INF)
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.where((page_table[:, j] >= 0)[:, None, None],
                          jnp.maximum(m_run, m_cur), m_run)
        p = jnp.where(valid[:, None, None],
                      jnp.exp(s - m_new[..., None]), 0.0)
        hj = jnp.where(page_table[:, j] >= 0, p.sum(axis=(1, 2, 3)), 0.0)
        heat = heat.at[:, j].set(hj)
        m_run = m_new
    return heat


def paged_decode_ref(q, pool_k, pool_v, page_tables, logical_idxs, lengths, *,
                     block_tokens: int, window=None):
    """Full multi-class oracle: page_tables/logical_idxs are dicts
    {order: [B, MP_c]}; page_blocks = 4**order."""
    parts = []
    heats = {}
    for order, tbl in sorted(page_tables.items()):
        acc, m, l, heat = paged_class_partials_ref(
            q, pool_k, pool_v, tbl, logical_idxs[order], lengths,
            page_blocks=4 ** order, block_tokens=block_tokens, window=window)
        parts.append((acc, m, l))
        heats[order] = heat
    out = combine_partials_ref(parts)
    return out.astype(q.dtype), heats
