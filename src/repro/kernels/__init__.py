"""Pallas TPU kernels for the perf-critical paths.

  paged_attention/  multi-size paged flash-decoding with per-page heat stats
                    (the paper's translated-read hot path)
  flash_attention/  causal/windowed GQA prefill-training attention
  block_copy/       page migration (compaction / khugepaged collapse)

Each directory: kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
wrapper), ref.py (pure-jnp oracle used by the allclose test sweeps).
All kernels target TPU (VMEM tiling, MXU-aligned blocks) and are validated
on CPU with interpret=True.
"""
