"""Pallas TPU kernel: pool block migration (compaction / collapse copies).

Executes a host-planned move list (src row -> dst row) over the KV pool with
one grid step per move; the move list rides in scalar-prefetch memory and
steers both BlockSpec index maps.  The pool aliases in-place
(input_output_aliases), so on TPU this is NB-row HBM->HBM DMA traffic — the
device half of the paper's "compaction cost" term, and what khugepaged-style
collapse executes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _kernel(src_ref, dst_ref, in_ref, out_ref):
    out_ref[...] = in_ref[...]


def block_copy(pool, src, dst, *, interpret: bool = False):
    """pool: [NB, E]; src/dst: [NM] int32. Returns the updated pool.

    Real plans always move into free rows; padding entries must be
    self-copies (src[i] == dst[i]), which are harmless.
    """
    NB, E = pool.shape
    NM = src.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(NM,),
        in_specs=[
            pl.BlockSpec((1, E), lambda i, src_r, dst_r: (src_r[i], 0)),
        ],
        out_specs=pl.BlockSpec((1, E), lambda i, src_r, dst_r: (dst_r[i], 0)),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((NB, E), pool.dtype),
        input_output_aliases={2: 0},    # pool (after the 2 scalar args) -> out
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary",)),
    )(src, dst, pool)
