"""jit'd wrapper: expand MM move plans into per-block row copies and run the
migration kernel over every pool in a serving cache."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .kernel import block_copy


def expand_moves(plan, pad_to: int | None = None):
    """[(src_start, dst_start, order)] -> (src[NM], dst[NM]) per-block rows."""
    src, dst = [], []
    for s, d, o in plan:
        n = 4 ** o
        src.extend(range(s, s + n))
        dst.extend(range(d, d + n))
    if pad_to is not None:
        while len(src) < pad_to:
            src.append(0)
            dst.append(0)      # self-copy padding
    return (np.asarray(src, np.int32), np.asarray(dst, np.int32))


@functools.partial(jax.jit, static_argnames=("interpret",))
def apply_moves(pool, src, dst, *, interpret: bool = False):
    """pool: [NB, ...] (any trailing dims); src/dst: [NM]."""
    shape = pool.shape
    flat = pool.reshape(shape[0], -1)
    out = block_copy(flat, src, dst, interpret=interpret)
    return out.reshape(shape)
