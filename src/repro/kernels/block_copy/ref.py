"""Oracle for the block migration kernel."""

from __future__ import annotations

import jax.numpy as jnp


def block_copy_ref(pool, src, dst):
    """pool: [NB, E]; src/dst: [NM] int32 (self-copies allowed as padding).
    Moves are applied in order; MM compaction plans guarantee destinations
    are free blocks, so order never matters for real plans."""
    out = pool
    for i in range(src.shape[0]):
        out = out.at[dst[i]].set(out[src[i]])
    return out
