import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
# ^ MUST precede any jax import/init: jax locks the device count on first use.

"""Multi-pod dry-run: lower + compile every (architecture x input-shape) cell
on the production meshes and record roofline inputs.

    PYTHONPATH=src python -m repro.launch.dryrun \
        --arch all --shape all --mesh single,multi --attn gather \
        --out results/dryrun

Per cell: .lower() -> .compile() must succeed; we record compile wall time,
compiled.cost_analysis() (FLOPs / bytes, per partition), per-device collective
operand bytes parsed from the post-SPMD HLO (all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute), and
compiled.memory_analysis() when the backend provides it (plus an analytic
per-device argument-bytes estimate that always works on CPU).
Failures (sharding mismatch, OOM at compile, unsupported collective) are
BUGS in the framework — the run exits nonzero listing them.
"""

import argparse
import json
import re
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import (ARCH_IDS, SHAPES, get_config, supports_shape)
from repro.distributed.flashdecode import set_decode_mesh
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import Cell, make_cell
from repro.models.decode import decode_step, prefill_step
from repro.training.train_step import make_train_step

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"=\s*[a-z0-9]+\[[0-9,]*\][^=]*?\b"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


_BODY_REF_RE = re.compile(r"body=%?([\w.\-]+)")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\([^)]*\)\s*->")


def collective_bytes(hlo_text: str, body_weight: float = 1.0) -> dict:
    """Per-device operand bytes of every collective, by op kind.

    XLA's HLO text prints each while-loop BODY once; real execution repeats
    it trip-count times.  We find body computations via ``body=%name``
    references on while ops and weight their collectives by ``body_weight``
    (the scan trip count from the model config) — 'weighted' is the
    per-step-accurate number the roofline uses.
    """
    bodies: set[str] = set()
    for m in _BODY_REF_RE.finditer(hlo_text):
        bodies.add(m.group(1))

    out: dict[str, int] = {}
    count: dict[str, int] = {}
    weighted: dict[str, float] = {}
    current_comp = ""
    for line in hlo_text.splitlines():
        hdr = _COMP_HDR_RE.match(line.strip())
        if hdr and "{" in line:
            current_comp = hdr.group(1)
            continue
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind = m.group(1)
        if "-done(" in line:        # async pair: count the -start only
            continue
        shapes = _SHAPE_RE.findall(line)
        if not shapes:
            continue
        paren = line[line.index("("):]
        operands = _SHAPE_RE.findall(paren)
        use = operands if operands else shapes[:1]
        total = sum(_shape_bytes(d, s) for d, s in use)
        w = body_weight if current_comp in bodies else 1.0
        out[kind] = out.get(kind, 0) + total
        weighted[kind] = weighted.get(kind, 0.0) + total * w
        count[kind] = count.get(kind, 0) + 1
    return {"bytes_by_kind": out, "count_by_kind": count,
            "total_bytes": sum(out.values()),
            "weighted_bytes_by_kind": weighted,
            "weighted_total_bytes": sum(weighted.values()),
            "body_weight": body_weight}


def arg_bytes_per_device(cell: Cell, mesh) -> int:
    """Analytic per-device input footprint (always available on CPU)."""
    ndev = int(np.prod(list(mesh.shape.values())))
    total = 0
    for leaf, sh in zip(jax.tree.leaves(cell.args),
                        jax.tree.leaves(cell.in_shardings,
                                        is_leaf=lambda x: hasattr(x, "spec"))):
        try:
            ss = sh.shard_shape(tuple(leaf.shape))
            total += int(np.prod(ss)) * leaf.dtype.itemsize
        except Exception:
            total += int(np.prod(leaf.shape)) * leaf.dtype.itemsize // ndev
    return total


def build_fn(cfg, cell: Cell):
    if cell.kind == "train":
        step = make_train_step(cfg, num_micro=1, chunk=1024, remat=True)
        return step
    if cell.kind == "prefill":
        extras = list(cell.meta["extras"].keys())

        def prefill(params, cache, tokens, tbl, *rest):
            kw = dict(zip(extras, rest))
            return prefill_step(params, cfg, cache, tokens, tbl, cell.layout,
                                chunk=1024, **kw)
        return prefill

    attn_impl = cell.meta["attn_impl"]
    has_st = "sharded_tables" in cell.meta
    has_pos3d = cell.meta.get("pos3d", False)

    def serve(params, cache, tokens, lengths, tbl, *rest):
        rest = list(rest)
        st = sl = pos3d = None
        if has_st:
            st = rest.pop(0)
            sl = rest.pop(0)
        if has_pos3d:
            pos3d = rest.pop(0)
        return decode_step(params, cfg, cache, tokens, lengths, tbl,
                           cell.layout, pos3d=pos3d, attn_impl=attn_impl,
                           sharded_table=st, sharded_logical=sl)
    return serve


def run_cell(arch: str, shape_name: str, mesh_name: str, attn_impl: str,
             out_dir: Path, *, force: bool = False) -> dict:
    tag = f"{arch}.{shape_name}.{mesh_name}.{attn_impl}"
    out_path = out_dir / f"{tag}.json"
    if out_path.exists() and not force:
        rec = json.loads(out_path.read_text())
        if rec.get("ok") or rec.get("skipped"):
            print(f"[cached ] {tag}")
            return rec

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok_shape, reason = supports_shape(cfg, shape)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "attn_impl": attn_impl, "kind": shape.kind}
    if not ok_shape:
        rec.update(skipped=True, reason=reason, ok=False)
        out_path.write_text(json.dumps(rec, indent=1))
        print(f"[SKIP   ] {tag}: {reason}")
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    set_decode_mesh(mesh)
    try:
        t0 = time.monotonic()
        cell = make_cell(cfg, shape, mesh, attn_impl=attn_impl)
        fn = build_fn(cfg, cell)
        with mesh:
            jitted = jax.jit(fn, in_shardings=cell.in_shardings,
                             out_shardings=cell.out_shardings)
            lowered = jitted.lower(*cell.args)
            t_lower = time.monotonic() - t0
            compiled = lowered.compile()
            t_compile = time.monotonic() - t0 - t_lower
        ca = compiled.cost_analysis() or {}
        if isinstance(ca, (list, tuple)):
            ca = ca[0] if ca else {}
        cost = {k: float(v) for k, v in ca.items()
                if isinstance(v, (int, float)) and (
                    "flops" in k or "bytes" in k or k == "optimal_seconds")}
        try:
            mem = compiled.memory_analysis()
            mem_rec = {
                "argument_size_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_size_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_size_bytes": getattr(mem, "temp_size_in_bytes", None),
                "generated_code_size_bytes": getattr(
                    mem, "generated_code_size_in_bytes", None),
            } if mem is not None else None
        except Exception:
            mem_rec = None
        hlo = compiled.as_text()
        from repro.models.transformer import build_layer_plans, build_segments
        reps = [seg[2] for seg in build_segments(build_layer_plans(cfg))
                if seg[0] == "scan"]
        body_weight = float(np.mean(reps)) if reps else 1.0
        coll = collective_bytes(hlo, body_weight=body_weight)
        rec.update(
            ok=True,
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            cost_analysis=cost,
            memory_analysis=mem_rec,
            arg_bytes_per_device=arg_bytes_per_device(cell, mesh),
            collectives=coll,
            hlo_bytes=len(hlo),
            meta=cell.meta,
            devices=int(np.prod(list(mesh.shape.values()))),
        )
        print(f"[OK     ] {tag}: lower {t_lower:.1f}s compile {t_compile:.1f}s "
              f"flops={cost.get('flops', 0):.3g} "
              f"coll={coll['total_bytes']/1e6:.1f}MB/dev "
              f"args={rec['arg_bytes_per_device']/1e9:.2f}GB/dev")
    except Exception as e:   # noqa: BLE001 — record and continue
        rec.update(ok=False, skipped=False, error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
        print(f"[FAIL   ] {tag}: {type(e).__name__}: {e}")
    finally:
        set_decode_mesh(None)
    out_path.write_text(json.dumps(rec, indent=1))
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single,multi")
    ap.add_argument("--attn", default="gather",
                    help="gather | flashdecode | flashdecode_blocksharded")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = args.mesh.split(",")
    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    failures = []
    for arch in archs:
        for shape in shapes:
            for mesh_name in meshes:
                attn = args.attn
                if SHAPES[shape].kind != "decode" and attn != "gather":
                    attn = "gather"      # flashdecode applies to decode only
                if attn.startswith("flashdecode") and \
                        SHAPES[shape].global_batch == 1:
                    attn = "flashdecode_blocksharded"
                rec = run_cell(arch, shape, mesh_name, attn, out_dir,
                               force=args.force)
                if not rec.get("ok") and not rec.get("skipped"):
                    failures.append(f"{arch}.{shape}.{mesh_name}")
    if failures:
        print(f"\n{len(failures)} FAILED cells:")
        for f in failures:
            print("  ", f)
        raise SystemExit(1)
    print("\nAll dry-run cells compiled.")


if __name__ == "__main__":
    main()
