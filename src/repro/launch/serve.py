"""Serving launcher: continuous batching with the eBPF-mm paged KV cache.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3_27b --smoke \
        --policy ebpf --requests 8 --max-new 24

Sweeps one policy; benchmarks/fig2_policy_sweep.py compares all of them.
"""

from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from repro.configs.base import get_config, get_smoke_config
from repro.core import Profile, ProfileRegion
from repro.models.common import materialize
from repro.models.decode import PagedLayout
from repro.models.transformer import model_spec
from repro.serving import Request, ServingEngine


def default_profile(max_blocks: int) -> Profile:
    """A serving profile: hot shared prefix, cold tail — what DAMON replay
    produces for chat workloads (system prompt + few-shot header is hot)."""
    hot_end = max(4, max_blocks // 4)
    return Profile("chat", [
        ProfileRegion(0, hot_end, (0, 150_000, 600_000, 2_500_000)),
        ProfileRegion(hot_end, max_blocks, (0, 0, 0, 0)),
    ])


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--policy", default="ebpf",
                    choices=["ebpf", "thp", "never", "thp-prog", "never-prog"])
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--blocks", type=int, default=512)
    ap.add_argument("--block-tokens", type=int, default=4)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    params = materialize(jax.random.PRNGKey(0), model_spec(cfg))
    max_blocks = -(-(args.prompt_len + args.max_new) // args.block_tokens) + 8
    layout = PagedLayout(num_blocks=args.blocks,
                         block_tokens=args.block_tokens,
                         max_blocks=max_blocks)
    prof = default_profile(max_blocks) if args.policy == "ebpf" else None
    eng = ServingEngine(cfg, params, layout, max_batch=args.batch,
                        policy=args.policy, profile=prof)
    rng = np.random.default_rng(0)
    for r in range(args.requests):
        plen = int(rng.integers(args.prompt_len // 2, args.prompt_len + 1))
        eng.submit(Request(
            rid=r, prompt=rng.integers(1, cfg.vocab, plen).tolist(),
            max_new_tokens=args.max_new, app="chat"))
    out = eng.run()
    print(json.dumps(out, indent=1))


if __name__ == "__main__":
    main()
