"""input_specs(): ShapeDtypeStruct stand-ins + shardings for every
(architecture x input-shape x mesh) dry-run cell.  No device allocation.

Cell kinds:
  train   -> lowers train_step(params, opt_state, batch)
  prefill -> lowers prefill_step(params, cache, tokens, block_table, ...)
  decode  -> lowers serve_step = decode_step(params, cache, tokens, lengths,
             block_table[, sharded tables when attn_impl=flashdecode*])
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig, ShapeSpec
from ..data.pipeline import batch_specs_for
from ..distributed.sharding import (DEFAULT_RULES, shardings_for_tree,
                                    spec_for, zero1_shardings_for_tree)
from ..models.common import abstract, logical_axes
from ..models.decode import PagedLayout, cache_spec
from ..models.transformer import build_layer_plans, build_segments, model_spec
from ..optim.adamw import AdamWState

Pytree = Any
BF16 = jnp.bfloat16

BLOCK_TOKENS = 16


@dataclass
class Cell:
    """Everything the dry-run needs to lower one (arch, shape, mesh) cell."""
    kind: str
    args: tuple                 # ShapeDtypeStructs, positional
    in_shardings: tuple
    out_shardings: Any
    layout: PagedLayout | None = None
    meta: dict | None = None


def _data_axes(mesh: Mesh):
    return tuple(n for n in mesh.axis_names if n not in ("model",))


def _batch_sharding(mesh: Mesh, batch: int) -> P:
    axes = _data_axes(mesh)
    total = int(np.prod([mesh.shape[a] for a in axes]))
    if batch % total == 0:
        return P(axes)
    # fall back: shard over plain data if divisible, else replicate
    if "data" in mesh.axis_names and batch % mesh.shape["data"] == 0:
        return P("data")
    return P()


def param_shardings(cfg: ModelConfig, mesh: Mesh):
    spec = model_spec(cfg)
    return abstract(spec), shardings_for_tree(abstract(spec),
                                              logical_axes(spec), mesh)


def batch_shardings(cfg: ModelConfig, mesh: Mesh, specs: dict):
    bspec = _batch_sharding(mesh, specs["tokens"].shape[0])
    out = {}
    for k, v in specs.items():
        if k == "pos3d":
            parts = [None] + list(bspec)
            out[k] = NamedSharding(mesh, P(*parts))
        else:
            out[k] = NamedSharding(mesh, bspec)
    return out


def make_layout(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh) -> PagedLayout:
    chips = int(np.prod(list(mesh.shape.values())))
    max_blocks = -(-shape.seq_len // BLOCK_TOKENS)
    num_blocks = shape.global_batch * max_blocks
    # round NB up to a multiple of the mesh size so the pool shards evenly
    num_blocks = -(-num_blocks // chips) * chips
    # keep per-sequence tables divisible by the model axis for flashdecode
    m = mesh.shape["model"]
    max_blocks = -(-max_blocks // m) * m
    return PagedLayout(num_blocks=num_blocks, block_tokens=BLOCK_TOKENS,
                       max_blocks=max_blocks)


def cache_shardings(cfg: ModelConfig, layout: PagedLayout, mesh: Mesh,
                    batch: int):
    """Sharding tree matching cache_spec: pool block dim over the whole mesh,
    per-sequence state over the batch sharding."""
    cspec = cache_spec(cfg, layout, batch, BF16)
    all_axes = tuple(mesh.axis_names)
    bspec = _batch_sharding(mesh, batch)

    def one(path, leaf):
        key = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        stacked = leaf.ndim >= 1 and key in (
            "pool_k", "pool_v", "pool_ckv", "ssm", "conv", "xk", "xv")
        # find the defining dim
        if key in ("pool_k", "pool_v", "pool_ckv"):
            nb_dim = 0 if leaf.shape[0] == layout.num_blocks else 1
            parts = [None] * leaf.ndim
            parts[nb_dim] = all_axes
            return NamedSharding(mesh, P(*parts))
        if key in ("ssm", "conv", "xk", "xv"):
            b_dim = 0 if leaf.shape[0] == batch else 1
            parts = [None] * leaf.ndim
            if leaf.shape[b_dim] == batch and len(bspec) > 0:
                parts[b_dim] = bspec[0]
            return NamedSharding(mesh, P(*parts))
        return NamedSharding(mesh, P())

    return cspec, jax.tree_util.tree_map_with_path(one, cspec)


def train_cell(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh) -> Cell:
    pspecs, pshard = param_shardings(cfg, mesh)
    mu_shard = zero1_shardings_for_tree(
        pspecs, logical_axes(model_spec(cfg)), mesh)
    opt_specs = AdamWState(
        step=jax.ShapeDtypeStruct((), jnp.int32),
        mu=jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32),
                        pspecs),
        nu=jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32),
                        pspecs))
    opt_shard = AdamWState(step=NamedSharding(mesh, P()), mu=mu_shard,
                           nu=jax.tree.map(lambda s: s, mu_shard))
    bspecs = batch_specs_for(cfg, shape.global_batch, shape.seq_len,
                             train=True)
    bshard = batch_shardings(cfg, mesh, bspecs)
    metrics_shard = NamedSharding(mesh, P())
    out_shardings = (pshard, opt_shard,
                     {"loss": metrics_shard, "lr": metrics_shard,
                      "grad_norm": metrics_shard,
                      "update_norm": metrics_shard})
    return Cell(kind="train",
                args=(pspecs, opt_specs, bspecs),
                in_shardings=(pshard, opt_shard, bshard),
                out_shardings=out_shardings,
                meta={"tokens_per_step": shape.global_batch * shape.seq_len})


def prefill_cell(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh) -> Cell:
    layout = make_layout(cfg, shape, mesh)
    B, S = shape.global_batch, shape.seq_len
    pspecs, pshard = param_shardings(cfg, mesh)
    cspec, cshard = cache_shardings(cfg, layout, mesh, B)
    bspecs = batch_specs_for(cfg, B, S, train=False)
    bshard = batch_shardings(cfg, mesh, bspecs)
    tbl = jax.ShapeDtypeStruct((B, layout.max_blocks), jnp.int32)
    tbl_shard = NamedSharding(mesh, _batch_sharding(mesh, B))
    args = [pspecs, cspec, bspecs["tokens"], tbl]
    in_sh = [pshard, cshard, bshard["tokens"], tbl_shard]
    meta_kw = {}
    for extra in ("frames", "patches", "pos3d"):
        if extra in bspecs:
            meta_kw[extra] = True
            args.append(bspecs[extra])
            in_sh.append(bshard[extra])
    rep = NamedSharding(mesh, P())
    out_shardings = (rep, cshard)
    return Cell(kind="prefill", args=tuple(args), in_shardings=tuple(in_sh),
                out_shardings=out_shardings, layout=layout,
                meta={"extras": meta_kw,
                      "tokens_per_step": B * S})


def decode_cell(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh,
                attn_impl: str = "gather") -> Cell:
    layout = make_layout(cfg, shape, mesh)
    B = shape.global_batch
    pspecs, pshard = param_shardings(cfg, mesh)
    cspec, cshard = cache_shardings(cfg, layout, mesh, B)
    bsh = _batch_sharding(mesh, B)
    toks = jax.ShapeDtypeStruct((B,), jnp.int32)
    lens = jax.ShapeDtypeStruct((B,), jnp.int32)
    tbl = jax.ShapeDtypeStruct((B, layout.max_blocks), jnp.int32)
    args = [pspecs, cspec, toks, lens, tbl]
    in_sh = [pshard, cshard, NamedSharding(mesh, bsh),
             NamedSharding(mesh, bsh), NamedSharding(mesh, bsh)]
    meta: dict = {"attn_impl": attn_impl, "tokens_per_step": B,
                  "kv_tokens": B * shape.seq_len}
    if attn_impl.startswith("flashdecode"):
        names = tuple(mesh.axis_names)
        M = mesh.shape["model"]
        if attn_impl.endswith("blocksharded"):
            NS = int(np.prod(list(mesh.shape.values())))
            st_spec = P(None, names, None)
        else:
            NS = M
            st_spec = P(_data_axes(mesh), "model", None)
        MBl = layout.max_blocks // NS if layout.max_blocks % NS == 0 \
            else -(-layout.max_blocks // NS)
        st = jax.ShapeDtypeStruct((B, NS, MBl), jnp.int32)
        args += [st, st]
        in_sh += [NamedSharding(mesh, st_spec), NamedSharding(mesh, st_spec)]
        meta["sharded_tables"] = (NS, MBl)
    if cfg.vlm_patches:
        args.append(jax.ShapeDtypeStruct((3, B, 1), jnp.float32))
        in_sh.append(NamedSharding(mesh, P(None, *bsh)))
        meta["pos3d"] = True
    # logits stay vocab-sharded over "model" (the lm_head layout) — gathering
    # the [B, V_pad] f32 logits was 75% of decode's collective bytes (§Perf)
    b_part = bsh[0] if len(bsh) else None
    logits_sh = NamedSharding(mesh, P(b_part, "model"))
    heat_sh = NamedSharding(mesh, bsh)
    out_shardings = (logits_sh, cshard, heat_sh)
    return Cell(kind="decode", args=tuple(args), in_shardings=tuple(in_sh),
                out_shardings=out_shardings, layout=layout, meta=meta)


def make_cell(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh,
              attn_impl: str = "gather") -> Cell:
    if shape.kind == "train":
        return train_cell(cfg, shape, mesh)
    if shape.kind == "prefill":
        return prefill_cell(cfg, shape, mesh)
    return decode_cell(cfg, shape, mesh, attn_impl=attn_impl)
