"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch mamba2_1p3b --smoke \
        --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

On a real TPU slice this process runs per host (jax.distributed.initialize()
first); here it drives the same Trainer/fault-tolerant loop on the local
devices.  ``--smoke`` selects the reduced config (full configs need the
production mesh).
"""

from __future__ import annotations

import argparse
import json
import tempfile

import jax

from repro.checkpoint.store import CheckpointStore
from repro.configs.base import get_config, get_smoke_config
from repro.data.pipeline import make_batch_iter
from repro.models.common import materialize
from repro.models.transformer import model_spec
from repro.training.trainer import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--micro", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    params = materialize(jax.random.PRNGKey(0), model_spec(cfg))
    data = make_batch_iter(cfg, args.batch, args.seq)
    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="repro_ckpt_")
    trainer = Trainer(
        TrainerConfig(num_steps=args.steps, checkpoint_every=args.ckpt_every,
                      base_lr=args.lr, num_micro=args.micro,
                      chunk=min(512, args.seq)),
        cfg, params, data, CheckpointStore(ckpt_dir))
    out = trainer.run()
    print(json.dumps(out["metrics"], indent=1))
    first, last = out["metrics"][0], out["metrics"][-1]
    print(f"loss {first['loss']:.3f} -> {last['loss']:.3f} over "
          f"{out['final_step']} steps (ckpts in {ckpt_dir})")


if __name__ == "__main__":
    main()
