"""Launchers: production mesh, multi-pod dry-run, train/serve CLIs.

NOTE: launch.dryrun sets XLA_FLAGS at import — do not import it from test or
engine code; it is a __main__-style entry point.
"""

from .mesh import make_host_mesh, make_production_mesh

__all__ = ["make_host_mesh", "make_production_mesh"]
