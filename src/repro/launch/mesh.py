"""Production mesh construction.

Single pod: 16 x 16 = 256 chips, axes ("data", "model").
Multi-pod:  2 x 16 x 16 = 512 chips, axes ("pod", "data", "model") — the pod
axis is the outer data-parallel axis (gradient all-reduce crosses DCI).

A FUNCTION, not a module constant, so importing never touches jax device
state (the dry-run must set XLA_FLAGS before any jax init).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(*, model: int = 1):
    """Tiny mesh over whatever devices exist (tests / CPU engine)."""
    n = len(jax.devices())
    data = max(1, n // model)
    return jax.make_mesh((data, model), ("data", "model"))
