"""Continuous-batching serving engine with an eBPF-mm-managed paged KV cache.

The paper's workflow, end to end:

  * every sequence is a "process" with a growing KV address space;
  * each decode step that crosses a block boundary is a PAGE FAULT —
    the MemoryManager runs the attached policy program (profile search +
    cost/benefit) to pick the page size backing the new mapping;
  * the paged-attention path emits per-block attention mass, which feeds the
    per-process DAMON monitors (the benefit signal);
  * the khugepaged analogue runs between engine steps, collapsing hot
    regions into larger pages; migrations/compactions come back as explicit
    block-copy move lists applied to the device pools;
  * pool exhaustion triggers the reclaim hook; with a host-DRAM tier
    configured (``host_blocks > 0``) the engine DEMOTES the victim's cold
    blocks to the host tier instead of evicting the whole process
    (demote-before-preempt): the mm_tier hook program vets each candidate
    (TierBPF-style admission control), approved pages migrate over PCIe via
    the same block-copy move lists, and a background promotion scan brings
    re-heated pages back to HBM between steps.  Whole-sequence preemption
    (requeue + recompute) remains only as the fallback when BOTH tiers are
    exhausted or the tier policy vetoes every demotion.

Policies (``policy=``): "ebpf" (profile + Figure-1 program), "thp"
(kernel-default greedy PMD-size), "never" (base pages), "thp-prog"/
"never-prog" (same baselines expressed as loaded programs, for measuring
hook overhead).  The Figure-2 benchmark sweeps these.  Orthogonally,
``tier_policy=`` selects the mm_tier program: "ebpf-tier" (DAMON-heat
admission control), "lru-tier" (age-based demotion baseline), "never-tier"
(veto all demotions -> preempt-only), "heat-tier" (heat-banded N-tier
placement incl. prefill-time cold-prefix placement), "edge-tier"
(TierBPF-style single-hop per-edge admission control), or "default"
(kernel-default path, no program attached).  The tier topology comes from
``host_blocks`` (classic HBM + host-DRAM) or ``tier_blocks`` (a chain of
spill-tier capacities: peer-HBM over ICI, host DRAM over PCIe, NVMe).  The
capacity-sweep benchmark sweeps these.
"""

from __future__ import annotations

import time
from contextlib import nullcontext
from dataclasses import dataclass, field
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..obs import (Telemetry, flatten_metrics, render_prometheus,
                   write_chrome_trace)
from ..obs.ringbuf import EV_PREEMPT

from ..configs.base import ModelConfig
from ..core import (MAX_PROFILE_REGIONS, FaultKind, HWSpec, Khugepaged,
                    KhugepagedConfig, MemoryManager, MMOutOfMemory, Profile,
                    ProfileSynthesizer, TieredMemoryManager,
                    default_tier_chain, ebpf_mm_program, make_cost_model,
                    never_program, profile_wss_program, reclaim_lru_program,
                    thp_always_program, tier_damon_program,
                    tier_edge_admission_program, tier_heat_band_program,
                    tier_lru_program, tier_never_program)
from ..core.buddy import order_blocks
from ..core.hooks import HOOK_EVICT, HOOK_FAULT, HOOK_PROFILE, HOOK_TIER
from ..core.programs import (evict_ghost_program, evict_lfu_program,
                             evict_lru_program)
from ..resilience import FailureInjector
from ..models.decode import (PagedLayout, cache_init, decode_step,
                             prefill_step, prefill_suffix_step)
from ..models.transformer import build_layer_plans
from .prefix_cache import PrefixCache
from .sampler import Sampler
from .tables import DeviceBlockTables

Pytree = Any


@dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new_tokens: int = 16       # reserved capacity (VMA is sized for this)
    app: str | None = None
    temperature: float = 0.0
    stop_after: int | None = None  # EOS point; None = runs to max_new_tokens


@dataclass
class SeqState:
    req: Request
    pid: int
    slot: int
    generated: list = field(default_factory=list)
    length: int = 0           # tokens currently in KV (prompt + generated)
    prefix: Any = None        # pinned PrefixMatch when admitted via the cache


@dataclass
class EngineStats:
    steps: int = 0
    prefills: int = 0
    prefill_tokens: int = 0        # tokens actually run through the kernel
    decode_tokens: int = 0
    preemptions: int = 0
    tier_reliefs: int = 0          # OOMs resolved by demotion, not preemption
    wall_host_s: float = 0.0
    completed: int = 0

    def snapshot(self) -> dict:
        return dict(self.__dict__)


class ServingEngine:
    # tier_policy name -> mm_tier program factory (None = kernel default)
    TIER_PROGRAMS = {
        "ebpf-tier": tier_damon_program,
        "lru-tier": tier_lru_program,
        "never-tier": tier_never_program,
        "heat-tier": tier_heat_band_program,
        "edge-tier": tier_edge_admission_program,
        "default": None,
    }
    # 2-tier baselines: their demote target never passes tier 1 (ebpf-tier
    # additionally gates on tier-1 free space alone), so on a deeper chain
    # they strand tiers 2.. and reclaim degrades back to preemption while
    # deep capacity sits free — reject the pairing instead of livelocking.
    TWO_TIER_POLICIES = frozenset({"ebpf-tier", "lru-tier"})
    # evict_policy name -> mm_evict program factory (None = kernel default:
    # the cache's built-in conservative LRU fallback, no program attached)
    EVICT_PROGRAMS = {
        "lru-evict": evict_lru_program,
        "lfu-evict": evict_lfu_program,
        "ghost-evict": evict_ghost_program,
        "default": None,
    }

    def __init__(self, cfg: ModelConfig, params: Pytree, layout: PagedLayout,
                 *, max_batch: int = 4, policy: str = "ebpf",
                 profile: "Profile | list | str | None" = None,
                 profile_period: int = 4, hw: HWSpec | None = None,
                 khugepaged: bool = True, seed: int = 0,
                 cache_dtype=jnp.bfloat16,
                 host_blocks: int = 0, tier_blocks=None,
                 tier_policy: str = "ebpf-tier",
                 prefix_cache: "bool | int" = False,
                 evict_policy: str = "lru-evict",
                 batch_faults: bool = True,
                 telemetry: "Telemetry | bool | None" = None,
                 trace: bool = False,
                 chaos: "int | FailureInjector | None" = None,
                 chaos_rate: float = 0.02,
                 containment: bool = True):
        # telemetry: None (default — zero-overhead no-op), True (counters/
        # histograms/ring), or a repro.obs.Telemetry instance.  trace=True
        # additionally records engine spans for the Chrome-trace exporter
        # (and implies telemetry when none was passed).
        if telemetry is True or (telemetry is None and trace):
            telemetry = Telemetry(trace=trace)
        elif telemetry is not None and trace:
            telemetry.trace_enabled = telemetry.enabled
        self.telemetry: Telemetry | None = telemetry or None
        # chaos: None (default — no injection, zero overhead), an int seed
        # (uniform chaos_rate at every failure site), or a pre-configured
        # FailureInjector.  containment=False keeps the injector but turns
        # OFF the resilience responses (supervisor detach, migration retry,
        # quarantine routing, degraded demote) — the chaos benchmark's
        # no-containment baseline.
        if chaos is None or isinstance(chaos, FailureInjector):
            self.injector = chaos
        else:
            self.injector = FailureInjector.uniform(int(chaos),
                                                    float(chaos_rate))
        self.containment = bool(containment)
        self.cfg = cfg
        self.params = params
        self.layout = layout
        self.max_batch = max_batch
        self.policy = policy
        # tier_blocks: spill-tier capacities walking down the chain — 1 pool
        # = (host-DRAM,), 2 = (peer-HBM, host-DRAM), 3 = (peer-HBM,
        # host-DRAM, NVMe).  host_blocks is the classic 2-pool shorthand.
        if tier_blocks is None and host_blocks > 0:
            tier_blocks = (host_blocks,)
        self.tier_blocks = tuple(int(b) for b in tier_blocks) \
            if tier_blocks else ()
        tiered = bool(self.tier_blocks)
        self.tier_policy = tier_policy if tiered else None
        # batch_faults=False keeps the pre-batching scalar fault path (one
        # policy invocation per fault) — the hot-path benchmark's baseline
        self.batch_faults = batch_faults
        self._modal_cache: dict = {}
        hw = hw or HWSpec()

        n_attn = sum(1 for k in cfg.layer_kinds() if k == "a")
        if cfg.mla is not None:
            slab = cfg.mla.kv_lora + cfg.mla.qk_rope
        else:
            slab = cfg.kv_heads * cfg.head_dim * 2
        cost = make_cost_model(hw, kv_heads=1, head_dim=1,
                               block_tokens=layout.block_tokens)
        cost.block_bytes = layout.block_tokens * slab * 2 * max(1, n_attn)

        default_mode = {"never": "never", "never-prog": "never"}.get(policy, "thp")
        if tiered:
            # tiered pool: HBM buddy + one buddy per spill tier; the device
            # cache below is materialized over the COMBINED index space so
            # tier crossings are ordinary block_copy moves
            self.mm = TieredMemoryManager(
                layout.num_blocks, cost,
                tiers=default_tier_chain(hw, self.tier_blocks),
                default_mode=default_mode, damon_seed=seed,
                telemetry=self.telemetry, injector=self.injector,
                containment=self.containment)
            if tier_policy not in self.TIER_PROGRAMS:
                raise ValueError(f"unknown tier_policy {tier_policy!r}")
            if len(self.tier_blocks) > 1 \
                    and tier_policy in self.TWO_TIER_POLICIES:
                raise ValueError(
                    f"tier_policy {tier_policy!r} is a 2-tier baseline that "
                    f"can never demote past tier 1; use 'heat-tier' or "
                    f"'edge-tier' for a {len(self.tier_blocks) + 1}-tier "
                    f"chain")
            prog = self.TIER_PROGRAMS[tier_policy]
            if prog is not None:
                self.mm.attach_tier_program(prog())
        else:
            self.mm = MemoryManager(layout.num_blocks, cost,
                                    default_mode=default_mode, damon_seed=seed,
                                    telemetry=self.telemetry,
                                    injector=self.injector,
                                    containment=self.containment)
        self._pool_blocks = layout.num_blocks + sum(self.tier_blocks)
        self.mm.attach_reclaim_program(reclaim_lru_program())
        self.profiler: ProfileSynthesizer | None = None
        if policy == "ebpf":
            if profile is None:
                raise ValueError(
                    "policy='ebpf' needs a profile (or list, or 'auto')")
            if isinstance(profile, str):
                if profile != "auto":
                    raise ValueError(f"unknown profile mode {profile!r}")
                # Online profiling plane: start with NO profile loaded.  A
                # verified profiler program samples the live DAMON regions
                # on the mm tick (HOOK_PROFILE) and the ProfileSynthesizer
                # hot-reloads synthesized profiles mid-run, so placement
                # converges to what an offline profiling run would load.
                # max_regions=8 keeps the fault program's verified search
                # loop the same shape as a small offline profile's.
                bound = 8
                # an EMPTY profile registers the map slot the verifier's
                # indirect-load check needs; the synthesizer's reloads are
                # map WRITEs into slots registered the same way
                self.mm.load_profile(Profile("_default", []))
                self.mm.attach_fault_program(
                    ebpf_mm_program(max_regions=bound))
                self.mm.attach_profile_program(profile_wss_program())
                self.profiler = ProfileSynthesizer(
                    self.mm, cost, period=profile_period,
                    max_regions=bound, telemetry=self.telemetry)
                self.mm.hooks.warm(HOOK_PROFILE, max_batch=16)
            else:
                profiles = profile if isinstance(profile, (list, tuple)) \
                    else [profile]
                for prof in profiles:
                    self.mm.load_profile(prof)
                # One program serves every app via the indirect profile-map
                # load.  The verified search loop is right-sized to the
                # profiles actually loaded (rounded up to a power of two):
                # it keeps the predicated batch executor's one-time compile
                # fast without changing any decision.
                nreg = max((len(p.regions) for p in profiles), default=0)
                bound = min(max(8, 1 << max(0, nreg - 1).bit_length()),
                            MAX_PROFILE_REGIONS)
                self.mm.attach_fault_program(
                    ebpf_mm_program(max_regions=bound))
        elif policy == "thp-prog":
            self.mm.attach_fault_program(thp_always_program())
        elif policy == "never-prog":
            self.mm.attach_fault_program(never_program())
        elif policy not in ("thp", "never"):
            raise ValueError(f"unknown policy {policy!r}")
        if self.batch_faults:
            # Build + compile the hook batch backends NOW (decode-sized
            # bucket), not on the first faulting step or the first batched
            # tier placement: warmup consults the cross-session artifact
            # cache (.cache/), so a process that has seen these programs
            # before skips the unroll and the XLA compile instead of
            # re-paying them mid-serve.
            self.mm.hooks.warm(HOOK_FAULT, max_batch=max_batch)
            self.mm.hooks.warm(HOOK_TIER, max_batch=max_batch)

        # Cross-request KV prefix cache: content-addressed shared prefix
        # blocks, admission via read-only borrows + CoW, HOOK_EVICT-driven
        # eviction into the tier chain.  prefix_cache=True sizes the budget
        # at a quarter of HBM; an int is an explicit cap in blocks.
        self.prefix_cache: PrefixCache | None = None
        if prefix_cache:
            bad = [k for k in cfg.layer_kinds() if k != "a"]
            if bad or cfg.enc_dec or cfg.vlm_patches \
                    or cfg.attn.mrope_sections is not None:
                raise ValueError(
                    "prefix_cache requires a plain all-attention decoder "
                    "(sequential state — mamba/enc-dec/vlm — cannot skip "
                    "prefix compute)")
            cap = (int(prefix_cache) if not isinstance(prefix_cache, bool)
                   else max(1, layout.num_blocks // 4))
            self.prefix_cache = PrefixCache(
                self.mm, layout.block_tokens, cap_blocks=cap,
                telemetry=self.telemetry)
            if evict_policy not in self.EVICT_PROGRAMS:
                raise ValueError(f"unknown evict_policy {evict_policy!r}")
            eprog = self.EVICT_PROGRAMS[evict_policy]
            if eprog is not None:
                self.mm.attach_evict_program(eprog())
                # a scan's ctx batch is ONE ROW PER ENTRY, and the entry
                # count can transiently reach ~2x the budget between scans
                # — warm every pow2 bucket up to that, or the first
                # over-budget scan compiles mid-serve
                warm_to = 1 << max(4, (2 * cap - 1).bit_length())
                self.mm.hooks.warm(HOOK_EVICT,
                                   max_batch=min(512, warm_to))
        self.evict_policy = evict_policy if self.prefix_cache else None

        self.khugepaged = (Khugepaged(self.mm, KhugepagedConfig())
                           if (khugepaged and policy == "ebpf") else None)
        pool_layout = layout if not tiered else PagedLayout(
            num_blocks=self._pool_blocks, block_tokens=layout.block_tokens,
            max_blocks=layout.max_blocks)
        self.cache = cache_init(cfg, pool_layout, max_batch, cache_dtype)
        self.sampler = Sampler(seed=seed)
        self.stats = EngineStats()

        self.waiting: list[Request] = []
        self.active: dict[int, SeqState] = {}    # slot -> seq
        self._next_pid = 1
        self.finished: dict[int, list[int]] = {}
        # rid -> [trace-clock t0, wall t0 or None once TTFT was observed]:
        # per-request serving-latency bookkeeping (telemetry only)
        self._req_t0: dict[int, list] = {}
        # per-app aggregate per-logical-block heat — the DAMON trace used by
        # offline profiling (profile_from_heat)
        self.heat_histograms: dict[str, np.ndarray] = {}

        # Device-resident management plane: the [B, max_blocks] block table
        # lives ON DEVICE as a persistent buffer; the host ships only dirty
        # rows (version-tracked in DeviceBlockTables) and both compiled
        # entries fold the row install into their single dispatch — the
        # decode step is table-install + policy-consume + kernel in ONE jit.
        MB = layout.max_blocks
        self._tables = DeviceBlockTables(max_batch, MB)
        self._table_buf = jnp.full((max_batch, MB), -1, jnp.int32)

        def _install_rows(buf, didx, drows, tri):
            # dirty rows are bucket-padded with idx -1: route pads out of
            # bounds and drop, same convention as the KV scatter.  Delta
            # triples (row, col, value) follow the same -1-row pad route.
            safe = jnp.where(didx >= 0, didx, buf.shape[0])
            buf = buf.at[safe].set(drows, mode="drop")
            trow = jnp.where(tri[:, 0] >= 0, tri[:, 0], buf.shape[0])
            return buf.at[trow, tri[:, 1]].set(tri[:, 2], mode="drop")

        def _decode_entry(p, c, buf, didx, drows, tri, t, l, act, pos3d):
            buf = _install_rows(buf, didx, drows, tri)
            logits, new_cache, heat = decode_step(
                p, cfg, c, t, l, buf, layout, active=act, pos3d=pos3d,
                attn_impl="gather")
            return logits, new_cache, heat, buf

        def _prefill_entry(p, c, buf, didx, drows, tri, t, slot, last, **kw):
            buf = _install_rows(buf, didx, drows, tri)
            table = jax.lax.dynamic_slice_in_dim(buf, slot, 1, 0)
            logits, new_cache = prefill_step(
                p, cfg, c, t, table, layout, chunk=256, last_index=last,
                **kw)
            return logits, new_cache, buf

        def _prefill_sfx_entry(p, c, buf, didx, drows, tri, t, slot, plen,
                               last, *, key_blocks):
            # cache-hit admission: prefill ONLY the uncached suffix; the
            # prefix KV is already in the pool behind the shared mappings
            buf = _install_rows(buf, didx, drows, tri)
            table = jax.lax.dynamic_slice_in_dim(buf, slot, 1, 0)
            logits, new_cache = prefill_suffix_step(
                p, cfg, c, t, table, layout, prefix_len=plen,
                key_blocks=key_blocks, chunk=256, last_index=last)
            return logits, new_cache, buf

        pool_blocks = self._pool_blocks

        def _moves_entry(cache, src, dst):
            # one fused KV block-copy over every paged pool leaf; pad
            # entries carry dst=-1 -> routed out of bounds and dropped
            def move(path, leaf):
                key = path[-1].key if hasattr(path[-1], "key") \
                    else str(path[-1])
                if key not in self._POOL_KEYS:
                    return leaf
                if leaf.ndim >= 2 and leaf.shape[0] != pool_blocks:
                    d = jnp.where(dst >= 0, dst, leaf.shape[1])
                    return leaf.at[:, d].set(leaf[:, src], mode="drop")
                d = jnp.where(dst >= 0, dst, leaf.shape[0])
                return leaf.at[d].set(leaf[src], mode="drop")
            return jax.tree_util.tree_map_with_path(move, cache)

        self._decode = jax.jit(_decode_entry)
        self._prefill = jax.jit(_prefill_entry)
        self._prefill_sfx = jax.jit(_prefill_sfx_entry,
                                    static_argnames=("key_blocks",))
        self._moves = jax.jit(_moves_entry)

    # ----------------------------------------------------------------- admin
    def _span(self, name: str, tid: str = "engine"):
        tel = self.telemetry
        if tel is None or not tel.trace_enabled:
            return nullcontext()
        return tel.span(name, cat="engine", tid=tid)

    def submit(self, req: Request) -> None:
        tel = self.telemetry
        if tel is not None and tel.enabled and req.rid not in self._req_t0:
            self._req_t0[req.rid] = [tel.now(), time.perf_counter_ns()]
        self.waiting.append(req)

    def _free_slots(self) -> list[int]:
        return [s for s in range(self.max_batch) if s not in self.active]

    def _blocks_needed(self, tokens: int) -> int:
        return -(-tokens // self.layout.block_tokens)

    # --------------------------------------------------------------- prefill
    def _admit(self) -> None:
        bt = self.layout.block_tokens
        for slot in self._free_slots():
            if not self.waiting:
                break
            req = self.waiting.pop(0)
            pid = self._next_pid
            self._next_pid += 1
            total = len(req.prompt) + req.max_new_tokens
            vma_blocks = min(self._blocks_needed(total) + 1,
                             self.layout.max_blocks)
            app = req.app
            if app is None and self.profiler is not None:
                # auto-profiling keys synthesized profiles by app — give
                # unlabeled requests the shared default bucket so the
                # profile map lookup has something to hit
                app = "_default"
            self.mm.create_process(pid, app=app, vma_blocks=vma_blocks)
            nblocks = self._blocks_needed(len(req.prompt))
            # prefix-cache admission: borrow the longest cached prefix
            # read-only (page-table surgery, no kernel work), fault only the
            # uncached suffix blocks, CoW-break a partially shared tail
            match = (self.prefix_cache.acquire(pid, req.prompt)
                     if self.prefix_cache is not None else None)
            n_shared = 0
            if match is not None:
                self.mm.map_shared(pid, 0, match.blocks)
                n_shared = len(match.blocks)
            if self.batch_faults:
                # the whole prefill span resolves through ONE policy
                # invocation (bulk FaultKind.PREFILL placement hints)
                fault_fn = lambda p=pid, s=n_shared, n=nblocks: \
                    self.mm.fault_range(p, s, n)  # noqa: E731
            else:
                fault_fn = lambda p=pid, s=n_shared, n=nblocks: \
                    self.mm.ensure_range(p, s, n)  # noqa: E731
            ok = self._ensure_with_reclaim(fault_fn, pid, nblocks - n_shared,
                                           allow_preempt=False)
            if ok and match is not None and match.cow_logical is not None:
                # the suffix prefill writes INSIDE the last borrowed block —
                # break the share first (private copy rides the move list)
                ok = self._ensure_with_reclaim(
                    lambda p=pid, a=match.cow_logical: self.mm.cow_break(p, a),
                    pid, 1, allow_preempt=False)
            if not ok:
                if match is not None:
                    self.prefix_cache.release(match)
                self.mm.free_process(pid)
                self.waiting.insert(0, req)
                break
            # land any demotion/compaction/CoW copies before prefill writes
            # the pool (same pre-kernel ordering as the decode path)
            self._apply_pending_moves()
            seq = SeqState(req=req, pid=pid, slot=slot,
                           length=len(req.prompt), prefix=match)
            self.active[slot] = seq
            with self._span(f"prefill rid={req.rid}"):
                self._run_prefill(seq)
            tel = self.telemetry
            rec = self._req_t0.get(req.rid)
            if tel is not None and rec is not None and rec[1] is not None:
                # the prefill above sampled the request's first token
                tel.observe_ttft(time.perf_counter_ns() - rec[1])
                rec[1] = None
            if self.prefix_cache is not None:
                # cache every whole block of the freshly prefilled prompt
                # (existing chain entries are skipped; copies ride the next
                # move-list drain, before any kernel can touch the donor)
                self.prefix_cache.insert(pid, req.prompt)
            self.stats.prefills += 1

    def _slot_pids(self) -> list:
        """Current slot -> pid assignment (None for empty slots)."""
        sp: list = [None] * self.max_batch
        for slot, seq in self.active.items():
            sp[slot] = seq.pid
        return sp

    def _sync_tables(self, slot_pids) -> tuple:
        """Dirty-row sync of the device-resident block tables.

        Returns ``(didx, drows, active, triples)`` with both the full-row
        dirty set and the delta-triple set bucket-padded to a power of two
        (pad idx / pad row = -1, dropped by the install scatter) so the
        fused entries compile once per bucket pair, not once per dirty
        count."""
        idx, rows, active, tri = self._tables.sync(self.mm, slot_pids)
        K = len(idx)
        bucket = 1 << (K - 1).bit_length() if K else 0
        if bucket > K:
            idx = np.concatenate(
                [idx, np.full(bucket - K, -1, np.int32)])
            rows = np.concatenate(
                [rows, np.zeros((bucket - K, self.layout.max_blocks),
                                np.int32)])
        T = len(tri)
        tbucket = 1 << (T - 1).bit_length() if T else 0
        if tbucket > T:
            pad = np.zeros((tbucket - T, 3), np.int32)
            pad[:, 0] = -1          # row -1 routes the pad out of bounds
            tri = np.concatenate([tri, pad])
        return jnp.asarray(idx), jnp.asarray(rows), active, jnp.asarray(tri)

    def _run_prefill(self, seq: SeqState) -> None:
        bt = self.layout.block_tokens
        prompt = np.asarray(seq.req.prompt, np.int32)
        match = seq.prefix
        if match is not None and match.tokens > 0:
            self._run_prefill_suffix(seq, match)
            return
        S_pad = self._blocks_needed(len(prompt)) * bt
        toks = np.zeros((1, S_pad), np.int32)
        toks[0, :len(prompt)] = prompt
        # the new pid's row arrives as a dirty-row upload; the prefill jit
        # installs it and slices the slot's row from the persistent buffer
        didx, drows, _active, tri = self._sync_tables(self._slot_pids())
        kw = self._modality_kwargs(1, S_pad)
        sub_cache = jax.tree.map(lambda c: c, self.cache)  # pools are shared
        logits, new_cache, self._table_buf = self._prefill(
            self.params, self._slot_cache_view(seq.slot), self._table_buf,
            didx, drows, tri, jnp.asarray(toks),
            jnp.asarray(seq.slot, jnp.int32),
            jnp.asarray([len(prompt) - 1], jnp.int32),
            **kw)
        self._merge_slot_cache(seq.slot, new_cache)
        self.mm.record_access(seq.pid,
                              np.ones(self._blocks_needed(len(prompt))))
        self.stats.prefill_tokens += len(prompt)
        tok = self.sampler.sample(np.asarray(logits)[0],
                                  self.cfg.vocab, seq.req.temperature)
        seq.generated.append(int(tok))

    def _run_prefill_suffix(self, seq: SeqState, match) -> None:
        """Cache-hit prefill: the first ``match.tokens`` tokens' KV is
        already in the pool (shared mappings + a CoW-broken tail), so only
        the suffix runs through the kernel.  The suffix jit assembles the
        full-length key stream — pool-gathered prefix + computed suffix —
        with the SAME padded length and chunking as the full prefill, which
        is what keeps its outputs bit-identical to the full path's suffix
        rows (the garbage tail past the valid tokens is causally masked to
        an exact-zero contribution)."""
        bt = self.layout.block_tokens
        prompt = np.asarray(seq.req.prompt, np.int32)
        L = len(prompt)
        S0 = match.tokens
        KB = self._blocks_needed(L)         # static: whole prompt's blocks
        SB = self._blocks_needed(L - S0) * bt
        toks = np.zeros((1, SB), np.int32)
        toks[0, :L - S0] = prompt[S0:]
        didx, drows, _active, tri = self._sync_tables(self._slot_pids())
        logits, new_cache, self._table_buf = self._prefill_sfx(
            self.params, self._slot_cache_view(seq.slot), self._table_buf,
            didx, drows, tri, jnp.asarray(toks),
            jnp.asarray(seq.slot, jnp.int32),
            jnp.asarray(S0, jnp.int32),
            jnp.asarray([L - S0 - 1], jnp.int32),
            key_blocks=KB)
        self._merge_slot_cache(seq.slot, new_cache)
        self.mm.record_access(seq.pid, np.ones(KB))
        self.stats.prefill_tokens += L - S0
        tok = self.sampler.sample(np.asarray(logits)[0],
                                  self.cfg.vocab, seq.req.temperature)
        seq.generated.append(int(tok))

    # -------------------------------------------------- per-slot cache views
    # Pools (block dim) are global — shared across slots.  Per-sequence state
    # (mamba ssm/conv, whisper cross-attn) is slot-indexed.  The prefill runs
    # with batch=1, so slice those leaves out and merge them back.
    _POOL_KEYS = ("pool_k", "pool_v", "pool_ckv")

    def _slot_cache_view(self, slot: int) -> Pytree:
        def f(path, leaf):
            key = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
            if key in self._POOL_KEYS:
                return leaf
            # batch-indexed leaf: [reps, B, ...] or [B, ...]
            if leaf.ndim >= 2 and key in ("ssm", "conv", "xk", "xv"):
                axis = 1 if leaf.shape[0] != self.max_batch else 0
                return jax.lax.dynamic_slice_in_dim(leaf, slot, 1, axis)
            return leaf
        return jax.tree_util.tree_map_with_path(f, self.cache)

    def _merge_slot_cache(self, slot: int, new_cache: Pytree) -> None:
        def f(path, old, new):
            key = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
            if key in self._POOL_KEYS:
                return new
            if old.ndim >= 2 and key in ("ssm", "conv", "xk", "xv"):
                axis = 1 if old.shape[0] != self.max_batch else 0
                return jax.lax.dynamic_update_slice_in_dim(
                    old, new.astype(old.dtype), slot, axis)
            return new
        self.cache = jax.tree_util.tree_map_with_path(f, self.cache, new_cache)

    def _modality_kwargs(self, batch: int, seq_len: int) -> dict:
        """Synthetic modality inputs (audio frames / vision patches).

        The prefill path always calls with ``batch == 1``, so they are a
        fixed function of the seed: generated ONCE and sliced per call —
        regenerating them from a fresh numpy RNG on every prefill was pure
        host overhead; slicing the cached full-size draw yields exactly the
        values the per-call draw produced (row-major fill order)."""
        assert batch == 1, "prefill runs one sequence at a time"
        kw = {}
        if self.cfg.enc_dec or self.cfg.vlm_patches:
            if not self._modal_cache:
                rng = np.random.default_rng(0)
                if self.cfg.enc_dec:
                    self._modal_cache["frames"] = jnp.asarray(rng.normal(
                        size=(1, self.cfg.enc_frames, self.cfg.d_model))
                        .astype(np.float32))
                if self.cfg.vlm_patches:
                    self._modal_cache["patches"] = rng.normal(
                        size=(1, self.cfg.vlm_patches, self.cfg.d_model)
                        ).astype(np.float32)
                    self._modal_cache["patch_views"] = {}
                    self._modal_cache["pos3d"] = {}
        if self.cfg.enc_dec:
            kw["frames"] = self._modal_cache["frames"]
        if self.cfg.vlm_patches:
            P = min(self.cfg.vlm_patches, seq_len)
            views = self._modal_cache["patch_views"]
            if P not in views:
                views[P] = jnp.asarray(self._modal_cache["patches"][:, :P])
            kw["patches"] = views[P]
            pos_cache = self._modal_cache["pos3d"]
            if seq_len not in pos_cache:
                pos_cache[seq_len] = jnp.asarray(np.tile(
                    np.arange(seq_len, dtype=np.float32), (3, 1, 1)))
            kw["pos3d"] = pos_cache[seq_len]
        return kw

    # ---------------------------------------------------------------- reclaim
    def _ensure_with_reclaim(self, fault_fn, faulting_pid: int,
                             need_blocks: int, *,
                             allow_preempt: bool = True) -> bool:
        """Run a fault entry point, relieving pressure on MMOutOfMemory.

        Demote-before-preempt: each OOM first tries to free HBM by demoting
        cold blocks to the host tier — scanning all processes coldest-first
        with the nominated victim's pages preferred (a single long sequence
        spills its own cold prefix this way).  Demotion reliefs retry as
        often as they make progress; whole-sequence preemption is the
        fallback when both tiers are exhausted (or the tier policy vetoes
        every candidate) and fires AT MOST ONCE per fault.  Admission passes
        ``allow_preempt=False`` (the waiting-queue watermark): a request that
        does not fit waits for completions instead of evicting the running
        batch — the admission-evicts-actives livelock the ROADMAP calls out.
        """
        preempted = False
        for _ in range(4 + 2 * need_blocks + self.max_batch):
            try:
                fault_fn()
                return True
            except MMOutOfMemory as oom:
                # cheapest relief first: evict/demote UNPINNED prefix-cache
                # entries (cache blocks are speculative capacity — a live
                # sequence always outranks them)
                if self.prefix_cache is not None and \
                        self.prefix_cache.scan(max(1, need_blocks)) > 0:
                    continue
                if isinstance(self.mm, TieredMemoryManager) and \
                        self.mm.demote_cold_global(
                            need_blocks, prefer_pid=oom.victim_pid) > 0:
                    self.stats.tier_reliefs += 1
                    continue
                if not allow_preempt or preempted or oom.victim_pid is None:
                    return False
                self._preempt(oom.victim_pid)
                preempted = True
        return False

    # ---------------------------------------------------------------- decode
    def _preempt(self, victim_pid: int | None) -> None:
        if victim_pid is None:
            raise MMOutOfMemory("pool exhausted and nothing to evict")
        tel = self.telemetry
        for slot, seq in list(self.active.items()):
            if seq.pid == victim_pid:
                if seq.prefix is not None:
                    self.prefix_cache.release(seq.prefix)
                self.mm.evict_process(victim_pid)
                del self.active[slot]
                self.waiting.insert(0, seq.req)   # recompute-from-scratch
                self.stats.preemptions += 1
                if tel is not None and tel.enabled:
                    tel.emit(EV_PREEMPT, victim_pid, seq.req.rid, seq.length,
                             ts=self.mm.ktime_ns)
                return
        self.mm.evict_process(victim_pid)

    def step(self) -> bool:
        """One engine iteration. Returns False when all work is done."""
        t0 = time.monotonic()
        tel = self.telemetry
        with self._span(f"step {self.stats.steps}"):
            self._admit()
            if not self.active and not self.waiting:
                return False
            if self.active:
                tok0 = self.stats.decode_tokens
                d0 = time.perf_counter_ns()
                with self._span("decode"):
                    self._decode_once()
                if tel is not None and tel.enabled:
                    tel.observe_decode_token(
                        time.perf_counter_ns() - d0,
                        self.stats.decode_tokens - tok0)
            with self._span("mm-tick", tid="mm"):
                if self.khugepaged is not None:
                    self.khugepaged.tick()
                if isinstance(self.mm, TieredMemoryManager):
                    # background promotion: bring re-heated host-tier pages
                    # back to HBM
                    self.mm.promotion_scan()
                if self.prefix_cache is not None:
                    # periodic eviction cadence (batched HOOK_EVICT scan)
                    self.prefix_cache.tick()
                self._apply_pending_moves()
                self.mm.tick()
                if self.profiler is not None and self.active:
                    # sampled HOOK_PROFILE scan + profile synthesis/reload
                    self.profiler.tick(
                        [(seq.pid, self.mm.procs[seq.pid].app)
                         for seq in self.active.values()
                         if seq.pid in self.mm.procs])
        self.stats.steps += 1
        dt = time.monotonic() - t0
        self.stats.wall_host_s += dt
        if tel is not None and tel.enabled:
            tel.mgmt_step_ns.observe(int(dt * 1e9))
        return bool(self.active or self.waiting)

    def _fault_slots_batched(self) -> set[int]:
        """Resolve every active slot's potential boundary crossing through a
        single ``fault_batch`` — with a fault program attached, a full decode
        step issues exactly ONE policy invocation.  OOM relief mirrors the
        scalar path: demote-before-preempt on a tiered pool (retrying while
        demotion makes progress, preempting at most once), plain preemption
        otherwise.  Returns the slots whose block is mapped (safe to decode).
        """
        bt = self.layout.block_tokens
        tiered = isinstance(self.mm, TieredMemoryManager)
        pending = [(slot, seq.pid, seq.length // bt)
                   for slot, seq in sorted(self.active.items())]
        preempted = False
        for _ in range(4 + 2 * len(pending) + self.max_batch):
            pending = [(s, p, a) for s, p, a in pending
                       if s in self.active and self.active[s].pid == p]
            if not pending:
                break
            try:
                self.mm.fault_batch([(p, a, FaultKind.FIRST_TOUCH)
                                     for _, p, a in pending])
                break
            except MMOutOfMemory as oom:
                if self.prefix_cache is not None and \
                        self.prefix_cache.scan(1) > 0:
                    continue
                if tiered and self.mm.demote_cold_global(
                        1, prefer_pid=oom.victim_pid) > 0:
                    self.stats.tier_reliefs += 1
                    continue
                if oom.victim_pid is None or (tiered and preempted):
                    break
                self._preempt(oom.victim_pid)
                preempted = True
        return {slot for slot, seq in self.active.items()
                if (seq.length // bt) in self.mm.procs[seq.pid].mapped}

    def _fault_slots_scalar(self) -> set[int]:
        """Pre-batching fault path: one ``ensure_mapped`` (one ctx build, one
        policy invocation) per faulting slot.  Kept for the hot-path
        benchmark baseline and as the reference semantics."""
        tiered = isinstance(self.mm, TieredMemoryManager)
        ok: set[int] = set()
        for slot, seq in list(self.active.items()):
            if slot not in self.active:       # preempted earlier this pass
                continue
            addr = seq.length // self.layout.block_tokens
            if tiered:
                good = self._ensure_with_reclaim(
                    lambda p=seq.pid, a=addr: self.mm.ensure_mapped(p, a),
                    seq.pid, 1)
                if good:
                    ok.add(slot)
                continue
            try:
                self.mm.ensure_mapped(seq.pid, addr)
                ok.add(slot)
            except MMOutOfMemory as oom:
                self._preempt(oom.victim_pid)
        # drop slots preempted while relieving a later slot's fault
        ok = {s for s in ok if s in self.active}
        if tiered and ok:
            # decode-time tier placement: consult HOOK_TIER for the blocks
            # this step just installed, mirroring the batched route (where
            # fault_batch runs the first-touch placement pass itself)
            bt = self.layout.block_tokens
            self.mm.place_decode(
                [(self.active[s].pid, self.active[s].length // bt,
                  FaultKind.FIRST_TOUCH) for s in sorted(ok)])
        return ok

    def _decode_once(self) -> None:
        B, MB = self.max_batch, self.layout.max_blocks
        tokens = np.zeros(B, np.int32)
        lengths = np.zeros(B, np.int32)
        # page-fault path: each active slot's new token may cross a block
        # boundary; the batched route resolves the whole step in one policy
        # invocation
        if self.batch_faults:
            ok_slots = self._fault_slots_batched()
        else:
            ok_slots = self._fault_slots_scalar()
        # Flush demotion/promotion/compaction copies BEFORE the kernel
        # touches the pool: a fault above may have freed block A and
        # re-allocated it — the copy must land before decode overwrites A —
        # and BEFORE syncing the device tables, which a later slot's reclaim
        # or compaction may have remapped (the move bumps table_version, so
        # the sync below re-uploads the row; syncing earlier would publish
        # the pre-move mapping to the device for this step).
        self._apply_pending_moves()
        skipped: set[int] = set()     # slots that must not advance this step
        slot_pids: list = [None] * B
        for slot, seq in self.active.items():
            if slot not in ok_slots:
                # pool truly exhausted for this slot (retry next step) or it
                # was preempted relieving another slot
                skipped.add(slot)
                continue
            tokens[slot] = seq.generated[-1]
            lengths[slot] = seq.length
            slot_pids[slot] = seq.pid
        # dirty-row upload: only rows whose table_version moved since the
        # last sync cross to the device; skipped slots sync as vacant so
        # their persistent rows cannot alias live pool blocks
        didx, drows, active, tri = self._sync_tables(slot_pids)
        pos3d = None
        if self.cfg.vlm_patches:
            pos3d = jnp.asarray(
                np.tile(lengths.astype(np.float32)[None, :, None], (3, 1, 1)))
        logits, self.cache, heat, self._table_buf = self._decode(
            self.params, self.cache, self._table_buf, didx, drows, tri,
            jnp.asarray(tokens), jnp.asarray(lengths),
            jnp.asarray(active), pos3d)
        logits_np = np.asarray(logits)
        heat_np = np.asarray(heat)
        for slot, seq in list(self.active.items()):
            if slot in skipped:
                # its batch row decoded with no block table — the logits are
                # garbage; the sequence stays put and refaults next step
                continue
            nb = self._blocks_needed(seq.length + 1)
            self.mm.record_access(seq.pid, heat_np[slot, :nb])
            if seq.prefix is not None:
                # fold the borrower's attention mass over the shared span
                # into the matched entries' heat EMAs (the DAMON signal the
                # eviction programs read as PAGE_HEAT)
                self.prefix_cache.note_heat(seq.prefix, heat_np[slot, :nb])
            app = seq.req.app or "_default"
            if app not in self.heat_histograms:
                self.heat_histograms[app] = np.zeros(self.layout.max_blocks,
                                                     np.float64)
            self.heat_histograms[app][:nb] += heat_np[slot, :nb]
            tok = self.sampler.sample(logits_np[slot], self.cfg.vocab,
                                      seq.req.temperature)
            seq.generated.append(int(tok))
            seq.length += 1
            self.stats.decode_tokens += 1
            limit = seq.req.max_new_tokens
            if seq.req.stop_after is not None:
                limit = min(limit, seq.req.stop_after)
            if len(seq.generated) >= limit:
                self.finished[seq.req.rid] = list(seq.generated)
                if seq.prefix is not None:
                    self.prefix_cache.release(seq.prefix)
                self.mm.free_process(seq.pid)
                del self.active[slot]
                self.stats.completed += 1
                tel = self.telemetry
                rec = self._req_t0.pop(seq.req.rid, None)
                if rec is not None and tel is not None and tel.trace_enabled:
                    # whole-request span (submit -> last token) on its own
                    # trace row
                    tel.spans.append((f"req {seq.req.rid}", "request",
                                      "requests", rec[0],
                                      tel.now() - rec[0]))

    def _apply_pending_moves(self) -> None:
        moves = self.mm.drain_moves()
        if not moves:
            return
        # A batched .at[dst].set(leaf[src]) reads every src from the PRE-move
        # pool, so a chain within one drain (compact A->B, then demote B->H)
        # would copy stale data; and a repeated destination (block freed and
        # re-allocated within the drain) makes the scatter winner undefined.
        # Segment the list so no batch reads OR writes a block an earlier
        # move in the same batch wrote; batches apply in order.
        batches: list[list[tuple[int, int, int]]] = [[]]
        written: set[int] = set()
        for s, d, o in moves:
            n = order_blocks(o)
            if any(b in written for b in range(s, s + n)) or \
                    any(b in written for b in range(d, d + n)):
                batches.append([])
                written = set()
            batches[-1].append((s, d, o))
            written.update(range(d, d + n))
        for batch in batches:
            self._apply_move_batch(batch)

    def _apply_move_batch(self, moves: list[tuple[int, int, int]]) -> None:
        src = np.concatenate([np.arange(s, s + order_blocks(o))
                              for s, _, o in moves]).astype(np.int32)
        dst = np.concatenate([np.arange(d, d + order_blocks(o))
                              for _, d, o in moves]).astype(np.int32)
        # pow2 bucket so the fused tree-wide copy compiles once per bucket
        # (pad dst = -1 routes out of bounds and is dropped); one dispatch
        # replaces an eager scatter per pool leaf — prefix-cache insert
        # copies and steady migration traffic both ride this path
        P = 1 << (len(src) - 1).bit_length()
        if P > len(src):
            src = np.concatenate([src, np.zeros(P - len(src), np.int32)])
            dst = np.concatenate([dst, np.full(P - len(dst), -1, np.int32)])
        self.cache = self._moves(self.cache, jnp.asarray(src),
                                 jnp.asarray(dst))

    # ------------------------------------------------------------------ run
    def run(self, max_steps: int = 10_000) -> dict:
        steps = 0
        while self.step():
            steps += 1
            if steps >= max_steps:
                break
        out = {"engine": self.stats.snapshot(), "mm": self.mm.stats.snapshot(),
               "huge_fraction": self.mm.hugepage_block_fraction(),
               "tables": {"syncs": self._tables.syncs,
                          "synced_rows": self._tables.synced_rows,
                          "blank_rows": self._tables.blank_rows,
                          "full_rows": self._tables.full_rows,
                          "delta_rows": self._tables.delta_rows,
                          "delta_cells": self._tables.delta_cells}}
        if isinstance(self.mm, TieredMemoryManager):
            out["tier"] = self.mm.tier_snapshot()
        if self.prefix_cache is not None:
            out["prefix_cache"] = self.prefix_cache.snapshot()
        if self.khugepaged is not None:
            out["khugepaged"] = {"collapsed": self.khugepaged.collapsed,
                                 "considered": self.khugepaged.considered}
        if self.profiler is not None:
            out["profiler"] = self.profiler.snapshot()
        if self.telemetry is not None and self.telemetry.enabled:
            out["telemetry"] = self.telemetry.snapshot()
        return out

    # ------------------------------------------------------------ telemetry
    def write_trace(self, path) -> None:
        """Write the Chrome trace-event JSON (load in Perfetto / chrome://
        tracing): engine spans on the wall-clock track, mm/program ring
        events on the modeled-clock track."""
        if self.telemetry is None:
            raise ValueError("engine was built without telemetry "
                             "(pass trace=True or telemetry=...)")
        write_chrome_trace(self.telemetry, path)

    def write_wss_curve(self, path) -> None:
        """Dump the online profiler's per-process WSS curve as JSON
        (samples of modeled time / WSS blocks / mapped blocks)."""
        if self.profiler is None:
            raise ValueError("engine has no online profiler "
                             "(pass profile='auto')")
        self.profiler.write_wss_curve(path)

    def metrics(self) -> dict:
        """Flat ``{metric_name: number}`` snapshot across every subsystem:
        engine stats, mm stats, hook counters, artifact-cache stats, tier
        pools, and (when telemetry is on) histograms/counters/ring stats."""
        sections = {
            "engine": self.stats.snapshot(),
            "mm": self.mm.stats.snapshot(),
            "huge_fraction": self.mm.hugepage_block_fraction(),
            "hooks": {"invocations": self.mm.hooks.invocations,
                      "calls": self.mm.hooks.calls,
                      "batch_calls": self.mm.hooks.batch_calls},
            "cache": self.mm.hooks._artifact_cache().stats,
        }
        res: dict = {"supervisor": self.mm.hooks.supervisor.snapshot()}
        if self.injector is not None:
            res["injector"] = self.injector.snapshot()
        if isinstance(self.mm, TieredMemoryManager):
            sections["tier"] = self.mm.tier_snapshot()
            res["health"] = self.mm.health.snapshot()
        if self.prefix_cache is not None:
            sections["prefix_cache"] = self.prefix_cache.snapshot()
        if self.profiler is not None:
            sections["profiler"] = self.profiler.snapshot()
        sections["resilience"] = res
        if self.telemetry is not None and self.telemetry.enabled:
            sections["telemetry"] = self.telemetry.snapshot()
        return flatten_metrics(sections)

    def metrics_text(self) -> str:
        """Prometheus-style text exposition of :meth:`metrics`."""
        return render_prometheus(self.metrics())

    def poll_events(self) -> list[dict]:
        """Drain and decode any ring events published since the last poll —
        the LIVE consumer path (mid-run), as opposed to the end-of-run
        ``write_trace`` export.  ``[]`` when telemetry is off."""
        if self.telemetry is None:
            return []
        return self.telemetry.poll_events()
