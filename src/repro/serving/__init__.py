from .engine import EngineStats, Request, ServingEngine
from .prefix_cache import PrefixCache, PrefixMatch, chunk_keys
from .sampler import Sampler

__all__ = ["EngineStats", "PrefixCache", "PrefixMatch", "Request",
           "Sampler", "ServingEngine", "chunk_keys"]
