from .engine import EngineStats, Request, ServingEngine
from .sampler import Sampler

__all__ = ["EngineStats", "Request", "ServingEngine", "Sampler"]
