"""Cross-request KV prefix cache with verified HOOK_EVICT eviction.

Serving traffic is dominated by shared prefixes — system prompts, few-shot
preambles, multi-turn histories.  This module keeps a CONTENT-ADDRESSED
index over KV blocks: prompts are chunked into token blocks, each chunk
keyed by a rolling hash of its contents chained through its predecessor
(so a chunk key commits to the entire prefix, not just its own tokens),
and each live entry owns one physical device block holding that chunk's
prefilled KV.

Admission (:meth:`PrefixCache.acquire`) walks the chain and returns the
longest cached prefix — whole blocks plus an optional partial tail — which
the engine maps READ-ONLY into the new sequence's page table
(``mm.map_shared``) and skips in prefill; only the uncached suffix runs
through the kernel.  A partial-tail share means the suffix prefill must
write into the shared block, so the engine breaks it first via
``mm.cow_break`` — the genuine copy-on-write path.  Entries are pinned
(refcounted) for the borrower's lifetime; insertion after a prefill COPIES
the new blocks into cache-owned storage (`mm.queue_block_copy` on the same
move list as migrations), so a donor finishing never invalidates the cache.

Eviction is a BPF decision, not a built-in heuristic: one batched
``HOOK_EVICT`` invocation per reclaim scan, each ctx row carrying the
entry's DAMON-style heat, refcount, age, hit count and size plus
cache-global budget/ghost state, each decision a TARGET TIER (demote cold
prefixes down the N-pool chain via ``mm.migrate_cache_block``) or
``EVICT_DROP``.  Entries are dropped ONLY when the program says so; with no
program attached a conservative LRU demote-then-drop default applies.  A
ghost FIFO of recently dropped keys measures over-eviction (the
Cache-is-King feedback signal surfaced to programs as CACHE_GHOST_HITS).
"""

from __future__ import annotations

import hashlib
from collections import OrderedDict
from dataclasses import dataclass, field

import numpy as np

from ..core.context import (CTX, EVICT_DROP, FIXED_POINT, POLICY_DETACHED,
                            POLICY_FALLBACK, ctx_batch, fill_system_columns)
from ..core.hooks import HOOK_EVICT
from ..obs.ringbuf import EV_CACHE_HIT, EV_EVICT

_ROOT = b"prefix-root"


def chunk_keys(tokens, block_tokens: int) -> list[bytes]:
    """Rolling-hash chain over whole token blocks.

    Key ``i`` digests (key ``i-1``, tokens of block ``i``), so equal keys
    imply equal FULL prefixes up to that block boundary (modulo hash
    collision — entries also store their tokens and lookups verify them,
    so a collision costs a miss, never a wrong share)."""
    toks = np.asarray(tokens, np.int64)
    n = toks.size // block_tokens
    keys: list[bytes] = []
    prev = _ROOT
    for i in range(n):
        h = hashlib.blake2b(prev, digest_size=16)
        h.update(toks[i * block_tokens:(i + 1) * block_tokens].tobytes())
        prev = h.digest()
        keys.append(prev)
    return keys


@dataclass
class CacheBlock:
    """One cache-owned physical block (tier-local coordinates)."""
    tier: int
    phys: int


@dataclass
class CacheEntry:
    key: bytes
    parent: bytes | None         # chain predecessor (None = first block)
    depth: int                   # logical block index within the prefix
    tokens: np.ndarray           # the block's tokens (collision guard)
    blk: CacheBlock
    eid: int                     # stable id for tracepoints
    refcount: int = 0
    hits: int = 0
    heat: float = 0.0            # EMA of forwarded attention mass
    last_hit_ns: int = 0
    created_ns: int = 0


@dataclass
class PrefixMatch:
    """A pinned admission-time match: release() exactly once."""
    pid: int
    entries: list[CacheEntry]
    tokens: int                  # shared token count (always <= prompt - 1)
    blocks: list[tuple[int, int]] = field(default_factory=list)
    cow_logical: int | None = None   # block the suffix will write into
    released: bool = False


class PrefixCache:
    """Content-addressed cross-request KV prefix cache.

    ``mm`` is the (possibly tiered) MemoryManager; physical blocks are
    allocated from its pools (``cache_alloc_block``) and live OUTSIDE any
    page table — borrowers reference them through ``shared=True`` mappings
    and compaction remaps arrive through the registered listener.
    """

    def __init__(self, mm, block_tokens: int, *, cap_blocks: int,
                 scan_period: int = 8, ghost_capacity: int = 1024,
                 doorkeeper: bool = True, door_capacity: int | None = None,
                 telemetry=None) -> None:
        self.mm = mm
        self.bt = int(block_tokens)
        self.cap_blocks = int(cap_blocks)
        self.scan_period = int(scan_period)
        self.telemetry = telemetry
        self.entries: dict[bytes, CacheEntry] = {}
        self.children: dict[bytes, set[bytes]] = {}
        self.ghost: OrderedDict[bytes, int] = OrderedDict()
        self.ghost_capacity = int(ghost_capacity)
        # TinyLFU-style doorkeeper: a chunk key must be SEEN once (or sit in
        # the ghost list — i.e. was cached before) before its block is
        # admitted.  One-hit-wonder prompts then cost two dict probes instead
        # of a device block copy each, and never churn the eviction scan.
        self.doorkeeper = bool(doorkeeper)
        self.door: OrderedDict[bytes, None] = OrderedDict()
        self.door_capacity = int(door_capacity if door_capacity is not None
                                 else max(4 * int(cap_blocks), 256))
        self.ntiers = len(getattr(mm, "pools", ())) or 1
        self._next_eid = 1
        self._ticks = 0
        self._last_scan = 0
        # stats (snapshot() exports)
        self.lookups = 0
        self.hits = 0                 # admissions that shared >= 1 block
        self.misses = 0
        self.ghost_hits = 0
        self.tokens_skipped = 0
        self.blocks_reused = 0
        self.inserted_blocks = 0
        self.door_rejects = 0
        self.evict_drops = 0
        self.evict_demotions = 0
        self.scans = 0
        mm.compaction_listeners.append(self._on_compaction)

    # ------------------------------------------------------------- accounting
    def _inc(self, name: str, v: int = 1) -> None:
        tel = self.telemetry
        if tel is not None and tel.enabled:
            tel.inc(name, v)

    def used_blocks(self, tier: int = 0) -> int:
        return sum(1 for e in self.entries.values() if e.blk.tier == tier)

    def _on_compaction(self, tier: int, remap: dict) -> None:
        """Cache-owned blocks live in no page table, so the compaction pass
        can't repoint them — this listener does."""
        for e in self.entries.values():
            if e.blk.tier == tier and e.blk.phys in remap:
                e.blk.phys = remap[e.blk.phys]

    # ----------------------------------------------------------------- lookup
    def _walk(self, tokens) -> tuple[list[CacheEntry], bytes | None]:
        """Longest verified chain for ``tokens``; also the first missing
        key (ghost probe)."""
        toks = np.asarray(tokens, np.int64)
        chain: list[CacheEntry] = []
        for i, key in enumerate(chunk_keys(toks, self.bt)):
            e = self.entries.get(key)
            if e is None:
                return chain, key
            blk = toks[i * self.bt:(i + 1) * self.bt]
            if not np.array_equal(e.tokens, blk):      # hash collision
                return chain, None
            chain.append(e)
        return chain, None

    def acquire(self, pid: int, tokens) -> PrefixMatch | None:
        """Longest cached prefix for a prompt, pinned for the borrower.

        The shared span is capped at ``len(tokens) - 1``: at least one
        token ALWAYS prefills, so the admission logits come off the same
        suffix-prefill path every time (never a special full-coverage
        decode).  Whole matched blocks are borrowed as-is; when the next
        chain entry matches a partial tail, its block is borrowed too and
        ``cow_logical`` names it — the suffix prefill will write inside
        it, so the engine must copy-on-write it first.  Returns None on a
        complete miss (nothing pinned)."""
        self.lookups += 1
        toks = np.asarray(tokens, np.int64)
        L = int(toks.size)
        if L < 2 or not self.entries:
            if L >= 2:
                self._ghost_probe(toks)
            self.misses += 1
            self._inc("prefix_cache_misses")
            return None
        chain, missing = self._walk(toks)
        if missing is not None and missing in self.ghost:
            self.ghost_hits += 1
            self._inc("prefix_cache_ghost_hits")
            self.ghost.move_to_end(missing)
        whole = min(len(chain), (L - 1) // self.bt)
        shared = whole * self.bt
        cow = None
        entries = chain[:whole]
        # partial tail: the NEXT chain entry may cover a few more tokens
        if whole < len(chain):
            nxt = chain[whole]
            rem = L - shared
            p = 0
            lim = min(rem - 1, self.bt)
            while p < lim and nxt.tokens[p] == toks[shared + p]:
                p += 1
            if p > 0:
                entries = chain[:whole] + [nxt]
                shared += p
                cow = whole
        if shared == 0:
            self.misses += 1
            self._inc("prefix_cache_misses")
            return None
        now = self.mm.ktime_ns
        for e in entries:
            e.refcount += 1
            e.hits += 1
            e.last_hit_ns = now
        self.hits += 1
        self.tokens_skipped += shared
        self.blocks_reused += len(entries)
        tel = self.telemetry
        if tel is not None and tel.enabled:
            tel.emit(EV_CACHE_HIT, pid, len(entries), shared, ts=now)
            tel.inc("prefix_cache_hits")
            tel.inc("prefix_tokens_skipped", shared)
        return PrefixMatch(pid=pid, entries=entries, tokens=shared,
                           blocks=[(e.blk.tier, e.blk.phys) for e in entries],
                           cow_logical=cow)

    def _ghost_probe(self, toks) -> None:
        keys = chunk_keys(toks, self.bt)
        if keys and keys[0] in self.ghost:
            self.ghost_hits += 1
            self._inc("prefix_cache_ghost_hits")
            self.ghost.move_to_end(keys[0])

    def release(self, match: PrefixMatch) -> None:
        """Unpin a borrower's chain (completion, preemption or failed
        admission)."""
        if match.released:
            return
        match.released = True
        for e in match.entries:
            e.refcount -= 1

    # ----------------------------------------------------------------- insert
    def _door_mark(self, key: bytes) -> None:
        door = self.door
        if key in door:
            door.move_to_end(key)
            return
        door[key] = None
        while len(door) > self.door_capacity:
            door.popitem(last=False)

    def insert(self, pid: int, tokens) -> int:
        """Cache the whole blocks of a freshly prefilled prompt.

        New entries get cache-owned HBM blocks and a queued device copy
        from the donor's pool blocks (flushed with the next move drain,
        before any kernel can overwrite the donor).  With the doorkeeper
        on (the default) an unseen chunk key is only NOTED on first sight
        and admitted when it shows up again (or was cached before — ghost
        hit): one-hit-wonder prompts never pay the copy or churn the scan.
        Insertion is opportunistic: when the pool can't supply a block the
        remaining chunks are skipped — never an OOM.  Returns blocks
        inserted."""
        toks = np.asarray(tokens, np.int64)
        n = toks.size // self.bt
        if n == 0:
            return 0
        table = self.mm.block_table(pid, n)
        keys = chunk_keys(toks, self.bt)
        inserted = 0
        parent: bytes | None = None
        now = self.mm.ktime_ns
        rejected = False            # chain invariant: once one chunk is
        for i, key in enumerate(keys):  # held at the door, descendants
            e = self.entries.get(key)   # have no parent to attach to
            if e is not None:
                parent = key
                continue
            if rejected or (self.doorkeeper and key not in self.door
                            and key not in self.ghost):
                self._door_mark(key)
                self.door_rejects += 1
                self._inc("prefix_cache_door_rejects")
                rejected = True
                continue
            if int(table[i]) < 0:       # unmapped (shouldn't happen post-
                break                   # prefill, but never trust a table)
            phys = self.mm.cache_alloc_block()
            if phys is None:
                break
            blk = CacheBlock(tier=0, phys=phys)
            self.mm.queue_block_copy(int(table[i]),
                                     self.mm.cache_device_index(0, phys))
            e = CacheEntry(key=key, parent=parent, depth=i,
                           tokens=toks[i * self.bt:(i + 1) * self.bt].copy(),
                           blk=blk, eid=self._next_eid, created_ns=now,
                           last_hit_ns=now)
            self._next_eid += 1
            self.entries[key] = e
            if parent is not None:
                self.children.setdefault(parent, set()).add(key)
            self.ghost.pop(key, None)
            self.door.pop(key, None)
            parent = key
            inserted += 1
        self.inserted_blocks += inserted
        if inserted:
            self._inc("prefix_cache_inserts", inserted)
        if self.used_blocks(0) > self.cap_blocks:
            self.scan()
        return inserted

    # ------------------------------------------------------------------- heat
    def note_heat(self, match: PrefixMatch, heat_rows) -> None:
        """Fold a borrower's per-logical-block attention mass into the
        matched entries' heat EMAs.  The engine calls this per decode step
        — entry ``i`` of the chain backs logical block ``i``, so the
        mapping is positional."""
        h = np.asarray(heat_rows, np.float64)
        for i, e in enumerate(match.entries):
            if i >= h.size:
                break
            e.heat = 0.5 * e.heat + float(h[i])

    # ---------------------------------------------------------------- faults
    def tick(self) -> None:
        """Reclaim cadence, driven from the engine's mm-tick.  Scans fire
        only over the HBM budget — every shipped program (and the kernel
        default) keeps entries while ``used <= cap``, so an under-budget
        scan is a guaranteed no-op whose batched dispatch would tax every
        serving step for nothing.  ``scan_period`` rate-limits the
        over-budget case (pinned entries can hold the pool over budget for
        many ticks; re-scanning every step won't free them any sooner)."""
        self._ticks += 1
        if self.used_blocks(0) > self.cap_blocks and \
                self._ticks - self._last_scan >= self.scan_period:
            self.scan()

    # --------------------------------------------------------------- eviction
    def _build_evict_ctx(self, cands: list[CacheEntry]) -> np.ndarray:
        mat = ctx_batch(len(cands))
        cols = self.mm.system_ctx_columns()
        fill_system_columns(mat, **cols,
                            cache_ghost_hits=self.ghost_hits,
                            cache_entries=len(self.entries),
                            cache_cap_blocks=self.cap_blocks,
                            cache_used_blocks=self.used_blocks(0))
        if not cols.get("ntiers"):
            # the untiered snapshot leaves NTIERS 0; evict programs need the
            # live chain length to detect "past the end" (drop)
            mat[:, CTX.NTIERS] = self.ntiers
        now = self.mm.ktime_ns
        tick_ns = 1_000_000
        for row, e in enumerate(cands):
            mat[row, CTX.ADDR] = e.eid
            mat[row, CTX.PAGE_TIER] = e.blk.tier
            mat[row, CTX.PAGE_ORDER] = 0
            mat[row, CTX.PAGE_AGE] = max(0, (now - e.last_hit_ns) // tick_ns)
            mat[row, CTX.PAGE_HEAT] = int(min(e.heat, 1 << 40) * FIXED_POINT)
            mat[row, CTX.CACHE_REFCOUNT] = e.refcount
            mat[row, CTX.CACHE_HITS] = e.hits
            mat[row, CTX.CACHE_BLOCKS] = 1
        return mat

    def scan(self, need_blocks: int = 0) -> int:
        """One eviction pass: ONE batched HOOK_EVICT invocation over every
        entry, decisions applied to unpinned entries (demote via the tier
        chain, drop only on EVICT_DROP).  With no program attached, a
        conservative LRU default demotes (dropping only when there is
        nowhere left to demote to) until the budget and ``need_blocks``
        are satisfied.  Returns HBM base blocks freed."""
        self._last_scan = self._ticks
        if not self.entries:
            return 0
        self.scans += 1
        self._inc("prefix_cache_scans")
        cands = sorted(self.entries.values(), key=lambda e: e.eid)
        decisions = None
        if self.mm.hooks.attached(HOOK_EVICT):
            mat = self._build_evict_ctx(cands)
            decisions = self.mm.hooks.run_batch(HOOK_EVICT, mat)
            self._tally_decisions(cands, decisions)
        freed = 0
        if decisions is not None:
            dropped: set[bytes] = set()
            for e, d in zip(cands, np.asarray(decisions)):
                if e.refcount > 0 or e.key in dropped:
                    continue
                d = int(d)
                if d in (POLICY_FALLBACK, POLICY_DETACHED):
                    d = self._default_decision(e, need_blocks - freed)
                if d >= EVICT_DROP:
                    freed += self._drop(e, dropped)
                else:
                    freed += self._demote(e, min(max(d, 0), self.ntiers - 1))
            return freed
        # kernel-default policy: LRU demote-then-drop, only under pressure
        over = self.used_blocks(0) - self.cap_blocks
        target = max(over, need_blocks)
        if target <= 0:
            return 0
        dropped = set()
        for e in sorted(self.entries.values(), key=lambda e: e.last_hit_ns):
            if freed >= target:
                break
            if e.refcount > 0 or e.key in dropped:
                continue
            d = self._default_decision(e, target - freed)
            if d >= EVICT_DROP:
                freed += self._drop(e, dropped)
            else:
                freed += self._demote(e, d)
        return freed

    def _tally_decisions(self, cands: list[CacheEntry], decisions) -> None:
        """Telemetry tally of the raw HOOK_EVICT verdicts of one scan —
        keep / demote / drop / fallback counters for the Prometheus export
        (tallied before pinning filters what actually gets applied)."""
        tel = self.telemetry
        if tel is None or not tel.enabled:
            return
        d = np.asarray(decisions)
        tiers = np.fromiter((e.blk.tier for e in cands), np.int64, len(cands))
        acted = (d >= 0) & (d < EVICT_DROP)
        tel.inc("evict_decision_fallback", int(np.sum(d < 0)))
        tel.inc("evict_decision_drop", int(np.sum(d >= EVICT_DROP)))
        tel.inc("evict_decision_keep", int(np.sum(acted & (d == tiers))))
        tel.inc("evict_decision_demote", int(np.sum(acted & (d != tiers))))

    def _default_decision(self, e: CacheEntry, still_needed: int) -> int:
        """The no-program policy for one entry: demote one tier when the
        chain has room, drop only off the end — and only under pressure."""
        if still_needed <= 0 and self.used_blocks(0) <= self.cap_blocks:
            return e.blk.tier
        nxt = e.blk.tier + 1
        return nxt if nxt < self.ntiers else EVICT_DROP

    def _demote(self, e: CacheEntry, dst: int) -> int:
        if dst == e.blk.tier:
            return 0
        was_hbm = e.blk.tier == 0
        if not self.mm.migrate_cache_block(e.blk, dst):
            return 0
        self.evict_demotions += 1
        tel = self.telemetry
        if tel is not None and tel.enabled:
            tel.emit(EV_EVICT, e.eid, 1, e.blk.tier, ts=self.mm.ktime_ns)
            tel.inc("prefix_cache_demotions")
        return 1 if was_hbm and e.blk.tier != 0 else 0

    def _drop(self, e: CacheEntry, dropped: set) -> int:
        """Drop an entry AND its cached descendants (a chain with a missing
        link is unreachable).  Chain pinning — borrowers pin every entry on
        their path — guarantees an unpinned entry has only unpinned
        descendants."""
        freed = 0
        stack = [e.key]
        tel = self.telemetry
        while stack:
            key = stack.pop()
            ent = self.entries.pop(key, None)
            if ent is None or key in dropped:
                continue
            dropped.add(key)
            stack.extend(self.children.pop(key, ()))
            self.mm.cache_free_block(ent.blk.tier, ent.blk.phys)
            if ent.blk.tier == 0:
                freed += 1
            if ent.parent is not None and ent.parent in self.children:
                self.children[ent.parent].discard(key)
            self.ghost[key] = self.mm.ktime_ns
            self.evict_drops += 1
            if tel is not None and tel.enabled:
                tel.emit(EV_EVICT, ent.eid, 1, ent.blk.tier | (1 << 8),
                         ts=self.mm.ktime_ns)
                tel.inc("prefix_cache_drops")
        while len(self.ghost) > self.ghost_capacity:
            self.ghost.popitem(last=False)
        return freed

    # ---------------------------------------------------------------- exports
    def snapshot(self) -> dict:
        per_tier = {}
        for e in self.entries.values():
            per_tier[e.blk.tier] = per_tier.get(e.blk.tier, 0) + 1
        return {
            "entries": len(self.entries),
            "cap_blocks": self.cap_blocks,
            "used_hbm_blocks": self.used_blocks(0),
            "tier_blocks": {f"t{t}": n for t, n in sorted(per_tier.items())},
            "lookups": self.lookups,
            "hits": self.hits,
            "misses": self.misses,
            "hit_rate_milli": (self.hits * 1000) // max(1, self.lookups),
            "ghost_hits": self.ghost_hits,
            "tokens_skipped": self.tokens_skipped,
            "blocks_reused": self.blocks_reused,
            "inserted_blocks": self.inserted_blocks,
            "door_rejects": self.door_rejects,
            "evict_drops": self.evict_drops,
            "evict_demotions": self.evict_demotions,
            "scans": self.scans,
        }
