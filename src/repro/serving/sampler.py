"""Token sampling (greedy / temperature) over the padded-vocab logits."""

from __future__ import annotations

import numpy as np


class Sampler:
    def __init__(self, seed: int = 0):
        self.rng = np.random.default_rng(seed)

    def sample(self, logits: np.ndarray, vocab: int,
               temperature: float = 0.0) -> int:
        logits = np.asarray(logits, np.float64)[:vocab]   # mask vocab padding
        if temperature <= 0.0:
            return int(np.argmax(logits))
        z = logits / temperature
        z -= z.max()
        p = np.exp(z)
        p /= p.sum()
        return int(self.rng.choice(vocab, p=p))
