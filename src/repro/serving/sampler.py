"""Token sampling (greedy / temperature) over the padded-vocab logits."""

from __future__ import annotations

import numpy as np


class Sampler:
    def __init__(self, seed: int = 0):
        self.rng = np.random.default_rng(seed)

    def sample(self, logits: np.ndarray, vocab: int,
               temperature: float = 0.0) -> int:
        logits = np.asarray(logits, np.float64)[:vocab]   # mask vocab padding
        if temperature <= 0.0:
            return int(np.argmax(logits))
        z = logits / temperature
        z -= z.max()
        p = np.exp(z)
        s = p.sum()
        # Degenerate distributions: all logits -inf (z.max() is -inf so p is
        # all-NaN), a NaN logit poisoning the row, or a sum that under/over-
        # flows.  rng.choice would raise (or worse, sample from garbage);
        # deterministic argmax is the only defensible answer.
        if not np.isfinite(s) or s <= 0.0 or not np.all(np.isfinite(p)):
            return int(np.argmax(np.nan_to_num(logits, nan=-np.inf)))
        p /= s
        return int(self.rng.choice(vocab, p=p))
