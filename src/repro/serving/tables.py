"""Device-resident block tables: the decode step's management plane on-device.

Before this module the engine re-captured a fresh ``[B, max_blocks]`` block
table from :meth:`MemoryManager.block_table` every step for every occupied
slot — O(B * max_blocks) host work plus a full-table host->device upload per
dispatch, despite the tables being maintained INCREMENTALLY on host (PR 2)
and changing only when a fault installs, compaction/migration moves, or an
unmap clears a span.

:class:`DeviceBlockTables` keeps the authoritative decode-time table as a
persistent device buffer and uploads only DIRTY ROWS.  Staleness is decided
by the per-process ``table_version`` generation counter
(:meth:`MemoryManager.table_version`), which ``core.mm`` bumps on every span
write or unmap — including tier-migration re-placements via ``_note_mapped``
— so a same-step migration can never publish a stale device row: the move
bumps the version, the row re-uploads before the decode dispatch.

The sync does NOT touch the device buffer itself; it returns the dirty row
indices/payloads for the engine to fold into its fused (table-install +
decode) jit entry, so the whole step stays one dispatch.  Rows of freed
slots are re-blanked to ``-1`` and the slot's active bit drops — the
explicit active-row mask is what makes a PERSISTENT table safe: a vacated
slot's old row otherwise still holds live-looking physical indices (the
PR 1 scatter-to-block-0 bug class, one level up).
"""

from __future__ import annotations

import numpy as np


class DeviceBlockTables:
    """Host mirror + dirty-row change tracking for a ``[B, max_blocks]``
    device-resident block-table buffer owned by the serving engine.

    The engine calls :meth:`sync` once per decode step with the current
    slot->pid assignment; the returned ``(dirty_idx, dirty_rows, active)``
    feed the fused decode dispatch.  ``uploads``/``synced_rows`` count the
    dirty-row traffic for the bench's crossings-per-step lane."""

    def __init__(self, batch_size: int, max_blocks: int) -> None:
        self.B = batch_size
        self.MB = max_blocks
        self.host = np.full((batch_size, max_blocks), -1, dtype=np.int32)
        # (pid, table_version) recorded at last upload, per slot; None for
        # a slot whose device row is blank (-1s)
        self._slot_key: list[tuple[int, int] | None] = [None] * batch_size
        self.syncs = 0          # sync() calls
        self.synced_rows = 0    # dirty rows shipped (the only table upload)
        self.blank_rows = 0     # rows re-blanked on slot free

    def sync(self, mm, slot_pids) -> tuple[np.ndarray, np.ndarray,
                                           np.ndarray]:
        """Refresh the host mirror against ``mm`` for ``slot_pids`` (a
        length-B sequence of pid or ``None`` for an empty slot).

        Returns ``(dirty_idx int32[K], dirty_rows int32[K, MB], active
        bool[B])`` — K == 0 when nothing changed.  The caller scatters the
        dirty rows into its persistent device buffer (inside the fused
        decode dispatch) and must treat ``active`` as authoritative: rows
        of inactive slots may still hold stale physical indices on device
        until their next reuse."""
        dirty: list[int] = []
        active = np.zeros(self.B, dtype=bool)
        for slot, pid in enumerate(slot_pids):
            if pid is None:
                if self._slot_key[slot] is not None:
                    self._slot_key[slot] = None
                    self.host[slot, :] = -1
                    self.blank_rows += 1
                    dirty.append(slot)
                continue
            active[slot] = True
            key = (pid, mm.table_version(pid))
            if self._slot_key[slot] != key:
                self.host[slot, :] = mm.block_table(pid, self.MB)
                self._slot_key[slot] = key
                dirty.append(slot)
        self.syncs += 1
        self.synced_rows += len(dirty)
        idx = np.asarray(dirty, dtype=np.int32)
        return idx, self.host[idx], active

    def invalidate(self, slot: int | None = None) -> None:
        """Force re-upload of one slot's row (or all rows) on next sync —
        used when the device buffer itself was rebuilt (bucket change)."""
        if slot is None:
            self._slot_key = [None] * self.B
            self.host[:, :] = -1
        else:
            self._slot_key[slot] = None
            self.host[slot, :] = -1
