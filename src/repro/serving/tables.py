"""Device-resident block tables: the decode step's management plane on-device.

Before this module the engine re-captured a fresh ``[B, max_blocks]`` block
table from :meth:`MemoryManager.block_table` every step for every occupied
slot — O(B * max_blocks) host work plus a full-table host->device upload per
dispatch, despite the tables being maintained INCREMENTALLY on host (PR 2)
and changing only when a fault installs, compaction/migration moves, or an
unmap clears a span.

:class:`DeviceBlockTables` keeps the authoritative decode-time table as a
persistent device buffer and uploads only DIRTY ROWS.  Staleness is decided
by the per-process ``table_version`` generation counter
(:meth:`MemoryManager.table_version`), which ``core.mm`` bumps on every span
write or unmap — including tier-migration re-placements via ``_note_mapped``
— so a same-step migration can never publish a stale device row: the move
bumps the version, the row re-uploads before the decode dispatch.

The sync does NOT touch the device buffer itself; it returns the dirty row
indices/payloads for the engine to fold into its fused (table-install +
decode) jit entry, so the whole step stays one dispatch.  Rows of freed
slots are re-blanked to ``-1`` and the slot's active bit drops — the
explicit active-row mask is what makes a PERSISTENT table safe: a vacated
slot's old row otherwise still holds live-looking physical indices (the
PR 1 scatter-to-block-0 bug class, one level up).

Dirty rows are additionally DELTA-ENCODED: the common steady-state change
is append-only (a fault maps one new block; every changed cell was ``-1``
in the mirror), which ships as ``(row, col, value)`` int32 triples — a
handful of cells instead of a ``max_blocks``-wide row.  Rows whose change
rewrites live cells (slot blanking, compaction/migration remaps, slot
reuse without an intervening blank sync) fall back to the full-row path;
the mirror comparison decides per row, so the device buffer always matches
the mirror bit-for-bit either way.
"""

from __future__ import annotations

import numpy as np


class DeviceBlockTables:
    """Host mirror + dirty-row change tracking for a ``[B, max_blocks]``
    device-resident block-table buffer owned by the serving engine.

    The engine calls :meth:`sync` once per decode step with the current
    slot->pid assignment; the returned ``(dirty_idx, dirty_rows, active,
    triples)`` feed the fused decode dispatch.  ``uploads``/``synced_rows``
    count the dirty-row traffic for the bench's crossings-per-step lane;
    ``delta_rows``/``delta_cells`` count the rows that shipped as triples
    and how many cells they carried."""

    def __init__(self, batch_size: int, max_blocks: int) -> None:
        self.B = batch_size
        self.MB = max_blocks
        self.host = np.full((batch_size, max_blocks), -1, dtype=np.int32)
        # (pid, table_version) recorded at last upload, per slot; None for
        # a slot whose device row is blank (-1s)
        self._slot_key: list[tuple[int, int] | None] = [None] * batch_size
        self.syncs = 0          # sync() calls
        self.synced_rows = 0    # dirty rows shipped (full + delta)
        self.blank_rows = 0     # rows re-blanked on slot free
        self.full_rows = 0      # dirty rows that shipped full-width
        self.delta_rows = 0     # dirty rows that shipped as triples
        self.delta_cells = 0    # total (row, col, value) triples shipped

    def sync(self, mm, slot_pids) -> tuple[np.ndarray, np.ndarray,
                                           np.ndarray, np.ndarray]:
        """Refresh the host mirror against ``mm`` for ``slot_pids`` (a
        length-B sequence of pid or ``None`` for an empty slot).

        Returns ``(dirty_idx int32[K], dirty_rows int32[K, MB], active
        bool[B], triples int32[T, 3])`` — K == T == 0 when nothing
        changed.  Append-only row changes (every rewritten cell was ``-1``
        in the mirror — the fault-installs-a-new-block steady state) ship
        as ``(row, col, value)`` triples; rows that blank or rewrite live
        cells (slot free, migration/compaction remap, slot reuse) ship
        full-width.  The caller scatters both into its persistent device
        buffer (inside the fused decode dispatch) and must treat
        ``active`` as authoritative: rows of inactive slots may still hold
        stale physical indices on device until their next reuse."""
        dirty: list[int] = []
        triples: list[np.ndarray] = []
        active = np.zeros(self.B, dtype=bool)
        for slot, pid in enumerate(slot_pids):
            if pid is None:
                if self._slot_key[slot] is not None:
                    self._slot_key[slot] = None
                    self.host[slot, :] = -1
                    self.blank_rows += 1
                    self.full_rows += 1
                    dirty.append(slot)
                continue
            active[slot] = True
            key = (pid, mm.table_version(pid))
            if self._slot_key[slot] != key:
                new = np.asarray(mm.block_table(pid, self.MB), np.int32)
                old = self.host[slot]
                changed = np.nonzero(new != old)[0]
                if changed.size and np.all(old[changed] == -1):
                    t = np.empty((changed.size, 3), np.int32)
                    t[:, 0] = slot
                    t[:, 1] = changed
                    t[:, 2] = new[changed]
                    triples.append(t)
                    self.delta_rows += 1
                    self.delta_cells += changed.size
                elif changed.size:
                    dirty.append(slot)
                    self.full_rows += 1
                self.host[slot, :] = new
                self._slot_key[slot] = key
        self.syncs += 1
        self.synced_rows += len(dirty) + len(triples)
        idx = np.asarray(dirty, dtype=np.int32)
        tri = (np.concatenate(triples, axis=0) if triples
               else np.empty((0, 3), np.int32))
        return idx, self.host[idx], active, tri

    def invalidate(self, slot: int | None = None) -> None:
        """Force re-upload of one slot's row (or all rows) on next sync —
        used when the device buffer itself was rebuilt (bucket change)."""
        if slot is None:
            self._slot_key = [None] * self.B
            self.host[:, :] = -1
        else:
            self._slot_key[slot] = None
            self.host[slot, :] = -1
