"""Attention primitives: chunked (flash-style) attention in pure JAX, GQA,
sliding windows, MLA (DeepSeek-V2), and dense-cache decode.

The jnp chunked implementation is the XLA-compiled production path for
training/prefill on TPU (bounded memory via lax.scan over KV chunks, f32
accumulators); the Pallas kernels in repro.kernels provide the hand-tiled
alternative and the paged decode path.
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

F32 = jnp.float32
NEG_INF = -1e30


def _chunk_mask(q_pos: jax.Array, k_pos: jax.Array, *, causal: bool,
                window: int | None) -> jax.Array:
    """[q, k] boolean mask; True = attend."""
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), dtype=bool)
    if causal:
        m &= q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        m &= k_pos[None, :] > (q_pos[:, None] - window)
    return m


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int | None = None,
                    q_offset: int = 0, chunk: int = 1024,
                    soft_cap: float | None = None) -> jax.Array:
    """Memory-bounded attention with a running-softmax scan over KV chunks.

    q: [B, Sq, H, D]; k, v: [B, Sk, KVH, D] with H % KVH == 0.
    q_offset: absolute position of q[0] (for decode/cross-chunk prefill).
    Returns [B, Sq, H, D].
    """
    B, Sq, H, D = q.shape
    _, Sk, KVH, _ = k.shape
    Dv = v.shape[-1]
    if H % KVH:
        raise ValueError(f"H={H} not divisible by KVH={KVH}")
    G = H // KVH
    scale = 1.0 / math.sqrt(D)

    nchunks = -(-Sk // chunk)
    pad = nchunks * chunk - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(B, nchunks, chunk, KVH, D).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nchunks, chunk, KVH, Dv).transpose(1, 0, 2, 3, 4)

    qg = q.reshape(B, Sq, KVH, G, D).astype(F32)
    q_pos = q_offset + jnp.arange(Sq)

    def step(carry, inp):
        m_prev, l_prev, acc_prev = carry
        kci, vci, ci = inp
        k_pos = ci * chunk + jnp.arange(chunk)
        s = jnp.einsum("bqkgd,bckd->bkgqc", qg, kci.astype(F32)) * scale
        if soft_cap is not None:
            s = soft_cap * jnp.tanh(s / soft_cap)
        mask = _chunk_mask(q_pos, k_pos, causal=causal, window=window)
        mask &= (k_pos < Sk)[None, :]
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_cur = jnp.max(s, axis=-1)                         # [B,KVH,G,Sq]
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[..., None])
        l_corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * l_corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgqc,bckd->bkgqd", p, vci.astype(F32))
        acc_new = acc_prev * l_corr[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, KVH, G, Sq), NEG_INF, F32)
    l0 = jnp.zeros((B, KVH, G, Sq), F32)
    a0 = jnp.zeros((B, KVH, G, Sq, Dv), F32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0), (kc, vc, jnp.arange(nchunks)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]            # [B,KVH,G,Sq,Dv]
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, Dv)
    return out.astype(q.dtype)


def decode_attention_dense(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                           lengths: jax.Array, *,
                           soft_cap: float | None = None,
                           window: int | None = None) -> jax.Array:
    """Single-token decode vs a dense KV cache.

    q: [B, H, D]; caches: [B, S, KVH, D]; lengths: [B] (valid prefix length,
    including the current token's slot).  Returns [B, H, D].
    """
    B, H, D = q.shape
    _, S, KVH, _ = k_cache.shape
    G = H // KVH
    scale = 1.0 / math.sqrt(D)
    qg = q.reshape(B, KVH, G, D).astype(F32)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache.astype(F32)) * scale
    if soft_cap is not None:
        s = soft_cap * jnp.tanh(s / soft_cap)
    pos = jnp.arange(S)[None, :]
    valid = pos < lengths[:, None]
    if window is not None:
        valid &= pos > (lengths[:, None] - 1 - window)
    s = jnp.where(valid[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(F32))
    return out.reshape(B, H, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# MLA — multi-head latent attention (DeepSeek-V2)
# ---------------------------------------------------------------------------

def mla_expand_attention(q_nope: jax.Array, q_rope: jax.Array,
                         c_kv: jax.Array, k_rope: jax.Array,
                         w_uk: jax.Array, w_uv: jax.Array, *,
                         causal: bool = True, chunk: int = 1024,
                         q_offset: int = 0) -> jax.Array:
    """Training-path MLA: expand latents to per-head K/V then flash-attend.

    q_nope: [B,Sq,H,Dn]; q_rope: [B,Sq,H,Dr]; c_kv: [B,Sk,L]; k_rope:
    [B,Sk,Dr]; w_uk: [H,L,Dn]; w_uv: [H,L,Dv].  Returns [B,Sq,H,Dv].
    ``q_offset`` is the absolute position of q[0] (suffix prefill attends
    queries for the tail of a sequence whose earlier latents came from the
    paged pool).
    """
    B, Sk = c_kv.shape[:2]
    H = q_nope.shape[2]
    k_nope = jnp.einsum("bsl,hld->bshd", c_kv, w_uk)
    v = jnp.einsum("bsl,hld->bshd", c_kv, w_uv)
    k_rope_b = jnp.broadcast_to(k_rope[:, :, None, :], (B, Sk, H, k_rope.shape[-1]))
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, k_rope_b], axis=-1)
    return flash_attention(q, k, v, causal=causal, chunk=chunk,
                           q_offset=q_offset)


def mla_absorbed_decode(q_nope: jax.Array, q_rope: jax.Array,
                        ckv_cache: jax.Array, krope_cache: jax.Array,
                        lengths: jax.Array, w_uk: jax.Array, w_uv: jax.Array
                        ) -> jax.Array:
    """Decode-path MLA with weight absorption: attend directly over the
    compressed latent cache (this is why MLA makes 500k-token decode cheap —
    the per-token cache line is kv_lora + rope_dim, not heads*head_dim*2).

    q_nope: [B,H,Dn]; q_rope: [B,H,Dr]; ckv_cache: [B,S,L];
    krope_cache: [B,S,Dr]; returns [B,H,Dv].
    """
    B, H, Dn = q_nope.shape
    L = ckv_cache.shape[-1]
    scale = 1.0 / math.sqrt(Dn + q_rope.shape[-1])
    # absorb W_uk into the query: q_eff[h] = q_nope[h] @ w_uk[h]^T  -> [B,H,L]
    q_eff = jnp.einsum("bhd,hld->bhl", q_nope.astype(F32), w_uk.astype(F32))
    s = (jnp.einsum("bhl,bsl->bhs", q_eff, ckv_cache.astype(F32))
         + jnp.einsum("bhr,bsr->bhs", q_rope.astype(F32), krope_cache.astype(F32)))
    s = s * scale
    valid = jnp.arange(ckv_cache.shape[1])[None, :] < lengths[:, None]
    s = jnp.where(valid[:, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bhs,bsl->bhl", p, ckv_cache.astype(F32))   # [B,H,L]
    out = jnp.einsum("bhl,hld->bhd", o_lat, w_uv.astype(F32))
    return out.astype(q_nope.dtype)
