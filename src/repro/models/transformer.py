"""Generic multi-family transformer: one assembly covering all 10 assigned
architectures via ModelConfig (dense GQA LMs, MLA, fine-grained MoE, Mamba-2,
Jamba-style hybrids, Whisper enc-dec, Qwen2-VL backbone).

Design for compile-time scalability: consecutive layers with the same
periodic structure are stacked and executed with ``lax.scan`` (params get a
leading "layers" axis), so HLO size and compile time are O(period), not
O(depth) — required for the 62-layer/512-device dry-runs.  Scan bodies are
``jax.checkpoint``-ed (activation remat) for training.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .attention import (decode_attention_dense, flash_attention,
                        mla_absorbed_decode, mla_expand_attention)
from .common import (BF16, F32, ParamSpec, activate, apply_mrope, apply_rope,
                     layer_norm, pad_vocab, rms_norm, sinusoidal_positions)
from .mamba2 import (mamba_apply, mamba_decode_step, mamba_spec,
                     mamba_state_init)
from .moe import moe_apply, moe_spec

Pytree = Any


@dataclass(frozen=True)
class LayerPlan:
    kind: str          # "attn" | "mamba"
    local: bool = False
    moe: bool = False
    xattn: bool = False   # adds cross-attention (whisper decoder)
    causal: bool = True
    ffn: bool = True      # False for pure-SSM blocks (mamba2): no separate MLP


# ---------------------------------------------------------------------------
# Segmenting: express the layer list as prefix + repeated cycle + suffix
# ---------------------------------------------------------------------------

def build_layer_plans(cfg: ModelConfig, *, decoder: bool = True) -> list[LayerPlan]:
    kinds = cfg.layer_kinds()
    attn_kinds = cfg.attn_kinds()
    moes = cfg.moe_layers()
    plans = []
    for i in range(cfg.n_layers):
        plans.append(LayerPlan(
            kind=kinds[i],
            local=(attn_kinds[i] == "l"),
            moe=moes[i],
            xattn=cfg.enc_dec and decoder,
            causal=decoder,
            ffn=(cfg.family != "ssm"),
        ))
    return plans


def build_segments(plans: list[LayerPlan]) -> list[tuple]:
    """Return segments: ("plain", plan) or ("scan", (plans...), reps)."""
    n = len(plans)
    best = None
    for pre in range(0, 3):
        for p in (1, 2, 3, 4, 6, 8):
            if n - pre < p:
                continue
            cycle = tuple(plans[pre:pre + p])
            reps = (n - pre) // p
            if all(plans[pre + i] == cycle[i % p] for i in range(reps * p)):
                suffix = n - pre - reps * p
                score = (pre + suffix) * 10 + p
                if best is None or score < best[0]:
                    best = (score, pre, p, reps, suffix)
    if best is None:   # fully irregular; all plain
        return [("plain", pl) for pl in plans]
    _, pre, p, reps, suffix = best
    segs: list[tuple] = [("plain", plans[i]) for i in range(pre)]
    segs.append(("scan", tuple(plans[pre:pre + p]), reps))
    segs.extend(("plain", plans[pre + reps * p + i]) for i in range(suffix))
    return segs


# ---------------------------------------------------------------------------
# Per-layer parameter specs
# ---------------------------------------------------------------------------

def _norm_spec(cfg: ModelConfig, d: int) -> Pytree:
    if cfg.norm == "rms":
        return {"scale": ParamSpec((d,), ("embed",), init="ones")}
    return {"scale": ParamSpec((d,), ("embed",), init="ones"),
            "bias": ParamSpec((d,), ("embed",), init="zeros")}


def _apply_norm(cfg: ModelConfig, p: Pytree, x: jax.Array) -> jax.Array:
    if cfg.norm == "rms":
        return rms_norm(x, p["scale"])
    return layer_norm(x, p["scale"], p["bias"])


def _attn_spec(cfg: ModelConfig) -> Pytree:
    d, H, KVH, hd = cfg.d_model, cfg.n_heads, cfg.kv_heads, cfg.head_dim
    if cfg.mla is not None:
        m = cfg.mla
        return {
            "wq": ParamSpec((d, H * (m.qk_nope + m.qk_rope)), ("embed", "q_heads")),
            "w_dkv": ParamSpec((d, m.kv_lora + m.qk_rope), ("embed", None)),
            "kv_norm": ParamSpec((m.kv_lora,), (None,), init="ones"),
            "w_uk": ParamSpec((H, m.kv_lora, m.qk_nope), ("q_heads", "kv_lora", "head_dim")),
            "w_uv": ParamSpec((H, m.kv_lora, m.v_head), ("q_heads", "kv_lora", "head_dim")),
            "wo": ParamSpec((H * m.v_head, d), ("q_heads", "embed")),
        }
    spec = {
        "wq": ParamSpec((d, H * hd), ("embed", "q_heads")),
        "wk": ParamSpec((d, KVH * hd), ("embed", "kv_heads")),
        "wv": ParamSpec((d, KVH * hd), ("embed", "kv_heads")),
        "wo": ParamSpec((H * hd, d), ("q_heads", "embed")),
    }
    if cfg.attn.qk_norm:
        spec["q_norm"] = ParamSpec((hd,), (None,), init="ones")
        spec["k_norm"] = ParamSpec((hd,), (None,), init="ones")
    return spec


def _xattn_spec(cfg: ModelConfig) -> Pytree:
    d, H, KVH, hd = cfg.d_model, cfg.n_heads, cfg.kv_heads, cfg.head_dim
    return {
        "wq": ParamSpec((d, H * hd), ("embed", "q_heads")),
        "wk": ParamSpec((d, KVH * hd), ("embed", "kv_heads")),
        "wv": ParamSpec((d, KVH * hd), ("embed", "kv_heads")),
        "wo": ParamSpec((H * hd, d), ("q_heads", "embed")),
    }


def _mlp_spec(cfg: ModelConfig) -> Pytree:
    d, f = cfg.d_model, cfg.d_ff
    spec = {
        "w_in": ParamSpec((d, f), ("embed", "ff")),
        "w_out": ParamSpec((f, d), ("ff", "embed")),
    }
    if cfg.mlp in ("swiglu", "geglu"):
        spec["w_gate"] = ParamSpec((d, f), ("embed", "ff"))
    return spec


def layer_spec(cfg: ModelConfig, plan: LayerPlan) -> Pytree:
    d = cfg.d_model
    if plan.kind == "mamba":
        spec = {"ln1": _norm_spec(cfg, d), "mamba": mamba_spec(d, cfg.mamba)}
    else:
        spec = {"ln1": _norm_spec(cfg, d), "attn": _attn_spec(cfg)}
    if plan.xattn:
        spec["lnx"] = _norm_spec(cfg, d)
        spec["xattn"] = _xattn_spec(cfg)
    if plan.ffn:
        spec["ln2"] = _norm_spec(cfg, d)
        if plan.moe:
            spec["moe"] = moe_spec(d, cfg.moe, cfg.mlp)
        else:
            spec["mlp"] = _mlp_spec(cfg)
    return spec


def _stack_spec(spec: Pytree, reps: int) -> Pytree:
    return jax.tree.map(
        lambda p: ParamSpec((reps,) + p.shape, ("layers",) + p.axes,
                            init=p.init, scale=p.scale, dtype=p.dtype),
        spec, is_leaf=lambda x: isinstance(x, ParamSpec))


def model_spec(cfg: ModelConfig) -> Pytree:
    V = pad_vocab(cfg.vocab)
    d = cfg.d_model
    spec: dict = {
        "embed": ParamSpec((V, d), ("vocab", "embed"), scale=0.02),
        "final_norm": _norm_spec(cfg, d),
    }
    if not cfg.tie_embeddings:
        spec["lm_head"] = ParamSpec((d, V), ("embed", "vocab"))
    segs = build_segments(build_layer_plans(cfg, decoder=True))
    blocks: dict = {}
    for si, seg in enumerate(segs):
        if seg[0] == "plain":
            blocks[f"p{si}"] = layer_spec(cfg, seg[1])
        else:
            _, cycle, reps = seg
            member = {f"m{j}": layer_spec(cfg, pl) for j, pl in enumerate(cycle)}
            blocks[f"s{si}"] = _stack_spec(member, reps)
    spec["blocks"] = blocks
    if cfg.enc_dec:
        enc_plan = LayerPlan(kind="attn", causal=False)
        enc_member = {"m0": layer_spec(cfg, enc_plan)}
        spec["encoder"] = {
            "blocks": _stack_spec(enc_member, cfg.enc_layers),
            "final_norm": _norm_spec(cfg, d),
        }
    return spec


# ---------------------------------------------------------------------------
# Forward (training / prefill)
# ---------------------------------------------------------------------------

def _project_qkv(cfg, p, h):
    B, S, d = h.shape
    H, KVH, hd = cfg.n_heads, cfg.kv_heads, cfg.head_dim
    q = (h @ p["wq"].astype(h.dtype)).reshape(B, S, H, hd)
    k = (h @ p["wk"].astype(h.dtype)).reshape(B, S, KVH, hd)
    v = (h @ p["wv"].astype(h.dtype)).reshape(B, S, KVH, hd)
    if cfg.attn.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    return q, k, v


def _attn_forward(cfg: ModelConfig, plan: LayerPlan, p: Pytree, h: jax.Array,
                  pos_info: dict, chunk: int) -> jax.Array:
    B, S, d = h.shape
    window = cfg.attn.window if plan.local else None
    if cfg.mla is not None:
        m = cfg.mla
        H = cfg.n_heads
        q = (h @ p["wq"].astype(h.dtype)).reshape(B, S, H, m.qk_nope + m.qk_rope)
        q_nope, q_rope = q[..., :m.qk_nope], q[..., m.qk_nope:]
        dkv = h @ p["w_dkv"].astype(h.dtype)
        c_kv = rms_norm(dkv[..., :m.kv_lora], p["kv_norm"])
        k_rope = dkv[..., m.kv_lora:]
        q_rope = apply_rope(q_rope, pos_info["positions"], theta=cfg.attn.rope_theta)
        k_rope = apply_rope(k_rope[:, :, None, :], pos_info["positions"],
                            theta=cfg.attn.rope_theta)[:, :, 0, :]
        out = mla_expand_attention(q_nope, q_rope, c_kv, k_rope,
                                   p["w_uk"].astype(h.dtype),
                                   p["w_uv"].astype(h.dtype),
                                   causal=plan.causal, chunk=chunk)
        out = out.reshape(B, S, H * m.v_head)
        return out @ p["wo"].astype(h.dtype)
    q, k, v = _project_qkv(cfg, p, h)
    if cfg.attn.mrope_sections is not None:
        q = apply_mrope(q, pos_info["pos3d"], cfg.attn.mrope_sections,
                        theta=cfg.attn.rope_theta)
        k = apply_mrope(k, pos_info["pos3d"], cfg.attn.mrope_sections,
                        theta=cfg.attn.rope_theta)
    elif cfg.attn.use_rope:
        q = apply_rope(q, pos_info["positions"], theta=cfg.attn.rope_theta)
        k = apply_rope(k, pos_info["positions"], theta=cfg.attn.rope_theta)
    out = flash_attention(q, k, v, causal=plan.causal, window=window,
                          chunk=chunk, soft_cap=cfg.attn.logit_soft_cap)
    out = out.reshape(B, S, cfg.n_heads * cfg.head_dim)
    return out @ p["wo"].astype(h.dtype)


def _xattn_forward(cfg, p, h, enc_out, chunk):
    B, S, d = h.shape
    H, KVH, hd = cfg.n_heads, cfg.kv_heads, cfg.head_dim
    q = (h @ p["wq"].astype(h.dtype)).reshape(B, S, H, hd)
    k = (enc_out @ p["wk"].astype(h.dtype)).reshape(B, enc_out.shape[1], KVH, hd)
    v = (enc_out @ p["wv"].astype(h.dtype)).reshape(B, enc_out.shape[1], KVH, hd)
    out = flash_attention(q, k, v, causal=False, chunk=chunk)
    out = out.reshape(B, S, H * hd)
    return out @ p["wo"].astype(h.dtype)


def _mlp_forward(cfg, p, h):
    x = h @ p["w_in"].astype(h.dtype)
    if cfg.mlp in ("swiglu", "geglu"):
        g = h @ p["w_gate"].astype(h.dtype)
        x = (jax.nn.silu(g) if cfg.mlp == "swiglu"
             else jax.nn.gelu(g, approximate=True)) * x
    else:
        x = activate(x, cfg.mlp)
    return x @ p["w_out"].astype(h.dtype)


def layer_forward(cfg: ModelConfig, plan: LayerPlan, p: Pytree, x: jax.Array,
                  aux: jax.Array, pos_info: dict, *, enc_out=None,
                  chunk: int = 1024) -> tuple[jax.Array, jax.Array]:
    h = _apply_norm(cfg, p["ln1"], x)
    if plan.kind == "mamba":
        x = x + mamba_apply(p["mamba"], h, cfg.mamba)
    else:
        x = x + _attn_forward(cfg, plan, p["attn"], h, pos_info, chunk)
    if plan.xattn:
        hx = _apply_norm(cfg, p["lnx"], x)
        x = x + _xattn_forward(cfg, p["xattn"], hx, enc_out, chunk)
    if plan.ffn:
        h2 = _apply_norm(cfg, p["ln2"], x)
        if plan.moe:
            B, S, d = h2.shape
            y, a = moe_apply(p["moe"], h2.reshape(B * S, d), cfg.moe, cfg.mlp)
            x = x + y.reshape(B, S, d)
            aux = aux + a
        else:
            x = x + _mlp_forward(cfg, p["mlp"], h2)
    return x, aux


def _run_blocks(cfg: ModelConfig, blocks: Pytree, segs: list, x: jax.Array,
                pos_info: dict, *, enc_out=None, chunk: int, remat: bool
                ) -> tuple[jax.Array, jax.Array]:
    aux = jnp.zeros((), F32)
    for si, seg in enumerate(segs):
        if seg[0] == "plain":
            plan = seg[1]

            def plain_fwd(p_, x_, a_, _plan=plan):
                return layer_forward(cfg, _plan, p_, x_, a_, pos_info,
                                     enc_out=enc_out, chunk=chunk)
            if remat:
                plain_fwd = jax.checkpoint(plain_fwd, prevent_cse=False)
            x, aux = plain_fwd(blocks[f"p{si}"], x, aux)
        else:
            _, cycle, reps = seg
            stacked = blocks[f"s{si}"]

            def body(carry, layer_params):
                xx, aa = carry
                for j, pl in enumerate(cycle):
                    xx, aa = layer_forward(cfg, pl, layer_params[f"m{j}"], xx,
                                           aa, pos_info, enc_out=enc_out,
                                           chunk=chunk)
                return (xx, aa), None

            if remat:
                body = jax.checkpoint(body, prevent_cse=False)
            (x, aux), _ = jax.lax.scan(body, (x, aux), stacked)
    return x, aux


def model_forward(params: Pytree, cfg: ModelConfig, tokens: jax.Array, *,
                  frames: jax.Array | None = None,
                  patches: jax.Array | None = None,
                  pos3d: jax.Array | None = None,
                  compute_dtype=BF16, chunk: int = 1024,
                  remat: bool = True) -> tuple[jax.Array, jax.Array]:
    """Full-sequence forward -> (logits [B,S,V_pad], aux_loss).

    tokens: [B,S] int32.  frames: whisper stub encoder input [B,F,d].
    patches: qwen2-vl stub patch embeddings [B,P,d] replacing the first P
    positions.  pos3d: [3,B,S] M-RoPE positions.
    """
    B, S = tokens.shape
    x = params["embed"].astype(compute_dtype)[tokens]
    if patches is not None:
        P = patches.shape[1]
        x = jnp.concatenate([patches.astype(compute_dtype), x[:, P:]], axis=1)
    positions = jnp.arange(S)[None, :].astype(F32)
    pos_info = {"positions": positions}
    if pos3d is not None:
        pos_info["pos3d"] = pos3d

    enc_out = None
    if cfg.enc_dec:
        if frames is None:
            raise ValueError("enc-dec model needs `frames`")
        enc_out = encoder_forward(params["encoder"], cfg, frames,
                                  compute_dtype=compute_dtype, chunk=chunk,
                                  remat=remat)
        # whisper decoder uses learned positions; sinusoidal stand-in
        x = x + sinusoidal_positions(S, cfg.d_model)[None].astype(compute_dtype)

    segs = build_segments(build_layer_plans(cfg, decoder=True))
    x, aux = _run_blocks(cfg, params["blocks"], segs, x, pos_info,
                         enc_out=enc_out, chunk=chunk, remat=remat)
    x = _apply_norm(cfg, params["final_norm"], x)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = x.astype(F32) @ head.astype(F32)
    return logits, aux


def encoder_forward(enc_params: Pytree, cfg: ModelConfig, frames: jax.Array,
                    *, compute_dtype=BF16, chunk: int = 1024,
                    remat: bool = True) -> jax.Array:
    """Whisper-style encoder over precomputed (stub) frame embeddings."""
    B, Fr, d = frames.shape
    x = frames.astype(compute_dtype)
    x = x + sinusoidal_positions(Fr, d)[None].astype(compute_dtype)
    pos_info = {"positions": jnp.arange(Fr)[None, :].astype(F32)}
    plan = LayerPlan(kind="attn", causal=False)

    def body(carry, layer_params):
        xx, aa = carry
        xx, aa = layer_forward(cfg, plan, layer_params["m0"], xx, aa, pos_info,
                               chunk=chunk)
        return (xx, aa), None

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    (x, _), _ = jax.lax.scan(body, (x, jnp.zeros((), F32)),
                             enc_params["blocks"])
    return _apply_norm(cfg, enc_params["final_norm"], x)


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------

def lm_loss(params: Pytree, cfg: ModelConfig, batch: dict, *,
            compute_dtype=BF16, chunk: int = 1024, remat: bool = True,
            z_loss: float = 1e-4) -> tuple[jax.Array, dict]:
    tokens = batch["tokens"]
    inputs, labels = tokens[:, :-1], tokens[:, 1:]
    weights = batch.get("weights")
    if weights is None:
        weights = jnp.ones_like(labels, F32)
    else:
        weights = weights[:, 1:].astype(F32)
    logits, aux = model_forward(
        params, cfg, inputs,
        frames=batch.get("frames"), patches=batch.get("patches"),
        pos3d=batch.get("pos3d"),
        compute_dtype=compute_dtype, chunk=chunk, remat=remat)
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = (logz - ll) * weights
    denom = jnp.maximum(weights.sum(), 1.0)
    loss = nll.sum() / denom
    zl = z_loss * (jnp.square(logz) * weights).sum() / denom
    total = loss + zl + aux
    return total, {"ce": loss, "z_loss": zl, "aux": aux}
