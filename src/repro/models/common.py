"""Parameter-spec machinery shared by all model definitions.

Models are pure-JAX functional modules: a *spec* (nested dict of
:class:`ParamSpec`) describes every weight's shape, dtype, init and logical
axes.  From one spec we derive:

  * ``materialize(rng, spec)``   — real parameters (smoke tests, examples),
  * ``abstract(spec)``           — ShapeDtypeStructs (multi-pod dry-run; no
                                   host allocation for the full-size configs),
  * ``logical_axes(spec)``       — the logical-axis pytree the distributed
                                   sharding rule engine consumes.

Logical axis names (mapped to mesh axes by repro.distributed.sharding):
  vocab, embed, q_heads, kv_heads, head_dim, ff, expert, kv_lora, state,
  conv, layers (stacked scan axis; never sharded).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any

F32 = jnp.float32
BF16 = jnp.bfloat16


@dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    init: str = "normal"         # normal | zeros | ones | scaled
    scale: float | None = None   # stddev override for normal/scaled
    dtype: Any = F32

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"shape {self.shape} vs axes {self.axes} rank mismatch")


def _fan_in(shape: tuple[int, ...]) -> int:
    # last axis is the output axis by convention (x @ w)
    return int(np.prod(shape[:-1])) if len(shape) > 1 else shape[0]


def materialize(rng: jax.Array, spec: Pytree) -> Pytree:
    leaves, treedef = jax.tree.flatten(spec, is_leaf=lambda x: isinstance(x, ParamSpec))
    keys = jax.random.split(rng, len(leaves))
    out = []
    for key, p in zip(keys, leaves):
        if p.init == "zeros":
            out.append(jnp.zeros(p.shape, p.dtype))
        elif p.init == "ones":
            out.append(jnp.ones(p.shape, p.dtype))
        else:
            std = p.scale if p.scale is not None else 1.0 / math.sqrt(max(1, _fan_in(p.shape)))
            out.append((jax.random.normal(key, p.shape, F32) * std).astype(p.dtype))
    return jax.tree.unflatten(treedef, out)


def abstract(spec: Pytree) -> Pytree:
    return jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, p.dtype),
        spec, is_leaf=lambda x: isinstance(x, ParamSpec))


def logical_axes(spec: Pytree) -> Pytree:
    return jax.tree.map(lambda p: p.axes, spec,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


def param_count(spec: Pytree) -> int:
    leaves = jax.tree.leaves(spec, is_leaf=lambda x: isinstance(x, ParamSpec))
    return int(sum(np.prod(p.shape) for p in leaves))


def cast_tree(tree: Pytree, dtype) -> Pytree:
    return jax.tree.map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x,
        tree)


# ---------------------------------------------------------------------------
# Common numeric helpers
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, scale: jax.Array, *, eps: float = 1e-6,
             offset: float = 0.0) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(F32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps) * (offset + scale.astype(F32))
    return y.astype(dt)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array, *,
               eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(F32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps) * scale.astype(F32) + bias.astype(F32)
    return y.astype(dt)


def activate(x: jax.Array, kind: str) -> jax.Array:
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x, approximate=True)
    if kind == "relu2":            # squared ReLU (Primer / nemotron-4)
        r = jax.nn.relu(x)
        return r * r
    if kind == "relu":
        return jax.nn.relu(x)
    raise ValueError(f"unknown activation {kind!r}")


def pad_vocab(vocab: int, multiple: int = 256) -> int:
    """Pad embedding tables so the vocab axis shards evenly (MaxText-style)."""
    return ((vocab + multiple - 1) // multiple) * multiple


# ---------------------------------------------------------------------------
# Rotary position embeddings (standard, and M-RoPE for qwen2-vl)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float = 10000.0) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=F32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, *, theta: float = 10000.0) -> jax.Array:
    """x: [..., seq, heads, head_dim]; positions: broadcastable to [..., seq]."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # [hd/2]
    ang = positions[..., :, None].astype(F32) * freqs   # [..., seq, hd/2]
    cos = jnp.cos(ang)[..., None, :]                    # [..., seq, 1, hd/2]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(F32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, positions_3d: jax.Array, sections: tuple[int, int, int],
                *, theta: float = 10000.0) -> jax.Array:
    """Multimodal RoPE (qwen2-vl): the head_dim/2 frequency channels are
    partitioned into (temporal, height, width) sections, each rotated by its
    own position stream.

    x: [..., seq, heads, head_dim]; positions_3d: [3, ..., seq].
    """
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # [hd/2]
    if sum(sections) != hd // 2:
        raise ValueError(f"M-RoPE sections {sections} must sum to {hd // 2}")
    sec_id = jnp.asarray(np.repeat(np.arange(3), np.asarray(sections)))  # [hd/2]
    # pick the position stream per frequency channel
    pos = positions_3d.astype(F32)                      # [3, ..., seq]
    pos_per_chan = jnp.take(pos, sec_id, axis=0)        # [hd/2, ..., seq]
    pos_per_chan = jnp.moveaxis(pos_per_chan, 0, -1)    # [..., seq, hd/2]
    ang = pos_per_chan[..., :, None, :] * freqs         # [..., seq, 1, hd/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(F32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(n: int, d: int) -> jax.Array:
    """Whisper-style fixed sinusoidal embeddings [n, d]."""
    pos = np.arange(n)[:, None]
    dim = np.arange(d // 2)[None, :]
    ang = pos / np.power(10000.0, 2 * dim / d)
    return jnp.asarray(np.concatenate([np.sin(ang), np.cos(ang)], axis=1), F32)


def sinusoidal_position_at(pos: jax.Array, d: int) -> jax.Array:
    """Sinusoidal embedding for dynamic positions. pos: [B] -> [B, d]
    (computed on the fly so decode never materializes an [S, d] table)."""
    dim = jnp.arange(d // 2, dtype=F32)[None, :]
    ang = pos.astype(F32)[:, None] / jnp.power(10000.0, 2 * dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=1)
