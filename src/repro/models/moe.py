"""Mixture-of-Experts FFN: fine-grained routed experts + shared experts
(DeepSeekMoE-style), with sort-based capacity dispatch.

Dispatch is the jit-friendly argsort formulation (no [T,E,C] one-hot):
tokens are sorted by assigned expert, each expert processes a static-capacity
slab, and overflow tokens are dropped (their gate mass is lost, standard
capacity-factor semantics).  The expert dimension is the EP axis — stacked
expert weights [E, ...] shard over the "model" mesh axis, and GSPMD lowers
the dispatch/combine gathers into all-to-alls across the expert shards.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ..configs.base import MoECfg
from .common import F32, ParamSpec, activate


def moe_spec(d_model: int, cfg: MoECfg, mlp_kind: str) -> dict:
    E, fe = cfg.num_experts, cfg.d_ff_expert
    spec = {
        "router": ParamSpec((d_model, E), ("embed", None), scale=0.02),
        "w_in": ParamSpec((E, d_model, fe), ("expert", "embed", "ff")),
        "w_out": ParamSpec((E, fe, d_model), ("expert", "ff", "embed")),
    }
    if mlp_kind in ("swiglu", "geglu"):
        spec["w_gate"] = ParamSpec((E, d_model, fe), ("expert", "embed", "ff"))
    if cfg.num_shared > 0:
        fs = cfg.num_shared * fe
        spec["shared_in"] = ParamSpec((d_model, fs), ("embed", "ff"))
        spec["shared_out"] = ParamSpec((fs, d_model), ("ff", "embed"))
        if mlp_kind in ("swiglu", "geglu"):
            spec["shared_gate"] = ParamSpec((d_model, fs), ("embed", "ff"))
    return spec


def _expert_ffn(params, x_ec: jax.Array, mlp_kind: str) -> jax.Array:
    """x_ec: [E, C, d] -> [E, C, d] through per-expert FFNs."""
    h = jnp.einsum("ecd,edf->ecf", x_ec, params["w_in"].astype(x_ec.dtype))
    if mlp_kind == "swiglu":
        g = jnp.einsum("ecd,edf->ecf", x_ec, params["w_gate"].astype(x_ec.dtype))
        h = jax.nn.silu(g) * h
    elif mlp_kind == "geglu":
        g = jnp.einsum("ecd,edf->ecf", x_ec, params["w_gate"].astype(x_ec.dtype))
        h = jax.nn.gelu(g, approximate=True) * h
    else:
        h = activate(h, mlp_kind)
    return jnp.einsum("ecf,efd->ecd", h, params["w_out"].astype(x_ec.dtype))


def _shared_ffn(params, x: jax.Array, mlp_kind: str) -> jax.Array:
    h = x @ params["shared_in"].astype(x.dtype)
    if mlp_kind in ("swiglu", "geglu"):
        g = x @ params["shared_gate"].astype(x.dtype)
        h = (jax.nn.silu(g) if mlp_kind == "swiglu"
             else jax.nn.gelu(g, approximate=True)) * h
    else:
        h = activate(h, mlp_kind)
    return h @ params["shared_out"].astype(x.dtype)


def moe_apply(params, x: jax.Array, cfg: MoECfg, mlp_kind: str,
              *, capacity: int | None = None) -> tuple[jax.Array, jax.Array]:
    """x: [T, d] -> ([T, d], aux_loss scalar).

    Dispatch path selection: when a runtime mesh is installed (launchers do
    this) and the expert/token counts divide it, dispatch goes through the
    shard_map expert-parallel all-to-all (moe_apply_ep) — the pjit global
    scatter was the dominant collective in MoE training cells (§Perf
    hillclimb #2).  Otherwise the single-device sort-based path runs."""
    from ..distributed.flashdecode import get_decode_mesh
    mesh = get_decode_mesh()
    if mesh is not None and "model" in mesh.axis_names:
        M = mesh.shape["model"]
        data_axes = tuple(n for n in mesh.axis_names if n != "model")
        import numpy as _np
        D = int(_np.prod([mesh.shape[n] for n in data_axes]))
        if (cfg.num_experts % M == 0 and x.shape[0] % D == 0
                and x.shape[0] // D >= 1 and M > 1):
            return moe_apply_ep(params, x, cfg, mlp_kind, mesh,
                                capacity=capacity)
    return _moe_apply_local(params, x, cfg, mlp_kind, capacity=capacity)


def _moe_apply_local(params, x: jax.Array, cfg: MoECfg, mlp_kind: str,
                     *, capacity: int | None = None):
    """Single-shard sort-based dispatch (reference path)."""
    T, d = x.shape
    E, K = cfg.num_experts, cfg.top_k

    logits = (x.astype(F32) @ params["router"].astype(F32))        # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)                # [T, K]
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)                    # norm_topk_prob

    # ---- load-balancing aux loss (GShard/DeepSeek form) ----
    me = probs.mean(axis=0)                                        # [E]
    ce = jnp.zeros(E, F32).at[expert_idx.reshape(-1)].add(1.0) / (T * K)
    aux = cfg.router_aux_weight * E * jnp.sum(me * ce)

    # ---- sort-based dispatch ----
    if capacity is None:
        capacity = max(8, int(T * K / E * cfg.capacity_factor) + 1)
    flat_expert = expert_idx.reshape(-1)                           # [T*K]
    flat_gate = gate_vals.reshape(-1)
    flat_token = jnp.repeat(jnp.arange(T), K)
    order = jnp.argsort(flat_expert)
    se, sg, stok = flat_expert[order], flat_gate[order], flat_token[order]
    # position of each entry within its expert group
    first_of_group = jnp.searchsorted(se, se, side="left")
    pos_in_group = jnp.arange(T * K) - first_of_group
    keep = pos_in_group < capacity
    dest = jnp.where(keep, se * capacity + pos_in_group, E * capacity)

    buf = jnp.zeros((E * capacity + 1, d), x.dtype)
    buf = buf.at[dest].set(x[stok])                                # drop row = E*C
    y_ec = _expert_ffn(params, buf[:-1].reshape(E, capacity, d), mlp_kind)

    y_flat = y_ec.reshape(E * capacity, d)
    gathered = jnp.where(keep[:, None], y_flat[jnp.minimum(dest, E * capacity - 1)], 0.0)
    out = jnp.zeros((T, d), x.dtype).at[stok].add(
        gathered * sg[:, None].astype(x.dtype))

    if cfg.num_shared > 0:
        out = out + _shared_ffn(params, x, mlp_kind)
    return out, aux


def moe_apply_ep(params, x: jax.Array, cfg: MoECfg, mlp_kind: str, mesh,
                 *, capacity: int | None = None):
    """Expert-parallel dispatch: tokens stay on their data shard; routed
    tokens cross the "model" axis with two all-to-alls (the Megatron/GShard
    EP pattern).  Per-device collective payload is T_local*K*d bytes instead
    of the global [E,C,d] buffer scatter GSPMD emits for the local path.
    """
    from jax.sharding import PartitionSpec as P
    import numpy as _np

    E, K = cfg.num_experts, cfg.top_k
    M = mesh.shape["model"]
    data_axes = tuple(n for n in mesh.axis_names if n != "model")
    D = int(_np.prod([mesh.shape[n] for n in data_axes]))
    T, d = x.shape
    T_loc = T // D
    E_loc = E // M
    if capacity is None:
        capacity = max(8, int(T_loc * K / E * cfg.capacity_factor) + 1)
    C = capacity

    def body(x_l, router, w_in, w_gate, w_out, shared):
        logits = x_l.astype(F32) @ router.astype(F32)         # [T_loc, E]
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, expert_idx = jax.lax.top_k(probs, K)
        gate_vals = gate_vals / jnp.maximum(
            gate_vals.sum(-1, keepdims=True), 1e-9)

        me = probs.mean(axis=0)
        ce = jnp.zeros(E, F32).at[expert_idx.reshape(-1)].add(1.0) / (T_loc * K)
        aux = cfg.router_aux_weight * E * jnp.sum(me * ce)
        aux = jax.lax.pmean(aux, data_axes) if data_axes else aux

        flat_expert = expert_idx.reshape(-1)
        flat_gate = gate_vals.reshape(-1)
        flat_token = jnp.repeat(jnp.arange(T_loc), K)
        order = jnp.argsort(flat_expert)
        se, sg, stok = flat_expert[order], flat_gate[order], flat_token[order]
        first = jnp.searchsorted(se, se, side="left")
        pos = jnp.arange(T_loc * K) - first
        keep = pos < C
        dest = jnp.where(keep, se * C + pos, E * C)
        buf = jnp.zeros((E * C + 1, d), x_l.dtype).at[dest].set(x_l[stok])
        send = buf[:-1].reshape(M, E_loc * C, d)
        recv = jax.lax.all_to_all(send, "model", split_axis=0, concat_axis=0,
                                  tiled=False)                # [M, E_loc*C, d]
        x_ec = recv.reshape(M, E_loc, C, d).transpose(1, 0, 2, 3) \
                   .reshape(E_loc, M * C, d)
        h = jnp.einsum("ecd,edf->ecf", x_ec, w_in.astype(x_ec.dtype))
        if mlp_kind in ("swiglu", "geglu"):
            g = jnp.einsum("ecd,edf->ecf", x_ec, w_gate.astype(x_ec.dtype))
            h = (jax.nn.silu(g) if mlp_kind == "swiglu"
                 else jax.nn.gelu(g, approximate=True)) * h
        else:
            h = activate(h, mlp_kind)
        y_ec = jnp.einsum("ecf,efd->ecd", h, w_out.astype(x_ec.dtype))
        back = y_ec.reshape(E_loc, M, C, d).transpose(1, 0, 2, 3) \
                   .reshape(M, E_loc * C, d)
        got = jax.lax.all_to_all(back, "model", split_axis=0, concat_axis=0,
                                 tiled=False)                 # [M, E_loc*C, d]
        y_flat = got.reshape(E * C, d)
        gathered = jnp.where(keep[:, None],
                             y_flat[jnp.minimum(dest, E * C - 1)], 0.0)
        out = jnp.zeros((T_loc, d), x_l.dtype).at[stok].add(
            gathered * sg[:, None].astype(x_l.dtype))
        if shared is not None:
            sh_in, sh_gate, sh_out = shared
            hs = x_l @ sh_in.astype(x_l.dtype)
            if sh_gate is not None:
                gs = x_l @ sh_gate.astype(x_l.dtype)
                hs = (jax.nn.silu(gs) if mlp_kind == "swiglu"
                      else jax.nn.gelu(gs, approximate=True)) * hs
            else:
                hs = activate(hs, mlp_kind)
            part = hs @ sh_out.astype(x_l.dtype)
            out = out + jax.lax.psum(part.astype(F32), "model").astype(x_l.dtype)
        return out, aux[None]

    glu = mlp_kind in ("swiglu", "geglu")
    shared_args = None
    shared_specs = None
    if cfg.num_shared > 0:
        shared_args = (params["shared_in"],
                       params.get("shared_gate") if glu else None,
                       params["shared_out"])
        shared_specs = (P(None, "model"),
                        P(None, "model") if glu else None,
                        P("model", None))

    def _sm(fn, in_specs, out_specs):
        try:
            return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_vma=False)
        except (AttributeError, TypeError):
            from jax.experimental.shard_map import shard_map
            return shard_map(fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_rep=False)

    tok_spec = P(data_axes) if data_axes else P()
    fn = _sm(
        body,
        in_specs=(tok_spec, P(None, None), P("model", None, None),
                  P("model", None, None) if glu else P(None),
                  P("model", None, None), shared_specs),
        out_specs=(tok_spec, P()))
    w_gate = params["w_gate"] if glu else jnp.zeros((1,), x.dtype)
    out, aux = fn(x, params["router"], params["w_in"], w_gate,
                  params["w_out"], shared_args)
    return out, aux[0]
