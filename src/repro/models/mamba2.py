"""Mamba-2 (SSD — state-space duality) layer: chunked training scan and the
O(1)-state decode step.

Follows Dao & Gu 2024 (arXiv:2405.21060): the selective SSM is computed as a
block decomposition — quadratic attention-like term within chunks, linear
state recurrence across chunks.  This keeps training compute matmul-dominated
(MXU-friendly) while decode carries only a [H, P, N] state per sequence —
which is why the paper's paged-KV technique is inapplicable to this family
(no growing translated address space; see DESIGN.md §Arch-applicability).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import MambaCfg
from .common import F32, ParamSpec, rms_norm


def mamba_spec(d_model: int, cfg: MambaCfg) -> dict:
    di = cfg.expand * d_model
    H = di // cfg.head_dim
    N = cfg.d_state
    conv_ch = di + 2 * N
    return {
        # in_proj emits [z (di), x (di), B (N), C (N), dt (H)]
        "in_proj": ParamSpec((d_model, 2 * di + 2 * N + H), ("embed", "ff")),
        "conv_w": ParamSpec((cfg.conv_dim, conv_ch), (None, "ff"), init="normal",
                            scale=0.5),
        "conv_b": ParamSpec((conv_ch,), ("ff",), init="zeros"),
        "A_log": ParamSpec((H,), ("q_heads",), init="zeros"),
        "D": ParamSpec((H,), ("q_heads",), init="ones"),
        "dt_bias": ParamSpec((H,), ("q_heads",), init="zeros"),
        "norm": ParamSpec((di,), ("ff",), init="ones"),
        "out_proj": ParamSpec((di, d_model), ("ff", "embed")),
    }


def _split_proj(z_x_b_c_dt: jax.Array, di: int, N: int, H: int):
    z = z_x_b_c_dt[..., :di]
    x = z_x_b_c_dt[..., di:2 * di]
    B = z_x_b_c_dt[..., 2 * di:2 * di + N]
    C = z_x_b_c_dt[..., 2 * di + N:2 * di + 2 * N]
    dt = z_x_b_c_dt[..., 2 * di + 2 * N:]
    return z, x, B, C, dt


def _causal_conv(u: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv1d. u: [B,S,C]; w: [K,C]."""
    K = w.shape[0]
    pad = jnp.pad(u, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + u.shape[1], :] * w[i] for i in range(K))
    return jax.nn.silu(out + b)


def ssd_chunked(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
                Cm: jax.Array, chunk: int) -> jax.Array:
    """SSD scan. x: [B,S,H,P]; dt: [B,S,H] (post-softplus); A: [H] (negative);
    Bm, Cm: [B,S,N].  Returns y: [B,S,H,P].
    """
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    nc = S // chunk
    assert nc * chunk == S, "seq len must be divisible by ssd chunk"

    dA = dt * A                                   # [B,S,H] negative decays
    # chunk-major layout for lax.scan: [nc, B, c, ...]
    xc = x.reshape(Bsz, nc, chunk, H, P).transpose(1, 0, 2, 3, 4)
    dtc = dt.reshape(Bsz, nc, chunk, H).transpose(1, 0, 2, 3)
    dAc = dA.reshape(Bsz, nc, chunk, H).transpose(1, 0, 2, 3)
    Bc = Bm.reshape(Bsz, nc, chunk, N).transpose(1, 0, 2, 3)
    Cc = Cm.reshape(Bsz, nc, chunk, N).transpose(1, 0, 2, 3)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))

    def step(h, inp):
        xg, dtg, dAg, Bg, Cg = inp
        seg = jnp.cumsum(dAg, axis=1)                          # [B,c,H]
        # intra-chunk: L[i,j] = exp(seg_i - seg_j) for i >= j (one chunk only,
        # so the [B,c,c,H] buffer stays small)
        rel = seg[:, :, None, :] - seg[:, None, :, :]          # [B,c,c,H]
        L = jnp.where(tri[None, :, :, None], jnp.exp(rel), 0.0)
        scores = jnp.einsum("bin,bjn->bij", Cg, Bg)            # [B,c,c]
        M = scores[..., None] * L * dtg[:, None, :, :]         # [B,c,c,H]
        y_intra = jnp.einsum("bijh,bjhp->bihp", M, xg)
        # inter-chunk: contribution of the carried state
        y_inter = jnp.einsum("bin,bih,bhnp->bihp", Cg, jnp.exp(seg), h)
        # update carried state
        decay_to_end = jnp.exp(seg[:, -1:, :] - seg)           # [B,c,H]
        S_chunk = jnp.einsum("bjn,bjh,bjhp->bhnp", Bg, dtg * decay_to_end, xg)
        h_new = h * jnp.exp(seg[:, -1, :])[..., None, None] + S_chunk
        return h_new, y_intra + y_inter

    h0 = jnp.zeros((Bsz, H, N, P), x.dtype)
    h_last, yc = jax.lax.scan(step, h0, (xc, dtc, dAc, Bc, Cc))
    y = yc.transpose(1, 0, 2, 3, 4).reshape(Bsz, S, H, P)
    return y, h_last


def mamba_apply(params, x: jax.Array, cfg: MambaCfg,
                return_state: bool = False):
    """Full-sequence (training/prefill) path. x: [B,S,d] -> [B,S,d]
    (optionally also the final decode state)."""
    Bsz, S, d = x.shape
    di = cfg.expand * d
    H = di // cfg.head_dim
    N = cfg.d_state
    proj = x @ params["in_proj"].astype(x.dtype)
    z, u, Bm, Cm, dt = _split_proj(proj, di, N, H)
    ubc_raw = jnp.concatenate([u, Bm, Cm], axis=-1)
    ubc = _causal_conv(ubc_raw, params["conv_w"].astype(x.dtype),
                       params["conv_b"].astype(x.dtype))
    u, Bm, Cm = ubc[..., :di], ubc[..., di:di + N], ubc[..., di + N:]
    dt = jax.nn.softplus(dt.astype(F32) + params["dt_bias"].astype(F32))
    A = -jnp.exp(params["A_log"].astype(F32))
    uh = u.reshape(Bsz, S, H, cfg.head_dim)
    y, h_last = ssd_chunked(uh.astype(F32), dt, A, Bm.astype(F32),
                            Cm.astype(F32), min(cfg.chunk, S))
    y = y + uh.astype(F32) * params["D"].astype(F32)[None, None, :, None]
    y = y.reshape(Bsz, S, di).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = rms_norm(y, params["norm"])
    out = y @ params["out_proj"].astype(x.dtype)
    if not return_state:
        return out
    K = cfg.conv_dim
    conv_state = ubc_raw[:, -(K - 1):, :] if S >= K - 1 else jnp.pad(
        ubc_raw, ((0, 0), (K - 1 - S, 0), (0, 0)))
    state = {"ssm": h_last.astype(x.dtype), "conv": conv_state}
    return out, state


def mamba_decode_step(params, x: jax.Array, state: dict, cfg: MambaCfg
                      ) -> tuple[jax.Array, dict]:
    """Single-token decode. x: [B,d]; state = {"ssm": [B,H,N,P],
    "conv": [B,K-1,conv_ch]}.  Returns ([B,d], new state)."""
    Bsz, d = x.shape
    di = cfg.expand * d
    H = di // cfg.head_dim
    N = cfg.d_state
    K = cfg.conv_dim
    proj = x @ params["in_proj"].astype(x.dtype)
    z, u, Bm, Cm, dt = _split_proj(proj, di, N, H)
    ubc = jnp.concatenate([u, Bm, Cm], axis=-1)                # [B, conv_ch]
    conv_hist = jnp.concatenate([state["conv"], ubc[:, None, :]], axis=1)  # [B,K,ch]
    w = params["conv_w"].astype(x.dtype)
    conv_out = jax.nn.silu(
        jnp.einsum("bkc,kc->bc", conv_hist, w) + params["conv_b"].astype(x.dtype))
    u, Bm, Cm = conv_out[..., :di], conv_out[..., di:di + N], conv_out[..., di + N:]
    dt = jax.nn.softplus(dt.astype(F32) + params["dt_bias"].astype(F32))   # [B,H]
    A = -jnp.exp(params["A_log"].astype(F32))
    g = jnp.exp(dt * A)                                        # [B,H]
    uh = u.reshape(Bsz, H, cfg.head_dim).astype(F32)
    dBx = jnp.einsum("bh,bn,bhp->bhnp", dt, Bm.astype(F32), uh)
    h = state["ssm"].astype(F32) * g[..., None, None] + dBx
    y = jnp.einsum("bn,bhnp->bhp", Cm.astype(F32), h)
    y = y + uh * params["D"].astype(F32)[None, :, None]
    y = y.reshape(Bsz, di).astype(x.dtype) * jax.nn.silu(z)
    y = rms_norm(y, params["norm"])
    out = y @ params["out_proj"].astype(x.dtype)
    new_state = {"ssm": h.astype(state["ssm"].dtype), "conv": conv_hist[:, 1:, :]}
    return out, new_state


def mamba_state_init(batch: int, d_model: int, cfg: MambaCfg, dtype=jnp.float32
                     ) -> dict:
    di = cfg.expand * d_model
    H = di // cfg.head_dim
    return {
        "ssm": jnp.zeros((batch, H, cfg.d_state, cfg.head_dim), dtype),
        "conv": jnp.zeros((batch, cfg.conv_dim - 1, di + 2 * cfg.d_state), dtype),
    }
