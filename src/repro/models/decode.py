"""Serving-side model execution: paged-KV prefill and single-token decode.

This is where the paper's technique meets the device: the KV cache lives in a
multi-size-paged HBM pool owned by repro.core.MemoryManager.  Every attention
layer reads KV through the block table (the page-table analogue) and emits
per-block attention mass — the DAMON heat signal that drives promotion
decisions.

Two attention backends:
  * "gather"      — reference/jnp path: gather blocks then dense attention.
                    Used by the CPU engine and as the dry-run BASELINE (its
                    lowering shows the collective cost of naive paged reads
                    on a sharded pool — see EXPERIMENTS.md §Perf).
  * "flashdecode" — shard_map flash-decoding over the ("data","model")-sharded
                    pool with shard-local block lists; the optimized path
                    (and the structure the Pallas kernel plugs into).

Cache layout (pytree mirroring the block segmentation of transformer.py):
  attn (GQA) : {"pool_k","pool_v"}: [NB, bt, KVH, hd]  (stacked [reps,...] in scans)
  MLA        : {"pool_ckv"}: [NB, bt, kv_lora + qk_rope]
  mamba      : {"ssm","conv"} per mamba_state_init
  whisper dec: adds {"xk","xv"}: [B, F, KVH, hd] static cross-attn cache
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .attention import flash_attention
from .common import BF16, F32, apply_mrope, apply_rope, pad_vocab, rms_norm, \
    sinusoidal_position_at, sinusoidal_positions
from .mamba2 import mamba_apply, mamba_decode_step, mamba_state_init
from .transformer import (LayerPlan, _apply_norm, _attn_forward, _mlp_forward,
                          _project_qkv, _xattn_forward, build_layer_plans,
                          build_segments, encoder_forward, layer_forward)
from .moe import moe_apply

Pytree = Any
NEG_INF = -1e30


@dataclass(frozen=True)
class PagedLayout:
    """Static paged-KV geometry for one served batch."""
    num_blocks: int          # NB: physical pool blocks (global)
    block_tokens: int        # bt
    max_blocks: int          # MB: per-sequence block-table length

    @property
    def max_seq(self) -> int:
        return self.max_blocks * self.block_tokens


# ---------------------------------------------------------------------------
# Cache construction (abstract for the dry-run, concrete for the engine)
# ---------------------------------------------------------------------------

def _attn_cache_shape(cfg: ModelConfig, layout: PagedLayout) -> dict:
    if cfg.mla is not None:
        m = cfg.mla
        return {"pool_ckv": (layout.num_blocks, layout.block_tokens,
                             m.kv_lora + m.qk_rope)}
    return {
        "pool_k": (layout.num_blocks, layout.block_tokens, cfg.kv_heads, cfg.head_dim),
        "pool_v": (layout.num_blocks, layout.block_tokens, cfg.kv_heads, cfg.head_dim),
    }


def cache_spec(cfg: ModelConfig, layout: PagedLayout, batch: int,
               dtype=BF16) -> Pytree:
    """ShapeDtypeStruct pytree of the serving cache, segment-structured."""
    def leaf(shape):
        return jax.ShapeDtypeStruct(shape, dtype)

    def layer_cache(plan: LayerPlan) -> dict:
        if plan.kind == "mamba":
            di = cfg.mamba.expand * cfg.d_model
            H = di // cfg.mamba.head_dim
            c = {
                "ssm": leaf((batch, H, cfg.mamba.d_state, cfg.mamba.head_dim)),
                "conv": leaf((batch, cfg.mamba.conv_dim - 1,
                              di + 2 * cfg.mamba.d_state)),
            }
        else:
            c = {k: leaf(s) for k, s in _attn_cache_shape(cfg, layout).items()}
        if plan.xattn:
            c["xk"] = leaf((batch, cfg.enc_frames, cfg.kv_heads, cfg.head_dim))
            c["xv"] = leaf((batch, cfg.enc_frames, cfg.kv_heads, cfg.head_dim))
        return c

    segs = build_segments(build_layer_plans(cfg, decoder=True))
    out: dict = {}
    for si, seg in enumerate(segs):
        if seg[0] == "plain":
            out[f"p{si}"] = layer_cache(seg[1])
        else:
            _, cycle, reps = seg
            member = {f"m{j}": layer_cache(pl) for j, pl in enumerate(cycle)}
            out[f"s{si}"] = jax.tree.map(
                lambda l: jax.ShapeDtypeStruct((reps,) + l.shape, l.dtype),
                member)
    return out


def cache_init(cfg: ModelConfig, layout: PagedLayout, batch: int,
               dtype=BF16) -> Pytree:
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_spec(cfg, layout, batch, dtype))


# ---------------------------------------------------------------------------
# Paged attention backends (decode)
# ---------------------------------------------------------------------------

def paged_decode_attention_gather(q, pool_k, pool_v, block_table, lengths, *,
                                  block_tokens: int, window=None,
                                  soft_cap=None):
    """Reference paged decode. q: [B,H,hd]; pools: [NB,bt,KVH,hd];
    block_table: [B,MB] (-1 = unmapped); lengths: [B] (tokens INCLUDING the
    current one).  Returns (out [B,H,hd], heat [B,MB])."""
    B, H, hd = q.shape
    MB = block_table.shape[1]
    KVH = pool_k.shape[2]
    G = H // KVH
    bt = block_tokens
    scale = 1.0 / math.sqrt(hd)
    safe_bt = jnp.maximum(block_table, 0)
    k = pool_k[safe_bt].reshape(B, MB * bt, KVH, hd)
    v = pool_v[safe_bt].reshape(B, MB * bt, KVH, hd)
    qg = q.reshape(B, KVH, G, hd).astype(F32)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k.astype(F32)) * scale
    if soft_cap is not None:
        s = soft_cap * jnp.tanh(s / soft_cap)
    pos = jnp.arange(MB * bt)[None, :]
    valid = (pos < lengths[:, None]) & jnp.repeat(block_table >= 0, bt, axis=1)
    if window is not None:
        valid &= pos > (lengths[:, None] - 1 - window)
    s = jnp.where(valid[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(valid[:, None, None], p, 0.0)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v.astype(F32))
    heat = p.sum(axis=(1, 2)).reshape(B, MB, bt).sum(-1)      # attention mass/block
    return out.reshape(B, H, hd).astype(q.dtype), heat


def paged_decode_attention_mla_gather(q_eff, q_rope, pool_ckv, block_table,
                                      lengths, *, block_tokens: int,
                                      kv_lora: int, qk_nope: int = 128):
    """MLA absorbed decode over the paged latent cache.
    q_eff: [B,H,L] (q_nope @ w_uk); q_rope: [B,H,Dr];
    pool_ckv: [NB,bt,L+Dr]. Returns (o_lat [B,H,L], heat [B,MB])."""
    B, H, L = q_eff.shape
    MB = block_table.shape[1]
    bt = block_tokens
    safe_bt = jnp.maximum(block_table, 0)
    lat = pool_ckv[safe_bt].reshape(B, MB * bt, -1)
    ckv, kr = lat[..., :kv_lora], lat[..., kv_lora:]
    scale = 1.0 / math.sqrt(qk_nope + q_rope.shape[-1])
    s = (jnp.einsum("bhl,bsl->bhs", q_eff.astype(F32), ckv.astype(F32))
         + jnp.einsum("bhr,bsr->bhs", q_rope.astype(F32), kr.astype(F32))) * scale
    pos = jnp.arange(MB * bt)[None, :]
    valid = (pos < lengths[:, None]) & jnp.repeat(block_table >= 0, bt, axis=1)
    s = jnp.where(valid[:, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(valid[:, None], p, 0.0)
    o_lat = jnp.einsum("bhs,bsl->bhl", p, ckv.astype(F32))
    heat = p.sum(axis=1).reshape(B, MB, bt).sum(-1)
    return o_lat, heat


# ---------------------------------------------------------------------------
# KV pool writes
# ---------------------------------------------------------------------------

def write_token_kv(pool, new_kv, block_table, lengths, *, block_tokens: int):
    """Scatter one token's KV into the pool.
    pool: [NB,bt,...]; new_kv: [B,...]; lengths: position of the new token.
    Rows whose target block is unmapped (table = -1: empty batch slots,
    sequences skipped this step) are dropped, like write_prefill_kv."""
    B = new_kv.shape[0]
    blk = lengths // block_tokens
    off = lengths % block_tokens
    raw = jnp.take_along_axis(block_table, blk[:, None], axis=1)[:, 0]
    # route unmapped rows out of bounds and drop them: a write-back of the
    # stale value would race a live row scattering to the same (block, off)
    safe = jnp.where(raw >= 0, raw, pool.shape[0])
    return pool.at[safe, off].set(new_kv.astype(pool.dtype), mode="drop")


def write_prefill_kv(pool, kv_seq, block_table, *, block_tokens: int):
    """Scatter a full prefill's KV. kv_seq: [B,S,...]; S % bt == 0 assumed
    (engine pads).  Blocks with table = -1 are dropped to a scratch row."""
    B, S = kv_seq.shape[:2]
    bt = block_tokens
    nb = S // bt
    kvb = kv_seq.reshape((B * nb, bt) + kv_seq.shape[2:])
    tbl = block_table[:, :nb].reshape(-1)
    # out-of-bounds + mode='drop' instead of a masked write-back, which
    # would race a live row scattering to the same block
    safe = jnp.where(tbl >= 0, tbl, pool.shape[0])
    return pool.at[safe].set(kvb.astype(pool.dtype), mode="drop")


# ---------------------------------------------------------------------------
# Decode step (single token for the whole batch)
# ---------------------------------------------------------------------------

def _decode_attn_layer(cfg: ModelConfig, plan: LayerPlan, p: Pytree,
                       cache: Pytree, x: jax.Array, lengths: jax.Array,
                       block_table: jax.Array, layout: PagedLayout,
                       pos3d=None, attn_impl: str = "gather",
                       sharded_table=None, sharded_logical=None):
    """x: [B,d] -> (out [B,d], new cache, heat [B,MB])."""
    B, d = x.shape
    H, KVH, hd = cfg.n_heads, cfg.kv_heads, cfg.head_dim
    bt = layout.block_tokens
    window = cfg.attn.window if plan.local else None
    positions = lengths.astype(F32)[:, None]                  # [B,1]

    if cfg.mla is not None:
        m = cfg.mla
        q = (x @ p["wq"].astype(x.dtype)).reshape(B, H, m.qk_nope + m.qk_rope)
        q_nope, q_rope = q[..., :m.qk_nope], q[..., m.qk_nope:]
        dkv = x @ p["w_dkv"].astype(x.dtype)
        c_kv = rms_norm(dkv[..., :m.kv_lora], p["kv_norm"])
        k_rope = dkv[..., m.kv_lora:]
        q_rope = apply_rope(q_rope[:, None], positions,
                            theta=cfg.attn.rope_theta)[:, 0]
        k_rope = apply_rope(k_rope[:, None, None], positions,
                            theta=cfg.attn.rope_theta)[:, 0, 0]
        new_lat = jnp.concatenate([c_kv, k_rope], axis=-1)
        pool = write_token_kv(cache["pool_ckv"], new_lat, block_table, lengths,
                              block_tokens=bt)
        q_eff = jnp.einsum("bhd,hld->bhl", q_nope.astype(F32),
                           p["w_uk"].astype(F32))
        if attn_impl.startswith("flashdecode"):
            from ..distributed.flashdecode import paged_mla_decode_sharded
            o_lat, heat = paged_mla_decode_sharded(
                q_eff, q_rope, pool, sharded_table, sharded_logical,
                lengths + 1, block_tokens=bt, kv_lora=m.kv_lora,
                qk_nope=m.qk_nope,
                batch_sharded=not attn_impl.endswith("blocksharded"))
        else:
            o_lat, heat = paged_decode_attention_mla_gather(
                q_eff, q_rope, pool, block_table, lengths + 1,
                block_tokens=bt, kv_lora=m.kv_lora, qk_nope=m.qk_nope)
        out = jnp.einsum("bhl,hld->bhd", o_lat, p["w_uv"].astype(F32))
        out = out.reshape(B, H * m.v_head).astype(x.dtype)
        return out @ p["wo"].astype(x.dtype), {"pool_ckv": pool}, heat

    q = (x @ p["wq"].astype(x.dtype)).reshape(B, H, hd)
    k_new = (x @ p["wk"].astype(x.dtype)).reshape(B, KVH, hd)
    v_new = (x @ p["wv"].astype(x.dtype)).reshape(B, KVH, hd)
    if cfg.attn.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k_new = rms_norm(k_new, p["k_norm"])
    if cfg.attn.mrope_sections is not None:
        q = apply_mrope(q[:, None], pos3d, cfg.attn.mrope_sections,
                        theta=cfg.attn.rope_theta)[:, 0]
        k_new = apply_mrope(k_new[:, None], pos3d, cfg.attn.mrope_sections,
                            theta=cfg.attn.rope_theta)[:, 0]
    elif cfg.attn.use_rope:
        q = apply_rope(q[:, None], positions, theta=cfg.attn.rope_theta)[:, 0]
        k_new = apply_rope(k_new[:, None], positions,
                           theta=cfg.attn.rope_theta)[:, 0]
    pool_k = write_token_kv(cache["pool_k"], k_new, block_table, lengths,
                            block_tokens=bt)
    pool_v = write_token_kv(cache["pool_v"], v_new, block_table, lengths,
                            block_tokens=bt)
    if attn_impl.startswith("flashdecode"):
        from ..distributed.flashdecode import paged_decode_attention_sharded
        out, heat = paged_decode_attention_sharded(
            q, pool_k, pool_v, sharded_table, sharded_logical, lengths + 1,
            block_tokens=bt, window=window, soft_cap=cfg.attn.logit_soft_cap,
            batch_sharded=not attn_impl.endswith("blocksharded"))
    else:
        out, heat = paged_decode_attention_gather(
            q, pool_k, pool_v, block_table, lengths + 1,
            block_tokens=bt, window=window, soft_cap=cfg.attn.logit_soft_cap)
    out = out.reshape(B, H * hd)
    new_cache = {"pool_k": pool_k, "pool_v": pool_v}
    return out @ p["wo"].astype(x.dtype), new_cache, heat


def _decode_layer(cfg, plan, p, cache, x, lengths, block_table, layout,
                  pos3d=None, attn_impl="gather", sharded_table=None,
                  sharded_logical=None):
    h = _apply_norm(cfg, p["ln1"], x)
    heat = jnp.zeros((x.shape[0], layout.max_blocks), F32)
    new_cache = dict(cache)
    if plan.kind == "mamba":
        y, st = mamba_decode_step(p["mamba"], h, cache, cfg.mamba)
        x = x + y
        new_cache.update(st)
    else:
        y, st, heat = _decode_attn_layer(cfg, plan, p["attn"], cache, h, lengths,
                                         block_table, layout, pos3d, attn_impl,
                                         sharded_table, sharded_logical)
        x = x + y
        new_cache.update(st)
    if plan.xattn:
        hx = _apply_norm(cfg, p["lnx"], x)
        q = (hx @ p["xattn"]["wq"].astype(x.dtype)).reshape(
            x.shape[0], cfg.n_heads, cfg.head_dim)
        from .attention import decode_attention_dense
        xo = decode_attention_dense(
            q, cache["xk"], cache["xv"],
            jnp.full((x.shape[0],), cache["xk"].shape[1], jnp.int32))
        x = x + xo.reshape(x.shape[0], -1) @ p["xattn"]["wo"].astype(x.dtype)
    if plan.ffn:
        h2 = _apply_norm(cfg, p["ln2"], x)
        if plan.moe:
            y, _ = moe_apply(p["moe"], h2, cfg.moe, cfg.mlp)
            x = x + y
        else:
            x = x + _mlp_forward(cfg, p["mlp"], h2)
    return x, new_cache, heat


def decode_step(params: Pytree, cfg: ModelConfig, cache: Pytree,
                tokens: jax.Array, lengths: jax.Array,
                block_table: jax.Array, layout: PagedLayout, *,
                active: jax.Array | None = None,
                pos3d: jax.Array | None = None, compute_dtype=BF16,
                attn_impl: str = "gather", sharded_table=None,
                sharded_logical=None):
    """One decode step for the batch.

    tokens: [B] int32 (the tokens at position ``lengths``); lengths: [B]
    current context length EXCLUDING the new token; block_table: [B, MB].
    ``active`` ([B] bool, optional) marks the rows that are real sequences
    this step; inactive rows see an all ``-1`` table, so their KV scatter is
    provably DROPPED (``write_token_kv`` routes them out of bounds) and
    their attention validity/heat is all-masked.  This matters once the
    block table is a PERSISTENT device buffer: a skipped or vacated slot's
    row still holds live-looking physical indices, and without the mask its
    length-0 decode would scatter garbage KV into its first block (the PR 1
    scatter-to-block-0 bug class).  ``active=None`` keeps the historical
    caller-builds-a-fresh-table behavior.
    Returns (logits [B, V_pad], new_cache, heat [B, MB]).
    """
    B = tokens.shape[0]
    if active is not None:
        block_table = jnp.where(active[:, None], block_table,
                                jnp.asarray(-1, block_table.dtype))
        lengths = jnp.where(active, lengths, jnp.asarray(0, lengths.dtype))
    x = params["embed"].astype(compute_dtype)[tokens]
    segs = build_segments(build_layer_plans(cfg, decoder=True))
    if cfg.enc_dec:
        x = x + sinusoidal_position_at(lengths, cfg.d_model).astype(compute_dtype)
    heat_total = jnp.zeros((B, layout.max_blocks), F32)
    new_cache: dict = {}
    for si, seg in enumerate(segs):
        key = f"p{si}" if seg[0] == "plain" else f"s{si}"
        if seg[0] == "plain":
            x, c, h = _decode_layer(cfg, seg[1], params["blocks"][key],
                                    cache[key], x, lengths, block_table,
                                    layout, pos3d, attn_impl,
                                    sharded_table, sharded_logical)
            new_cache[key] = c
            heat_total = heat_total + h
        else:
            _, cycle, reps = seg

            def body(carry, xs):
                xx, hh = carry
                layer_params, layer_cache = xs
                new_lc = {}
                for j, pl in enumerate(cycle):
                    xx, cj, hj = _decode_layer(
                        cfg, pl, layer_params[f"m{j}"], layer_cache[f"m{j}"],
                        xx, lengths, block_table, layout, pos3d, attn_impl,
                        sharded_table, sharded_logical)
                    new_lc[f"m{j}"] = cj
                    hh = hh + hj
                return (xx, hh), new_lc

            (x, heat_total), nc = jax.lax.scan(
                body, (x, heat_total),
                (params["blocks"][key], cache[key]))
            new_cache[key] = nc
    x = _apply_norm(cfg, params["final_norm"], x)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = x.astype(F32) @ head.astype(F32)
    return logits, new_cache, heat_total


# ---------------------------------------------------------------------------
# Prefill (full sequence, populating the paged pools)
# ---------------------------------------------------------------------------

def prefill_step(params: Pytree, cfg: ModelConfig, cache: Pytree,
                 tokens: jax.Array, block_table: jax.Array,
                 layout: PagedLayout, *, frames: jax.Array | None = None,
                 patches: jax.Array | None = None,
                 pos3d: jax.Array | None = None, compute_dtype=BF16,
                 chunk: int = 1024, last_index: jax.Array | None = None):
    """Forward the prompt and write K/V (or latents / SSM state) into the
    serving cache.  Returns (last-token logits [B,V_pad], new cache).
    ``last_index``: [B] index of each sequence's final REAL token (prompts
    are right-padded to a block multiple); defaults to the last position."""
    B, S = tokens.shape
    x = params["embed"].astype(compute_dtype)[tokens]
    if patches is not None:
        P = patches.shape[1]
        x = jnp.concatenate([patches.astype(compute_dtype), x[:, P:]], axis=1)
    positions = jnp.arange(S)[None, :].astype(F32)
    pos_info = {"positions": positions}
    if pos3d is not None:
        pos_info["pos3d"] = pos3d
    enc_out = None
    if cfg.enc_dec:
        enc_out = encoder_forward(params["encoder"], cfg, frames,
                                  compute_dtype=compute_dtype, chunk=chunk,
                                  remat=False)
        x = x + sinusoidal_positions(S, cfg.d_model)[None].astype(compute_dtype)

    segs = build_segments(build_layer_plans(cfg, decoder=True))
    new_cache: dict = {}

    def prefill_layer(plan, p, layer_cache, x):
        h = _apply_norm(cfg, p["ln1"], x)
        nc = dict(layer_cache)
        if plan.kind == "mamba":
            y, st = mamba_apply(p["mamba"], h, cfg.mamba, return_state=True)
            x = x + y
            nc["ssm"] = st["ssm"].astype(layer_cache["ssm"].dtype)
            nc["conv"] = st["conv"].astype(layer_cache["conv"].dtype)
        elif cfg.mla is not None:
            ap = p["attn"]
            m = cfg.mla
            H = cfg.n_heads
            q = (h @ ap["wq"].astype(h.dtype)).reshape(B, S, H, m.qk_nope + m.qk_rope)
            q_nope, q_rope = q[..., :m.qk_nope], q[..., m.qk_nope:]
            dkv = h @ ap["w_dkv"].astype(h.dtype)
            c_kv = rms_norm(dkv[..., :m.kv_lora], ap["kv_norm"])
            k_rope = dkv[..., m.kv_lora:]
            q_rope = apply_rope(q_rope, positions, theta=cfg.attn.rope_theta)
            k_rope_r = apply_rope(k_rope[:, :, None, :], positions,
                                  theta=cfg.attn.rope_theta)[:, :, 0, :]
            from .attention import mla_expand_attention
            o = mla_expand_attention(q_nope, q_rope, c_kv, k_rope_r,
                                     ap["w_uk"].astype(h.dtype),
                                     ap["w_uv"].astype(h.dtype),
                                     causal=True, chunk=chunk)
            x = x + o.reshape(B, S, -1) @ ap["wo"].astype(h.dtype)
            lat = jnp.concatenate([c_kv, k_rope_r], axis=-1)
            nc["pool_ckv"] = write_prefill_kv(
                layer_cache["pool_ckv"], lat, block_table,
                block_tokens=layout.block_tokens)
        else:
            ap = p["attn"]
            q, k, v = _project_qkv(cfg, ap, h)
            if cfg.attn.mrope_sections is not None:
                q = apply_mrope(q, pos_info["pos3d"], cfg.attn.mrope_sections,
                                theta=cfg.attn.rope_theta)
                k = apply_mrope(k, pos_info["pos3d"], cfg.attn.mrope_sections,
                                theta=cfg.attn.rope_theta)
            elif cfg.attn.use_rope:
                q = apply_rope(q, positions, theta=cfg.attn.rope_theta)
                k = apply_rope(k, positions, theta=cfg.attn.rope_theta)
            window = cfg.attn.window if plan.local else None
            o = flash_attention(q, k, v, causal=plan.causal, window=window,
                                chunk=chunk, soft_cap=cfg.attn.logit_soft_cap)
            x = x + o.reshape(B, S, -1) @ ap["wo"].astype(h.dtype)
            nc["pool_k"] = write_prefill_kv(layer_cache["pool_k"], k,
                                            block_table,
                                            block_tokens=layout.block_tokens)
            nc["pool_v"] = write_prefill_kv(layer_cache["pool_v"], v,
                                            block_table,
                                            block_tokens=layout.block_tokens)
        if plan.xattn:
            hx = _apply_norm(cfg, p["lnx"], x)
            x = x + _xattn_forward(cfg, p["xattn"], hx, enc_out, chunk)
            kx = (enc_out @ p["xattn"]["wk"].astype(h.dtype)).reshape(
                B, enc_out.shape[1], cfg.kv_heads, cfg.head_dim)
            vx = (enc_out @ p["xattn"]["wv"].astype(h.dtype)).reshape(
                B, enc_out.shape[1], cfg.kv_heads, cfg.head_dim)
            nc["xk"], nc["xv"] = kx, vx
        if plan.ffn:
            h2 = _apply_norm(cfg, p["ln2"], x)
            if plan.moe:
                y, _ = moe_apply(p["moe"], h2.reshape(B * S, -1), cfg.moe, cfg.mlp)
                x = x + y.reshape(B, S, -1)
            else:
                x = x + _mlp_forward(cfg, p["mlp"], h2)
        return x, nc

    for si, seg in enumerate(segs):
        key = f"p{si}" if seg[0] == "plain" else f"s{si}"
        if seg[0] == "plain":
            x, nc = prefill_layer(seg[1], params["blocks"][key], cache[key], x)
            new_cache[key] = nc
        else:
            _, cycle, reps = seg

            def body(x, xs):
                layer_params, layer_cache = xs
                nlc = {}
                for j, pl in enumerate(cycle):
                    x, nlc[f"m{j}"] = prefill_layer(pl, layer_params[f"m{j}"],
                                                    layer_cache[f"m{j}"], x)
                return x, nlc

            x, nc = jax.lax.scan(body, x, (params["blocks"][key], cache[key]))
            new_cache[key] = nc
    if last_index is None:
        x_last = x[:, -1]
    else:
        x_last = jnp.take_along_axis(
            x, last_index[:, None, None].astype(jnp.int32), axis=1)[:, 0]
    x_last = _apply_norm(cfg, params["final_norm"], x_last)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = x_last.astype(F32) @ head.astype(F32)
    return logits, new_cache


# ---------------------------------------------------------------------------
# Suffix prefill (prefix-cache hit: prefix KV comes from the pool)
# ---------------------------------------------------------------------------

def write_suffix_kv(pool, kv_seq, block_table, start, *, block_tokens: int):
    """Scatter a suffix prefill's KV at token offset ``start`` (traced).

    kv_seq: [B, S, ...] — the KV of tokens [start, start+S); rows whose
    target position lands in an unmapped block (table -1) or past the
    table are dropped, like the other pool scatters."""
    B, S = kv_seq.shape[:2]
    bt = block_tokens
    MB = block_table.shape[1]
    pos = start + jnp.arange(S)                     # [S] absolute positions
    blk = pos // bt
    off = pos % bt
    raw = jnp.take_along_axis(
        block_table, jnp.broadcast_to(jnp.where(blk < MB, blk, 0)[None],
                                      (B, S)), axis=1)
    ok = (blk < MB)[None, :] & (raw >= 0)
    safe = jnp.where(ok, raw, pool.shape[0]).reshape(-1)
    flat = kv_seq.reshape((B * S,) + kv_seq.shape[2:])
    return pool.at[safe, jnp.tile(off, B)].set(flat.astype(pool.dtype),
                                               mode="drop")


def _gather_full_kv(pool, suffix, block_table, start, key_blocks: int,
                    block_tokens: int):
    """Assemble the FULL-length per-layer KV stream of one sequence:
    positions [0, start) gathered from the paged pool (the cached prefix),
    [start, start+S) from the freshly computed ``suffix``, the rest zero.

    ``key_blocks`` is static (the prompt's padded block count), so the
    result has the exact shape — and the exact flash-attention chunking —
    of the k/v stream the ordinary full prefill would have built; the
    zero/garbage tail past the valid tokens is causally masked for every
    valid query row, which is what makes the suffix path's attention
    outputs equal the full path's suffix rows."""
    B, S = suffix.shape[:2]
    bt = block_tokens
    KB = key_blocks * bt
    tbl = block_table[:, :key_blocks]                     # [B, nb]
    gathered = pool[jnp.maximum(tbl, 0)]                  # [B, nb, bt, ...]
    full = gathered.reshape((B, KB) + pool.shape[2:])
    posk = jnp.arange(KB)
    valid = (posk[None, :] < start) & (jnp.repeat(tbl, bt, axis=1) >= 0)
    full = jnp.where(valid.reshape(valid.shape + (1,) * (full.ndim - 2)),
                     full, 0).astype(suffix.dtype)
    idx = start + jnp.arange(S)
    return full.at[:, idx].set(suffix.astype(full.dtype), mode="drop")


def prefill_suffix_step(params: Pytree, cfg: ModelConfig, cache: Pytree,
                        tokens: jax.Array, block_table: jax.Array,
                        layout: PagedLayout, *, prefix_len: jax.Array,
                        key_blocks: int, compute_dtype=BF16,
                        chunk: int = 1024,
                        last_index: jax.Array | None = None):
    """Prefill ONLY the uncached suffix of a prompt whose first
    ``prefix_len`` tokens' KV already sits in the paged pool (a prefix-cache
    hit: shared blocks mapped read-only, the partial tail already
    copy-on-write-broken).

    tokens: [B, S] the SUFFIX tokens (block-padded); prefix_len: [B]-free
    traced scalar — absolute position of tokens[:, 0]; key_blocks: STATIC
    padded block count of the whole prompt (compile key, with S).  Each
    attention layer projects q/k/v for the suffix rows only, attends
    against pool-gathered prefix + computed suffix keys via
    ``flash_attention(q_offset=prefix_len)``, and scatters the suffix KV at
    its token offset.  Layer kinds with sequential state (mamba, cross-
    attn, enc-dec) cannot skip prefix compute and are rejected.
    Returns (last-token logits [B, V_pad], new cache).
    """
    B, S = tokens.shape
    bt = layout.block_tokens
    if cfg.enc_dec or cfg.vlm_patches or cfg.attn.mrope_sections is not None:
        raise ValueError("suffix prefill supports plain decoder LMs only")
    x = params["embed"].astype(compute_dtype)[tokens]
    positions = (prefix_len + jnp.arange(S))[None, :].astype(F32)
    segs = build_segments(build_layer_plans(cfg, decoder=True))
    new_cache: dict = {}

    def suffix_layer(plan, p, layer_cache, x):
        if plan.kind not in ("a", "attn") or plan.xattn:
            raise ValueError(
                f"suffix prefill cannot skip prefix compute for layer kind "
                f"{plan.kind!r} (sequential state)")
        h = _apply_norm(cfg, p["ln1"], x)
        nc = dict(layer_cache)
        if cfg.mla is not None:
            ap = p["attn"]
            m = cfg.mla
            H = cfg.n_heads
            q = (h @ ap["wq"].astype(h.dtype)).reshape(B, S, H,
                                                       m.qk_nope + m.qk_rope)
            q_nope, q_rope = q[..., :m.qk_nope], q[..., m.qk_nope:]
            dkv = h @ ap["w_dkv"].astype(h.dtype)
            c_kv = rms_norm(dkv[..., :m.kv_lora], ap["kv_norm"])
            k_rope = dkv[..., m.kv_lora:]
            q_rope = apply_rope(q_rope, positions, theta=cfg.attn.rope_theta)
            k_rope_r = apply_rope(k_rope[:, :, None, :], positions,
                                  theta=cfg.attn.rope_theta)[:, :, 0, :]
            lat = jnp.concatenate([c_kv, k_rope_r], axis=-1)
            lat_full = _gather_full_kv(layer_cache["pool_ckv"], lat,
                                       block_table, prefix_len, key_blocks, bt)
            from .attention import mla_expand_attention
            o = mla_expand_attention(q_nope, q_rope,
                                     lat_full[..., :m.kv_lora],
                                     lat_full[..., m.kv_lora:],
                                     ap["w_uk"].astype(h.dtype),
                                     ap["w_uv"].astype(h.dtype),
                                     causal=True, chunk=chunk,
                                     q_offset=prefix_len)
            x = x + o.reshape(B, S, -1) @ ap["wo"].astype(h.dtype)
            nc["pool_ckv"] = write_suffix_kv(
                layer_cache["pool_ckv"], lat, block_table, prefix_len,
                block_tokens=bt)
        else:
            ap = p["attn"]
            q, k, v = _project_qkv(cfg, ap, h)
            if cfg.attn.use_rope:
                q = apply_rope(q, positions, theta=cfg.attn.rope_theta)
                k = apply_rope(k, positions, theta=cfg.attn.rope_theta)
            window = cfg.attn.window if plan.local else None
            k_full = _gather_full_kv(layer_cache["pool_k"], k, block_table,
                                     prefix_len, key_blocks, bt)
            v_full = _gather_full_kv(layer_cache["pool_v"], v, block_table,
                                     prefix_len, key_blocks, bt)
            o = flash_attention(q, k_full, v_full, causal=plan.causal,
                                window=window, chunk=chunk,
                                soft_cap=cfg.attn.logit_soft_cap,
                                q_offset=prefix_len)
            x = x + o.reshape(B, S, -1) @ ap["wo"].astype(h.dtype)
            nc["pool_k"] = write_suffix_kv(layer_cache["pool_k"], k,
                                           block_table, prefix_len,
                                           block_tokens=bt)
            nc["pool_v"] = write_suffix_kv(layer_cache["pool_v"], v,
                                           block_table, prefix_len,
                                           block_tokens=bt)
        if plan.ffn:
            h2 = _apply_norm(cfg, p["ln2"], x)
            if plan.moe:
                y, _ = moe_apply(p["moe"], h2.reshape(B * S, -1), cfg.moe,
                                 cfg.mlp)
                x = x + y.reshape(B, S, -1)
            else:
                x = x + _mlp_forward(cfg, p["mlp"], h2)
        return x, nc

    for si, seg in enumerate(segs):
        key = f"p{si}" if seg[0] == "plain" else f"s{si}"
        if seg[0] == "plain":
            x, nc = suffix_layer(seg[1], params["blocks"][key], cache[key], x)
            new_cache[key] = nc
        else:
            _, cycle, reps = seg

            def body(x, xs):
                layer_params, layer_cache = xs
                nlc = {}
                for j, pl in enumerate(cycle):
                    x, nlc[f"m{j}"] = suffix_layer(pl, layer_params[f"m{j}"],
                                                   layer_cache[f"m{j}"], x)
                return x, nlc

            x, nc = jax.lax.scan(body, x, (params["blocks"][key], cache[key]))
            new_cache[key] = nc
    if last_index is None:
        x_last = x[:, -1]
    else:
        x_last = jnp.take_along_axis(
            x, last_index[:, None, None].astype(jnp.int32), axis=1)[:, 0]
    x_last = _apply_norm(cfg, params["final_norm"], x_last)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = x_last.astype(F32) @ head.astype(F32)
    return logits, new_cache
