"""Pure-JAX model zoo: one generic assembly (transformer.py) configured per
architecture, plus the serving-side paged-KV execution paths (decode.py)."""

from .common import abstract, logical_axes, materialize, pad_vocab, param_count
from .decode import (PagedLayout, cache_init, cache_spec, decode_step,
                     prefill_step)
from .transformer import (build_layer_plans, build_segments, lm_loss,
                          model_forward, model_spec)

__all__ = [
    "abstract", "logical_axes", "materialize", "pad_vocab", "param_count",
    "PagedLayout", "cache_init", "cache_spec", "decode_step", "prefill_step",
    "build_layer_plans", "build_segments", "lm_loss", "model_forward",
    "model_spec",
]
