"""Gradient compression for the data-parallel all-reduce, with error feedback.

Modes:
  none  — plain f32 psum.
  bf16  — cast to bf16 before the wire (2x byte reduction), f32 accumulate.
  int8  — per-row int8 quantization + f32 scale, exchanged with all_gather
          over the data axis and reduced locally in f32 (the 1-bit-Adam-style
          formulation that keeps the sum exact per-shard).  ~4x byte
          reduction.  Error feedback carries the quantization residual into
          the next step so compression error does not bias convergence.

All collectives are expressed inside shard_map so the wire dtype is the
compressed one (a psum of int8 would up-cast; all_gather does not).
"""

from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

Pytree = Any
F32 = jnp.float32


def quantize_int8(x: jax.Array):
    """Symmetric per-row int8 quantization. x: [*, d]."""
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0).astype(F32)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(F32) * scale


def compress_residual(x: jax.Array, err: jax.Array):
    """Apply error feedback: quantize (x + err), return (q, scale, new_err)."""
    target = x.astype(F32) + err
    q, scale = quantize_int8(target.reshape(-1, x.shape[-1]) if x.ndim > 1
                             else target[None, :])
    deq = dequantize_int8(q, scale).reshape(target.shape)
    return q, scale, target - deq


def _shard_map(fn, mesh, in_specs, out_specs):
    try:
        return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    except (AttributeError, TypeError):
        from jax.experimental.shard_map import shard_map
        return shard_map(fn, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_rep=False)


def compressed_psum_mean(x: jax.Array, mesh: Mesh, axis: str = "data", *,
                         mode: str = "int8", err: jax.Array | None = None):
    """Mean-reduce ``x`` (replicated-layout gradient shard pattern: every
    shard holds ITS microbatch's gradient of the full tensor) over ``axis``.

    Returns (mean, new_err).  ``err`` is the error-feedback state (int8 mode).
    """
    n = mesh.shape[axis]
    if mode == "none":
        def body(v):
            return jax.lax.psum(v, axis) / n
        fn = _shard_map(body, mesh, (P(axis),), P(axis))
        # caller handles layout; simple path for tests
        return fn(x), err
    if mode == "bf16":
        def body(v):
            return jax.lax.psum(v.astype(jnp.bfloat16).astype(F32), axis) / n
        fn = _shard_map(body, mesh, (P(axis),), P(axis))
        return fn(x), err
    if mode != "int8":
        raise ValueError(f"unknown compression mode {mode!r}")

    if err is None:
        err = jnp.zeros(x.shape[1:], F32)

    def body(v, e):
        # v: [1, *shape] local microbatch grad; e: [1, *shape] local residual
        g = v[0]
        q, scale, new_e = compress_residual(g, e[0])
        rows = q.shape[0]
        qg = jax.lax.all_gather(q, axis)                 # int8 on the wire
        sg = jax.lax.all_gather(scale, axis)
        total = jnp.sum(dequantize_int8(qg.reshape(n * rows, -1),
                                        sg.reshape(n * rows, 1))
                        .reshape((n,) + g.shape), axis=0)
        return (total / n)[None], new_e[None]

    fn = _shard_map(body, mesh, (P(axis), P(axis)), (P(axis), P(axis)))
    xs = jnp.broadcast_to(x[None], (n,) + x.shape) if x.ndim == err.ndim \
        else x
    # callers pass per-shard grads stacked on dim0 (size n)
    mean, new_err = fn(x, jnp.broadcast_to(err[None], (n,) + err.shape))
    return mean[0], new_err[0]


def compressed_grad_mean_tree(grads: Pytree, mesh: Mesh, axis: str = "data",
                              *, mode: str = "int8",
                              err_tree: Pytree | None = None):
    """Tree version for stacked per-shard grads [n_shards, ...] per leaf."""
    if err_tree is None:
        err_tree = jax.tree.map(lambda g: jnp.zeros(g.shape[1:], F32), grads)
    outs = jax.tree.map(
        lambda g, e: compressed_psum_mean(g, mesh, axis, mode=mode, err=e),
        grads, err_tree)
    mean = jax.tree.map(lambda t: t[0], outs,
                        is_leaf=lambda x: isinstance(x, tuple))
    err = jax.tree.map(lambda t: t[1], outs,
                       is_leaf=lambda x: isinstance(x, tuple))
    return mean, err
