"""Sharding rule engine: logical parameter/activation axes -> mesh axes.

Megatron-style TP on the "model" axis, DP over ("pod","data"); every rule is
divisibility-checked against the actual dim size and falls back to
replication when it does not divide (e.g. qwen2-vl's 28 heads on TP=16 —
recorded in DESIGN.md).  ZeRO-1 additionally shards optimizer state over the
data axis on the largest still-replicated dim.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Pytree = Any

# logical axis -> ordered mesh-axis candidates (first that divides wins)
DEFAULT_RULES: dict[str, tuple] = {
    "vocab": ("model",),
    "ff": ("model",),
    "expert": ("model",),
    "q_heads": ("model",),
    "kv_heads": ("model",),
    "kv_lora": (),
    "head_dim": (),
    "embed": (),
    "layers": (),
    "state": (),
    "conv": (),
    # activations
    "batch": (("pod", "data"), "data"),
    "seq": ("data",),
    "pool_blocks": (("data", "model"),),
}


def _axis_size(mesh: Mesh, axis) -> int:
    if isinstance(axis, tuple):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return mesh.shape[axis]


def _mesh_axes_present(mesh: Mesh, axis) -> bool:
    names = mesh.axis_names
    if isinstance(axis, tuple):
        return all(a in names for a in axis)
    return axis in names


def spec_for(shape: tuple[int, ...], axes: tuple, mesh: Mesh,
             rules: dict | None = None) -> P:
    rules = rules or DEFAULT_RULES
    used: set = set()
    parts = []
    for dim, name in zip(shape, axes):
        chosen = None
        for cand in rules.get(name, ()) if name else ():
            if not _mesh_axes_present(mesh, cand):
                continue
            flat = cand if isinstance(cand, tuple) else (cand,)
            if any(a in used for a in flat):
                continue
            if dim % _axis_size(mesh, cand) == 0:
                chosen = cand
                used.update(flat)
                break
        parts.append(chosen)
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def shardings_for_tree(shapes_tree: Pytree, axes_tree: Pytree, mesh: Mesh,
                       rules: dict | None = None) -> Pytree:
    """shapes_tree: ShapeDtypeStructs (or arrays); axes_tree: logical axes."""
    def one(sds, axes):
        return NamedSharding(mesh, spec_for(tuple(sds.shape), axes, mesh, rules))
    return jax.tree.map(one, shapes_tree, axes_tree,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            isinstance(e, (str, type(None))) for e in x))


def specs_for_tree(shapes_tree: Pytree, axes_tree: Pytree, mesh: Mesh,
                   rules: dict | None = None) -> Pytree:
    def one(sds, axes):
        return spec_for(tuple(sds.shape), axes, mesh, rules)
    return jax.tree.map(one, shapes_tree, axes_tree,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            isinstance(e, (str, type(None))) for e in x))


def zero1_spec(shape: tuple[int, ...], axes: tuple, mesh: Mesh,
               rules: dict | None = None) -> P:
    """Optimizer-state sharding: param spec + shard the largest replicated
    dim over the data axis (ZeRO-1)."""
    base = spec_for(shape, axes, mesh, rules)
    parts = list(base) + [None] * (len(shape) - len(base))
    if "data" not in mesh.axis_names:
        return base
    dsz = mesh.shape["data"]
    best, best_dim = -1, None
    for i, (dim, cur) in enumerate(zip(shape, parts)):
        if cur is None and dim % dsz == 0 and dim > best:
            best, best_dim = dim, i
    if best_dim is not None:
        parts[best_dim] = "data"
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def zero1_shardings_for_tree(shapes_tree, axes_tree, mesh, rules=None):
    def one(sds, axes):
        return NamedSharding(mesh, zero1_spec(tuple(sds.shape), axes, mesh, rules))
    return jax.tree.map(one, shapes_tree, axes_tree,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            isinstance(e, (str, type(None))) for e in x))


def batch_spec(mesh: Mesh) -> P:
    """Batch-dim sharding: over pod+data when multi-pod."""
    if "pod" in mesh.axis_names:
        return P(("pod", "data"))
    return P("data")
