"""Distributed paged flash-decoding (the optimized serve path).

The gather baseline reads the whole KV through one global gather — on a
sharded pool GSPMD turns that into pool-sized collectives.  This module is
the beyond-paper fix: the pool's block dim shards over the whole mesh,
the engine round-robins each sequence's blocks across the owning shards,
and shard_map runs flash partials over SHARD-LOCAL blocks only; partials
combine with one tiny pmax/psum (flash-decoding algebra).  Per-chip HBM
traffic drops to KV_bytes / num_chips and the only cross-chip payload is
[B, H, hd]-sized — see EXPERIMENTS.md §Perf.

Two layouts:
  * batch_sharded=True  — B divides the data axis: batch over ("pod","data"),
    blocks over "model"; combine = psum over "model".  (decode_32k)
  * batch_sharded=False — small B (long-context): batch replicated, blocks
    over the WHOLE mesh; combine = psum over every axis.   (long_500k)

The per-shard inner loop is exactly the computation of the Pallas
paged-attention kernel; on TPU the jnp body below is swapped for the kernel
(same signature), which additionally coalesces multi-size pages into single
DMAs.
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

F32 = jnp.float32
NEG_INF = -1e30

_DECODE_MESH: Mesh | None = None


def set_decode_mesh(mesh: Mesh | None) -> None:
    global _DECODE_MESH
    _DECODE_MESH = mesh


def get_decode_mesh() -> Mesh | None:
    return _DECODE_MESH


def _shard_map(fn, mesh, in_specs, out_specs):
    try:
        return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    except (AttributeError, TypeError):
        from jax.experimental.shard_map import shard_map
        return shard_map(fn, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_rep=False)


def _axis_index(names) -> jax.Array:
    if isinstance(names, str):
        return jax.lax.axis_index(names)
    idx = jax.lax.axis_index(names[0])
    for n in names[1:]:
        idx = idx * jax.lax.psum(1, n) + jax.lax.axis_index(n)
    return idx


def _partials(q_l, k, v, logical, ok, len_l, *, bt, window, soft_cap, KVH, G):
    """Shared inner flash-partial computation over local blocks."""
    Bl, MBl = logical.shape
    hd = q_l.shape[-1]
    scale = 1.0 / math.sqrt(hd)
    k = k.reshape(Bl, MBl * bt, KVH, hd)
    v = v.reshape(Bl, MBl * bt, KVH, hd)
    qg = q_l.reshape(Bl, KVH, G, hd).astype(F32)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k.astype(F32)) * scale
    if soft_cap is not None:
        s = soft_cap * jnp.tanh(s / soft_cap)
    pos = (jnp.maximum(logical, 0)[:, :, None] * bt
           + jnp.arange(bt)[None, None, :]).reshape(Bl, MBl * bt)
    valid = jnp.repeat(ok, bt, axis=1) & (pos < len_l[:, None])
    if window is not None:
        valid &= pos > (len_l[:, None] - 1 - window)
    s = jnp.where(valid[:, None, None], s, NEG_INF)
    m_loc = jnp.max(s, axis=-1)
    p = jnp.where(valid[:, None, None], jnp.exp(s - m_loc[..., None]), 0.0)
    l_loc = p.sum(-1)
    acc = jnp.einsum("bkgs,bskd->bkgd", p, v.astype(F32))
    heat = p.sum(axis=(1, 2)).reshape(Bl, MBl, bt).sum(-1)
    return m_loc, l_loc, acc, heat


def _combine(m_loc, l_loc, acc, axes):
    m_g = m_loc
    for ax in axes:
        m_g = jax.lax.pmax(m_g, ax)
    corr = jnp.where(m_loc <= NEG_INF / 2, 0.0, jnp.exp(m_loc - m_g))
    l_g = jax.lax.psum(l_loc * corr, axes)
    acc_g = jax.lax.psum(acc * corr[..., None], axes)
    return acc_g / jnp.maximum(l_g, 1e-30)[..., None]


def paged_decode_attention_sharded(q, pool_k, pool_v, sharded_table,
                                   sharded_logical, lengths, *,
                                   block_tokens: int, window=None,
                                   soft_cap=None, batch_sharded: bool = True):
    """q: [B,H,hd]; pools: [NB,bt,KVH,hd] (NB mesh-sharded);
    sharded_table/logical: [B, NS, MBl] int32 — entry (b,s,:) lists the
    GLOBAL phys blocks of sequence b owned by shard s (-1 pads; the engine/
    placement policy guarantees locality); lengths: [B] incl. current token.

    batch_sharded: NS = model axis size, B sharded over data (+pod).
    else:          NS = total shards, B replicated, blocks over whole mesh.

    Returns (out [B,H,hd], heat [B, NS*MBl] f32)."""
    mesh = _DECODE_MESH
    if mesh is None:
        raise RuntimeError("set_decode_mesh() first (launch/serve does this)")
    names = mesh.axis_names
    data_axes = tuple(n for n in names if n != "model")       # ("pod","data")
    model_ax = "model"
    D = int(np.prod([mesh.shape[n] for n in data_axes]))
    M = mesh.shape[model_ax]
    NB = pool_k.shape[0]
    B, H, hd = q.shape
    KVH = pool_k.shape[2]
    G = H // KVH
    bt = block_tokens
    pool_spec = P((*data_axes, model_ax))
    NB_loc = NB // (D * M)

    if batch_sharded:
        def body(q_l, pk_l, pv_l, tbl_l, log_l, len_l):
            d = _axis_index(data_axes)
            m = jax.lax.axis_index(model_ax)
            offset = (d * M + m) * NB_loc
            tbl = tbl_l[:, 0, :]
            logical = log_l[:, 0, :]
            local = tbl - offset
            ok = (tbl >= 0) & (local >= 0) & (local < NB_loc)
            safe = jnp.clip(local, 0, NB_loc - 1)
            m_loc, l_loc, acc, heat = _partials(
                q_l, pk_l[safe], pv_l[safe], logical, ok, len_l,
                bt=bt, window=window, soft_cap=soft_cap, KVH=KVH, G=G)
            out = _combine(m_loc, l_loc, acc, (model_ax,))
            Bl = tbl.shape[0]
            return (out.reshape(Bl, H, hd).astype(q_l.dtype),
                    heat[:, None, :])

        fn = _shard_map(
            body, mesh,
            in_specs=(P(data_axes, None, None), pool_spec, pool_spec,
                      P(data_axes, model_ax, None),
                      P(data_axes, model_ax, None), P(data_axes)),
            out_specs=(P(data_axes, None, None),
                       P(data_axes, model_ax, None)))
    else:
        all_axes = tuple(names)

        def body(q_l, pk_l, pv_l, tbl_l, log_l, len_l):
            shard = _axis_index(all_axes)
            offset = shard * NB_loc
            tbl = tbl_l[:, 0, :]
            logical = log_l[:, 0, :]
            local = tbl - offset
            ok = (tbl >= 0) & (local >= 0) & (local < NB_loc)
            safe = jnp.clip(local, 0, NB_loc - 1)
            m_loc, l_loc, acc, heat = _partials(
                q_l, pk_l[safe], pv_l[safe], logical, ok, len_l,
                bt=bt, window=window, soft_cap=soft_cap, KVH=KVH, G=G)
            out = _combine(m_loc, l_loc, acc, all_axes)
            return (out.reshape(B, H, hd).astype(q_l.dtype),
                    heat[:, None, :])

        fn = _shard_map(
            body, mesh,
            in_specs=(P(None, None, None), pool_spec, pool_spec,
                      P(None, all_axes, None), P(None, all_axes, None),
                      P(None)),
            out_specs=(P(None, None, None), P(None, all_axes, None)))

    out, heat = fn(q, pool_k, pool_v, sharded_table, sharded_logical, lengths)
    return out, heat.reshape(B, -1)


def paged_mla_decode_sharded(q_eff, q_rope, pool_ckv, sharded_table,
                             sharded_logical, lengths, *, block_tokens: int,
                             kv_lora: int, qk_nope: int = 128,
                             batch_sharded: bool = True):
    """MLA absorbed decode over the mesh-sharded latent pool (flash-decoding
    over latent blocks; §Perf hillclimb #1).

    q_eff: [B,H,L] (q_nope @ w_uk); q_rope: [B,H,Dr];
    pool_ckv: [NB, bt, L+Dr] with NB sharded over the whole mesh;
    sharded_table/logical: [B, NS, MBl] as in the GQA path.
    Returns (o_lat [B,H,L] f32, heat [B, NS*MBl])."""
    mesh = _DECODE_MESH
    if mesh is None:
        raise RuntimeError("set_decode_mesh() first (launch/serve does this)")
    names = mesh.axis_names
    data_axes = tuple(n for n in names if n != "model")
    D = int(np.prod([mesh.shape[n] for n in data_axes]))
    M = mesh.shape["model"]
    NB = pool_ckv.shape[0]
    NB_loc = NB // (D * M)
    B, H, L = q_eff.shape
    bt = block_tokens
    scale = 1.0 / math.sqrt(qk_nope + q_rope.shape[-1])
    pool_spec = P((*data_axes, "model"))
    comb_axes = ("model",) if batch_sharded else tuple(names)
    if batch_sharded:
        q_spec = P(data_axes, None, None)
        tbl_spec = P(data_axes, "model", None)
        len_spec = P(data_axes)
    else:
        q_spec = P(None, None, None)
        tbl_spec = P(None, tuple(names), None)
        len_spec = P(None)

    def body(qe_l, qr_l, pool_l, tbl_l, log_l, len_l):
        if batch_sharded:
            d = _axis_index(data_axes)
            m = jax.lax.axis_index("model")
            shard = d * M + m
        else:
            shard = _axis_index(tuple(names))
        offset = shard * NB_loc
        tbl = tbl_l[:, 0, :]
        logical = log_l[:, 0, :]
        local = tbl - offset
        ok = (tbl >= 0) & (local >= 0) & (local < NB_loc)
        safe = jnp.clip(local, 0, NB_loc - 1)
        lat = pool_l[safe]                           # [Bl, MBl, bt, L+Dr]
        Bl, MBl = tbl.shape
        lat = lat.reshape(Bl, MBl * bt, -1)
        ckv, kr = lat[..., :kv_lora], lat[..., kv_lora:]
        s = (jnp.einsum("bhl,bsl->bhs", qe_l.astype(F32), ckv.astype(F32))
             + jnp.einsum("bhr,bsr->bhs", qr_l.astype(F32), kr.astype(F32)))
        s = s * scale
        pos = (jnp.maximum(logical, 0)[:, :, None] * bt
               + jnp.arange(bt)[None, None, :]).reshape(Bl, MBl * bt)
        valid = jnp.repeat(ok, bt, axis=1) & (pos < len_l[:, None])
        s = jnp.where(valid[:, None], s, NEG_INF)
        m_loc = jnp.max(s, axis=-1)                  # [Bl,H]
        p = jnp.where(valid[:, None], jnp.exp(s - m_loc[..., None]), 0.0)
        l_loc = p.sum(-1)
        acc = jnp.einsum("bhs,bsl->bhl", p, ckv.astype(F32))
        heat = p.sum(axis=1).reshape(Bl, MBl, bt).sum(-1)
        m_g = m_loc
        for ax in comb_axes:
            m_g = jax.lax.pmax(m_g, ax)
        corr = jnp.where(m_loc <= NEG_INF / 2, 0.0, jnp.exp(m_loc - m_g))
        l_g = jax.lax.psum(l_loc * corr, comb_axes)
        acc_g = jax.lax.psum(acc * corr[..., None], comb_axes)
        o_lat = acc_g / jnp.maximum(l_g, 1e-30)[..., None]
        return o_lat, heat[:, None, :]

    fn = _shard_map(
        body, mesh,
        in_specs=(q_spec, q_spec, pool_spec, tbl_spec, tbl_spec, len_spec),
        out_specs=(q_spec, tbl_spec))
    o_lat, heat = fn(q_eff, q_rope, pool_ckv, sharded_table, sharded_logical,
                     lengths)
    return o_lat, heat.reshape(B, -1)
