"""Fault tolerance & straggler mitigation for long-running multi-pod jobs.

On a real 1000+-node deployment this wraps the JAX distributed runtime; the
mechanisms here are host-side and runtime-agnostic so they are fully
exercisable (and tested) on one process:

  * StepWatchdog       — per-step wall-time monitor; flags straggling steps
                         (> k x rolling median) and escalates to a restart
                         recommendation after a run of them.  At scale this
                         is the signal used to evict a slow host from the
                         next slice assignment.
  * HeartbeatRegistry  — tracks worker liveness; a missed-heartbeat worker
                         marks the job degraded and triggers
                         checkpoint-restart planning (elastic_plan).
  * elastic_plan       — given a target chip count, pick the largest
                         (data, model) mesh the checkpoint can be resharded
                         onto (model axis preserved first; data shrinks) —
                         consumed by checkpoint.restore on restart.
  * RestartableLoop    — crash-only training-loop wrapper: every step is
                         resumable from (step, ckpt); simulated failures in
                         tests restore and replay deterministically.
"""

from __future__ import annotations

import statistics
import time
from dataclasses import dataclass, field


@dataclass
class StepWatchdog:
    slow_factor: float = 3.0
    escalate_after: int = 3
    window: int = 32
    durations: list = field(default_factory=list)
    slow_steps: int = 0
    consecutive_slow: int = 0
    _t0: float | None = None

    def start(self) -> None:
        self._t0 = time.monotonic()

    def stop(self) -> dict:
        assert self._t0 is not None, "stop() without start()"
        dt = time.monotonic() - self._t0
        self._t0 = None
        return self.record(dt)

    def record(self, dt: float) -> dict:
        med = statistics.median(self.durations) if self.durations else dt
        slow = len(self.durations) >= 4 and dt > self.slow_factor * med
        self.durations.append(dt)
        if len(self.durations) > self.window:
            self.durations.pop(0)
        if slow:
            self.slow_steps += 1
            self.consecutive_slow += 1
        else:
            self.consecutive_slow = 0
        return {
            "duration": dt,
            "median": med,
            "slow": slow,
            "restart_recommended": self.consecutive_slow >= self.escalate_after,
        }


@dataclass
class HeartbeatRegistry:
    timeout_s: float = 60.0
    last_seen: dict = field(default_factory=dict)

    def beat(self, worker: str, now: float | None = None) -> None:
        self.last_seen[worker] = time.monotonic() if now is None else now

    def dead_workers(self, now: float | None = None) -> list[str]:
        now = time.monotonic() if now is None else now
        return [w for w, t in self.last_seen.items()
                if now - t > self.timeout_s]

    def healthy(self, now: float | None = None) -> bool:
        return not self.dead_workers(now)


def elastic_plan(target_chips: int, *, model_axis: int = 16,
                 min_data: int = 1) -> tuple[int, int]:
    """Largest (data, model) mesh fitting ``target_chips``; the model axis is
    preserved if possible (TP degree is baked into kernel block shapes),
    otherwise halved until it fits. Returns (data, model)."""
    m = model_axis
    while m > 1 and target_chips < m * min_data:
        m //= 2
    d = max(min_data, target_chips // m)
    return d, m


class SimulatedFailure(Exception):
    """Raised by tests / chaos hooks to exercise the restart path."""


class RestartableLoop:
    """Crash-only loop: run(step_fn) resumes from the last checkpoint on
    SimulatedFailure (or any transient exception type passed in)."""

    def __init__(self, save_fn, restore_fn, *, max_restarts: int = 3,
                 transient=(SimulatedFailure,)):
        self.save_fn = save_fn
        self.restore_fn = restore_fn
        self.max_restarts = max_restarts
        self.transient = transient
        self.restarts = 0

    def run(self, state, start_step: int, num_steps: int, step_fn,
            checkpoint_every: int = 10):
        step = start_step
        while step < num_steps:
            try:
                state = step_fn(state, step)
                step += 1
                if step % checkpoint_every == 0:
                    self.save_fn(state, step)
            except self.transient:
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    raise
                state, step = self.restore_fn()
        return state, step
