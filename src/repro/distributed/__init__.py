"""Distributed runtime: sharding rules, flash-decoding shard_map path,
gradient compression, fault tolerance / elasticity."""

from .compression import (compress_residual, compressed_psum_mean,
                          dequantize_int8, quantize_int8)
from .fault import (HeartbeatRegistry, RestartableLoop, SimulatedFailure,
                    StepWatchdog, elastic_plan)
from .flashdecode import (get_decode_mesh, paged_decode_attention_sharded,
                          set_decode_mesh)
from .sharding import (DEFAULT_RULES, batch_spec, shardings_for_tree,
                       spec_for, specs_for_tree, zero1_spec,
                       zero1_shardings_for_tree)

__all__ = [n for n in dir() if not n.startswith("_")]
