"""Chrome trace-event JSON exporter (perfetto / chrome://tracing loadable).

Two process tracks:

  * pid 1 ``host`` — the engine's wall-clock spans (step / prefill /
    decode) as complete ("X") events, one thread row per span ``tid``;
  * pid 2 ``modeled`` — the event ring as instant ("i") events.  Program
    and mm events carry the modeled ktime clock, host-side events a wall
    timestamp; both are offset-normalized so the track starts near 0.
    Migration-hop events additionally render as complete ("X") spans on a
    dedicated ``mm migration`` thread row — each hop carries its modeled
    transfer duration (``a2`` ns), so a multi-hop demotion reads as a
    chain of adjacent spans instead of dimensionless ticks.  Profiler
    events get their own ``mm profiler`` row: EV_WSS samples render as
    counter ("C") series (the WSS curve per process), program-emitted
    heat-histogram samples as per-bucket counters, and profile reloads
    as instants.

Timestamps are microseconds (the trace-event format's unit); sub-``us``
durations survive as fractions.
"""

from __future__ import annotations

import json

from .ringbuf import (EV_MIGRATE_HOP, EV_PROFILE, EV_WSS, PROF_TAG_HEAT,
                      tag_name)


def chrome_trace(tel) -> dict:
    events = []
    tids: dict[str, int] = {}

    def tid_of(name: str) -> int:
        if name not in tids:
            tids[name] = len(tids) + 1
            events.append({"ph": "M", "name": "thread_name", "pid": 1,
                           "tid": tids[name], "args": {"name": name}})
        return tids[name]

    events.append({"ph": "M", "name": "process_name", "pid": 1,
                   "args": {"name": "host"}})
    events.append({"ph": "M", "name": "process_name", "pid": 2,
                   "args": {"name": "modeled"}})
    for name, cat, tid, ts0, dur in tel.spans:
        events.append({"ph": "X", "name": name, "cat": cat, "pid": 1,
                       "tid": tid_of(tid), "ts": ts0 / 1000.0,
                       "dur": dur / 1000.0})
    ring = tel.ring.peek()
    base = int(ring[:, 0].min()) if len(ring) else 0
    have_hops = False
    have_prof = False

    def profiler_thread() -> None:
        nonlocal have_prof
        if not have_prof:
            have_prof = True
            events.append({"ph": "M", "name": "thread_name", "pid": 2,
                           "tid": 3, "args": {"name": "mm profiler"}})

    for row in ring:
        ts, tag, a0, a1, a2 = (int(x) for x in row)
        events.append({"ph": "i", "name": tag_name(tag), "cat": "ring",
                       "pid": 2, "tid": 1, "ts": (ts - base) / 1000.0,
                       "s": "t", "args": {"a0": a0, "a1": a1, "a2": a2}})
        if tag == EV_WSS:
            # WSS curve: one counter track per process (working set vs
            # mapped blocks render as stacked series in Perfetto)
            profiler_thread()
            events.append({"ph": "C", "name": f"wss pid{a0}", "pid": 2,
                           "tid": 3, "ts": (ts - base) / 1000.0,
                           "args": {"wss_blocks": a1,
                                    "mapped_blocks": a2 - a1
                                    if a2 > a1 else 0}})
        elif tag == PROF_TAG_HEAT:
            # program-emitted log2 heat histogram: per-bucket region-block
            # counters (a1 = bucket, a2 = blocks in the sampled region)
            profiler_thread()
            events.append({"ph": "C", "name": f"heat b{a1} pid{a0}",
                           "pid": 2, "tid": 3, "ts": (ts - base) / 1000.0,
                           "args": {"blocks": a2}})
        elif tag == EV_PROFILE:
            profiler_thread()
            events.append({"ph": "i", "name": f"profile reload v{a2}",
                           "cat": "profiler", "pid": 2, "tid": 3,
                           "ts": (ts - base) / 1000.0, "s": "t",
                           "args": {"pid": a0, "regions": a1,
                                    "version": a2}})
        if tag == EV_MIGRATE_HOP:
            # span view of the same hop: a0 packs (src_tier<<8)|dst_tier,
            # a2 is the modeled transfer time of this edge
            if not have_hops:
                have_hops = True
                events.append({"ph": "M", "name": "thread_name", "pid": 2,
                               "tid": 2, "args": {"name": "mm migration"}})
            events.append({"ph": "X", "cat": "migration",
                           "name": f"hop t{a0 >> 8}->t{a0 & 0xff}",
                           "pid": 2, "tid": 2, "ts": (ts - base) / 1000.0,
                           "dur": a2 / 1000.0,
                           "args": {"bytes": a1, "ns": a2}})
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def write_chrome_trace(tel, path: str) -> None:
    with open(path, "w") as f:
        json.dump(chrome_trace(tel), f)
