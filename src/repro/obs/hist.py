"""Log2-bucketed histograms, bpftool-profile style.

Bucket ``k`` holds values whose bit length is ``k`` — i.e. bucket 0 is
``v <= 0``, bucket 1 is ``v == 1``, bucket k is ``2^(k-1) <= v < 2^k``
(clamped at 63).  That is exactly the layout ``bpftool prog profile`` and
the classic bcc latency tools print, and it makes observation O(1) with no
preset range.
"""

from __future__ import annotations

import numpy as np

NUM_BUCKETS = 64


class Log2Hist:
    __slots__ = ("counts", "count", "total")

    def __init__(self) -> None:
        self.counts = np.zeros(NUM_BUCKETS, np.int64)
        self.count = 0       # observations
        self.total = 0       # sum of observed values

    @staticmethod
    def bucket(v: int) -> int:
        if v <= 0:
            return 0
        return min(int(v).bit_length(), NUM_BUCKETS - 1)

    @staticmethod
    def bucket_hi(k: int) -> int:
        """Inclusive upper bound of bucket ``k`` (0 for the <=0 bucket)."""
        return 0 if k == 0 else (1 << k) - 1

    def observe(self, v: int) -> None:
        self.counts[self.bucket(v)] += 1
        self.count += 1
        self.total += int(v)

    def observe_many(self, values) -> None:
        values = np.asarray(values, np.int64)
        if values.size == 0:
            return
        pos = np.maximum(values, 1)
        idx = np.minimum(np.floor(np.log2(pos)).astype(np.int64) + 1,
                         NUM_BUCKETS - 1)
        idx = np.where(values <= 0, 0, idx)
        np.add.at(self.counts, idx, 1)
        self.count += int(values.size)
        self.total += int(values.sum())

    def percentile(self, p: float) -> int:
        """Upper bound of the bucket holding the p-th percentile (the
        resolution a log2 histogram offers — same convention bpftool uses
        when summarizing)."""
        if self.count == 0:
            return 0
        target = max(1, int(np.ceil(self.count * p / 100.0)))
        cum = 0
        for k in range(NUM_BUCKETS):
            cum += int(self.counts[k])
            if cum >= target:
                return self.bucket_hi(k)
        return self.bucket_hi(NUM_BUCKETS - 1)

    def snapshot(self) -> dict:
        """Stable export shape: count/sum/percentiles + sparse buckets."""
        return {
            "count": int(self.count),
            "sum": int(self.total),
            "p50": self.percentile(50),
            "p99": self.percentile(99),
            "buckets": {str(k): int(c) for k, c in enumerate(self.counts)
                        if c},
        }
