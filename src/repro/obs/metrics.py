"""Flat Prometheus-style metrics snapshot.

``flatten_metrics`` turns a tree of sections (nested dicts/lists of
numbers, e.g. ``{"mm": mm.stats.snapshot(), "telemetry": tel.snapshot()}``)
into one flat ``{"mm_faults": 42, ...}`` mapping; ``render_prometheus``
prints it in the exposition text format (one ``repro_<key> <value>`` line
per scalar).  Non-numeric leaves are skipped, so arbitrary snapshot dicts
can be fed in unfiltered.
"""

from __future__ import annotations

import re

_SAN = re.compile(r"[^a-zA-Z0-9_]")


def _clean(key: str) -> str:
    return _SAN.sub("_", str(key))


def flatten_metrics(sections: dict, prefix: str = "",
                    out: dict | None = None) -> dict:
    if out is None:
        out = {}
    for key, val in sections.items():
        name = f"{prefix}{_clean(key)}"
        if isinstance(val, bool):
            out[name] = int(val)
        elif isinstance(val, (int, float)):
            out[name] = val
        elif isinstance(val, dict):
            flatten_metrics(val, f"{name}_", out)
        elif isinstance(val, (list, tuple)):
            for i, item in enumerate(val):
                if isinstance(item, dict):
                    flatten_metrics(item, f"{name}_{i}_", out)
                elif isinstance(item, (int, float)) and not isinstance(item, bool):
                    out[f"{name}_{i}"] = item
        # strings / None / arrays: not a metric
    return out


def render_prometheus(flat: dict, namespace: str = "repro") -> str:
    lines = []
    for key in sorted(flat):
        val = flat[key]
        if isinstance(val, float):
            lines.append(f"{namespace}_{key} {val:.6g}")
        else:
            lines.append(f"{namespace}_{key} {val}")
    return "\n".join(lines) + "\n"
