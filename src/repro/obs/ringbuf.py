"""BPF-style event ring buffer.

One fixed record shape for every producer — ``(ts, tag, a0, a1, a2)``,
five int64 words — so the ring is a preallocated ``[capacity, 5]`` numpy
array, not a list of heterogeneous objects.  Overflow follows
``bpf_ringbuf_reserve`` semantics: when the ring is full the *producer*
loses the event and a drop counter increments; nothing is overwritten
(consumers drain explicitly, as bpftool does).

Timestamp convention: events emitted by verified programs and by the
memory-manager tracepoints carry the MODELED clock (``ctx[KTIME_NS]`` /
``mm.ktime_ns``) so their streams are deterministic and bit-identical
across executors; host-side events (hook invocation wall time, compiles)
carry a wall-clock timestamp relative to telemetry start.  The trace
exporter keeps the two timelines on separate tracks.
"""

from __future__ import annotations

import numpy as np

EVENT_FIELDS = ("ts", "tag", "a0", "a1", "a2")

# Framework tracepoint tags (a0/a1/a2 payloads documented per site):
EV_FAULT = 1          # a0=pid, a1=addr, a2=order | hinted<<8
EV_MIGRATE_HOP = 2    # a0=(src_tier<<8)|dst_tier, a1=bytes, a2=modeled ns
EV_RECLAIM = 3        # a0=pid (victim / prefer, -1 none), a1=freed, a2=needed
EV_PREEMPT = 4        # a0=victim pid, a1=blocks freed
EV_HOOK = 5           # a0=hook index, a1=batch size, a2=wall ns
EV_COMPILE = 6        # a0=hook index, a1=segments (-1 = while+switch JIT), a2=wall ns
EV_CACHE = 7          # a0=unroll hits, a1=misses | corrupt_misses<<24
                      # (miss-reason field), a2=disk hits (snapshot at build)
EV_COMPACT = 8        # a0=tier, a1=blocks moved, a2=modeled ns
EV_COLLAPSE = 9       # a0=pid, a1=addr, a2=order

# Resilience tracepoints (modeled-clock timestamps):
EV_DETACH = 10        # a0=hook index, a1=strikes, a2=detach reason
EV_QUARANTINE = 11    # a0=edge, a1=backoff window ns, a2=backoff level
EV_RETRY = 12         # a0=edge, a1=attempt, a2=backoff charged (modeled ns)
EV_READMIT = 13       # a0=edge, a1=errors so far, a2=successes so far

# Prefix-cache tracepoints (modeled-clock timestamps):
EV_CACHE_HIT = 14     # a0=pid, a1=blocks reused, a2=tokens skipped
EV_EVICT = 15         # a0=entry id, a1=blocks, a2=target tier | dropped<<8

# Online-profiling tracepoints (modeled-clock timestamps):
EV_PROFILE = 17       # a0=pid, a1=regions in synthesized profile, a2=version
EV_WSS = 18           # a0=pid, a1=WSS estimate (blocks), a2=mapped blocks

# Program-emitted tags: HELPER_TRACE lands on EV_PROG_TRACE (a0 = r1);
# bpf_ringbuf_output carries an arbitrary program tag in r1 — programs
# should use tags >= EV_PROG_BASE to stay clear of the framework range.
EV_PROG_TRACE = 16
EV_PROG_BASE = 32

# Well-known profiler program tags (mm_profile programs emit these through
# bpf_ringbuf_output; >= EV_PROG_BASE like every program tag).  Defined here
# rather than next to the programs so the exporters can key on them without
# importing the core package:
PROF_TAG_WSS = EV_PROG_BASE + 1       # a0=pid, a1=WSS contribution, a2=blocks
PROF_TAG_HEAT = EV_PROG_BASE + 2      # a0=pid, a1=log2 heat bucket, a2=blocks
PROF_TAG_BENEFIT = EV_PROG_BASE + 3   # a0=region start, a1=best order, a2=net ns

_TAG_NAMES = {
    EV_FAULT: "mm_fault", EV_MIGRATE_HOP: "migrate_hop",
    EV_RECLAIM: "reclaim", EV_PREEMPT: "preempt", EV_HOOK: "hook_invoke",
    EV_COMPILE: "compile", EV_CACHE: "cache", EV_COMPACT: "compact",
    EV_COLLAPSE: "collapse", EV_DETACH: "detach",
    EV_QUARANTINE: "quarantine", EV_RETRY: "migrate_retry",
    EV_READMIT: "readmit", EV_CACHE_HIT: "cache_hit", EV_EVICT: "evict",
    EV_PROG_TRACE: "prog_trace", EV_PROFILE: "profile_reload",
    EV_WSS: "wss_sample", PROF_TAG_WSS: "prof_wss",
    PROF_TAG_HEAT: "prof_heat", PROF_TAG_BENEFIT: "prof_benefit",
}


def tag_name(tag: int) -> str:
    return _TAG_NAMES.get(tag, f"prog_{tag}" if tag >= EV_PROG_BASE
                          else f"tag_{tag}")


class EventRing:
    """Preallocated typed event buffer with drop-on-overflow."""

    def __init__(self, capacity: int = 8192) -> None:
        if capacity < 1:
            raise ValueError("ring capacity must be >= 1")
        self.capacity = int(capacity)
        self.buf = np.zeros((self.capacity, len(EVENT_FIELDS)), np.int64)
        self._n = 0          # live (undrained) records
        self.emitted = 0     # lifetime successful pushes
        self.dropped = 0     # lifetime overflow drops

    def __len__(self) -> int:
        return self._n

    def push(self, ts: int, tag: int, a0: int = 0, a1: int = 0,
             a2: int = 0) -> bool:
        """Append one record; False (and a drop count) when full."""
        if self._n >= self.capacity:
            self.dropped += 1
            return False
        self.buf[self._n] = (ts, tag, a0, a1, a2)
        self._n += 1
        self.emitted += 1
        return True

    def peek(self) -> np.ndarray:
        """Live records (oldest first) WITHOUT consuming them."""
        return self.buf[:self._n]

    def drain(self) -> np.ndarray:
        """Consume and return all live records (oldest first)."""
        out = self.buf[:self._n].copy()
        self._n = 0
        return out

    def snapshot(self) -> dict:
        return {"capacity": self.capacity, "pending": self._n,
                "emitted": self.emitted, "dropped": self.dropped}
