"""Low-overhead telemetry in the Linux BPF observability mold.

Three layers, mirroring the kernel-side tooling the paper's ecosystem
(DAMON, TierBPF, "Cache is King") leans on to evaluate eBPF policies:

  * :mod:`ringbuf` — a preallocated, fixed-capacity, typed event ring
    (``bpf_ringbuf`` style: producers drop on overflow, a counter records
    how many) that both the framework tracepoints and verified programs
    (via the ``bpf_ringbuf_output`` helper) emit into;
  * :mod:`hist` — log2-bucketed histograms (bpftool-profile style) for
    latency/size distributions;
  * :mod:`telemetry` — the per-engine hub tying ring + histograms +
    counters + trace spans together, with :mod:`trace` (Chrome trace-event
    JSON, perfetto-loadable) and :mod:`metrics` (flat Prometheus-style
    snapshot) as exporters.

This package is numpy-only and imports nothing from :mod:`repro.core`, so
the core pipeline can depend on it without cycles.
"""

from .hist import Log2Hist
from .metrics import flatten_metrics, render_prometheus
from .ringbuf import (EV_CACHE, EV_CACHE_HIT, EV_COLLAPSE, EV_COMPACT,
                      EV_COMPILE, EV_DETACH, EV_EVICT, EV_FAULT, EV_HOOK,
                      EV_MIGRATE_HOP, EV_PREEMPT, EV_PROFILE, EV_PROG_BASE,
                      EV_PROG_TRACE, EV_QUARANTINE, EV_READMIT, EV_RECLAIM,
                      EV_RETRY, EV_WSS, EVENT_FIELDS, PROF_TAG_BENEFIT,
                      PROF_TAG_HEAT, PROF_TAG_WSS, EventRing, tag_name)
from .telemetry import Telemetry
from .trace import chrome_trace, write_chrome_trace

__all__ = [
    "EventRing", "EVENT_FIELDS", "tag_name",
    "EV_FAULT", "EV_MIGRATE_HOP", "EV_RECLAIM", "EV_PREEMPT", "EV_HOOK",
    "EV_COMPILE", "EV_CACHE", "EV_COMPACT", "EV_COLLAPSE",
    "EV_DETACH", "EV_QUARANTINE", "EV_RETRY", "EV_READMIT",
    "EV_CACHE_HIT", "EV_EVICT", "EV_PROFILE", "EV_WSS",
    "EV_PROG_TRACE", "EV_PROG_BASE",
    "PROF_TAG_WSS", "PROF_TAG_HEAT", "PROF_TAG_BENEFIT",
    "Log2Hist", "Telemetry",
    "chrome_trace", "write_chrome_trace",
    "flatten_metrics", "render_prometheus",
]
