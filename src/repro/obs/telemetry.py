"""Telemetry hub: one object tying the event ring, the per-hook latency
histograms, plain counters and (optionally) Chrome-trace spans together.

Cost model: the hot paths guard every tracepoint with a single ``tel is
None or not tel.enabled`` check, so an engine built without telemetry (the
default) pays one attribute read + ``is None`` per candidate site and
allocates nothing.  A constructed-but-disabled hub (``enabled=False``) is
the benchmark's "attached, tracing off" lane — every site short-circuits
at the ``enabled`` flag.
"""

from __future__ import annotations

import time
from contextlib import contextmanager

import numpy as np

from .hist import Log2Hist
from .ringbuf import EventRing, tag_name


class Telemetry:
    def __init__(self, *, ring_capacity: int = 8192, trace: bool = False,
                 enabled: bool = True) -> None:
        self.enabled = bool(enabled)
        # spans (Chrome-trace timeline) are opt-in on top of metrics: span
        # bookkeeping appends per step/prefill/decode, which callers only
        # want when they intend to export a trace.
        self.trace_enabled = bool(trace) and self.enabled
        self.ring = EventRing(ring_capacity)
        self.hook_invoke_ns: dict[str, Log2Hist] = {}
        self.hook_batch_size: dict[str, Log2Hist] = {}
        self.migrate_path_ns = Log2Hist()   # modeled cost per migration hop
        self.mgmt_step_ns = Log2Hist()      # wall per management step (bench)
        # Per-request serving latency: wall ns from submit to the first
        # sampled token, and wall ns per generated decode token.
        self.request_ttft_ns = Log2Hist()
        self.decode_token_ns = Log2Hist()
        self.counters: dict[str, int] = {}
        # drops at the PROGRAM layer: per-lane event slots exhausted inside
        # one invocation (distinct from ring overflow, which is host-side)
        self.prog_lane_drops = 0
        # per-(tier, order) residency in block-ticks, grown on demand
        self._residency = np.zeros((1, 1), np.int64)
        self.spans: list[tuple] = []        # (name, cat, tid, ts0_ns, dur_ns)
        self._t0 = time.perf_counter_ns()

    @classmethod
    def disabled(cls) -> "Telemetry":
        return cls(ring_capacity=1, enabled=False)

    def now(self) -> int:
        """Wall clock (ns) relative to telemetry start."""
        return time.perf_counter_ns() - self._t0

    # ------------------------------------------------------------ producers
    def emit(self, tag: int, a0: int = 0, a1: int = 0, a2: int = 0,
             ts: int | None = None) -> None:
        if not self.enabled:
            return
        self.ring.push(self.now() if ts is None else int(ts), tag,
                       int(a0), int(a1), int(a2))

    def inc(self, name: str, v: int = 1) -> None:
        if not self.enabled:
            return
        self.counters[name] = self.counters.get(name, 0) + v

    def observe_hook(self, hook: str, wall_ns: int, batch: int) -> None:
        if not self.enabled:
            return
        h = self.hook_invoke_ns.get(hook)
        if h is None:
            h = self.hook_invoke_ns[hook] = Log2Hist()
            self.hook_batch_size[hook] = Log2Hist()
        h.observe(wall_ns)
        self.hook_batch_size[hook].observe(batch)

    def observe_migrate(self, ns: int) -> None:
        if self.enabled:
            self.migrate_path_ns.observe(ns)

    def observe_ttft(self, wall_ns: int) -> None:
        if self.enabled:
            self.request_ttft_ns.observe(wall_ns)

    def observe_decode_token(self, wall_ns: int, tokens: int = 1) -> None:
        """Per-token decode latency: a decode step that produced ``tokens``
        tokens in ``wall_ns`` contributes one observation per token at the
        per-token share."""
        if self.enabled and tokens > 0:
            per = wall_ns // tokens
            for _ in range(tokens):
                self.decode_token_ns.observe(per)

    def observe_residency(self, tiers, orders, sizes) -> None:
        """Accumulate per-(tier, order) resident block-ticks — callers pass
        the mapping arrays of one process at one sampling tick."""
        if not self.enabled:
            return
        tiers = np.asarray(tiers, np.int64)
        orders = np.asarray(orders, np.int64)
        sizes = np.asarray(sizes, np.int64)
        if tiers.size == 0:
            return
        t_hi = int(tiers.max()) + 1
        o_hi = int(orders.max()) + 1
        if t_hi > self._residency.shape[0] or o_hi > self._residency.shape[1]:
            grown = np.zeros((max(t_hi, self._residency.shape[0]),
                              max(o_hi, self._residency.shape[1])), np.int64)
            grown[:self._residency.shape[0], :self._residency.shape[1]] = \
                self._residency
            self._residency = grown
        np.add.at(self._residency, (tiers, orders), sizes)

    @contextmanager
    def span(self, name: str, cat: str = "engine", tid: str = "engine"):
        """Chrome-trace complete-event span; a cheap no-op pass-through when
        span collection is off."""
        if not self.trace_enabled:
            yield
            return
        t0 = self.now()
        try:
            yield
        finally:
            self.spans.append((name, cat, tid, t0, self.now() - t0))

    # ----------------------------------------------------------- consumers
    def poll_events(self) -> list[dict]:
        """LIVE ring consumer (bpftool ``map event_pipe`` style): drain and
        return every pending event mid-run, oldest first, as
        ``{"ts", "tag", "name", "a0", "a1", "a2"}`` dicts.

        Draining CONSUMES: polled events no longer appear in a later
        Chrome-trace export (the exporter peeks at whatever is still
        pending).  Callers that want both should export the trace first or
        accept the split.  Returns ``[]`` when telemetry is off.
        """
        if not self.enabled:
            return []
        return [{"ts": int(ts), "tag": int(tag), "name": tag_name(int(tag)),
                 "a0": int(a0), "a1": int(a1), "a2": int(a2)}
                for ts, tag, a0, a1, a2 in self.ring.drain()]

    # ------------------------------------------------------------- exports
    def snapshot(self) -> dict:
        hooks = {}
        for name, h in self.hook_invoke_ns.items():
            hooks[name] = {"invoke_ns": h.snapshot(),
                           "batch_size": self.hook_batch_size[name].snapshot()}
        ring = self.ring.snapshot()
        ring["prog_lane_drops"] = int(self.prog_lane_drops)
        return {
            "enabled": self.enabled,
            "ring": ring,
            "hooks": hooks,
            "migrate_path_ns": self.migrate_path_ns.snapshot(),
            "mgmt_step_ns": self.mgmt_step_ns.snapshot(),
            "request_ttft_ns": self.request_ttft_ns.snapshot(),
            "decode_token_ns": self.decode_token_ns.snapshot(),
            "counters": dict(self.counters),
            "residency_block_ticks": {
                f"t{t}_o{o}": int(v)
                for (t, o), v in np.ndenumerate(self._residency) if v},
        }
