"""Checkpointing: sharded, manifest-driven, async-capable, elastic.

Layout of one checkpoint:
    <dir>/step_000123/
        manifest.json     {step, tree structure, leaf shapes/dtypes, mesh}
        shard_<i>.npz     flattened leaves (split round-robin into shards so
                          restore can be parallelized / partially read)
        _COMMITTED        written LAST — a checkpoint without it is garbage
                          (crash-consistent commit protocol)

Elasticity: restore() only needs the manifest + shards; the caller passes the
NEW mesh/shardings (possibly a different device count — see
distributed.fault.elastic_plan) and leaves are device_put with the new
sharding.  Host RAM is the staging buffer, which matches the
checkpoint-via-host path used at scale.

Async: save(..., blocking=False) snapshots to host then writes on a worker
thread; wait() joins.  The commit marker ordering keeps crash windows safe.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path
from typing import Any

import jax
import numpy as np

Pytree = Any


class CheckpointStore:
    def __init__(self, directory: str | os.PathLike, *, num_shards: int = 4):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.num_shards = num_shards
        self._pending: threading.Thread | None = None

    # ------------------------------------------------------------------ save
    def save(self, tree: Pytree, *, step: int, keep: int | None = None,
             blocking: bool = True) -> Path:
        self.wait()
        leaves, treedef = jax.tree.flatten(tree)
        host_leaves = [np.asarray(l) for l in leaves]   # host snapshot NOW
        path = self.dir / f"step_{step:09d}"

        def write():
            tmp = path.with_suffix(".tmp")
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            manifest = {
                "step": step,
                "treedef": str(treedef),
                "num_leaves": len(host_leaves),
                "num_shards": self.num_shards,
                "leaves": [{"shape": list(l.shape), "dtype": str(l.dtype)}
                           for l in host_leaves],
            }
            for s in range(self.num_shards):
                arrs = {f"leaf_{i}": host_leaves[i]
                        for i in range(s, len(host_leaves), self.num_shards)}
                np.savez(tmp / f"shard_{s}.npz", **arrs)
            (tmp / "manifest.json").write_text(json.dumps(manifest))
            (tmp / "_COMMITTED").touch()
            if path.exists():
                shutil.rmtree(path)
            tmp.rename(path)
            if keep is not None:
                self._gc(keep)

        if blocking:
            write()
        else:
            self._pending = threading.Thread(target=write, daemon=True)
            self._pending.start()
        self._treedef = treedef
        return path

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _gc(self, keep: int) -> None:
        steps = self.all_steps()
        for s in steps[:-keep]:
            shutil.rmtree(self.dir / f"step_{s:09d}", ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        out = []
        for p in sorted(self.dir.glob("step_*")):
            if (p / "_COMMITTED").exists():
                out.append(int(p.name.split("_")[1]))
        return out

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, *, like: Pytree | None = None,
                shardings: Pytree | None = None) -> tuple[Pytree, dict]:
        """Restore step. ``like`` provides the treedef (required unless save()
        ran in this process); ``shardings`` (same structure) device_puts each
        leaf with the given (possibly NEW-mesh) sharding — the elastic path.
        """
        path = self.dir / f"step_{step:09d}"
        if not (path / "_COMMITTED").exists():
            raise FileNotFoundError(f"no committed checkpoint at {path}")
        manifest = json.loads((path / "manifest.json").read_text())
        n = manifest["num_leaves"]
        leaves: list = [None] * n
        for s in range(manifest["num_shards"]):
            with np.load(path / f"shard_{s}.npz") as z:
                for key in z.files:
                    leaves[int(key.split("_")[1])] = z[key]
        if like is not None:
            treedef = jax.tree.structure(like)
        else:
            treedef = self._treedef
        tree = jax.tree.unflatten(treedef, leaves)
        if shardings is not None:
            tree = jax.tree.map(
                lambda arr, sh: jax.device_put(arr, sh), tree, shardings)
        return tree, {"step": manifest["step"]}
