"""AdamW with decoupled weight decay, global-norm clipping, f32 master
weights over bf16 compute params (mixed-precision policy lives here)."""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

Pytree = Any
F32 = jnp.float32


class AdamWState(NamedTuple):
    step: jax.Array          # int32 scalar
    mu: Pytree               # f32, like params
    nu: Pytree               # f32, like params


def adamw_init(params: Pytree) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                      nu=jax.tree.map(jnp.copy, zeros))


def global_norm(tree: Pytree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(F32))) for l in leaves))


def adamw_update(params: Pytree, grads: Pytree, state: AdamWState, *,
                 lr: jax.Array, b1: float = 0.9, b2: float = 0.95,
                 eps: float = 1e-8, weight_decay: float = 0.1,
                 clip_norm: float | None = 1.0):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    if clip_norm is not None:
        scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g.astype(F32) * scale, grads)
    else:
        grads = jax.tree.map(lambda g: g.astype(F32), grads)

    step = state.step + 1
    t = step.astype(F32)
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
    nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state.nu, grads)

    def upd(p, m, v):
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(F32)
        return (p.astype(F32) - lr * delta).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    metrics = {"grad_norm": gnorm,
               "update_norm": lr * jnp.ones((), F32)}
    return new_params, AdamWState(step=step, mu=mu, nu=nu), metrics
