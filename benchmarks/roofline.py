"""Roofline analysis over the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch x shape) on the single-pod 16x16 mesh:

    compute term    = FLOPs / (chips * 197e12)        [bf16 peak, v5e]
    memory term     = HBM bytes / (chips * 819e9)
    collective term = per-chip collective bytes / 50e9 [per-link ICI]

FLOPs/bytes come from benchmarks.flops_model (analytic, exact for the model
code — the CPU backend's cost_analysis misses while-loop trip counts; its raw
numbers are reported alongside).  Collective bytes come from the compiled
HLO, with scan-body collectives weighted by trip count (dryrun.py).
"""

from __future__ import annotations

import glob
import json
from pathlib import Path

from repro.configs.base import SHAPES, get_config
from repro.core.cost import HWSpec

from .flops_model import cell_bytes, cell_flops, model_flops_6nd

HW = HWSpec()


def load_records(dryrun_dir: str = "results/dryrun") -> list[dict]:
    recs = []
    for f in sorted(glob.glob(f"{dryrun_dir}/*.json")):
        recs.append(json.loads(Path(f).read_text()))
    return recs


def roofline_row(rec: dict) -> dict | None:
    if not rec.get("ok"):
        return None
    cfg = get_config(rec["arch"])
    shape = SHAPES[rec["shape"]]
    chips = rec["devices"]
    fl = cell_flops(cfg, shape)
    by = cell_bytes(cfg, shape)
    mf = model_flops_6nd(cfg, shape)

    t_compute = fl["hlo_equiv"] / (chips * HW.peak_flops_bf16)
    t_memory = by["total"] / (chips * HW.hbm_bw)
    coll_dev = rec["collectives"].get("weighted_total_bytes",
                                      rec["collectives"]["total_bytes"])
    t_coll = coll_dev / HW.ici_bw_per_link

    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    t_step = max(terms.values())
    # roofline fraction: useful-model-FLOPs time / bound step time
    t_model = mf["model_flops"] / (chips * HW.peak_flops_bf16)
    frac = t_model / t_step if t_step > 0 else 0.0
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "attn_impl": rec.get("attn_impl", "gather"),
        "chips": chips,
        "compute_s": t_compute, "memory_s": t_memory, "collective_s": t_coll,
        "dominant": dominant,
        "model_flops": mf["model_flops"], "hlo_equiv_flops": fl["hlo_equiv"],
        "useful_ratio": mf["model_flops"] / fl["hlo_equiv"],
        "roofline_fraction": frac,
        "hbm_bytes": by["total"],
        "coll_bytes_per_dev": coll_dev,
        "coll_raw_bytes_per_dev": rec["collectives"]["total_bytes"],
        "cost_analysis_flops_per_dev": rec.get("cost_analysis", {}).get("flops"),
        "arg_bytes_per_device": rec.get("arg_bytes_per_device"),
        "compile_s": rec.get("compile_s"),
    }


def build_table(dryrun_dir: str = "results/dryrun", mesh: str = "single",
                attn: str | None = None) -> list[dict]:
    rows = []
    for rec in load_records(dryrun_dir):
        if rec.get("mesh") != mesh:
            continue
        if attn is not None and rec.get("attn_impl") != attn:
            continue
        row = roofline_row(rec)
        if row:
            rows.append(row)
    rows.sort(key=lambda r: (r["arch"], r["shape"], r["attn_impl"]))
    return rows


def fmt_us(s: float) -> str:
    return f"{s*1e6:10.1f}"


def print_table(rows: list[dict]) -> None:
    hdr = (f"{'arch':<22}{'shape':<13}{'attn':<14}"
           f"{'compute_us':>11}{'memory_us':>11}{'coll_us':>11}"
           f"  {'dominant':<11}{'frac':>6}{'useful':>7}")
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        print(f"{r['arch']:<22}{r['shape']:<13}{r['attn_impl']:<14}"
              f"{fmt_us(r['compute_s']):>11}{fmt_us(r['memory_s']):>11}"
              f"{fmt_us(r['collective_s']):>11}"
              f"  {r['dominant']:<11}{r['roofline_fraction']:>6.2f}"
              f"{r['useful_ratio']:>7.2f}")


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun-dir", default="results/dryrun")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--out", default="results/roofline.json")
    args = ap.parse_args()
    rows = build_table(args.dryrun_dir, args.mesh)
    print_table(rows)
    Path(args.out).write_text(json.dumps(rows, indent=1))
    print(f"\nwrote {args.out} ({len(rows)} rows)")


if __name__ == "__main__":
    main()
