"""CI perf gate: the cross-request KV prefix cache must pay for itself.

Holds the acceptance numbers of the prefix-cache PR at 50% shared-prefix
traffic — the break-even point the design targets:

- ``prefill_token_reduction >= 1.5`` — tokens actually run through the
  prefill kernel with the cache ON must be at most 2/3 of the cache-OFF
  count (a hit skips the shared span; only the suffix prefills);
- admission hit rate stays >= 45% AND does not regress against the
  committed ``BENCH_prefix.json`` — the warm shared chain must keep
  hitting (a doorkeeper or eviction regression that flushes the hot
  prefix trips this long before the wall-clock does);
- cache-on steps/s STRICTLY exceeds cache-off — the bookkeeping
  (hashing, pinning, CoW, scans) must cost less than the prefill work it
  saves.

Both lanes run on the same engines across attempts (pass 0 warms every
jit bucket and the cache itself outside the clock).  Host jitter on
shared CI runners can flip a marginal wall-clock run, so the throughput
ratio takes the BEST of up to three attempts; the token-reduction and
hit-rate invariants are jitter-free and must hold on EVERY attempt.

Run:  PYTHONPATH=src python -m benchmarks.prefix_gate [BASELINE_JSON]
"""

from __future__ import annotations

import json
import pathlib
import sys

from benchmarks.prefix_bench import _setup, build_engine, run_pass

SHARE = 0.5
ATTEMPTS = 3
REDUCTION_MIN = 1.5
RATIO_MIN = 1.0                 # "strictly higher" — any margin passes
HIT_RATE_MIN_MILLI = 450


def _baseline_hit_rate(path: pathlib.Path) -> int:
    """Committed hit rate (milli) for the 50% cell; 0 if no artifact."""
    if not path.exists():
        return 0
    with open(path) as f:
        doc = json.load(f)
    cell = doc["summary"].get(f"share_{int(SHARE * 100)}")
    return int(cell["hit_rate_milli"]) if cell else 0


def main(argv: list[str]) -> int:
    path = pathlib.Path(argv[0]) if argv else \
        pathlib.Path(__file__).resolve().parent.parent / "BENCH_prefix.json"
    hit_floor = max(HIT_RATE_MIN_MILLI, _baseline_hit_rate(path))
    setup = _setup()
    off = build_engine(setup, cache_on=False)
    on = build_engine(setup, cache_on=True)
    for eng in (on, off):       # warm: compiles + cache admission, untimed
        run_pass(eng, share=SHARE, seed=0, rid_base=90_000)
    best = 0.0
    for attempt in range(1, ATTEMPTS + 1):
        r_off = run_pass(off, share=SHARE, seed=attempt,
                         rid_base=attempt * 1000)
        r_on = run_pass(on, share=SHARE, seed=attempt,
                        rid_base=attempt * 1000)
        ratio = r_on["steps_per_s"] / r_off["steps_per_s"]
        reduction = r_off["prefill_tokens"] / max(1, r_on["prefill_tokens"])
        hit_rate = r_on["hit_rate_milli"]
        best = max(best, ratio)
        print(f"attempt {attempt}: on={r_on['steps_per_s']:.1f} "
              f"off={r_off['steps_per_s']:.1f} steps/s ratio={ratio:.3f} "
              f"prefill_reduction={reduction:.2f}x "
              f"hit_rate={hit_rate / 10:.1f}%")
        if reduction < REDUCTION_MIN:
            print(f"FAIL: prefill token reduction {reduction:.2f}x < "
                  f"{REDUCTION_MIN}x — hits are not skipping the shared span")
            return 1
        if hit_rate < hit_floor:
            print(f"FAIL: hit rate {hit_rate / 10:.1f}% < "
                  f"{hit_floor / 10:.1f}% (committed baseline "
                  f"{path.name}) — the warm shared chain is not being "
                  f"found (admission or eviction regression)")
            return 1
        if best > RATIO_MIN:
            print(f"PASS: cache-on strictly faster at {int(SHARE * 100)}% "
                  f"shared-prefix traffic (best ratio {best:.3f}), "
                  f"reduction {reduction:.2f}x")
            return 0
    print(f"FAIL: best steps/s ratio {best:.3f} <= {RATIO_MIN} on every "
          f"attempt — the cache no longer pays for its bookkeeping")
    return 1


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
