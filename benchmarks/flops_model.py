"""Analytic FLOP / byte model per (arch x shape) cell.

The CPU backend's HLO cost analysis visits while-loop bodies ONCE (verified:
exact on a plain matmul, ~L x low on scanned models), so the roofline's
compute/memory terms come from this analytic model — exact matmul accounting
of the very model code in repro.models — and the dry-run JSON numbers are
kept as secondary artifacts.

Conventions: FLOPs are global per step (multiply-add = 2 FLOPs); bytes are
global per step over HBM.  MODEL_FLOPS follows the assignment: 6*N*D for
dense, 6*N_active*D for MoE (D = tokens per step); SCHED_FLOPS additionally
counts the remat re-forward for training (fwd+refwd+bwd = 4x fwd).
"""

from __future__ import annotations

from dataclasses import dataclass

from repro.configs.base import ModelConfig, ShapeSpec
from repro.models.common import pad_vocab
from repro.models.transformer import build_layer_plans

GLU = ("swiglu", "geglu")


def _attn_len(cfg: ModelConfig, plan, S: int, kind: str) -> float:
    """Average attended KV length per query token."""
    window = cfg.attn.window if plan.local else None
    if kind == "decode":
        return float(min(window, S)) if window else float(S)
    full = (S + 1) / 2.0
    return float(min(window, full)) if window else full


def layer_fwd_flops(cfg: ModelConfig, plan, T: float, S: int,
                    kind: str) -> float:
    d = cfg.d_model
    f = 0.0
    if plan.kind == "mamba":
        m = cfg.mamba
        di = m.expand * d
        H = di // m.head_dim
        N = m.d_state
        f += 2 * T * d * (2 * di + 2 * N + H)            # in_proj
        f += 2 * T * m.conv_dim * (di + 2 * N)            # causal conv
        if kind == "decode":
            f += 3 * 2 * T * di * N                        # state update + out
        else:
            c = min(m.chunk, S)
            f += 2 * T * c * N                             # C.B scores
            f += 2 * T * c * di                            # intra M@x
            f += 4 * T * N * di                            # states + inter
        f += 2 * T * di * d                                # out_proj
    elif cfg.mla is not None:
        m = cfg.mla
        H = cfg.n_heads
        L_att = _attn_len(cfg, plan, S, kind)
        f += 2 * T * d * H * (m.qk_nope + m.qk_rope)       # q proj
        f += 2 * T * d * (m.kv_lora + m.qk_rope)           # dkv proj
        if kind == "decode":
            # absorbed: q_eff + scores over latents + out latents + uv
            f += 2 * T * H * m.qk_nope * m.kv_lora
            f += 2 * T * H * L_att * (m.kv_lora + m.qk_rope)
            f += 2 * T * H * L_att * m.kv_lora
            f += 2 * T * H * m.kv_lora * m.v_head
        else:
            f += 2 * T * m.kv_lora * H * (m.qk_nope + m.v_head)  # expand k,v
            f += 2 * 2 * T * L_att * H * (m.qk_nope + m.qk_rope)
        f += 2 * T * H * m.v_head * d                      # out proj
    else:
        H, KVH, hd = cfg.n_heads, cfg.kv_heads, cfg.head_dim
        L_att = _attn_len(cfg, plan, S, kind)
        f += 2 * T * d * (H + 2 * KVH) * hd                # qkv proj
        f += 2 * 2 * T * L_att * H * hd                    # QK^T and PV
        f += 2 * T * H * hd * d                            # out proj
    if plan.xattn:
        H, KVH, hd = cfg.n_heads, cfg.kv_heads, cfg.head_dim
        Fx = cfg.enc_frames
        f += 2 * T * d * (H + 2 * KVH) * hd + 2 * 2 * T * Fx * H * hd \
            + 2 * T * H * hd * d
    if plan.ffn:
        mults = 3 if cfg.mlp in GLU else 2
        if plan.moe:
            mo = cfg.moe
            f += 2 * T * d * mo.num_experts                # router
            f += 2 * T * mo.top_k * d * mo.d_ff_expert * mults
            if mo.num_shared:
                f += 2 * T * d * mo.num_shared * mo.d_ff_expert * mults
        else:
            f += 2 * T * d * cfg.d_ff * mults
    return f


def fwd_flops(cfg: ModelConfig, shape: ShapeSpec) -> float:
    kind = shape.kind
    B, S = shape.global_batch, shape.seq_len
    T = float(B if kind == "decode" else B * S)
    total = 0.0
    for plan in build_layer_plans(cfg):
        total += layer_fwd_flops(cfg, plan, T, S, kind)
    if cfg.enc_dec:
        enc_plan = build_layer_plans(cfg)[0].__class__(kind="attn",
                                                       causal=False)
        Tenc = float(B * cfg.enc_frames) if kind != "decode" else 0.0
        for _ in range(cfg.enc_layers):
            total += layer_fwd_flops(cfg, enc_plan, Tenc, cfg.enc_frames,
                                     "prefill")
    total += 2 * T * cfg.d_model * pad_vocab(cfg.vocab)    # lm head
    return total


def cell_flops(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    F = fwd_flops(cfg, shape)
    if shape.kind == "train":
        return {"fwd": F, "hlo_equiv": 4 * F,   # fwd + remat refwd + bwd(2x)
                "no_remat": 3 * F}
    return {"fwd": F, "hlo_equiv": F, "no_remat": F}


# ---------------------------------------------------------------------------
# Parameter & traffic model
# ---------------------------------------------------------------------------

def param_count_analytic(cfg: ModelConfig) -> float:
    from repro.models.common import param_count
    from repro.models.transformer import model_spec
    return float(param_count(model_spec(cfg)))


def active_param_count(cfg: ModelConfig) -> float:
    """Activated params per token (MoE: routed top-k only + shared)."""
    total = param_count_analytic(cfg)
    if cfg.moe is None:
        return total
    mo = cfg.moe
    mults = 3 if cfg.mlp in GLU else 2
    expert_params = mults * cfg.d_model * mo.d_ff_expert
    n_moe_layers = sum(cfg.moe_layers())
    inactive = n_moe_layers * (mo.num_experts - mo.top_k) * expert_params
    return total - inactive


def kv_token_bytes(cfg: ModelConfig, dtype_bytes: int = 2) -> float:
    """KV-cache bytes per token per attention layer."""
    if cfg.mla is not None:
        return (cfg.mla.kv_lora + cfg.mla.qk_rope) * dtype_bytes
    return 2 * cfg.kv_heads * cfg.head_dim * dtype_bytes


def cell_bytes(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """Global HBM bytes per step (estimate; labeled terms)."""
    B, S = shape.global_batch, shape.seq_len
    kind = shape.kind
    P = param_count_analytic(cfg)
    plans = build_layer_plans(cfg)
    n_attn = sum(1 for p in plans if p.kind == "attn")
    kvb = kv_token_bytes(cfg)
    out = {}
    if kind == "train":
        T = B * S
        # params bf16 read (fwd+refwd+bwd ~3x) + f32 master rw + moments rw + grad
        out["params"] = P * (3 * 2 + 4 * 2 + 8 * 2 + 4)
        out["activations"] = len(plans) * T * cfg.d_model * 2 * 8
        out["logits"] = T * pad_vocab(cfg.vocab) * 4 * 2
    elif kind == "prefill":
        T = B * S
        out["params"] = P * 2
        out["activations"] = len(plans) * T * cfg.d_model * 2 * 4
        out["kv_write"] = T * kvb * n_attn
    else:
        out["params"] = P * 2
        kv_read = 0.0
        for p in plans:
            if p.kind != "attn":
                continue
            L_att = _attn_len(cfg, p, S, "decode")
            kv_read += B * L_att * kvb
        if cfg.mamba is not None:
            di = cfg.mamba.expand * cfg.d_model
            H = di // cfg.mamba.head_dim
            n_m = sum(1 for p in plans if p.kind == "mamba")
            kv_read += 2 * B * H * cfg.mamba.d_state * cfg.mamba.head_dim * \
                4 * n_m                      # ssm state rw
        out["kv_read"] = kv_read
        out["kv_write"] = B * kvb * n_attn
    out["total"] = sum(out.values())
    return out


def model_flops_6nd(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    D = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    N = param_count_analytic(cfg)
    Na = active_param_count(cfg)
    mult = 6 if shape.kind == "train" else 2
    return {"model_flops": mult * Na * D, "params": N, "active_params": Na,
            "tokens": D}
